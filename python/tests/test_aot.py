"""AOT pipeline tests: artifacts lower to loadable HLO text with the
expected entry shapes, and the lowered graphs compute the same values as
the eager models (executed via jax on the same CPU backend the Rust PJRT
client uses)."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot, model


def test_build_artifacts(tmp_path):
    meta = aot.build_artifacts(str(tmp_path))
    for name in ("tile_matmul", "cluster_compute", "noc_perf"):
        path = tmp_path / f"{name}.hlo.txt"
        assert path.exists(), name
        text = path.read_text()
        assert text.startswith("HloModule"), f"{name} is not HLO text"
        assert meta["artifacts"][name]["hlo_chars"] == len(text)
    m = json.loads((tmp_path / "meta.json").read_text())
    assert m["tile_dim"] == model.TILE_DIM
    assert m["dse_mesh_n"] == model.DSE_MESH_N


def test_lowered_matmul_matches_eager():
    d = model.TILE_DIM
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((d, d)), dtype=jnp.float32)
    w = jnp.asarray(rng.standard_normal((d, d)), dtype=jnp.float32)
    lowered = aot.lower_entry(
        model.tile_matmul,
        (jax.ShapeDtypeStruct((d, d), jnp.float32),) * 2,
    )
    compiled = lowered.compile()
    got = compiled(x, w)
    np.testing.assert_allclose(got, model.tile_matmul(x, w), rtol=1e-5, atol=1e-5)


def test_lowered_noc_perf_matches_eager():
    n = model.DSE_MESH_N
    rng = np.random.default_rng(1)
    t = jnp.asarray(rng.uniform(0, 1, (n * n, n * n)), dtype=jnp.float32)
    lowered = aot.lower_entry(
        model.noc_perf, (jax.ShapeDtypeStruct((n * n, n * n), jnp.float32),)
    )
    loads, mx, mean, sat = lowered.compile()(t)
    eloads, emx, emean, esat = model.noc_perf(t)
    np.testing.assert_allclose(loads, eloads, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(mx), float(emx), rtol=1e-5)
    np.testing.assert_allclose(float(sat), float(esat), rtol=1e-5)
    del mean, emean


def test_hlo_text_is_self_contained(tmp_path):
    """The artifact must not contain custom-calls the CPU PJRT client
    cannot execute (the interpret=True guarantee)."""
    aot.build_artifacts(str(tmp_path))
    for name in ("tile_matmul", "cluster_compute", "noc_perf"):
        text = (tmp_path / f"{name}.hlo.txt").read_text()
        assert "mosaic" not in text.lower(), f"{name} contains a Mosaic call"


def test_makefile_artifact_dir_default():
    # aot.py writes ../artifacts relative to python/: the Makefile contract.
    assert "artifacts" in os.path.normpath(
        os.path.join("python", "..", "artifacts")
    )
