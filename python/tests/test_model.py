"""Layer-2 model tests: shapes, semantics, and the link-load model's
agreement between the Pallas path and the oracle."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


class TestClusterCompute:
    def test_matches_ref(self):
        d = model.TILE_DIM
        x, w, b = rand((d, d), 0), rand((d, d), 1), rand((d,), 2)
        got = model.cluster_compute(x, w, b)
        want = ref.cluster_compute_ref(x, w, b)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_relu_clamps(self):
        d = model.TILE_DIM
        x = jnp.zeros((d, d), jnp.float32)
        w = jnp.zeros((d, d), jnp.float32)
        b = jnp.full((d,), -1.0, jnp.float32)
        out = model.cluster_compute(x, w, b)
        assert float(out.max()) == 0.0

    def test_tile_matmul_matches_ref(self):
        d = model.TILE_DIM
        x, w = rand((d, d), 3), rand((d, d), 4)
        np.testing.assert_allclose(
            model.tile_matmul(x, w), ref.matmul_ref(x, w), rtol=1e-5, atol=1e-5
        )


class TestLinkLoads:
    def test_matches_oracle_uniform(self):
        n = 4
        t = jnp.ones((n * n, n * n), jnp.float32) / (n * n)
        got = model.link_loads(t, n)
        want = ref.link_loads_ref(t, n)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_single_flow_path(self):
        # Node (0,0) -> (2,1) on a 3x3 mesh: X leg crosses E links at
        # (0,0) and (1,0); Y leg crosses the N link at (2,0).
        n = 3
        t = np.zeros((9, 9), np.float32)
        t[0, 1 * n + 2] = 1.0
        loads = np.asarray(model.link_loads(jnp.asarray(t), n))
        east, west, north, south = loads
        assert east[0, 0] == 1.0 and east[0, 1] == 1.0
        assert east.sum() == 2.0
        assert north[0, 2] == 1.0 and north.sum() == 1.0
        assert west.sum() == 0.0 and south.sum() == 0.0

    def test_boundary_links_unused(self):
        # The E link of the last column / N link of the top row can never
        # be used by XY routing inside the mesh.
        n = 4
        rng = np.random.default_rng(7)
        t = jnp.asarray(rng.uniform(0, 1, (16, 16)).astype(np.float32))
        loads = np.asarray(model.link_loads(t, n))
        east, west, north, south = loads
        assert east[:, n - 1].sum() == 0.0
        assert west[:, n - 1].sum() == 0.0  # bwd[p] stored at p = n-1 unused
        assert north[n - 1, :].sum() == 0.0

    @settings(max_examples=10, deadline=None)
    @given(n=st.sampled_from([2, 3, 4, 5]), seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_matches_oracle(self, n, seed):
        rng = np.random.default_rng(seed)
        t = jnp.asarray(
            rng.uniform(0, 1, (n * n, n * n)).astype(np.float32)
        )
        got = model.link_loads(t, n)
        want = ref.link_loads_ref(t, n)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_noc_perf_summary(self):
        n = model.DSE_MESH_N
        t = jnp.ones((n * n, n * n), jnp.float32) * 0.01
        loads, max_load, mean_load, sat = model.noc_perf(t)
        assert loads.shape == (4, n, n)
        assert float(max_load) >= float(mean_load) > 0.0
        # Saturation scale is the inverse of the max link load.
        np.testing.assert_allclose(float(sat) * float(max_load), 1.0, rtol=1e-5)

    def test_conservation(self):
        # Total (fwd+bwd) X-leg load equals sum of |dx - sx| per flow;
        # same for Y legs — the interval model conserves hop counts.
        n = 4
        rng = np.random.default_rng(11)
        t = rng.uniform(0, 1, (16, 16)).astype(np.float32)
        loads = np.asarray(model.link_loads(jnp.asarray(t), n))
        coords = [(i % n, i // n) for i in range(16)]
        want_x = sum(
            t[s, d] * abs(coords[d][0] - coords[s][0])
            for s in range(16)
            for d in range(16)
        )
        want_y = sum(
            t[s, d] * abs(coords[d][1] - coords[s][1])
            for s in range(16)
            for d in range(16)
        )
        np.testing.assert_allclose(loads[0].sum() + loads[1].sum(), want_x, rtol=1e-4)
        np.testing.assert_allclose(loads[2].sum() + loads[3].sum(), want_y, rtol=1e-4)
