"""Kernel-vs-oracle correctness: the core build-time signal.

Every Pallas kernel must match its pure-jnp reference; hypothesis sweeps
shapes and values.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import link_load, matmul, ref


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


# --------------------------------------------------------------- matmul


class TestMatmul:
    def test_square_exact_blocks(self):
        x, w = rand((64, 64), 0), rand((64, 64), 1)
        got = matmul.matmul(x, w, bm=32, bn=32, bk=32)
        np.testing.assert_allclose(got, ref.matmul_ref(x, w), rtol=1e-5, atol=1e-5)

    def test_rectangular(self):
        x, w = rand((32, 96), 2), rand((96, 64), 3)
        got = matmul.matmul(x, w, bm=16, bn=16, bk=32)
        np.testing.assert_allclose(got, ref.matmul_ref(x, w), rtol=1e-5, atol=1e-5)

    def test_single_block(self):
        x, w = rand((16, 16), 4), rand((16, 16), 5)
        got = matmul.matmul(x, w, bm=16, bn=16, bk=16)
        np.testing.assert_allclose(got, ref.matmul_ref(x, w), rtol=1e-5, atol=1e-5)

    def test_k_accumulation_many_steps(self):
        # 8 K-steps: exercises the revisited-output accumulator.
        x, w = rand((16, 128), 6), rand((128, 16), 7)
        got = matmul.matmul(x, w, bm=16, bn=16, bk=16)
        np.testing.assert_allclose(got, ref.matmul_ref(x, w), rtol=1e-5, atol=1e-5)

    def test_rejects_non_tiling_shapes(self):
        x, w = rand((10, 10), 8), rand((10, 10), 9)
        with pytest.raises(AssertionError):
            matmul.matmul(x, w, bm=16, bn=16, bk=16)

    @settings(max_examples=20, deadline=None)
    @given(
        mb=st.integers(1, 4),
        nb=st.integers(1, 4),
        kb=st.integers(1, 4),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shape_sweep(self, mb, nb, kb, seed):
        bm = bn = bk = 8
        x = rand((mb * bm, kb * bk), seed)
        w = rand((kb * bk, nb * bn), seed + 1)
        got = matmul.matmul(x, w, bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(got, ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4)

    def test_identity(self):
        x = rand((32, 32), 10)
        eye = jnp.eye(32, dtype=jnp.float32)
        got = matmul.matmul(x, eye, bm=16, bn=16, bk=16)
        np.testing.assert_allclose(got, x, rtol=1e-6, atol=1e-6)

    def test_vmem_footprint_estimator(self):
        # (128,128,128) f32 blocks: 128*128*4 = 64 KiB each, 4 blocks total.
        assert matmul.vmem_footprint_bytes(128, 128, 128) == 4 * 128 * 128 * 4

    def test_mxu_estimate_perfect_at_128(self):
        assert matmul.mxu_utilization_estimate(128, 128, 128) == 1.0
        assert matmul.mxu_utilization_estimate(64, 128, 128) == 0.5


# ----------------------------------------------------------- interval load


class TestIntervalLoad:
    def test_matches_ref_basic(self):
        w = rand((4, 8, 8), 11) ** 2  # non-negative traffic
        fwd, bwd = link_load.interval_load(w)
        rfwd, rbwd = ref.interval_load_ref(w)
        np.testing.assert_allclose(fwd, rfwd, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(bwd, rbwd, rtol=1e-5, atol=1e-6)

    def test_single_flow_forward(self):
        # One unit of traffic 1 -> 3 crosses links 1->2 and 2->3.
        w = np.zeros((1, 4, 4), dtype=np.float32)
        w[0, 1, 3] = 1.0
        fwd, bwd = link_load.interval_load(jnp.asarray(w))
        np.testing.assert_allclose(fwd[0], [0, 1, 1, 0])
        np.testing.assert_allclose(bwd[0], [0, 0, 0, 0])

    def test_single_flow_backward(self):
        w = np.zeros((1, 4, 4), dtype=np.float32)
        w[0, 3, 0] = 2.0
        fwd, bwd = link_load.interval_load(jnp.asarray(w))
        np.testing.assert_allclose(fwd[0], [0, 0, 0, 0])
        np.testing.assert_allclose(bwd[0], [2, 2, 2, 0])

    def test_self_traffic_loads_nothing(self):
        w = jnp.asarray(np.diag([1.0] * 5)[None].astype(np.float32))
        fwd, bwd = link_load.interval_load(w)
        assert float(fwd.sum()) == 0.0
        assert float(bwd.sum()) == 0.0

    @settings(max_examples=15, deadline=None)
    @given(
        g=st.integers(1, 6),
        n=st.integers(2, 10),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_matches_ref(self, g, n, seed):
        rng = np.random.default_rng(seed)
        w = jnp.asarray(
            rng.uniform(0, 2, size=(g, n, n)).astype(np.float32)
        )
        fwd, bwd = link_load.interval_load(w)
        rfwd, rbwd = ref.interval_load_ref(w)
        np.testing.assert_allclose(fwd, rfwd, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(bwd, rbwd, rtol=1e-5, atol=1e-5)
