"""AOT lowering: JAX models -> HLO text artifacts for the Rust runtime.

HLO *text* (not serialized ``HloModuleProto``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly. Lowering uses
``return_tuple=True`` so the Rust side unwraps a single tuple result.

Usage::

    python -m compile.aot --out-dir ../artifacts

Produces:
  * ``tile_matmul.hlo.txt``      x[64,64] w[64,64] -> (y[64,64],)
  * ``cluster_compute.hlo.txt``  x[64,64] w[64,64] b[64] -> (y[64,64],)
  * ``noc_perf.hlo.txt``         traffic[16,16] -> (loads[4,4,4], max, mean, sat)
  * ``meta.json``                shape/metadata contract for the runtime
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, example_args):
    return jax.jit(fn).lower(*example_args)


def build_artifacts(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    f32 = jnp.float32
    d = model.TILE_DIM
    n = model.DSE_MESH_N
    entries = {
        "tile_matmul": (
            model.tile_matmul,
            (
                jax.ShapeDtypeStruct((d, d), f32),
                jax.ShapeDtypeStruct((d, d), f32),
            ),
        ),
        "cluster_compute": (
            model.cluster_compute,
            (
                jax.ShapeDtypeStruct((d, d), f32),
                jax.ShapeDtypeStruct((d, d), f32),
                jax.ShapeDtypeStruct((d,), f32),
            ),
        ),
        "noc_perf": (
            model.noc_perf,
            (jax.ShapeDtypeStruct((n * n, n * n), f32),),
        ),
    }
    meta = {"tile_dim": d, "dse_mesh_n": n, "artifacts": {}}
    for name, (fn, args) in entries.items():
        lowered = lower_entry(fn, args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        meta["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [list(a.shape) for a in args],
            "hlo_chars": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")
    meta_path = os.path.join(out_dir, "meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=2)
    print(f"wrote {meta_path}")
    return meta


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    build_artifacts(args.out_dir)


if __name__ == "__main__":
    main()
