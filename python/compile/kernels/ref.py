"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every kernel in this package must
match its oracle to float tolerance under pytest + hypothesis sweeps
(``python/tests/test_kernels.py``).
"""

import jax.numpy as jnp


def matmul_ref(x, w):
    """Plain dense matmul, fp32 accumulation."""
    return jnp.matmul(x, w, preferred_element_type=jnp.float32)


def cluster_compute_ref(x, w, b):
    """The tile workload: GEMM + bias + ReLU (the DMA-fed FP kernel the
    Snitch cluster case study motivates)."""
    return jnp.maximum(matmul_ref(x, w) + b[None, :], 0.0)


def interval_load_ref(w):
    """Oracle for the interval-crossing load computation.

    ``w[..., a, b]`` is traffic starting at coordinate ``a`` and ending at
    ``b`` along one mesh dimension. Returns ``(fwd, bwd)`` where
    ``fwd[..., p]`` is the load on the link ``p -> p+1`` (used iff
    ``a <= p < b``) and ``bwd[..., p]`` on ``p+1 -> p`` (used iff
    ``b <= p < a``).
    """
    n = w.shape[-1]
    p = jnp.arange(n)[:, None, None]
    a = jnp.arange(n)[None, :, None]
    b = jnp.arange(n)[None, None, :]
    fwd_mask = (a <= p) & (p < b)
    bwd_mask = (b <= p) & (p < a)
    fwd = jnp.einsum("pab,...ab->...p", fwd_mask.astype(w.dtype), w)
    bwd = jnp.einsum("pab,...ab->...p", bwd_mask.astype(w.dtype), w)
    return fwd, bwd


def link_loads_ref(traffic, n):
    """XY-routing link loads for an ``n x n`` mesh.

    ``traffic[s, d]`` is offered load (flits/cycle) from node ``s`` to
    node ``d``; nodes are row-major (``id = y * n + x``). Returns an array
    ``[4, n, n]`` with loads of the E, W, N, S output links of the router
    at ``(x, y)`` (axis order ``[dir, y_or_column, position]`` — see
    below).

    Dimension-ordered XY: the X leg runs at the source row ``sy`` from
    ``sx`` to ``dx``; the Y leg runs at the destination column ``dx``
    from ``sy`` to ``dy``.

    Layout of the result:
      * ``loads[0, y, x]`` — E link of router (x, y)
      * ``loads[1, y, x]`` — W link of router (x+1, y)  (bwd on row y)
      * ``loads[2, y, x]`` — N link of router (x=?, ...)`` transposed:
        ``loads[2, y, x]`` is the N link of router (x, y) and
        ``loads[3, y, x]`` its S counterpart.
    """
    t4 = traffic.reshape(n, n, n, n)  # [sy, sx, dy, dx]
    # X legs: aggregate over dy -> w_row[sy][sx, dx].
    w_row = t4.sum(axis=2)  # [sy, sx, dx]
    east, west = interval_load_ref(w_row)  # [sy, p]
    # Y legs: aggregate over sx -> w_col[dx][sy, dy].
    w_col = t4.sum(axis=1).transpose(2, 0, 1)  # [dx, sy, dy]
    north, south = interval_load_ref(w_col)  # [dx, p]
    loads = jnp.stack(
        [
            east,  # [y, x]
            west,  # [y, x]
            north.T,  # [dx, y] -> [y, x=dx]
            south.T,
        ]
    )
    return loads


def noc_perf_ref(traffic, n):
    """Analytical NoC performance summary from link loads.

    Returns ``(loads, max_load, mean_load, saturation_scale)`` where
    ``saturation_scale`` is the factor by which the offered traffic can be
    scaled before the most-loaded link saturates (1 flit/cycle capacity).
    """
    loads = link_loads_ref(traffic, n)
    max_load = loads.max()
    mean_load = loads.mean()
    sat = jnp.where(max_load > 0, 1.0 / jnp.maximum(max_load, 1e-9), jnp.inf)
    return loads, max_load, mean_load, sat
