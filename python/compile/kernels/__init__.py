"""Layer-1 Pallas kernels and their pure-jnp oracles."""

from . import link_load, matmul, ref  # noqa: F401
