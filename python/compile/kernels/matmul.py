"""Layer-1 Pallas kernel: blocked matmul (the tile's compute hot-spot).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's wide
512-bit link exists to feed DMA-driven double-buffered tile compute. On
TPU terms the same structure is a grid over (M, N, K) blocks whose
``BlockSpec``s stage operand tiles HBM->VMEM — the BlockSpec schedule
plays the role the DMA bursts play in the Snitch cluster, and the inner
``jnp.dot`` targets the MXU systolic array.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and correctness (vs ``ref.matmul_ref``) is the build-time
gate. VMEM-footprint and MXU-utilization estimates for a real TPU are
derived analytically in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (bm, bn) output block; grid = (M/bm, N/bn, K/bk).

    The output block is revisited across the K dimension (its index map
    ignores ``k``), so it serves as the VMEM-resident f32 accumulator —
    the standard Pallas reduction pattern.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, w, *, bm=64, bn=64, bk=64):
    """Blocked ``x @ w`` via a Pallas kernel (interpret mode).

    Shapes must tile exactly: ``M % bm == N % bn == K % bk == 0``.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shapes ({m},{k})x({k},{n}) must tile by ({bm},{bn},{bk})"
    )
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


def vmem_footprint_bytes(bm, bn, bk, dtype_bytes=4):
    """Per-grid-step VMEM residency estimate: x, w blocks + accumulator +
    output block (double-buffering would multiply operand blocks by 2)."""
    return dtype_bytes * (bm * bk + bk * bn + 2 * bm * bn)


def mxu_utilization_estimate(bm, bn, bk, mxu=128):
    """Fraction of MXU lanes a (bm, bn, bk) block keeps busy: the systolic
    array processes 128x128 tiles, so each dimension contributes
    ``min(dim, mxu) / mxu`` (ceil-division padding waste otherwise)."""

    def eff(d):
        import math

        padded = math.ceil(d / mxu) * mxu
        return d / padded

    return eff(bm) * eff(bn) * eff(bk)
