"""Layer-1 Pallas kernel: XY-routing interval-load computation.

The analytical NoC model (Layer 2, ``model.link_loads``) reduces the
traffic matrix to stacks of per-dimension weight matrices ``w[g, a, b]``
(traffic entering a row/column at coordinate ``a`` and leaving at ``b``);
this kernel computes, for every coordinate ``p``, the load crossing the
forward link ``p -> p+1`` (``a <= p < b``) and the backward link
``p+1 -> p`` (``b <= p < a``).

The grid runs over ``g`` (one mesh row or column per step) so each step
holds a single ``[n, n]`` slab in VMEM — the same tiling discipline the
matmul kernel uses for its operand blocks.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _interval_kernel(w_ref, fwd_ref, bwd_ref):
    w = w_ref[...]  # [1, n, n] block: one row/column's weights
    n = w.shape[-1]
    p = jax.lax.broadcasted_iota(jnp.int32, (n, n, n), 0)
    a = jax.lax.broadcasted_iota(jnp.int32, (n, n, n), 1)
    b = jax.lax.broadcasted_iota(jnp.int32, (n, n, n), 2)
    fwd_mask = ((a <= p) & (p < b)).astype(w.dtype)
    bwd_mask = ((b <= p) & (p < a)).astype(w.dtype)
    # [p, a, b] x [a, b] -> [p]
    fwd_ref[...] = jnp.einsum("pab,ab->p", fwd_mask, w[0])[None, :]
    bwd_ref[...] = jnp.einsum("pab,ab->p", bwd_mask, w[0])[None, :]


@jax.jit
def interval_load(w):
    """Pallas interval-load over a stack ``w[g, n, n]`` -> ``(fwd, bwd)``
    each of shape ``[g, n]``."""
    g, n, n2 = w.shape
    assert n == n2, f"weight slabs must be square, got {w.shape}"
    out = jax.ShapeDtypeStruct((g, n), w.dtype)
    return pl.pallas_call(
        _interval_kernel,
        grid=(g,),
        in_specs=[pl.BlockSpec((1, n, n), lambda i: (i, 0, 0))],
        out_specs=(
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
        ),
        out_shape=(out, out),
        interpret=True,
    )(w)
