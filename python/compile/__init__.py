"""Build-time compile path: JAX/Pallas models AOT-lowered to HLO text.

Nothing in this package runs at simulation time — the Rust coordinator
loads the artifacts produced by ``python -m compile.aot``.
"""
