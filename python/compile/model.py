"""Layer-2 JAX models, built on the Layer-1 Pallas kernels.

Three AOT entry points (see ``aot.py``):

* ``tile_matmul`` — the bare tile GEMM used by the distributed
  mesh-matmul example (accumulated across tiles on the Rust side);
* ``cluster_compute`` — GEMM + bias + ReLU, the full per-tile workload;
* ``noc_perf`` — the analytical XY link-load model used by the DSE flow.
"""

import jax.numpy as jnp

from .kernels import link_load, matmul

# Fixed AOT shapes (the PJRT artifacts are shape-specialized; the Rust
# runtime asserts against these constants, re-exported in meta.json).
TILE_DIM = 64
DSE_MESH_N = 4


def tile_matmul(x, w):
    """Bare tile GEMM ``[64,64] @ [64,64]`` via the Pallas kernel."""
    return matmul.matmul(x, w, bm=32, bn=32, bk=32)


def cluster_compute(x, w, b):
    """The tile workload: GEMM + bias + ReLU."""
    y = matmul.matmul(x, w, bm=32, bn=32, bk=32)
    return jnp.maximum(y + b[None, :], 0.0)


def link_loads(traffic, n):
    """XY link loads for an ``n x n`` mesh via the interval kernel.

    Mirrors ``ref.link_loads_ref`` but routes the interval computation
    through the Pallas kernel: build the row-wise (X-leg) and column-wise
    (Y-leg) weight stacks, run one fused kernel over ``2n`` slabs, and
    reassemble the ``[4, n, n]`` load tensor.
    """
    t4 = traffic.reshape(n, n, n, n)  # [sy, sx, dy, dx]
    w_row = t4.sum(axis=2)  # [sy, sx, dx]
    w_col = t4.sum(axis=1).transpose(2, 0, 1)  # [dx, sy, dy]
    stack = jnp.concatenate([w_row, w_col], axis=0)  # [2n, n, n]
    fwd, bwd = link_load.interval_load(stack)
    east, north = fwd[:n], fwd[n:]
    west, south = bwd[:n], bwd[n:]
    return jnp.stack([east, west, north.T, south.T])


def noc_perf(traffic):
    """DSE entry point (fixed ``DSE_MESH_N``): returns
    ``(loads[4,n,n], max_load, mean_load, saturation_scale)``."""
    loads = link_loads(traffic, DSE_MESH_N)
    max_load = loads.max()
    mean_load = loads.mean()
    sat = jnp.where(
        max_load > 0, 1.0 / jnp.maximum(max_load, 1e-9), jnp.inf
    )
    return loads, max_load, mean_load, sat
