//! End-to-end driver: a distributed tiled matmul on a simulated FlooNoC
//! mesh with the tile compute executed through the AOT-lowered
//! JAX/Pallas artifact via PJRT — all three layers composing:
//!
//!   L3  the cycle-accurate NoC moves every operand/result tile as wide
//!       DMA bursts (AXI4-checked, wormhole-routed, ROB-reordered);
//!   L2  the `tile_matmul` JAX graph (lowered once at build time);
//!   L1  the Pallas blocked-matmul kernel inside it.
//!
//! A 128x128 GEMM is split into 2x2 tiles of 64x64. Tile (i,j) of a 2x2
//! mesh DMA-reads A_ik and B_kj from the west-edge memory controllers,
//! multiplies them through PJRT, accumulates, and DMA-writes C_ij back.
//! The result is verified against a host matmul; the NoC cost (cycles,
//! bandwidth, energy) is reported from the simulation.
//!
//! ```sh
//! make artifacts && cargo run --release --example mesh_matmul
//! ```

use floonoc::cluster::{TileTraffic, TiledWorkload};
use floonoc::compute::{accumulate, host_matmul, max_abs_diff, HostMemory, TileCompute};
use floonoc::flit::NodeId;
use floonoc::noc::{NocConfig, NocSystem, NET_WIDE};
use floonoc::phys::energy::{Activity, EnergyModel};
use floonoc::runtime::Runtime;
use floonoc::topology::{MemEdge, MEM_BASE};
use floonoc::traffic::GenCfg;
use floonoc::util::rng::Rng;

const MESH: u8 = 2; // 2x2 tiles
const KB: u64 = 1024;

fn main() -> anyhow::Result<()> {
    // ---------------------------------------------------------- layer 2+1
    let rt = Runtime::new("artifacts")?;
    println!("PJRT platform: {}", rt.platform());
    let tc = TileCompute::new(&rt)?;
    let d = tc.dim; // 64
    let full = d * MESH as usize; // 128

    // Problem data lives behind the memory controllers.
    let mut rng = Rng::new(0x6E55);
    let a: Vec<f32> = (0..full * full).map(|_| rng.f64() as f32 - 0.5).collect();
    let b: Vec<f32> = (0..full * full).map(|_| rng.f64() as f32 - 0.5).collect();
    let mut host_mem = HostMemory::new();
    let tile_bytes = (d * d * 4) as u64; // 16 KiB per 64x64 f32 tile
    let tile_addr = |matrix: u64, i: u64, k: u64| -> u64 {
        MEM_BASE + matrix * (1 << 20) + (i * MESH as u64 + k) * tile_bytes
    };
    for i in 0..MESH as usize {
        for k in 0..MESH as usize {
            host_mem.write(
                tile_addr(0, i as u64, k as u64),
                extract_tile(&a, full, d, i, k),
            );
            host_mem.write(
                tile_addr(1, i as u64, k as u64),
                extract_tile(&b, full, d, i, k),
            );
        }
    }

    // ------------------------------------------------------------ layer 3
    // Phase 1: every tile DMA-reads its 2 A-tiles and 2 B-tiles
    // (4 x 16 KiB = 64 x 1 KiB bursts) from the west memory controllers.
    let sys = NocSystem::new(NocConfig::mesh(MESH, MESH).with_mem_edge(MemEdge::West));
    let mem_ctrls = sys.topo.mem_ctrls();
    let fetch_bursts = 4 * (tile_bytes / KB); // 64 bursts per tile
    let profiles: Vec<TileTraffic> = (0..MESH as usize * MESH as usize)
        .map(|t| {
            let mem = mem_ctrls[t % mem_ctrls.len()];
            let mut c = GenCfg::dma_burst(mem, fetch_bursts, false);
            c.max_outstanding = 8;
            c.seed = 0xFE7C + t as u64;
            TileTraffic {
                core: None,
                dma: Some(c),
            }
        })
        .collect();
    let mut w = TiledWorkload::new(sys, profiles);
    anyhow::ensure!(w.run_to_completion(10_000_000), "fetch phase stalled");
    anyhow::ensure!(w.protocol_ok(), "AXI violation during fetch");
    let fetch_cycles = w.sys.now;
    let fetch_hops = w.sys.router_flit_hops(NET_WIDE);

    // ---------------------------------------------------- layer 2+1 again
    // Phase 2: per-tile GEMMs through the PJRT executable, accumulating
    // over k — real numerics on the data the simulated DMA just moved.
    let mut c_tiles: Vec<Vec<f32>> = Vec::new();
    for i in 0..MESH as usize {
        for j in 0..MESH as usize {
            let mut acc = vec![0f32; d * d];
            for k in 0..MESH as usize {
                let at = host_mem
                    .read(tile_addr(0, i as u64, k as u64))
                    .expect("A tile fetched");
                let bt = host_mem
                    .read(tile_addr(1, k as u64, j as u64))
                    .expect("B tile fetched");
                let partial = tc.matmul(at, bt)?;
                accumulate(&mut acc, &partial);
            }
            c_tiles.push(acc);
        }
    }

    // Phase 3: DMA-write C tiles back to the memory controllers.
    let sys2 = NocSystem::new(NocConfig::mesh(MESH, MESH).with_mem_edge(MemEdge::West));
    let wb_bursts = tile_bytes / KB; // 16 bursts per tile
    let profiles: Vec<TileTraffic> = (0..MESH as usize * MESH as usize)
        .map(|t| {
            let mem = mem_ctrls[t % mem_ctrls.len()];
            let mut c = GenCfg::dma_burst(mem, wb_bursts, true);
            c.max_outstanding = 8;
            c.seed = 0xC0DE + t as u64;
            TileTraffic {
                core: None,
                dma: Some(c),
            }
        })
        .collect();
    let mut w2 = TiledWorkload::new(sys2, profiles);
    anyhow::ensure!(w2.run_to_completion(10_000_000), "writeback stalled");
    anyhow::ensure!(w2.protocol_ok(), "AXI violation during writeback");
    let wb_cycles = w2.sys.now;
    let wb_hops = w2.sys.router_flit_hops(NET_WIDE);

    // -------------------------------------------------------- verification
    let want = host_matmul(&a, &b, full);
    let mut max_err = 0f32;
    for i in 0..MESH as usize {
        for j in 0..MESH as usize {
            let got = &c_tiles[i * MESH as usize + j];
            let want_tile = extract_tile(&want, full, d, i, j);
            max_err = max_err.max(max_abs_diff(got, &want_tile));
        }
    }
    anyhow::ensure!(max_err < 1e-3, "GEMM mismatch: {max_err}");

    // ------------------------------------------------------------- report
    let moved_kib = 4 * 4 * tile_bytes / KB + 4 * tile_bytes / KB;
    let em = EnergyModel::default();
    let energy_pj = em.noc_dynamic_pj(&Activity {
        wide_flit_hops: fetch_hops + wb_hops,
        narrow_flit_hops: 0,
        cycles: fetch_cycles + wb_cycles,
        active_cores: 0,
    });
    println!("distributed 128x128 GEMM on a {MESH}x{MESH} FlooNoC mesh:");
    println!("  operand fetch : {fetch_cycles} cycles ({fetch_hops} wide flit-hops)");
    println!("  writeback     : {wb_cycles} cycles ({wb_hops} wide flit-hops)");
    println!("  data moved    : {moved_kib} KiB over the NoC");
    println!(
        "  NoC energy    : {:.1} nJ ({:.2} pJ/B/hop model)",
        energy_pj / 1000.0,
        em.pj_per_byte_hop
    );
    println!("  numerics      : max |C - C_ref| = {max_err:.2e}  ✓ verified");
    println!("\nAll three layers composed: Pallas kernel -> JAX graph -> HLO");
    println!("artifact -> PJRT execution, fed by the cycle-accurate NoC.");
    Ok(())
}

/// Copy tile (i, j) of an `n x n` matrix into a dense `d x d` buffer.
fn extract_tile(m: &[f32], n: usize, d: usize, i: usize, j: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(d * d);
    for r in 0..d {
        let row = i * d + r;
        out.extend_from_slice(&m[row * n + j * d..row * n + (j + 1) * d]);
    }
    out
}
