//! Quickstart: build a FlooNoC mesh, run heterogeneous traffic, and look
//! at the numbers the paper leads with.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use floonoc::cluster::{TileTraffic, TiledWorkload};
use floonoc::coordinator::zero_load_latency;
use floonoc::flit::{NocLayout, NodeId};
use floonoc::noc::{LinkMode, NocConfig, NocSystem};
use floonoc::phys::BandwidthModel;
use floonoc::traffic::GenCfg;

fn main() -> anyhow::Result<()> {
    // --- 1. the link-level protocol (Table I), from first principles ----
    let layout = NocLayout::default();
    println!(
        "FlooNoC links: narrow_req={} narrow_rsp={} wide={} bits",
        layout.narrow_req().flit_bits(),
        layout.narrow_rsp().flit_bits(),
        layout.wide_link().flit_bits()
    );
    let bw = BandwidthModel::default();
    println!(
        "wide link peak at 1.23 GHz: {:.0} Gbps ({:.2} Tbps duplex)\n",
        bw.wide_link_gbps(),
        bw.wide_duplex_tbps()
    );

    // --- 2. zero-load latency (§VI-A) -----------------------------------
    let lat = zero_load_latency(LinkMode::NarrowWide);
    println!("zero-load adjacent-tile round trip: {lat} cycles (paper: 18)\n");

    // --- 3. a live 4x4 mesh under heterogeneous traffic -----------------
    // Every tile: cores probe the +x neighbour with single-word reads
    // while the DMA streams 1 kB bursts to the same neighbour.
    let sys = NocSystem::new(NocConfig::mesh(4, 4));
    let n = 4u16;
    let profiles: Vec<TileTraffic> = (0..16u16)
        .map(|i| {
            let y = i / n;
            let x = i % n;
            let dst = NodeId(y * n + (x + 1) % n);
            TileTraffic {
                core: Some(GenCfg::narrow_probe(dst, 50)),
                dma: Some(GenCfg::dma_burst(dst, 8, false)),
            }
        })
        .collect();
    let mut w = TiledWorkload::new(sys, profiles);
    anyhow::ensure!(w.run_to_completion(1_000_000), "workload did not drain");
    anyhow::ensure!(w.protocol_ok(), "AXI ordering violated");
    let mut narrow_mean = 0.0;
    let mut wide_mean = 0.0;
    for t in &mut w.tiles {
        narrow_mean += t.core_gen.as_mut().unwrap().latencies.mean() / 16.0;
        wide_mean += t.dma_gen.as_mut().unwrap().latencies.mean() / 16.0;
    }
    println!("4x4 mesh, all tiles active ({} cycles total):", w.sys.now);
    println!("  narrow read mean latency : {narrow_mean:.1} cycles");
    println!("  1 kB DMA burst mean      : {wide_mean:.1} cycles");
    println!(
        "  wide-net flit-hops       : {}",
        w.sys.router_flit_hops(floonoc::noc::NET_WIDE)
    );
    println!("\nAll transactions AXI4-ordered (monitor clean). Done.");
    Ok(())
}
