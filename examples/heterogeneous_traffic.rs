//! The paper's headline experiment (Fig. 5), end to end: how do the
//! narrow-wide links protect latency-sensitive traffic from bulk DMA
//! bursts — and the DMA bandwidth from small-message pollution?
//!
//! ```sh
//! cargo run --release --example heterogeneous_traffic
//! ```

use floonoc::coordinator::{fig5a, fig5b};
use floonoc::noc::LinkMode;
use floonoc::report;

fn main() {
    println!("=== Fig. 5a: narrow latency vs wide-burst interference ===\n");
    let levels = [0u32, 1, 2, 4, 8];
    for bidir in [false, true] {
        for mode in [LinkMode::NarrowWide, LinkMode::WideOnly] {
            let rows = fig5a(mode, bidir, &levels);
            print!("{}", report::fig5a_table(&rows));
            println!();
        }
    }

    println!("=== Fig. 5b: wide effective bandwidth vs narrow interference ===\n");
    let levels = [0u32, 2, 4, 8, 16, 32];
    for mode in [LinkMode::NarrowWide, LinkMode::WideOnly] {
        let rows = fig5b(mode, false, &levels);
        print!("{}", report::fig5b_table(&rows));
        println!();
    }

    println!(
        "Takeaway (matches the paper): with wide-only links the narrow\n\
         transactions suffer multi-x latency degradation under burst\n\
         traffic, and the wide link loses effective bandwidth to small\n\
         messages; the narrow-wide configuration keeps both flat."
    );
}
