//! Design-space exploration: sweep mesh sizes and traffic patterns with
//! the analytical XY link-load model (native + PJRT Pallas artifact),
//! sanity-check a point against the cycle-accurate simulator, and fan a
//! multi-point cycle-accurate sweep out across all cores with the
//! deterministic parallel runner.
//!
//! ```sh
//! make artifacts && cargo run --release --example dse_sweep
//! ```

use floonoc::dse;
use floonoc::dse::parallel::{run_sweep, sweep_report_json, ParallelRunner, SweepPoint};
use floonoc::noc::LinkMode;
use floonoc::phys::BandwidthModel;
use floonoc::runtime::Runtime;
use floonoc::util::bench::time_once;
use floonoc::util::json::pretty;

fn main() -> anyhow::Result<()> {
    let bw = BandwidthModel::default();
    println!("== mesh scaling: saturation injection rate (uniform traffic) ==");
    println!(
        "{:<8} {:>14} {:>16} {:>20}",
        "mesh", "max link load", "sat inject rate", "bisection GB/s@1.23"
    );
    for n in [2usize, 3, 4, 6, 8] {
        let loads = dse::link_loads(&dse::uniform_traffic(n, 1.0), n);
        let max = dse::max_load(&loads);
        // Bisection: n links per direction across the middle cut.
        let bisection = n as f64 * 2.0 * bw.wide_link_gbps() / 8.0;
        println!(
            "{:<8} {:>14.3} {:>16.3} {:>20.0}",
            format!("{n}x{n}"),
            max,
            1.0 / max,
            bisection
        );
    }

    println!("\n== traffic patterns on a 4x4 mesh ==");
    for (name, t) in [
        ("ring +x", dse::ring_traffic(4, 1.0)),
        ("uniform", dse::uniform_traffic(4, 1.0)),
    ] {
        let loads = dse::link_loads(&t, 4);
        println!(
            "{name:<10} max {:.3}  mean {:.3}  saturation at {:.2} flits/cycle/node",
            dse::max_load(&loads),
            dse::mean_load(&loads),
            1.0 / dse::max_load(&loads)
        );
    }

    println!("\n== PJRT artifact cross-check (L1 Pallas kernel via L3) ==");
    match Runtime::new("artifacts") {
        Ok(rt) => {
            let n = rt.meta.dse_mesh_n;
            let t = dse::uniform_traffic(n, 0.6);
            let native = dse::link_loads(&t, n);
            let (art, max, mean, sat) = dse::artifact_link_loads(&rt, &t)?;
            let mut diff = 0.0f64;
            for d in 0..4 {
                for y in 0..n {
                    for x in 0..n {
                        diff = diff.max((art[d][y][x] - native[d][y][x]).abs());
                    }
                }
            }
            println!(
                "artifact: max {max:.3} mean {mean:.3} sat {sat:.2}x; \
                 |artifact - native|max = {diff:.2e}"
            );
            anyhow::ensure!(diff < 1e-4, "model divergence");
        }
        Err(e) => println!("artifacts not built ({e}); run `make artifacts`"),
    }

    println!("\n== simulator spot-check (ring workload, 4x4) ==");
    let (tput, cycles) = dse::simulate_ring_throughput(4, 8);
    println!(
        "measured mean E-link throughput {tput:.3} flits/cycle over {cycles} \
         cycles (analytical: uniform across used E-links)"
    );

    // ---- parallel cycle-accurate sweep ---------------------------------
    // Independent points (mesh size x link mode x burst length) fanned
    // out across cores; the report is byte-identical to a serial run.
    let points = SweepPoint::grid(
        &[2, 3, 4],
        &[LinkMode::NarrowWide, LinkMode::WideOnly],
        &[7, 15],
    );
    let runner = ParallelRunner::default();
    println!(
        "\n== parallel cycle-accurate sweep: {} points on {} core(s) ==",
        points.len(),
        runner.threads()
    );
    let mut serial_results = Vec::new();
    let t_serial = time_once(|| serial_results = run_sweep(&points, &ParallelRunner::serial()));
    let mut parallel_results = Vec::new();
    let t_parallel = time_once(|| parallel_results = run_sweep(&points, &runner));
    let serial_json = pretty(&sweep_report_json(&serial_results));
    let parallel_json = pretty(&sweep_report_json(&parallel_results));
    anyhow::ensure!(
        serial_json == parallel_json,
        "parallel sweep diverged from serial reference"
    );
    println!("{parallel_json}");
    println!(
        "serial {:.2}s vs parallel {:.2}s => {:.2}x speedup, byte-identical report",
        t_serial.as_secs_f64(),
        t_parallel.as_secs_f64(),
        t_serial.as_secs_f64() / t_parallel.as_secs_f64().max(1e-9)
    );
    Ok(())
}
