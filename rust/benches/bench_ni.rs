//! NI micro-benchmarks: ROB allocation and reorder-table throughput —
//! the paper's endpoint machinery on the simulator's critical path.

use floonoc::flit::NodeId;
use floonoc::ni::rob::RobAllocator;
use floonoc::ni::{Initiator, InitiatorCfg, ReorderTable};
use floonoc::util::bench::Bencher;
use floonoc::util::rng::Rng;

fn rob_alloc_release(b: &mut Bencher) {
    const OPS: u64 = 100_000;
    b.bench("ROB alloc/release (16-beat grants)", Some(OPS), || {
        let mut rob = RobAllocator::new(128);
        let mut live = Vec::new();
        let mut rng = Rng::new(1);
        for _ in 0..OPS {
            if live.len() < 6 && rng.chance(0.6) {
                if let Some(g) = rob.alloc(16) {
                    live.push(g);
                }
            } else if let Some(g) = live.pop() {
                rob.release(g);
            }
        }
        for g in live.drain(..) {
            rob.release(g);
        }
    });
}

fn reorder_bypass_path(b: &mut Bencher) {
    const OPS: u64 = 100_000;
    b.bench("reorder table in-order bypass", Some(OPS), || {
        let mut t = ReorderTable::new(16, 4);
        for i in 0..OPS {
            let id = (i % 16) as u16;
            if !t.can_push(id) {
                continue;
            }
            t.push(id, floonoc::ni::rob::RobGrant { base: 0, len: 1 }, 1);
            t.on_response_beat(id, 0, true);
            t.complete_bypass(id);
        }
    });
}

fn initiator_issue_path(b: &mut Bencher) {
    use floonoc::axi::{AxReq, Burst};
    const OPS: u64 = 50_000;
    b.bench("initiator AR issue + response", Some(OPS), || {
        let mut init = Initiator::new(InitiatorCfg::wide_default(), NodeId(0));
        for i in 0..OPS {
            init.push_ar(
                AxReq {
                    id: (i % 4) as u16,
                    addr: 0x1000,
                    len: 0,
                    size: 6,
                    burst: Burst::Incr,
                    atop: false,
                },
                NodeId(1),
            );
            let flit = init.try_issue(i, true).expect("issue");
            // Immediate in-order response.
            let rsp = floonoc::flit::FlooFlit::new(
                floonoc::flit::Header {
                    dst: NodeId(0),
                    src: NodeId(1),
                    rob_idx: flit.header.rob_idx,
                    rob_req: true,
                    atomic: false,
                    last: true,
                },
                floonoc::flit::Payload::WideR(floonoc::axi::RBeat {
                    id: (i % 4) as u16,
                    beat: 0,
                    last: true,
                    resp: floonoc::axi::Resp::Okay,
                }),
                i,
            );
            assert!(init.handle_response(&rsp));
            init.r_out.pop();
        }
    });
}

fn main() {
    println!("== bench_ni (endpoint machinery) ==");
    let mut b = Bencher::default();
    rob_alloc_release(&mut b);
    reorder_bypass_path(&mut b);
    initiator_issue_path(&mut b);
}
