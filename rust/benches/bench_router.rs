//! Router micro-benchmarks: per-cycle stepping cost of the switch — the
//! simulator's innermost hot path (L3 perf target: >10 M router-flit
//! events/s).

use floonoc::axi::{AxReq, Burst};
use floonoc::flit::{FlooFlit, Header, NodeId, Payload};
use floonoc::router::{Router, RouterCfg, RouteTable};
use floonoc::sim::Link;
use floonoc::util::bench::Bencher;

fn flit(dst: u16) -> FlooFlit {
    FlooFlit::new(
        Header {
            dst: NodeId(dst),
            src: NodeId(0),
            rob_idx: 0,
            rob_req: true,
            atomic: false,
            last: true,
        },
        Payload::NarrowAr(AxReq {
            id: 0,
            addr: 0,
            len: 0,
            size: 3,
            burst: Burst::Incr,
            atop: false,
        }),
        0,
    )
}

/// 5-port router with all ports looped: saturated crossbar stepping.
fn saturated_router_cycle(b: &mut Bencher) {
    let ports = 5;
    let mut links: Vec<Link<FlooFlit>> = (0..2 * ports).map(|_| Link::new(4)).collect();
    let mut table = vec![0u8; ports];
    for (i, t) in table.iter_mut().enumerate() {
        *t = i as u8;
    }
    let mut r = Router::new(
        RouterCfg {
            ports,
            in_buf_depth: 4,
            vcs: 1,
        },
        RouteTable::new(table),
    );
    for p in 0..ports {
        r.in_links[p] = Some(p);
        r.out_links[p] = Some(ports + p);
    }
    const CYCLES: u64 = 100_000;
    b.bench("router 5x5 saturated step", Some(CYCLES * 4), || {
        for _ in 0..CYCLES {
            // Keep inputs loaded with flits to rotating outputs (no
            // loopback: input i sends to (i+1) % ports).
            for p in 0..ports {
                if links[p].can_offer() {
                    links[p].offer(flit(((p + 1) % ports) as u16));
                }
            }
            for l in links.iter_mut() {
                l.deliver();
            }
            r.step(&mut links);
            // Drain outputs.
            for p in 0..ports {
                links[ports + p].pop();
            }
        }
    });
}

/// Idle router stepping (common case in large meshes).
fn idle_router_cycle(b: &mut Bencher) {
    let ports = 5;
    let mut links: Vec<Link<FlooFlit>> = (0..2 * ports).map(|_| Link::new(4)).collect();
    let mut r = Router::new(RouterCfg::default(), RouteTable::new(vec![0; ports]));
    for p in 0..ports {
        r.in_links[p] = Some(p);
        r.out_links[p] = Some(ports + p);
    }
    const CYCLES: u64 = 1_000_000;
    b.bench("router 5x5 idle step", Some(CYCLES), || {
        for _ in 0..CYCLES {
            for l in links.iter_mut() {
                l.deliver();
            }
            r.step(&mut links);
        }
    });
}

fn main() {
    println!("== bench_router (L3 hot path) ==");
    let mut b = Bencher::default();
    saturated_router_cycle(&mut b);
    idle_router_cycle(&mut b);
}
