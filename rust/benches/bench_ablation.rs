//! Ablation benches over the design choices DESIGN.md calls out: ROB
//! sizing (the paper's footnote 2), router buffering, burst length,
//! output registers, mesh scaling, and the AXI4-matrix baseline's
//! scalability wall.

use floonoc::baseline::AxiMatrixModel;
use floonoc::coordinator as exp;
use floonoc::dse::ParallelRunner;
use floonoc::report;
use floonoc::util::bench::Bencher;

fn main() {
    println!("== bench_ablation ==\n");
    let mut b = Bencher::new(0, 1);
    // Serial runner: keep reported per-sweep wall-clock single-threaded
    // and comparable across hosts (fan-out is bench_e2e's subject).
    let serial = ParallelRunner::serial();

    let mut rows = Vec::new();
    b.bench("ROB size sweep", None, || {
        rows = exp::ablate_rob_size_with(&[16, 32, 64, 128, 256], &serial);
    });
    print!(
        "{}",
        report::ablation_table("wide-ROB slots vs 16x1kB-read makespan (cycles)", &rows)
    );
    // The paper sized the 8 kB ROB for >=2 outstanding max-size bursts:
    // halving below that (<=32 slots = 2 kB) must visibly hurt.
    let t16 = rows[0].metric;
    let t128 = rows[3].metric;
    assert!(t16 > t128 * 1.2, "small ROB must throttle: {t16} vs {t128}");
    println!();

    b.bench("buffer depth sweep", None, || {
        rows = exp::ablate_buffer_depth_with(&[1, 2, 4, 8], &serial);
    });
    print!(
        "{}",
        report::ablation_table(
            "router input-buffer depth vs narrow latency under interference",
            &rows
        )
    );
    println!();

    b.bench("burst length sweep", None, || {
        rows = exp::ablate_burst_len_with(&[0, 1, 3, 7, 15, 31], &serial);
    });
    print!(
        "{}",
        report::ablation_table("burst beats vs effective wide utilization", &rows)
    );
    assert!(
        rows.last().unwrap().metric > rows[0].metric,
        "longer bursts amortize better"
    );
    println!();

    b.bench("output-register ablation", None, || {
        rows = exp::ablate_output_reg();
    });
    print!(
        "{}",
        report::ablation_table("output register (2-cycle router) vs zero-load", &rows)
    );
    println!();

    b.bench("mesh scaling", None, || {
        rows = exp::scale_mesh_with(&[2, 3, 4, 6], &serial);
    });
    print!(
        "{}",
        report::ablation_table("mesh size vs delivered wide bytes/cycle", &rows)
    );
    println!();

    // AXI4-matrix baseline: the scalability argument quantified.
    let m = AxiMatrixModel::default();
    println!("AXI4-matrix tracker growth (per crossbar stage):");
    for s in m.sweep(6) {
        println!(
            "  {} hops: {:>2}-bit IDs, {:>12} tracker entries",
            s.hops,
            s.id_bits,
            if s.tracker_entries > 1 << 40 {
                ">1e12".to_string()
            } else {
                s.tracker_entries.to_string()
            }
        );
    }
    println!(
        "  FlooNoC NI (hop-independent): {} entries; matrix exceeds the \
         entire 500 kGE NoC budget at {} hops",
        m.floonoc_ni_entries(),
        m.scalability_wall_hops(500_000)
    );
}
