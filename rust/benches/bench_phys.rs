//! Benches regenerating the physical-model results: Table I, Fig. 6a,
//! Fig. 6b, §V wires, §VI-B bandwidth, Table II — plus their evaluation
//! cost (all analytical, so these also serve as regression checks).

use floonoc::cluster::TileSpec;
use floonoc::coordinator::fig6b_power;
use floonoc::flit::NocLayout;
use floonoc::phys::{AreaModel, BandwidthModel, ChannelGeometry};
use floonoc::report;
use floonoc::util::bench::Bencher;

fn main() {
    println!("== bench_phys: Table I / Fig. 6a / Fig. 6b / §V / §VI-B ==\n");
    let layout = NocLayout::default();
    print!("{}", report::table_one(&layout));
    println!();
    print!("{}", report::table_two());
    println!();

    let area = AreaModel::default().tile(&TileSpec::default(), &layout, 2);
    println!(
        "Fig. 6a: tile {:.2} MGE, NoC {:.0} kGE ({:.1} %) \
         [paper: ~5 MGE, ~500 kGE, 10 %]",
        area.tile_total() / 1e6,
        area.noc_total() / 1e3,
        area.noc_fraction() * 100.0
    );

    let (power, pjb) = fig6b_power();
    println!(
        "Fig. 6b: tile {:.1} mW, NoC {:.1} % | {:.2} pJ/B/hop \
         [paper: 139 mW, 7 %, 0.19 pJ/B/hop]",
        power.total_mw,
        power.noc_fraction * 100.0,
        pjb
    );

    let geom = ChannelGeometry::default();
    println!(
        "§V wires: {} per duplex channel, {:.0} um slice, {} island sets \
         [paper: ~1600, 120 um, 3]",
        geom.duplex_wires(&layout),
        geom.channel_width_um(&layout),
        geom.island_sets()
    );

    let bw = BandwidthModel::default();
    println!(
        "§VI-B: {:.0} Gbps/link, {:.2} Tbps duplex, 7x7 boundary {:.1} TB/s \
         [paper: 629, 1.26, 4.4]",
        bw.wide_link_gbps(),
        bw.wide_duplex_tbps(),
        bw.mesh_boundary_tbs(7)
    );

    println!("\ntimings:");
    let mut b = Bencher::default();
    b.bench("full area model evaluation", Some(1), || {
        std::hint::black_box(AreaModel::default().tile(&TileSpec::default(), &layout, 2));
    });
    b.bench("fig6b power experiment (incl. simulation)", Some(1), || {
        std::hint::black_box(fig6b_power());
    });
}
