//! End-to-end simulator throughput: full meshes under load — the number
//! that gates how big a sweep we can afford (L3 perf deliverable).
//!
//! Reports simulated cycles/s and router-flit-events/s, runs the
//! `cycles_per_second` regression gate (pin a floor with `CPS_FLOOR=<n>`),
//! and measures the parallel sweep runner against its serial reference:
//! same points, byte-identical report, wall-clock speedup printed.

use floonoc::cluster::{TileTraffic, TiledWorkload};
use floonoc::dse::parallel::{run_sweep, sweep_report_json, ParallelRunner, SweepPoint};
use floonoc::flit::NodeId;
use floonoc::noc::{LinkMode, NocConfig, NocSystem};
use floonoc::traffic::{GenCfg, Pattern};
use floonoc::util::bench::{cps_gate, time_once, Bencher};
use floonoc::util::json::pretty;

fn saturated_workload(n: u8) -> TiledWorkload {
    let sys = NocSystem::new(NocConfig::mesh(n, n));
    let tiles = sys.topo.num_tiles;
    let profiles: Vec<TileTraffic> = (0..tiles)
        .map(|i| TileTraffic {
            core: Some(GenCfg {
                pattern: Pattern::UniformTiles,
                num_txns: u64::MAX,
                seed: i as u64,
                ..GenCfg::narrow_probe(NodeId(0), 1)
            }),
            dma: Some(GenCfg {
                pattern: Pattern::UniformTiles,
                num_txns: u64::MAX,
                seed: 100 + i as u64,
                ..GenCfg::dma_burst(NodeId(0), 1, false)
            }),
        })
        .collect();
    TiledWorkload::new(sys, profiles)
}

fn bench_mesh(b: &mut Bencher, n: u8, label: &str) {
    const CYCLES: u64 = 20_000;
    let mut flits = 0u64;
    let mut w = saturated_workload(n);
    b.bench(&format!("{label}: {CYCLES} cycles saturated"), Some(CYCLES), || {
        w = saturated_workload(n);
        for _ in 0..CYCLES {
            w.step();
        }
        flits = (0..w.sys.nets.len())
            .map(|i| w.sys.router_flit_hops(i))
            .sum();
    });
    let per_cycle = flits as f64 / CYCLES as f64;
    println!("    ({flits} flit-hops total, {per_cycle:.1} per cycle)");
}

/// The sweep used for the serial-vs-parallel comparison: independent
/// ring-DMA points across mesh sizes and link modes, sized so one point
/// is a nontrivial simulation.
fn speedup_points() -> Vec<SweepPoint> {
    let mut points = SweepPoint::grid(
        &[4, 6],
        &[LinkMode::NarrowWide, LinkMode::WideOnly],
        &[7, 15],
    );
    for p in &mut points {
        p.bursts_per_tile = 24;
    }
    points
}

fn bench_parallel_sweep() {
    let points = speedup_points();
    let cores = ParallelRunner::default().threads();
    println!(
        "\n== parallel sweep: {} points, {} cores ==",
        points.len(),
        cores
    );
    let mut serial_results = Vec::new();
    let serial = time_once(|| {
        serial_results = run_sweep(&points, &ParallelRunner::serial());
    });
    let mut parallel_results = Vec::new();
    let parallel = time_once(|| {
        parallel_results = run_sweep(&points, &ParallelRunner::default());
    });
    let serial_json = pretty(&sweep_report_json(&serial_results));
    let parallel_json = pretty(&sweep_report_json(&parallel_results));
    assert_eq!(
        serial_json, parallel_json,
        "parallel sweep must be byte-identical to serial"
    );
    let speedup = serial.as_secs_f64() / parallel.as_secs_f64().max(1e-9);
    println!(
        "serial {:.2}s, parallel {:.2}s => speedup {speedup:.2}x (byte-identical reports)",
        serial.as_secs_f64(),
        parallel.as_secs_f64()
    );
    if cores >= 4 && speedup < 2.0 {
        println!("    WARNING: expected >= 2x on >= 4 cores, got {speedup:.2}x");
    }
}

fn main() {
    println!("== bench_e2e: whole-system simulation throughput ==");
    let mut b = Bencher::new(1, 5);
    bench_mesh(&mut b, 2, "2x2 mesh");
    bench_mesh(&mut b, 4, "4x4 mesh");
    bench_mesh(&mut b, 8, "8x8 mesh");

    // cycles/s regression gate over the 4x4 saturated mesh (the sweep
    // workhorse size). Pin a floor in CI with CPS_FLOOR=<cycles/s>.
    let mut w = saturated_workload(4);
    cps_gate("4x4-saturated", 20_000, || w.step());

    bench_parallel_sweep();
}
