//! End-to-end simulator throughput: full meshes under load — the number
//! that gates how big a sweep we can afford (L3 perf deliverable).
//!
//! Reports simulated cycles/s and router-flit-events/s.

use floonoc::cluster::{TileTraffic, TiledWorkload};
use floonoc::flit::NodeId;
use floonoc::noc::{NocConfig, NocSystem};
use floonoc::traffic::{GenCfg, Pattern};
use floonoc::util::bench::Bencher;

fn bench_mesh(b: &mut Bencher, n: u8, label: &str) {
    let mk = || {
        let sys = NocSystem::new(NocConfig::mesh(n, n));
        let tiles = sys.topo.num_tiles;
        let profiles: Vec<TileTraffic> = (0..tiles)
            .map(|i| TileTraffic {
                core: Some(GenCfg {
                    pattern: Pattern::UniformTiles,
                    num_txns: u64::MAX,
                    seed: i as u64,
                    ..GenCfg::narrow_probe(NodeId(0), 1)
                }),
                dma: Some(GenCfg {
                    pattern: Pattern::UniformTiles,
                    num_txns: u64::MAX,
                    seed: 100 + i as u64,
                    ..GenCfg::dma_burst(NodeId(0), 1, false)
                }),
            })
            .collect();
        TiledWorkload::new(sys, profiles)
    };
    const CYCLES: u64 = 20_000;
    let mut flits = 0u64;
    let mut w = mk();
    b.bench(&format!("{label}: {CYCLES} cycles saturated"), Some(CYCLES), || {
        w = mk();
        for _ in 0..CYCLES {
            w.step();
        }
        flits = (0..w.sys.nets.len())
            .map(|i| w.sys.router_flit_hops(i))
            .sum();
    });
    let per_cycle = flits as f64 / CYCLES as f64;
    println!("    ({flits} flit-hops total, {per_cycle:.1} per cycle)");
}

fn main() {
    println!("== bench_e2e: whole-system simulation throughput ==");
    let mut b = Bencher::new(1, 5);
    bench_mesh(&mut b, 2, "2x2 mesh");
    bench_mesh(&mut b, 4, "4x4 mesh");
    bench_mesh(&mut b, 8, "8x8 mesh");
}
