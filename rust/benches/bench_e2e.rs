//! End-to-end simulator throughput: full meshes under load — the number
//! that gates how big a sweep we can afford (L3 perf deliverable).
//!
//! Everything measured here is implemented in `floonoc::perf` (shared
//! with `repro bench`, so CI and developers measure identical code):
//!
//! * classic per-mesh-size iteration timings (2×2 / 4×4 / 8×8 saturated);
//! * activity-gated vs dense-reference cycles/s on the sparse-trace and
//!   saturated scenarios;
//! * event-driven fast-forward vs gated cycles/s on the duty-cycled
//!   scenario (event cps counts *simulated* cycles per wall second);
//! * the `cycles_per_second` regression gates (pin floors with
//!   `CPS_FLOOR=<n>`, `CPS_FLOOR_4X4_SATURATED=<n>`, or
//!   `CPS_FLOOR_8X8_DUTY_EVENT=<n>`; CI does);
//! * the parallel sweep runner against its serial reference (same
//!   points, byte-identical report, wall-clock speedup printed);
//! * the `BENCH_e2e.json` trajectory file at the repository root
//!   (override the location with `BENCH_OUT=<path>`; `BENCH_QUICK=1`
//!   shrinks cycle counts for smoke runs).
//!
//! For *where a saturated cycle's time goes* (link deliver vs router
//! sweep vs NI vs generators), run the companion phase profiler instead:
//! `repro bench --profile` (`floonoc::perf::profile`, writes
//! `BENCH_profile.json`).

use floonoc::perf;
use floonoc::sim::SimMode;
use floonoc::util::bench::Bencher;

fn bench_mesh(b: &mut Bencher, n: u8, label: &str) {
    const CYCLES: u64 = 20_000;
    let mut flits = 0u64;
    b.bench(&format!("{label}: {CYCLES} cycles saturated"), Some(CYCLES), || {
        let mut w = perf::saturated_workload(n, SimMode::Gated);
        for _ in 0..CYCLES {
            w.step();
        }
        flits = (0..w.sys.nets.len())
            .map(|i| w.sys.router_flit_hops(i))
            .sum();
    });
    let per_cycle = flits as f64 / CYCLES as f64;
    println!("    ({flits} flit-hops total, {per_cycle:.1} per cycle)");
}

fn main() {
    println!("== bench_e2e: whole-system simulation throughput ==");
    let mut b = Bencher::new(1, 5);
    bench_mesh(&mut b, 2, "2x2 mesh");
    bench_mesh(&mut b, 4, "4x4 mesh");
    bench_mesh(&mut b, 8, "8x8 mesh");

    let quick = std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let report = perf::run_e2e(quick);
    let path = match std::env::var("BENCH_OUT") {
        Ok(p) => std::path::PathBuf::from(p),
        Err(_) => perf::default_report_path(),
    };
    perf::write_report(&report, &path).expect("bench report must be writable");
}
