//! End-to-end benches regenerating the paper's Fig. 5a and Fig. 5b data
//! (both link configurations, both directions), timing each point.
//!
//! Points run through `ParallelRunner::serial()` so the reported
//! wall-clock measures single-thread experiment cost and stays
//! comparable across runs/hosts (the multi-core fan-out is measured
//! separately in `bench_e2e`).
//!
//! `BENCH_SAMPLES=3 cargo bench --bench bench_fig5` for a quick pass.

use floonoc::coordinator::{fig5a_with, fig5b_with};
use floonoc::dse::ParallelRunner;
use floonoc::noc::LinkMode;
use floonoc::report;
use floonoc::util::bench::Bencher;

fn main() {
    println!("== bench_fig5: regenerate Fig. 5a / 5b ==");
    let mut b = Bencher::new(0, 3);
    let serial = ParallelRunner::serial();

    let mut out_5a = Vec::new();
    b.bench("fig5a sweep (both modes, unidir)", None, || {
        out_5a.clear();
        for mode in [LinkMode::NarrowWide, LinkMode::WideOnly] {
            out_5a.extend(fig5a_with(mode, false, &[0, 1, 2, 4, 8], &serial));
        }
    });
    print!("{}", report::fig5a_table(&out_5a));

    let mut out_5a_bidir = Vec::new();
    b.bench("fig5a sweep (both modes, bidir)", None, || {
        out_5a_bidir.clear();
        for mode in [LinkMode::NarrowWide, LinkMode::WideOnly] {
            out_5a_bidir.extend(fig5a_with(mode, true, &[0, 1, 2, 4, 8], &serial));
        }
    });
    print!("{}", report::fig5a_table(&out_5a_bidir));

    let mut out_5b = Vec::new();
    b.bench("fig5b sweep (both modes)", None, || {
        out_5b.clear();
        for mode in [LinkMode::NarrowWide, LinkMode::WideOnly] {
            out_5b.extend(fig5b_with(mode, false, &[0, 2, 4, 8, 16, 32], &serial));
        }
    });
    print!("{}", report::fig5b_table(&out_5b));

    // Shape assertions (the paper's claims, as a regression gate).
    let nw_max = out_5a
        .iter()
        .filter(|r| r.mode == LinkMode::NarrowWide)
        .map(|r| r.slowdown)
        .fold(0.0f64, f64::max);
    let wo_max = out_5a
        .iter()
        .filter(|r| r.mode == LinkMode::WideOnly)
        .map(|r| r.slowdown)
        .fold(0.0f64, f64::max);
    println!(
        "\nfig5a: narrow-wide max slowdown {nw_max:.2}x vs wide-only {wo_max:.2}x \
         (paper: flat vs up to 5x)"
    );
    assert!(nw_max < wo_max, "narrow-wide must dominate");
}
