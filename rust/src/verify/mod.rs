//! Static network verification: prove deadlock-freedom, route sanity,
//! and config consistency before a single cycle runs.
//!
//! PR 4's dateline virtual channels made wrap fabrics deadlock-free,
//! but the acyclicity argument lived as prose in `docs/deadlock.md` and
//! violations were only caught dynamically, by a stalled-cycle watchdog
//! minutes into a simulation. This module turns that reasoning into an
//! executable pass pipeline over any [`NocConfig`]:
//!
//! 1. **config lints** ([`lints`]) — wrap fabrics below their dateline
//!    VC default, dateline bits on non-wrap ports, zero FIFO depths,
//!    attach-port mismatches, ROB byte-budget mismatches,
//!    undersized per-VC buffer depths (`FV101`–`FV106`, warnings), and
//!    adaptive routing without a lane above the escape lanes (`FV107`,
//!    an error — adaptivity with nothing to adapt on);
//! 2. **route sanity** ([`cdg`]) — every `src → dst` route terminates
//!    within its minimal hop bound, never U-turns, exits through
//!    connected ports, and stays within the configured VC count
//!    (`FV002`–`FV005`);
//! 3. **CDG acyclicity** ([`cdg`]) — Tarjan SCC over the channel
//!    dependency graph on (channel, VC) nodes; a cycle is a reachable
//!    wormhole deadlock, reported as a `(router, port, vc) → …` chain
//!    (`FV001`).
//!
//! Every finding carries a stable diagnostic code, severity and
//! span-like context ([`report`]); the full code table is in
//! `docs/verification.md`. [`preflight`] runs the pipeline for a
//! config; [`crate::noc::NocSystem::new`] calls it mandatorily and
//! refuses to build on error-severity findings (escape hatch:
//! [`NocConfig::no_verify`] / CLI `--no-verify`). The CLI front end is
//! `repro verify [--config …] [--json] [--deep]`.
//!
//! The fourth pass is dynamic: [`live`] analyzes a *running* system's
//! blocked wait-for dependencies through the same chain printer, and
//! the watchdog prints it when it trips.

pub mod cdg;
pub mod lints;
pub mod live;
pub mod report;

pub use report::{Category, ChainNode, Finding, Report, Severity};

use crate::noc::NocConfig;
use crate::router::RoutingKind;
use crate::topology::Topology;

/// The deployed dateline-mask array of `topo`: bit `p` of entry `r`
/// marks output `p` of router `r` as a wraparound (dateline) exit,
/// exactly as [`Topology::dateline_ports`] assigns them at
/// construction. Pass a modified copy to [`verify_topology`] to check
/// hypothetical maskings (e.g. a cleared dateline).
pub fn default_masks(topo: &Topology) -> Vec<u8> {
    (0..topo.width as usize * topo.height as usize)
        .map(|r| topo.dateline_ports(topo.nodes[r].coord))
        .collect()
}

/// Verify a fabric directly: structural lints (`FV102`, `FV104`), route
/// sanity (`FV002`–`FV005`) and CDG acyclicity (`FV001`) for `topo`
/// with `vcs` lanes per channel under the dateline-mask array `masks`.
///
/// This is the mask-override entry point; [`preflight`] is the
/// config-level wrapper that adds the [`NocConfig`]-knob lints.
///
/// ```
/// use floonoc::topology::{MemEdge, Topology};
/// use floonoc::verify::{default_masks, verify_topology};
/// let topo = Topology::torus(4, 4, MemEdge::West);
/// // The deployed dateline keeps the 2-VC torus acyclic…
/// assert!(!verify_topology(&topo, 2, &default_masks(&topo)).has_errors());
/// // …but clearing the mask (or dropping to 1 VC) closes the cycle.
/// let zeros = vec![0u8; topo.width as usize * topo.height as usize];
/// assert!(verify_topology(&topo, 2, &zeros).has_errors());
/// assert!(verify_topology(&topo, 1, &default_masks(&topo)).has_errors());
/// ```
pub fn verify_topology(topo: &Topology, vcs: usize, masks: &[u8]) -> Report {
    let mut report = Report::new();
    lints::lint_topology(topo, masks, &mut report);
    cdg::analyze(topo, vcs, masks, &mut report);
    report
}

/// The mandatory preflight: run the full pass pipeline for `cfg`
/// (config lints + structural lints + route sanity + CDG acyclicity,
/// with the deployed dateline masks). [`crate::noc::NocSystem::new`]
/// panics on [`Report::has_errors`] unless `cfg.verify` is cleared.
///
/// ```
/// use floonoc::noc::NocConfig;
/// use floonoc::verify::preflight;
/// // Shipped defaults verify clean…
/// assert!(preflight(&NocConfig::torus(4, 4)).is_clean());
/// // …a 4×4 torus forced to one VC is provably deadlock-prone…
/// let bad = preflight(&NocConfig::torus(4, 4).with_vcs(1));
/// assert!(bad.has_errors() && !bad.with_code("FV001").is_empty());
/// // …while a 3×3 torus at one VC has an acyclic CDG (warnings only):
/// let small = preflight(&NocConfig::torus(3, 3).with_vcs(1));
/// assert!(!small.has_errors() && small.warning_count() > 0);
/// ```
pub fn preflight(cfg: &NocConfig) -> Report {
    let topo = Topology::new(cfg.topology, cfg.width, cfg.height, cfg.mem_edge);
    let masks = default_masks(&topo);
    let mut report = Report::new();
    lints::lint_config(cfg, &topo, &mut report);
    // Adaptive routing: the Duato argument reduces deadlock freedom to
    // the acyclicity of the *escape subgraph* — the deterministic
    // baseline on the escape lanes. The router's no-re-entry rule makes
    // an escape entry lane-equivalent to a fresh injection, so that
    // subgraph is exactly the deterministic fabric's CDG at the escape
    // lane count; the adaptive lanes above it are covered by the
    // sharpness pass ([`verify_adaptive_unrestricted`]) only as a
    // justification, never as a deployment requirement.
    let cdg_vcs = match cfg.routing {
        RoutingKind::Deterministic => cfg.vcs,
        RoutingKind::Adaptive => cfg.vcs.min(cfg.topology.default_vcs()),
    };
    report.merge(verify_topology(&topo, cdg_vcs, &masks));
    report
}

/// The **sharpness** check behind the escape-VC restriction: verify
/// `topo` as if minimal-adaptive routing ran with *no* escape lanes —
/// the full candidate sets offered to every lane
/// ([`cdg::analyze_adaptive_unrestricted`]). An `FV001` here proves the
/// escape restriction is load-bearing, not conservative: the same
/// candidate sets the deployed adaptive router uses would deadlock
/// without the escape subgraph beneath them.
///
/// ```
/// use floonoc::topology::{MemEdge, Topology};
/// use floonoc::verify::verify_adaptive_unrestricted;
/// // Unrestricted adaptivity closes cycles on wrap fabrics and meshes…
/// let torus = Topology::torus(4, 4, MemEdge::None);
/// assert!(verify_adaptive_unrestricted(&torus).has_errors());
/// let mesh = Topology::mesh(4, 4, MemEdge::None);
/// assert!(verify_adaptive_unrestricted(&mesh).has_errors());
/// // …which the deployed escape-lane restriction provably avoids
/// // (`preflight` accepts the same fabrics in adaptive configs).
/// ```
pub fn verify_adaptive_unrestricted(topo: &Topology) -> Report {
    let mut report = Report::new();
    cdg::analyze_adaptive_unrestricted(topo, &mut report);
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::MemEdge;

    #[test]
    fn shipped_defaults_are_clean() {
        for cfg in [
            NocConfig::mesh(4, 4),
            NocConfig::torus(4, 4),
            NocConfig::ring(8),
        ] {
            let r = preflight(&cfg);
            assert!(r.is_clean(), "{:?} {}x{}: {r}", cfg.topology, cfg.width, cfg.height);
        }
    }

    #[test]
    fn small_wrap_fabrics_are_acyclic_even_at_one_vc() {
        // Every in-dimension trip is a single hop when the dimension is
        // shorter than 4, so no same-dimension dependency edge exists:
        // the graph analysis accepts what a naive lint would reject.
        for cfg in [
            NocConfig::torus(3, 3).with_vcs(1),
            NocConfig::torus(2, 2).with_vcs(1),
            NocConfig::ring(3).with_vcs(1),
        ] {
            let r = preflight(&cfg);
            assert!(!r.has_errors(), "{:?} {}x{}: {r}", cfg.topology, cfg.width, cfg.height);
            assert!(!r.with_code("FV101").is_empty(), "the lint still warns");
        }
    }

    #[test]
    fn long_wrap_dimension_at_one_vc_closes_the_cycle() {
        for cfg in [
            NocConfig::torus(4, 4).with_vcs(1),
            NocConfig::ring(4).with_vcs(1),
            NocConfig::ring(8).with_vcs(1),
        ] {
            let r = preflight(&cfg);
            assert!(r.has_errors(), "{:?} {}x{}", cfg.topology, cfg.width, cfg.height);
            let fv001 = r.with_code("FV001");
            assert!(!fv001.is_empty());
            // The chain is printed as a readable cycle.
            assert!(fv001[0].context.iter().any(|l| l.contains("→")));
            assert!(fv001[0].context.iter().any(|l| l.starts_with("back to ")));
        }
    }

    #[test]
    fn cleared_dateline_mask_is_rejected_and_extra_bits_warn() {
        let topo = Topology::torus(4, 4, MemEdge::West);
        let zeros = vec![0u8; topo.width as usize * topo.height as usize];
        let cleared = verify_topology(&topo, 2, &zeros);
        assert!(cleared.has_errors());
        assert!(!cleared.with_code("FV001").is_empty());
        // A mask bit on a port with no wrap channel behind it: FV102.
        let mut extra = default_masks(&topo);
        extra[5] |= 1 << crate::router::PORT_LOCAL;
        let r = verify_topology(&topo, 2, &extra);
        assert!(!r.with_code("FV102").is_empty());
        assert!(!r.has_errors(), "an extra bit alone is a warning: {r}");
    }

    #[test]
    fn attach_mismatches_are_flagged() {
        use crate::topology::NodeKind;
        let mut topo = Topology::torus(3, 3, MemEdge::West);
        let mem = topo.num_tiles; // first controller node index
        topo.nodes[mem].kind = NodeKind::MemCtrl {
            attach_port: crate::router::PORT_E, // collides with a channel
        };
        let masks = default_masks(&topo);
        let r = verify_topology(&topo, 2, &masks);
        assert!(!r.with_code("FV104").is_empty(), "{r}");
        // Beyond-radix attach is also caught, without panicking.
        topo.nodes[mem].kind = NodeKind::MemCtrl { attach_port: 9 };
        let r = verify_topology(&topo, 2, &masks);
        assert!(!r.with_code("FV104").is_empty(), "{r}");
    }

    /// Adaptive shipped defaults verify clean: the preflight restricts
    /// the CDG to the escape subgraph (the deterministic baseline at the
    /// fabric's escape-lane count), which is exactly the proof the
    /// deterministic defaults already pass.
    #[test]
    fn adaptive_defaults_are_clean() {
        for cfg in [
            NocConfig::mesh(4, 4).adaptive(),
            NocConfig::torus(4, 4).adaptive(),
            NocConfig::torus(8, 8).adaptive(),
            NocConfig::ring(8).adaptive(),
        ] {
            let r = preflight(&cfg);
            assert!(r.is_clean(), "{:?} {}x{}: {r}", cfg.topology, cfg.width, cfg.height);
        }
    }

    /// FV107: adaptive routing without a lane above the escape lanes is
    /// an error-tier lint, whatever the fabric.
    #[test]
    fn adaptive_without_adaptive_lanes_is_rejected() {
        let mut mesh = NocConfig::mesh(4, 4).adaptive();
        mesh.vcs = 1;
        let mut torus = NocConfig::torus(4, 4).adaptive();
        torus.vcs = 2;
        for cfg in [mesh, torus] {
            let r = preflight(&cfg);
            assert!(r.has_errors(), "{:?}: {r}", cfg.topology);
            assert!(!r.with_code("FV107").is_empty(), "{:?}: {r}", cfg.topology);
        }
        // The builder cannot produce the degenerate config by itself.
        assert!(preflight(&NocConfig::torus(4, 4).adaptive()).is_clean());
    }

    #[test]
    fn zero_depth_lints() {
        let mut cfg = NocConfig::mesh(2, 2);
        cfg.in_buf_depth = 0;
        let r = preflight(&cfg);
        assert!(!r.with_code("FV103").is_empty());
        assert!(!r.has_errors());
    }

    #[test]
    fn rob_budget_mismatch_lints() {
        // 256 wide slots exceed the 7-bit wide rob_idx range (8 kB /
        // 64 B = 128 addressable slots): FV105, warning tier.
        let mut cfg = NocConfig::mesh(2, 2);
        cfg.wide_init.rob_slots = 256;
        let r = preflight(&cfg);
        assert!(!r.with_code("FV105").is_empty(), "{r}");
        assert!(!r.has_errors());
        // A zero capacity would panic inside RobAllocator::new at build.
        let mut cfg = NocConfig::mesh(2, 2);
        cfg.narrow_init.rob_slots = 0;
        let r = preflight(&cfg);
        assert!(!r.with_code("FV105").is_empty(), "{r}");
        // The shipped defaults stay FV105-clean (pinned by
        // shipped_defaults_are_clean above).
    }
}
