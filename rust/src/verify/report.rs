//! Findings, reports, and the shared chain printer.
//!
//! Every check in the verifier — static or live — speaks the same
//! vocabulary: a [`Finding`] with a stable diagnostic code (`FV001`
//! style, see the table in `docs/verification.md`), a [`Severity`], a
//! [`Category`], a one-line message and span-like context lines. A
//! [`Report`] collects findings, renders them for humans
//! (`Display`) or machines ([`Report::to_json`]), and answers the one
//! question the preflight gate asks: [`Report::has_errors`].
//!
//! The chain printer ([`format_cycle`]) renders a sequence of
//! `(router, port, vc)` nodes the same way for a static
//! channel-dependency cycle ([`crate::verify::cdg`]) and for a live
//! wait-for cycle dumped by a tripped watchdog
//! ([`crate::verify::live`]), so dynamic deadlocks and static findings
//! share one report format.

use std::fmt;

use crate::flit::Coord;
use crate::router::{PORT_E, PORT_LOCAL, PORT_MEM, PORT_N, PORT_S, PORT_W};
use crate::util::json::Json;

/// How seriously a finding should be taken by the preflight gate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Suspicious but buildable: the system can be constructed and
    /// simulated; the finding names a degraded or unusual regime.
    Warning,
    /// Provably broken: building this configuration risks deadlock or
    /// misrouting. The preflight refuses unless verification is
    /// explicitly disabled ([`crate::noc::NocConfig::no_verify`]).
    Error,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        })
    }
}

/// Which pass of the pipeline produced a finding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Category {
    /// Channel-dependency-graph acyclicity (the deadlock proof).
    Deadlock,
    /// Route-table sanity (termination, reachability, U-turns, VCs).
    Route,
    /// Configuration consistency lints.
    Config,
}

impl fmt::Display for Category {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Category::Deadlock => "deadlock",
            Category::Route => "route",
            Category::Config => "config",
        })
    }
}

/// One diagnostic: a stable code, severity, category, message, and
/// indented context lines (route examples, cycle chains, ...).
#[derive(Debug, Clone)]
pub struct Finding {
    /// Stable diagnostic code (`"FV001"` style); documented in
    /// `docs/verification.md` and never renumbered.
    pub code: &'static str,
    /// Gate behavior: [`Severity::Error`] blocks construction.
    pub severity: Severity,
    /// Producing pass.
    pub category: Category,
    /// One-line statement of the problem.
    pub message: String,
    /// Span-like context: example routes, the offending cycle chain,
    /// the routers/ports involved. Rendered indented under the message.
    pub context: Vec<String>,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "{}[{}] ({}): {}",
            self.severity, self.code, self.category, self.message
        )?;
        for line in &self.context {
            writeln!(f, "    {line}")?;
        }
        Ok(())
    }
}

/// The outcome of a verification run: every finding, in pass order.
#[derive(Debug, Clone, Default)]
pub struct Report {
    /// All findings, in the order the passes produced them.
    pub findings: Vec<Finding>,
}

impl Report {
    /// An empty (clean) report.
    pub fn new() -> Self {
        Report::default()
    }

    /// Append one finding.
    pub fn push(&mut self, f: Finding) {
        self.findings.push(f);
    }

    /// Append every finding of `other`.
    pub fn merge(&mut self, other: Report) {
        self.findings.extend(other.findings);
    }

    /// Does the report contain any [`Severity::Error`] finding? This is
    /// the preflight gate: errors refuse construction, warnings do not.
    pub fn has_errors(&self) -> bool {
        self.findings.iter().any(|f| f.severity == Severity::Error)
    }

    /// No findings at all (not even warnings).
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Number of error-severity findings.
    pub fn error_count(&self) -> usize {
        self.findings
            .iter()
            .filter(|f| f.severity == Severity::Error)
            .count()
    }

    /// Number of warning-severity findings.
    pub fn warning_count(&self) -> usize {
        self.findings
            .iter()
            .filter(|f| f.severity == Severity::Warning)
            .count()
    }

    /// Findings with a given code (test/diagnostic convenience).
    pub fn with_code(&self, code: &str) -> Vec<&Finding> {
        self.findings.iter().filter(|f| f.code == code).collect()
    }

    /// Machine-readable form (schema `floonoc-verify/1`): `ok` is the
    /// gate verdict (`!has_errors`), `findings` keep pass order.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::Str("floonoc-verify/1".to_string())),
            ("ok", Json::Bool(!self.has_errors())),
            ("errors", Json::Num(self.error_count() as f64)),
            ("warnings", Json::Num(self.warning_count() as f64)),
            (
                "findings",
                Json::Arr(
                    self.findings
                        .iter()
                        .map(|f| {
                            Json::obj(vec![
                                ("code", Json::Str(f.code.to_string())),
                                ("severity", Json::Str(f.severity.to_string())),
                                ("category", Json::Str(f.category.to_string())),
                                ("message", Json::Str(f.message.clone())),
                                (
                                    "context",
                                    Json::Arr(
                                        f.context
                                            .iter()
                                            .map(|c| Json::Str(c.clone()))
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

impl fmt::Display for Report {
    /// Errors first, then warnings, then a one-line summary.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for sev in [Severity::Error, Severity::Warning] {
            for finding in self.findings.iter().filter(|x| x.severity == sev) {
                write!(f, "{finding}")?;
            }
        }
        write!(
            f,
            "verify: {} error(s), {} warning(s)",
            self.error_count(),
            self.warning_count()
        )
    }
}

/// One node of a dependency chain: a router's output `port` on VC `vc`
/// — i.e. one (channel, VC) pair, named by its producing router.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChainNode {
    /// Coordinate of the router that drives the channel.
    pub coord: Coord,
    /// Output port the channel leaves through.
    pub port: usize,
    /// Virtual-channel lane.
    pub vc: usize,
}

impl fmt::Display for ChainNode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "(router ({}, {}), {}, vc {})",
            self.coord.x,
            self.coord.y,
            port_label(self.port),
            self.vc
        )
    }
}

/// Human name of a router port (`"local"`, `"N"`, `"E"`, `"S"`, `"W"`,
/// `"mem"`; out-of-range ports print as `"port<n>"` rather than
/// panicking — the verifier must survive broken configurations).
pub fn port_label(port: usize) -> String {
    match port {
        PORT_LOCAL => "local".to_string(),
        PORT_N => "N".to_string(),
        PORT_E => "E".to_string(),
        PORT_S => "S".to_string(),
        PORT_W => "W".to_string(),
        PORT_MEM => "mem".to_string(),
        other => format!("port{other}"),
    }
}

/// Render a dependency cycle as context lines: one `(router, port, vc)`
/// node per line with a trailing arrow, closed by a `back to` line so
/// the loop is visually explicit. Both the static CDG pass and the live
/// watchdog analysis print their cycles through this one function.
pub fn format_cycle(nodes: &[ChainNode]) -> Vec<String> {
    let mut out: Vec<String> = nodes.iter().map(|n| format!("{n} →")).collect();
    if let Some(first) = nodes.first() {
        out.push(format!("back to {first}"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finding(code: &'static str, sev: Severity) -> Finding {
        Finding {
            code,
            severity: sev,
            category: Category::Config,
            message: "m".to_string(),
            context: vec![],
        }
    }

    #[test]
    fn gate_counts_and_codes() {
        let mut r = Report::new();
        assert!(r.is_clean() && !r.has_errors());
        r.push(finding("FV101", Severity::Warning));
        assert!(!r.has_errors() && !r.is_clean());
        r.push(finding("FV001", Severity::Error));
        assert!(r.has_errors());
        assert_eq!((r.error_count(), r.warning_count()), (1, 1));
        assert_eq!(r.with_code("FV001").len(), 1);
    }

    #[test]
    fn display_orders_errors_first() {
        let mut r = Report::new();
        r.push(finding("FV101", Severity::Warning));
        r.push(finding("FV001", Severity::Error));
        let text = r.to_string();
        let e = text.find("error[FV001]").unwrap();
        let w = text.find("warning[FV101]").unwrap();
        assert!(e < w, "{text}");
        assert!(text.ends_with("verify: 1 error(s), 1 warning(s)"), "{text}");
    }

    #[test]
    fn cycle_printer_closes_the_loop() {
        let a = ChainNode {
            coord: Coord::new(0, 0),
            port: PORT_E,
            vc: 0,
        };
        let b = ChainNode {
            coord: Coord::new(1, 0),
            port: PORT_W,
            vc: 1,
        };
        let lines = format_cycle(&[a, b]);
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[0], "(router (0, 0), E, vc 0) →");
        assert_eq!(lines[1], "(router (1, 0), W, vc 1) →");
        assert_eq!(lines[2], "back to (router (0, 0), E, vc 0)");
    }

    #[test]
    fn json_shape() {
        let mut r = Report::new();
        r.push(finding("FV001", Severity::Error));
        let j = r.to_json();
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(j.get("errors").and_then(Json::as_f64), Some(1.0));
        assert_eq!(
            j.get("schema").and_then(Json::as_str),
            Some("floonoc-verify/1")
        );
    }
}
