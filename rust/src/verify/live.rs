//! Live wait-for analysis: the verifier's view of a *running* (and
//! possibly wedged) fabric.
//!
//! When the stalled-cycle watchdog
//! ([`crate::cluster::TiledWorkload::run_with_watchdog`]) trips, this
//! module explains the freeze instead of leaving a bare "no progress"
//! panic: for every input-buffer head flit in every network it computes
//! the output lane the switch would assign — the same route lookup and
//! capped dateline rule the router itself applies — and reports the
//! blocked `(router, input, vc) → (output, vc)` dependencies: heads
//! whose wanted output lane is wormhole-locked by *another* packet
//! ([`crate::router::router::Router::lock_holder`]) or backpressured by
//! a full downstream lane. Running Tarjan over those wait-for edges
//! (nodes are `(link, vc)` pairs, like the static CDG's) surfaces any
//! cycle among them — a live wormhole deadlock — printed through the
//! same chain printer static `FV001` findings use
//! ([`crate::verify::report::format_cycle`]).
//!
//! No blocked dependency at all is itself a diagnosis: the fabric is
//! idle or draining, so the stall lives outside it (NI, generator, or
//! memory model).

use crate::noc::NocSystem;
use crate::router::routing::dateline_vc;
use crate::router::MAX_VCS;

use super::cdg::{extract_cycle, sccs};
use super::report::{format_cycle, port_label, ChainNode};

/// Blocked-input lines printed per network before eliding the rest.
const MAX_LINES: usize = 16;

/// Render the live wait-for analysis of `sys`'s current state as a
/// multi-line report (one section per network). Read-only: safe to call
/// on a live, wedged, or drained system.
pub fn analyze(sys: &NocSystem) -> String {
    let mut out = format!("live wait-for analysis at cycle {}:\n", sys.now);
    let mut any_blocked = false;
    for (ni, net) in sys.nets.iter().enumerate() {
        // Producer map: which (router, output port) drives each link.
        let mut src_of: Vec<Option<(usize, usize)>> = vec![None; net.links.len()];
        for (r, router) in net.routers.iter().enumerate() {
            for (port, lid) in router.out_links.iter().enumerate() {
                if let Some(lid) = lid {
                    src_of[*lid] = Some((r, port));
                }
            }
        }
        // Wait-for edges over (link, vc) nodes, stride MAX_VCS.
        let n_nodes = net.links.len() * MAX_VCS;
        let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n_nodes];
        let mut lines = 0usize;
        let mut elided = 0usize;
        for (r, router) in net.routers.iter().enumerate() {
            let coord = sys.topo.nodes[r].coord;
            for (i, in_lid) in router.in_links.iter().enumerate() {
                let Some(in_lid) = *in_lid else { continue };
                for v in 0..net.links[in_lid].vcs() {
                    let Some(flit) = net.links[in_lid].peek_vc(v) else {
                        continue;
                    };
                    let o = router.table.lookup(flit.header.dst);
                    let Some(out_lid) = router.out_links.get(o).copied().flatten() else {
                        continue;
                    };
                    let wrap = router.table.crosses_dateline(o);
                    let out_vcs = net.links[out_lid].vcs();
                    let v_out =
                        (dateline_vc(i, o, wrap, v as u8) as usize).min(out_vcs - 1);
                    let lock = router.lock_holder(o, v_out);
                    let locked_by_other =
                        matches!(lock, Some(h) if h != (i as u8, v as u8));
                    let backpressured = !net.links[out_lid].can_offer_vc(v_out);
                    if !(locked_by_other || backpressured) {
                        continue;
                    }
                    any_blocked = true;
                    adj[in_lid * MAX_VCS + v].push((out_lid * MAX_VCS + v_out) as u32);
                    if lines < MAX_LINES {
                        let why = if locked_by_other {
                            let (hp, hv) = lock.expect("locked_by_other implies a holder");
                            format!("locked by input ({}, vc {hv})", port_label(hp as usize))
                        } else {
                            "backpressured".to_string()
                        };
                        out.push_str(&format!(
                            "  net {ni}: (router ({}, {}), in {}, vc {v}) → ({}, vc {v_out}): \
                             {why} [head → node {}]\n",
                            coord.x,
                            coord.y,
                            port_label(i),
                            port_label(o),
                            flit.header.dst.0
                        ));
                        lines += 1;
                    } else {
                        elided += 1;
                    }
                }
            }
        }
        if elided > 0 {
            out.push_str(&format!(
                "  net {ni}: ... and {elided} more blocked input(s)\n"
            ));
        }
        // Cycles among the wait-for edges: a live wormhole deadlock.
        for comp in sccs(n_nodes, &adj).into_iter().filter(|c| c.len() > 1) {
            let cycle = extract_cycle(&adj, &comp);
            let chain: Vec<ChainNode> = cycle
                .iter()
                .filter_map(|&node| {
                    let (lid, vc) = (node as usize / MAX_VCS, node as usize % MAX_VCS);
                    src_of[lid].map(|(r, port)| ChainNode {
                        coord: sys.topo.nodes[r].coord,
                        port,
                        vc,
                    })
                })
                .collect();
            out.push_str(&format!("  net {ni}: wait-for cycle (wormhole deadlock):\n"));
            for line in format_cycle(&chain) {
                out.push_str("    ");
                out.push_str(&line);
                out.push('\n');
            }
        }
    }
    if !any_blocked {
        out.push_str(
            "  no blocked (router, input, vc) → (output, vc) dependency in any network — \
             the stall is outside the fabric (NI / generator / memory model)\n",
        );
    }
    out
}
