//! The channel-dependency-graph pass: route sanity + Dally/Seitz
//! acyclicity, statically, before a single cycle runs.
//!
//! The model: a CDG node is one **(directed channel, VC lane)** pair of
//! the fabric's router-to-router channels ([`Topology::channels`] gives
//! each physical channel once; we split it into its two directions).
//! Walking every minimal `src → dst` route through the generated
//! [`RouteTable`]s, applying the dateline rule
//! ([`crate::router::routing::dateline_vc`]) with the **same output-VC
//! cap the router's switch applies at runtime**
//! (`min(assigned, vcs - 1)`), yields a dependency edge for every pair
//! of channels a wormhole packet can hold simultaneously. By Dally &
//! Seitz, an acyclic CDG means no routing-level wormhole deadlock;
//! a cycle is reported as a readable `(router, port, vc) → …` chain
//! (diagnostic `FV001`).
//!
//! Injection and ejection channels are deliberately not CDG nodes: an
//! injection channel has no predecessor and an ejection channel has no
//! successor, so neither can lie on a cycle.
//!
//! The same walk checks route-table sanity along the way: every route
//! must terminate within its minimal hop bound (`FV002`), never U-turn
//! (`FV003`), only exit through connected ports and eject exactly at
//! its destination (`FV004`), and the dateline assignment must stay
//! within the configured VC count (`FV005` — a warning, because the
//! switch caps the lane at runtime; the capped graph is what the
//! `FV001` analysis judges). Note what this makes the graph analysis
//! *sharper* than any "wrap fabrics need 2 VCs" lint: a wrapping
//! dimension shorter than 4 routers produces no same-dimension
//! dependency edge (every in-dimension trip is a single hop), so e.g. a
//! 3×3 torus at `vcs = 1` is **provably deadlock-free** and accepted,
//! while a 4×4 torus at `vcs = 1` closes the directional ring and is
//! rejected with its cycle printed.

use crate::router::routing::dateline_vc;
use crate::router::{RouteTable, PORT_LOCAL};
use crate::topology::{NodeKind, Topology};

use super::report::{format_cycle, port_label, Category, ChainNode, Finding, Report, Severity};

/// One direction of a physical channel: `src` router drives it out of
/// `out_port`; `dst` router receives it on `in_port`.
#[derive(Debug, Clone, Copy)]
struct DirLink {
    src: usize,
    out_port: usize,
    dst: usize,
    in_port: usize,
}

/// How many example routes each aggregated route-sanity finding keeps.
const MAX_EXAMPLES: usize = 3;
/// How many cyclic components `FV001` prints chains for.
const MAX_CYCLES: usize = 4;

/// Per-code aggregation of route-walk findings (one `Finding` per code,
/// with a violation count and a few example routes as context).
struct RouteAgg {
    code: &'static str,
    severity: Severity,
    what: &'static str,
    count: usize,
    examples: Vec<String>,
}

impl RouteAgg {
    fn new(code: &'static str, severity: Severity, what: &'static str) -> Self {
        RouteAgg {
            code,
            severity,
            what,
            count: 0,
            examples: Vec::new(),
        }
    }

    fn hit(&mut self, example: String) {
        self.count += 1;
        if self.examples.len() < MAX_EXAMPLES {
            self.examples.push(example);
        }
    }

    fn flush(self, report: &mut Report) {
        if self.count == 0 {
            return;
        }
        let mut context = self.examples;
        if self.count > context.len() {
            context.push(format!(
                "... {} violating route(s) in total",
                self.count
            ));
        }
        report.push(Finding {
            code: self.code,
            severity: self.severity,
            category: Category::Route,
            message: format!("{} route(s) {}", self.count, self.what),
            context,
        });
    }
}

/// Run the route-sanity walk and the CDG acyclicity check over `topo`
/// with `vcs` lanes per channel and the per-router dateline-mask array
/// `masks` (bit `p` of `masks[r]` marks router `r`'s output `p` as a
/// wraparound exit). Findings are appended to `report`.
///
/// `masks` is taken explicitly — rather than read from the generated
/// tables — so callers can verify *hypothetical* fabrics: pass
/// [`crate::verify::default_masks`] for the deployed configuration, or
/// an all-zero array to prove what clearing the dateline would do.
pub fn analyze(topo: &Topology, vcs: usize, masks: &[u8], report: &mut Report) {
    assert!(vcs >= 1, "a fabric has at least one VC lane");
    let num_routers = topo.width as usize * topo.height as usize;
    let radix = topo.router_radix();

    // Directed channel table + per-router output map.
    let mut dirlinks: Vec<DirLink> = Vec::new();
    let mut out_map: Vec<Vec<Option<usize>>> = vec![vec![None; radix]; num_routers];
    for (a, pa, b, pb) in topo.channels() {
        out_map[a][pa] = Some(dirlinks.len());
        dirlinks.push(DirLink {
            src: a,
            out_port: pa,
            dst: b,
            in_port: pb,
        });
        out_map[b][pb] = Some(dirlinks.len());
        dirlinks.push(DirLink {
            src: b,
            out_port: pb,
            dst: a,
            in_port: pa,
        });
    }

    let tables: Vec<RouteTable> = (0..num_routers)
        .map(|r| topo.route_table(topo.nodes[r].coord))
        .collect();
    let mask_of = |r: usize| masks.get(r).copied().unwrap_or(0);

    let mut fv002 = RouteAgg::new(
        "FV002",
        Severity::Error,
        "exceed their minimal hop bound (non-terminating or detouring table)",
    );
    let mut fv003 = RouteAgg::new("FV003", Severity::Error, "U-turn (exit == entry port)");
    let mut fv004 = RouteAgg::new(
        "FV004",
        Severity::Error,
        "exit through an unconnected port or miss their destination's attach port",
    );
    let mut fv005 = RouteAgg::new(
        "FV005",
        Severity::Warning,
        "get a dateline VC beyond the configured count (lane capped at runtime; \
         dateline separation disabled on these hops)",
    );

    // CDG edges over (dirlink, capped VC) nodes, deduplicated.
    let n_nodes = dirlinks.len() * vcs;
    let mut edges: std::collections::BTreeSet<(u32, u32)> = std::collections::BTreeSet::new();

    for src in &topo.nodes {
        for dst in &topo.nodes {
            if src.id == dst.id {
                continue;
            }
            let label = |at: String| format!("route {} → {}: {at}", src.id.0, dst.id.0);
            let dst_router = topo.router_index(dst.coord);
            let terminal_port = match dst.kind {
                NodeKind::Tile => PORT_LOCAL,
                NodeKind::MemCtrl { attach_port } => attach_port,
            };
            let mut at = topo.router_index(src.coord);
            let mut in_port = match src.kind {
                NodeKind::Tile => PORT_LOCAL,
                NodeKind::MemCtrl { attach_port } => attach_port,
            };
            let mut vc: usize = 0;
            let mut prev: Option<u32> = None;
            let bound = topo.hops(src.id, dst.id) as usize;
            let mut hops = 0usize;
            loop {
                let port = tables[at].lookup(dst.id);
                let coord = topo.nodes[at].coord;
                if at == dst_router {
                    if port != terminal_port {
                        fv004.hit(label(format!(
                            "at destination router ({}, {}) the table says {} \
                             instead of the attach port {}",
                            coord.x,
                            coord.y,
                            port_label(port),
                            port_label(terminal_port)
                        )));
                    }
                    break;
                }
                if port == in_port {
                    fv003.hit(label(format!(
                        "U-turn at router ({}, {}): enters and exits {}",
                        coord.x,
                        coord.y,
                        port_label(port)
                    )));
                    break;
                }
                let Some(dl) = out_map[at].get(port).copied().flatten() else {
                    fv004.hit(label(format!(
                        "router ({}, {}) exit {} has no channel",
                        coord.x,
                        coord.y,
                        port_label(port)
                    )));
                    break;
                };
                let wrap = (mask_of(at) >> port) & 1 == 1;
                let raw = dateline_vc(in_port, port, wrap, vc as u8) as usize;
                if raw >= vcs {
                    fv005.hit(label(format!(
                        "exit {} at router ({}, {}) assigns vc {raw} >= vcs {vcs}",
                        port_label(port),
                        coord.x,
                        coord.y
                    )));
                }
                let capped = raw.min(vcs - 1);
                let node = (dl * vcs + capped) as u32;
                if let Some(p) = prev {
                    edges.insert((p, node));
                }
                prev = Some(node);
                at = dirlinks[dl].dst;
                in_port = dirlinks[dl].in_port;
                vc = capped;
                hops += 1;
                if hops > bound {
                    fv002.hit(label(format!(
                        "still in transit after {bound} hop(s) (the minimal bound)"
                    )));
                    break;
                }
            }
        }
    }

    fv002.flush(report);
    fv003.flush(report);
    fv004.flush(report);
    fv005.flush(report);

    // Acyclicity: Tarjan SCCs over the dependency edges; any SCC with
    // more than one node (self-edges cannot occur — a directed channel
    // never follows itself) is a wormhole-deadlock cycle.
    let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n_nodes];
    for &(a, b) in &edges {
        adj[a as usize].push(b);
    }
    let cyclic: Vec<Vec<u32>> = sccs(n_nodes, &adj)
        .into_iter()
        .filter(|c| c.len() > 1)
        .collect();
    for comp in cyclic.iter().take(MAX_CYCLES) {
        let cycle = extract_cycle(&adj, comp);
        let chain: Vec<ChainNode> = cycle
            .iter()
            .map(|&node| {
                let dl = dirlinks[node as usize / vcs];
                ChainNode {
                    coord: topo.nodes[dl.src].coord,
                    port: dl.out_port,
                    vc: node as usize % vcs,
                }
            })
            .collect();
        let mut context = vec![format!(
            "cyclic dependency over {} (channel, vc) node(s):",
            comp.len()
        )];
        context.extend(format_cycle(&chain));
        report.push(Finding {
            code: "FV001",
            severity: Severity::Error,
            category: Category::Deadlock,
            message: "channel dependency graph has a cycle — wormhole deadlock is reachable"
                .to_string(),
            context,
        });
    }
    if cyclic.len() > MAX_CYCLES {
        report.push(Finding {
            code: "FV001",
            severity: Severity::Error,
            category: Category::Deadlock,
            message: format!(
                "... and {} more cyclic component(s) not printed",
                cyclic.len() - MAX_CYCLES
            ),
            context: vec![],
        });
    }
}

/// The **sharpness** analysis behind the escape-VC restriction: build
/// the channel-level dependency graph of *unrestricted* minimal-adaptive
/// routing — an edge `c1 → c2` whenever some destination `d` makes `c1`
/// productive from its source router **and** `c2` productive from `c1`'s
/// sink router (per [`Topology::route_table_adaptive`]'s candidate
/// masks) — and report any cycle as `FV001`.
///
/// This is what adaptive routing would be *without* the Duato escape
/// lanes: every wrap fabric with a ring dimension of 4+ routers, and
/// every mesh of 2×2 or larger (the adaptive candidate sets admit all
/// four turn directions, closing the classic turn cycle), is cyclic
/// here. The deployed router never offers these full candidate sets to
/// a single lane class — adaptive lanes always sit above a proven-
/// acyclic escape subgraph — so a finding from this pass is the
/// *justification* for that restriction, not a defect in the deployed
/// fabric. VC lanes are deliberately not modelled: adaptivity lets a
/// packet use any adaptive lane of a chosen channel, so lanes add no
/// separation the channel-level graph doesn't already show.
pub fn analyze_adaptive_unrestricted(topo: &Topology, report: &mut Report) {
    let num_routers = topo.width as usize * topo.height as usize;
    let radix = topo.router_radix();

    let mut dirlinks: Vec<DirLink> = Vec::new();
    let mut out_map: Vec<Vec<Option<usize>>> = vec![vec![None; radix]; num_routers];
    for (a, pa, b, pb) in topo.channels() {
        out_map[a][pa] = Some(dirlinks.len());
        dirlinks.push(DirLink {
            src: a,
            out_port: pa,
            dst: b,
            in_port: pb,
        });
        out_map[b][pb] = Some(dirlinks.len());
        dirlinks.push(DirLink {
            src: b,
            out_port: pb,
            dst: a,
            in_port: pa,
        });
    }

    let tables: Vec<RouteTable> = (0..num_routers)
        .map(|r| topo.route_table_adaptive(topo.nodes[r].coord))
        .collect();

    let mut edges: std::collections::BTreeSet<(u32, u32)> = std::collections::BTreeSet::new();
    for (i, dl) in dirlinks.iter().enumerate() {
        for d in &topo.nodes {
            // `dl` carries a packet for `d` iff its exit is a candidate
            // at its source router. A destination's own router returns
            // only the attach/local port, which has no neighbour
            // channel — so terminated routes add no edges naturally.
            if tables[dl.src].candidates(d.id) & (1 << dl.out_port) == 0 {
                continue;
            }
            let next_cand = tables[dl.dst].candidates(d.id);
            for (p, &slot) in out_map[dl.dst].iter().enumerate() {
                let Some(j) = slot else { continue };
                if next_cand & (1 << p) != 0 {
                    edges.insert((i as u32, j as u32));
                }
            }
        }
    }

    let mut adj: Vec<Vec<u32>> = vec![Vec::new(); dirlinks.len()];
    for &(a, b) in &edges {
        adj[a as usize].push(b);
    }
    let cyclic: Vec<Vec<u32>> = sccs(dirlinks.len(), &adj)
        .into_iter()
        .filter(|c| c.len() > 1)
        .collect();
    // The printed lane is the fabric's first adaptive lane — the lane
    // class the unrestricted candidates would actually deadlock.
    let lane = topo.kind.default_vcs();
    for comp in cyclic.iter().take(MAX_CYCLES) {
        let cycle = extract_cycle(&adj, comp);
        let chain: Vec<ChainNode> = cycle
            .iter()
            .map(|&node| {
                let dl = dirlinks[node as usize];
                ChainNode {
                    coord: topo.nodes[dl.src].coord,
                    port: dl.out_port,
                    vc: lane,
                }
            })
            .collect();
        let mut context = vec![format!(
            "unrestricted adaptive candidates close a cycle over {} channel(s):",
            comp.len()
        )];
        context.extend(format_cycle(&chain));
        report.push(Finding {
            code: "FV001",
            severity: Severity::Error,
            category: Category::Deadlock,
            message: "adaptive routing without the escape-VC restriction has a cyclic \
                      channel dependency graph — wormhole deadlock is reachable"
                .to_string(),
            context,
        });
    }
    if cyclic.len() > MAX_CYCLES {
        report.push(Finding {
            code: "FV001",
            severity: Severity::Error,
            category: Category::Deadlock,
            message: format!(
                "... and {} more cyclic component(s) not printed",
                cyclic.len() - MAX_CYCLES
            ),
            context: vec![],
        });
    }
}

/// Tarjan's strongly-connected components, iterative (explicit frame
/// stack — fabric CDGs are small, but recursion depth must not depend
/// on fabric size). Returns every SCC; order is reverse-topological.
pub(crate) fn sccs(n: usize, adj: &[Vec<u32>]) -> Vec<Vec<u32>> {
    const UNSET: u32 = u32::MAX;
    let mut index = vec![UNSET; n];
    let mut low = vec![0u32; n];
    let mut on_stack = vec![false; n];
    let mut stack: Vec<u32> = Vec::new();
    let mut frames: Vec<(u32, usize)> = Vec::new();
    let mut next = 0u32;
    let mut out: Vec<Vec<u32>> = Vec::new();
    for root in 0..n {
        if index[root] != UNSET {
            continue;
        }
        index[root] = next;
        low[root] = next;
        next += 1;
        stack.push(root as u32);
        on_stack[root] = true;
        frames.push((root as u32, 0));
        while let Some(&(v, ci)) = frames.last() {
            let vi = v as usize;
            if ci < adj[vi].len() {
                let w = adj[vi][ci] as usize;
                frames.last_mut().expect("frame exists").1 += 1;
                if index[w] == UNSET {
                    index[w] = next;
                    low[w] = next;
                    next += 1;
                    stack.push(w as u32);
                    on_stack[w] = true;
                    frames.push((w as u32, 0));
                } else if on_stack[w] {
                    low[vi] = low[vi].min(index[w]);
                }
            } else {
                frames.pop();
                if let Some(&(p, _)) = frames.last() {
                    let pi = p as usize;
                    low[pi] = low[pi].min(low[vi]);
                }
                if low[vi] == index[vi] {
                    let mut comp = Vec::new();
                    loop {
                        let w = stack.pop().expect("SCC root on stack");
                        on_stack[w as usize] = false;
                        comp.push(w);
                        if w as usize == vi {
                            break;
                        }
                    }
                    out.push(comp);
                }
            }
        }
    }
    out
}

/// Extract one concrete cycle from a cyclic SCC by following, from any
/// member, the first successor that stays inside the component until a
/// node repeats; the segment from its first occurrence is the cycle.
/// Every node of a multi-node SCC has an intra-component successor, so
/// this terminates within `|scc| + 1` steps.
pub(crate) fn extract_cycle(adj: &[Vec<u32>], comp: &[u32]) -> Vec<u32> {
    let in_comp: std::collections::BTreeSet<u32> = comp.iter().copied().collect();
    let mut path: Vec<u32> = vec![comp[0]];
    let mut pos: std::collections::BTreeMap<u32, usize> = std::collections::BTreeMap::new();
    pos.insert(comp[0], 0);
    loop {
        let cur = *path.last().expect("path is non-empty");
        let next = adj[cur as usize]
            .iter()
            .copied()
            .find(|w| in_comp.contains(w))
            .expect("every node of a cyclic SCC has an intra-SCC successor");
        if let Some(&i) = pos.get(&next) {
            return path[i..].to_vec();
        }
        pos.insert(next, path.len());
        path.push(next);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::MemEdge;

    /// The sharpness pass flags every fabric whose unrestricted
    /// adaptive candidates close a cycle: wrap fabrics with a 4+ ring
    /// dimension and meshes of 2×2 or larger (the full turn set).
    #[test]
    fn unrestricted_adaptive_is_cyclic_on_real_fabrics() {
        for topo in [
            Topology::torus(4, 4, MemEdge::None),
            Topology::ring(4, MemEdge::None),
            Topology::mesh(2, 2, MemEdge::None),
            Topology::mesh(4, 4, MemEdge::West),
        ] {
            let mut report = Report::new();
            analyze_adaptive_unrestricted(&topo, &mut report);
            assert!(
                !report.with_code("FV001").is_empty(),
                "{:?} {}x{}: expected a cycle without the escape restriction",
                topo.kind,
                topo.width,
                topo.height
            );
        }
    }

    /// Degenerate fabrics with no closable cycle stay clean even
    /// without the escape restriction: a 1-D mesh line (single
    /// productive direction, no turns) and a 3-ring (every pair is one
    /// hop, so no channel ever depends on another).
    #[test]
    fn unrestricted_adaptive_is_acyclic_on_degenerate_fabrics() {
        for topo in [Topology::mesh(4, 1, MemEdge::None), Topology::ring(3, MemEdge::None)] {
            let mut report = Report::new();
            analyze_adaptive_unrestricted(&topo, &mut report);
            assert!(
                !report.has_errors(),
                "{:?} {}x{}: {:?}",
                topo.kind,
                topo.width,
                topo.height,
                report.findings
            );
        }
    }

    #[test]
    fn tarjan_finds_the_cycle_and_the_tail() {
        // 0 → 1 → 2 → 0 (cycle), 3 → 0 (tail), 4 isolated.
        let adj: Vec<Vec<u32>> = vec![vec![1], vec![2], vec![0], vec![0], vec![]];
        let comps = sccs(5, &adj);
        let mut sizes: Vec<usize> = comps.iter().map(|c| c.len()).collect();
        sizes.sort_unstable();
        assert_eq!(sizes, vec![1, 1, 3]);
        let cyc = comps.into_iter().find(|c| c.len() == 3).unwrap();
        let mut cycle = extract_cycle(&adj, &cyc);
        assert_eq!(cycle.len(), 3);
        cycle.sort_unstable();
        assert_eq!(cycle, vec![0, 1, 2]);
    }

    #[test]
    fn tarjan_on_a_dag_yields_singletons() {
        let adj: Vec<Vec<u32>> = vec![vec![1, 2], vec![3], vec![3], vec![]];
        let comps = sccs(4, &adj);
        assert_eq!(comps.len(), 4);
        assert!(comps.iter().all(|c| c.len() == 1));
    }

    #[test]
    fn two_disjoint_cycles_are_two_components() {
        let adj: Vec<Vec<u32>> = vec![vec![1], vec![0], vec![3], vec![2]];
        let comps = sccs(4, &adj);
        let cyclic: Vec<_> = comps.into_iter().filter(|c| c.len() > 1).collect();
        assert_eq!(cyclic.len(), 2);
        for c in &cyclic {
            assert_eq!(extract_cycle(&adj, c).len(), 2);
        }
    }
}
