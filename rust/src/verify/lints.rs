//! Configuration-consistency lints (`FV101`–`FV106`).
//!
//! These are the pipeline's warning tier: each names a configuration
//! that builds and simulates but is degraded, surprising, or one step
//! from the error tier. Codes are stable; the table lives in
//! `docs/verification.md`.
//!
//! * `FV101` — a wrap fabric (torus/ring) configured with fewer VCs
//!   than its dateline default: the lane-separation scheme is (partly)
//!   disabled. Whether that actually deadlocks is decided by the CDG
//!   pass ([`crate::verify::cdg`]), which is sharper than this lint —
//!   small wrap fabrics (every dimension shorter than 4) stay acyclic
//!   even at 1 VC.
//! * `FV102` — a dateline-mask bit on a port that has no wraparound
//!   channel: the VC switch would escalate lanes on a plain grid hop.
//! * `FV103` — a zero input-buffer depth: `Link::with_vcs` silently
//!   clamps every lane to at least one slot, so the built system is
//!   deeper than the config says.
//! * `FV104` — memory-controller attach-port mismatches: an attach
//!   port beyond the router radix, or colliding with a neighbour
//!   channel or another node's local port.
//! * `FV105` — a ROB capacity that mismatches the wire format's byte
//!   budget: the flit header's `rob_idx` field is sized from the paper
//!   layout ([`RobParams`]: 2 kB / 8 B narrow ⇒ 8 bits, 8 kB / 64 B
//!   wide ⇒ 7 bits), while the simulated allocator takes its capacity
//!   from the `rob_slots` config knob — a slot count the header cannot
//!   index could not echo its grants in hardware, and a zero capacity
//!   panics at build (`RobAllocator::new`).
//! * `FV106` — an input-buffer depth smaller than the VC count:
//!   `Link::with_vcs` splits the configured depth across lanes as
//!   `(depth / vcs).max(1)`, so every lane collapses to a single
//!   buffer slot and the built fabric holds `vcs` slots per link —
//!   *more* than configured, with *less* slack per lane than the
//!   depth knob suggests (single-slot lanes serialize wormhole
//!   continuations behind the register stage).
//! * `FV107` — **error** tier: adaptive routing with no lane above the
//!   fabric's escape lanes (`vcs <= default_vcs`). The escape lanes run
//!   the deterministic baseline, so such a config has zero adaptive
//!   lanes — every head takes the escape fallback and the "adaptive"
//!   fabric silently degenerates to deterministic routing. An error
//!   rather than a warning because the configuration cannot mean what
//!   it says; `NocConfig::adaptive` raises `vcs` automatically.

use crate::flit::RobParams;
use crate::noc::NocConfig;
use crate::router::RoutingKind;
use crate::topology::{NodeKind, Topology};

use super::report::{port_label, Category, Finding, Report, Severity};

/// Config-level lints (`FV101`, `FV103`, `FV105`–`FV107`): facts
/// readable from the [`NocConfig`] knobs plus the fabric geometry.
pub fn lint_config(cfg: &NocConfig, topo: &Topology, report: &mut Report) {
    let num_routers = topo.width as usize * topo.height as usize;
    let wraps = (0..num_routers).any(|r| topo.dateline_ports(topo.nodes[r].coord) != 0);
    let default_vcs = cfg.topology.default_vcs();
    // FV107 (error): adaptive routing needs at least one lane above the
    // escape lanes, or there is nothing to adapt on.
    if cfg.routing == RoutingKind::Adaptive && cfg.vcs < default_vcs + 1 {
        report.push(Finding {
            code: "FV107",
            severity: Severity::Error,
            category: Category::Config,
            message: format!(
                "adaptive routing with vcs = {} leaves no adaptive lane above the \
                 {} escape lane(s) this fabric reserves for the deterministic \
                 baseline; the config degenerates to deterministic routing",
                cfg.vcs, default_vcs
            ),
            context: vec![format!(
                "raise vcs to at least {} (NocConfig::adaptive does this \
                 automatically), or drop routing back to deterministic",
                default_vcs + 1
            )],
        });
    }
    if wraps && cfg.vcs < default_vcs {
        report.push(Finding {
            code: "FV101",
            severity: Severity::Warning,
            category: Category::Config,
            message: format!(
                "wrap fabric configured with vcs = {} (below the dateline default {}); \
                 deadlock freedom now rests on the CDG analysis alone",
                cfg.vcs, default_vcs
            ),
            context: vec![
                "the FV001 pass decides whether this particular fabric stays acyclic"
                    .to_string(),
            ],
        });
    }
    if cfg.in_buf_depth == 0 {
        report.push(Finding {
            code: "FV103",
            severity: Severity::Warning,
            category: Category::Config,
            message: "in_buf_depth = 0: Link::with_vcs clamps every lane to >= 1 slot, \
                      so the built fabric is deeper than configured"
                .to_string(),
            context: vec![],
        });
    }
    // FV106: a depth smaller than the VC count collapses every lane to
    // the one-slot minimum (`(depth / vcs).max(1)`). Gated on depth >= 1
    // so a zero depth reports only FV103, not both.
    if cfg.vcs > 1 && cfg.in_buf_depth >= 1 && cfg.in_buf_depth < cfg.vcs {
        let per_lane = (cfg.in_buf_depth / cfg.vcs).max(1);
        report.push(Finding {
            code: "FV106",
            severity: Severity::Warning,
            category: Category::Config,
            message: format!(
                "in_buf_depth = {} is below vcs = {}: Link::with_vcs degrades every \
                 lane to {per_lane} buffer slot(s), so each link carries {} total \
                 slots instead of the configured {}",
                cfg.in_buf_depth,
                cfg.vcs,
                cfg.vcs * per_lane,
                cfg.in_buf_depth
            ),
            context: vec![
                "single-slot lanes serialize wormhole continuations behind the \
                 register stage; raise in_buf_depth to at least vcs"
                    .to_string(),
            ],
        });
    }
    // FV105: ROB byte budgets that mismatch the wire format.
    for (which, slots, params) in [
        ("narrow", cfg.narrow_init.rob_slots, RobParams::narrow()),
        ("wide", cfg.wide_init.rob_slots, RobParams::wide()),
    ] {
        let addressable = 1u32 << params.idx_bits();
        if slots == 0 {
            report.push(Finding {
                code: "FV105",
                severity: Severity::Warning,
                category: Category::Config,
                message: format!(
                    "{which} initiator configured with rob_slots = 0: \
                     RobAllocator::new panics at build (a ROB needs at least one slot)"
                ),
                context: vec![],
            });
        } else if slots > addressable {
            report.push(Finding {
                code: "FV105",
                severity: Severity::Warning,
                category: Category::Config,
                message: format!(
                    "{which} ROB byte budget mismatch: rob_slots = {slots} \
                     ({} B at the {} B granule) exceeds the {addressable} slots \
                     the wire-format rob_idx field can address ({} B budget, \
                     {} index bits)",
                    slots as u64 * params.granule as u64,
                    params.granule,
                    params.bytes,
                    params.idx_bits()
                ),
                context: vec![
                    "grants beyond the addressable range could not be echoed in \
                     hardware headers; shrink rob_slots or widen RobParams"
                        .to_string(),
                ],
            });
        }
    }
}

/// Topology-structural lints (`FV102`, `FV104`): facts readable from
/// the fabric geometry plus the dateline-mask array under test.
pub fn lint_topology(topo: &Topology, masks: &[u8], report: &mut Report) {
    let num_routers = topo.width as usize * topo.height as usize;
    let radix = topo.router_radix();

    // FV102: mask bits with no wraparound channel behind them.
    let mut extra_ctx = Vec::new();
    for r in 0..num_routers {
        let coord = topo.nodes[r].coord;
        let extra = masks.get(r).copied().unwrap_or(0) & !topo.dateline_ports(coord);
        for port in 0..8 {
            if (extra >> port) & 1 == 1 {
                extra_ctx.push(format!(
                    "router ({}, {}): dateline bit on non-wrap exit {}",
                    coord.x,
                    coord.y,
                    port_label(port)
                ));
            }
        }
    }
    if !extra_ctx.is_empty() {
        report.push(Finding {
            code: "FV102",
            severity: Severity::Warning,
            category: Category::Config,
            message: format!(
                "{} dateline-mask bit(s) on ports without a wraparound channel",
                extra_ctx.len()
            ),
            context: extra_ctx,
        });
    }

    // FV104: local attach ports must exist and be exclusive — neighbour
    // channels and node attachments may never share a router port.
    let mut used: Vec<Vec<Option<String>>> = vec![vec![None; radix]; num_routers];
    for (a, pa, b, pb) in topo.channels() {
        used[a][pa] = Some("a neighbour channel".to_string());
        used[b][pb] = Some("a neighbour channel".to_string());
    }
    let mut attach_ctx = Vec::new();
    for node in &topo.nodes {
        let r = topo.router_index(node.coord);
        let (port, what) = match node.kind {
            NodeKind::Tile => (crate::router::PORT_LOCAL, "tile"),
            NodeKind::MemCtrl { attach_port } => (attach_port, "memory controller"),
        };
        let coord = topo.nodes[r].coord;
        if port >= radix {
            attach_ctx.push(format!(
                "node {} ({what}) attaches to router ({}, {}) port {port}, \
                 beyond the radix {radix}",
                node.id.0, coord.x, coord.y
            ));
            continue;
        }
        if let Some(prev) = &used[r][port] {
            attach_ctx.push(format!(
                "node {} ({what}) attach {} at router ({}, {}) collides with {prev}",
                node.id.0,
                port_label(port),
                coord.x,
                coord.y
            ));
        } else {
            used[r][port] = Some(format!("node {}'s local port", node.id.0));
        }
    }
    if !attach_ctx.is_empty() {
        report.push(Finding {
            code: "FV104",
            severity: Severity::Warning,
            category: Category::Config,
            message: format!(
                "{} memory-port / local-port attach mismatch(es)",
                attach_ctx.len()
            ),
            context: attach_ctx,
        });
    }
}
