//! PJRT runtime: load and execute the AOT-lowered JAX/Pallas artifacts.
//!
//! With the `pjrt` feature enabled this wraps the `xla` crate:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` → `compile` →
//! `execute`. One compiled executable per model entry point; Python never
//! runs on this path.
//!
//! The offline crate snapshot has no `xla` bindings, so the default build
//! compiles a stub with the same public API whose [`Runtime::new`] returns
//! a descriptive error. Callers that merely cross-check against the
//! artifacts (the `dse` command, the `dse_sweep` example,
//! `tests/integration_runtime.rs`) treat that error as "artifacts
//! unavailable" and skip, so the simulator and every experiment run
//! without PJRT. The one caller that *requires* PJRT — the `mesh_matmul`
//! example, whose whole point is executing the lowered GEMM — propagates
//! the error and exits with the message instead. [`ArtifactMeta`] parsing
//! is dependency-free and available in both builds.

use std::path::Path;

use anyhow::Context;

use crate::util::json::Json;

/// Artifact metadata (the `meta.json` contract emitted by `compile.aot`).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// Tile matrix dimension the kernels were lowered for.
    pub tile_dim: usize,
    /// Mesh size the `noc_perf` artifact is specialized to.
    pub dse_mesh_n: usize,
    /// `(name, input_shapes)` per compiled executable.
    pub entries: Vec<(String, Vec<Vec<usize>>)>,
}

impl ArtifactMeta {
    /// Parse `meta.json` from an artifacts directory.
    pub fn load(dir: &Path) -> crate::Result<ArtifactMeta> {
        let text = std::fs::read_to_string(dir.join("meta.json"))
            .with_context(|| format!("reading {}/meta.json (run `make artifacts`)", dir.display()))?;
        let j = Json::parse(&text).context("meta.json is not valid JSON")?;
        let tile_dim = j
            .get("tile_dim")
            .and_then(Json::as_usize)
            .context("meta.json missing tile_dim")?;
        let dse_mesh_n = j
            .get("dse_mesh_n")
            .and_then(Json::as_usize)
            .context("meta.json missing dse_mesh_n")?;
        let mut entries = Vec::new();
        let arts = j
            .get("artifacts")
            .and_then(Json::as_obj)
            .context("meta.json missing artifacts")?;
        for (name, info) in arts {
            let inputs = info
                .get("inputs")
                .and_then(Json::as_arr)
                .context("artifact missing inputs")?
                .iter()
                .map(|shape| {
                    shape
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(Json::as_usize)
                        .collect()
                })
                .collect();
            entries.push((name.clone(), inputs));
        }
        Ok(ArtifactMeta {
            tile_dim,
            dse_mesh_n,
            entries,
        })
    }
}

// Fail fast with instructions instead of a wall of unresolved-import
// errors: the offline snapshot cannot declare the `xla` dependency, so
// enabling `pjrt` requires wiring it in first. Delete this guard after
// adding `xla` to [dependencies].
#[cfg(feature = "pjrt")]
compile_error!(
    "feature `pjrt` requires the `xla` bindings crate: add it to [dependencies] \
     in Cargo.toml (needs a networked build environment) and remove this guard \
     in rust/src/runtime/mod.rs"
);

#[cfg(feature = "pjrt")]
mod backend {
    use std::path::{Path, PathBuf};

    use anyhow::{bail, Context};

    use super::ArtifactMeta;

    /// A compiled model: PJRT executable + its input-shape contract.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        /// Artifact name (`meta.json` key).
        pub name: String,
        /// Input-shape contract from `meta.json`.
        pub input_shapes: Vec<Vec<usize>>,
    }

    impl Executable {
        /// Execute with f32 inputs (shape-checked against the contract).
        /// Returns the flattened f32 outputs of the result tuple.
        pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> crate::Result<Vec<Vec<f32>>> {
            if inputs.len() != self.input_shapes.len() {
                bail!(
                    "{}: expected {} inputs, got {}",
                    self.name,
                    self.input_shapes.len(),
                    inputs.len()
                );
            }
            let mut literals = Vec::with_capacity(inputs.len());
            for (i, (data, shape)) in inputs.iter().enumerate() {
                let want = &self.input_shapes[i];
                if shape != want {
                    bail!(
                        "{}: input {i} shape {shape:?} != artifact contract {want:?}",
                        self.name
                    );
                }
                let numel: usize = shape.iter().product();
                if data.len() != numel {
                    bail!("{}: input {i} has {} elems, shape needs {numel}", self.name, data.len());
                }
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(data).reshape(&dims)?;
                literals.push(lit);
            }
            let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
                .to_literal_sync()?;
            // Lowered with return_tuple=True: unpack the tuple elements.
            let elems = result.to_tuple()?;
            let mut out = Vec::with_capacity(elems.len());
            for e in elems {
                out.push(e.to_vec::<f32>()?);
            }
            Ok(out)
        }
    }

    /// The runtime: a PJRT CPU client plus compiled executables.
    pub struct Runtime {
        client: xla::PjRtClient,
        /// Parsed artifact metadata.
        pub meta: ArtifactMeta,
        dir: PathBuf,
    }

    impl Runtime {
        /// Create a CPU PJRT client and load artifact metadata from `dir`.
        pub fn new(dir: impl AsRef<Path>) -> crate::Result<Runtime> {
            let dir = dir.as_ref().to_path_buf();
            let meta = ArtifactMeta::load(&dir)?;
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Runtime { client, meta, dir })
        }

        /// PJRT platform name (diagnostics).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile one artifact by entry-point name.
        pub fn load(&self, name: &str) -> crate::Result<Executable> {
            let (entry, shapes) = self
                .meta
                .entries
                .iter()
                .find(|(n, _)| n == name)
                .with_context(|| format!("artifact '{name}' not in meta.json"))?;
            let path = self.dir.join(format!("{entry}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            Ok(Executable {
                exe,
                name: name.to_string(),
                input_shapes: shapes.clone(),
            })
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod backend {
    use std::path::Path;

    use anyhow::bail;

    use super::ArtifactMeta;

    const UNAVAILABLE: &str = "PJRT runtime unavailable: built without the `pjrt` \
         feature (the offline crate snapshot has no `xla` bindings)";

    /// Stub with the same API as the PJRT-backed executable; never
    /// constructible because [`Runtime::new`] always errors.
    pub struct Executable {
        /// Artifact name (`meta.json` key).
        pub name: String,
        /// Input-shape contract from `meta.json`.
        pub input_shapes: Vec<Vec<usize>>,
    }

    impl Executable {
        /// Always errors: the stub cannot execute.
        pub fn run_f32(&self, _inputs: &[(&[f32], &[usize])]) -> crate::Result<Vec<Vec<f32>>> {
            bail!("{UNAVAILABLE}");
        }
    }

    /// Stub runtime: carries the metadata type so signatures line up.
    pub struct Runtime {
        /// Parsed artifact metadata (never populated by the stub).
        pub meta: ArtifactMeta,
    }

    impl Runtime {
        /// Always errors with wiring instructions (see the `pjrt` feature).
        pub fn new(dir: impl AsRef<Path>) -> crate::Result<Runtime> {
            let _ = dir.as_ref();
            bail!("{UNAVAILABLE}");
        }

        /// The stub's platform name.
        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        /// Always errors: no artifacts exist in the stub build.
        pub fn load(&self, name: &str) -> crate::Result<Executable> {
            bail!("{UNAVAILABLE} (artifact '{name}')");
        }
    }
}

pub use backend::{Executable, Runtime};

// Tests for the PJRT-backed runtime live in rust/tests/integration_runtime.rs
// because they require `make artifacts` to have produced the HLO files; they
// skip gracefully in both the stub build and an artifact-less pjrt build.
