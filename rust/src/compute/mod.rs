//! Compute bridge: couples the cycle-accurate NoC simulation with real
//! numerics executed through PJRT.
//!
//! The simulator moves *traffic* (flits with sizes and addresses, not bit
//! patterns); this module holds the actual tensor data keyed by address,
//! so an example can (a) simulate the DMA bursts that move a tile's
//! operands, (b) execute the tile GEMM via the AOT artifact once the
//! simulated transfer completes, and (c) verify the final numerics
//! against a host reference — proving the three layers compose.

use std::collections::HashMap;

use anyhow::Context;

use crate::runtime::{Executable, Runtime};

/// Host-side backing store for simulated memory: address → f32 block.
#[derive(Debug, Default)]
pub struct HostMemory {
    blocks: HashMap<u64, Vec<f32>>,
}

impl HostMemory {
    /// An empty store.
    pub fn new() -> Self {
        Self::default()
    }

    /// Write a tensor block at a (simulated) base address.
    pub fn write(&mut self, addr: u64, data: Vec<f32>) {
        self.blocks.insert(addr, data);
    }

    /// Borrow the block at `addr`, if present.
    pub fn read(&self, addr: u64) -> Option<&[f32]> {
        self.blocks.get(&addr).map(Vec::as_slice)
    }

    /// Remove and return the block at `addr`.
    pub fn take(&mut self, addr: u64) -> Option<Vec<f32>> {
        self.blocks.remove(&addr)
    }
}

/// The tile-compute engine: wraps the `tile_matmul` and `cluster_compute`
/// executables with shape bookkeeping.
pub struct TileCompute {
    /// Tile matrix dimension the artifacts were lowered for.
    pub dim: usize,
    matmul: Executable,
    cluster: Executable,
}

impl TileCompute {
    /// Load the compute executables from a PJRT runtime.
    pub fn new(rt: &Runtime) -> crate::Result<TileCompute> {
        Ok(TileCompute {
            dim: rt.meta.tile_dim,
            matmul: rt.load("tile_matmul")?,
            cluster: rt.load("cluster_compute")?,
        })
    }

    /// `x @ w` for one `dim × dim` tile via the Pallas-kernel artifact.
    pub fn matmul(&self, x: &[f32], w: &[f32]) -> crate::Result<Vec<f32>> {
        let d = self.dim;
        let mut out = self
            .matmul
            .run_f32(&[(x, &[d, d]), (w, &[d, d])])
            .context("tile_matmul execution")?;
        Ok(out.remove(0))
    }

    /// Full tile workload: `relu(x @ w + b)`.
    pub fn cluster_compute(&self, x: &[f32], w: &[f32], b: &[f32]) -> crate::Result<Vec<f32>> {
        let d = self.dim;
        let mut out = self
            .cluster
            .run_f32(&[(x, &[d, d]), (w, &[d, d]), (b, &[d])])
            .context("cluster_compute execution")?;
        Ok(out.remove(0))
    }
}

/// Host reference matmul for end-to-end verification.
pub fn host_matmul(x: &[f32], w: &[f32], d: usize) -> Vec<f32> {
    let mut out = vec![0f32; d * d];
    for i in 0..d {
        for k in 0..d {
            let xv = x[i * d + k];
            if xv == 0.0 {
                continue;
            }
            for j in 0..d {
                out[i * d + j] += xv * w[k * d + j];
            }
        }
    }
    out
}

/// Element-wise accumulate: `acc += x`.
pub fn accumulate(acc: &mut [f32], x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    for (a, v) in acc.iter_mut().zip(x) {
        *a += v;
    }
}

/// Max absolute difference (verification helper).
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_matmul_identity() {
        let d = 4;
        let x: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let mut eye = vec![0f32; 16];
        for i in 0..d {
            eye[i * d + i] = 1.0;
        }
        assert_eq!(host_matmul(&x, &eye, d), x);
    }

    #[test]
    fn accumulate_adds() {
        let mut acc = vec![1.0, 2.0];
        accumulate(&mut acc, &[0.5, 0.5]);
        assert_eq!(acc, vec![1.5, 2.5]);
    }

    #[test]
    fn host_memory_roundtrip() {
        let mut m = HostMemory::new();
        m.write(0x1000, vec![1.0, 2.0]);
        assert_eq!(m.read(0x1000), Some(&[1.0, 2.0][..]));
        assert_eq!(m.take(0x1000), Some(vec![1.0, 2.0]));
        assert_eq!(m.read(0x1000), None);
    }

    #[test]
    fn max_abs_diff_works() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.0, 2.5]), 0.5);
    }
}
