//! `repro` — the FlooNoC reproduction CLI (leader entrypoint).
//!
//! See `repro help` or [`floonoc::cli::HELP`]. Top-level usage:
//!
//! ```text
//! repro info
//! repro reproduce <tab1|tab2|fig5a|fig5b|fig6a|fig6b|latency|bandwidth|
//!                  wires|scaling|all> [--bidir] [--levels a,b,c] [--jobs n]
//! repro simulate  [--config f.json] [--mesh n] [--txns n] [--wide-only]
//!                 [--topology mesh|torus|ring]
//!                 [--routing deterministic|adaptive] [--vcs n]
//!                 [--sim-mode gated|dense|event] [--shards n]
//!                 [--no-verify] [--check-invariants]
//! repro verify    [--config f.json] [--mesh n] [--topology mesh|torus|ring]
//!                 [--routing deterministic|adaptive] [--vcs n] [--wide-only]
//!                 [--sim-mode gated|dense|event] [--json] [--deep]
//! repro sweep     <rob|buffers|burst|mesh|topology|vcs|output-reg> [--jobs n]
//! repro scale_topology [--mesh n] [--jobs n]
//! repro dse       [--mesh n] [--artifacts dir] [--jobs n]
//! repro bench     [--out path] [--quick] [--profile]
//! ```
//!
//! `--jobs n` controls the parallel sweep runner: every sweep/ablation
//! point is an independent simulation fanned out over `n` worker threads
//! (`0` or omitted = all cores, `1` = serial). Results are deterministic
//! and identical for any worker count.

use anyhow::{bail, Context};

use floonoc::cli::{Args, HELP};
use floonoc::cluster::{TileSpec, TileTraffic, TiledWorkload};
use floonoc::config;
use floonoc::coordinator as exp;
use floonoc::dse::ParallelRunner;
use floonoc::flit::{NocLayout, NodeId};
use floonoc::noc::{LinkMode, NocConfig, NocSystem};
use floonoc::phys::{AreaModel, BandwidthModel, ChannelGeometry, TimingModel};
use floonoc::report;
use floonoc::traffic::{GenCfg, Pattern};
use floonoc::util::json::{pretty, Json};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        println!("{HELP}");
        return;
    }
    let args = match Args::parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> anyhow::Result<()> {
    match args.command.as_str() {
        "help" | "--help" | "-h" => println!("{HELP}"),
        "info" => info(),
        "reproduce" => reproduce(args)?,
        "simulate" => simulate(args)?,
        "verify" => verify_cmd(args)?,
        "sweep" => sweep(args)?,
        "scale_topology" => scale_topology(args)?,
        "dse" => dse(args)?,
        "bench" => bench(args)?,
        other => bail!("unknown command '{other}' (try 'repro help')"),
    }
    Ok(())
}

fn info() {
    let layout = NocLayout::default();
    let bw = BandwidthModel::default();
    let timing = TimingModel::default();
    let geom = ChannelGeometry::default();
    let area = AreaModel::default().tile(&TileSpec::default(), &layout, 2);
    println!("FlooNoC reproduction — system summary\n");
    println!("{}", report::table_one(&layout));
    println!(
        "clock: {:.2} GHz at {:.0} FO4 | wide link {:.0} Gbps, duplex {:.2} Tbps",
        1.23,
        timing.fo4_depth(1.23),
        bw.wide_link_gbps(),
        bw.wide_duplex_tbps()
    );
    println!(
        "7x7 mesh boundary aggregate: {:.1} TB/s",
        bw.mesh_boundary_tbs(7)
    );
    println!(
        "routing channel: {} wires, {:.0} um slice, {} buffer-island sets",
        geom.duplex_wires(&layout),
        geom.channel_width_um(&layout),
        geom.island_sets()
    );
    println!(
        "tile area: {:.2} MGE, NoC {:.0} kGE ({:.1} %)",
        area.tile_total() / 1e6,
        area.noc_total() / 1e3,
        area.noc_fraction() * 100.0
    );
}

/// The sweep runner selected by `--jobs` (0/absent = all cores).
fn runner_from(args: &Args) -> anyhow::Result<ParallelRunner> {
    Ok(ParallelRunner::new(args.opt_u64("jobs", 0)? as usize))
}

fn parse_levels_u32(args: &Args, default: &[u32]) -> anyhow::Result<Vec<u32>> {
    match args.opt("levels") {
        Some(s) => s
            .split(',')
            .map(|v| v.parse().with_context(|| format!("bad level '{v}'")))
            .collect(),
        None => Ok(default.to_vec()),
    }
}

fn reproduce(args: &Args) -> anyhow::Result<()> {
    let what = args.pos(0).unwrap_or("all");
    let bidir = args.flag("bidir");
    let layout = NocLayout::default();
    match what {
        "tab1" => print!("{}", report::table_one(&layout)),
        "tab2" => print!("{}", report::table_two()),
        "fig5a" => {
            let levels = parse_levels_u32(args, &[0, 1, 2, 4, 8])?;
            let runner = runner_from(args)?;
            for mode in [LinkMode::NarrowWide, LinkMode::WideOnly] {
                let rows = exp::fig5a_with(mode, bidir, &levels, &runner);
                print!("{}", report::fig5a_table(&rows));
            }
        }
        "fig5b" => {
            let levels = parse_levels_u32(args, &[0, 2, 4, 8, 16, 32])?;
            let runner = runner_from(args)?;
            for mode in [LinkMode::NarrowWide, LinkMode::WideOnly] {
                let rows = exp::fig5b_with(mode, bidir, &levels, &runner);
                print!("{}", report::fig5b_table(&rows));
            }
        }
        "fig6a" => {
            let area = AreaModel::default().tile(&TileSpec::default(), &layout, 2);
            println!("Fig. 6a: area breakdown");
            println!("{}", pretty(&area.to_json()));
        }
        "fig6b" => {
            let (p, pjb) = exp::fig6b_power();
            println!("Fig. 6b: power breakdown during a single 1 kB DMA transfer");
            println!("{}", pretty(&p.to_json()));
            println!("energy efficiency: {pjb:.2} pJ/B/hop (paper: 0.19)");
        }
        "latency" => {
            let l = exp::zero_load_latency(LinkMode::NarrowWide);
            println!("zero-load tile-to-adjacent-tile round trip: {l} cycles (paper: 18)");
        }
        "bandwidth" => {
            let bw = BandwidthModel::default();
            let (util, gbps) = exp::peak_bandwidth(1.23);
            println!(
                "wide link peak: {:.0} Gbps theoretical, {gbps:.0} Gbps measured \
                 (utilization {:.1} %)",
                bw.wide_link_gbps(),
                util * 100.0
            );
            println!("duplex: {:.2} Tbps", bw.wide_duplex_tbps());
            println!(
                "7x7 mesh boundary aggregate: {:.1} TB/s (paper: 4.4)",
                bw.mesh_boundary_tbs(7)
            );
        }
        "wires" => {
            let g = ChannelGeometry::default();
            println!(
                "duplex channel: {} wires (paper: ~1600), slice {:.0} um \
                 (paper: 120), {} buffer-island sets (paper: 3)",
                g.duplex_wires(&layout),
                g.channel_width_um(&layout),
                g.island_sets()
            );
        }
        "scaling" => {
            let m = floonoc::baseline::AxiMatrixModel::default();
            println!("AXI4-matrix baseline scalability (per-stage ID tracker):");
            for row in m.sweep(7) {
                println!("{}", row.to_json());
            }
            println!(
                "FlooNoC NI reorder-table entries (hop-independent): {}",
                m.floonoc_ni_entries()
            );
        }
        "all" => {
            for e in [
                "tab1", "tab2", "latency", "bandwidth", "wires", "fig6a", "fig6b",
                "scaling", "fig5a", "fig5b",
            ] {
                println!("==================== {e} ====================");
                let mut sub = args.clone();
                sub.positional = vec![e.to_string()];
                reproduce(&sub)?;
            }
        }
        other => bail!("unknown experiment '{other}'"),
    }
    Ok(())
}

/// The `NocConfig` described by the shared fabric options: `--config`
/// (JSON file, wins over everything else) or `--mesh`/`--topology`/
/// `--wide-only`, plus a `--vcs` override. Used by both `simulate` and
/// `verify` so "verify what you are about to simulate" is the same
/// config object, flag for flag.
fn build_cfg(args: &Args) -> anyhow::Result<NocConfig> {
    let mut cfg = match args.opt("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading config '{path}'"))?;
            config::noc_config_from_json(&text)?
        }
        None => {
            let n = args.opt_u64("mesh", 4)? as u8;
            let kind = match args.opt("topology") {
                Some(t) => config::topology_from_str(t)?,
                None => floonoc::topology::TopologyKind::Mesh,
            };
            let mut c = match kind {
                floonoc::topology::TopologyKind::Ring => {
                    // `--mesh n` keeps its "n*n tiles" meaning across fabrics.
                    let tiles = n as u64 * n as u64;
                    anyhow::ensure!(tiles <= u8::MAX as u64, "ring too large");
                    NocConfig::ring(tiles as u8)
                }
                k => NocConfig::fabric(k, n, n),
            };
            if args.flag("wide-only") {
                c = c.wide_only();
            }
            c
        }
    };
    // `--routing` before `--vcs`: `adaptive()` raises the VC count to
    // escape + 1 adaptive lane, and an explicit `--vcs` then overrides
    // it (possibly back down into FV107 territory, which the verifier
    // reports instead of the CLI silently correcting).
    if let Some(r) = args.opt("routing") {
        cfg = match r {
            "deterministic" => cfg,
            "adaptive" => cfg.adaptive(),
            other => bail!("--routing expects deterministic|adaptive, got '{other}'"),
        };
    }
    if args.opt("vcs").is_some() {
        let vcs = args.opt_u64("vcs", 0)? as usize;
        anyhow::ensure!(
            (1..=floonoc::router::MAX_VCS).contains(&vcs),
            "--vcs expects 1..={}, got {vcs}",
            floonoc::router::MAX_VCS
        );
        cfg = cfg.with_vcs(vcs);
    }
    if let Some(mode) = args.opt("sim-mode") {
        cfg = cfg.with_sim_mode(match mode {
            "gated" => floonoc::sim::SimMode::Gated,
            "dense" => floonoc::sim::SimMode::Dense,
            "event" => floonoc::sim::SimMode::Event,
            other => bail!("--sim-mode expects gated|dense|event, got '{other}'"),
        });
    }
    if args.opt("shards").is_some() {
        let shards = args.opt_u64("shards", 1)? as usize;
        anyhow::ensure!(shards >= 1, "--shards expects an integer >= 1");
        cfg = cfg.with_shards(shards);
    }
    Ok(cfg)
}

/// `repro verify`: the static analyzer as a standalone command — print
/// the full [`floonoc::verify`] report for a config without simulating,
/// exit non-zero if it contains error-severity findings. `--json` emits
/// the machine-readable report (schema `floonoc-verify/1`); `--deep`
/// additionally runs one activity-gated warm-up epoch with the
/// "occupied ⇒ active" invariant scans forced on (release builds
/// included), catching gating-soundness bugs the static passes cannot
/// see.
fn verify_cmd(args: &Args) -> anyhow::Result<()> {
    let cfg = build_cfg(args)?;
    let report = floonoc::verify::preflight(&cfg);
    if args.flag("json") {
        println!("{}", pretty(&report.to_json()));
    } else {
        println!("config: {}", config::noc_config_to_json(&cfg));
        println!("{report}");
    }
    if report.has_errors() {
        bail!(
            "verification failed: {} error(s) (see docs/verification.md)",
            report.error_count()
        );
    }
    if args.flag("deep") {
        let sys = NocSystem::new(cfg.no_verify().with_invariant_checks());
        let tiles = sys.topo.num_tiles;
        let profiles: Vec<TileTraffic> = (0..tiles)
            .map(|i| TileTraffic {
                core: Some(GenCfg {
                    pattern: Pattern::UniformTiles,
                    seed: 0xDEE9 + i as u64,
                    ..GenCfg::narrow_probe(NodeId(0), 8)
                }),
                dma: Some(GenCfg {
                    pattern: Pattern::UniformTiles,
                    seed: 0xDEE9 + i as u64,
                    ..GenCfg::dma_burst(NodeId(0), 2, false)
                }),
            })
            .collect();
        let mut w = TiledWorkload::new(sys, profiles);
        let drained = w.run_to_completion(5_000_000);
        anyhow::ensure!(drained, "--deep warm-up epoch did not drain");
        anyhow::ensure!(w.protocol_ok(), "--deep warm-up epoch: AXI protocol violations");
        if !args.flag("json") {
            println!(
                "deep check: warm-up epoch drained in {} cycles with gating invariants enforced",
                w.sys.now
            );
        }
    }
    Ok(())
}

fn simulate(args: &Args) -> anyhow::Result<()> {
    let mut cfg = build_cfg(args)?;
    if args.flag("no-verify") {
        cfg = cfg.no_verify();
    }
    if args.flag("check-invariants") {
        cfg = cfg.with_invariant_checks();
    }
    // Preflight here (instead of inside `NocSystem::new`) so a rejected
    // config is a readable CLI error, not a panic with a backtrace.
    if cfg.verify {
        let report = floonoc::verify::preflight(&cfg);
        if report.has_errors() {
            eprintln!("{report}");
            bail!(
                "config failed static verification ({} error(s)); \
                 run 'repro verify' for details or pass --no-verify to simulate anyway",
                report.error_count()
            );
        }
    }
    let txns = args.opt_u64("txns", 64)?;
    println!("config: {}", config::noc_config_to_json(&cfg));
    let sys = NocSystem::new(cfg);
    let tiles = sys.topo.num_tiles;
    // Uniform-random wide wormhole bursts are safe on every fabric:
    // torus/ring configs carry dateline virtual channels by default
    // (docs/deadlock.md), so the wrap-saturation workload no longer
    // needs the single-hop DMA restriction it shipped with pre-VC.
    let profiles: Vec<TileTraffic> = (0..tiles)
        .map(|i| TileTraffic {
            core: Some(GenCfg {
                pattern: Pattern::UniformTiles,
                ..GenCfg::narrow_probe(NodeId(0), txns)
            }),
            dma: Some(GenCfg {
                pattern: Pattern::UniformTiles,
                seed: 0xD0A + i as u64,
                ..GenCfg::dma_burst(NodeId(0), (txns / 4).max(1), false)
            }),
        })
        .collect();
    let mut w = TiledWorkload::new(sys, profiles);
    let ok = w.run_to_completion(50_000_000);
    if !ok {
        bail!("workload did not drain");
    }
    if !w.protocol_ok() {
        bail!("AXI protocol violations detected");
    }
    let mut lat = floonoc::stats::LatencyRecorder::new();
    let mut dma_lat = floonoc::stats::LatencyRecorder::new();
    for t in &mut w.tiles {
        if let Some(g) = t.core_gen.as_mut() {
            lat.record(g.latencies.mean() as u64);
        }
        if let Some(g) = t.dma_gen.as_mut() {
            dma_lat.record(g.latencies.mean() as u64);
        }
    }
    let j = Json::obj(vec![
        ("cycles", Json::Num(w.sys.now as f64)),
        ("narrow_mean_latency", Json::Num(lat.mean())),
        ("wide_mean_latency", Json::Num(dma_lat.mean())),
        (
            "req_net_flit_hops",
            Json::Num(w.sys.router_flit_hops(0) as f64),
        ),
        (
            "rsp_net_flit_hops",
            Json::Num(w.sys.router_flit_hops(1) as f64),
        ),
    ]);
    println!("{}", pretty(&j));
    Ok(())
}

fn sweep(args: &Args) -> anyhow::Result<()> {
    let what = args.pos(0).unwrap_or("rob");
    let runner = runner_from(args)?;
    let table = match what {
        "rob" => report::ablation_table(
            "wide-ROB size vs 16x1kB-read makespan (cycles)",
            &exp::ablate_rob_size_with(&[16, 32, 64, 128, 256], &runner),
        ),
        "buffers" => report::ablation_table(
            "router input-buffer depth vs narrow latency under interference",
            &exp::ablate_buffer_depth_with(&[1, 2, 4, 8], &runner),
        ),
        "burst" => report::ablation_table(
            "burst length vs effective wide utilization",
            &exp::ablate_burst_len_with(&[0, 1, 3, 7, 15, 31], &runner),
        ),
        "mesh" => report::ablation_table(
            "mesh size vs delivered wide bytes/cycle (neighbor ring)",
            &exp::scale_mesh_with(&[2, 3, 4, 6], &runner),
        ),
        "vcs" => report::ablation_table(
            "VC count vs 4x4-torus tornado makespan (vcs > 2 => adaptive routing)",
            &exp::ablate_vcs_with(&[2, 3, 4], &runner),
        ),
        "output-reg" => report::ablation_table(
            "router output register (0/1) vs zero-load latency",
            &exp::ablate_output_reg(),
        ),
        "topology" => return scale_topology(args),
        other => bail!("unknown sweep '{other}'"),
    };
    print!("{table}");
    Ok(())
}

/// `repro scale_topology`: the cross-fabric comparison at one tile count.
fn scale_topology(args: &Args) -> anyhow::Result<()> {
    let n = args.opt_u64("mesh", 4)? as u8;
    let runner = runner_from(args)?;
    let rows = exp::scale_topology_with(n, &runner);
    println!("topology comparison at {} tiles (uniform-random narrow reads)", rows[0].tiles);
    if rows.len() == 2 {
        println!("(ring row skipped: {} tiles exceed the 255-node ring bound)", rows[0].tiles);
    }
    println!(
        "{:<8} {:>12} {:>14} {:>16} {:>10}",
        "fabric", "mean hops", "measured hops", "txns/kcycle", "cycles"
    );
    for r in &rows {
        println!(
            "{:<8} {:>12.3} {:>14.3} {:>16.2} {:>10}",
            r.kind.name(),
            r.mean_hops,
            r.measured_hops,
            r.txns_per_kcycle,
            r.cycles
        );
    }
    Ok(())
}

fn dse(args: &Args) -> anyhow::Result<()> {
    let n = args.opt_u64("mesh", 4)? as u8;
    let dir = args.opt("artifacts").unwrap_or("artifacts");
    floonoc::dse::run_dse(n, dir, &runner_from(args)?)
}

/// `repro bench`: the end-to-end performance scenarios of
/// `cargo bench --bench bench_e2e`, runnable from the installed binary,
/// writing the `BENCH_e2e.json` trajectory file. With `--profile` it
/// instead runs the per-phase wall-time profiler over the saturated
/// scenarios and writes the `floonoc-profile/1` report
/// (`BENCH_profile.json` unless `--out` overrides).
fn bench(args: &Args) -> anyhow::Result<()> {
    if args.flag("profile") {
        let profiles = floonoc::perf::profile::run_profile(args.flag("quick"));
        let path = match args.opt("out") {
            Some(p) => std::path::PathBuf::from(p),
            None => floonoc::perf::profile::default_profile_path(),
        };
        return floonoc::perf::profile::write_profile(&profiles, &path);
    }
    let report = floonoc::perf::run_e2e(args.flag("quick"));
    let path = match args.opt("out") {
        Some(p) => std::path::PathBuf::from(p),
        None => floonoc::perf::default_report_path(),
    };
    floonoc::perf::write_report(&report, &path)
}
