//! FlooNoC routers (§III-C).
//!
//! Design points taken from the paper, each visible in the code:
//!
//! * **no internal pipelining** — a router is input FIFOs + route
//!   computation + round-robin switch allocation, nothing else;
//!   single-cycle latency because forwarding happens the same cycle a
//!   flit sits at an input-buffer head;
//! * **virtual channels only where the fabric needs them** — the paper's
//!   mesh runs VC-free (and our 1-VC configuration is byte-identical to
//!   that router); wrap fabrics (torus/ring) configure 2 VCs and the
//!   dateline rule ([`routing::dateline_vc`]) for deadlock freedom —
//!   per-input-per-VC buffers, per-(output, VC) wormhole locks, one
//!   traversal per output per cycle (see `docs/deadlock.md`);
//! * **multilink** — one independent router instance per physical link
//!   (narrow_req / narrow_rsp / wide); the three networks never share
//!   resources;
//! * **wormhole routing with valid-ready flow control** — an output port
//!   locks to the winning input until the flit marked `last` passes;
//! * **configurable radix** — any number of ports (the paper's tile uses
//!   5×5: local + 4 cardinal);
//! * **optional output register** ("elastic buffer") — trades one extra
//!   cycle for relaxed link timing; the paper's physical implementation
//!   uses this two-cycle variant, and so does our calibrated default;
//! * **static routing** — a pluggable [`RoutingAlgorithm`] (XY for
//!   meshes, wrap-minimizing dimension-ordered for tori, shortest
//!   direction for rings) generates per-router destination-indexed
//!   tables; the hot loop only ever does table lookups.

pub mod router;
pub mod routing;
pub mod arbiter;

pub use arbiter::RoundRobin;
pub use router::{
    LinkPool, Router, RouterActivity, RouterCfg, MAX_VCS, PORT_E, PORT_LOCAL, PORT_MEM, PORT_N,
    PORT_S, PORT_W,
};
pub use routing::{
    dateline_vc, port_dim, ring_route, torus_route, xy_route, RouteTable, RoutingAlgorithm,
    RoutingKind,
};
