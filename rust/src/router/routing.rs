//! Static routing: dimension-ordered XY and table-based.
//!
//! Both are materialized per router as a destination-indexed table (what
//! "table-based routing using the destination's ID" means in the paper);
//! [`xy_route`] is the generator rule for XY tables and is also exposed for
//! direct use/testing.

use crate::flit::{Coord, NodeId};

use super::router::{PORT_E, PORT_LOCAL, PORT_N, PORT_S, PORT_W};

/// Dimension-ordered XY step from `me` towards `dst`: move in X first,
/// then Y, then deliver locally. Returns the output port.
pub fn xy_route(me: Coord, dst: Coord) -> usize {
    if dst.x > me.x {
        PORT_E
    } else if dst.x < me.x {
        PORT_W
    } else if dst.y > me.y {
        PORT_N
    } else if dst.y < me.y {
        PORT_S
    } else {
        PORT_LOCAL
    }
}

/// Per-router route table: output port for every destination node.
#[derive(Debug, Clone)]
pub struct RouteTable {
    ports: Vec<u8>,
}

impl RouteTable {
    pub fn new(ports: Vec<u8>) -> Self {
        RouteTable { ports }
    }

    /// Output port for `dst`. Panics on unknown destinations — a routing
    /// table must be total over the deployed nodes.
    #[inline]
    pub fn lookup(&self, dst: NodeId) -> usize {
        self.ports[dst.0 as usize] as usize
    }

    pub fn len(&self) -> usize {
        self.ports.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ports.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xy_goes_x_first() {
        let me = Coord::new(1, 1);
        assert_eq!(xy_route(me, Coord::new(3, 0)), PORT_E);
        assert_eq!(xy_route(me, Coord::new(0, 3)), PORT_W);
        // Same column: move in Y.
        assert_eq!(xy_route(me, Coord::new(1, 3)), PORT_N);
        assert_eq!(xy_route(me, Coord::new(1, 0)), PORT_S);
        // Arrived.
        assert_eq!(xy_route(me, me), PORT_LOCAL);
    }

    #[test]
    fn xy_path_is_monotone() {
        // Walk the rule from (0,0) to (3,2): first 3 E steps, then 2 N.
        let dst = Coord::new(3, 2);
        let mut cur = Coord::new(0, 0);
        let mut ports = Vec::new();
        loop {
            let p = xy_route(cur, dst);
            if p == PORT_LOCAL {
                break;
            }
            ports.push(p);
            match p {
                PORT_E => cur.x += 1,
                PORT_W => cur.x -= 1,
                PORT_N => cur.y += 1,
                PORT_S => cur.y -= 1,
                _ => unreachable!(),
            }
        }
        assert_eq!(ports, vec![PORT_E, PORT_E, PORT_E, PORT_N, PORT_N]);
    }

    #[test]
    fn table_lookup() {
        let t = RouteTable::new(vec![0, 2, 2, 4]);
        assert_eq!(t.lookup(NodeId(0)), 0);
        assert_eq!(t.lookup(NodeId(2)), 2);
        assert_eq!(t.len(), 4);
    }
}
