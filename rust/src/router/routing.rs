//! Static routing: per-topology generator rules and table-based lookup.
//!
//! Every fabric is routed from a destination-indexed table materialized
//! per router (what "table-based routing using the destination's ID"
//! means in the paper); the *generator rules* that fill those tables are
//! the [`RoutingAlgorithm`] variants:
//!
//! * [`xy_route`] — dimension-ordered XY for meshes (X first, then Y;
//!   provably deadlock-free on a mesh);
//! * [`torus_route`] — dimension-ordered XY with **wraparound-hop
//!   minimization** for tori: within each dimension the direction with
//!   the fewer ring hops is taken, crossing the wraparound link when that
//!   is strictly shorter (ties break towards east/north);
//! * [`ring_route`] — shortest direction around a 1-D ring.
//!
//! Torus/ring wraparound introduces cyclic channel dependencies that XY
//! on a mesh does not have; deadlock freedom there comes from **dateline
//! virtual channels**: each [`RouteTable`] carries the router's dateline
//! mask (which output ports cross a wraparound link) and [`dateline_vc`]
//! switches wrap-crossing flits from VC 0 to VC 1, breaking every
//! channel-dependency cycle (proof sketch in `docs/deadlock.md`).
//!
//! The **adaptive** variants ([`RoutingAlgorithm::AdaptiveXy`],
//! [`RoutingAlgorithm::AdaptiveTorus`], [`RoutingAlgorithm::AdaptiveRing`])
//! keep the deterministic rule above as a Duato-style *escape* baseline
//! and additionally publish a per-destination **candidate set**
//! ([`RoutingAlgorithm::candidates`]): every output port that strictly
//! decreases the distance to the destination (minimal adaptivity; on
//! even rings a diametrically-opposite destination yields *both*
//! directions). The router picks among candidates per cycle by local
//! congestion on the adaptive lanes and falls back to the escape lanes
//! — which run exactly the deterministic step — whenever no adaptive
//! lane is admissible ([`super::router::Router`],
//! "Adaptive routing on escape VCs" in `docs/deadlock.md`).

use crate::flit::{Coord, NodeId};

use super::router::{PORT_E, PORT_LOCAL, PORT_N, PORT_S, PORT_W};

/// Dimension-ordered XY step from `me` towards `dst`: move in X first,
/// then Y, then deliver locally. Returns the output port.
pub fn xy_route(me: Coord, dst: Coord) -> usize {
    if dst.x > me.x {
        PORT_E
    } else if dst.x < me.x {
        PORT_W
    } else if dst.y > me.y {
        PORT_N
    } else if dst.y < me.y {
        PORT_S
    } else {
        PORT_LOCAL
    }
}

/// Shortest-direction step along one ring dimension of `n` positions:
/// `Some(true)` = move in the increasing direction (E/N), `Some(false)` =
/// decreasing (W/S), `None` = already there. The increasing direction
/// wins ties (even `n`, diametrically opposite positions).
fn ring_step(me: u8, dst: u8, n: u8) -> Option<bool> {
    if me == dst {
        return None;
    }
    let fwd = (dst as u16 + n as u16 - me as u16) % n as u16; // hops going +
    Some(fwd <= (n as u16 - fwd))
}

/// Hop count along one ring dimension (minimum of the two directions).
fn ring_dist(a: u8, b: u8, n: u8) -> u32 {
    let fwd = (b as u16 + n as u16 - a as u16) % n as u16;
    fwd.min(n as u16 - fwd) as u32
}

/// The *productive* directions along one ring dimension: `(increasing,
/// decreasing)` flags, each true iff one hop that way strictly
/// decreases the ring distance to `dst`. Both are true exactly at the
/// diametrically-opposite tie on an even ring (either arc is minimal);
/// both are false on arrival.
fn ring_productive(me: u8, dst: u8, n: u8) -> (bool, bool) {
    if me == dst {
        return (false, false);
    }
    let fwd = (dst as u16 + n as u16 - me as u16) % n as u16;
    (fwd <= n as u16 - fwd, n as u16 - fwd <= fwd)
}

/// Shortest-direction step around a 1-D ring of `n` nodes laid out along
/// X: east if the clockwise (+x, wrapping) distance is at most the
/// counter-clockwise one, west otherwise, local on arrival.
pub fn ring_route(me: Coord, dst: Coord, n: u8) -> usize {
    debug_assert_eq!(me.y, dst.y, "ring is one-dimensional");
    match ring_step(me.x, dst.x, n) {
        None => PORT_LOCAL,
        Some(true) => PORT_E,
        Some(false) => PORT_W,
    }
}

/// Dimension-ordered (X then Y) torus step with wraparound-hop
/// minimization: within the current dimension, take the direction with
/// the fewer ring hops, using the wraparound link iff it is strictly
/// shorter (ties break towards E/N, i.e. the increasing direction).
pub fn torus_route(me: Coord, dst: Coord, width: u8, height: u8) -> usize {
    if let Some(east) = ring_step(me.x, dst.x, width) {
        return if east { PORT_E } else { PORT_W };
    }
    match ring_step(me.y, dst.y, height) {
        Some(true) => PORT_N,
        Some(false) => PORT_S,
        None => PORT_LOCAL,
    }
}

/// A route-table generator rule: one step of the deterministic,
/// dimension-ordered route from a router towards a destination router.
///
/// The variants carry the fabric dimensions they need, so a rule is a
/// self-contained function of `(me, dst)`; `Topology::route_table`
/// materializes it into a per-router [`RouteTable`] and the hot loop
/// never sees anything but table lookups.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingAlgorithm {
    /// Dimension-ordered XY on a mesh (deadlock-free, minimal).
    Xy,
    /// Dimension-ordered XY on a torus with wraparound-hop minimization
    /// per dimension (minimal; cyclic dependencies documented above).
    TorusXy {
        /// Ring length of the X dimension.
        width: u8,
        /// Ring length of the Y dimension.
        height: u8,
    },
    /// Shortest direction around a 1-D ring laid out along X.
    RingShortest {
        /// Number of nodes on the ring.
        nodes: u8,
    },
    /// Minimal-adaptive mesh routing over a Duato-style escape lane:
    /// candidate set = every productive cardinal direction, escape
    /// baseline = [`RoutingAlgorithm::Xy`].
    AdaptiveXy,
    /// Minimal-adaptive torus routing; escape baseline =
    /// [`RoutingAlgorithm::TorusXy`] on the dateline escape lanes.
    AdaptiveTorus {
        /// Ring length of the X dimension.
        width: u8,
        /// Ring length of the Y dimension.
        height: u8,
    },
    /// Minimal-adaptive ring routing; escape baseline =
    /// [`RoutingAlgorithm::RingShortest`] on the dateline escape lanes.
    AdaptiveRing {
        /// Number of nodes on the ring.
        nodes: u8,
    },
}

impl RoutingAlgorithm {
    /// One routing step: the output port a flit at router `me` takes
    /// towards destination router `dst` ([`PORT_LOCAL`] on arrival).
    ///
    /// For the adaptive variants this is the **escape** step — the
    /// deterministic, dimension-ordered baseline the escape lanes run.
    pub fn step(&self, me: Coord, dst: Coord) -> usize {
        match *self {
            RoutingAlgorithm::Xy | RoutingAlgorithm::AdaptiveXy => xy_route(me, dst),
            RoutingAlgorithm::TorusXy { width, height }
            | RoutingAlgorithm::AdaptiveTorus { width, height } => {
                torus_route(me, dst, width, height)
            }
            RoutingAlgorithm::RingShortest { nodes }
            | RoutingAlgorithm::AdaptiveRing { nodes } => ring_route(me, dst, nodes),
        }
    }

    /// Analytic shortest-path router-to-router hop count under this rule
    /// (the routes generated by [`Self::step`] are minimal, so walking a
    /// table takes exactly this many hops; adaptive candidates are
    /// strictly distance-decreasing, so adaptive paths are equally
    /// minimal whatever the per-cycle choices).
    pub fn distance(&self, a: Coord, b: Coord) -> u32 {
        match *self {
            RoutingAlgorithm::Xy | RoutingAlgorithm::AdaptiveXy => {
                (a.x.abs_diff(b.x) + a.y.abs_diff(b.y)) as u32
            }
            RoutingAlgorithm::TorusXy { width, height }
            | RoutingAlgorithm::AdaptiveTorus { width, height } => {
                ring_dist(a.x, b.x, width) + ring_dist(a.y, b.y, height)
            }
            RoutingAlgorithm::RingShortest { nodes }
            | RoutingAlgorithm::AdaptiveRing { nodes } => ring_dist(a.x, b.x, nodes),
        }
    }

    /// Whether this rule publishes adaptive candidate sets.
    pub fn is_adaptive(&self) -> bool {
        matches!(
            self,
            RoutingAlgorithm::AdaptiveXy
                | RoutingAlgorithm::AdaptiveTorus { .. }
                | RoutingAlgorithm::AdaptiveRing { .. }
        )
    }

    /// The **candidate set** for a flit at `me` towards `dst`: a bitmask
    /// over output ports, every one of which strictly decreases
    /// [`Self::distance`] (minimal adaptivity). Always non-empty for
    /// `me != dst`, and always a superset of `1 << self.step(me, dst)`
    /// — the escape route is itself a candidate, so the adaptive router
    /// can fall back to it without ever taking a non-minimal hop.
    ///
    /// Deterministic variants return exactly their single step. Adaptive
    /// variants return every productive cardinal direction; on an even
    /// ring dimension a diametrically-opposite destination is
    /// equidistant both ways, so **both** directions are included (each
    /// strictly decreases the distance). `me == dst` returns
    /// `1 << PORT_LOCAL` (the caller substitutes the real attach port
    /// for memory-controller nodes).
    pub fn candidates(&self, me: Coord, dst: Coord) -> u8 {
        if me == dst {
            return 1 << PORT_LOCAL;
        }
        match *self {
            RoutingAlgorithm::Xy
            | RoutingAlgorithm::TorusXy { .. }
            | RoutingAlgorithm::RingShortest { .. } => 1 << self.step(me, dst),
            RoutingAlgorithm::AdaptiveXy => {
                let mut mask = 0u8;
                if dst.x > me.x {
                    mask |= 1 << PORT_E;
                } else if dst.x < me.x {
                    mask |= 1 << PORT_W;
                }
                if dst.y > me.y {
                    mask |= 1 << PORT_N;
                } else if dst.y < me.y {
                    mask |= 1 << PORT_S;
                }
                mask
            }
            RoutingAlgorithm::AdaptiveTorus { width, height } => {
                let mut mask = 0u8;
                let (e, w) = ring_productive(me.x, dst.x, width);
                if e {
                    mask |= 1 << PORT_E;
                }
                if w {
                    mask |= 1 << PORT_W;
                }
                let (n, s) = ring_productive(me.y, dst.y, height);
                if n {
                    mask |= 1 << PORT_N;
                }
                if s {
                    mask |= 1 << PORT_S;
                }
                mask
            }
            RoutingAlgorithm::AdaptiveRing { nodes } => {
                let mut mask = 0u8;
                let (e, w) = ring_productive(me.x, dst.x, nodes);
                if e {
                    mask |= 1 << PORT_E;
                }
                if w {
                    mask |= 1 << PORT_W;
                }
                mask
            }
        }
    }
}

/// The routing discipline a fabric is configured with — the
/// `NocConfig` knob the network builder turns into per-router
/// [`RouteTable`]s (deterministic: escape tables only; adaptive:
/// candidate sets over dateline escape lanes).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoutingKind {
    /// The deterministic dimension-ordered/dateline baseline.
    #[default]
    Deterministic,
    /// Minimal-adaptive candidates over Duato escape lanes.
    Adaptive,
}

/// Routing dimension a cardinal port moves a flit in: `Some(0)` for X
/// (east/west), `Some(1)` for Y (north/south), `None` for every
/// non-cardinal port (local, memory attach) — i.e. injection/ejection.
#[inline]
pub fn port_dim(port: usize) -> Option<u8> {
    match port {
        PORT_E | PORT_W => Some(0),
        PORT_N | PORT_S => Some(1),
        _ => None,
    }
}

/// The dateline virtual-channel rule: which VC a flit rides on the link
/// it is about to traverse, given where it came from and where it goes.
///
/// * crossing a **dateline** (a wraparound link, `crosses_dateline`) →
///   VC 1, unconditionally;
/// * continuing in the **same dimension** (E/W → E/W, N/S → N/S) →
///   keep the current VC (a flit that crossed the wrap stays on VC 1
///   until it leaves the dimension — returning early would re-close the
///   dependency cycle through the dateline, see `docs/deadlock.md`);
/// * **entering a dimension** (injection, or an X→Y turn under
///   dimension-ordered routing) → back to VC 0: each dimension's ring is
///   broken independently, and dimension-ordered routing never turns
///   Y→X, so the cross-dimension edges are acyclic by themselves.
///
/// ```
/// use floonoc::router::routing::dateline_vc;
/// use floonoc::router::{PORT_E, PORT_LOCAL, PORT_N, PORT_W};
/// // Injected flit heading east on a plain channel: VC 0.
/// assert_eq!(dateline_vc(PORT_LOCAL, PORT_E, false, 0), 0);
/// // The same hop over the row's wraparound link: switch to VC 1.
/// assert_eq!(dateline_vc(PORT_LOCAL, PORT_E, true, 0), 1);
/// // Continuing east after the wrap: stay on VC 1...
/// assert_eq!(dateline_vc(PORT_W, PORT_E, false, 1), 1);
/// // ...until the dimension-ordered turn into Y resets to VC 0.
/// assert_eq!(dateline_vc(PORT_W, PORT_N, false, 1), 0);
/// ```
#[inline]
pub fn dateline_vc(in_port: usize, out_port: usize, crosses_dateline: bool, vc_in: u8) -> u8 {
    if crosses_dateline {
        1
    } else if port_dim(in_port).is_some() && port_dim(in_port) == port_dim(out_port) {
        vc_in
    } else {
        0
    }
}

/// Per-router route table: output port for every destination node, plus
/// the router's **dateline mask** — which of its output ports cross a
/// wraparound link (always empty on meshes). The mask is what makes the
/// table the single source of the VC-switch decision: the router hot
/// loop asks [`RouteTable::crosses_dateline`] and [`dateline_vc`] and
/// never re-derives fabric geometry.
///
/// Under adaptive routing the table additionally carries a
/// per-destination **candidate mask** ([`RouteTable::candidates`], from
/// [`RoutingAlgorithm::candidates`]) and the number of **escape lanes**
/// reserved for the deterministic baseline; the `ports` vector then
/// holds the escape step. A table with no candidate vector
/// (`!is_adaptive()`) routes exactly as before.
#[derive(Debug, Clone)]
pub struct RouteTable {
    ports: Vec<u8>,
    dateline: u8,
    /// Per-destination candidate port bitmask; empty ⇔ deterministic.
    cand: Vec<u8>,
    /// VC lanes `0..escape_lanes` reserved for the escape baseline.
    escape_lanes: u8,
}

impl RouteTable {
    /// Build from the destination-indexed port vector, with no dateline
    /// ports (correct for meshes and for unit fixtures).
    pub fn new(ports: Vec<u8>) -> Self {
        RouteTable::with_dateline(ports, 0)
    }

    /// Build with an explicit dateline mask (bit `p` set = output port
    /// `p` crosses a wraparound link). `Topology::route_table` fills
    /// this from `Topology::dateline_ports`.
    pub fn with_dateline(ports: Vec<u8>, dateline: u8) -> Self {
        RouteTable {
            ports,
            dateline,
            cand: Vec::new(),
            escape_lanes: 1,
        }
    }

    /// Build an adaptive table: escape steps in `ports`, the dateline
    /// mask, per-destination candidate masks (same indexing as `ports`)
    /// and the escape-lane count (the fabric's dateline VC default: 1
    /// on meshes, 2 on wrap fabrics). `Topology::route_table_adaptive`
    /// fills all four.
    pub fn with_candidates(ports: Vec<u8>, dateline: u8, cand: Vec<u8>, escape_lanes: u8) -> Self {
        assert_eq!(ports.len(), cand.len(), "one candidate mask per destination");
        assert!(escape_lanes >= 1, "the escape baseline needs a lane");
        RouteTable {
            ports,
            dateline,
            cand,
            escape_lanes,
        }
    }

    /// Whether this table carries adaptive candidate sets.
    #[inline]
    pub fn is_adaptive(&self) -> bool {
        !self.cand.is_empty()
    }

    /// Number of VC lanes reserved for the deterministic escape
    /// baseline (`0..escape_lanes`); lanes above are adaptive.
    #[inline]
    pub fn escape_lanes(&self) -> u8 {
        self.escape_lanes
    }

    /// Candidate output-port bitmask for `dst` (adaptive tables only;
    /// panics when the table is deterministic — callers gate on
    /// [`RouteTable::is_adaptive`]).
    #[inline]
    pub fn candidates(&self, dst: NodeId) -> u8 {
        self.cand[dst.0 as usize]
    }

    /// Does leaving this router through `port` cross a wraparound
    /// (dateline) link?
    #[inline]
    pub fn crosses_dateline(&self, port: usize) -> bool {
        (self.dateline >> port) & 1 == 1
    }

    /// Output port for `dst`. Panics on unknown destinations — a routing
    /// table must be total over the deployed nodes.
    #[inline]
    pub fn lookup(&self, dst: NodeId) -> usize {
        self.ports[dst.0 as usize] as usize
    }

    /// Number of destinations the table covers.
    pub fn len(&self) -> usize {
        self.ports.len()
    }

    /// True for a table with no destinations.
    pub fn is_empty(&self) -> bool {
        self.ports.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xy_goes_x_first() {
        let me = Coord::new(1, 1);
        assert_eq!(xy_route(me, Coord::new(3, 0)), PORT_E);
        assert_eq!(xy_route(me, Coord::new(0, 3)), PORT_W);
        // Same column: move in Y.
        assert_eq!(xy_route(me, Coord::new(1, 3)), PORT_N);
        assert_eq!(xy_route(me, Coord::new(1, 0)), PORT_S);
        // Arrived.
        assert_eq!(xy_route(me, me), PORT_LOCAL);
    }

    #[test]
    fn xy_path_is_monotone() {
        // Walk the rule from (0,0) to (3,2): first 3 E steps, then 2 N.
        let dst = Coord::new(3, 2);
        let mut cur = Coord::new(0, 0);
        let mut ports = Vec::new();
        loop {
            let p = xy_route(cur, dst);
            if p == PORT_LOCAL {
                break;
            }
            ports.push(p);
            match p {
                PORT_E => cur.x += 1,
                PORT_W => cur.x -= 1,
                PORT_N => cur.y += 1,
                PORT_S => cur.y -= 1,
                _ => unreachable!(),
            }
        }
        assert_eq!(ports, vec![PORT_E, PORT_E, PORT_E, PORT_N, PORT_N]);
    }

    #[test]
    fn ring_takes_shorter_arc() {
        let n = 5;
        let at = |x| Coord::new(x, 0);
        // 0 -> 1: one E hop. 0 -> 4: one W hop via wraparound.
        assert_eq!(ring_route(at(0), at(1), n), PORT_E);
        assert_eq!(ring_route(at(0), at(4), n), PORT_W);
        // 0 -> 2 east (2 hops) beats west (3 hops).
        assert_eq!(ring_route(at(0), at(2), n), PORT_E);
        assert_eq!(ring_route(at(0), at(3), n), PORT_W);
        assert_eq!(ring_route(at(2), at(2), n), PORT_LOCAL);
    }

    #[test]
    fn ring_tie_breaks_east() {
        // Even ring, diametrically opposite: both arcs are 2 hops; the
        // deterministic choice is east.
        let n = 4;
        assert_eq!(ring_route(Coord::new(0, 0), Coord::new(2, 0), n), PORT_E);
        assert_eq!(ring_route(Coord::new(3, 0), Coord::new(1, 0), n), PORT_E);
    }

    #[test]
    fn torus_wraps_each_dimension_independently() {
        let (w, h) = (5, 5);
        let me = Coord::new(0, 0);
        // X resolved first, wrapping west when shorter.
        assert_eq!(torus_route(me, Coord::new(4, 3), w, h), PORT_W);
        assert_eq!(torus_route(me, Coord::new(2, 3), w, h), PORT_E);
        // X aligned: Y wraps south when shorter.
        assert_eq!(torus_route(me, Coord::new(0, 4), w, h), PORT_S);
        assert_eq!(torus_route(me, Coord::new(0, 2), w, h), PORT_N);
        assert_eq!(torus_route(me, me, w, h), PORT_LOCAL);
    }

    #[test]
    fn algorithm_distances_match_fabric() {
        let corner = Coord::new(0, 0);
        let far = Coord::new(3, 3);
        assert_eq!(RoutingAlgorithm::Xy.distance(corner, far), 6);
        // 4x4 torus: one wrap hop per dimension.
        let t = RoutingAlgorithm::TorusXy { width: 4, height: 4 };
        assert_eq!(t.distance(corner, far), 2);
        // 8-ring: 0 -> 5 is 3 hops the short way.
        let r = RoutingAlgorithm::RingShortest { nodes: 8 };
        assert_eq!(r.distance(Coord::new(0, 0), Coord::new(5, 0)), 3);
        // Distance is symmetric.
        assert_eq!(t.distance(far, corner), 2);
        assert_eq!(r.distance(Coord::new(5, 0), Coord::new(0, 0)), 3);
    }

    #[test]
    fn table_lookup() {
        let t = RouteTable::new(vec![0, 2, 2, 4]);
        assert_eq!(t.lookup(NodeId(0)), 0);
        assert_eq!(t.lookup(NodeId(2)), 2);
        assert_eq!(t.len(), 4);
    }

    #[test]
    fn dateline_mask_per_port() {
        let t = RouteTable::new(vec![0]);
        for p in 0..6 {
            assert!(!t.crosses_dateline(p), "plain tables have no datelines");
        }
        let t = RouteTable::with_dateline(vec![0], (1 << PORT_E) | (1 << PORT_S));
        assert!(t.crosses_dateline(PORT_E));
        assert!(t.crosses_dateline(PORT_S));
        assert!(!t.crosses_dateline(PORT_W));
        assert!(!t.crosses_dateline(PORT_LOCAL));
    }

    /// The dateline rule, case by case: wrap hops always land on VC 1,
    /// in-dimension hops preserve the VC, and dimension entry (injection
    /// or the X→Y turn) resets to VC 0.
    #[test]
    fn dateline_vc_rule() {
        // Wrap crossing dominates everything, whatever the current VC.
        for vc in [0, 1] {
            assert_eq!(dateline_vc(PORT_LOCAL, PORT_E, true, vc), 1);
            assert_eq!(dateline_vc(PORT_W, PORT_E, true, vc), 1);
            assert_eq!(dateline_vc(PORT_E, PORT_N, true, vc), 1);
        }
        // Same dimension, no wrap: the VC sticks (both X and Y).
        assert_eq!(dateline_vc(PORT_W, PORT_E, false, 0), 0);
        assert_eq!(dateline_vc(PORT_W, PORT_E, false, 1), 1);
        assert_eq!(dateline_vc(PORT_S, PORT_N, false, 1), 1);
        // Dimension change / injection / ejection: reset to VC 0.
        assert_eq!(dateline_vc(PORT_W, PORT_N, false, 1), 0, "X->Y turn");
        assert_eq!(dateline_vc(PORT_LOCAL, PORT_E, false, 1), 0, "injection");
        assert_eq!(dateline_vc(PORT_E, PORT_LOCAL, false, 1), 0, "ejection");
        assert_eq!(dateline_vc(PORT_E, super::super::router::PORT_MEM, false, 1), 0);
    }

    #[test]
    fn adaptive_candidates_are_minimal_and_contain_escape() {
        let algs = [
            RoutingAlgorithm::AdaptiveXy,
            RoutingAlgorithm::AdaptiveTorus { width: 4, height: 4 },
            RoutingAlgorithm::AdaptiveTorus { width: 5, height: 3 },
            RoutingAlgorithm::AdaptiveRing { nodes: 8 },
        ];
        for alg in algs {
            let (w, h) = match alg {
                RoutingAlgorithm::AdaptiveTorus { width, height } => (width, height),
                RoutingAlgorithm::AdaptiveRing { nodes } => (nodes, 1),
                _ => (4, 4),
            };
            for sy in 0..h {
                for sx in 0..w {
                    for dy in 0..h {
                        for dx in 0..w {
                            let me = Coord::new(sx, sy);
                            let dst = Coord::new(dx, dy);
                            let cand = alg.candidates(me, dst);
                            assert_ne!(cand, 0, "{alg:?}: empty candidate set");
                            if me == dst {
                                assert_eq!(cand, 1 << PORT_LOCAL);
                                continue;
                            }
                            assert_ne!(
                                cand & (1 << alg.step(me, dst)),
                                0,
                                "{alg:?} {me:?}->{dst:?}: escape step not a candidate"
                            );
                            // Every candidate hop strictly decreases the
                            // distance (minimality).
                            let wraps = !matches!(alg, RoutingAlgorithm::AdaptiveXy);
                            for port in [PORT_N, PORT_E, PORT_S, PORT_W] {
                                if cand & (1 << port) == 0 {
                                    continue;
                                }
                                let next = match (port, wraps) {
                                    (PORT_E, true) => Coord::new((sx + 1) % w, sy),
                                    (PORT_E, false) => Coord::new(sx + 1, sy),
                                    (PORT_W, true) => Coord::new((sx + w - 1) % w, sy),
                                    (PORT_W, false) => Coord::new(sx - 1, sy),
                                    (PORT_N, true) => Coord::new(sx, (sy + 1) % h),
                                    (PORT_N, false) => Coord::new(sx, sy + 1),
                                    (PORT_S, true) => Coord::new(sx, (sy + h - 1) % h),
                                    (PORT_S, false) => Coord::new(sx, sy - 1),
                                    _ => unreachable!(),
                                };
                                assert_eq!(
                                    alg.distance(next, dst) + 1,
                                    alg.distance(me, dst),
                                    "{alg:?} {me:?}->{dst:?} via {port}: not minimal"
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn tornado_tie_yields_both_directions() {
        // Diametrically-opposite destination on an even ring: either arc
        // is minimal, so the adaptive candidate set carries both
        // directions while the deterministic escape tie-breaks east.
        let alg = RoutingAlgorithm::AdaptiveRing { nodes: 8 };
        let cand = alg.candidates(Coord::new(0, 0), Coord::new(4, 0));
        assert_eq!(cand, (1 << PORT_E) | (1 << PORT_W));
        let t = RoutingAlgorithm::AdaptiveTorus { width: 8, height: 8 };
        let cand = t.candidates(Coord::new(0, 0), Coord::new(4, 4));
        assert_eq!(
            cand,
            (1 << PORT_E) | (1 << PORT_W) | (1 << PORT_N) | (1 << PORT_S),
            "tornado pairs see all four productive directions"
        );
    }

    #[test]
    fn deterministic_candidates_are_the_single_step() {
        let alg = RoutingAlgorithm::TorusXy { width: 4, height: 4 };
        let me = Coord::new(0, 0);
        for (dx, dy) in [(1u8, 0u8), (3, 0), (0, 2), (2, 3)] {
            let dst = Coord::new(dx, dy);
            assert_eq!(alg.candidates(me, dst), 1 << alg.step(me, dst));
        }
    }

    #[test]
    fn adaptive_table_carries_candidates_and_escape_lanes() {
        let t = RouteTable::with_candidates(
            vec![PORT_E as u8, PORT_N as u8],
            1 << PORT_E,
            vec![(1 << PORT_E) | (1 << PORT_N), 1 << PORT_N],
            2,
        );
        assert!(t.is_adaptive());
        assert_eq!(t.escape_lanes(), 2);
        assert_eq!(t.candidates(NodeId(0)), (1 << PORT_E) | (1 << PORT_N));
        assert_eq!(t.lookup(NodeId(0)), PORT_E);
        let d = RouteTable::new(vec![0]);
        assert!(!d.is_adaptive());
        assert_eq!(d.escape_lanes(), 1);
    }

    #[test]
    fn port_dimensions() {
        assert_eq!(port_dim(PORT_E), Some(0));
        assert_eq!(port_dim(PORT_W), Some(0));
        assert_eq!(port_dim(PORT_N), Some(1));
        assert_eq!(port_dim(PORT_S), Some(1));
        assert_eq!(port_dim(PORT_LOCAL), None);
        assert_eq!(port_dim(super::super::router::PORT_MEM), None);
    }
}
