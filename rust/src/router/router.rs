//! The router proper: input-buffered, wormhole, round-robin switch.

use crate::flit::FlooFlit;
use crate::sim::{Link, LinkId};

use super::arbiter::RoundRobin;
use super::routing::RouteTable;

/// Canonical port numbering: the tile-facing local port of the 5×5 router.
pub const PORT_LOCAL: usize = 0;
/// Cardinal port towards +y.
pub const PORT_N: usize = 1;
/// Cardinal port towards +x.
pub const PORT_E: usize = 2;
/// Cardinal port towards -y.
pub const PORT_S: usize = 3;
/// Cardinal port towards -x.
pub const PORT_W: usize = 4;
/// Dedicated memory-controller attach port on radix-6 torus routers:
/// every cardinal port of a torus router is taken by a neighbour (the
/// wraparound closes each row and column), so controllers get their own
/// sixth port instead of a free boundary port.
pub const PORT_MEM: usize = 5;

/// Static router configuration.
#[derive(Debug, Clone)]
pub struct RouterCfg {
    /// Radix (inputs = outputs = ports). The paper's tile router is 5.
    pub ports: usize,
    /// Input FIFO depth in flits.
    pub in_buf_depth: usize,
}

impl Default for RouterCfg {
    fn default() -> Self {
        RouterCfg {
            ports: 5,
            in_buf_depth: 2,
        }
    }
}

/// What a [`Router::step`] call did, for the activity-gated step loop:
/// which output links received a flit this cycle (a wake-up edge per
/// offered output — those links must enter the active set so next
/// cycle's link sweep delivers them), and whether any input held a flit
/// at all (false means the whole step was a no-op).
#[derive(Debug, Clone, Copy, Default)]
pub struct RouterActivity {
    /// At least one input buffer held a head flit this cycle.
    pub any_input: bool,
    /// Bitmask over *output ports* (not link ids) that accepted a flit
    /// during commit. Radix is ≤ 6 in every supported fabric, so a u32
    /// is comfortable headroom.
    pub woke_outputs: u32,
}

/// Per-output wormhole/arbitration state.
#[derive(Debug, Clone)]
struct OutputState {
    /// Input port holding this output until its packet's `last` flit.
    lock: Option<usize>,
    arb: RoundRobin,
    /// Forwarded flit count (utilization accounting).
    forwarded: u64,
}

/// One router instance of one physical network.
///
/// The router does not own its links; it holds [`LinkId`]s into the
/// network's link arena and is stepped with that arena (`step`). `None`
/// entries are unconnected ports (mesh boundary).
#[derive(Debug)]
pub struct Router {
    /// Radix and buffering parameters this router was built with.
    pub cfg: RouterCfg,
    /// Input link per port (delivers into this router's input buffers).
    pub in_links: Vec<Option<LinkId>>,
    /// Output link per port.
    pub out_links: Vec<Option<LinkId>>,
    /// Routing table (dst node -> output port).
    pub table: RouteTable,
    outputs: Vec<OutputState>,
    /// Reusable route-computation scratch (avoids per-cycle allocation).
    want: Vec<Option<usize>>,
    /// Total flits forwarded (all ports).
    pub forwarded: u64,
    /// Cycles with at least one forwarded flit (activity factor).
    pub active_cycles: u64,
}

impl Router {
    /// Build a router with all ports unconnected and the given static
    /// route table; the network builder wires `in_links`/`out_links`.
    pub fn new(cfg: RouterCfg, table: RouteTable) -> Self {
        let outputs = (0..cfg.ports)
            .map(|_| OutputState {
                lock: None,
                arb: RoundRobin::new(cfg.ports),
                forwarded: 0,
            })
            .collect();
        Router {
            in_links: vec![None; cfg.ports],
            out_links: vec![None; cfg.ports],
            table,
            outputs,
            want: vec![None; cfg.ports],
            cfg,
            forwarded: 0,
            active_cycles: 0,
        }
    }

    /// Flits forwarded through a specific output port.
    pub fn forwarded_on(&self, port: usize) -> u64 {
        self.outputs[port].forwarded
    }

    /// One cycle, in two explicit phases: **compute** (route lookup on
    /// every input-buffer head, no state changes) and **commit** (switch
    /// allocation honouring wormhole locks, then traversal into the output
    /// links). The split mirrors the deliver/step discipline of the
    /// engine: all routing decisions observe the same pre-cycle state, and
    /// only the commit phase mutates links.
    ///
    /// Returns a [`RouterActivity`] summary for the gated step loop;
    /// dense-mode and unit-test callers are free to ignore it.
    pub fn step(&mut self, links: &mut [Link<FlooFlit>]) -> RouterActivity {
        if self.compute_requests(links) {
            RouterActivity {
                any_input: true,
                woke_outputs: self.commit_switch(links),
            }
        } else {
            RouterActivity::default()
        }
    }

    /// Compute phase: fill `want[i] = Some(o)` for every input head flit
    /// requesting output `o`. Returns false when every input is empty —
    /// the common case in large meshes, letting `step` exit early. The
    /// scratch buffer lives in the router (no per-cycle allocation).
    fn compute_requests(&mut self, links: &[Link<FlooFlit>]) -> bool {
        let ports = self.cfg.ports;
        let mut any_input = false;
        for i in 0..ports {
            self.want[i] = None;
            let Some(lid) = self.in_links[i] else { continue };
            if let Some(flit) = links[lid].peek() {
                let o = self.table.lookup(flit.header.dst);
                debug_assert!(o < ports, "route table port out of range");
                debug_assert!(
                    o != i,
                    "loopback disabled: flit at port {i} routed back (dst {:?})",
                    flit.header.dst
                );
                self.want[i] = Some(o);
                any_input = true;
            }
        }
        any_input
    }

    /// Commit phase: one winner per output port, wormhole locks honoured,
    /// round-robin arbitration otherwise; winners traverse into their
    /// output links. Returns the bitmask of output ports that accepted a
    /// flit (the gated loop's router→output-link wake edges).
    fn commit_switch(&mut self, links: &mut [Link<FlooFlit>]) -> u32 {
        let ports = self.cfg.ports;
        let mut woke: u32 = 0;
        let mut any = false;
        for o in 0..ports {
            let Some(out_lid) = self.out_links[o] else { continue };
            if !links[out_lid].can_offer() {
                // Downstream backpressure (ready deasserted). A held lock
                // survives the stall untouched: it is released only by the
                // packet's `last` flit actually traversing, never by the
                // output going not-ready mid-packet.
                continue;
            }
            let want = &self.want;
            let winner = match self.outputs[o].lock {
                // Wormhole: the locked input continues its packet; if its
                // next flit hasn't arrived yet the output idles but stays
                // locked (no interleaving, as in RTL).
                Some(i) => {
                    // Mid-packet, the locked input's head (when present)
                    // must still target the locked output — its packet's
                    // remaining flits are the only thing it may send. A
                    // divergent head would deadlock the output silently;
                    // fail loudly instead.
                    debug_assert!(
                        want[i].is_none() || want[i] == Some(o),
                        "locked input {i} head diverged from output {o} mid-packet"
                    );
                    if want[i] == Some(o) {
                        Some(i)
                    } else {
                        None
                    }
                }
                None => self.outputs[o].arb.arbitrate_with(|i| want[i] == Some(o)),
            };
            let Some(i) = winner else { continue };
            let in_lid = self.in_links[i].unwrap();
            let flit = links[in_lid].pop().unwrap();
            self.outputs[o].lock = if flit.header.last { None } else { Some(i) };
            links[out_lid].offer(flit);
            self.outputs[o].forwarded += 1;
            self.forwarded += 1;
            self.want[i] = None; // an input feeds at most one output per cycle
            woke |= 1 << o;
            any = true;
        }
        if any {
            self.active_cycles += 1;
        }
        woke
    }

    /// True when all input buffers this router reads from are empty and no
    /// output is mid-packet.
    pub fn is_idle(&self, links: &[Link<FlooFlit>]) -> bool {
        self.outputs.iter().all(|o| o.lock.is_none())
            && self
                .in_links
                .iter()
                .flatten()
                .all(|&lid| links[lid].peek().is_none())
    }

    /// Clock-gating predicate: true when stepping this router would be a
    /// no-op — every input buffer it reads from is empty. Wormhole locks
    /// are deliberately ignored: a locked output with no pending input
    /// flit idles (and stays locked) whether or not the router is
    /// stepped, so a lock alone never requires a clock. The gated loop
    /// wakes a router the cycle any of its input links delivers a flit.
    pub fn is_quiescent(&self, links: &[Link<FlooFlit>]) -> bool {
        self.in_links
            .iter()
            .flatten()
            .all(|&lid| links[lid].buffered() == 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::axi::{AxReq, Burst, Resp, RBeat};
    use crate::flit::{Header, NodeId, Payload};

    fn flit(dst: u16, last: bool, tag: u32) -> FlooFlit {
        FlooFlit {
            header: Header {
                dst: NodeId(dst),
                src: NodeId(0),
                rob_idx: tag,
                rob_req: true,
                atomic: false,
                last,
            },
            payload: Payload::NarrowAr(AxReq {
                id: 0,
                addr: 0,
                len: 0,
                size: 3,
                burst: Burst::Incr,
                atop: false,
            }),
            injected_at: 0,
        }
    }

    fn rflit(dst: u16, beat: u32, last: bool) -> FlooFlit {
        FlooFlit {
            header: Header {
                dst: NodeId(dst),
                src: NodeId(0),
                rob_idx: 0,
                rob_req: true,
                atomic: false,
                last,
            },
            payload: Payload::WideR(RBeat {
                id: 0,
                beat,
                last,
                resp: Resp::Okay,
            }),
            injected_at: 0,
        }
    }

    /// Build a 3-port router with dedicated in/out links.
    /// dst 0 -> port 0, dst 1 -> port 1, dst 2 -> port 2.
    fn mini() -> (Router, Vec<Link<FlooFlit>>) {
        let mut links: Vec<Link<FlooFlit>> = (0..6).map(|_| Link::new(2)).collect();
        let _ = &mut links;
        let mut r = Router::new(
            RouterCfg {
                ports: 3,
                in_buf_depth: 2,
            },
            RouteTable::new(vec![0, 1, 2]),
        );
        for p in 0..3 {
            r.in_links[p] = Some(p);
            r.out_links[p] = Some(3 + p);
        }
        (r, links)
    }

    fn deliver_all(links: &mut [Link<FlooFlit>]) {
        for l in links {
            l.deliver();
        }
    }

    #[test]
    fn single_cycle_forwarding() {
        let (mut r, mut links) = mini();
        links[0].offer(flit(1, true, 7));
        deliver_all(&mut links); // flit reaches input buffer
        r.step(&mut links); // forwarded to out link 4 (port 1)
        deliver_all(&mut links);
        let got = links[4].pop().unwrap();
        assert_eq!(got.header.rob_idx, 7);
        assert_eq!(r.forwarded, 1);
    }

    #[test]
    fn wormhole_locks_output_until_last() {
        let (mut r, mut links) = mini();
        // Input 0: 2-beat packet to dst 2. Input 1: single flit to dst 2.
        links[0].offer(rflit(2, 0, false));
        links[1].offer(flit(2, true, 99));
        deliver_all(&mut links);
        r.step(&mut links); // winner starts packet, output 2 locks
        deliver_all(&mut links);
        let first = links[5].pop().unwrap();
        // Offer second beat from the same input that won.
        let winner_was_0 = matches!(first.payload, Payload::WideR(_));
        if winner_was_0 {
            links[0].offer(rflit(2, 1, true));
        } else {
            // rr picked input 1's single flit; nothing to continue. Not the
            // scenario under test; force the deterministic case instead.
            panic!("expected input 0 to win first rr grant");
        }
        deliver_all(&mut links);
        r.step(&mut links);
        deliver_all(&mut links);
        let second = links[5].pop().unwrap();
        assert!(
            matches!(second.payload, Payload::WideR(RBeat { beat: 1, .. })),
            "locked output must continue the packet, not interleave: {second:?}"
        );
        // Now the lock is released; the waiting flit goes through.
        r.step(&mut links);
        deliver_all(&mut links);
        assert_eq!(links[5].pop().unwrap().header.rob_idx, 99);
    }

    #[test]
    fn backpressure_holds_flit() {
        let (mut r, mut links) = mini();
        // Fill output 1's downstream buffer (depth 2) + register.
        links[0].offer(flit(1, true, 1));
        deliver_all(&mut links);
        r.step(&mut links);
        links[0].offer(flit(1, true, 2));
        deliver_all(&mut links);
        r.step(&mut links);
        links[0].offer(flit(1, true, 3));
        deliver_all(&mut links);
        r.step(&mut links);
        // out link 4 now: buf [1,2] + reg 3 -> full.
        links[0].offer(flit(1, true, 4));
        deliver_all(&mut links);
        let before = r.forwarded;
        r.step(&mut links); // cannot offer: register busy
        assert_eq!(r.forwarded, before, "no forward under backpressure");
        // Drain one and try again.
        assert_eq!(links[4].pop().unwrap().header.rob_idx, 1);
        deliver_all(&mut links); // reg 3 -> buf
        r.step(&mut links); // 4 forwards into reg
        assert_eq!(r.forwarded, before + 1);
    }

    #[test]
    fn parallel_disjoint_transfers_same_cycle() {
        let (mut r, mut links) = mini();
        links[0].offer(flit(1, true, 10));
        links[1].offer(flit(2, true, 20));
        deliver_all(&mut links);
        r.step(&mut links);
        deliver_all(&mut links);
        assert_eq!(links[4].pop().unwrap().header.rob_idx, 10);
        assert_eq!(links[5].pop().unwrap().header.rob_idx, 20);
        assert_eq!(r.forwarded, 2, "crossbar moves disjoint pairs in parallel");
    }

    #[test]
    fn contention_resolved_round_robin() {
        let (mut r, mut links) = mini();
        // Both inputs target output 2 with single-flit packets repeatedly.
        let mut order = Vec::new();
        for round in 0..4 {
            links[0].offer(flit(2, true, 100 + round));
            links[1].offer(flit(2, true, 200 + round));
            deliver_all(&mut links);
            r.step(&mut links);
            deliver_all(&mut links);
            order.push(links[5].pop().unwrap().header.rob_idx / 100);
            // Second one goes through next cycle.
            r.step(&mut links);
            deliver_all(&mut links);
            order.push(links[5].pop().unwrap().header.rob_idx / 100);
        }
        // Fair alternation: each round serves both, rotating priority.
        let ones = order.iter().filter(|&&x| x == 1).count();
        let twos = order.iter().filter(|&&x| x == 2).count();
        assert_eq!(ones, 4);
        assert_eq!(twos, 4);
    }

    #[test]
    fn idle_detection() {
        let (mut r, mut links) = mini();
        assert!(r.is_idle(&links));
        links[0].offer(flit(1, true, 1));
        deliver_all(&mut links);
        assert!(!r.is_idle(&links));
        r.step(&mut links);
        assert!(r.is_idle(&links));
    }
}
