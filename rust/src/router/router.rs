//! The router proper: input-buffered, wormhole, round-robin switch with
//! optional virtual channels.
//!
//! With `vcs == 1` (the default, and every mesh) this is exactly the
//! paper's VC-free router. With `vcs > 1` the switch becomes VC-aware
//! for dateline deadlock avoidance on wrap fabrics (`docs/deadlock.md`):
//!
//! * each input port's link carries per-VC lanes; route computation
//!   considers every lane head;
//! * wormhole locks are per **(output port, output VC)** — a packet
//!   blocked on one VC never prevents another VC's packet from using
//!   the same physical output;
//! * switch allocation still grants at most **one traversal per output
//!   port per cycle** (the physical channel's bandwidth), with locked
//!   continuations served first and round-robin arbitration over
//!   `(input, VC)` pairs otherwise;
//! * the output VC of a traversal follows the dateline rule
//!   ([`super::routing::dateline_vc`]): wrap crossings switch to VC 1,
//!   in-dimension hops keep the VC, dimension changes reset to VC 0.
//!
//! # Adaptive routing on escape VCs
//!
//! An **adaptive** route table ([`RouteTable::is_adaptive`]) splits the
//! VC lanes into *escape* lanes (`0..escape_lanes`, running exactly the
//! deterministic/dateline baseline above) and *adaptive* lanes
//! (`escape_lanes..vcs`). Each cycle, every un-granted head re-chooses
//! its output among the table's minimal candidate set by local
//! congestion — the count of admissible adaptive lanes (unlocked, with
//! credit) per candidate output; the highest count wins, lowest port on
//! ties, and the head plans the lowest admissible adaptive lane. When
//! no candidate has an admissible adaptive lane the head falls back to
//! the **escape route**: the deterministic output on the dateline lane.
//!
//! Two rules make this Duato-safe (full argument in
//! `docs/deadlock.md`):
//!
//! * **always-available escape** — every head can always *request* its
//!   escape route, whose (channel, VC) subgraph is proven acyclic by
//!   the static verifier, so some packet can always eventually drain;
//! * **no re-entry** — a head that arrives on an escape lane from a
//!   neighbouring router is *escape-committed*: it routes
//!   deterministically for the rest of its journey and never climbs
//!   back onto adaptive lanes. Without this, adaptive hops downstream
//!   of an escape hop would add indirect dependencies that re-close
//!   the escape cycle. Commitment also makes an escape entry
//!   lane-equivalent to a fresh injection (the dateline rule with
//!   `vc_in = 0` does not depend on the input port), so the escape
//!   subgraph equals the deterministic fabric's CDG at
//!   `min(vcs, escape_lanes)` lanes — the proof the verifier already
//!   runs.
//!
//! Adaptivity stays a pure function of pre-cycle simulator state (this
//! router's own output credits and locks — state no other component
//! mutates concurrently in any engine), so dense/gated/event × sharded
//! digests remain byte-identical.

use crate::flit::{FlooFlit, NodeId};
use crate::sim::{Link, LinkId};

use super::arbiter::RoundRobin;
use super::routing::{dateline_vc, RouteTable};

/// Upper bound on virtual channels per link. The dateline scheme needs
/// exactly 2; the headroom allows escape-VC adaptive routing without a
/// storage redesign (wormhole locks are fixed-size arrays of this many
/// slots, copied per output per cycle in the switch hot path — keep it
/// small).
pub const MAX_VCS: usize = 4;

/// Canonical port numbering: the tile-facing local port of the 5×5 router.
pub const PORT_LOCAL: usize = 0;
/// Cardinal port towards +y.
pub const PORT_N: usize = 1;
/// Cardinal port towards +x.
pub const PORT_E: usize = 2;
/// Cardinal port towards -y.
pub const PORT_S: usize = 3;
/// Cardinal port towards -x.
pub const PORT_W: usize = 4;
/// Dedicated memory-controller attach port on radix-6 torus routers:
/// every cardinal port of a torus router is taken by a neighbour (the
/// wraparound closes each row and column), so controllers get their own
/// sixth port instead of a free boundary port.
pub const PORT_MEM: usize = 5;

/// Read/write access to a network's link arena by [`LinkId`].
///
/// The serial engine owns every link of a network in one dense
/// `Vec<Link>`; the sharded engine ([`crate::noc::sharded`]) moves each
/// shard's links into a sparse per-shard view where non-owned output
/// links are reached through lock-free credit mirrors and boundary
/// mailboxes instead of direct state. This trait is the seam: the
/// router's compute/commit phases are written against it once and run
/// identically over both storages.
pub trait LinkPool {
    /// Lane (virtual-channel) count of link `lid`.
    fn vcs(&self, lid: LinkId) -> usize;
    /// Head flit of lane `vc` of link `lid`, if one has been delivered.
    fn peek_vc(&self, lid: LinkId, vc: usize) -> Option<&FlooFlit>;
    /// Whether lane `vc` of link `lid` can accept an offer this cycle.
    fn can_offer_vc(&self, lid: LinkId, vc: usize) -> bool;
    /// Pop the delivered head flit of lane `vc` of link `lid`.
    fn pop_vc(&mut self, lid: LinkId, vc: usize) -> Option<FlooFlit>;
    /// Offer `flit` on lane `vc` of link `lid` (panics when not
    /// [`LinkPool::can_offer_vc`], exactly like [`Link::offer_vc`]).
    fn offer_vc(&mut self, lid: LinkId, vc: usize, flit: FlooFlit);
    /// Flits buffered at the consumer side of link `lid`, all lanes.
    fn buffered(&self, lid: LinkId) -> usize;
    /// Bitmask of lanes of link `lid` whose consumer buffer holds at
    /// least one delivered flit (bit `v` ⇔ lane `v` has a head to
    /// peek). Lets the route-compute pass skip empty lanes without
    /// probing each one. Only meaningful for a router's *input* links —
    /// the sharded engine answers it for owned links only.
    fn occupied_lanes(&self, lid: LinkId) -> u32;
}

impl LinkPool for [Link<FlooFlit>] {
    fn vcs(&self, lid: LinkId) -> usize {
        self[lid].vcs()
    }
    fn peek_vc(&self, lid: LinkId, vc: usize) -> Option<&FlooFlit> {
        self[lid].peek_vc(vc)
    }
    fn can_offer_vc(&self, lid: LinkId, vc: usize) -> bool {
        self[lid].can_offer_vc(vc)
    }
    fn pop_vc(&mut self, lid: LinkId, vc: usize) -> Option<FlooFlit> {
        self[lid].pop_vc(vc)
    }
    fn offer_vc(&mut self, lid: LinkId, vc: usize, flit: FlooFlit) {
        self[lid].offer_vc(vc, flit)
    }
    fn buffered(&self, lid: LinkId) -> usize {
        self[lid].buffered()
    }
    fn occupied_lanes(&self, lid: LinkId) -> u32 {
        self[lid].occupied_lanes()
    }
}

impl LinkPool for Vec<Link<FlooFlit>> {
    fn vcs(&self, lid: LinkId) -> usize {
        self.as_slice().vcs(lid)
    }
    fn peek_vc(&self, lid: LinkId, vc: usize) -> Option<&FlooFlit> {
        self.as_slice().peek_vc(lid, vc)
    }
    fn can_offer_vc(&self, lid: LinkId, vc: usize) -> bool {
        self.as_slice().can_offer_vc(lid, vc)
    }
    fn pop_vc(&mut self, lid: LinkId, vc: usize) -> Option<FlooFlit> {
        self.as_mut_slice().pop_vc(lid, vc)
    }
    fn offer_vc(&mut self, lid: LinkId, vc: usize, flit: FlooFlit) {
        self.as_mut_slice().offer_vc(lid, vc, flit)
    }
    fn buffered(&self, lid: LinkId) -> usize {
        self.as_slice().buffered(lid)
    }
    fn occupied_lanes(&self, lid: LinkId) -> u32 {
        self.as_slice().occupied_lanes(lid)
    }
}

/// Static router configuration.
#[derive(Debug, Clone)]
pub struct RouterCfg {
    /// Radix (inputs = outputs = ports). The paper's tile router is 5.
    pub ports: usize,
    /// Input FIFO depth in flits (split across VCs when `vcs > 1`).
    pub in_buf_depth: usize,
    /// Virtual channels per link (1 = the paper's VC-free router; 2 =
    /// dateline deadlock avoidance on wrap fabrics). At most
    /// [`MAX_VCS`].
    pub vcs: usize,
}

impl Default for RouterCfg {
    fn default() -> Self {
        RouterCfg {
            ports: 5,
            in_buf_depth: 2,
            vcs: 1,
        }
    }
}

/// What a [`Router::step`] call did, for the activity-gated step loop:
/// which output links received a flit this cycle (a wake-up edge per
/// offered output — those links must enter the active set so next
/// cycle's link sweep delivers them), and whether any input held a flit
/// at all (false means the whole step was a no-op).
#[derive(Debug, Clone, Copy, Default)]
pub struct RouterActivity {
    /// At least one input buffer held a head flit this cycle.
    pub any_input: bool,
    /// Bitmask over *output ports* (not link ids) that accepted a flit
    /// during commit. Radix is ≤ 6 in every supported fabric, so a u32
    /// is comfortable headroom.
    pub woke_outputs: u32,
}

/// Per-output wormhole/arbitration state.
#[derive(Debug, Clone)]
struct OutputState {
    /// Per-output-VC wormhole lock: `locks[v]` names the `(input port,
    /// input VC)` pair whose packet holds output lane `v` until its
    /// `last` flit. With `vcs == 1` only slot 0 is ever used and this
    /// degenerates to the classic single output lock. Eject links carry
    /// one lane, so every packet to an eject port competes for slot 0 —
    /// NI-bound packets never interleave, exactly as before VCs.
    locks: [Option<(u8, u8)>; MAX_VCS],
    /// Rotating priority over `(input port, input VC)` pairs (index
    /// `input * vcs + vc`). Only consulted — and only advanced — when no
    /// locked continuation wins, mirroring the pre-VC router where
    /// locked outputs bypassed the arbiter entirely.
    arb: RoundRobin,
    /// Forwarded flit count (utilization accounting).
    forwarded: u64,
}

/// One router instance of one physical network.
///
/// The router does not own its links; it holds [`LinkId`]s into the
/// network's link arena and is stepped with that arena (`step`). `None`
/// entries are unconnected ports (mesh boundary).
#[derive(Debug)]
pub struct Router {
    /// Radix and buffering parameters this router was built with.
    pub cfg: RouterCfg,
    /// Input link per port (delivers into this router's input buffers).
    pub in_links: Vec<Option<LinkId>>,
    /// Output link per port.
    pub out_links: Vec<Option<LinkId>>,
    /// Routing table (dst node -> output port, plus the dateline mask).
    pub table: RouteTable,
    outputs: Vec<OutputState>,
    /// Memoized route computation, indexed `input * vcs + vc`: the
    /// output port the lane's *current* head flit routes to, `None`
    /// when the lane is empty. This router is the sole consumer of its
    /// input links, so a lane's head changes only when the commit phase
    /// pops it — the entry stays valid across cycles and a stalled head
    /// is looked up once, not once per cycle.
    want: Vec<Option<u8>>,
    /// Per-output requester bitmask: bit `input * vcs + vc` set ⇔
    /// `want[input * vcs + vc] == Some(output)`. Lets the commit phase
    /// skip outputs nobody wants and hands the arbiter a set-bit mask
    /// instead of a probe-everything closure. Maintained alongside
    /// `want` (set on route, cleared on pop).
    req: Vec<u32>,
    /// Adaptive mode flag (`table.is_adaptive()` at build), hoisted out
    /// of the hot loop so the deterministic path costs one branch.
    adaptive: bool,
    /// Escape-lane count (`min(table.escape_lanes(), cfg.vcs)`); lanes
    /// `escape_lanes..vcs` are the adaptive lanes. 1 in deterministic
    /// mode (unused there).
    escape_lanes: usize,
    /// Adaptive mode only: the planned *output lane* for each input
    /// lane's head, maintained alongside `want` (a deterministic head's
    /// output lane is a pure function of `(input, output, vc)` so no
    /// plan is needed; an adaptive head's lane was chosen against this
    /// cycle's congestion and must be committed as planned).
    plan_vc: Vec<Option<u8>>,
    /// Adaptive mode only: `(output port, output lane)` a mid-packet
    /// input lane is wormhole-committed to — the inverse view of the
    /// per-output locks. Continuation flits bypass the adaptive choice
    /// and follow the hold; cleared when the `last` flit is granted.
    hold: Vec<Option<(u8, u8)>>,
    /// Total flits forwarded (all ports).
    pub forwarded: u64,
    /// Cycles with at least one forwarded flit (activity factor).
    pub active_cycles: u64,
}

impl Router {
    /// Build a router with all ports unconnected and the given static
    /// route table; the network builder wires `in_links`/`out_links`.
    pub fn new(cfg: RouterCfg, table: RouteTable) -> Self {
        assert!(
            (1..=MAX_VCS).contains(&cfg.vcs),
            "router vcs must be in 1..={MAX_VCS}, got {}",
            cfg.vcs
        );
        assert!(
            cfg.ports * cfg.vcs <= 32,
            "requester bitmasks pack (input, VC) pairs into a u32"
        );
        let outputs = (0..cfg.ports)
            .map(|_| OutputState {
                locks: [None; MAX_VCS],
                arb: RoundRobin::new(cfg.ports * cfg.vcs),
                forwarded: 0,
            })
            .collect();
        let adaptive = table.is_adaptive();
        let escape_lanes = (table.escape_lanes() as usize).min(cfg.vcs);
        Router {
            in_links: vec![None; cfg.ports],
            out_links: vec![None; cfg.ports],
            table,
            outputs,
            want: vec![None; cfg.ports * cfg.vcs],
            req: vec![0; cfg.ports],
            adaptive,
            escape_lanes,
            plan_vc: vec![None; cfg.ports * cfg.vcs],
            hold: vec![None; cfg.ports * cfg.vcs],
            cfg,
            forwarded: 0,
            active_cycles: 0,
        }
    }

    /// Flits forwarded through a specific output port.
    pub fn forwarded_on(&self, port: usize) -> u64 {
        self.outputs[port].forwarded
    }

    /// The `(input port, input VC)` pair whose packet currently holds
    /// the wormhole lock on lane `vc` of output `port`, if any. A
    /// read-only view for the live wait-for analysis
    /// ([`crate::verify::live`]); the switch itself owns and releases
    /// the lock when the flit marked `last` passes.
    pub fn lock_holder(&self, port: usize, vc: usize) -> Option<(u8, u8)> {
        self.outputs[port].locks[vc]
    }

    /// One cycle, in two explicit phases: **compute** (route lookup on
    /// every input-buffer head, no state changes) and **commit** (switch
    /// allocation honouring wormhole locks, then traversal into the output
    /// links). The split mirrors the deliver/step discipline of the
    /// engine: all routing decisions observe the same pre-cycle state, and
    /// only the commit phase mutates links.
    ///
    /// Returns a [`RouterActivity`] summary for the gated step loop;
    /// dense-mode and unit-test callers are free to ignore it.
    pub fn step<P: LinkPool + ?Sized>(&mut self, links: &mut P) -> RouterActivity {
        if self.compute_requests(links) {
            RouterActivity {
                any_input: true,
                woke_outputs: self.commit_switch(links),
            }
        } else {
            RouterActivity::default()
        }
    }

    /// Compute phase: ensure `want[i * vcs + v] = Some(o)` (and the
    /// matching `req[o]` bit) for every input-lane head flit requesting
    /// output `o`. Only *newly arrived* heads are looked up — a lane
    /// whose memo survives from last cycle (head unpopped) is skipped,
    /// and empty lanes are skipped wholesale via the link's occupied
    /// bitmask. Returns false when every input is empty — the common
    /// case in large meshes, letting `step` exit early.
    fn compute_requests<P: LinkPool + ?Sized>(&mut self, links: &P) -> bool {
        let ports = self.cfg.ports;
        let vcs = self.cfg.vcs;
        if self.adaptive {
            // Un-granted adaptive plans are retracted so every free head
            // re-chooses against *this* cycle's congestion; mid-packet
            // lanes (hold set) keep their committed output. The memo
            // optimisation is deterministic-only — adaptivity's whole
            // point is re-evaluating stalled heads.
            for k in 0..ports * vcs {
                if self.hold[k].is_none() {
                    if let Some(o) = self.want[k] {
                        self.want[k] = None;
                        self.plan_vc[k] = None;
                        self.req[o as usize] &= !(1u32 << k);
                    }
                }
            }
        }
        let mut any_input = false;
        for i in 0..ports {
            let Some(lid) = self.in_links[i] else { continue };
            // Inject/eject links carry one lane regardless of the
            // router's VC count; neighbour links carry `vcs` lanes.
            let in_lanes = links.vcs(lid);
            let nv = in_lanes.min(vcs);
            // Single-lane input links are injection/attach feeds (every
            // router-to-router link carries the full lane complement):
            // their heads are fresh packets, free to choose adaptively.
            let from_router = in_lanes > 1;
            let mut occ = links.occupied_lanes(lid) & ((1u32 << nv) - 1);
            any_input |= occ != 0;
            while occ != 0 {
                let v = occ.trailing_zeros() as usize;
                occ &= occ - 1;
                let k = i * vcs + v;
                if let Some(o) = self.want[k] {
                    // Memo hit: the head was routed when it first
                    // appeared and this router hasn't popped it since
                    // (adaptive mode: a held continuation).
                    debug_assert!(
                        self.adaptive
                            || links.peek_vc(lid, v).map(|f| self.table.lookup(f.header.dst))
                                == Some(o as usize),
                        "memoized route for input {i} lane {v} went stale"
                    );
                    continue;
                }
                let flit = links.peek_vc(lid, v).expect("occupied lane with no head");
                debug_assert_eq!(
                    flit.vc as usize,
                    v,
                    "flit VC sideband diverged from the lane it rides"
                );
                let (o, vo) = if self.adaptive {
                    self.route_adaptive(links, i, v, from_router, flit.header.dst)
                } else {
                    (self.table.lookup(flit.header.dst), 0)
                };
                debug_assert!(o < ports, "route table port out of range");
                debug_assert!(
                    o != i,
                    "loopback disabled: flit at port {i} routed back (dst {:?})",
                    flit.header.dst
                );
                self.want[k] = Some(o as u8);
                self.req[o] |= 1 << k;
                if self.adaptive {
                    self.plan_vc[k] = Some(vo as u8);
                }
            }
        }
        any_input
    }

    /// Adaptive route decision for the head flit on input `i`, lane
    /// `v_in`: returns `(output port, output lane)`. Pure — reads only
    /// this router's own state (table, locks, holds) and its output
    /// links' producer-side credits, all of which are stable for the
    /// whole compute phase in every engine, so the choice is identical
    /// across dense/gated/event and serial/sharded execution.
    fn route_adaptive<P: LinkPool + ?Sized>(
        &self,
        links: &P,
        i: usize,
        v_in: usize,
        from_router: bool,
        dst: NodeId,
    ) -> (usize, usize) {
        let vcs = self.cfg.vcs;
        let esc = self.escape_lanes;
        if let Some((o, vo)) = self.hold[i * vcs + v_in] {
            // Mid-packet: follow the wormhole hold, no choice to make.
            return (o as usize, vo as usize);
        }
        // No re-entry: a head that arrived on an escape lane of a
        // router-to-router link is escape-committed (see the module
        // docs); only fresh injections and adaptive-lane arrivals
        // choose adaptively.
        if !(from_router && v_in < esc) {
            let mut cand = self.table.candidates(dst);
            let mut best: Option<(usize, usize, u32)> = None;
            while cand != 0 {
                let o = cand.trailing_zeros() as usize;
                cand &= cand - 1;
                let Some(out_lid) = self.out_links[o] else { continue };
                let max_v = vcs.min(links.vcs(out_lid));
                let locks = &self.outputs[o].locks;
                // Congestion score: admissible adaptive lanes (unlocked
                // with credit). The lowest admissible lane is the plan.
                let mut score = 0u32;
                let mut lane = None;
                for vo in esc..max_v {
                    if locks[vo].is_none() && links.can_offer_vc(out_lid, vo) {
                        score += 1;
                        if lane.is_none() {
                            lane = Some(vo);
                        }
                    }
                }
                if let Some(vo) = lane {
                    // Strictly-greater replacement: ties stay with the
                    // lowest candidate port (deterministic).
                    let better = match best {
                        None => true,
                        Some((_, _, s)) => score > s,
                    };
                    if better {
                        best = Some((o, vo, score));
                    }
                }
            }
            if let Some((o, vo, _)) = best {
                return (o, vo);
            }
        }
        // Escape: the deterministic baseline. A committed head keeps
        // its lane history (`v_in`); a head *entering* escape here is
        // lane-equivalent to an injection at this router (`vc_in = 0`).
        let o = self.table.lookup(dst);
        let out_lid = self.out_links[o].expect("escape route exits an unconnected port");
        let out_vcs = links.vcs(out_lid);
        let vc_eff = if from_router && v_in < esc { v_in as u8 } else { 0 };
        let vo = (dateline_vc(i, o, self.table.crosses_dateline(o), vc_eff) as usize)
            .min(out_vcs - 1);
        (o, vo)
    }

    /// Commit phase: one winner per output port (the physical channel
    /// carries one flit per cycle, whatever the VC count), wormhole
    /// locks honoured per output VC, round-robin arbitration over
    /// `(input, VC)` pairs otherwise; winners traverse into their output
    /// links on the lane the dateline rule assigns. Returns the bitmask
    /// of output ports that accepted a flit (the gated loop's
    /// router→output-link wake edges).
    fn commit_switch<P: LinkPool + ?Sized>(&mut self, links: &mut P) -> u32 {
        let ports = self.cfg.ports;
        let vcs = self.cfg.vcs;
        let mut woke: u32 = 0;
        let mut any = false;
        // Lanes of every input *port* granted a traversal this cycle:
        // one physical path into the crossbar per port, whatever lane
        // won, so a granted port's whole lane group is masked out of
        // later outputs' request sets (the pre-memo switch cleared the
        // port's scratch entries to the same effect).
        let mut used_lanes: u32 = 0;
        for o in 0..ports {
            let Some(out_lid) = self.out_links[o] else { continue };
            // Requesters still eligible this cycle; an output nobody
            // wants costs one AND and a branch, not an arbiter probe.
            let avail = self.req[o] & !used_lanes;
            if avail == 0 {
                continue;
            }
            let out_vcs = links.vcs(out_lid);
            let wrap = self.table.crosses_dateline(o);
            // The output lane a traversal (input i, input VC v) lands
            // on: the dateline rule, capped to the link's lane count
            // (eject links carry one lane; so does every link of a 1-VC
            // configuration, which keeps wrap fabrics at vcs = 1 in the
            // documented pre-VC danger regime rather than panicking).
            let ovc =
                |i: usize, v: usize| (dateline_vc(i, o, wrap, v as u8) as usize).min(out_vcs - 1);
            // Locks are copied out so the arbitration closure below can
            // read them while the arbiter is mutably borrowed (a small
            // Copy array, no allocation).
            let locks = self.outputs[o].locks;
            // Tier 1 — wormhole continuations: a locked output lane
            // whose packet has its next flit waiting continues first
            // (lowest lane wins ties; bounded unfairness, released at
            // the packet's `last` flit). If the locked lane's next flit
            // hasn't arrived, or its lane is backpressured, the lane
            // idles but stays locked (no interleaving, as in RTL).
            let mut winner: Option<(usize, usize, usize)> = None;
            for (v_out, lock) in locks.iter().enumerate().take(out_vcs) {
                let Some((li, lv)) = *lock else { continue };
                let (li, lv) = (li as usize, lv as usize);
                let k = li * vcs + lv;
                // Mid-packet, the locked input lane's head (when
                // present) must still target the locked output — its
                // packet's remaining flits are the only thing it may
                // send. A divergent head would deadlock the output lane
                // silently; fail loudly instead.
                debug_assert!(
                    self.want[k].is_none() || self.want[k] == Some(o as u8),
                    "locked input {li} (vc {lv}) head diverged from output {o} mid-packet"
                );
                debug_assert!(
                    if self.adaptive {
                        self.hold[k] == Some((o as u8, v_out as u8))
                    } else {
                        ovc(li, lv) == v_out
                    },
                    "lock lane disagrees with the planned/dateline lane"
                );
                if (avail >> k) & 1 == 1 && links.can_offer_vc(out_lid, v_out) {
                    winner = Some((li, lv, v_out));
                    break;
                }
            }
            // Tier 2 — free lanes: round-robin over the set bits of the
            // eligible-requester mask (membership already encodes
            // "wants this output and port unused this cycle"); the
            // accept gate keeps only the lock and credit checks. The
            // arbiter's rotation only advances when it actually grants,
            // exactly as the pre-VC router never advanced it while an
            // output was locked or backpressured.
            if winner.is_none() {
                let pool = &*links;
                let adaptive = self.adaptive;
                // Disjoint field borrows: the closure reads the compute
                // phase's planned lanes while the arbiter is mutably
                // borrowed from the same struct.
                let plan_vc = &self.plan_vc;
                let lane_of = |k: usize| {
                    if adaptive {
                        plan_vc[k].expect("adaptive requester without a planned lane") as usize
                    } else {
                        ovc(k / vcs, k % vcs)
                    }
                };
                let arb = &mut self.outputs[o].arb;
                let grant = arb.arbitrate_mask(avail, |k| {
                    let v_out = lane_of(k);
                    locks[v_out].is_none() && pool.can_offer_vc(out_lid, v_out)
                });
                winner = grant.map(|k| (k / vcs, k % vcs, lane_of(k)));
            }
            let Some((i, v_in, v_out)) = winner else { continue };
            let in_lid = self.in_links[i].unwrap();
            let mut flit = links.pop_vc(in_lid, v_in).unwrap();
            // The pop retires the lane's head: invalidate its memo (the
            // next head, if any, is routed on the next compute pass) and
            // retire its request bit — a lane requests exactly one
            // output, so clearing `req[o]` covers it.
            self.want[i * vcs + v_in] = None;
            self.req[o] &= !(1 << (i * vcs + v_in));
            // An input *port* feeds at most one output per cycle (one
            // physical path into the crossbar), whatever lane won.
            used_lanes |= ((1u32 << vcs) - 1) << (i * vcs);
            self.outputs[o].locks[v_out] = if flit.header.last {
                None
            } else {
                Some((i as u8, v_in as u8))
            };
            if self.adaptive {
                let k = i * vcs + v_in;
                self.plan_vc[k] = None;
                // Mid-packet lanes remember their committed (output,
                // lane) so continuation flits bypass the adaptive
                // choice; the `last` flit clears the hold.
                self.hold[k] = if flit.header.last {
                    None
                } else {
                    Some((o as u8, v_out as u8))
                };
            }
            flit.vc = v_out as u8;
            links.offer_vc(out_lid, v_out, flit);
            self.outputs[o].forwarded += 1;
            self.forwarded += 1;
            woke |= 1 << o;
            any = true;
        }
        if any {
            self.active_cycles += 1;
        }
        woke
    }

    /// True when all input buffers this router reads from are empty (on
    /// every VC lane) and no output lane is mid-packet.
    pub fn is_idle<P: LinkPool + ?Sized>(&self, links: &P) -> bool {
        self.outputs
            .iter()
            .all(|o| o.locks.iter().all(Option::is_none))
            && self
                .in_links
                .iter()
                .flatten()
                .all(|&lid| links.buffered(lid) == 0)
    }

    /// Clock-gating predicate: true when stepping this router would be a
    /// no-op — every input buffer it reads from is empty. Wormhole locks
    /// are deliberately ignored: a locked output with no pending input
    /// flit idles (and stays locked) whether or not the router is
    /// stepped, so a lock alone never requires a clock. The gated loop
    /// wakes a router the cycle any of its input links delivers a flit.
    pub fn is_quiescent<P: LinkPool + ?Sized>(&self, links: &P) -> bool {
        self.in_links
            .iter()
            .flatten()
            .all(|&lid| links.buffered(lid) == 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::axi::{AxReq, Burst, Resp, RBeat};
    use crate::flit::{Header, NodeId, Payload};

    fn flit(dst: u16, last: bool, tag: u32) -> FlooFlit {
        FlooFlit {
            header: Header {
                dst: NodeId(dst),
                src: NodeId(0),
                rob_idx: tag,
                rob_req: true,
                atomic: false,
                last,
            },
            payload: Payload::NarrowAr(AxReq {
                id: 0,
                addr: 0,
                len: 0,
                size: 3,
                burst: Burst::Incr,
                atop: false,
            }),
            injected_at: 0,
            vc: 0,
        }
    }

    fn rflit(dst: u16, beat: u32, last: bool) -> FlooFlit {
        FlooFlit {
            header: Header {
                dst: NodeId(dst),
                src: NodeId(0),
                rob_idx: 0,
                rob_req: true,
                atomic: false,
                last,
            },
            payload: Payload::WideR(RBeat {
                id: 0,
                beat,
                last,
                resp: Resp::Okay,
            }),
            injected_at: 0,
            vc: 0,
        }
    }

    /// Build a 3-port router with dedicated in/out links.
    /// dst 0 -> port 0, dst 1 -> port 1, dst 2 -> port 2.
    fn mini() -> (Router, Vec<Link<FlooFlit>>) {
        let mut links: Vec<Link<FlooFlit>> = (0..6).map(|_| Link::new(2)).collect();
        let _ = &mut links;
        let mut r = Router::new(
            RouterCfg {
                ports: 3,
                in_buf_depth: 2,
                vcs: 1,
            },
            RouteTable::new(vec![0, 1, 2]),
        );
        for p in 0..3 {
            r.in_links[p] = Some(p);
            r.out_links[p] = Some(3 + p);
        }
        (r, links)
    }

    /// A 5-port, 2-VC router with 2-lane links on every port (in links
    /// 0..5, out links 5..10) and real cardinal port numbering, so the
    /// dateline rule sees genuine dimensions. dst 0 -> PORT_LOCAL,
    /// dst 1 -> PORT_E, dst 2 -> PORT_N; `wrap_e` marks PORT_E as a
    /// dateline port.
    fn mini_vc(wrap_e: bool) -> (Router, Vec<Link<FlooFlit>>) {
        let links: Vec<Link<FlooFlit>> = (0..10).map(|_| Link::with_vcs(4, 2, 0)).collect();
        let mask = if wrap_e { 1 << PORT_E } else { 0 };
        let mut r = Router::new(
            RouterCfg {
                ports: 5,
                in_buf_depth: 4,
                vcs: 2,
            },
            RouteTable::with_dateline(vec![PORT_LOCAL as u8, PORT_E as u8, PORT_N as u8], mask),
        );
        for p in 0..5 {
            r.in_links[p] = Some(p);
            r.out_links[p] = Some(5 + p);
        }
        (r, links)
    }

    /// A flit riding an explicit VC lane (the caller offers it on the
    /// matching lane of the input link).
    fn flit_vc(dst: u16, last: bool, tag: u32, vc: u8) -> FlooFlit {
        let mut f = flit(dst, last, tag);
        f.vc = vc;
        f
    }

    fn deliver_all(links: &mut [Link<FlooFlit>]) {
        for l in links {
            l.deliver();
        }
    }

    #[test]
    fn single_cycle_forwarding() {
        let (mut r, mut links) = mini();
        links[0].offer(flit(1, true, 7));
        deliver_all(&mut links); // flit reaches input buffer
        r.step(&mut links); // forwarded to out link 4 (port 1)
        deliver_all(&mut links);
        let got = links[4].pop().unwrap();
        assert_eq!(got.header.rob_idx, 7);
        assert_eq!(r.forwarded, 1);
    }

    #[test]
    fn wormhole_locks_output_until_last() {
        let (mut r, mut links) = mini();
        // Input 0: 2-beat packet to dst 2. Input 1: single flit to dst 2.
        links[0].offer(rflit(2, 0, false));
        links[1].offer(flit(2, true, 99));
        deliver_all(&mut links);
        r.step(&mut links); // winner starts packet, output 2 locks
        deliver_all(&mut links);
        let first = links[5].pop().unwrap();
        // Offer second beat from the same input that won.
        let winner_was_0 = matches!(first.payload, Payload::WideR(_));
        if winner_was_0 {
            links[0].offer(rflit(2, 1, true));
        } else {
            // rr picked input 1's single flit; nothing to continue. Not the
            // scenario under test; force the deterministic case instead.
            panic!("expected input 0 to win first rr grant");
        }
        deliver_all(&mut links);
        r.step(&mut links);
        deliver_all(&mut links);
        let second = links[5].pop().unwrap();
        assert!(
            matches!(second.payload, Payload::WideR(RBeat { beat: 1, .. })),
            "locked output must continue the packet, not interleave: {second:?}"
        );
        // Now the lock is released; the waiting flit goes through.
        r.step(&mut links);
        deliver_all(&mut links);
        assert_eq!(links[5].pop().unwrap().header.rob_idx, 99);
    }

    #[test]
    fn backpressure_holds_flit() {
        let (mut r, mut links) = mini();
        // Fill output 1's downstream buffer (depth 2) + register.
        links[0].offer(flit(1, true, 1));
        deliver_all(&mut links);
        r.step(&mut links);
        links[0].offer(flit(1, true, 2));
        deliver_all(&mut links);
        r.step(&mut links);
        links[0].offer(flit(1, true, 3));
        deliver_all(&mut links);
        r.step(&mut links);
        // out link 4 now: buf [1,2] + reg 3 -> full.
        links[0].offer(flit(1, true, 4));
        deliver_all(&mut links);
        let before = r.forwarded;
        r.step(&mut links); // cannot offer: register busy
        assert_eq!(r.forwarded, before, "no forward under backpressure");
        // Drain one and try again.
        assert_eq!(links[4].pop().unwrap().header.rob_idx, 1);
        deliver_all(&mut links); // reg 3 -> buf
        r.step(&mut links); // 4 forwards into reg
        assert_eq!(r.forwarded, before + 1);
    }

    #[test]
    fn parallel_disjoint_transfers_same_cycle() {
        let (mut r, mut links) = mini();
        links[0].offer(flit(1, true, 10));
        links[1].offer(flit(2, true, 20));
        deliver_all(&mut links);
        r.step(&mut links);
        deliver_all(&mut links);
        assert_eq!(links[4].pop().unwrap().header.rob_idx, 10);
        assert_eq!(links[5].pop().unwrap().header.rob_idx, 20);
        assert_eq!(r.forwarded, 2, "crossbar moves disjoint pairs in parallel");
    }

    #[test]
    fn contention_resolved_round_robin() {
        let (mut r, mut links) = mini();
        // Both inputs target output 2 with single-flit packets repeatedly.
        let mut order = Vec::new();
        for round in 0..4 {
            links[0].offer(flit(2, true, 100 + round));
            links[1].offer(flit(2, true, 200 + round));
            deliver_all(&mut links);
            r.step(&mut links);
            deliver_all(&mut links);
            order.push(links[5].pop().unwrap().header.rob_idx / 100);
            // Second one goes through next cycle.
            r.step(&mut links);
            deliver_all(&mut links);
            order.push(links[5].pop().unwrap().header.rob_idx / 100);
        }
        // Fair alternation: each round serves both, rotating priority.
        let ones = order.iter().filter(|&&x| x == 1).count();
        let twos = order.iter().filter(|&&x| x == 2).count();
        assert_eq!(ones, 4);
        assert_eq!(twos, 4);
    }

    #[test]
    fn idle_detection() {
        let (mut r, mut links) = mini();
        assert!(r.is_idle(&links));
        links[0].offer(flit(1, true, 1));
        deliver_all(&mut links);
        assert!(!r.is_idle(&links));
        r.step(&mut links);
        assert!(r.is_idle(&links));
    }

    // --------------------------------------------- virtual channels

    /// A flit leaving through a dateline (wrap) port switches VC 0 → 1
    /// and rides lane 1 of the output link.
    #[test]
    fn dateline_switch_on_wrap_port() {
        let (mut r, mut links) = mini_vc(true);
        links[PORT_W].offer_vc(0, flit_vc(1, true, 7, 0));
        deliver_all(&mut links);
        r.step(&mut links);
        deliver_all(&mut links);
        let east = 5 + PORT_E;
        assert_eq!(links[east].peek_vc(0), None, "wrap traffic must leave VC 0");
        let got = links[east].pop_vc(1).unwrap();
        assert_eq!((got.header.rob_idx, got.vc), (7, 1));
    }

    /// In-dimension hops keep the VC; the dimension-ordered X→Y turn
    /// resets to VC 0.
    #[test]
    fn vc_kept_in_dimension_and_reset_on_turn() {
        let (mut r, mut links) = mini_vc(false);
        // VC 1 flit continuing east (W → E, same dimension, no wrap).
        links[PORT_W].offer_vc(1, flit_vc(1, true, 21, 1));
        deliver_all(&mut links);
        r.step(&mut links);
        deliver_all(&mut links);
        let east = links[5 + PORT_E].pop_vc(1).unwrap();
        assert_eq!((east.header.rob_idx, east.vc), (21, 1), "same dimension keeps VC");
        // VC 1 flit turning north (W → N: dimension change).
        links[PORT_W].offer_vc(1, flit_vc(2, true, 22, 1));
        deliver_all(&mut links);
        r.step(&mut links);
        deliver_all(&mut links);
        let north = links[5 + PORT_N].pop_vc(0).unwrap();
        assert_eq!((north.header.rob_idx, north.vc), (22, 0), "X→Y turn resets to VC 0");
    }

    /// The property VCs exist for: a wormhole packet stalled mid-stream
    /// on VC 0 holds only its own lane — VC 1 traffic crosses the same
    /// physical output meanwhile, and the VC 0 lock still excludes
    /// competing VC 0 packets until the locked packet's `last` beat.
    #[test]
    fn vc1_bypasses_stalled_vc0_wormhole() {
        let (mut r, mut links) = mini_vc(false);
        let east = 5 + PORT_E;
        // Beat 0 of a 2-beat VC 0 packet from input S locks (E, VC 0).
        links[PORT_S].offer_vc(0, rflit(1, 0, false));
        deliver_all(&mut links);
        r.step(&mut links);
        deliver_all(&mut links);
        assert!(matches!(links[east].pop_vc(0).unwrap().payload, Payload::WideR(_)));
        // The packet stalls (beat 1 not produced yet). A VC 1 single-flit
        // packet from input W crosses the same physical output meanwhile.
        links[PORT_W].offer_vc(1, flit_vc(1, true, 99, 1));
        deliver_all(&mut links);
        r.step(&mut links);
        deliver_all(&mut links);
        assert_eq!(
            links[east].pop_vc(1).unwrap().header.rob_idx,
            99,
            "VC 1 must pass a wormhole-locked, stalled VC 0 output"
        );
        // The VC 0 lock still holds: a competing VC 0 flit waits for the
        // locked packet's last beat, then goes.
        links[PORT_W].offer_vc(0, flit_vc(1, true, 50, 0));
        links[PORT_S].offer_vc(0, rflit(1, 1, true));
        deliver_all(&mut links);
        r.step(&mut links); // locked continuation wins the output
        deliver_all(&mut links);
        assert!(matches!(
            links[east].pop_vc(0).unwrap().payload,
            Payload::WideR(RBeat { beat: 1, .. })
        ));
        r.step(&mut links); // lock released: the waiting VC 0 flit goes
        deliver_all(&mut links);
        assert_eq!(links[east].pop_vc(0).unwrap().header.rob_idx, 50);
    }

    /// VCs multiply stall isolation, not bandwidth: two ready candidates
    /// on different lanes of the same output still cross one per cycle.
    #[test]
    fn one_traversal_per_output_per_cycle_across_vcs() {
        let (mut r, mut links) = mini_vc(false);
        links[PORT_S].offer_vc(0, flit_vc(1, true, 1, 0));
        links[PORT_W].offer_vc(1, flit_vc(1, true, 2, 1));
        deliver_all(&mut links);
        r.step(&mut links);
        deliver_all(&mut links);
        assert_eq!(r.forwarded, 1, "one flit per output port per cycle");
        r.step(&mut links);
        deliver_all(&mut links);
        assert_eq!(r.forwarded, 2);
        assert_eq!(links[5 + PORT_E].buffered(), 2, "both arrived, one per cycle");
    }

    /// A single-lane output link (ejection, or a 1-VC fabric) caps the
    /// dateline switch to the only lane instead of panicking.
    #[test]
    fn single_lane_output_caps_dateline_vc() {
        let (mut r, mut links) = mini_vc(true);
        links[5 + PORT_E] = Link::new(2); // 1-lane output despite vcs = 2
        links[PORT_W].offer_vc(0, flit_vc(1, true, 8, 0));
        deliver_all(&mut links);
        r.step(&mut links);
        deliver_all(&mut links);
        let f = links[5 + PORT_E].pop().unwrap();
        assert_eq!((f.header.rob_idx, f.vc), (8, 0), "capped to the only lane");
    }

    /// Three inputs contending for one output, grant order pinned as a
    /// literal: the per-output round-robin pointer must visit requester
    /// slots (LOCAL = 0, S = 6, W = 8 at `vcs = 2`) in rotation and
    /// advance only on grants, wrapping past slot 9 back to LOCAL. A
    /// bitmask-walk or memo-invalidation bug fails here with a readable
    /// diff instead of only tripping the whole-system digest suites.
    #[test]
    fn three_input_contention_grant_order_pinned() {
        let (mut r, mut links) = mini_vc(false);
        let east = 5 + PORT_E;
        let mut order = Vec::new();
        for batch in 0..3u32 {
            for (src, tag) in [(PORT_LOCAL, 100), (PORT_S, 300), (PORT_W, 400)] {
                links[src].offer_vc(0, flit_vc(1, true, tag + batch, 0));
            }
            deliver_all(&mut links);
            for _ in 0..3 {
                r.step(&mut links);
                deliver_all(&mut links);
                order.push(links[east].pop_vc(0).unwrap().header.rob_idx / 100);
            }
        }
        assert_eq!(order, vec![1, 3, 4, 1, 3, 4, 1, 3, 4]);
        assert_eq!(r.forwarded_on(PORT_E), 9);
    }

    // --------------------------------------------- adaptive routing

    /// A 5-port, 3-VC adaptive router: escape lane 0 plus adaptive
    /// lanes 1–2. Injection/ejection links (LOCAL) carry one lane;
    /// cardinal links carry 3 lanes with a depth-1 buffer so a lane is
    /// persistently blocked by two offers around one deliver
    /// (`block_lane`). dst 0 ejects locally; dst 1 has candidates
    /// {N, E} with escape step E; dst 2 routes N only.
    fn mini_adaptive() -> (Router, Vec<Link<FlooFlit>>) {
        let links: Vec<Link<FlooFlit>> = (0..10)
            .map(|p| {
                if p % 5 == PORT_LOCAL {
                    Link::new(4)
                } else {
                    Link::with_vcs(1, 3, 0)
                }
            })
            .collect();
        let mut r = Router::new(
            RouterCfg {
                ports: 5,
                in_buf_depth: 4,
                vcs: 3,
            },
            RouteTable::with_candidates(
                vec![PORT_LOCAL as u8, PORT_E as u8, PORT_N as u8],
                0,
                vec![1 << PORT_LOCAL, (1 << PORT_E) | (1 << PORT_N), 1 << PORT_N],
                1,
            ),
        );
        for p in 0..5 {
            r.in_links[p] = Some(p);
            r.out_links[p] = Some(5 + p);
        }
        (r, links)
    }

    /// Make lane `vc` of link `lid` refuse offers indefinitely: fill
    /// the depth-1 buffer and the register with junk.
    fn block_lane(links: &mut [Link<FlooFlit>], lid: usize, vc: usize) {
        links[lid].offer_vc(vc, flit_vc(0, true, 0, vc as u8));
        links[lid].deliver();
        links[lid].offer_vc(vc, flit_vc(0, true, 0, vc as u8));
        assert!(!links[lid].can_offer_vc(vc));
    }

    /// Equal congestion on both candidates resolves to the lowest
    /// candidate port (N) on the lowest adaptive lane — the
    /// deterministic tie-break the digest suites depend on.
    #[test]
    fn adaptive_tie_resolves_to_lowest_candidate_port() {
        let (mut r, mut links) = mini_adaptive();
        links[PORT_LOCAL].offer(flit(1, true, 9));
        deliver_all(&mut links);
        r.step(&mut links);
        deliver_all(&mut links);
        let f = links[5 + PORT_N].pop_vc(1).unwrap();
        assert_eq!((f.header.rob_idx, f.vc), (9, 1));
    }

    /// Congestion steers the choice: with N's adaptive lanes blocked, a
    /// fresh head takes E even though N wins the uncongested tie.
    #[test]
    fn adaptive_head_picks_least_congested_candidate() {
        let (mut r, mut links) = mini_adaptive();
        let north = 5 + PORT_N;
        block_lane(&mut links, north, 1);
        block_lane(&mut links, north, 2);
        links[PORT_LOCAL].offer(flit(1, true, 7));
        deliver_all(&mut links);
        r.step(&mut links);
        deliver_all(&mut links);
        let f = links[5 + PORT_E].pop_vc(1).unwrap();
        assert_eq!((f.header.rob_idx, f.vc), (7, 1), "freer port, lowest adaptive lane");
    }

    /// With every adaptive lane of every candidate blocked, the head
    /// falls back to the escape route: the deterministic step on lane 0.
    #[test]
    fn escape_fallback_when_all_adaptive_lanes_blocked() {
        let (mut r, mut links) = mini_adaptive();
        for lid in [5 + PORT_E, 5 + PORT_N] {
            block_lane(&mut links, lid, 1);
            block_lane(&mut links, lid, 2);
        }
        links[PORT_LOCAL].offer(flit(1, true, 11));
        deliver_all(&mut links);
        r.step(&mut links);
        deliver_all(&mut links);
        let f = links[5 + PORT_E].pop_vc(0).unwrap();
        assert_eq!((f.header.rob_idx, f.vc), (11, 0), "escape = deterministic step, lane 0");
    }

    /// The Duato no-re-entry rule: a head arriving on the escape lane
    /// of a router-to-router link is committed to the deterministic
    /// route — it never climbs back onto adaptive lanes. An
    /// adaptive-lane arrival keeps choosing freely.
    #[test]
    fn escape_lane_arrival_is_committed_to_the_deterministic_route() {
        let (mut r, mut links) = mini_adaptive();
        links[PORT_W].offer_vc(0, flit_vc(1, true, 21, 0));
        deliver_all(&mut links);
        r.step(&mut links);
        deliver_all(&mut links);
        let f = links[5 + PORT_E].pop_vc(0).unwrap();
        assert_eq!((f.header.rob_idx, f.vc), (21, 0));
        assert_eq!(links[5 + PORT_N].buffered(), 0, "no adaptive hop for a committed head");
        // Same source link, adaptive lane: free choice (N wins the tie).
        links[PORT_W].offer_vc(2, flit_vc(1, true, 22, 2));
        deliver_all(&mut links);
        r.step(&mut links);
        deliver_all(&mut links);
        let f = links[5 + PORT_N].pop_vc(1).unwrap();
        assert_eq!((f.header.rob_idx, f.vc), (22, 1), "adaptive arrival re-chooses");
    }

    /// Wormhole commitment under adaptivity: a mid-packet lane follows
    /// its hold even when congestion has since made another candidate
    /// more attractive; the `last` beat releases the hold and the next
    /// packet chooses freshly.
    #[test]
    fn hold_pins_a_wormhole_packet_through_congestion_changes() {
        let (mut r, mut links) = mini_adaptive();
        let north = 5 + PORT_N;
        // Beat 0 of a 2-beat packet: the uncongested tie picks N lane 1.
        links[PORT_LOCAL].offer(rflit(1, 0, false));
        deliver_all(&mut links);
        r.step(&mut links);
        deliver_all(&mut links);
        assert!(matches!(links[north].pop_vc(1).unwrap().payload, Payload::WideR(_)));
        // Congestion flips (N down to one free adaptive lane, E has
        // two): a fresh head would pick E, the continuation must not.
        block_lane(&mut links, north, 2);
        links[PORT_LOCAL].offer(rflit(1, 1, true));
        deliver_all(&mut links);
        r.step(&mut links);
        deliver_all(&mut links);
        let f = links[north].pop_vc(1).unwrap();
        assert!(matches!(f.payload, Payload::WideR(RBeat { beat: 1, .. })));
        assert_eq!(f.vc, 1, "continuation rides the held lane");
        // Hold and lock released at `last`: the next packet re-chooses
        // and lands on the freer port.
        links[PORT_LOCAL].offer(flit(1, true, 33));
        deliver_all(&mut links);
        r.step(&mut links);
        deliver_all(&mut links);
        assert_eq!(links[5 + PORT_E].pop_vc(1).unwrap().header.rob_idx, 33);
    }

    /// Ejection (a non-cardinal output) resets the VC to 0 — flits hand
    /// their dateline history back before reaching the NI.
    #[test]
    fn ejection_resets_vc() {
        let (mut r, mut links) = mini_vc(false);
        links[PORT_E].offer_vc(1, flit_vc(0, true, 3, 1));
        deliver_all(&mut links);
        r.step(&mut links);
        deliver_all(&mut links);
        let f = links[5 + PORT_LOCAL].pop_vc(0).unwrap();
        assert_eq!((f.header.rob_idx, f.vc), (3, 0));
    }
}
