//! Round-robin arbiter, as used for switch allocation in the router.

/// Rotating-priority arbiter over `n` requesters.
#[derive(Debug, Clone)]
pub struct RoundRobin {
    n: usize,
    /// Index that has highest priority next arbitration.
    next: usize,
}

impl RoundRobin {
    /// An `n`-requestor arbiter; index 0 wins the first tie.
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        RoundRobin { n, next: 0 }
    }

    /// Grant among `requests` (true = requesting). The winner becomes the
    /// lowest-priority requester for the next round.
    pub fn arbitrate(&mut self, requests: &[bool]) -> Option<usize> {
        debug_assert_eq!(requests.len(), self.n);
        self.arbitrate_with(|i| requests[i])
    }

    /// Allocation-free variant: `requesting(i)` answers whether requester
    /// `i` wants a grant this round (the simulator's hot path).
    #[inline]
    pub fn arbitrate_with<F: Fn(usize) -> bool>(&mut self, requesting: F) -> Option<usize> {
        for off in 0..self.n {
            let i = (self.next + off) % self.n;
            if requesting(i) {
                self.next = (i + 1) % self.n;
                return Some(i);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grants_rotate_fairly() {
        let mut a = RoundRobin::new(3);
        let all = [true, true, true];
        let seq: Vec<_> = (0..6).map(|_| a.arbitrate(&all).unwrap()).collect();
        assert_eq!(seq, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn skips_idle_requesters() {
        let mut a = RoundRobin::new(4);
        assert_eq!(a.arbitrate(&[false, false, true, false]), Some(2));
        // Priority moved past 2.
        assert_eq!(a.arbitrate(&[true, false, true, false]), Some(0));
    }

    #[test]
    fn none_when_no_requests() {
        let mut a = RoundRobin::new(2);
        assert_eq!(a.arbitrate(&[false, false]), None);
    }

    #[test]
    fn no_starvation_under_contention() {
        let mut a = RoundRobin::new(4);
        let mut grants = [0u32; 4];
        for _ in 0..400 {
            let g = a.arbitrate(&[true, true, true, true]).unwrap();
            grants[g] += 1;
        }
        assert_eq!(grants, [100, 100, 100, 100]);
    }
}
