//! Round-robin arbiter, as used for switch allocation in the router.

/// Rotating-priority arbiter over `n` requesters.
#[derive(Debug, Clone)]
pub struct RoundRobin {
    n: usize,
    /// Index that has highest priority next arbitration.
    next: usize,
}

impl RoundRobin {
    /// An `n`-requestor arbiter; index 0 wins the first tie.
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        debug_assert!(n <= 32, "arbitrate_mask packs requesters into a u32");
        RoundRobin { n, next: 0 }
    }

    /// Grant among `requests` (true = requesting). The winner becomes the
    /// lowest-priority requester for the next round.
    pub fn arbitrate(&mut self, requests: &[bool]) -> Option<usize> {
        debug_assert_eq!(requests.len(), self.n);
        self.arbitrate_with(|i| requests[i])
    }

    /// Allocation-free variant: `requesting(i)` answers whether requester
    /// `i` wants a grant this round (the simulator's hot path).
    #[inline]
    pub fn arbitrate_with<F: Fn(usize) -> bool>(&mut self, requesting: F) -> Option<usize> {
        for off in 0..self.n {
            let i = (self.next + off) % self.n;
            if requesting(i) {
                self.next = (i + 1) % self.n;
                return Some(i);
            }
        }
        None
    }

    /// Bitmask variant of [`arbitrate_with`](Self::arbitrate_with):
    /// requesters are the set bits of `mask` (bit `i` ⇔ requester `i`),
    /// and `accept(i)` applies any further per-requester gate (e.g.
    /// credit checks). Probes only set bits — in the exact order the
    /// linear scan would visit them: set bits at or above the priority
    /// pointer ascending, then set bits below it ascending — and
    /// advances the pointer only on a grant, so the grant sequence is
    /// identical to `arbitrate_with` restricted to `mask`.
    #[inline]
    pub fn arbitrate_mask<F: Fn(usize) -> bool>(&mut self, mask: u32, accept: F) -> Option<usize> {
        if mask == 0 {
            return None;
        }
        // `next < n <= 32`; `next == 0` makes `hi` the whole mask and
        // the low part empty, matching a scan that starts at bit 0.
        let hi = if self.next == 0 { mask } else { mask & (u32::MAX << self.next) };
        for part in [hi, mask & !hi] {
            let mut m = part;
            while m != 0 {
                let i = m.trailing_zeros() as usize;
                if accept(i) {
                    self.next = (i + 1) % self.n;
                    return Some(i);
                }
                m &= m - 1;
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grants_rotate_fairly() {
        let mut a = RoundRobin::new(3);
        let all = [true, true, true];
        let seq: Vec<_> = (0..6).map(|_| a.arbitrate(&all).unwrap()).collect();
        assert_eq!(seq, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn skips_idle_requesters() {
        let mut a = RoundRobin::new(4);
        assert_eq!(a.arbitrate(&[false, false, true, false]), Some(2));
        // Priority moved past 2.
        assert_eq!(a.arbitrate(&[true, false, true, false]), Some(0));
    }

    #[test]
    fn none_when_no_requests() {
        let mut a = RoundRobin::new(2);
        assert_eq!(a.arbitrate(&[false, false]), None);
    }

    #[test]
    fn no_starvation_under_contention() {
        let mut a = RoundRobin::new(4);
        let mut grants = [0u32; 4];
        for _ in 0..400 {
            let g = a.arbitrate(&[true, true, true, true]).unwrap();
            grants[g] += 1;
        }
        assert_eq!(grants, [100, 100, 100, 100]);
    }

    #[test]
    fn mask_matches_linear_probe_grant_for_grant() {
        // Twin arbiters over the same request sequence: the bitmask
        // walk must reproduce the linear probe's grants exactly,
        // including the advance-only-on-grant pointer rule.
        let mut linear = RoundRobin::new(10);
        let mut masked = RoundRobin::new(10);
        let rounds: [u32; 8] = [
            0b00_0100_0101, // {0, 2, 6}
            0b00_0100_0101,
            0b10_0000_0001, // {0, 9} — wraps past the pointer
            0b00_0000_0000, // no requests: pointer must not move
            0b10_0000_0001,
            0b01_1000_0000, // {7, 8}
            0b00_0000_0010, // {1} — far below the pointer
            0b11_1111_1111, // everyone
        ];
        let mut got = Vec::new();
        for mask in rounds {
            let a = linear.arbitrate_with(|i| mask & (1 << i) != 0);
            let b = masked.arbitrate_mask(mask, |_| true);
            assert_eq!(a, b, "twin arbiters diverged on mask {mask:#b}");
            got.push(a);
        }
        assert_eq!(
            got,
            vec![
                Some(0),
                Some(2),
                Some(9),
                None,
                Some(0),
                Some(7),
                Some(1),
                Some(2),
            ]
        );
    }

    #[test]
    fn mask_respects_accept_gate() {
        // A set bit whose accept() says no must be skipped without
        // advancing the pointer past it.
        let mut a = RoundRobin::new(6);
        assert_eq!(a.arbitrate_mask(0b000110, |i| i != 1), Some(2));
        // Pointer now at 3; 1 requests again and is accepted.
        assert_eq!(a.arbitrate_mask(0b000010, |_| true), Some(1));
        // Everything refused: no grant, pointer stays at 2.
        assert_eq!(a.arbitrate_mask(0b111111, |_| false), None);
        assert_eq!(a.arbitrate_mask(0b111111, |_| true), Some(2));
    }
}
