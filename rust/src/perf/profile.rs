//! Per-phase wall-time profiler for the saturated hot path
//! (`repro bench --profile`; JSON schema `floonoc-profile/1`).
//!
//! The e2e bench ([`super::run_e2e`]) answers "how fast is a cycle";
//! this module answers "where does a cycle's time go". One saturated
//! gated run is stepped through [`NocSystem`]'s phase helpers with a
//! timestamp between each, attributing wall time to:
//!
//! * **link_deliver** — every network's link sweep (active-set walk +
//!   [`crate::sim::Link::deliver`] per occupied link);
//! * **router_sweep** — every network's router sweep (route compute +
//!   switch allocation + commit);
//! * **ni** — NI termination/injection plus the clock advance;
//! * **generators** — the harness generator pass (traffic issue);
//! * **gating_overhead** — the pre-step bookkeeping (event-mode
//!   fast-forward check, cycle accounting) plus the residual between
//!   the whole run's wall time and the sum of the timed phases — i.e.
//!   the loop and timestamping cost the profiler itself adds. The
//!   active-set word scans *inside* the sweeps are deliberately charged
//!   to their sweep: they are inseparable from the work they gate.
//!
//! Shares therefore sum to exactly 1.0 by construction. Caveat: each
//! profiled cycle takes five `Instant::now()` calls (tens of
//! nanoseconds each), so on very small fabrics the `gating_overhead`
//! bucket can be a visible fraction — compare shares, and compare cps
//! against the untimed bench figures, not across fabric sizes.
//!
//! Results go to `BENCH_profile.json` at the repository root (CI
//! uploads it next to the `BENCH_e2e.json` artifact).

use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::cluster::TiledWorkload;
use crate::sim::SimMode;
use crate::util::json::{pretty, Json};

use super::{saturated_workload, wrap_saturated_workload};

/// Wall-time attribution of one profiled scenario run.
#[derive(Debug, Clone)]
pub struct PhaseProfile {
    /// Scenario name (JSON key in the report).
    pub name: String,
    /// Simulated cycles in the timed region.
    pub cycles: u64,
    /// Whole-run wall time in seconds (outer timer, not the phase sum).
    pub total_seconds: f64,
    /// Seconds in the link-delivery sweeps.
    pub link_deliver: f64,
    /// Seconds in the router sweeps.
    pub router_sweep: f64,
    /// Seconds in NI termination/injection.
    pub ni: f64,
    /// Seconds in the harness generator pass.
    pub generators: f64,
    /// Seconds of pre-step bookkeeping plus the profiler's own loop and
    /// timestamping residual (see the module docs).
    pub gating_overhead: f64,
}

impl PhaseProfile {
    /// Simulated cycles per wall second over the whole timed region.
    pub fn cps(&self) -> f64 {
        self.cycles as f64 / self.total_seconds.max(1e-9)
    }

    /// A phase's share of the total (0.0 when the run was too fast to
    /// time).
    fn share(&self, seconds: f64) -> f64 {
        if self.total_seconds > 0.0 {
            seconds / self.total_seconds
        } else {
            0.0
        }
    }

    /// JSON object for the profile file: per-phase `seconds` + `share`,
    /// shares summing to 1.0 by construction.
    pub fn to_json(&self) -> Json {
        let phase = |s: f64| {
            Json::obj(vec![
                ("seconds", Json::Num(s)),
                ("share", Json::Num(self.share(s))),
            ])
        };
        Json::obj(vec![
            ("cycles", Json::Num(self.cycles as f64)),
            ("total_seconds", Json::Num(self.total_seconds)),
            ("cps", Json::Num(self.cps())),
            (
                "phases",
                Json::obj(vec![
                    ("link_deliver", phase(self.link_deliver)),
                    ("router_sweep", phase(self.router_sweep)),
                    ("ni", phase(self.ni)),
                    ("generators", phase(self.generators)),
                    ("gating_overhead", phase(self.gating_overhead)),
                ]),
            ),
        ])
    }
}

/// Step `w` for `cycles` cycles with a timestamp between every phase,
/// accumulating per-phase wall time. Behaviourally identical to calling
/// [`TiledWorkload::step`] `cycles` times — the phase helpers are the
/// same code `step` composes, in the same order — so a profiled run's
/// statistics match an unprofiled one bit for bit.
pub fn profile_workload(name: &str, cycles: u64, w: &mut TiledWorkload) -> PhaseProfile {
    let mut link_deliver = 0.0f64;
    let mut router_sweep = 0.0f64;
    let mut ni = 0.0f64;
    let mut generators = 0.0f64;
    let mut pre = 0.0f64;
    let run0 = Instant::now();
    for _ in 0..cycles {
        let t0 = Instant::now();
        w.sys.pre_step();
        let t1 = Instant::now();
        w.sys.link_phase();
        let t2 = Instant::now();
        w.sys.router_phase();
        let t3 = Instant::now();
        w.sys.ni_phase();
        let t4 = Instant::now();
        for t in &mut w.tiles {
            t.step(&mut w.sys);
        }
        let t5 = Instant::now();
        pre += (t1 - t0).as_secs_f64();
        link_deliver += (t2 - t1).as_secs_f64();
        router_sweep += (t3 - t2).as_secs_f64();
        ni += (t4 - t3).as_secs_f64();
        generators += (t5 - t4).as_secs_f64();
    }
    let total_seconds = run0.elapsed().as_secs_f64();
    // Residual = outer timer minus the phase sum: loop control and the
    // Instant calls themselves. Folded into the overhead bucket so the
    // shares partition the total exactly.
    let residual = (total_seconds - (pre + link_deliver + router_sweep + ni + generators)).max(0.0);
    let p = PhaseProfile {
        name: name.to_string(),
        cycles,
        total_seconds,
        link_deliver,
        router_sweep,
        ni,
        generators,
        gating_overhead: pre + residual,
    };
    println!(
        "{:<24} {:>10.0} c/s | link {:>4.1}% | router {:>4.1}% | ni {:>4.1}% | gen {:>4.1}% | overhead {:>4.1}%",
        p.name,
        p.cps(),
        100.0 * p.share(p.link_deliver),
        100.0 * p.share(p.router_sweep),
        100.0 * p.share(p.ni),
        100.0 * p.share(p.generators),
        100.0 * p.share(p.gating_overhead),
    );
    p
}

/// Profile the three saturated scenarios (4×4 mesh, 4×4 torus, 8×8
/// mesh) under gated stepping — the hot-path record the bitmask
/// allocator and flattened lanes are measured against. `quick` shrinks
/// the cycle budget for CI smoke runs.
pub fn run_profile(quick: bool) -> Vec<PhaseProfile> {
    let cycles = if quick { 2_000 } else { 8_000 };
    println!("== phase profile: saturated scenarios, gated stepping ==");
    let mut out = Vec::new();
    let mut w = saturated_workload(4, SimMode::Gated);
    out.push(profile_workload("saturated_4x4", cycles, &mut w));
    let mut w = wrap_saturated_workload(4, SimMode::Gated);
    out.push(profile_workload("wrap_saturated_torus_4x4", cycles, &mut w));
    let mut w = saturated_workload(8, SimMode::Gated);
    out.push(profile_workload("saturated_8x8", cycles / 2, &mut w));
    out
}

/// Serialize profiles to the `floonoc-profile/1` schema.
pub fn profile_to_json(profiles: &[PhaseProfile]) -> Json {
    Json::obj(vec![
        ("schema", Json::Str("floonoc-profile/1".into())),
        ("mode", Json::Str(SimMode::Gated.name().into())),
        (
            "scenarios",
            Json::Obj(
                profiles
                    .iter()
                    .map(|p| (p.name.clone(), p.to_json()))
                    .collect(),
            ),
        ),
    ])
}

/// Default location of the profile file: the repository root, next to
/// `BENCH_e2e.json` (same relocation fallback as
/// [`super::default_report_path`]).
pub fn default_profile_path() -> PathBuf {
    let repo_root = Path::new(env!("CARGO_MANIFEST_DIR"));
    if repo_root.is_dir() {
        repo_root.join("BENCH_profile.json")
    } else {
        PathBuf::from("BENCH_profile.json")
    }
}

/// Write profiles as pretty JSON to `path`.
pub fn write_profile(profiles: &[PhaseProfile], path: &Path) -> crate::Result<()> {
    use anyhow::Context;
    let text = format!("{}\n", pretty(&profile_to_json(profiles)));
    std::fs::write(path, text)
        .with_context(|| format!("writing phase profile to {}", path.display()))?;
    println!("phase profile written to {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A profiled run is behaviourally identical to an unprofiled one:
    /// same clock, same injected/ejected counters, bit for bit.
    #[test]
    fn profiled_run_matches_plain_stepping() {
        let mut plain = saturated_workload(4, SimMode::Gated);
        for _ in 0..400 {
            plain.step();
        }
        let mut profiled = saturated_workload(4, SimMode::Gated);
        profile_workload("unit", 400, &mut profiled);
        assert_eq!(plain.sys.now, profiled.sys.now);
        for (n, (a, b)) in plain
            .sys
            .counters
            .iter()
            .zip(&profiled.sys.counters)
            .enumerate()
        {
            assert_eq!(
                (a.injected, a.ejected),
                (b.injected, b.ejected),
                "profiled net{n} counters must match plain stepping"
            );
        }
    }

    /// Shares partition the total: they are non-negative and sum to 1
    /// (the residual is folded into the overhead bucket).
    #[test]
    fn shares_partition_the_total() {
        let mut w = saturated_workload(4, SimMode::Gated);
        let p = profile_workload("unit", 200, &mut w);
        assert_eq!(p.cycles, 200);
        assert!(p.total_seconds > 0.0);
        let parts = [
            p.link_deliver,
            p.router_sweep,
            p.ni,
            p.generators,
            p.gating_overhead,
        ];
        assert!(parts.iter().all(|&s| s >= 0.0));
        let sum: f64 = parts.iter().map(|&s| p.share(s)).sum();
        assert!(
            (sum - 1.0).abs() < 1e-6,
            "phase shares must sum to 1.0, got {sum}"
        );
        assert!(p.cps() > 0.0);
    }

    #[test]
    fn profile_json_shape() {
        let p = PhaseProfile {
            name: "saturated_4x4".into(),
            cycles: 100,
            total_seconds: 1.0,
            link_deliver: 0.3,
            router_sweep: 0.4,
            ni: 0.15,
            generators: 0.1,
            gating_overhead: 0.05,
        };
        let j = profile_to_json(std::slice::from_ref(&p));
        assert_eq!(
            j.get("schema").and_then(Json::as_str),
            Some("floonoc-profile/1")
        );
        assert_eq!(j.get("mode").and_then(Json::as_str), Some("gated"));
        let sat = j
            .get("scenarios")
            .and_then(|s| s.get("saturated_4x4"))
            .unwrap();
        assert_eq!(sat.get("cps").and_then(Json::as_f64), Some(100.0));
        let router = sat
            .get("phases")
            .and_then(|ph| ph.get("router_sweep"))
            .unwrap();
        assert_eq!(router.get("seconds").and_then(Json::as_f64), Some(0.4));
        assert_eq!(router.get("share").and_then(Json::as_f64), Some(0.4));
    }
}
