//! End-to-end simulator-performance scenarios and the recorded benchmark
//! trajectory (`BENCH_e2e.json`).
//!
//! This module is the single implementation behind two entry points —
//! `cargo bench --bench bench_e2e` and `repro bench` — so the numbers
//! the CI gate sees and the numbers a developer reproduces locally come
//! from identical code. Each run measures:
//!
//! * **sparse_trace** — a PATRONoC-style trace workload on an 8×8 mesh
//!   where a handful of nodes exchange traffic and most of the fabric is
//!   quiet most cycles: the activity-gated step loop's home turf (the
//!   tentpole bar is ≥ 2× dense here);
//! * **saturated** — every tile of a 4×4 mesh injecting uniform-random
//!   narrow + wide traffic at full rate: the gated loop's worst case
//!   (bar: within 5% of dense — the active set is allowed to cost its
//!   bookkeeping only when it buys nothing);
//! * **saturated_8x8** — the same full-rate uniform traffic on an 8×8
//!   mesh: four times the routers per cycle, so the per-cycle hot
//!   loops (switch allocation, link delivery) dominate — the record
//!   the bitmask/memoization optimisations are tracked against;
//! * **wrap_saturated** — the same full-rate uniform traffic on a 4×4
//!   torus with its default 2 dateline VCs: the VC switch's cps record
//!   (this workload deadlocked — or needed crippled outstanding budgets
//!   — before the virtual-channel PR);
//! * **tornado_adaptive_8x8** — full-rate tornado traffic on an 8×8
//!   torus under minimal-adaptive routing (2 escape + 1 adaptive VC):
//!   the adaptive hot path's cps record — per-head candidate scoring
//!   and plan retraction on top of the VC switch, gated by
//!   `CPS_FLOOR_TORNADO_ADAPTIVE_8X8`;
//! * **duty_cycled** — every tile of an 8×8 mesh firing a short
//!   full-rate burst once per long period, silent between: the
//!   event-driven mode's home turf (bar: event ≥ 5× gated cycles/s —
//!   the fast-forward must actually jump the idle stretches);
//! * **sharded_16x16** — the saturated workload scaled to a 16×16 mesh
//!   and run to the same cycle horizon serial (`shards = 1`) and on the
//!   deterministic sharded engine (`shards = 4`), with an
//!   identical-counters check: the self-relative bar is ≥ 2× at four
//!   shards (see `docs/architecture.md`, "Sharded execution");
//! * **parallel sweep** — the serial-vs-parallel `ParallelRunner`
//!   speedup on identical points with a byte-identical-report check;
//! * **cps gates** — [`crate::util::bench::cps_gate`] over the gated
//!   saturated workload, plus an event-mode gate over the duty-cycled
//!   workload (measured as simulated cycles per wall second — step
//!   invocations undercount a fast-forwarding engine), each enforcing
//!   its pinned `CPS_FLOOR_*` when CI sets one.
//!
//! Results are written as `BENCH_e2e.json` at the repository root so the
//! performance trajectory is recorded PR-over-PR (see
//! `docs/performance.md` for how to read the file). Every scenario
//! object carries a `"provenance"` field; reports written by this code
//! are always `"measured"` (the checked-in trajectory file may carry
//! `"estimated-offline"` entries until the first post-merge CI run
//! refreshes them).
//!
//! The [`profile`] submodule is the companion *phase* profiler
//! (`repro bench --profile`): instead of comparing step modes it
//! attributes wall time inside one saturated gated run to the per-cycle
//! phases (link deliver / router sweep / NI / generators).

pub mod profile;

use std::path::{Path, PathBuf};

use crate::cluster::{TileTraffic, TiledWorkload};
use crate::dse::parallel::{run_sweep, sweep_report_json, ParallelRunner, SweepPoint};
use crate::flit::NodeId;
use crate::noc::{LinkMode, NocConfig, NocSystem};
use crate::sim::SimMode;
use crate::traffic::{DutyCycle, GenCfg, Pattern};
use crate::util::bench::{cps_floor, cps_gate, measure_cps, time_once, CpsResult};
use crate::util::json::{pretty, Json};

/// Every tile injecting uniform-random narrow + wide traffic at full
/// rate on an `n × n` mesh — the saturation scenario (and the historic
/// `bench_e2e` workload).
pub fn saturated_workload(n: u8, mode: SimMode) -> TiledWorkload {
    let sys = NocSystem::new(NocConfig::mesh(n, n).with_sim_mode(mode));
    let tiles = sys.topo.num_tiles;
    let profiles: Vec<TileTraffic> = (0..tiles)
        .map(|i| TileTraffic {
            core: Some(GenCfg {
                pattern: Pattern::UniformTiles,
                num_txns: u64::MAX,
                seed: i as u64,
                ..GenCfg::narrow_probe(NodeId(0), 1)
            }),
            dma: Some(GenCfg {
                pattern: Pattern::UniformTiles,
                num_txns: u64::MAX,
                seed: 100 + i as u64,
                ..GenCfg::dma_burst(NodeId(0), 1, false)
            }),
        })
        .collect();
    TiledWorkload::new(sys, profiles)
}

/// Every tile of an `n × n` **torus** injecting uniform-random wide
/// wormhole bursts (plus narrow probes) at full rate — the
/// wrap-saturation scenario the dateline virtual channels (PR 4)
/// unlocked: before VCs this workload was undrivable (cyclic-wait
/// deadlock risk); now it records the VC machinery's simulation-speed
/// cost in the trajectory file as `wrap_saturated_torus_4x4`.
pub fn wrap_saturated_workload(n: u8, mode: SimMode) -> TiledWorkload {
    let sys = NocSystem::new(NocConfig::torus(n, n).with_sim_mode(mode));
    let tiles = sys.topo.num_tiles;
    let profiles: Vec<TileTraffic> = (0..tiles)
        .map(|i| TileTraffic {
            core: Some(GenCfg {
                pattern: Pattern::UniformTiles,
                num_txns: u64::MAX,
                seed: i as u64,
                ..GenCfg::narrow_probe(NodeId(0), 1)
            }),
            dma: Some(GenCfg {
                pattern: Pattern::UniformTiles,
                num_txns: u64::MAX,
                seed: 300 + i as u64,
                ..GenCfg::dma_burst(NodeId(0), 1, false)
            }),
        })
        .collect();
    TiledWorkload::new(sys, profiles)
}

/// Every tile of an `n × n` torus streaming wide wormhole bursts (plus
/// narrow probes) to its tornado partner — the tile half-way around
/// both ring dimensions — at full rate, with `routing` selecting the
/// discipline. The tornado is the adversarial pattern for deterministic
/// minimal routing on wrap fabrics: every flow travels the diameter and
/// the tied-distance choice piles onto one direction. Shared builder
/// behind [`tornado_adaptive_workload`] / the deterministic twin.
fn tornado_torus_workload(n: u8, mode: SimMode, adaptive: bool) -> TiledWorkload {
    let mut cfg = NocConfig::torus(n, n).with_sim_mode(mode);
    if adaptive {
        cfg = cfg.adaptive();
    }
    let sys = NocSystem::new(cfg);
    let tiles = sys.topo.num_tiles;
    let profiles: Vec<TileTraffic> = (0..tiles)
        .map(|i| TileTraffic {
            core: Some(GenCfg {
                pattern: Pattern::Tornado,
                num_txns: u64::MAX,
                seed: 0x70AD + i as u64,
                ..GenCfg::narrow_probe(NodeId(0), 1)
            }),
            dma: Some(GenCfg {
                pattern: Pattern::Tornado,
                num_txns: u64::MAX,
                burst_len: 15,
                seed: 0x500 + i as u64,
                ..GenCfg::dma_burst(NodeId(0), 1, false)
            }),
        })
        .collect();
    TiledWorkload::new(sys, profiles)
}

/// The tornado scenario under **minimal-adaptive routing on Duato
/// escape VCs** (`NocConfig::adaptive`: 2 dateline escape lanes + 1
/// adaptive lane): heads spread the tornado's tied-distance flows over
/// both ring directions by local credit availability. Recorded in the
/// trajectory file as `tornado_adaptive_8x8`; its gated side is the
/// adaptive hot path's cps record ([`TORNADO_GATE_NAME`]).
pub fn tornado_adaptive_workload(n: u8, mode: SimMode) -> TiledWorkload {
    tornado_torus_workload(n, mode, true)
}

/// The deterministic twin of [`tornado_adaptive_workload`] — identical
/// traffic and seeds, dimension-ordered dateline routing. The throughput
/// comparison between the two is the adaptive PR's acceptance study
/// (`docs/experiments.md`); in this module it exists so benchmarks and
/// tests can measure both sides of the same scenario.
pub fn tornado_deterministic_workload(n: u8, mode: SimMode) -> TiledWorkload {
    tornado_torus_workload(n, mode, false)
}

/// A sparse trace-style workload on an `n × n` mesh (PATRONoC-style,
/// arXiv 2308.00154): one DMA producer streaming occasional bursts to
/// the far corner, one probing core, everything else idle. Flits are in
/// flight on a thin path most cycles — so the dense loop cannot use its
/// whole-network idle skip — while > 95% of links and routers are
/// quiescent: exactly the regime activity gating is built for.
pub fn sparse_trace_workload(n: u8, mode: SimMode) -> TiledWorkload {
    let sys = NocSystem::new(NocConfig::mesh(n, n).with_sim_mode(mode));
    let tiles = sys.topo.num_tiles;
    let far = NodeId((tiles - 1) as u16);
    let profiles: Vec<TileTraffic> = (0..tiles)
        .map(|i| {
            if i == 0 {
                TileTraffic {
                    core: Some(GenCfg {
                        rate: 0.05,
                        num_txns: u64::MAX,
                        seed: 0x5AFE,
                        ..GenCfg::narrow_probe(far, 1)
                    }),
                    dma: Some(GenCfg {
                        rate: 0.02,
                        num_txns: u64::MAX,
                        max_outstanding: 2,
                        seed: 0x50DA,
                        ..GenCfg::dma_burst(far, 1, false)
                    }),
                }
            } else if i == tiles / 2 {
                TileTraffic {
                    core: Some(GenCfg {
                        rate: 0.03,
                        num_txns: u64::MAX,
                        seed: 0x7ACE,
                        ..GenCfg::narrow_probe(NodeId(0), 1)
                    }),
                    dma: None,
                }
            } else {
                TileTraffic::idle()
            }
        })
        .collect();
    TiledWorkload::new(sys, profiles)
}

/// Every tile of an `n × n` mesh issuing a short full-rate burst of
/// narrow reads once per 512-cycle period (a 16-cycle duty window,
/// offsets lightly staggered per tile), silent the other ~97% of the
/// time. Bernoulli-sparse workloads (`rate < 1`) can draw an issue on
/// *any* cycle, so they never present a provably idle stretch; this
/// duty-cycled shape does — it is the scenario the event-driven
/// fast-forward ([`SimMode::Event`]) is measured and gated on.
pub fn duty_cycled_workload(n: u8, mode: SimMode) -> TiledWorkload {
    let sys = NocSystem::new(NocConfig::mesh(n, n).with_sim_mode(mode));
    let tiles = sys.topo.num_tiles;
    let profiles: Vec<TileTraffic> = (0..tiles)
        .map(|i| TileTraffic {
            core: Some(GenCfg {
                pattern: Pattern::UniformTiles,
                num_txns: u64::MAX,
                seed: 0xD117 + i as u64,
                duty: Some(DutyCycle {
                    period: 512,
                    active: 16,
                    offset: (i as u64 % 4) * 4,
                }),
                ..GenCfg::narrow_probe(NodeId(0), 1)
            }),
            dma: None,
        })
        .collect();
    TiledWorkload::new(sys, profiles)
}

/// One gated-vs-dense throughput comparison of a scenario.
#[derive(Debug, Clone)]
pub struct ModeComparison {
    /// Scenario name (JSON key in the report).
    pub name: String,
    /// Simulated cycles per measured run.
    pub cycles: u64,
    /// Dense-reference cycles/second.
    pub dense_cps: f64,
    /// Activity-gated cycles/second.
    pub gated_cps: f64,
}

impl ModeComparison {
    /// Gated speedup over dense (> 1 means gating wins).
    pub fn speedup(&self) -> f64 {
        if self.dense_cps > 0.0 {
            self.gated_cps / self.dense_cps
        } else {
            0.0
        }
    }

    /// JSON object for the report file. Reports this code writes are
    /// always freshly measured; the per-scenario `provenance` field
    /// exists so the checked-in trajectory file can distinguish them
    /// from `"estimated-offline"` placeholder entries.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("provenance", Json::Str("measured".into())),
            ("cycles", Json::Num(self.cycles as f64)),
            ("dense_cps", Json::Num(self.dense_cps)),
            ("gated_cps", Json::Num(self.gated_cps)),
            ("gated_speedup", Json::Num(self.speedup())),
        ])
    }
}

/// Measure a scenario in both [`SimMode`]s. `mk` must build a fresh,
/// identically-seeded workload per mode (warm construction is excluded
/// from the timed region).
pub fn compare_modes<F>(name: &str, cycles: u64, mk: F) -> ModeComparison
where
    F: Fn(SimMode) -> TiledWorkload,
{
    let mut dense_w = mk(SimMode::Dense);
    let dense = measure_cps(cycles, || dense_w.step());
    let mut gated_w = mk(SimMode::Gated);
    let gated = measure_cps(cycles, || gated_w.step());
    let r = ModeComparison {
        name: name.to_string(),
        cycles,
        dense_cps: dense.cycles_per_second(),
        gated_cps: gated.cycles_per_second(),
    };
    println!(
        "{:<24} dense {:>12.0} c/s | gated {:>12.0} c/s | speedup {:.2}x",
        r.name,
        r.dense_cps,
        r.gated_cps,
        r.speedup()
    );
    r
}

/// One gated-vs-event throughput comparison of a (duty-cycled)
/// scenario. Unlike [`ModeComparison`] the two sides are measured
/// differently: gated by step invocations (one simulated cycle each),
/// event by **simulated cycles per wall second** — a fast-forwarding
/// step can advance many cycles, so counting invocations would
/// undercount exactly the speedup being measured.
#[derive(Debug, Clone)]
pub struct EventComparison {
    /// Scenario name (JSON key in the report).
    pub name: String,
    /// Gated measurement (cycles == step invocations).
    pub gated: CpsResult,
    /// Event measurement (cycles == simulated cycles at stop; may
    /// overshoot the gated budget by up to one fast-forward jump).
    pub event: CpsResult,
    /// Cycles the event engine actually executed.
    pub event_stepped: u64,
    /// Cycles the event engine fast-forwarded over.
    pub event_skipped: u64,
}

impl EventComparison {
    /// Event speedup over gated (> 1 means fast-forward wins).
    pub fn speedup(&self) -> f64 {
        let g = self.gated.cycles_per_second();
        if g > 0.0 {
            self.event.cycles_per_second() / g
        } else {
            0.0
        }
    }

    /// JSON object for the report file (`provenance`: see
    /// [`ModeComparison::to_json`]).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("provenance", Json::Str("measured".into())),
            ("cycles", Json::Num(self.gated.cycles as f64)),
            ("gated_cps", Json::Num(self.gated.cycles_per_second())),
            ("event_cps", Json::Num(self.event.cycles_per_second())),
            ("event_speedup", Json::Num(self.speedup())),
            ("event_stepped_cycles", Json::Num(self.event_stepped as f64)),
            ("event_skipped_cycles", Json::Num(self.event_skipped as f64)),
        ])
    }
}

/// Measure a scenario under gated and event stepping. `mk` must build a
/// fresh, identically-seeded workload per mode. The event side runs to
/// the same simulated-cycle horizon (not the same step count) and its
/// cps is simulated cycles over wall time.
pub fn compare_event<F>(name: &str, cycles: u64, mk: F) -> EventComparison
where
    F: Fn(SimMode) -> TiledWorkload,
{
    let mut gated_w = mk(SimMode::Gated);
    let gated = measure_cps(cycles, || gated_w.step());
    let mut event_w = mk(SimMode::Event);
    let t0 = std::time::Instant::now();
    while event_w.sys.now < cycles {
        event_w.step();
    }
    let event = CpsResult {
        cycles: event_w.sys.now,
        wall_seconds: t0.elapsed().as_secs_f64(),
    };
    let r = EventComparison {
        name: name.to_string(),
        gated,
        event,
        event_stepped: event_w.sys.stepped_cycles,
        event_skipped: event_w.sys.skipped_cycles,
    };
    println!(
        "{:<24} gated {:>12.0} c/s | event {:>12.0} c/s | speedup {:.2}x (stepped {} / skipped {})",
        r.name,
        r.gated.cycles_per_second(),
        r.event.cycles_per_second(),
        r.speedup(),
        r.event_stepped,
        r.event_skipped,
    );
    r
}

/// One serial-vs-sharded comparison of a single simulation: the same
/// workload run to the same cycle horizon with `shards = 1` and with
/// `shards = n`, identical-counters checked. Unlike the parallel sweep
/// (independent points fanned out), this measures intra-simulation
/// parallelism — one `NocSystem` cut into strips and stepped on `n`
/// threads by `floonoc::noc::sharded`.
#[derive(Debug, Clone)]
pub struct ShardComparison {
    /// Scenario name (JSON key in the report).
    pub name: String,
    /// Simulated cycles per measured run.
    pub cycles: u64,
    /// Shard count of the sharded side.
    pub shards: usize,
    /// Serial (`shards = 1`) cycles/second.
    pub serial_cps: f64,
    /// Sharded cycles/second.
    pub sharded_cps: f64,
}

impl ShardComparison {
    /// Sharded speedup over serial (> 1 means sharding wins).
    pub fn speedup(&self) -> f64 {
        if self.serial_cps > 0.0 {
            self.sharded_cps / self.serial_cps
        } else {
            0.0
        }
    }

    /// JSON object for the report file (`provenance`: see
    /// [`ModeComparison::to_json`]).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("provenance", Json::Str("measured".into())),
            ("cycles", Json::Num(self.cycles as f64)),
            ("shards", Json::Num(self.shards as f64)),
            ("serial_cps", Json::Num(self.serial_cps)),
            ("sharded_cps", Json::Num(self.sharded_cps)),
            ("sharded_speedup", Json::Num(self.speedup())),
        ])
    }
}

/// Measure a workload serial and sharded to the same cycle horizon.
/// `mk` must build a fresh, identically-seeded workload per side; the
/// two runs' clocks and per-network flit counters are asserted equal —
/// determinism is part of the sharded engine's contract, so the bench
/// re-checks it on every measurement rather than trusting the test
/// suite alone.
pub fn compare_sharded<F>(name: &str, cycles: u64, shards: usize, mk: F) -> ShardComparison
where
    F: Fn() -> TiledWorkload,
{
    let run = |shards: usize| {
        let mut w = mk();
        w.sys.cfg.shards = shards;
        let wall = time_once(|| {
            w.run_to_completion(cycles);
        });
        (w, wall.as_secs_f64())
    };
    let (serial_w, serial_s) = run(1);
    let (sharded_w, sharded_s) = run(shards);
    assert_eq!(
        serial_w.sys.now, sharded_w.sys.now,
        "sharded run must stop on the same cycle as serial"
    );
    let pairs = serial_w.sys.counters.iter().zip(&sharded_w.sys.counters);
    for (n, (a, b)) in pairs.enumerate() {
        assert_eq!(
            (a.injected, a.ejected),
            (b.injected, b.ejected),
            "sharded net{n} counters must match serial byte for byte"
        );
    }
    let r = ShardComparison {
        name: name.to_string(),
        cycles: serial_w.sys.now,
        shards,
        serial_cps: serial_w.sys.now as f64 / serial_s.max(1e-9),
        sharded_cps: sharded_w.sys.now as f64 / sharded_s.max(1e-9),
    };
    println!(
        "{:<24} serial {:>11.0} c/s | {}-shard {:>11.0} c/s | speedup {:.2}x (identical counters)",
        r.name,
        r.serial_cps,
        r.shards,
        r.sharded_cps,
        r.speedup()
    );
    r
}

/// Serial-vs-parallel sweep comparison (byte-identical reports checked).
#[derive(Debug, Clone)]
pub struct SweepComparison {
    /// Independent sweep points executed.
    pub points: usize,
    /// Worker threads of the parallel run.
    pub threads: usize,
    /// Serial wall time in seconds.
    pub serial_seconds: f64,
    /// Parallel wall time in seconds.
    pub parallel_seconds: f64,
}

impl SweepComparison {
    /// Parallel speedup over serial.
    pub fn speedup(&self) -> f64 {
        self.serial_seconds / self.parallel_seconds.max(1e-9)
    }

    /// JSON object for the report file (`provenance`: see
    /// [`ModeComparison::to_json`]).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("provenance", Json::Str("measured".into())),
            ("points", Json::Num(self.points as f64)),
            ("threads", Json::Num(self.threads as f64)),
            ("serial_seconds", Json::Num(self.serial_seconds)),
            ("parallel_seconds", Json::Num(self.parallel_seconds)),
            ("parallel_speedup", Json::Num(self.speedup())),
        ])
    }
}

/// The sweep used for the serial-vs-parallel comparison: independent
/// ring-DMA points across mesh sizes and link modes, sized so one point
/// is a nontrivial simulation (smaller under `quick`).
fn speedup_points(quick: bool) -> Vec<SweepPoint> {
    let mut points = if quick {
        SweepPoint::grid(&[4], &[LinkMode::NarrowWide, LinkMode::WideOnly], &[7, 15])
    } else {
        SweepPoint::grid(
            &[4, 6],
            &[LinkMode::NarrowWide, LinkMode::WideOnly],
            &[7, 15],
        )
    };
    for p in &mut points {
        p.bursts_per_tile = if quick { 8 } else { 24 };
    }
    points
}

/// Run the serial-vs-parallel sweep comparison, asserting byte-identical
/// reports (determinism is part of the contract, not just speed).
pub fn sweep_speedup(quick: bool) -> SweepComparison {
    let points = speedup_points(quick);
    let threads = ParallelRunner::default().threads();
    let mut serial_results = Vec::new();
    let serial = time_once(|| {
        serial_results = run_sweep(&points, &ParallelRunner::serial());
    });
    let mut parallel_results = Vec::new();
    let parallel = time_once(|| {
        parallel_results = run_sweep(&points, &ParallelRunner::default());
    });
    assert_eq!(
        pretty(&sweep_report_json(&serial_results)),
        pretty(&sweep_report_json(&parallel_results)),
        "parallel sweep must be byte-identical to serial"
    );
    let r = SweepComparison {
        points: points.len(),
        threads,
        serial_seconds: serial.as_secs_f64(),
        parallel_seconds: parallel.as_secs_f64(),
    };
    println!(
        "parallel sweep: {} points on {} threads, serial {:.2}s / parallel {:.2}s => {:.2}x (byte-identical)",
        r.points,
        r.threads,
        r.serial_seconds,
        r.parallel_seconds,
        r.speedup()
    );
    r
}

/// One full end-to-end performance report (the content of
/// `BENCH_e2e.json`).
#[derive(Debug, Clone)]
pub struct E2eReport {
    /// Sparse trace scenario (gating's target regime; bar: ≥ 2×).
    pub sparse: ModeComparison,
    /// Saturated scenario (gating's worst case; bar: ≥ 0.95×).
    pub saturated: ModeComparison,
    /// Saturated scenario scaled to an 8×8 mesh — the hot-path
    /// optimisation record (bitmask switch allocation, memoized route
    /// lookups, flattened lanes): four times the routers of
    /// `saturated_4x4`, so per-cycle loop cost dominates and the entry
    /// tracks the allocator/link inner loops PR-over-PR.
    pub saturated8: ModeComparison,
    /// Wrap-saturation scenario on a 2-VC torus (the dateline-VC
    /// feature's cps record; no bar — the entry tracks the VC switch's
    /// cost PR-over-PR).
    pub wrap: ModeComparison,
    /// Tornado on an 8×8 torus under adaptive routing (3 VCs: 2 escape
    /// + 1 adaptive) — the adaptive hot path's cps record: per-cycle
    /// congestion scoring and plan retraction on top of the VC switch.
    pub tornado_adaptive: ModeComparison,
    /// Duty-cycled scenario under gated vs event stepping (the
    /// fast-forward's target regime; bar: ≥ 5×).
    pub duty: EventComparison,
    /// Saturated 16×16 mesh, serial vs 4-shard single-simulation
    /// execution (the sharded engine's target regime; bar: ≥ 2×
    /// self-relative).
    pub sharded: ShardComparison,
    /// Serial-vs-parallel sweep runner comparison.
    pub sweep: SweepComparison,
    /// The regression-gate measurement (gated saturated workload).
    pub gate: CpsResult,
    /// The pinned floor the gate enforced, if CI set one.
    pub gate_floor: Option<f64>,
    /// The pinned floor the event-mode gate enforced, if CI set one.
    pub event_gate_floor: Option<f64>,
    /// The pinned floor the sharded gate enforced, if CI set one.
    pub sharded_gate_floor: Option<f64>,
    /// The pinned floor the tornado-adaptive gate enforced, if CI set
    /// one.
    pub tornado_gate_floor: Option<f64>,
}

/// The name the cps regression gate runs under (also the suffix of its
/// per-gate floor env var, `CPS_FLOOR_4X4_SATURATED`).
pub const GATE_NAME: &str = "4x4-saturated";

/// The name the event-mode cps gate runs under (per-gate floor env var:
/// `CPS_FLOOR_8X8_DUTY_EVENT` — see [`crate::util::bench::cps_floor`]
/// for the sanitization rule). Its measurement is simulated cycles per
/// wall second on the duty-cycled 8×8 scenario under [`SimMode::Event`].
pub const EVENT_GATE_NAME: &str = "8x8-duty-event";

/// The name the sharded cps gate runs under (per-gate floor env var:
/// `CPS_FLOOR_SHARDED_16X16`). Its measurement is the sharded side of
/// the serial-vs-sharded comparison on the saturated 16×16 mesh.
pub const SHARDED_GATE_NAME: &str = "sharded-16x16";

/// The name the adaptive-routing cps gate runs under (per-gate floor
/// env var: `CPS_FLOOR_TORNADO_ADAPTIVE_8X8`). Its measurement is the
/// gated side of the tornado-adaptive comparison — the cost of the
/// per-cycle candidate scoring and plan retraction the adaptive router
/// adds on top of the VC switch.
pub const TORNADO_GATE_NAME: &str = "tornado-adaptive-8x8";

/// Run every scenario. `quick` shrinks cycle counts and sweep sizes for
/// CI smoke runs; the measured *ratios* stay meaningful, absolute
/// cycles/s less so.
pub fn run_e2e(quick: bool) -> E2eReport {
    let (sparse_cycles, sat_cycles) = if quick {
        (20_000, 8_000)
    } else {
        (60_000, 20_000)
    };
    println!("== e2e performance: activity-gated vs dense reference ==");
    let sparse = compare_modes("sparse_trace_8x8", sparse_cycles, |m| {
        sparse_trace_workload(8, m)
    });
    let saturated = compare_modes("saturated_4x4", sat_cycles, |m| saturated_workload(4, m));
    // The 8×8 saturated entry runs fewer cycles — four times the
    // routers per cycle keeps the measured wall time comparable.
    let saturated8 = compare_modes("saturated_8x8", sat_cycles / 2, |m| saturated_workload(8, m));
    let wrap = compare_modes("wrap_saturated_torus_4x4", sat_cycles, |m| {
        wrap_saturated_workload(4, m)
    });
    // The 8×8 adaptive tornado runs the same reduced cycle budget as
    // saturated_8x8 (four times the routers per cycle, plus the
    // adaptive scoring work on every head).
    let tornado_adaptive = compare_modes("tornado_adaptive_8x8", sat_cycles / 2, |m| {
        tornado_adaptive_workload(8, m)
    });
    // Adaptive gate: floor enforced on the gated side's absolute
    // throughput, same contract as the other gates.
    let tornado_gate_floor = cps_floor(TORNADO_GATE_NAME);
    println!(
        "cps_gate name={TORNADO_GATE_NAME} cycles={} cycles_per_second={:.0} floor={}",
        tornado_adaptive.cycles,
        tornado_adaptive.gated_cps,
        tornado_gate_floor
            .map(|f| format!("{f:.0}"))
            .unwrap_or_else(|| "unset".into()),
    );
    if let Some(floor) = tornado_gate_floor {
        assert!(
            tornado_adaptive.gated_cps >= floor,
            "cps regression: {TORNADO_GATE_NAME} ran at {:.0} cycles/s, floor is {floor:.0}",
            tornado_adaptive.gated_cps
        );
    }
    if sparse.speedup() < 2.0 {
        println!(
            "    WARNING: sparse-trace gated speedup {:.2}x below the 2x tentpole bar",
            sparse.speedup()
        );
    }
    if saturated.speedup() < 0.95 {
        println!(
            "    WARNING: saturated gated throughput {:.2}x dense — more than 5% regression",
            saturated.speedup()
        );
    }
    println!("== e2e performance: event-driven fast-forward vs gated ==");
    let duty = compare_event("duty_cycled_8x8", sparse_cycles, |m| {
        duty_cycled_workload(8, m)
    });
    if duty.speedup() < 5.0 {
        println!(
            "    WARNING: duty-cycled event speedup {:.2}x below the 5x tentpole bar",
            duty.speedup()
        );
    }
    println!("== e2e performance: sharded single-simulation execution ==");
    let sharded_cycles = if quick { 2_000 } else { 6_000 };
    let sharded = compare_sharded("sharded_16x16", sharded_cycles, 4, || {
        saturated_workload(16, SimMode::Gated)
    });
    if sharded.speedup() < 2.0 {
        println!(
            "    WARNING: 4-shard speedup {:.2}x below the 2x tentpole bar",
            sharded.speedup()
        );
    }
    // Sharded gate: floor enforced on the sharded side's absolute
    // throughput, same contract as the other gates.
    let sharded_gate_floor = cps_floor(SHARDED_GATE_NAME);
    println!(
        "cps_gate name={SHARDED_GATE_NAME} cycles={} cycles_per_second={:.0} floor={}",
        sharded.cycles,
        sharded.sharded_cps,
        sharded_gate_floor
            .map(|f| format!("{f:.0}"))
            .unwrap_or_else(|| "unset".into()),
    );
    if let Some(floor) = sharded_gate_floor {
        assert!(
            sharded.sharded_cps >= floor,
            "cps regression: {SHARDED_GATE_NAME} ran at {:.0} cycles/s, floor is {floor:.0}",
            sharded.sharded_cps
        );
    }
    // Regression gate over the gated saturated mesh (the sweep workhorse).
    let mut w = saturated_workload(4, SimMode::Gated);
    let gate = cps_gate(GATE_NAME, sat_cycles, || w.step());
    let gate_floor = cps_floor(GATE_NAME);
    // Event-mode gate: the measurement already exists (the duty
    // comparison's event side, in simulated cycles per wall second);
    // [`cps_gate`] cannot re-run it because it counts step invocations,
    // which a fast-forwarding engine makes meaningless. Same print
    // format and same floor-enforcement contract.
    let event_gate_floor = cps_floor(EVENT_GATE_NAME);
    println!(
        "cps_gate name={EVENT_GATE_NAME} cycles={} wall_s={:.4} cycles_per_second={:.0} floor={}",
        duty.event.cycles,
        duty.event.wall_seconds,
        duty.event.cycles_per_second(),
        event_gate_floor
            .map(|f| format!("{f:.0}"))
            .unwrap_or_else(|| "unset".into()),
    );
    if let Some(floor) = event_gate_floor {
        assert!(
            duty.event.cycles_per_second() >= floor,
            "cps regression: {EVENT_GATE_NAME} ran at {:.0} cycles/s, floor is {floor:.0}",
            duty.event.cycles_per_second()
        );
    }
    let sweep = sweep_speedup(quick);
    E2eReport {
        sparse,
        saturated,
        saturated8,
        wrap,
        tornado_adaptive,
        duty,
        sharded,
        sweep,
        gate,
        gate_floor,
        event_gate_floor,
        sharded_gate_floor,
        tornado_gate_floor,
    }
}

/// Serialize a report to the `BENCH_e2e.json` schema.
pub fn report_to_json(r: &E2eReport) -> Json {
    Json::obj(vec![
        ("schema", Json::Str("floonoc-bench-e2e/1".into())),
        ("provenance", Json::Str("measured".into())),
        (
            "scenarios",
            Json::obj(vec![
                (r.sparse.name.as_str(), r.sparse.to_json()),
                (r.saturated.name.as_str(), r.saturated.to_json()),
                (r.saturated8.name.as_str(), r.saturated8.to_json()),
                (r.wrap.name.as_str(), r.wrap.to_json()),
                (r.tornado_adaptive.name.as_str(), r.tornado_adaptive.to_json()),
                (r.duty.name.as_str(), r.duty.to_json()),
                (r.sharded.name.as_str(), r.sharded.to_json()),
                ("parallel_sweep", r.sweep.to_json()),
            ]),
        ),
        (
            "cps_gate",
            Json::obj(vec![
                ("name", Json::Str(GATE_NAME.into())),
                ("cycles", Json::Num(r.gate.cycles as f64)),
                ("cycles_per_second", Json::Num(r.gate.cycles_per_second())),
                (
                    "floor",
                    match r.gate_floor {
                        Some(f) => Json::Num(f),
                        None => Json::Null,
                    },
                ),
            ]),
        ),
        (
            "event_cps_gate",
            Json::obj(vec![
                ("name", Json::Str(EVENT_GATE_NAME.into())),
                ("cycles", Json::Num(r.duty.event.cycles as f64)),
                (
                    "cycles_per_second",
                    Json::Num(r.duty.event.cycles_per_second()),
                ),
                (
                    "floor",
                    match r.event_gate_floor {
                        Some(f) => Json::Num(f),
                        None => Json::Null,
                    },
                ),
            ]),
        ),
        (
            "sharded_cps_gate",
            Json::obj(vec![
                ("name", Json::Str(SHARDED_GATE_NAME.into())),
                ("cycles", Json::Num(r.sharded.cycles as f64)),
                ("cycles_per_second", Json::Num(r.sharded.sharded_cps)),
                (
                    "floor",
                    match r.sharded_gate_floor {
                        Some(f) => Json::Num(f),
                        None => Json::Null,
                    },
                ),
            ]),
        ),
        (
            "tornado_adaptive_cps_gate",
            Json::obj(vec![
                ("name", Json::Str(TORNADO_GATE_NAME.into())),
                ("cycles", Json::Num(r.tornado_adaptive.cycles as f64)),
                ("cycles_per_second", Json::Num(r.tornado_adaptive.gated_cps)),
                (
                    "floor",
                    match r.tornado_gate_floor {
                        Some(f) => Json::Num(f),
                        None => Json::Null,
                    },
                ),
            ]),
        ),
    ])
}

/// Default location of the trajectory file: the repository root, so the
/// result is recorded PR-over-PR next to `CHANGES.md`.
pub fn default_report_path() -> PathBuf {
    // CARGO_MANIFEST_DIR of this crate *is* the repository root (the
    // manifest lives there; sources are under rust/) — but it is baked
    // in at build time, so an installed/relocated `repro` binary may
    // point at a directory that no longer exists. Fall back to the
    // working directory rather than failing after minutes of
    // measurement (or silently writing into a stale checkout).
    let repo_root = Path::new(env!("CARGO_MANIFEST_DIR"));
    if repo_root.is_dir() {
        repo_root.join("BENCH_e2e.json")
    } else {
        PathBuf::from("BENCH_e2e.json")
    }
}

/// Write a report as pretty JSON to `path`.
pub fn write_report(r: &E2eReport, path: &Path) -> crate::Result<()> {
    use anyhow::Context;
    let text = format!("{}\n", pretty(&report_to_json(r)));
    std::fs::write(path, text)
        .with_context(|| format!("writing bench report to {}", path.display()))?;
    println!("bench report written to {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The sparse workload really is sparse: after a settle-in period
    /// the gated active set stays a small fraction of the fabric.
    #[test]
    fn sparse_workload_keeps_most_links_gated_off() {
        let mut w = sparse_trace_workload(8, SimMode::Gated);
        let mut max_active = 0usize;
        let mut total_links = 0usize;
        for _ in 0..2_000 {
            w.step();
            let active: usize = w.sys.nets.iter().map(|n| n.active_link_count()).sum();
            max_active = max_active.max(active);
        }
        for n in &w.sys.nets {
            total_links += n.links.len();
        }
        assert!(
            max_active * 4 < total_links,
            "sparse scenario must keep >75% of links quiescent: {max_active}/{total_links}"
        );
    }

    /// Both scenario constructors are deterministic per mode: two builds
    /// stepped the same number of cycles agree on injected-flit counts.
    #[test]
    fn scenarios_deterministic() {
        for mk in [
            sparse_trace_workload,
            saturated_workload,
            wrap_saturated_workload,
            tornado_adaptive_workload,
            tornado_deterministic_workload,
            duty_cycled_workload,
        ] {
            let count = |mode: SimMode| {
                let mut w = mk(4, mode);
                for _ in 0..500 {
                    w.step();
                }
                (0..w.sys.nets.len()).map(|n| w.sys.counters[n].injected).sum::<u64>()
            };
            assert_eq!(count(SimMode::Gated), count(SimMode::Gated));
            assert_eq!(count(SimMode::Gated), count(SimMode::Dense));
        }
    }

    /// The duty-cycled scenario actually exercises the fast-forward: the
    /// event engine executes a small fraction of the simulated cycles,
    /// and the stepped/skipped split reconciles with the clock. This is
    /// the in-crate half of the duty-cycle regression (the cross-mode
    /// digest half lives in `tests/mode_equivalence_sweep.rs`).
    #[test]
    fn duty_cycled_event_fast_forwards() {
        let mut w = duty_cycled_workload(4, SimMode::Event);
        while w.sys.now < 4_096 {
            w.step();
        }
        let (stepped, skipped, now) = (w.sys.stepped_cycles, w.sys.skipped_cycles, w.sys.now);
        assert_eq!(stepped + skipped, now, "cycle accounting must reconcile");
        assert!(
            stepped * 4 < now,
            "duty workload should skip >75% of cycles: stepped {stepped} of {now}"
        );
        // Gated never skips on the same workload.
        let mut g = duty_cycled_workload(4, SimMode::Gated);
        for _ in 0..1_000 {
            g.step();
        }
        assert_eq!(g.sys.skipped_cycles, 0);
        assert_eq!(g.sys.stepped_cycles, g.sys.now);
    }

    #[test]
    fn report_json_shape() {
        let r = E2eReport {
            sparse: ModeComparison {
                name: "sparse_trace_8x8".into(),
                cycles: 10,
                dense_cps: 100.0,
                gated_cps: 400.0,
            },
            saturated: ModeComparison {
                name: "saturated_4x4".into(),
                cycles: 10,
                dense_cps: 100.0,
                gated_cps: 99.0,
            },
            saturated8: ModeComparison {
                name: "saturated_8x8".into(),
                cycles: 5,
                dense_cps: 50.0,
                gated_cps: 49.0,
            },
            wrap: ModeComparison {
                name: "wrap_saturated_torus_4x4".into(),
                cycles: 10,
                dense_cps: 90.0,
                gated_cps: 90.0,
            },
            tornado_adaptive: ModeComparison {
                name: "tornado_adaptive_8x8".into(),
                cycles: 5,
                dense_cps: 80.0,
                gated_cps: 80.0,
            },
            duty: EventComparison {
                name: "duty_cycled_8x8".into(),
                gated: crate::util::bench::CpsResult {
                    cycles: 100,
                    wall_seconds: 0.1,
                },
                event: crate::util::bench::CpsResult {
                    cycles: 120,
                    wall_seconds: 0.02,
                },
                event_stepped: 20,
                event_skipped: 100,
            },
            sharded: ShardComparison {
                name: "sharded_16x16".into(),
                cycles: 10,
                shards: 4,
                serial_cps: 100.0,
                sharded_cps: 250.0,
            },
            sweep: SweepComparison {
                points: 4,
                threads: 2,
                serial_seconds: 2.0,
                parallel_seconds: 1.0,
            },
            gate: crate::util::bench::CpsResult {
                cycles: 10,
                wall_seconds: 0.1,
            },
            gate_floor: None,
            event_gate_floor: Some(350_000.0),
            sharded_gate_floor: Some(40_000.0),
            tornado_gate_floor: Some(100_000.0),
        };
        let j = report_to_json(&r);
        assert_eq!(
            j.get("schema").and_then(Json::as_str),
            Some("floonoc-bench-e2e/1")
        );
        let sparse = j.get("scenarios").and_then(|s| s.get("sparse_trace_8x8")).unwrap();
        assert_eq!(sparse.get("gated_speedup").and_then(Json::as_f64), Some(4.0));
        assert_eq!(
            sparse.get("provenance").and_then(Json::as_str),
            Some("measured"),
            "every scenario object records its provenance"
        );
        let sat8 = j.get("scenarios").and_then(|s| s.get("saturated_8x8")).unwrap();
        assert_eq!(sat8.get("cycles").and_then(Json::as_f64), Some(5.0));
        assert_eq!(sat8.get("provenance").and_then(Json::as_str), Some("measured"));
        let duty = j.get("scenarios").and_then(|s| s.get("duty_cycled_8x8")).unwrap();
        // 120 cycles / 0.02 s = 6000 c/s event vs 100 / 0.1 = 1000 gated.
        assert_eq!(duty.get("event_speedup").and_then(Json::as_f64), Some(6.0));
        assert_eq!(
            duty.get("event_skipped_cycles").and_then(Json::as_f64),
            Some(100.0)
        );
        let gate = j.get("cps_gate").unwrap();
        assert_eq!(gate.get("name").and_then(Json::as_str), Some(GATE_NAME));
        assert!(matches!(gate.get("floor"), Some(Json::Null)));
        let egate = j.get("event_cps_gate").unwrap();
        assert_eq!(egate.get("name").and_then(Json::as_str), Some(EVENT_GATE_NAME));
        assert_eq!(egate.get("floor").and_then(Json::as_f64), Some(350_000.0));
        let shd = j.get("scenarios").and_then(|s| s.get("sharded_16x16")).unwrap();
        assert_eq!(shd.get("sharded_speedup").and_then(Json::as_f64), Some(2.5));
        assert_eq!(shd.get("shards").and_then(Json::as_f64), Some(4.0));
        let sgate = j.get("sharded_cps_gate").unwrap();
        assert_eq!(sgate.get("name").and_then(Json::as_str), Some(SHARDED_GATE_NAME));
        assert_eq!(sgate.get("floor").and_then(Json::as_f64), Some(40_000.0));
        let tornado = j
            .get("scenarios")
            .and_then(|s| s.get("tornado_adaptive_8x8"))
            .unwrap();
        assert_eq!(tornado.get("cycles").and_then(Json::as_f64), Some(5.0));
        assert_eq!(tornado.get("provenance").and_then(Json::as_str), Some("measured"));
        let tgate = j.get("tornado_adaptive_cps_gate").unwrap();
        assert_eq!(tgate.get("name").and_then(Json::as_str), Some(TORNADO_GATE_NAME));
        assert_eq!(tgate.get("floor").and_then(Json::as_f64), Some(100_000.0));
    }

    /// The serial-vs-sharded bench comparison's built-in determinism
    /// check holds on a small saturated mesh (the full byte-level digest
    /// differential lives in `tests/`; this pins the bench path itself —
    /// same clock, same counters, sane cps figures).
    #[test]
    fn compare_sharded_is_deterministic_and_measures() {
        let r = compare_sharded("sharded_unit", 300, 2, || {
            saturated_workload(4, SimMode::Gated)
        });
        assert_eq!(r.cycles, 300);
        assert_eq!(r.shards, 2);
        assert!(r.serial_cps > 0.0 && r.sharded_cps > 0.0);
        assert!(r.speedup() > 0.0);
    }
}
