//! Spatial partitioning of a fabric into contiguous shards for the
//! deterministic sharded execution engine ([`crate::noc::sharded`]).
//!
//! A [`ShardPlan`] assigns every router (and therefore every node — a
//! node lives with its host router) to exactly one shard. Shards are
//! contiguous coordinate strips:
//!
//! * fabrics with `height > 1` are cut into **row strips** (`shard =
//!   ⌊y·S/H⌋`), so a shard owns whole rows and only the N/S channels at
//!   strip borders cross shards;
//! * one-dimensional fabrics (`height == 1`, i.e. rings and 1-row
//!   meshes) are cut into **column strips** (`shard = ⌊x·S/W⌋`)
//!   instead, since rows cannot be split further.
//!
//! The requested shard count is clamped to the strip dimension's
//! length, so every shard is guaranteed non-empty — `⌊p·S/N⌋` for
//! `p ∈ 0..N` with `S ≤ N` hits every value in `0..S` and is monotone,
//! which gives contiguity for free. Wraparound channels (torus/ring)
//! simply become boundary links between the first and last strip; the
//! engine treats them like any other cross-shard channel.

use super::Topology;

/// A partition of a fabric's routers and nodes into contiguous strips.
///
/// ```
/// use floonoc::topology::{partition::ShardPlan, MemEdge, Topology};
/// let topo = Topology::mesh(4, 4, MemEdge::West);
/// let plan = ShardPlan::new(&topo, 4);
/// assert_eq!(plan.shards, 4); // one row each
/// assert_eq!(plan.router_shard[0], 0);
/// assert_eq!(plan.router_shard[15], 3);
/// // Requests beyond the strip dimension are clamped.
/// assert_eq!(ShardPlan::new(&topo, 99).shards, 4);
/// ```
#[derive(Debug, Clone)]
pub struct ShardPlan {
    /// Effective shard count after clamping to the strip dimension.
    pub shards: usize,
    /// Owning shard of each router, indexed by
    /// [`Topology::router_index`].
    pub router_shard: Vec<usize>,
    /// Owning shard of each node (tiles and memory controllers), indexed
    /// by node id. A node always lives with its host router.
    pub node_shard: Vec<usize>,
}

impl ShardPlan {
    /// Partition `topo` into (at most) `requested` contiguous strips.
    /// `requested` is clamped to `[1, strip dimension length]`.
    pub fn new(topo: &Topology, requested: usize) -> Self {
        let (span, by_row) = if topo.height > 1 {
            (topo.height as usize, true)
        } else {
            (topo.width as usize, false)
        };
        let shards = requested.clamp(1, span);
        let num_routers = topo.width as usize * topo.height as usize;
        let router_shard: Vec<usize> = (0..num_routers)
            .map(|r| {
                let coord = topo.nodes[r].coord;
                let pos = if by_row { coord.y } else { coord.x } as usize;
                pos * shards / span
            })
            .collect();
        let node_shard = topo
            .nodes
            .iter()
            .map(|n| router_shard[topo.router_index(n.coord)])
            .collect();
        ShardPlan {
            shards,
            router_shard,
            node_shard,
        }
    }

    /// Router indices owned by `shard`, ascending.
    pub fn routers_of(&self, shard: usize) -> Vec<usize> {
        (0..self.router_shard.len())
            .filter(|&r| self.router_shard[r] == shard)
            .collect()
    }

    /// Node indices owned by `shard`, ascending.
    pub fn nodes_of(&self, shard: usize) -> Vec<usize> {
        (0..self.node_shard.len())
            .filter(|&n| self.node_shard[n] == shard)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::MemEdge;

    #[test]
    fn row_strips_are_contiguous_and_cover_every_shard() {
        let topo = Topology::mesh(4, 6, MemEdge::None);
        for requested in 1..=8 {
            let plan = ShardPlan::new(&topo, requested);
            assert_eq!(plan.shards, requested.min(6));
            // Monotone in y, constant within a row.
            let mut prev = 0;
            for y in 0..6u8 {
                let row: Vec<usize> = (0..4u8)
                    .map(|x| {
                        plan.router_shard
                            [topo.router_index(crate::flit::Coord::new(x, y))]
                    })
                    .collect();
                assert!(row.iter().all(|&s| s == row[0]), "row {y} split");
                assert!(row[0] >= prev, "shards not monotone");
                prev = row[0];
            }
            // Every shard owns at least one router.
            for s in 0..plan.shards {
                assert!(!plan.routers_of(s).is_empty(), "shard {s} empty");
            }
            assert_eq!(prev, plan.shards - 1, "last shard unused");
        }
    }

    #[test]
    fn one_dimensional_fabrics_cut_by_column() {
        let topo = Topology::ring(8, MemEdge::West);
        let plan = ShardPlan::new(&topo, 4);
        assert_eq!(plan.shards, 4);
        assert_eq!(plan.router_shard, vec![0, 0, 1, 1, 2, 2, 3, 3]);
    }

    #[test]
    fn requests_are_clamped_to_the_strip_dimension() {
        let topo = Topology::mesh(16, 2, MemEdge::None);
        // Two rows: at most two row strips, even for shards = 4.
        assert_eq!(ShardPlan::new(&topo, 4).shards, 2);
        assert_eq!(ShardPlan::new(&topo, 0).shards, 1);
        let dot = Topology::ring(1, MemEdge::None);
        assert_eq!(ShardPlan::new(&dot, 4).shards, 1);
    }

    #[test]
    fn nodes_live_with_their_host_router() {
        let topo = Topology::torus(4, 4, MemEdge::West);
        let plan = ShardPlan::new(&topo, 4);
        for node in &topo.nodes {
            let host = topo.router_index(node.coord);
            assert_eq!(
                plan.node_shard[node.id.0 as usize],
                plan.router_shard[host],
                "node {} strays from its host router",
                node.id.0
            );
        }
        // Memory controllers (ids beyond num_tiles) are included.
        assert!(topo.num_nodes() > topo.num_tiles);
    }

    #[test]
    fn partition_covers_all_routers_exactly_once() {
        let topo = Topology::mesh(5, 5, MemEdge::All);
        let plan = ShardPlan::new(&topo, 3);
        let mut seen = vec![false; 25];
        for s in 0..plan.shards {
            for r in plan.routers_of(s) {
                assert!(!seen[r], "router {r} owned twice");
                seen[r] = true;
            }
        }
        assert!(seen.iter().all(|&b| b));
    }
}
