//! Mesh topology, node naming, address map and route-table generation.
//!
//! A deployment is a `W×H` mesh of compute tiles (one multilink router +
//! NI each) plus memory controllers attached to the free cardinal ports of
//! boundary routers (paper Fig. 4a: "Memory controllers can be placed on
//! the mesh boundary and connected to the NoC").

use crate::flit::{Coord, NodeId};
use crate::router::{xy_route, RouteTable, PORT_E, PORT_LOCAL, PORT_N, PORT_S, PORT_W};

/// What kind of endpoint a node is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeKind {
    /// Compute tile at its own mesh coordinate.
    Tile,
    /// Memory controller attached to the boundary router at `host` via
    /// `attach_port` (the otherwise-unused cardinal port).
    MemCtrl { attach_port: usize },
}

/// Static description of one node.
#[derive(Debug, Clone, Copy)]
pub struct Node {
    pub id: NodeId,
    pub kind: NodeKind,
    /// Mesh coordinate: own coordinate for tiles, the host router's
    /// coordinate for memory controllers.
    pub coord: Coord,
}

/// Which mesh edges get memory controllers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemEdge {
    None,
    West,
    EastWest,
    All,
}

/// Global address-map constants. Each node owns a contiguous window; the
/// paper's tile has a 128 kB SPM, memory controllers front large DRAM
/// regions.
pub const TILE_SPAN: u64 = 1 << 24; // 16 MB window per tile (SPM + MMIO)
pub const SPM_BYTES: u64 = 128 * 1024;
pub const MEM_BASE: u64 = 1 << 40; // memory controllers live high
pub const MEM_SPAN: u64 = 1 << 32; // 4 GB window per controller

/// A full topology: tiles in row-major order, then memory controllers.
#[derive(Debug, Clone)]
pub struct Topology {
    pub width: u8,
    pub height: u8,
    pub nodes: Vec<Node>,
    /// Number of tile nodes (tiles occupy ids `0..num_tiles`).
    pub num_tiles: usize,
}

impl Topology {
    /// Build a `width × height` tile mesh with memory controllers on the
    /// chosen edges (one per boundary router on that edge).
    pub fn mesh(width: u8, height: u8, mem: MemEdge) -> Self {
        assert!(width >= 1 && height >= 1);
        assert!(width as usize * height as usize <= u16::MAX as usize);
        let mut nodes = Vec::new();
        for y in 0..height {
            for x in 0..width {
                nodes.push(Node {
                    id: NodeId((y as u16) * width as u16 + x as u16),
                    kind: NodeKind::Tile,
                    coord: Coord::new(x, y),
                });
            }
        }
        let num_tiles = nodes.len();
        let mut next_id = num_tiles as u16;
        let mut add_mem = |coord: Coord, attach_port: usize, nodes: &mut Vec<Node>| {
            nodes.push(Node {
                id: NodeId(next_id),
                kind: NodeKind::MemCtrl { attach_port },
                coord,
            });
            next_id += 1;
        };
        let west = matches!(mem, MemEdge::West | MemEdge::EastWest | MemEdge::All);
        let east = matches!(mem, MemEdge::EastWest | MemEdge::All);
        let north_south = matches!(mem, MemEdge::All);
        if west {
            for y in 0..height {
                add_mem(Coord::new(0, y), PORT_W, &mut nodes);
            }
        }
        if east {
            for y in 0..height {
                add_mem(Coord::new(width - 1, y), PORT_E, &mut nodes);
            }
        }
        if north_south {
            for x in 0..width {
                add_mem(Coord::new(x, height - 1), PORT_N, &mut nodes);
                add_mem(Coord::new(x, 0), PORT_S, &mut nodes);
            }
        }
        Topology {
            width,
            height,
            nodes,
            num_tiles,
        }
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.0 as usize]
    }

    /// Tile id at mesh coordinate.
    pub fn tile_at(&self, c: Coord) -> NodeId {
        debug_assert!(c.x < self.width && c.y < self.height);
        NodeId((c.y as u16) * self.width as u16 + c.x as u16)
    }

    /// All memory-controller node ids.
    pub fn mem_ctrls(&self) -> Vec<NodeId> {
        self.nodes[self.num_tiles..].iter().map(|n| n.id).collect()
    }

    /// Router index for a mesh coordinate (routers exist per tile).
    pub fn router_index(&self, c: Coord) -> usize {
        (c.y as usize) * self.width as usize + c.x as usize
    }

    // ------------------------------------------------------------ addresses

    /// Base address of a node's memory window.
    pub fn base_addr(&self, id: NodeId) -> u64 {
        match self.node(id).kind {
            NodeKind::Tile => id.0 as u64 * TILE_SPAN,
            NodeKind::MemCtrl { .. } => {
                MEM_BASE + (id.0 as usize - self.num_tiles) as u64 * MEM_SPAN
            }
        }
    }

    /// Address-map lookup: which node owns `addr`?
    pub fn node_of_addr(&self, addr: u64) -> Option<NodeId> {
        if addr >= MEM_BASE {
            let idx = ((addr - MEM_BASE) / MEM_SPAN) as usize;
            let id = self.num_tiles + idx;
            (id < self.nodes.len()).then(|| NodeId(id as u16))
        } else {
            let idx = (addr / TILE_SPAN) as usize;
            (idx < self.num_tiles).then(|| NodeId(idx as u16))
        }
    }

    // -------------------------------------------------------------- routing

    /// Generate the XY route table for the router at `me`: for each
    /// destination node, the output port a flit should take. Memory
    /// controllers route like their host router, plus the final attach-port
    /// exit at the host itself.
    pub fn xy_table(&self, me: Coord) -> RouteTable {
        let ports = self
            .nodes
            .iter()
            .map(|n| {
                if n.coord == me {
                    match n.kind {
                        NodeKind::Tile => PORT_LOCAL as u8,
                        NodeKind::MemCtrl { attach_port } => attach_port as u8,
                    }
                } else {
                    xy_route(me, n.coord) as u8
                }
            })
            .collect();
        RouteTable::new(ports)
    }

    /// XY hop count between two nodes' host routers (for analytical checks).
    pub fn hops(&self, a: NodeId, b: NodeId) -> u32 {
        let ca = self.node(a).coord;
        let cb = self.node(b).coord;
        (ca.x.abs_diff(cb.x) + ca.y.abs_diff(cb.y)) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mesh_node_counts() {
        let t = Topology::mesh(4, 4, MemEdge::West);
        assert_eq!(t.num_tiles, 16);
        assert_eq!(t.num_nodes(), 20);
        assert_eq!(t.mem_ctrls().len(), 4);
    }

    #[test]
    fn tile_coords_row_major() {
        let t = Topology::mesh(3, 2, MemEdge::None);
        assert_eq!(t.node(NodeId(0)).coord, Coord::new(0, 0));
        assert_eq!(t.node(NodeId(2)).coord, Coord::new(2, 0));
        assert_eq!(t.node(NodeId(3)).coord, Coord::new(0, 1));
        assert_eq!(t.tile_at(Coord::new(2, 1)), NodeId(5));
    }

    #[test]
    fn address_map_roundtrip() {
        let t = Topology::mesh(4, 4, MemEdge::EastWest);
        for n in &t.nodes {
            let base = t.base_addr(n.id);
            assert_eq!(t.node_of_addr(base), Some(n.id));
            assert_eq!(t.node_of_addr(base + 0x1000), Some(n.id));
        }
    }

    #[test]
    fn address_map_rejects_unmapped() {
        let t = Topology::mesh(2, 2, MemEdge::None);
        assert_eq!(t.node_of_addr(MEM_BASE), None, "no mem ctrls configured");
        assert_eq!(t.node_of_addr(4 * TILE_SPAN), None, "beyond last tile");
    }

    #[test]
    fn xy_tables_deliver_everywhere() {
        // Follow the generated tables hop by hop from every source to every
        // destination and check arrival within the Manhattan bound.
        let t = Topology::mesh(4, 3, MemEdge::EastWest);
        for src in &t.nodes {
            for dst in &t.nodes {
                if src.id == dst.id {
                    continue;
                }
                let mut cur = src.coord;
                let mut hops = 0;
                loop {
                    let table = t.xy_table(cur);
                    let port = table.lookup(dst.id);
                    match port {
                        PORT_LOCAL => {
                            assert!(matches!(dst.kind, NodeKind::Tile));
                            assert_eq!(cur, dst.coord);
                            break;
                        }
                        PORT_N => cur.y += 1,
                        PORT_S => cur.y -= 1,
                        PORT_E => cur.x += 1,
                        PORT_W => {
                            if let NodeKind::MemCtrl { attach_port: PORT_W } = dst.kind {
                                if cur == dst.coord && cur.x == 0 {
                                    break; // exited to the west mem ctrl
                                }
                            }
                            cur.x -= 1;
                        }
                        p => panic!("unexpected port {p}"),
                    }
                    if port == PORT_E
                        && matches!(dst.kind, NodeKind::MemCtrl { attach_port: PORT_E })
                        && cur.x == t.width
                    {
                        break; // exited east; coord is off-mesh by design
                    }
                    hops += 1;
                    assert!(hops <= t.hops(src.id, dst.id) + 1, "path too long");
                }
            }
        }
    }

    #[test]
    fn mem_ctrl_attach_ports() {
        let t = Topology::mesh(2, 2, MemEdge::EastWest);
        let mems = t.mem_ctrls();
        assert_eq!(mems.len(), 4);
        let west: Vec<_> = mems
            .iter()
            .filter(|&&m| {
                matches!(t.node(m).kind, NodeKind::MemCtrl { attach_port: PORT_W })
            })
            .collect();
        assert_eq!(west.len(), 2);
    }

    #[test]
    fn hops_manhattan() {
        let t = Topology::mesh(4, 4, MemEdge::None);
        assert_eq!(t.hops(NodeId(0), NodeId(15)), 6);
        assert_eq!(t.hops(NodeId(0), NodeId(1)), 1);
        assert_eq!(t.hops(NodeId(5), NodeId(5)), 0);
    }
}
