//! Fabric topologies, node naming, address map and route-table generation.
//!
//! A deployment is a [`TopologyKind`] fabric of compute tiles (one
//! multilink router + NI each) plus memory controllers attached to
//! otherwise-unused router ports:
//!
//! * **mesh** — the paper's `W×H` grid (Fig. 4a); controllers sit on the
//!   free cardinal ports of boundary routers ("Memory controllers can be
//!   placed on the mesh boundary and connected to the NoC");
//! * **torus** — the same grid with wraparound links closing every row
//!   and column; no boundary exists, so routers grow a dedicated sixth
//!   port ([`PORT_MEM`]) for controllers;
//! * **ring** — a 1-D chain of `W` tiles closed by one wraparound link;
//!   the unused north ports host controllers.
//!
//! Routing is table-driven everywhere: [`Topology::route_table`]
//! materializes the fabric's [`RoutingAlgorithm`] into a per-router
//! destination-indexed table, so the router hot loop is identical for
//! all three fabrics. Link construction consumes [`Topology::channels`],
//! the single home of the wraparound rules.

pub mod partition;

use crate::flit::{Coord, NodeId};
use crate::router::{
    RouteTable, RoutingAlgorithm, PORT_E, PORT_LOCAL, PORT_MEM, PORT_N, PORT_S, PORT_W,
};

/// The fabric shapes the simulator can build (the `--topology` axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TopologyKind {
    /// `W×H` grid, no wraparound; XY routing.
    Mesh,
    /// `W×H` grid with wraparound in both dimensions; wrap-minimizing
    /// dimension-ordered routing on radix-6 routers.
    Torus,
    /// 1-D chain of `W` tiles closed into a cycle; shortest-direction
    /// routing. Requires `height == 1`.
    Ring,
}

impl TopologyKind {
    /// Stable lowercase name (CLI/config/report vocabulary).
    pub fn name(&self) -> &'static str {
        match self {
            TopologyKind::Mesh => "mesh",
            TopologyKind::Torus => "torus",
            TopologyKind::Ring => "ring",
        }
    }

    /// Virtual channels this fabric needs for deadlock-free wormhole
    /// routing, and the default a [`crate::noc::NocConfig`] built for it
    /// gets: 1 on meshes (XY is turn-cycle-free), 2 on wrap fabrics
    /// (dateline VCs break each closed row/column's channel cycle — see
    /// `docs/deadlock.md`).
    pub fn default_vcs(&self) -> usize {
        match self {
            TopologyKind::Mesh => 1,
            TopologyKind::Torus | TopologyKind::Ring => 2,
        }
    }
}

/// What kind of endpoint a node is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeKind {
    /// Compute tile at its own fabric coordinate.
    Tile,
    /// Memory controller attached to the router at `host` via
    /// `attach_port` (an otherwise-unused router port: a free boundary
    /// port on meshes, [`PORT_N`] on rings, [`PORT_MEM`] on tori).
    MemCtrl {
        /// Host-router port the controller hangs off.
        attach_port: usize,
    },
}

/// Static description of one node.
#[derive(Debug, Clone, Copy)]
pub struct Node {
    /// Global node id (tiles first, then memory controllers).
    pub id: NodeId,
    /// Tile or memory controller.
    pub kind: NodeKind,
    /// Fabric coordinate: own coordinate for tiles, the host router's
    /// coordinate for memory controllers.
    pub coord: Coord,
}

/// Which positions get memory controllers, interpreted per topology:
///
/// | | mesh | torus | ring |
/// |---|---|---|---|
/// | `West` | west edge (free W ports) | column `x = 0` ([`PORT_MEM`]) | node `x = 0` ([`PORT_N`]) |
/// | `EastWest` | west + east edges | columns `0` and `W/2` (opposite arcs) | nodes `0` and `W/2` (opposite arcs) |
/// | `All` | all four edges | every router | every node |
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemEdge {
    /// No memory controllers.
    None,
    /// One column/position of controllers.
    West,
    /// Two opposite columns/positions (bisection-balanced).
    EastWest,
    /// The maximum placement the fabric supports.
    All,
}

/// Per-tile address window: 16 MB (SPM + MMIO).
pub const TILE_SPAN: u64 = 1 << 24;
/// Scratchpad bytes per tile (the paper's 128 kB SPM).
pub const SPM_BYTES: u64 = 128 * 1024;
/// Base of the memory-controller region (controllers live high).
pub const MEM_BASE: u64 = 1 << 40;
/// Address window per memory controller (4 GB of fronted DRAM).
pub const MEM_SPAN: u64 = 1 << 32;

/// A full topology: tiles in row-major order, then memory controllers.
#[derive(Debug, Clone)]
pub struct Topology {
    /// Fabric shape (decides routing rule, wraparound links, radix and
    /// memory-controller attachment).
    pub kind: TopologyKind,
    /// Tiles per row.
    pub width: u8,
    /// Rows (always 1 for rings).
    pub height: u8,
    /// All nodes: tiles at ids `0..num_tiles`, then controllers.
    pub nodes: Vec<Node>,
    /// Number of tile nodes (tiles occupy ids `0..num_tiles`).
    pub num_tiles: usize,
}

impl Topology {
    /// Build a fabric of `kind` with `width × height` tiles and memory
    /// controllers at the [`MemEdge`] positions.
    ///
    /// ```
    /// use floonoc::topology::{MemEdge, Topology, TopologyKind};
    /// let t = Topology::new(TopologyKind::Torus, 4, 4, MemEdge::West);
    /// assert_eq!(t.num_tiles, 16);
    /// assert_eq!(t.mem_ctrls().len(), 4); // column x = 0
    /// ```
    pub fn new(kind: TopologyKind, width: u8, height: u8, mem: MemEdge) -> Self {
        assert!(width >= 1 && height >= 1);
        assert!(width as usize * height as usize <= u16::MAX as usize);
        assert!(
            kind != TopologyKind::Ring || height == 1,
            "a ring is one-dimensional: height must be 1, got {height}"
        );
        let mut nodes = Vec::new();
        for y in 0..height {
            for x in 0..width {
                nodes.push(Node {
                    id: NodeId((y as u16) * width as u16 + x as u16),
                    kind: NodeKind::Tile,
                    coord: Coord::new(x, y),
                });
            }
        }
        let num_tiles = nodes.len();
        let mut next_id = num_tiles as u16;
        let mut add_mem = |coord: Coord, attach_port: usize, nodes: &mut Vec<Node>| {
            nodes.push(Node {
                id: NodeId(next_id),
                kind: NodeKind::MemCtrl { attach_port },
                coord,
            });
            next_id += 1;
        };
        match kind {
            TopologyKind::Mesh => {
                let west = matches!(mem, MemEdge::West | MemEdge::EastWest | MemEdge::All);
                let east = matches!(mem, MemEdge::EastWest | MemEdge::All);
                let north_south = matches!(mem, MemEdge::All);
                if west {
                    for y in 0..height {
                        add_mem(Coord::new(0, y), PORT_W, &mut nodes);
                    }
                }
                if east {
                    for y in 0..height {
                        add_mem(Coord::new(width - 1, y), PORT_E, &mut nodes);
                    }
                }
                if north_south {
                    for x in 0..width {
                        add_mem(Coord::new(x, height - 1), PORT_N, &mut nodes);
                        add_mem(Coord::new(x, 0), PORT_S, &mut nodes);
                    }
                }
            }
            TopologyKind::Torus => {
                // No boundary exists; controllers use the dedicated
                // radix-6 attach port, at most one per router.
                let mut columns: Vec<u8> = match mem {
                    MemEdge::None => vec![],
                    MemEdge::West => vec![0],
                    // Opposite arcs of the row rings: columns 0 and W-1
                    // would be wrap-adjacent on a torus.
                    MemEdge::EastWest => vec![0, width / 2],
                    MemEdge::All => (0..width).collect(),
                };
                columns.dedup();
                for x in columns {
                    for y in 0..height {
                        add_mem(Coord::new(x, y), PORT_MEM, &mut nodes);
                    }
                }
            }
            TopologyKind::Ring => {
                // North ports are free on the 1-D chain.
                let mut xs: Vec<u8> = match mem {
                    MemEdge::None => vec![],
                    MemEdge::West => vec![0],
                    MemEdge::EastWest => vec![0, width / 2],
                    MemEdge::All => (0..width).collect(),
                };
                xs.dedup();
                for x in xs {
                    add_mem(Coord::new(x, 0), PORT_N, &mut nodes);
                }
            }
        }
        Topology {
            kind,
            width,
            height,
            nodes,
            num_tiles,
        }
    }

    /// Build a `width × height` tile mesh with memory controllers on the
    /// chosen edges (one per boundary router on that edge).
    ///
    /// ```
    /// use floonoc::topology::{MemEdge, Topology};
    /// let t = Topology::mesh(4, 4, MemEdge::West);
    /// assert_eq!((t.num_tiles, t.mem_ctrls().len()), (16, 4));
    /// ```
    pub fn mesh(width: u8, height: u8, mem: MemEdge) -> Self {
        Topology::new(TopologyKind::Mesh, width, height, mem)
    }

    /// Build a `width × height` torus (wraparound in both dimensions).
    pub fn torus(width: u8, height: u8, mem: MemEdge) -> Self {
        Topology::new(TopologyKind::Torus, width, height, mem)
    }

    /// Build a ring of `n` tiles (a 1-D chain closed by a wraparound
    /// link).
    pub fn ring(n: u8, mem: MemEdge) -> Self {
        Topology::new(TopologyKind::Ring, n, 1, mem)
    }

    /// Total node count (tiles + memory controllers).
    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Static description of a node.
    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.0 as usize]
    }

    /// Tile id at a fabric coordinate.
    pub fn tile_at(&self, c: Coord) -> NodeId {
        debug_assert!(c.x < self.width && c.y < self.height);
        NodeId((c.y as u16) * self.width as u16 + c.x as u16)
    }

    /// All memory-controller node ids.
    pub fn mem_ctrls(&self) -> Vec<NodeId> {
        self.nodes[self.num_tiles..].iter().map(|n| n.id).collect()
    }

    /// Router index for a fabric coordinate (routers exist per tile).
    pub fn router_index(&self, c: Coord) -> usize {
        (c.y as usize) * self.width as usize + c.x as usize
    }

    /// Router radix this fabric needs: 5 (local + 4 cardinal) for mesh
    /// and ring, 6 for torus (the [`PORT_MEM`] attach port).
    pub fn router_radix(&self) -> usize {
        match self.kind {
            TopologyKind::Mesh | TopologyKind::Ring => 5,
            TopologyKind::Torus => 6,
        }
    }

    /// Which dimensions of this fabric are closed by a wraparound link:
    /// `(x, y)`. The single home of the wrap rule — both the channel
    /// list ([`Topology::channels`]) and the dateline masks
    /// ([`Topology::dateline_ports`]) derive from it, so they can never
    /// disagree about which links exist. A dimension of length 1 never
    /// wraps (the wrap would be a self-link).
    fn wrap_dims(&self) -> (bool, bool) {
        let wrap_x = match self.kind {
            TopologyKind::Mesh => false,
            TopologyKind::Torus | TopologyKind::Ring => self.width > 1,
        };
        let wrap_y = self.kind == TopologyKind::Torus && self.height > 1;
        (wrap_x, wrap_y)
    }

    /// Bidirectional neighbour channels as
    /// `(router_a, port_on_a, router_b, port_on_b)`: `a`'s port faces
    /// `b` and vice versa, each physical channel listed exactly once.
    /// This is the single place that knows which wraparound links exist:
    ///
    /// * mesh — grid-adjacent pairs only;
    /// * torus — grid pairs plus a wrap pair closing every row (last E →
    ///   first W) and every column (last N → first S);
    /// * ring — the chain pairs plus the single closing wrap pair.
    pub fn channels(&self) -> Vec<(usize, usize, usize, usize)> {
        let w = self.width as usize;
        let h = self.height as usize;
        let idx = |x: usize, y: usize| y * w + x;
        let mut out = Vec::new();
        for y in 0..h {
            for x in 0..w {
                let me = idx(x, y);
                if x + 1 < w {
                    out.push((me, PORT_E, idx(x + 1, y), PORT_W));
                }
                if y + 1 < h {
                    out.push((me, PORT_N, idx(x, y + 1), PORT_S));
                }
            }
        }
        let (wrap_x, wrap_y) = self.wrap_dims();
        if wrap_x {
            for y in 0..h {
                out.push((idx(w - 1, y), PORT_E, idx(0, y), PORT_W));
            }
        }
        if wrap_y {
            for x in 0..w {
                out.push((idx(x, h - 1), PORT_N, idx(x, 0), PORT_S));
            }
        }
        out
    }

    // ------------------------------------------------------------ addresses

    /// Base address of a node's memory window.
    pub fn base_addr(&self, id: NodeId) -> u64 {
        match self.node(id).kind {
            NodeKind::Tile => id.0 as u64 * TILE_SPAN,
            NodeKind::MemCtrl { .. } => {
                MEM_BASE + (id.0 as usize - self.num_tiles) as u64 * MEM_SPAN
            }
        }
    }

    /// Address-map lookup: which node owns `addr`?
    pub fn node_of_addr(&self, addr: u64) -> Option<NodeId> {
        if addr >= MEM_BASE {
            let idx = ((addr - MEM_BASE) / MEM_SPAN) as usize;
            let id = self.num_tiles + idx;
            (id < self.nodes.len()).then(|| NodeId(id as u16))
        } else {
            let idx = (addr / TILE_SPAN) as usize;
            (idx < self.num_tiles).then(|| NodeId(idx as u16))
        }
    }

    // -------------------------------------------------------------- routing

    /// The route-generator rule for this fabric.
    pub fn algorithm(&self) -> RoutingAlgorithm {
        match self.kind {
            TopologyKind::Mesh => RoutingAlgorithm::Xy,
            TopologyKind::Torus => RoutingAlgorithm::TorusXy {
                width: self.width,
                height: self.height,
            },
            TopologyKind::Ring => RoutingAlgorithm::RingShortest { nodes: self.width },
        }
    }

    /// The minimal-adaptive route-generator rule for this fabric: the
    /// adaptive twin of [`Topology::algorithm`] (same escape step, plus
    /// per-destination candidate sets).
    pub fn adaptive_algorithm(&self) -> RoutingAlgorithm {
        match self.kind {
            TopologyKind::Mesh => RoutingAlgorithm::AdaptiveXy,
            TopologyKind::Torus => RoutingAlgorithm::AdaptiveTorus {
                width: self.width,
                height: self.height,
            },
            TopologyKind::Ring => RoutingAlgorithm::AdaptiveRing { nodes: self.width },
        }
    }

    /// Output ports of the router at `me` whose channel is a wraparound
    /// — dateline — link, as a bitmask over port numbers. This is the
    /// geometric complement of [`Topology::channels`]'s wrap rules
    /// (both derive from the same private `wrap_dims` helper, so the
    /// mask can never disagree with the channels that actually exist):
    /// the last router of a wrapping dimension exits it through E/N,
    /// the first through W/S. Always zero on meshes; degenerate
    /// dimensions (length 1) have no wrap channel and contribute no
    /// bits.
    pub fn dateline_ports(&self, me: Coord) -> u8 {
        let (wrap_x, wrap_y) = self.wrap_dims();
        let mut mask = 0u8;
        if wrap_x && me.x == self.width - 1 {
            mask |= 1 << PORT_E;
        }
        if wrap_x && me.x == 0 {
            mask |= 1 << PORT_W;
        }
        if wrap_y && me.y == self.height - 1 {
            mask |= 1 << PORT_N;
        }
        if wrap_y && me.y == 0 {
            mask |= 1 << PORT_S;
        }
        mask
    }

    /// Generate the route table for the router at `me`: for each
    /// destination node, the output port a flit should take, per the
    /// fabric's [`RoutingAlgorithm`], plus the router's dateline mask
    /// ([`Topology::dateline_ports`]) so the VC-aware switch knows which
    /// exits cross a wraparound link. Memory controllers route like
    /// their host router, plus the final attach-port exit at the host
    /// itself.
    pub fn route_table(&self, me: Coord) -> RouteTable {
        let alg = self.algorithm();
        let ports = self
            .nodes
            .iter()
            .map(|n| {
                if n.coord == me {
                    match n.kind {
                        NodeKind::Tile => PORT_LOCAL as u8,
                        NodeKind::MemCtrl { attach_port } => attach_port as u8,
                    }
                } else {
                    alg.step(me, n.coord) as u8
                }
            })
            .collect();
        RouteTable::with_dateline(ports, self.dateline_ports(me))
    }

    /// Generate the **adaptive** route table for the router at `me`:
    /// the escape steps and dateline mask of [`Topology::route_table`],
    /// plus a per-destination candidate mask
    /// ([`RoutingAlgorithm::candidates`]) and the fabric's escape-lane
    /// count ([`TopologyKind::default_vcs`] — the lanes the
    /// deterministic baseline needs, 1 on meshes and 2 on wrap
    /// fabrics). Memory controllers at their host router exit through
    /// the attach port with no alternative, so their candidate mask is
    /// exactly that port.
    pub fn route_table_adaptive(&self, me: Coord) -> RouteTable {
        let alg = self.adaptive_algorithm();
        let mut ports = Vec::with_capacity(self.nodes.len());
        let mut cand = Vec::with_capacity(self.nodes.len());
        for n in &self.nodes {
            let (port, mask) = if n.coord == me {
                let p = match n.kind {
                    NodeKind::Tile => PORT_LOCAL as u8,
                    NodeKind::MemCtrl { attach_port } => attach_port as u8,
                };
                (p, 1u8 << p)
            } else {
                (alg.step(me, n.coord) as u8, alg.candidates(me, n.coord))
            };
            ports.push(port);
            cand.push(mask);
        }
        RouteTable::with_candidates(
            ports,
            self.dateline_ports(me),
            cand,
            self.kind.default_vcs() as u8,
        )
    }

    /// Shortest-path hop count between two nodes' host routers under the
    /// fabric's routing rule (for analytical checks): Manhattan distance
    /// on meshes, per-dimension ring distance on tori and rings.
    pub fn hops(&self, a: NodeId, b: NodeId) -> u32 {
        self.algorithm().distance(self.node(a).coord, self.node(b).coord)
    }

    /// Mean router-to-router hop count over all ordered pairs of
    /// distinct tiles — the expected hop count of uniform-random
    /// tile-to-tile traffic, and the analytic quantity behind the
    /// `scale_topology` comparison (a torus halves the worst-case
    /// distance of the equally-sized mesh).
    pub fn mean_tile_hops(&self) -> f64 {
        let n = self.num_tiles;
        if n < 2 {
            return 0.0;
        }
        let mut total = 0u64;
        for a in 0..n {
            for b in 0..n {
                if a != b {
                    total += self.hops(NodeId(a as u16), NodeId(b as u16)) as u64;
                }
            }
        }
        total as f64 / (n * (n - 1)) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mesh_node_counts() {
        let t = Topology::mesh(4, 4, MemEdge::West);
        assert_eq!(t.num_tiles, 16);
        assert_eq!(t.num_nodes(), 20);
        assert_eq!(t.mem_ctrls().len(), 4);
    }

    #[test]
    fn tile_coords_row_major() {
        let t = Topology::mesh(3, 2, MemEdge::None);
        assert_eq!(t.node(NodeId(0)).coord, Coord::new(0, 0));
        assert_eq!(t.node(NodeId(2)).coord, Coord::new(2, 0));
        assert_eq!(t.node(NodeId(3)).coord, Coord::new(0, 1));
        assert_eq!(t.tile_at(Coord::new(2, 1)), NodeId(5));
    }

    #[test]
    fn address_map_roundtrip() {
        let t = Topology::mesh(4, 4, MemEdge::EastWest);
        for n in &t.nodes {
            let base = t.base_addr(n.id);
            assert_eq!(t.node_of_addr(base), Some(n.id));
            assert_eq!(t.node_of_addr(base + 0x1000), Some(n.id));
        }
    }

    #[test]
    fn address_map_rejects_unmapped() {
        let t = Topology::mesh(2, 2, MemEdge::None);
        assert_eq!(t.node_of_addr(MEM_BASE), None, "no mem ctrls configured");
        assert_eq!(t.node_of_addr(4 * TILE_SPAN), None, "beyond last tile");
    }

    #[test]
    fn xy_tables_deliver_everywhere() {
        // Follow the generated tables hop by hop from every source to every
        // destination and check arrival within the Manhattan bound.
        let t = Topology::mesh(4, 3, MemEdge::EastWest);
        for src in &t.nodes {
            for dst in &t.nodes {
                if src.id == dst.id {
                    continue;
                }
                let mut cur = src.coord;
                let mut hops = 0;
                loop {
                    let table = t.route_table(cur);
                    let port = table.lookup(dst.id);
                    match port {
                        PORT_LOCAL => {
                            assert!(matches!(dst.kind, NodeKind::Tile));
                            assert_eq!(cur, dst.coord);
                            break;
                        }
                        PORT_N => cur.y += 1,
                        PORT_S => cur.y -= 1,
                        PORT_E => cur.x += 1,
                        PORT_W => {
                            if let NodeKind::MemCtrl { attach_port: PORT_W } = dst.kind {
                                if cur == dst.coord && cur.x == 0 {
                                    break; // exited to the west mem ctrl
                                }
                            }
                            cur.x -= 1;
                        }
                        p => panic!("unexpected port {p}"),
                    }
                    if port == PORT_E
                        && matches!(dst.kind, NodeKind::MemCtrl { attach_port: PORT_E })
                        && cur.x == t.width
                    {
                        break; // exited east; coord is off-mesh by design
                    }
                    hops += 1;
                    assert!(hops <= t.hops(src.id, dst.id) + 1, "path too long");
                }
            }
        }
    }

    #[test]
    fn mem_ctrl_attach_ports() {
        let t = Topology::mesh(2, 2, MemEdge::EastWest);
        let mems = t.mem_ctrls();
        assert_eq!(mems.len(), 4);
        let west: Vec<_> = mems
            .iter()
            .filter(|&&m| {
                matches!(t.node(m).kind, NodeKind::MemCtrl { attach_port: PORT_W })
            })
            .collect();
        assert_eq!(west.len(), 2);
    }

    #[test]
    fn hops_manhattan() {
        let t = Topology::mesh(4, 4, MemEdge::None);
        assert_eq!(t.hops(NodeId(0), NodeId(15)), 6);
        assert_eq!(t.hops(NodeId(0), NodeId(1)), 1);
        assert_eq!(t.hops(NodeId(5), NodeId(5)), 0);
    }

    #[test]
    fn torus_hops_wrap() {
        let t = Topology::torus(4, 4, MemEdge::None);
        // Opposite corner: one wrap hop per dimension.
        assert_eq!(t.hops(NodeId(0), NodeId(15)), 2);
        assert_eq!(t.hops(NodeId(0), NodeId(3)), 1, "row wrap");
        assert_eq!(t.hops(NodeId(0), NodeId(12)), 1, "column wrap");
    }

    #[test]
    fn ring_hops_wrap() {
        let t = Topology::ring(6, MemEdge::None);
        assert_eq!(t.hops(NodeId(0), NodeId(5)), 1, "wraparound is shorter");
        assert_eq!(t.hops(NodeId(0), NodeId(3)), 3, "diameter");
        assert_eq!(t.hops(NodeId(1), NodeId(4)), 3);
    }

    #[test]
    fn torus_mem_ctrls_use_dedicated_port() {
        let t = Topology::torus(3, 3, MemEdge::West);
        assert_eq!(t.router_radix(), 6);
        let mems = t.mem_ctrls();
        assert_eq!(mems.len(), 3, "one per router of column 0");
        for m in mems {
            assert!(matches!(t.node(m).kind, NodeKind::MemCtrl { attach_port: PORT_MEM }));
            assert_eq!(t.node(m).coord.x, 0);
        }
    }

    #[test]
    fn ring_mem_ctrls_on_north_ports() {
        let t = Topology::ring(8, MemEdge::EastWest);
        let mems = t.mem_ctrls();
        assert_eq!(mems.len(), 2);
        let xs: Vec<u8> = mems.iter().map(|&m| t.node(m).coord.x).collect();
        assert_eq!(xs, vec![0, 4], "opposite arcs of the ring");
        for m in t.mem_ctrls() {
            assert!(matches!(t.node(m).kind, NodeKind::MemCtrl { attach_port: PORT_N }));
        }
    }

    #[test]
    fn channel_counts_per_topology() {
        // W*H tiles: a mesh has W*(H-1) + H*(W-1) channels; the torus
        // closes every row and column (+W +H); the ring adds exactly 1.
        let mesh = Topology::mesh(4, 3, MemEdge::None);
        assert_eq!(mesh.channels().len(), 4 * 2 + 3 * 3);
        let torus = Topology::torus(4, 3, MemEdge::None);
        assert_eq!(torus.channels().len(), 4 * 2 + 3 * 3 + 4 + 3);
        let ring = Topology::ring(5, MemEdge::None);
        assert_eq!(ring.channels().len(), 4 + 1);
        // The ring's wrap pair connects the chain ends.
        assert!(ring.channels().contains(&(4, PORT_E, 0, PORT_W)));
    }

    #[test]
    fn torus_tables_deliver_everywhere_with_wrap() {
        // Walk the generated tables with wraparound coordinate movement;
        // every pair must arrive in exactly the analytic hop count.
        let t = Topology::torus(4, 3, MemEdge::West);
        let (w, h) = (t.width, t.height);
        for src in &t.nodes {
            for dst in &t.nodes {
                if src.id == dst.id {
                    continue;
                }
                let mut cur = src.coord;
                let mut hops = 0;
                loop {
                    let port = t.route_table(cur).lookup(dst.id);
                    match port {
                        PORT_LOCAL => {
                            assert_eq!(cur, dst.coord);
                            break;
                        }
                        PORT_MEM => {
                            assert!(matches!(dst.kind, NodeKind::MemCtrl { .. }));
                            assert_eq!(cur, dst.coord);
                            break;
                        }
                        PORT_N => cur.y = (cur.y + 1) % h,
                        PORT_S => cur.y = (cur.y + h - 1) % h,
                        PORT_E => cur.x = (cur.x + 1) % w,
                        PORT_W => cur.x = (cur.x + w - 1) % w,
                        p => panic!("unexpected port {p}"),
                    }
                    hops += 1;
                    assert!(hops <= t.hops(src.id, dst.id), "non-minimal path");
                }
                assert_eq!(hops, t.hops(src.id, dst.id));
            }
        }
    }

    /// Dateline masks match the channel rules exactly: mesh routers have
    /// none; torus border routers expose their wrap exits; interior
    /// routers none; length-1 dimensions contribute nothing.
    #[test]
    fn dateline_ports_per_fabric() {
        let mesh = Topology::mesh(4, 4, MemEdge::None);
        for n in &mesh.nodes {
            assert_eq!(mesh.dateline_ports(n.coord), 0, "meshes have no datelines");
        }
        let torus = Topology::torus(4, 3, MemEdge::None);
        assert_eq!(
            torus.dateline_ports(Coord::new(0, 0)),
            (1 << PORT_W) | (1 << PORT_S),
            "corner exits both dimensions through wraps"
        );
        assert_eq!(
            torus.dateline_ports(Coord::new(3, 1)),
            1 << PORT_E,
            "row-end router wraps east only"
        );
        assert_eq!(torus.dateline_ports(Coord::new(1, 1)), 0, "interior router");
        let ring = Topology::ring(6, MemEdge::None);
        assert_eq!(ring.dateline_ports(Coord::new(0, 0)), 1 << PORT_W);
        assert_eq!(ring.dateline_ports(Coord::new(5, 0)), 1 << PORT_E);
        assert_eq!(ring.dateline_ports(Coord::new(2, 0)), 0);
        // Degenerate 1-wide ring: no wrap channel, no dateline.
        let dot = Topology::ring(1, MemEdge::None);
        assert_eq!(dot.dateline_ports(Coord::new(0, 0)), 0);
        // The mask flows into the generated route tables.
        assert!(torus.route_table(Coord::new(3, 1)).crosses_dateline(PORT_E));
        assert!(!torus.route_table(Coord::new(1, 1)).crosses_dateline(PORT_E));
    }

    /// Adaptive tables carry the same escape steps and dateline mask as
    /// the deterministic tables, candidate sets that always include the
    /// escape step, and the fabric's escape-lane count.
    #[test]
    fn adaptive_tables_extend_the_deterministic_tables() {
        for t in [
            Topology::mesh(4, 3, MemEdge::West),
            Topology::torus(4, 4, MemEdge::West),
            Topology::ring(6, MemEdge::EastWest),
        ] {
            for y in 0..t.height {
                for x in 0..t.width {
                    let me = Coord::new(x, y);
                    let det = t.route_table(me);
                    let ada = t.route_table_adaptive(me);
                    assert!(ada.is_adaptive());
                    assert_eq!(ada.escape_lanes() as usize, t.kind.default_vcs());
                    for n in &t.nodes {
                        assert_eq!(
                            ada.lookup(n.id),
                            det.lookup(n.id),
                            "{:?} at {me:?}: escape step diverged for {:?}",
                            t.kind,
                            n.id
                        );
                        let cand = ada.candidates(n.id);
                        assert_ne!(cand, 0);
                        assert_ne!(
                            cand & (1 << ada.lookup(n.id)),
                            0,
                            "{:?} at {me:?}: escape step not a candidate for {:?}",
                            t.kind,
                            n.id
                        );
                    }
                    for p in 0..t.router_radix() {
                        assert_eq!(ada.crosses_dateline(p), det.crosses_dateline(p));
                    }
                }
            }
        }
    }

    /// A memory controller's host router exits through the attach port
    /// with no adaptive alternative.
    #[test]
    fn adaptive_mem_ctrl_candidates_are_the_attach_port() {
        let t = Topology::torus(3, 3, MemEdge::West);
        for m in t.mem_ctrls() {
            let host = t.node(m).coord;
            let ada = t.route_table_adaptive(host);
            assert_eq!(ada.candidates(m), 1 << PORT_MEM);
            assert_eq!(ada.lookup(m), PORT_MEM);
        }
    }

    #[test]
    fn default_vcs_per_kind() {
        assert_eq!(TopologyKind::Mesh.default_vcs(), 1);
        assert_eq!(TopologyKind::Torus.default_vcs(), 2);
        assert_eq!(TopologyKind::Ring.default_vcs(), 2);
    }

    #[test]
    fn torus_beats_mesh_on_mean_hops() {
        for n in [4u8, 5, 6] {
            let mesh = Topology::mesh(n, n, MemEdge::None);
            let torus = Topology::torus(n, n, MemEdge::None);
            assert!(
                torus.mean_tile_hops() < mesh.mean_tile_hops(),
                "{n}x{n}: torus {:.3} !< mesh {:.3}",
                torus.mean_tile_hops(),
                mesh.mean_tile_hops()
            );
        }
        // Spot values against the closed forms: 4x4 mesh sums 320 hops
        // per dimension over 240 ordered pairs (640/240 = 8/3); the 4x4
        // torus halves the per-dimension mean (512/240 = 32/15).
        let mesh = Topology::mesh(4, 4, MemEdge::None);
        assert!((mesh.mean_tile_hops() - 8.0 / 3.0).abs() < 1e-9);
        let torus = Topology::torus(4, 4, MemEdge::None);
        assert!((torus.mean_tile_hops() - 32.0 / 15.0).abs() < 1e-9);
    }
}
