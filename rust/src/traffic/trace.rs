//! Transaction trace recording and replay.
//!
//! A [`TraceRecorder`] captures every transaction a generator issues
//! (cycle, bus, direction, destination, burst geometry) as JSON lines; a
//! [`TraceWorkload`] replays a trace against a live system with the
//! original inter-issue timing — enabling (a) regression workloads pinned
//! to files, (b) cross-configuration comparisons on identical traffic,
//! and (c) external trace import (one JSON object per line).

use std::io::{BufRead, Write};

use anyhow::Context;

use crate::axi::{AxReq, Burst};
use crate::flit::{BusKind, NodeId};
use crate::noc::NocSystem;
use crate::util::json::Json;

/// One recorded transaction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    /// Issue cycle (relative to trace start).
    pub cycle: u64,
    /// Issuing tile.
    pub src: NodeId,
    /// Destination node.
    pub dst: NodeId,
    /// Narrow or wide bus.
    pub bus: BusKind,
    /// Write (true) or read (false).
    pub is_write: bool,
    /// AXI transaction ID.
    pub id: u16,
    /// AxLEN (beats - 1).
    pub len: u8,
    /// AxSIZE (log2 bytes per beat).
    pub size: u8,
    /// Start byte address.
    pub addr: u64,
}

impl TraceEvent {
    /// Serialize as one JSON object (one line of a trace file).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("cycle", Json::Num(self.cycle as f64)),
            ("src", Json::Num(self.src.0 as f64)),
            ("dst", Json::Num(self.dst.0 as f64)),
            (
                "bus",
                Json::Str(
                    match self.bus {
                        BusKind::Narrow => "narrow",
                        BusKind::Wide => "wide",
                    }
                    .into(),
                ),
            ),
            ("write", Json::Bool(self.is_write)),
            ("id", Json::Num(self.id as f64)),
            ("len", Json::Num(self.len as f64)),
            ("size", Json::Num(self.size as f64)),
            ("addr", Json::Num(self.addr as f64)),
        ])
    }

    /// Parse one JSON trace line.
    pub fn from_json(j: &Json) -> crate::Result<TraceEvent> {
        let get_u64 = |k: &str| {
            j.get(k)
                .and_then(Json::as_u64)
                .with_context(|| format!("trace event missing '{k}'"))
        };
        let bus = match j.get("bus").and_then(Json::as_str) {
            Some("narrow") => BusKind::Narrow,
            Some("wide") => BusKind::Wide,
            other => anyhow::bail!("bad bus {other:?}"),
        };
        Ok(TraceEvent {
            cycle: get_u64("cycle")?,
            src: NodeId(get_u64("src")? as u16),
            dst: NodeId(get_u64("dst")? as u16),
            bus,
            is_write: j
                .get("write")
                .and_then(Json::as_bool)
                .context("missing 'write'")?,
            id: get_u64("id")? as u16,
            len: get_u64("len")? as u8,
            size: get_u64("size")? as u8,
            addr: get_u64("addr")?,
        })
    }

    /// Convert to the AXI request this event describes.
    pub fn to_req(&self) -> AxReq {
        AxReq {
            id: self.id,
            addr: self.addr,
            len: self.len,
            size: self.size,
            burst: Burst::Incr,
            atop: false,
        }
    }
}

/// Collects events; serializes one JSON object per line.
#[derive(Debug, Default)]
pub struct TraceRecorder {
    /// The recorded events, in record order.
    pub events: Vec<TraceEvent>,
}

impl TraceRecorder {
    /// An empty recorder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one event.
    pub fn record(&mut self, ev: TraceEvent) {
        self.events.push(ev);
    }

    /// Write the trace as JSON lines.
    pub fn write_to(&self, w: &mut impl Write) -> crate::Result<()> {
        for ev in &self.events {
            writeln!(w, "{}", ev.to_json())?;
        }
        Ok(())
    }

    /// Parse a JSON-lines trace.
    pub fn read_from(r: impl BufRead) -> crate::Result<TraceRecorder> {
        let mut events = Vec::new();
        for (no, line) in r.lines().enumerate() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let j = Json::parse(&line)
                .with_context(|| format!("trace line {}", no + 1))?;
            events.push(TraceEvent::from_json(&j)?);
        }
        Ok(TraceRecorder { events })
    }
}

/// Replays a trace against a live system with original timing; tracks
/// completion like a generator (but across all sources).
pub struct TraceWorkload {
    events: Vec<TraceEvent>,
    next: usize,
    /// Events issued so far.
    pub issued: u64,
    /// Read transactions completed.
    pub completed_reads: u64,
    /// Write transactions completed.
    pub completed_writes: u64,
}

impl TraceWorkload {
    /// Sort events by cycle and prepare for replay.
    pub fn new(mut events: Vec<TraceEvent>) -> Self {
        events.sort_by_key(|e| e.cycle);
        TraceWorkload {
            events,
            next: 0,
            issued: 0,
            completed_reads: 0,
            completed_writes: 0,
        }
    }

    /// Every event has been issued.
    pub fn done_issuing(&self) -> bool {
        self.next >= self.events.len()
    }

    /// Issue all events due at the current cycle (best effort: an event
    /// whose initiator port is full is retried next cycle).
    pub fn step(&mut self, sys: &mut NocSystem) {
        let now = sys.now;
        while self.next < self.events.len() && self.events[self.next].cycle <= now {
            let ev = self.events[self.next];
            let init = match ev.bus {
                BusKind::Narrow => sys.narrow_init(ev.src),
                BusKind::Wide => sys.wide_init(ev.src),
            };
            let ready = if ev.is_write {
                init.aw_ready()
            } else {
                init.ar_ready()
            };
            if !ready {
                break; // retry next cycle, preserving order
            }
            if ev.is_write {
                init.push_aw(ev.to_req(), ev.dst);
            } else {
                init.push_ar(ev.to_req(), ev.dst);
            }
            self.issued += 1;
            self.next += 1;
        }
        // Consume completions (all tiles).
        for idx in 0..sys.nodes.len() {
            if let Some(init) = sys.nodes[idx].narrow.as_mut() {
                while let Some(b) = init.r_out.pop() {
                    if b.last {
                        self.completed_reads += 1;
                    }
                }
                while init.b_out.pop().is_some() {
                    self.completed_writes += 1;
                }
            }
            if let Some(init) = sys.nodes[idx].wide.as_mut() {
                while let Some(b) = init.r_out.pop() {
                    if b.last {
                        self.completed_reads += 1;
                    }
                }
                while init.b_out.pop().is_some() {
                    self.completed_writes += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::NocConfig;
    use crate::topology::TILE_SPAN;

    fn ev(cycle: u64, src: u16, dst: u16, write: bool) -> TraceEvent {
        TraceEvent {
            cycle,
            src: NodeId(src),
            dst: NodeId(dst),
            bus: BusKind::Wide,
            is_write: write,
            id: 1,
            len: 15,
            size: 6,
            addr: dst as u64 * TILE_SPAN + 0x400,
        }
    }

    #[test]
    fn json_roundtrip() {
        let e = ev(42, 0, 1, true);
        let j = e.to_json();
        let back = TraceEvent::from_json(&j).unwrap();
        assert_eq!(e, back);
    }

    #[test]
    fn file_format_roundtrip() {
        let mut rec = TraceRecorder::new();
        rec.record(ev(0, 0, 1, false));
        rec.record(ev(10, 1, 0, true));
        let mut buf = Vec::new();
        rec.write_to(&mut buf).unwrap();
        let back = TraceRecorder::read_from(&buf[..]).unwrap();
        assert_eq!(back.events, rec.events);
    }

    #[test]
    fn rejects_garbage_lines() {
        let r = TraceRecorder::read_from("not json\n".as_bytes());
        assert!(r.is_err());
    }

    #[test]
    fn replay_completes_transactions() {
        let mut sys = NocSystem::new(NocConfig::mesh(2, 1));
        let mut w = TraceWorkload::new(vec![
            ev(0, 0, 1, false),
            ev(5, 0, 1, true),
            ev(20, 1, 0, false),
        ]);
        for _ in 0..2_000 {
            sys.step();
            w.step(&mut sys);
            if w.done_issuing()
                && w.completed_reads + w.completed_writes == 3
                && sys.is_idle()
            {
                break;
            }
        }
        assert_eq!(w.issued, 3);
        assert_eq!(w.completed_reads, 2);
        assert_eq!(w.completed_writes, 1);
        assert!(sys.is_idle());
    }

    #[test]
    fn replay_preserves_issue_order_under_backpressure() {
        // Burst of simultaneous events: port depth 4 forces retries; all
        // must still issue (in order) and complete.
        let mut sys = NocSystem::new(NocConfig::mesh(2, 1));
        let events: Vec<_> = (0..10).map(|i| ev(0, 0, 1, i % 2 == 0)).collect();
        let mut w = TraceWorkload::new(events);
        for _ in 0..10_000 {
            sys.step();
            w.step(&mut sys);
            if w.done_issuing() && sys.is_idle() {
                break;
            }
        }
        assert_eq!(w.issued, 10);
        assert_eq!(w.completed_reads + w.completed_writes, 10);
    }
}
