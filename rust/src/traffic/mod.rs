//! Traffic generators.
//!
//! A [`Generator`] drives one initiator port of one tile with a
//! parameterized workload: destination pattern, burst geometry, injection
//! rate, read/write mix and outstanding-transaction budget. Every
//! generator carries its own [`OrderingMonitor`] (AXI protocol compliance
//! is *checked*, not assumed, in every experiment) and a
//! [`LatencyRecorder`] for per-transaction latency.
//!
//! The paper's Fig. 5 workloads map to:
//!
//! * narrow latency probe — `GenCfg::narrow_probe` (single-beat reads,
//!   NUMNARROWTRANS = 100, to the adjacent tile);
//! * wide interference — `GenCfg::dma_burst` (BURSTLEN = 16 wide bursts,
//!   unidirectional or bidirectional).

use std::collections::VecDeque;

use crate::axi::{AxReq, Burst, OrderingMonitor};
use crate::flit::{BusKind, NodeId};
use crate::ni::Initiator;
use crate::stats::LatencyRecorder;
use crate::topology::{Topology, SPM_BYTES};
use crate::util::rng::Rng;

/// Destination selection.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Pattern {
    /// Always the given node.
    FixedDst(NodeId),
    /// Uniformly random among all *other* tiles.
    UniformTiles,
    /// The nearest neighbour in +x (wrapping at the row end).
    Neighbor,
    /// Uniformly random among boundary memory controllers.
    MemCtrls,
    /// Tornado: the tile half-way around each wrapping dimension
    /// (`x + W/2 mod W`, and `y + H/2 mod H` when `H > 1`). On a torus
    /// or ring this is the classic adversarial pattern for minimal
    /// routing — every flow travels the fabric diameter and the
    /// wraparound links carry half of it; on a mesh the same flows have
    /// no wrap links to use and pile onto the center.
    Tornado,
    /// Uniformly random among the wrapping ±x (and, when `H > 1`, ±y)
    /// neighbours. Unlike [`Pattern::Neighbor`] the -x direction is
    /// exercised too, so on a ring/torus *both* directions of every
    /// wraparound link see traffic.
    NearestNeighbor,
}

/// A periodic on/off issue window: the generator may issue only during
/// the first `active` cycles of each `period`-cycle window, with the
/// window grid shifted by `offset`. Modeling bursty duty-cycled traffic
/// (a DMA that fires every N cycles, a core that polls periodically) —
/// the off phases are exactly the idle stretches the event-driven mode
/// ([`crate::sim::SimMode::Event`]) fast-forwards over.
///
/// The gate is pure arithmetic on the cycle number (no RNG draw), so a
/// duty-cycled workload behaves bit-identically under every
/// [`crate::sim::SimMode`].
#[derive(Debug, Clone, Copy)]
pub struct DutyCycle {
    /// Window length in cycles (must be > 0).
    pub period: u64,
    /// Issue-eligible cycles at the start of each window (1..=period).
    pub active: u64,
    /// Phase shift of the window grid (taken mod `period`); staggering
    /// offsets across tiles decorrelates their bursts.
    pub offset: u64,
}

impl DutyCycle {
    /// Position of `now` inside its window.
    fn phase(&self, now: u64) -> u64 {
        debug_assert!(self.period > 0 && self.active >= 1 && self.active <= self.period);
        let off = self.offset % self.period;
        (now + self.period - off) % self.period
    }

    /// Whether the generator may issue at cycle `now`.
    pub fn in_window(&self, now: u64) -> bool {
        self.phase(now) < self.active
    }

    /// Earliest cycle `>= t` inside an active window — the generator's
    /// scheduled wake for the event calendar.
    pub fn next_active(&self, t: u64) -> u64 {
        let p = self.phase(t);
        if p < self.active {
            t
        } else {
            t + (self.period - p)
        }
    }
}

/// Generator configuration.
#[derive(Debug, Clone)]
pub struct GenCfg {
    /// Which of the tile's two initiators (narrow/wide) this drives.
    pub bus: BusKind,
    /// Destination selection rule.
    pub pattern: Pattern,
    /// Total transactions to issue; `u64::MAX` = run until stopped.
    pub num_txns: u64,
    /// Injection attempts per cycle in (0, 1]: 1.0 = back-to-back.
    pub rate: f64,
    /// AxLEN (beats = len + 1).
    pub burst_len: u8,
    /// AxSIZE (paper: 3 for the 64-bit bus, 6 for the 512-bit bus).
    pub beat_size: u8,
    /// Fraction of writes in the mix (0.0 = read-only).
    pub write_fraction: f64,
    /// Outstanding-transaction budget for this generator.
    pub max_outstanding: u32,
    /// Number of distinct AXI IDs to rotate through.
    pub ids: u16,
    /// RNG seed (mixed with the node id for decorrelated streams).
    pub seed: u64,
    /// Optional periodic issue window (None = always eligible).
    pub duty: Option<DutyCycle>,
}

impl GenCfg {
    /// The paper's latency-sensitive core traffic: single-beat narrow
    /// reads (Fig. 5a's NUMNARROWTRANS = 100 probe).
    pub fn narrow_probe(dst: NodeId, num: u64) -> Self {
        GenCfg {
            bus: BusKind::Narrow,
            pattern: Pattern::FixedDst(dst),
            num_txns: num,
            rate: 1.0,
            burst_len: 0,
            beat_size: 3,
            write_fraction: 0.0,
            max_outstanding: 4,
            ids: 4,
            seed: 0xC0FE,
            duty: None,
        }
    }

    /// The paper's DMA traffic: 16-beat (1 kB) wide bursts (Fig. 5's
    /// BURSTLEN = 16).
    pub fn dma_burst(dst: NodeId, num: u64, write: bool) -> Self {
        GenCfg {
            bus: BusKind::Wide,
            pattern: Pattern::FixedDst(dst),
            num_txns: num,
            rate: 1.0,
            burst_len: 15,
            beat_size: 6,
            write_fraction: if write { 1.0 } else { 0.0 },
            max_outstanding: 8,
            ids: 4,
            seed: 0xD0A,
            duty: None,
        }
    }
}

/// Outstanding-read bookkeeping (per ID, in issue order).
#[derive(Debug, Clone, Copy)]
struct PendingRead {
    issued_at: u64,
    beats: u32,
    beats_seen: u32,
}

/// One traffic generator attached to one initiator port.
#[derive(Debug)]
pub struct Generator {
    /// The workload parameters.
    pub cfg: GenCfg,
    /// Tile this generator injects from.
    pub node: NodeId,
    rng: Rng,
    /// Transactions issued so far.
    pub issued: u64,
    /// Transactions fully completed (last beat / B received).
    pub completed: u64,
    outstanding: u32,
    /// Cycle before which no new issue may happen (rate limiting).
    next_issue_at: u64,
    reads: Vec<VecDeque<PendingRead>>,
    writes: Vec<VecDeque<u64>>,
    id_rr: u16,
    /// Protocol compliance monitor — violations fail the experiment.
    pub monitor: OrderingMonitor,
    /// Per-transaction round-trip latency (issue to last beat).
    pub latencies: LatencyRecorder,
}

impl Generator {
    /// Bind a workload config to a source tile.
    pub fn new(cfg: GenCfg, node: NodeId) -> Self {
        let rng = Rng::new(cfg.seed ^ (node.0 as u64) << 32);
        let ids = cfg.ids as usize;
        Generator {
            node,
            rng,
            issued: 0,
            completed: 0,
            outstanding: 0,
            next_issue_at: 0,
            reads: (0..ids).map(|_| VecDeque::new()).collect(),
            writes: (0..ids).map(|_| VecDeque::new()).collect(),
            id_rr: 0,
            monitor: OrderingMonitor::new(),
            latencies: LatencyRecorder::new(),
            cfg,
        }
    }

    /// All requested transactions issued and completed.
    pub fn done(&self) -> bool {
        self.issued >= self.cfg.num_txns && self.outstanding == 0
    }

    /// Transactions in flight right now.
    pub fn outstanding(&self) -> u32 {
        self.outstanding
    }

    /// The next cycle (generator time — the post-increment clock this
    /// generator is stepped at) at which it could possibly issue, for
    /// the event-driven fast-forward's wake list. `u64::MAX` means "no
    /// scheduled wake": the generator is done, or blocked on responses —
    /// a *reactive* wake, safe to omit because the in-flight responses
    /// keep the networks or memories busy until they arrive.
    ///
    /// Conservative by construction: the true next issue may be later
    /// (rate RNG, backpressure), which only costs a wasted stepped
    /// cycle, never a missed one.
    pub fn next_wake(&self, now: u64) -> u64 {
        if self.issued >= self.cfg.num_txns || self.outstanding >= self.cfg.max_outstanding {
            return u64::MAX;
        }
        let t = self.next_issue_at.max(now + 1);
        match &self.cfg.duty {
            Some(d) => d.next_active(t),
            None => t,
        }
    }

    fn pick_dst(&mut self, topo: &Topology) -> NodeId {
        match self.cfg.pattern {
            Pattern::FixedDst(d) => d,
            Pattern::UniformTiles => loop {
                let cand = NodeId(self.rng.below(topo.num_tiles as u64) as u16);
                if cand != self.node {
                    break cand;
                }
            },
            Pattern::Neighbor => {
                let c = topo.node(self.node).coord;
                let nx = if (c.x as usize + 1) < topo.width as usize {
                    c.x + 1
                } else {
                    0
                };
                topo.tile_at(crate::flit::Coord::new(nx, c.y))
            }
            Pattern::MemCtrls => {
                let mems = topo.mem_ctrls();
                assert!(!mems.is_empty(), "MemCtrls pattern needs controllers");
                *self.rng.choose(&mems)
            }
            Pattern::Tornado => {
                let c = topo.node(self.node).coord;
                let w = topo.width as usize;
                let h = topo.height as usize;
                let nx = ((c.x as usize + w / 2) % w) as u8;
                let ny = if h > 1 {
                    ((c.y as usize + h / 2) % h) as u8
                } else {
                    c.y
                };
                let dst = topo.tile_at(crate::flit::Coord::new(nx, ny));
                assert!(dst != self.node, "tornado is degenerate on a 1x1 fabric");
                dst
            }
            Pattern::NearestNeighbor => {
                let c = topo.node(self.node).coord;
                let (w, h) = (topo.width, topo.height);
                // Widened arithmetic: `x + w - 1` overflows u8 for large
                // rings (w can be up to 255). Fixed buffer: pick_dst runs
                // once per issued transaction — no heap allocation.
                let dec = |v: u8, n: u8| ((v as u16 + n as u16 - 1) % n as u16) as u8;
                let mut cands = [c; 4];
                let mut k = 0;
                if w > 1 {
                    cands[k] = crate::flit::Coord::new((c.x + 1) % w, c.y);
                    cands[k + 1] = crate::flit::Coord::new(dec(c.x, w), c.y);
                    k += 2;
                }
                if h > 1 {
                    cands[k] = crate::flit::Coord::new(c.x, (c.y + 1) % h);
                    cands[k + 1] = crate::flit::Coord::new(c.x, dec(c.y, h));
                    k += 2;
                }
                assert!(k > 0, "nearest-neighbor needs > 1 tile");
                topo.tile_at(*self.rng.choose(&cands[..k]))
            }
        }
    }

    /// One cycle: consume completed responses, then issue new requests.
    pub fn step(&mut self, now: u64, init: &mut Initiator, topo: &Topology) {
        // ------------------------------------------------ response intake
        while let Some(beat) = init.r_out.pop() {
            self.monitor.on_r(beat);
            let nids = self.reads.len();
            let q = &mut self.reads[beat.id as usize % nids];
            let head = q.front_mut().expect("R beat without outstanding read");
            debug_assert_eq!(head.beats_seen, beat.beat, "in-order beats per ID");
            head.beats_seen += 1;
            if beat.last {
                debug_assert_eq!(head.beats_seen, head.beats);
                self.latencies.record(now - head.issued_at);
                q.pop_front();
                self.outstanding -= 1;
                self.completed += 1;
            }
        }
        while let Some(b) = init.b_out.pop() {
            self.monitor.on_b(b);
            let nids = self.writes.len();
            let q = &mut self.writes[b.id as usize % nids];
            let issued_at = q.pop_front().expect("B without outstanding write");
            self.latencies.record(now - issued_at);
            self.outstanding -= 1;
            self.completed += 1;
        }
        // ------------------------------------------------------- issue
        if self.issued >= self.cfg.num_txns
            || self.outstanding >= self.cfg.max_outstanding
            || now < self.next_issue_at
        {
            return;
        }
        // Duty window before the rate draw: off-window cycles consume no
        // RNG state, so the issue sequence is a pure function of which
        // cycles are in-window (identical under every sim mode).
        if let Some(d) = &self.cfg.duty {
            if !d.in_window(now) {
                return;
            }
        }
        if self.cfg.rate < 1.0 && !self.rng.chance(self.cfg.rate) {
            return;
        }
        let is_write = self.rng.chance(self.cfg.write_fraction);
        if is_write && !init.aw_ready() {
            return;
        }
        if !is_write && !init.ar_ready() {
            return;
        }
        let dst = self.pick_dst(topo);
        let id = self.id_rr % self.cfg.ids;
        self.id_rr = self.id_rr.wrapping_add(1);
        let bytes = (self.cfg.burst_len as u64 + 1) << self.cfg.beat_size;
        // Keep each burst inside the destination SPM window and 4 kB-rule
        // compliant: align the offset to the burst size.
        let span = SPM_BYTES.max(bytes);
        let slots = span / bytes;
        let offset = self.rng.below(slots) * bytes;
        let req = AxReq {
            id,
            addr: topo.base_addr(dst) + offset,
            len: self.cfg.burst_len,
            size: self.cfg.beat_size,
            burst: Burst::Incr,
            atop: false,
        };
        debug_assert!(req.is_legal(1 << self.cfg.beat_size));
        if is_write {
            self.monitor.on_aw(req);
            self.writes[id as usize].push_back(now);
            init.push_aw(req, dst);
            self.issued += 1;
            self.outstanding += 1;
        } else {
            self.monitor.on_ar(req);
            self.reads[id as usize].push_back(PendingRead {
                issued_at: now,
                beats: req.beats(),
                beats_seen: 0,
            });
            init.push_ar(req, dst);
            self.issued += 1;
            self.outstanding += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::{NocConfig, NocSystem};
    use crate::topology::MemEdge;

    /// Drive a generator against a live 2×2 system until done.
    fn run_gen(cfg: GenCfg, src: NodeId, max_cycles: u64) -> Generator {
        let mut sys = NocSystem::new(NocConfig::mesh(2, 2));
        let mut g = Generator::new(cfg, src);
        for _ in 0..max_cycles {
            sys.step();
            sys.step_generator(&mut g);
            if g.done() {
                break;
            }
        }
        g
    }

    #[test]
    fn narrow_probe_completes_all() {
        let g = run_gen(GenCfg::narrow_probe(NodeId(1), 20), NodeId(0), 5_000);
        assert!(g.done(), "issued {} completed {}", g.issued, g.completed);
        assert_eq!(g.completed, 20);
        assert!(g.monitor.ok(), "violations: {:?}", g.monitor.violations);
        assert!(g.latencies.mean() >= 18.0);
    }

    #[test]
    fn dma_bursts_complete() {
        let g = run_gen(GenCfg::dma_burst(NodeId(1), 8, false), NodeId(0), 5_000);
        assert!(g.done());
        assert_eq!(g.completed, 8);
        assert!(g.monitor.ok());
    }

    #[test]
    fn dma_writes_complete() {
        let g = run_gen(GenCfg::dma_burst(NodeId(2), 8, true), NodeId(0), 5_000);
        assert!(g.done());
        assert!(g.monitor.ok());
    }

    #[test]
    fn uniform_pattern_reaches_many_tiles() {
        let cfg = GenCfg {
            pattern: Pattern::UniformTiles,
            num_txns: 60,
            ..GenCfg::narrow_probe(NodeId(0), 60)
        };
        let g = run_gen(cfg, NodeId(0), 20_000);
        assert!(g.done());
        assert!(g.monitor.ok());
    }

    #[test]
    fn memctrl_pattern() {
        let mut sys = NocSystem::new(
            NocConfig::mesh(2, 2).with_mem_edge(MemEdge::West),
        );
        let mut g = Generator::new(
            GenCfg {
                pattern: Pattern::MemCtrls,
                ..GenCfg::dma_burst(NodeId(0), 4, false)
            },
            NodeId(3),
        );
        for _ in 0..5_000 {
            sys.step();
            sys.step_generator(&mut g);
            if g.done() {
                break;
            }
        }
        assert!(g.done());
        assert!(g.monitor.ok());
    }

    #[test]
    fn rate_limits_injection() {
        let mut cfg = GenCfg::narrow_probe(NodeId(1), 50);
        cfg.rate = 0.1;
        let g = run_gen(cfg, NodeId(0), 50_000);
        assert!(g.done());
        // At rate 0.1 with latency ~18, issue dominates: mean inter-issue
        // gap ≈ 10 cycles ⇒ total ≫ 50·1. Check the latency stayed near
        // zero-load (no self-congestion).
        assert!(g.latencies.mean() < 30.0);
    }

    #[test]
    fn neighbor_pattern_wraps() {
        let cfg = GenCfg {
            pattern: Pattern::Neighbor,
            ..GenCfg::narrow_probe(NodeId(0), 5)
        };
        // Tile 1 of a 2×2 mesh: neighbour wraps to tile 0 (x: 1 -> 0).
        let g = run_gen(cfg, NodeId(1), 5_000);
        assert!(g.done());
    }

    #[test]
    fn tornado_targets_half_way_around() {
        // On a 4-ring, every tile's tornado destination is x + 2 mod 4.
        let topo = crate::topology::Topology::ring(4, MemEdge::None);
        for x in 0..4u16 {
            let mut g = Generator::new(
                GenCfg {
                    pattern: Pattern::Tornado,
                    ..GenCfg::narrow_probe(NodeId(0), 1)
                },
                NodeId(x),
            );
            assert_eq!(g.pick_dst(&topo), NodeId((x + 2) % 4));
        }
        // On a 4x4 torus it shifts both dimensions.
        let topo = crate::topology::Topology::torus(4, 4, MemEdge::None);
        let mut g = Generator::new(
            GenCfg {
                pattern: Pattern::Tornado,
                ..GenCfg::narrow_probe(NodeId(0), 1)
            },
            NodeId(5), // (1, 1)
        );
        assert_eq!(g.pick_dst(&topo), NodeId(15)); // (3, 3)
    }

    #[test]
    fn nearest_neighbor_picks_wrapping_neighbors_only() {
        let topo = crate::topology::Topology::ring(6, MemEdge::None);
        let mut g = Generator::new(
            GenCfg {
                pattern: Pattern::NearestNeighbor,
                ..GenCfg::narrow_probe(NodeId(0), 1)
            },
            NodeId(0),
        );
        for _ in 0..50 {
            let d = g.pick_dst(&topo);
            assert!(
                d == NodeId(1) || d == NodeId(5),
                "ring neighbours of 0 are 1 and 5 (via wrap), got {d:?}"
            );
        }
    }

    #[test]
    fn tornado_completes_on_torus() {
        // Live run: tornado over a 4x4 torus at the full default
        // outstanding budget — every flow crosses a dateline, riding the
        // fabric's default 2 VCs (the pre-VC budget cap is gone).
        let mut sys = NocSystem::new(crate::noc::NocConfig::torus(4, 4));
        let mut gens: Vec<Generator> = (0..16)
            .map(|i| {
                let mut c = GenCfg::narrow_probe(NodeId(0), 8);
                c.pattern = Pattern::Tornado;
                c.seed = 0x70AD0 + i as u64;
                Generator::new(c, NodeId(i as u16))
            })
            .collect();
        for _ in 0..50_000 {
            sys.step();
            for g in &mut gens {
                sys.step_generator(g);
            }
            if gens.iter().all(Generator::done) {
                break;
            }
        }
        assert!(gens.iter().all(Generator::done), "tornado must drain");
        assert!(gens.iter().all(|g| g.monitor.ok()));
    }

    /// Duty-window arithmetic: phase, membership, and the wake target
    /// used by the event calendar, including a non-zero offset.
    #[test]
    fn duty_cycle_window_arithmetic() {
        let d = DutyCycle {
            period: 8,
            active: 2,
            offset: 3,
        };
        // Windows open at 3, 11, 19, ... for two cycles each.
        for t in 0..24u64 {
            let open = matches!(t % 8, 3 | 4);
            assert_eq!(d.in_window(t), open, "cycle {t}");
        }
        assert_eq!(d.next_active(0), 3);
        assert_eq!(d.next_active(3), 3); // already open
        assert_eq!(d.next_active(4), 4);
        assert_eq!(d.next_active(5), 11); // just closed
        assert_eq!(d.next_active(11), 11);
        // offset is taken mod period.
        let wrapped = DutyCycle {
            period: 8,
            active: 2,
            offset: 11,
        };
        assert_eq!(wrapped.next_active(0), 3);
    }

    /// `next_wake` semantics: a fresh generator wakes at its next
    /// eligible issue cycle (pushed to the duty window's opening); a
    /// finished generator has no scheduled wake at all.
    #[test]
    fn next_wake_respects_duty_and_completion() {
        let mut cfg = GenCfg::narrow_probe(NodeId(1), 4);
        cfg.duty = Some(DutyCycle {
            period: 100,
            active: 5,
            offset: 0,
        });
        let g = Generator::new(cfg, NodeId(0));
        // At now = 10 the window [0, 5) is closed: wake at the next one.
        assert_eq!(g.next_wake(10), 100);
        // Inside a window the wake is simply the next cycle.
        assert_eq!(g.next_wake(2), 3);
        // Without a duty cycle the conservative wake is always now + 1.
        let free = Generator::new(GenCfg::narrow_probe(NodeId(1), 4), NodeId(0));
        assert_eq!(free.next_wake(10), 11);
        // Done (num_txns = 0 is trivially exhausted) ⇒ no wake.
        let done = Generator::new(GenCfg::narrow_probe(NodeId(1), 0), NodeId(0));
        assert_eq!(done.next_wake(10), u64::MAX);
    }

    /// A duty-cycled probe still completes and stays protocol-clean —
    /// the gate delays issues, it must never drop them.
    #[test]
    fn duty_cycled_probe_completes() {
        let mut cfg = GenCfg::narrow_probe(NodeId(1), 12);
        cfg.duty = Some(DutyCycle {
            period: 64,
            active: 4,
            offset: 1,
        });
        let g = run_gen(cfg, NodeId(0), 50_000);
        assert!(g.done(), "issued {} completed {}", g.issued, g.completed);
        assert_eq!(g.completed, 12);
        assert!(g.monitor.ok(), "violations: {:?}", g.monitor.violations);
    }
}
pub mod trace;
