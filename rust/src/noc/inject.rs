//! Per-node injection scheduling with wormhole packet atomicity.
//!
//! A node injects at most one flit per cycle per physical network. While a
//! multi-flit packet (a W burst from an initiator or a multi-beat R burst
//! from the target memory) is streaming, its network's local port is
//! locked to that source until the `last` flit — otherwise flits of
//! different packets would interleave on the link, which wormhole routing
//! forbids.

use crate::flit::FlooFlit;

use super::system::{InjectPlan, NetCounters, Network, NodeNi, NET_REQ, NET_RSP, NET_WIDE};

/// Sources that can hold a local-port wormhole lock.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Src {
    /// Narrow initiator's W-beat stream.
    NarrowInitW,
    /// Wide initiator's W-beat stream.
    WideInitW,
    /// Target's narrow-memory response stream (multi-beat narrow R).
    TgtNarrow,
    /// Target's wide-memory response stream (multi-beat wide R).
    TgtWideR,
}

/// Per-node injection state: one lock slot per network + fairness bits.
#[derive(Debug)]
pub struct InjectState {
    /// Per-network wormhole source lock (an NI stream holds its
    /// network until its packet's last flit).
    pub locks: [Option<Src>; 3],
    /// Alternation between narrow and wide initiators on the request net.
    rr_init: bool,
}

impl InjectState {
    /// Fresh state: no locks held.
    pub fn new() -> Self {
        InjectState {
            locks: [None; 3],
            rr_init: false,
        }
    }

    /// No wormhole lock held on any network — an NI stream mid-packet
    /// *must* be stepped every cycle (it injects a beat whenever its
    /// link accepts), so a held lock blocks the event-mode fast-forward.
    pub fn quiet(&self) -> bool {
        self.locks.iter().all(Option::is_none)
    }
}

impl Default for InjectState {
    fn default() -> Self {
        Self::new()
    }
}

/// One node's local inject ports, one per physical network.
///
/// The injection state machines below are written against this seam so
/// they run unchanged under both engines: the serial engine's
/// [`SerialPort`] offers straight into the network link arenas, while
/// the sharded engine ([`crate::noc::sharded`]) substitutes a port over
/// its shard-local link storage. Both must count the injection and wake
/// the inject link in their engine's active set, exactly as
/// [`SerialPort::offer`] does.
pub trait LocalPort {
    /// Whether this node's inject link into network `net` can accept a
    /// flit this cycle.
    fn can_offer(&self, net: usize) -> bool;
    /// Offer `flit` on this node's inject link into network `net`,
    /// waking the link and counting the injection.
    fn offer(&mut self, net: usize, flit: FlooFlit);
}

/// The serial engine's [`LocalPort`]: direct access to the per-network
/// link arenas and injection counters of one node.
pub struct SerialPort<'a> {
    /// All physical networks of the system.
    pub nets: &'a mut [Network],
    /// Per-network injection/ejection counters.
    pub counters: &'a mut [NetCounters],
    /// The injecting node's index.
    pub node_idx: usize,
}

impl LocalPort for SerialPort<'_> {
    fn can_offer(&self, net: usize) -> bool {
        let lid = self.nets[net].inject[self.node_idx];
        self.nets[net].links[lid].can_offer()
    }

    fn offer(&mut self, net: usize, flit: FlooFlit) {
        let lid = self.nets[net].inject[self.node_idx];
        self.nets[net].links[lid].offer(flit);
        // Commit-time wake edge (NI inject → local link): the gated step
        // loop must visit this link next cycle or the flit would be
        // stranded in a "clock-gated" inject register forever.
        self.nets[net].wake_link(lid);
        self.counters[net].injected += 1;
    }
}

/// Schedule this node's injections for one cycle. The [`InjectPlan`] is
/// the link mode resolved once at system construction, so this per-node
/// per-cycle path carries no mode dispatch of its own.
pub fn inject_node<P: LocalPort>(plan: InjectPlan, node: &mut NodeNi, port: &mut P, now: u64) {
    inject_req_net(node, port, now, plan.shared_w);
    inject_rsp_net(node, port, now, plan.merged_rsp);
    if plan.has_wide_net {
        inject_wide_net(node, port, now);
    }
}

/// Request network: initiator AR/AW issue + W-beat streams.
/// `shared_w`: wide W beats ride this network too (wide-only mode);
/// otherwise they ride NET_WIDE.
fn inject_req_net<P: LocalPort>(node: &mut NodeNi, port: &mut P, now: u64, shared_w: bool) {
    if node.narrow.is_none() || !port.can_offer(NET_REQ) {
        return;
    }
    match node.inj.locks[NET_REQ] {
        Some(Src::NarrowInitW) => {
            let n = node.narrow.as_mut().unwrap();
            if let Some(f) = n.next_w_flit(now) {
                if f.header.last {
                    node.inj.locks[NET_REQ] = None;
                }
                port.offer(NET_REQ, f);
            }
        }
        Some(Src::WideInitW) => {
            debug_assert!(shared_w, "wide W on req net only in wide-only mode");
            let w = node.wide.as_mut().unwrap();
            if let Some(f) = w.next_w_flit(now) {
                if f.header.last {
                    node.inj.locks[NET_REQ] = None;
                }
                port.offer(NET_REQ, f);
            }
        }
        Some(_) => unreachable!("target sources never lock the request net"),
        None => {
            // Alternate which initiator gets first shot (fairness between
            // the latency-critical narrow bus and the wide DMA bus).
            let wide_first = node.inj.rr_init;
            for turn in 0..2 {
                let pick_wide = (turn == 0) == wide_first;
                if pick_wide {
                    // Wide initiator: its W beats ride NET_WIDE (narrow-wide)
                    // or this net (wide-only); AW issue requires that link's
                    // lock to be free.
                    let w_net = if shared_w { NET_REQ } else { NET_WIDE };
                    let w_free = node.inj.locks[w_net].is_none();
                    let w = node.wide.as_mut().unwrap();
                    if let Some(f) = w.try_issue(now, w_free) {
                        if w.streaming_w() {
                            node.inj.locks[w_net] = Some(Src::WideInitW);
                        }
                        port.offer(NET_REQ, f);
                        node.inj.rr_init = !node.inj.rr_init;
                        return;
                    }
                } else {
                    // Narrow initiator: its W beats ride this same network.
                    let n = node.narrow.as_mut().unwrap();
                    if let Some(f) = n.try_issue(now, true) {
                        if n.streaming_w() {
                            node.inj.locks[NET_REQ] = Some(Src::NarrowInitW);
                        }
                        port.offer(NET_REQ, f);
                        node.inj.rr_init = !node.inj.rr_init;
                        return;
                    }
                }
            }
        }
    }
}

/// Response network. In narrow-wide mode it carries narrow R/B and wide B
/// (`merged = false`: wide R goes to NET_WIDE instead). In wide-only mode
/// (`merged = true`) it carries every response.
fn inject_rsp_net<P: LocalPort>(node: &mut NodeNi, port: &mut P, now: u64, merged: bool) {
    if !port.can_offer(NET_RSP) {
        return;
    }
    match node.inj.locks[NET_RSP] {
        Some(Src::TgtNarrow) => {
            if let Some(f) = node.target.pop_narrow(now) {
                if f.header.last {
                    node.inj.locks[NET_RSP] = None;
                }
                port.offer(NET_RSP, f);
            }
        }
        Some(Src::TgtWideR) => {
            debug_assert!(merged, "wide R on rsp net only in wide-only mode");
            if let Some(f) = node.target.pop_wide(now) {
                if f.header.last {
                    node.inj.locks[NET_RSP] = None;
                }
                port.offer(NET_RSP, f);
            }
        }
        Some(_) => unreachable!("initiator sources never lock the response net"),
        None => {
            let n_ready = node.target.narrow_head_ready(now);
            // Wide memory contributes to this net: only B responses in
            // narrow-wide mode, anything in wide-only mode.
            let w_ready = match node.target.wide_head(now) {
                Some(is_read) => merged || !is_read,
                None => false,
            };
            let pick_wide = match (n_ready, w_ready) {
                (true, true) => node.target.flip_rr(),
                (false, true) => true,
                (true, false) => false,
                (false, false) => return,
            };
            let f = if pick_wide {
                node.target.pop_wide(now).unwrap()
            } else {
                node.target.pop_narrow(now).unwrap()
            };
            if !f.header.last {
                node.inj.locks[NET_RSP] = Some(if pick_wide {
                    Src::TgtWideR
                } else {
                    Src::TgtNarrow
                });
            }
            port.offer(NET_RSP, f);
        }
    }
}

/// Wide network (narrow-wide mode only): wide W streams from the initiator
/// and wide R streams from the target share the local port.
fn inject_wide_net<P: LocalPort>(node: &mut NodeNi, port: &mut P, now: u64) {
    if !port.can_offer(NET_WIDE) {
        return;
    }
    match node.inj.locks[NET_WIDE] {
        Some(Src::WideInitW) => {
            let w = node
                .wide
                .as_mut()
                .expect("wide W lock on node without initiator");
            if let Some(f) = w.next_w_flit(now) {
                if f.header.last {
                    node.inj.locks[NET_WIDE] = None;
                }
                port.offer(NET_WIDE, f);
            }
        }
        Some(Src::TgtWideR) => {
            if let Some(f) = node.target.pop_wide(now) {
                if f.header.last {
                    node.inj.locks[NET_WIDE] = None;
                }
                port.offer(NET_WIDE, f);
            }
        }
        Some(_) => unreachable!("narrow sources never touch the wide net"),
        None => {
            // Wide R streams start here; wide W streams start via the AW
            // issue on the request net (which takes this lock directly).
            // Alternate fairness is implicit: W streams pre-empt only when
            // the port is free, and R streams likewise.
            let r_ready = matches!(node.target.wide_head(now), Some(true));
            if r_ready {
                let f = node.target.pop_wide(now).unwrap();
                if !f.header.last {
                    node.inj.locks[NET_WIDE] = Some(Src::TgtWideR);
                }
                port.offer(NET_WIDE, f);
            }
        }
    }
}
