//! The complete NoC system: networks, routers, NIs, tiles and memories
//! wired together and stepped cycle by cycle.
//!
//! This is where the paper's architecture becomes executable: a fabric
//! of tiles (mesh, torus or ring — see `crate::topology`) where every
//! tile hosts a multilink router (one router per physical network), an
//! AXI4 NI (narrow + wide initiator halves and one target), and memory
//! controllers hang off otherwise-unused router ports (free boundary
//! ports on meshes, the dedicated sixth port on tori, north ports on
//! rings).
//!
//! Two link configurations are supported, selected by `LinkMode`:
//!
//! * **NarrowWide** (the paper's proposal): three physical networks —
//!   `narrow_req`, `narrow_rsp`, `wide` — with the Table-I payload
//!   mapping;
//! * **WideOnly** (the paper's Fig. 5 baseline): two wide physical
//!   networks (request + response; the paper keeps request/response
//!   separation even in the baseline to remain deadlock-free), all
//!   payload classes sharing them.

pub mod system;
pub mod inject;
pub mod sharded;

pub use system::{InjectPlan, LinkMode, Network, NocConfig, NocSystem, NET_REQ, NET_RSP, NET_WIDE};
