//! System construction and the per-cycle step loop.

use crate::flit::{ChannelClass, FlooFlit, MsgClass, NodeId, Payload};
use crate::ni::{Initiator, InitiatorCfg, Target, TargetCfg};
use crate::router::{Router, RouterCfg, RoutingKind, PORT_LOCAL};
use crate::sim::{Link, LinkId, SimMode};
use crate::stats::BandwidthMeter;
use crate::topology::{MemEdge, NodeKind, Topology, TopologyKind};
use crate::util::activeset::ActiveSet;
use crate::util::calendar::Calendar;

use super::inject::InjectState;

/// Physical-network index of the (narrow) request network.
pub const NET_REQ: usize = 0;
/// Physical-network index of the (narrow) response network.
pub const NET_RSP: usize = 1;
/// Physical-network index of the dedicated wide network (narrow-wide
/// mode only).
pub const NET_WIDE: usize = 2;

/// Link configuration under evaluation (the Fig. 5 comparison axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkMode {
    /// The paper's proposal: narrow_req + narrow_rsp + wide networks.
    NarrowWide,
    /// Baseline: one wide request network + one wide response network
    /// carrying every payload class.
    WideOnly,
}

/// Per-cycle injection dispatch, hoisted out of the hot loop: all
/// [`LinkMode`] branching is resolved once at construction instead of
/// per node per cycle.
#[derive(Debug, Clone, Copy)]
pub struct InjectPlan {
    /// Wide W beats ride the request network (wide-only mode).
    pub shared_w: bool,
    /// Every response class rides the response network (wide-only mode).
    pub merged_rsp: bool,
    /// A dedicated wide network exists (narrow-wide mode).
    pub has_wide_net: bool,
}

impl InjectPlan {
    /// Resolve the per-cycle dispatch decisions for a link mode.
    pub fn for_mode(mode: LinkMode) -> Self {
        match mode {
            LinkMode::NarrowWide => InjectPlan {
                shared_w: false,
                merged_rsp: false,
                has_wide_net: true,
            },
            LinkMode::WideOnly => InjectPlan {
                shared_w: true,
                merged_rsp: true,
                has_wide_net: false,
            },
        }
    }
}

impl LinkMode {
    /// Number of physical networks this mode instantiates.
    pub fn num_nets(&self) -> usize {
        match self {
            LinkMode::NarrowWide => 3,
            LinkMode::WideOnly => 2,
        }
    }

    /// Which network a payload rides in this mode.
    pub fn net_of(&self, p: &Payload) -> usize {
        match self {
            LinkMode::NarrowWide => match p.phys_link() {
                ChannelClass::NarrowReq => NET_REQ,
                ChannelClass::NarrowRsp => NET_RSP,
                ChannelClass::Wide => NET_WIDE,
            },
            LinkMode::WideOnly => match p.class() {
                MsgClass::Request => NET_REQ,
                MsgClass::Response => NET_RSP,
            },
        }
    }
}

/// Full system configuration.
#[derive(Debug, Clone)]
pub struct NocConfig {
    /// Fabric shape (mesh/torus/ring) — decides routing rule, wraparound
    /// links, router radix and memory-controller attachment.
    pub topology: TopologyKind,
    /// Tiles per row.
    pub width: u8,
    /// Rows of tiles (must be 1 for [`TopologyKind::Ring`]).
    pub height: u8,
    /// Memory-controller placement (interpreted per topology).
    pub mem_edge: MemEdge,
    /// Physical-link configuration under evaluation.
    pub mode: LinkMode,
    /// Step-loop strategy: activity-gated (default), the dense reference
    /// sweep, or gated + event-driven fast-forward
    /// ([`SimMode::Event`]). Cycle-accurate equivalence between all
    /// three is pinned by `tests/gated_equivalence.rs` and
    /// `tests/mode_equivalence_sweep.rs`.
    pub sim_mode: SimMode,
    /// Router input-buffer depth (flits; split across VCs when
    /// `vcs > 1`).
    pub in_buf_depth: usize,
    /// Virtual channels per router-to-router link (JSON `"vcs"`, CLI
    /// `--vcs`). `1` is the paper's VC-free router and the mesh default;
    /// wrap fabrics (torus/ring) default to `2` and use the dateline
    /// rule for deadlock freedom (see `docs/deadlock.md`). Inject/eject
    /// links always carry one lane. At most
    /// [`crate::router::MAX_VCS`].
    ///
    /// ```
    /// use floonoc::noc::NocConfig;
    /// use floonoc::topology::TopologyKind;
    /// // Meshes need no VCs; wrap fabrics get dateline VCs by default.
    /// assert_eq!(NocConfig::mesh(4, 4).vcs, 1);
    /// assert_eq!(NocConfig::torus(4, 4).vcs, 2);
    /// assert_eq!(NocConfig::ring(8).vcs, 2);
    /// assert_eq!(NocConfig::fabric(TopologyKind::Torus, 3, 3).vcs, 2);
    /// // Explicit override via the builder:
    /// assert_eq!(NocConfig::torus(4, 4).with_vcs(1).vcs, 1);
    /// ```
    pub vcs: usize,
    /// Routing discipline (JSON `"routing"`, CLI `--routing`):
    /// deterministic dimension-ordered/dateline routing (the default),
    /// or minimal-adaptive routing over Duato escape lanes
    /// ([`RoutingKind::Adaptive`] — per-cycle congestion-driven output
    /// choice on lanes above the fabric's escape-lane count, see
    /// `docs/deadlock.md`). Adaptive routing needs at least one lane
    /// beyond the escape lanes (`vcs >= default_vcs + 1`, lint FV107);
    /// [`NocConfig::adaptive`] raises `vcs` accordingly.
    pub routing: RoutingKind,
    /// Output register on router links ("elastic buffer", §III-C): the
    /// two-cycle router used by the paper's physical implementation.
    pub output_reg: bool,
    /// Narrow-bus (core) NI initiator sizing.
    pub narrow_init: InitiatorCfg,
    /// Wide-bus (DMA) NI initiator sizing.
    pub wide_init: InitiatorCfg,
    /// Run the static verifier ([`crate::verify::preflight`]) before
    /// building: [`NocSystem::new`] panics on error-severity findings
    /// (CDG deadlock cycles, broken route tables). On by default; clear
    /// it with [`NocConfig::no_verify`] (JSON `"verify": false`, CLI
    /// `--no-verify`) to build a provably unsafe fabric anyway — e.g.
    /// to demonstrate the deadlock the verifier predicts.
    pub verify: bool,
    /// Keep the gating-invariant scans ("occupied ⇒ active",
    /// "buffered ⇒ woken") in release builds too (CLI
    /// `--check-invariants`; `repro verify --deep` uses this for its
    /// gated warm-up epoch). Debug builds always scan; the flag only
    /// costs anything in release mode.
    pub check_invariants: bool,
    /// Worker threads for batch runs (JSON `"shards"`, CLI `--shards`).
    /// `1` (the default) is the unchanged serial engine. Above 1,
    /// `TiledWorkload::run_to_completion` partitions the fabric into
    /// contiguous spatial strips ([`crate::topology::partition`]) and
    /// steps them concurrently under a phased cycle barrier
    /// ([`crate::noc::sharded`]). Deterministic: digests are
    /// byte-identical to the serial engine at any shard count (the
    /// request is clamped to the fabric's strip dimension). Per-cycle
    /// stepping ([`NocSystem::step`], `TiledWorkload::step`,
    /// `run_with_watchdog`) always runs serially regardless of this
    /// knob.
    pub shards: usize,
    /// Tile SPM target timing.
    pub spm: TargetCfg,
    /// Memory-controller target timing.
    pub mem_ctrl: TargetCfg,
}

impl Default for NocConfig {
    fn default() -> Self {
        NocConfig {
            topology: TopologyKind::Mesh,
            width: 2,
            height: 1,
            mem_edge: MemEdge::None,
            mode: LinkMode::NarrowWide,
            sim_mode: SimMode::Gated,
            in_buf_depth: 2,
            vcs: 1,
            routing: RoutingKind::default(),
            output_reg: true,
            narrow_init: InitiatorCfg::narrow_default(),
            wide_init: InitiatorCfg::wide_default(),
            verify: true,
            check_invariants: false,
            shards: 1,
            spm: TargetCfg::spm_default(),
            mem_ctrl: TargetCfg::mem_ctrl_default(),
        }
    }
}

impl NocConfig {
    /// A `width × height` mesh with otherwise-default parameters.
    pub fn mesh(width: u8, height: u8) -> Self {
        NocConfig {
            width,
            height,
            ..Default::default()
        }
    }

    /// A `width × height` torus (wraparound rows and columns), with the
    /// fabric's default dateline VC count (2 — deadlock-free wormhole
    /// wrap traffic out of the box).
    pub fn torus(width: u8, height: u8) -> Self {
        NocConfig {
            topology: TopologyKind::Torus,
            width,
            height,
            vcs: TopologyKind::Torus.default_vcs(),
            ..Default::default()
        }
    }

    /// A ring of `n` tiles (1-D chain closed by one wraparound link),
    /// with the fabric's default dateline VC count (2).
    pub fn ring(n: u8) -> Self {
        NocConfig {
            topology: TopologyKind::Ring,
            width: n,
            height: 1,
            vcs: TopologyKind::Ring.default_vcs(),
            ..Default::default()
        }
    }

    /// A fabric of `kind` with `width × height` tiles. The tile-count
    /// semantics hold for every kind: a ring request lays the same
    /// `width × height` tiles out as one closed chain (so the result is
    /// always a valid config, never a deferred height assert). Each kind
    /// gets its default VC count (1 for mesh, 2 for wrap fabrics).
    pub fn fabric(kind: TopologyKind, width: u8, height: u8) -> Self {
        match kind {
            TopologyKind::Ring => {
                let tiles = width as usize * height as usize;
                assert!(tiles <= u8::MAX as usize, "ring fabric supports at most 255 tiles");
                NocConfig::ring(tiles as u8)
            }
            TopologyKind::Torus => NocConfig::torus(width, height),
            TopologyKind::Mesh => NocConfig::mesh(width, height),
        }
    }

    /// Switch to the wide-only baseline link configuration.
    pub fn wide_only(mut self) -> Self {
        self.mode = LinkMode::WideOnly;
        self
    }

    /// Set the memory-controller placement.
    pub fn with_mem_edge(mut self, edge: MemEdge) -> Self {
        self.mem_edge = edge;
        self
    }

    /// Select the step-loop strategy (gated vs dense reference).
    pub fn with_sim_mode(mut self, mode: SimMode) -> Self {
        self.sim_mode = mode;
        self
    }

    /// Set the virtual-channel count per router-to-router link (see
    /// [`NocConfig::vcs`]). Panics outside `1..=MAX_VCS`.
    ///
    /// ```
    /// use floonoc::noc::{NocConfig, NocSystem};
    /// // A 3×3 torus forced back to 1 VC still builds: every dimension
    /// // is shorter than 4, so the verifier proves its CDG acyclic even
    /// // without dateline lanes. A mesh raised to 2 VCs also builds.
    /// let _ = NocSystem::new(NocConfig::torus(3, 3).with_vcs(1));
    /// let _ = NocSystem::new(NocConfig::mesh(2, 2).with_vcs(2));
    /// // A 4×4 torus at 1 VC is rejected by the preflight; building it
    /// // anyway requires the explicit escape hatch (`no_verify`).
    /// ```
    pub fn with_vcs(mut self, vcs: usize) -> Self {
        assert!(
            (1..=crate::router::MAX_VCS).contains(&vcs),
            "vcs must be in 1..={}, got {vcs}",
            crate::router::MAX_VCS
        );
        self.vcs = vcs;
        self
    }

    /// Switch to minimal-adaptive routing on Duato escape lanes (see
    /// [`NocConfig::routing`]). Raises `vcs` to the fabric's minimum
    /// for adaptivity (`default_vcs + 1`: one adaptive lane above the
    /// escape lanes — 2 on meshes, 3 on wrap fabrics) when the current
    /// value is below it; an explicit higher [`NocConfig::with_vcs`]
    /// is kept.
    ///
    /// ```
    /// use floonoc::noc::NocConfig;
    /// use floonoc::router::RoutingKind;
    /// let cfg = NocConfig::torus(4, 4).adaptive();
    /// assert_eq!((cfg.routing, cfg.vcs), (RoutingKind::Adaptive, 3));
    /// assert_eq!(NocConfig::mesh(4, 4).adaptive().vcs, 2);
    /// assert_eq!(NocConfig::torus(4, 4).with_vcs(4).adaptive().vcs, 4);
    /// ```
    pub fn adaptive(mut self) -> Self {
        self.routing = RoutingKind::Adaptive;
        self.vcs = self.vcs.max(self.topology.default_vcs() + 1);
        self
    }

    /// Switch to the dense reference step loop (differential testing).
    pub fn dense(self) -> Self {
        self.with_sim_mode(SimMode::Dense)
    }

    /// Switch to event-driven fast-forward stepping ([`SimMode::Event`]):
    /// gated sweeps plus calendar-driven jumps over provably idle
    /// stretches. Byte-identical statistics to the other modes.
    pub fn event(self) -> Self {
        self.with_sim_mode(SimMode::Event)
    }

    /// Disable the mandatory build preflight (see [`NocConfig::verify`])
    /// — the escape hatch for deliberately building a configuration the
    /// static verifier rejects.
    ///
    /// ```
    /// use floonoc::noc::{NocConfig, NocSystem};
    /// // A 4×4 torus at 1 VC has a cyclic channel dependency graph;
    /// // the preflight refuses it, but the escape hatch builds it.
    /// let cfg = NocConfig::torus(4, 4).with_vcs(1).no_verify();
    /// let _ = NocSystem::new(cfg);
    /// ```
    pub fn no_verify(mut self) -> Self {
        self.verify = false;
        self
    }

    /// Keep the gating-invariant scans on in release builds (see
    /// [`NocConfig::check_invariants`]).
    pub fn with_invariant_checks(mut self) -> Self {
        self.check_invariants = true;
        self
    }

    /// Set the worker-thread count for batch runs (see
    /// [`NocConfig::shards`]). Panics on 0 — ask for 1 to force the
    /// serial engine.
    pub fn with_shards(mut self, shards: usize) -> Self {
        assert!(shards >= 1, "shards must be >= 1, got {shards}");
        self.shards = shards;
        self
    }
}

/// One physical network: one router per tile, the fabric's channels
/// (including wraparound links) plus per-node local ports.
#[derive(Debug)]
pub struct Network {
    /// Link arena; routers hold [`LinkId`]s into it.
    pub links: Vec<Link<FlooFlit>>,
    /// One router per tile coordinate, row-major.
    pub routers: Vec<Router>,
    /// Per node: NI -> router link.
    pub inject: Vec<LinkId>,
    /// Per node: router -> NI link.
    pub eject: Vec<LinkId>,
    /// Consumer router per link (`None` for eject links, whose consumer
    /// is the node's NI). This is the static wake-edge table of the
    /// gated step loop: when a link's deliver leaves its input buffer
    /// non-empty, the sink router is woken for this cycle.
    pub(crate) link_sink: Vec<Option<usize>>,
    /// Clock-gating bitmap: links that may hold flits. Invariant — every
    /// link with `occupancy() > 0` has its bit set (the set may lag on
    /// the quiescent side; stale bits are pruned by the next sweep).
    pub(crate) link_active: ActiveSet,
    /// Routers to step *this* cycle; rebuilt from link wake edges every
    /// cycle (a router runs iff one of its input buffers holds a flit).
    pub(crate) router_wake: ActiveSet,
    /// Run the gating-invariant scans even in release builds (from
    /// [`NocConfig::check_invariants`]; debug builds always scan).
    pub(crate) check_invariants: bool,
}

impl Network {
    /// Mark a link as holding flits (wake edge at commit time). Called
    /// for every producer-side [`Link::offer`]: router commits wake
    /// their output links internally via [`Network::route_gated`]; NI
    /// injection calls this directly.
    #[inline]
    pub(crate) fn wake_link(&mut self, lid: LinkId) {
        self.link_active.insert(lid);
    }

    /// Number of links currently in the active set (instrumentation:
    /// the activity factor the gated loop actually pays for).
    pub fn active_link_count(&self) -> usize {
        self.link_active.count()
    }

    /// Is `lid` currently in the active set? (test/instrumentation)
    pub fn link_is_active(&self, lid: LinkId) -> bool {
        self.link_active.contains(lid)
    }

    /// Phase 1 of an activity-gated cycle: the **link sweep**. Only
    /// links in the active set deliver. A link whose buffer holds flits
    /// afterwards wakes its sink router (filling `router_wake` for
    /// [`Network::route_gated`]); a link left with zero occupancy is
    /// pruned from the set (it can only re-enter via an offer-time wake
    /// edge).
    pub(crate) fn deliver_gated(&mut self) {
        let Network {
            links,
            link_sink,
            link_active,
            router_wake,
            check_invariants,
            ..
        } = self;
        // Gating invariant (debug builds, or any build with
        // `--check-invariants`): no occupied link may be missing from
        // the active set — a violation means an offer path without a
        // wake edge, which would strand flits silently.
        if cfg!(debug_assertions) || *check_invariants {
            for (lid, l) in links.iter().enumerate() {
                assert!(
                    l.is_quiescent() || link_active.contains(lid),
                    "occupied link {lid} missing from the active set"
                );
            }
        }
        router_wake.clear();
        for wi in 0..link_active.num_words() {
            // Copy the word, then walk its set bits: the sweep only
            // removes bits of links it has already visited, so mutating
            // the live set underneath the copy is safe.
            let mut w = link_active.word(wi);
            while w != 0 {
                let lid = (wi << 6) + w.trailing_zeros() as usize;
                w &= w - 1;
                let s = links[lid].deliver();
                if s.consumer_ready {
                    if let Some(r) = link_sink[lid] {
                        router_wake.insert(r);
                    }
                }
                if !s.still_active {
                    link_active.remove(lid);
                }
            }
        }
    }

    /// Phase 2 of an activity-gated cycle: the **router sweep**. Only
    /// routers woken by [`Network::deliver_gated`] step. Every output
    /// port that accepted a flit during commit wakes its output link so
    /// next cycle's link sweep visits it.
    ///
    /// Skipped components are exactly those whose step would have been
    /// a no-op (empty links return immediately; routers with empty
    /// input buffers never pass the compute phase), so all statistics
    /// are byte-identical to dense stepping.
    pub(crate) fn route_gated(&mut self) {
        let Network {
            links,
            routers,
            link_active,
            router_wake,
            check_invariants,
            ..
        } = self;
        // Wake-completeness invariant (debug builds, or any build with
        // `--check-invariants`): every router with a non-empty input
        // buffer must have been woken by the link sweep — a miss here
        // means a consumer_ready edge was lost and a flit would rot in
        // an input buffer.
        if cfg!(debug_assertions) || *check_invariants {
            for (r, router) in routers.iter().enumerate() {
                assert!(
                    router.is_quiescent(links) || router_wake.contains(r),
                    "router {r} has buffered input but was not woken"
                );
            }
        }
        // The router sweep never mutates `router_wake` itself (only
        // `link_active` and the routers), so plain iteration is safe.
        for r in router_wake.iter() {
            let act = routers[r].step(links);
            // Wake-precision converse: the link sweep only wakes routers
            // whose input buffers hold flits, so a woken router must see
            // at least one input. A spurious wake is harmless for stats
            // (the step no-ops) but means an edge fired wrongly.
            debug_assert!(act.any_input, "woken router {r} saw no input");
            let mut m = act.woke_outputs;
            while m != 0 {
                let o = m.trailing_zeros() as usize;
                m &= m - 1;
                let lid = routers[r].out_links[o]
                    .expect("commit woke an unconnected output port");
                link_active.insert(lid);
            }
        }
    }

    /// Phase 1 of a dense reference cycle: every link delivers.
    pub(crate) fn deliver_dense(&mut self) {
        for l in &mut self.links {
            l.deliver();
        }
    }

    /// Phase 2 of a dense reference cycle: every router steps.
    pub(crate) fn route_dense(&mut self) {
        for r in &mut self.routers {
            r.step(&mut self.links);
        }
    }

}

/// Per-node NI bundle: initiators exist on tiles only.
#[derive(Debug)]
pub struct NodeNi {
    /// Narrow-bus initiator (tiles only).
    pub narrow: Option<Initiator>,
    /// Wide-bus initiator (tiles only).
    pub wide: Option<Initiator>,
    /// The node's target NI (SPM on tiles, DRAM front on controllers).
    pub target: Target,
    /// Per-network injection arbitration state.
    pub inj: InjectState,
}

/// Aggregate flit statistics per network.
#[derive(Debug, Clone, Default)]
pub struct NetCounters {
    /// Flits offered into inject links since reset.
    pub injected: u64,
    /// Flits popped from eject links since reset.
    pub ejected: u64,
}

/// The complete simulated system.
pub struct NocSystem {
    /// The deployed fabric (tiles, controllers, address map, tables).
    pub topo: Topology,
    /// The configuration the system was built from.
    pub cfg: NocConfig,
    /// One [`Network`] per physical link class of the mode.
    pub nets: Vec<Network>,
    /// Per-node NI bundles, indexed by node id.
    pub nodes: Vec<NodeNi>,
    /// Hoisted link-mode dispatch for the injection hot path.
    pub(crate) plan: InjectPlan,
    /// Current simulation cycle.
    pub now: u64,
    /// Per-network, per-node ejection bandwidth meters: every consumed
    /// ejection is observed with 512 useful bits for WideR/WideW flits and
    /// 0 bits for anything else sharing that link — the Fig. 5b
    /// effective-bandwidth instrument. Indexed `[net][node]`.
    pub eject_meters: Vec<Vec<BandwidthMeter>>,
    /// Flit-conservation counters per network (drive the idle skip).
    pub counters: Vec<NetCounters>,
    /// Scheduled memory-retirement cycles ([`SimMode::Event`] only):
    /// every target memory accept registers its `ready_at` here so the
    /// fast-forward knows when a quiet system next becomes active on its
    /// own. Entries are pruned lazily (see [`Calendar`]).
    pub(crate) calendar: Calendar,
    /// Earliest generator wake folded by [`Self::step_generator`] during
    /// the *previous* cycle's generator pass, in generator time (the
    /// post-increment clock generators are stepped at). `u64::MAX` when
    /// no generator reported a finite wake; reset at the end of every
    /// [`Self::step`]. Initialized to 0 so no fast-forward can fire
    /// before the first full generator pass has reported in.
    pub(crate) gen_wake_min: u64,
    /// Step invocations actually executed (every [`Self::step`] call).
    /// Deliberately **not** part of the equivalence digest: it measures
    /// the mechanism (how much work the mode did), not the simulated
    /// behaviour.
    pub stepped_cycles: u64,
    /// Cycles jumped over by event-driven fast-forward. Always 0 outside
    /// [`SimMode::Event`]. `stepped_cycles + skipped_cycles == now` for
    /// a system driven purely through [`Self::step`]. Not in the digest,
    /// like [`Self::stepped_cycles`].
    pub skipped_cycles: u64,
}

impl NocSystem {
    /// Build the complete system (topology, per-network routers and
    /// links, per-node NIs) for `cfg`.
    ///
    /// # Panics
    ///
    /// Unless `cfg.verify` is cleared ([`NocConfig::no_verify`], CLI
    /// `--no-verify`), the static verifier ([`crate::verify::preflight`])
    /// runs first and this constructor panics — printing the full
    /// report — on any error-severity finding (a channel-dependency
    /// cycle, a broken route table). Warnings never panic; the CLI
    /// front end surfaces them separately.
    pub fn new(cfg: NocConfig) -> Self {
        if cfg.verify {
            let report = crate::verify::preflight(&cfg);
            if report.has_errors() {
                panic!(
                    "NocConfig failed static verification (see docs/verification.md):\n\
                     {report}\n\
                     use NocConfig::no_verify() (CLI: --no-verify) to build anyway"
                );
            }
        }
        let topo = Topology::new(cfg.topology, cfg.width, cfg.height, cfg.mem_edge);
        let nets = (0..cfg.mode.num_nets())
            .map(|_| build_network(&topo, &cfg))
            .collect();
        let nodes = topo
            .nodes
            .iter()
            .map(|n| match n.kind {
                NodeKind::Tile => NodeNi {
                    narrow: Some(Initiator::new(cfg.narrow_init.clone(), n.id)),
                    wide: Some(Initiator::new(cfg.wide_init.clone(), n.id)),
                    target: Target::new(cfg.spm.clone(), n.id),
                    inj: InjectState::new(),
                },
                NodeKind::MemCtrl { .. } => NodeNi {
                    narrow: None,
                    wide: None,
                    target: Target::new(cfg.mem_ctrl.clone(), n.id),
                    inj: InjectState::new(),
                },
            })
            .collect();
        let eject_meters = (0..cfg.mode.num_nets())
            .map(|_| topo.nodes.iter().map(|_| BandwidthMeter::new(512)).collect())
            .collect();
        let counters = vec![NetCounters::default(); cfg.mode.num_nets()];
        NocSystem {
            topo,
            nets,
            nodes,
            plan: InjectPlan::for_mode(cfg.mode),
            now: 0,
            eject_meters,
            counters,
            calendar: Calendar::new(),
            gen_wake_min: 0,
            stepped_cycles: 0,
            skipped_cycles: 0,
            cfg,
        }
    }

    /// Flits currently inside network `n` (anywhere in its links). Exact
    /// by flit conservation: flits enter a network only through inject
    /// links (counted at offer) and leave only through eject pops.
    #[inline]
    pub fn in_flight(&self, n: usize) -> u64 {
        self.counters[n].injected - self.counters[n].ejected
    }

    /// Borrow a tile's narrow initiator (panics for memory controllers).
    pub fn narrow_init(&mut self, node: NodeId) -> &mut Initiator {
        self.nodes[node.0 as usize]
            .narrow
            .as_mut()
            .expect("node has no narrow initiator")
    }

    /// Borrow a tile's wide initiator (panics for memory controllers).
    pub fn wide_init(&mut self, node: NodeId) -> &mut Initiator {
        self.nodes[node.0 as usize]
            .wide
            .as_mut()
            .expect("node has no wide initiator")
    }

    /// Step a traffic generator against its tile's initiator, splitting
    /// the borrow between the topology (read) and the NI (write).
    pub fn step_generator(&mut self, g: &mut crate::traffic::Generator) {
        let now = self.now;
        let topo = &self.topo;
        let node = &mut self.nodes[g.node.0 as usize];
        let init = match g.cfg.bus {
            crate::flit::BusKind::Narrow => node.narrow.as_mut(),
            crate::flit::BusKind::Wide => node.wide.as_mut(),
        }
        .expect("generator attached to node without initiator");
        g.step(now, init, topo);
        if self.cfg.sim_mode == SimMode::Event {
            // Fold this generator's next interesting cycle into the wake
            // horizon the next step()'s fast-forward consults. Generators
            // run at the post-increment clock, so the fold happens after
            // `now += 1` and before the following step — exactly the
            // window `gen_wake_min` is valid for.
            self.gen_wake_min = self.gen_wake_min.min(g.next_wake(now));
        }
    }

    /// Advance one clock cycle. Under [`SimMode::Event`] this may first
    /// fast-forward `now` over a provably idle stretch (see
    /// `try_fast_forward`), then executes one real cycle at the
    /// (possibly jumped-to) time.
    ///
    /// The cycle is composed of four phase helpers — [`Self::pre_step`],
    /// [`Self::link_phase`], [`Self::router_phase`], [`Self::ni_phase`] —
    /// so the profiler (`perf::profile`) can time each phase separately
    /// while production runs pay only straight-line calls.
    pub fn step(&mut self) {
        self.pre_step();
        self.link_phase();
        self.router_phase();
        self.ni_phase();
    }

    /// Phase 0: event-mode fast-forward and cycle bookkeeping. Must run
    /// exactly once per cycle, before any component is stepped.
    pub(crate) fn pre_step(&mut self) {
        if self.cfg.sim_mode == SimMode::Event {
            self.try_fast_forward();
        }
        self.stepped_cycles += 1;
    }

    /// Phase 1: every network's link sweep. Gated mode (default) sweeps
    /// only the active-set bits — cost tracks activity, not fabric size;
    /// its empty-set case subsumes the whole-network idle skip. Event
    /// mode runs the same gated sweep (fast-forward changed only `now`,
    /// never component state). Dense mode is the reference sweep, still
    /// guarded by the flit-conservation skip (a network with no flit in
    /// flight has nothing to deliver — the sweep is a no-op by
    /// construction).
    ///
    /// Running *all* networks' link sweeps before *any* network's router
    /// sweep is digest-equivalent to interleaving them per network:
    /// networks share no links, routers, or counters within phases 1–2
    /// (counters change only in phase 3). The sharded engine already
    /// orders its phases this way.
    pub(crate) fn link_phase(&mut self) {
        match self.cfg.sim_mode {
            SimMode::Gated | SimMode::Event => {
                for net in &mut self.nets {
                    net.deliver_gated();
                }
            }
            SimMode::Dense => {
                for n in 0..self.nets.len() {
                    if self.in_flight(n) == 0 {
                        continue;
                    }
                    self.nets[n].deliver_dense();
                }
            }
        }
    }

    /// Phase 2: every network's router sweep. The dense-mode
    /// flit-conservation skip is recomputed here; that is safe because
    /// the counters it reads change only in phase 3, so both phases see
    /// the same verdict (a skipped network's router sweep would see
    /// empty inputs and no-op; wormhole locks and arbiter state are
    /// untouched either way).
    pub(crate) fn router_phase(&mut self) {
        match self.cfg.sim_mode {
            SimMode::Gated | SimMode::Event => {
                for net in &mut self.nets {
                    net.route_gated();
                }
            }
            SimMode::Dense => {
                for n in 0..self.nets.len() {
                    if self.in_flight(n) == 0 {
                        continue;
                    }
                    self.nets[n].route_dense();
                }
            }
        }
    }

    /// Phase 3: NIs terminate and inject, then the clock advances.
    pub(crate) fn ni_phase(&mut self) {
        let event_mode = self.cfg.sim_mode == SimMode::Event;
        let now = self.now;
        let plan = self.plan;
        for idx in 0..self.nodes.len() {
            self.eject_node(idx, now);
            self.nodes[idx].target.pump_writes(now);
            if event_mode {
                // Register this cycle's memory accepts (eject_node and
                // pump_writes above are the only accept paths) so the
                // fast-forward knows when the retirements come due.
                if let Some(t) = self.nodes[idx].target.take_scheduled() {
                    self.calendar.schedule(t);
                }
            }
            let mut port = super::inject::SerialPort {
                nets: &mut self.nets,
                counters: &mut self.counters,
                node_idx: idx,
            };
            super::inject::inject_node(plan, &mut self.nodes[idx], &mut port, now);
            let node = &mut self.nodes[idx];
            if let Some(n) = node.narrow.as_mut() {
                n.drain_cycle();
            }
            if let Some(w) = node.wide.as_mut() {
                w.drain_cycle();
            }
        }
        self.now += 1;
        // The generator pass that follows this step (harness-driven, at
        // the post-increment clock) re-folds its wake horizon from
        // scratch; stale minima must not linger once consumed.
        if event_mode {
            self.gen_wake_min = u64::MAX;
        }
    }

    /// Event-driven fast-forward ([`SimMode::Event`]): if stepping at
    /// `now` — and at every cycle up to the jump target — would be a
    /// provable no-op for *every* component, jump `now` directly to the
    /// earliest cycle at which anything can happen. Skipped cycles
    /// change no statistics because nothing would have changed: the
    /// condition below is deliberately conservative (any doubt keeps
    /// dense stepping), which can only cost stepped cycles, never
    /// correctness.
    ///
    /// The skip condition:
    /// * every network's flit-conservation counter reads zero in flight
    ///   (no link sweep or router can do anything, and no stall/busy
    ///   counter can tick);
    /// * every node's NI is quiet: no wormhole lock held
    ///   ([`InjectState::quiet`]), nothing issuable or drainable at the
    ///   initiators ([`Initiator::inject_quiet`] — also guarantees no
    ///   stall counter ticks), no memory head ready and no matched write
    ///   pair pending at the target ([`Target::eject_quiet`]).
    ///
    /// The jump target is the earlier of the next scheduled memory
    /// retirement (the calendar) and the next generator wake
    /// (`gen_wake_min`, folded during the previous generator pass;
    /// generators run at the post-increment clock, so their phase-time
    /// wake is one cycle earlier). No finite wake source ⇒ no jump — a
    /// fully drained system steps densely (its steps are cheap no-ops
    /// and `run`-style loops terminate on their own conditions).
    fn try_fast_forward(&mut self) {
        if (0..self.nets.len()).any(|n| self.in_flight(n) != 0) {
            return;
        }
        let now = self.now;
        for node in &self.nodes {
            let quiet = node.inj.quiet()
                && node.target.eject_quiet(now)
                && node
                    .narrow
                    .as_ref()
                    .map(Initiator::inject_quiet)
                    .unwrap_or(true)
                && node
                    .wide
                    .as_ref()
                    .map(Initiator::inject_quiet)
                    .unwrap_or(true);
            if !quiet {
                return;
            }
        }
        // Entries at or before `now` are stale: eject_quiet just proved
        // no memory head is ready, and per-port ready times are
        // monotonic (acceptance order), so those ops already retired.
        self.calendar.prune_through(now);
        let mem_wake = self.calendar.earliest().unwrap_or(u64::MAX);
        let gen_wake = match self.gen_wake_min {
            u64::MAX => u64::MAX,
            w => w.saturating_sub(1), // gen-time → phase-time
        };
        let target = mem_wake.min(gen_wake);
        if target == u64::MAX || target <= now {
            return;
        }
        self.skipped_cycles += target - now;
        self.now = target;
    }

    /// Terminate at most one flit per network at this node.
    fn eject_node(&mut self, idx: usize, now: u64) {
        for n in 0..self.nets.len() {
            if self.in_flight(n) == 0 {
                continue; // nothing buffered anywhere in this network
            }
            let lid = self.nets[n].eject[idx];
            let Some(flit) = self.nets[n].links[lid].peek() else {
                continue;
            };
            let node = &mut self.nodes[idx];
            let consumed = match flit.payload.class() {
                MsgClass::Request => node.target.handle_request(flit, now),
                MsgClass::Response => {
                    let init = match flit.payload.bus() {
                        crate::flit::BusKind::Narrow => node.narrow.as_mut(),
                        crate::flit::BusKind::Wide => node.wide.as_mut(),
                    }
                    .expect("response delivered to node without initiator");
                    init.handle_response(flit)
                }
            };
            if consumed {
                let f = self.nets[n].links[lid].pop().unwrap();
                self.counters[n].ejected += 1;
                // Fig. 5b instrument: wide data counts 512 useful bits;
                // everything else occupies a slot of the observed link at
                // zero useful wide bits.
                let wide_bits = match f.payload {
                    Payload::WideR(_) | Payload::WideW { .. } => 512,
                    _ => 0,
                };
                self.eject_meters[n][idx].observe(now, wide_bits);
            }
        }
    }

    /// Run for `n` cycles.
    pub fn run(&mut self, n: u64) {
        for _ in 0..n {
            self.step();
        }
    }

    /// Everything drained: no flits in flight, no outstanding transactions,
    /// no memory ops pending. The link check is O(#networks) via the
    /// conservation counters — this runs every cycle in
    /// [`Self::run_until_idle`] / `TiledWorkload::run_to_completion` and
    /// must not rescan every link.
    pub fn is_idle(&self) -> bool {
        let links_idle = (0..self.nets.len()).all(|n| self.in_flight(n) == 0);
        debug_assert_eq!(
            links_idle,
            self.nets
                .iter()
                .all(|net| net.links.iter().all(Link::is_idle)),
            "flit conservation violated: counters disagree with link scan"
        );
        links_idle
            && self.nodes.iter().all(|n| {
                n.target.is_idle()
                    && n.narrow.as_ref().map(Initiator::is_idle).unwrap_or(true)
                    && n.wide.as_ref().map(Initiator::is_idle).unwrap_or(true)
            })
    }

    /// Run until idle (true) or `max` cycles elapse (false).
    pub fn run_until_idle(&mut self, max: u64) -> bool {
        for _ in 0..max {
            if self.is_idle() {
                return true;
            }
            self.step();
        }
        self.is_idle()
    }

    /// Total flits forwarded by all routers of network `n` (hop count
    /// integral — the energy model's activity input).
    pub fn router_flit_hops(&self, n: usize) -> u64 {
        self.nets[n].routers.iter().map(|r| r.forwarded).sum()
    }

    /// The meter observing the link that carries wide data towards
    /// `node`'s initiator (read-bandwidth experiments): NET_WIDE in
    /// narrow-wide mode, the shared response net in wide-only mode.
    pub fn wide_read_meter(&self, node: NodeId) -> &BandwidthMeter {
        let net = match self.cfg.mode {
            LinkMode::NarrowWide => NET_WIDE,
            LinkMode::WideOnly => NET_RSP,
        };
        &self.eject_meters[net][node.0 as usize]
    }

    /// The meter observing the link that carries wide data towards
    /// `node`'s target (write-bandwidth experiments).
    pub fn wide_write_meter(&self, node: NodeId) -> &BandwidthMeter {
        let net = match self.cfg.mode {
            LinkMode::NarrowWide => NET_WIDE,
            LinkMode::WideOnly => NET_REQ,
        };
        &self.eject_meters[net][node.0 as usize]
    }
}

/// Build one physical network over the topology: routers with the
/// fabric's radix and route tables, the neighbour channels (including
/// torus/ring wraparound links) from [`Topology::channels`], and the
/// per-node local ports.
fn build_network(topo: &Topology, cfg: &NocConfig) -> Network {
    let num_routers = topo.width as usize * topo.height as usize;
    let mut links: Vec<Link<FlooFlit>> = Vec::new();
    // Neighbour channels carry the configured VC lane count; local
    // (inject/eject) links always carry one lane — flits inject on VC 0
    // and the router's dateline rule resets ejecting flits to VC 0, so
    // extra NI-side lanes would never be used (and a single eject lane
    // keeps NI-bound packets non-interleaved via the lane-0 lock).
    let new_link = |links: &mut Vec<Link<FlooFlit>>, pipelined: bool, vcs: usize| -> LinkId {
        let stages = usize::from(pipelined && cfg.output_reg);
        links.push(Link::with_vcs(cfg.in_buf_depth, vcs, stages));
        links.len() - 1
    };

    let radix = topo.router_radix();
    let mut routers: Vec<Router> = (0..num_routers)
        .map(|i| {
            let coord = topo.nodes[i].coord;
            let table = match cfg.routing {
                RoutingKind::Deterministic => topo.route_table(coord),
                RoutingKind::Adaptive => topo.route_table_adaptive(coord),
            };
            Router::new(
                RouterCfg {
                    ports: radix,
                    in_buf_depth: cfg.in_buf_depth,
                    vcs: cfg.vcs,
                },
                table,
            )
        })
        .collect();

    // Neighbour channels — grid-adjacent pairs plus the fabric's
    // wraparound links — as two directed links each (router outputs are
    // pipelined when output_reg is set: the two-cycle router). Each
    // link's consuming router is recorded in `link_sink`: the gated
    // step loop's static wake-edge table.
    let mut link_sink: Vec<Option<usize>> = Vec::new();
    for (a, port_a, b, port_b) in topo.channels() {
        debug_assert!(
            routers[a].out_links[port_a].is_none() && routers[b].in_links[port_b].is_none(),
            "channel collision at router {a} port {port_a}"
        );
        let l = new_link(&mut links, true, cfg.vcs);
        routers[a].out_links[port_a] = Some(l);
        routers[b].in_links[port_b] = Some(l);
        link_sink.push(Some(b));
        let l = new_link(&mut links, true, cfg.vcs);
        routers[b].out_links[port_b] = Some(l);
        routers[a].in_links[port_a] = Some(l);
        link_sink.push(Some(a));
    }

    // Local ports: tiles on PORT_LOCAL, memory controllers on their attach
    // ports of the host router.
    let mut inject = vec![usize::MAX; topo.num_nodes()];
    let mut eject = vec![usize::MAX; topo.num_nodes()];
    for node in &topo.nodes {
        let r = topo.router_index(node.coord);
        let port = match node.kind {
            NodeKind::Tile => PORT_LOCAL,
            NodeKind::MemCtrl { attach_port } => attach_port,
        };
        debug_assert!(
            routers[r].in_links[port].is_none(),
            "local-port collision at router {r} port {port}"
        );
        let inj = new_link(&mut links, false, 1);
        routers[r].in_links[port] = Some(inj);
        inject[node.id.0 as usize] = inj;
        link_sink.push(Some(r));
        let ej = new_link(&mut links, true, 1);
        routers[r].out_links[port] = Some(ej);
        eject[node.id.0 as usize] = ej;
        // Eject links are consumed by the node's NI, which is stepped
        // every cycle in phase 3 — no router wake edge.
        link_sink.push(None);
    }

    debug_assert_eq!(link_sink.len(), links.len());
    let num_links = links.len();
    let num_routers = routers.len();
    Network {
        links,
        routers,
        inject,
        eject,
        link_sink,
        link_active: ActiveSet::new(num_links),
        router_wake: ActiveSet::new(num_routers),
        check_invariants: cfg.check_invariants,
    }
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::axi::{AxReq, Burst};
    use crate::topology::TILE_SPAN;

    fn rd(id: u16, len: u8, size: u8, addr: u64) -> AxReq {
        AxReq {
            id,
            addr,
            len,
            size,
            burst: Burst::Incr,
            atop: false,
        }
    }

    /// Single narrow read from tile 0 to adjacent tile 1: the §VI-A
    /// zero-load scenario. The total must be deterministic; the exact
    /// value is pinned by the zero-load calibration (see cluster module).
    #[test]
    fn zero_load_narrow_read_completes() {
        let mut sys = NocSystem::new(NocConfig::mesh(2, 1));
        let dst = NodeId(1);
        sys.narrow_init(NodeId(0))
            .push_ar(rd(1, 0, 3, TILE_SPAN + 0x100), dst);
        let mut completed_at = None;
        for _ in 0..100 {
            sys.step();
            if sys.narrow_init(NodeId(0)).r_out.pop().is_some() {
                completed_at = Some(sys.now);
                break;
            }
        }
        let lat = completed_at.expect("read must complete");
        assert!(sys.run_until_idle(10));
        // Print for calibration visibility when running with --nocapture.
        println!("zero-load round trip: {lat} cycles");
        assert!(lat >= 10 && lat <= 30, "sane zero-load range, got {lat}");
    }

    /// A wide DMA burst (16 beats x 64 B = 1 kB) completes and delivers
    /// every beat.
    #[test]
    fn wide_read_burst_completes() {
        let mut sys = NocSystem::new(NocConfig::mesh(2, 1));
        sys.wide_init(NodeId(0))
            .push_ar(rd(2, 15, 6, TILE_SPAN + 0x0), NodeId(1));
        let mut beats = 0;
        for _ in 0..200 {
            sys.step();
            while sys.wide_init(NodeId(0)).r_out.pop().is_some() {
                beats += 1;
            }
            if beats == 16 {
                break;
            }
        }
        assert_eq!(beats, 16);
        assert!(sys.run_until_idle(10));
        // All 16 beats crossed the wide network once each direction of the
        // request traveled the narrow_req net.
        assert!(sys.router_flit_hops(NET_WIDE) >= 16);
    }

    /// A wide write burst: AW on narrow_req, beats on wide, B back on
    /// narrow_rsp.
    #[test]
    fn wide_write_burst_completes() {
        let mut sys = NocSystem::new(NocConfig::mesh(2, 1));
        sys.wide_init(NodeId(0))
            .push_aw(rd(3, 15, 6, TILE_SPAN + 0x40), NodeId(1));
        let mut done = false;
        for _ in 0..200 {
            sys.step();
            if sys.wide_init(NodeId(0)).b_out.pop().is_some() {
                done = true;
                break;
            }
        }
        assert!(done, "write must receive its B response");
        assert!(sys.run_until_idle(10));
        assert_eq!(sys.nodes[1].target.stats.writes_served, 1);
    }

    /// The same traffic in wide-only mode also completes (the baseline
    /// config is functionally correct, just slower under contention).
    #[test]
    fn wide_only_mode_functional() {
        let mut sys = NocSystem::new(NocConfig::mesh(2, 1).wide_only());
        sys.narrow_init(NodeId(0))
            .push_ar(rd(1, 0, 3, TILE_SPAN + 0x100), NodeId(1));
        sys.wide_init(NodeId(0))
            .push_aw(rd(3, 15, 6, TILE_SPAN + 0x40), NodeId(1));
        let mut r = false;
        let mut b = false;
        for _ in 0..300 {
            sys.step();
            r |= sys.narrow_init(NodeId(0)).r_out.pop().is_some();
            b |= sys.wide_init(NodeId(0)).b_out.pop().is_some();
            if r && b {
                break;
            }
        }
        assert!(r && b);
        assert!(sys.run_until_idle(10));
        assert_eq!(sys.nets.len(), 2);
    }

    /// Memory-controller traffic: DMA read from a boundary controller.
    #[test]
    fn mem_ctrl_read() {
        use crate::topology::{MemEdge, MEM_BASE};
        let mut sys =
            NocSystem::new(NocConfig::mesh(2, 2).with_mem_edge(MemEdge::West));
        let mem = sys.topo.mem_ctrls()[0];
        sys.wide_init(NodeId(3))
            .push_ar(rd(0, 15, 6, MEM_BASE), mem);
        let mut beats = 0;
        for _ in 0..400 {
            sys.step();
            while sys.wide_init(NodeId(3)).r_out.pop().is_some() {
                beats += 1;
            }
            if beats == 16 {
                break;
            }
        }
        assert_eq!(beats, 16);
        assert!(sys.run_until_idle(20));
    }

    /// Table-I payload steering in WideOnly mode: only two networks
    /// exist, all request classes (including wide W data) share NET_REQ
    /// and every response class shares NET_RSP — request/response
    /// separation survives the merge (deadlock freedom).
    #[test]
    fn net_of_wide_only_maps_by_class() {
        use crate::axi::{BResp, RBeat, Resp, WBeat};
        let m = LinkMode::WideOnly;
        assert_eq!(m.num_nets(), 2);
        let ar = rd(1, 0, 3, 0x100);
        let wbeat = WBeat { beat: 0, last: false };
        let rbeat = RBeat { id: 0, beat: 0, last: true, resp: Resp::Okay };
        let b = BResp { id: 0, resp: Resp::Okay };
        // Requests, narrow and wide alike, ride the request network.
        assert_eq!(m.net_of(&Payload::NarrowAr(ar)), NET_REQ);
        assert_eq!(m.net_of(&Payload::NarrowAw(ar)), NET_REQ);
        assert_eq!(m.net_of(&Payload::NarrowW { id: 0, beat: wbeat }), NET_REQ);
        assert_eq!(m.net_of(&Payload::WideAr(ar)), NET_REQ);
        assert_eq!(m.net_of(&Payload::WideAw(ar)), NET_REQ);
        assert_eq!(m.net_of(&Payload::WideW { id: 0, beat: wbeat }), NET_REQ);
        // Responses ride the response network.
        assert_eq!(m.net_of(&Payload::NarrowR(rbeat)), NET_RSP);
        assert_eq!(m.net_of(&Payload::NarrowB(b)), NET_RSP);
        assert_eq!(m.net_of(&Payload::WideR(rbeat)), NET_RSP);
        assert_eq!(m.net_of(&Payload::WideB(b)), NET_RSP);
        // Contrast with narrow-wide: bulk data gets the dedicated net.
        let nw = LinkMode::NarrowWide;
        assert_eq!(nw.net_of(&Payload::WideR(rbeat)), NET_WIDE);
        assert_eq!(nw.net_of(&Payload::WideW { id: 0, beat: wbeat }), NET_WIDE);
        assert_eq!(nw.net_of(&Payload::WideB(b)), NET_RSP);
        assert_eq!(nw.net_of(&Payload::WideAr(ar)), NET_REQ);
        // The hoisted plans agree with the mode they were derived from.
        let wo_plan = InjectPlan::for_mode(m);
        assert!(wo_plan.shared_w && wo_plan.merged_rsp && !wo_plan.has_wide_net);
        let nw_plan = InjectPlan::for_mode(nw);
        assert!(!nw_plan.shared_w && !nw_plan.merged_rsp && nw_plan.has_wide_net);
    }

    /// The idle-network fast path must be invisible: in-flight counts hit
    /// zero between bursts and the system still completes and drains with
    /// conserved flits.
    #[test]
    fn idle_network_skip_preserves_conservation() {
        let mut sys = NocSystem::new(NocConfig::mesh(2, 1));
        for n in 0..sys.nets.len() {
            assert_eq!(sys.in_flight(n), 0);
        }
        // A burst, a quiet gap (all nets idle again), then another burst.
        for round in 0..2u64 {
            sys.narrow_init(NodeId(0))
                .push_ar(rd(1, 0, 3, TILE_SPAN + 0x100 + round * 0x40), NodeId(1));
            let mut got = false;
            for _ in 0..100 {
                sys.step();
                if sys.narrow_init(NodeId(0)).r_out.pop().is_some() {
                    got = true;
                    break;
                }
            }
            assert!(got, "read {round} completed");
            assert!(sys.run_until_idle(20));
            for n in 0..sys.nets.len() {
                assert_eq!(sys.in_flight(n), 0, "net {n} drained");
                assert_eq!(sys.counters[n].injected, sys.counters[n].ejected);
            }
        }
        // The wide network never carried anything and was skipped
        // throughout — its routers report zero activity.
        assert_eq!(sys.router_flit_hops(NET_WIDE), 0);
    }

    /// A ring delivers over the wraparound link: tile 0 -> tile 3 of a
    /// 4-ring is a single westward wrap hop, and the request leaves
    /// router 0 through PORT_W even though tile 3 is "far east" in
    /// coordinates.
    #[test]
    fn ring_routes_via_wraparound() {
        use crate::router::PORT_W;
        let mut sys = NocSystem::new(NocConfig::ring(4));
        sys.narrow_init(NodeId(0))
            .push_ar(rd(1, 0, 3, 3 * TILE_SPAN + 0x100), NodeId(3));
        let mut done = false;
        for _ in 0..100 {
            sys.step();
            if sys.narrow_init(NodeId(0)).r_out.pop().is_some() {
                done = true;
                break;
            }
        }
        assert!(done, "wraparound read must complete");
        assert!(sys.run_until_idle(10));
        assert!(
            sys.nets[NET_REQ].routers[0].forwarded_on(PORT_W) > 0,
            "request must take the westward wrap link"
        );
    }

    /// Torus wraparound in both dimensions: a read from corner (0,0) to
    /// corner (3,3) of a 4x4 torus crosses exactly one wrap link per
    /// dimension (2 hops instead of the mesh's 6).
    #[test]
    fn torus_routes_via_wraparound() {
        let mut sys = NocSystem::new(NocConfig::torus(4, 4));
        assert_eq!(sys.topo.hops(NodeId(0), NodeId(15)), 2);
        sys.narrow_init(NodeId(0))
            .push_ar(rd(1, 0, 3, 15 * TILE_SPAN + 0x100), NodeId(15));
        let mut done = false;
        for _ in 0..200 {
            sys.step();
            if sys.narrow_init(NodeId(0)).r_out.pop().is_some() {
                done = true;
                break;
            }
        }
        assert!(done, "torus wraparound read must complete");
        assert!(sys.run_until_idle(10));
        // Request path is 2 router-to-router hops + inject/eject: 3
        // router traversals total per direction.
        assert_eq!(sys.router_flit_hops(NET_REQ), 3);
    }

    /// A wide DMA burst to a torus memory controller on the dedicated
    /// radix-6 attach port completes.
    #[test]
    fn torus_mem_ctrl_on_port_mem() {
        use crate::topology::MEM_BASE;
        let mut sys =
            NocSystem::new(NocConfig::torus(3, 3).with_mem_edge(MemEdge::West));
        let mem = sys.topo.mem_ctrls()[0];
        sys.wide_init(NodeId(4)).push_ar(rd(0, 7, 6, MEM_BASE), mem);
        let mut beats = 0;
        for _ in 0..400 {
            sys.step();
            while sys.wide_init(NodeId(4)).r_out.pop().is_some() {
                beats += 1;
            }
            if beats == 8 {
                break;
            }
        }
        assert_eq!(beats, 8);
        assert!(sys.run_until_idle(20));
    }

    /// Dateline VCs on the default torus: wrap fabrics build with 2 VCs,
    /// wrap-crossing flits really ride lane 1 of the wrap link, and the
    /// wrap link's VC 0 lane stays clear (the invariant the acyclicity
    /// proof rests on — see docs/deadlock.md).
    #[test]
    fn torus_wrap_traffic_rides_vc1() {
        use crate::router::PORT_W;
        let mut sys = NocSystem::new(NocConfig::torus(4, 4));
        assert_eq!(sys.cfg.vcs, 2);
        sys.narrow_init(NodeId(0))
            .push_ar(rd(1, 0, 3, 15 * TILE_SPAN + 0x100), NodeId(15));
        let mut done = false;
        for _ in 0..200 {
            sys.step();
            if sys.narrow_init(NodeId(0)).r_out.pop().is_some() {
                done = true;
                break;
            }
        }
        assert!(done, "wraparound read must complete with VCs on");
        assert!(sys.run_until_idle(10));
        // Request path 0 -> 15 starts with the westward wrap hop out of
        // router 0 (x = 0 going W crosses the row dateline).
        let wrap = sys.nets[NET_REQ].routers[0].out_links[PORT_W].unwrap();
        let l = &sys.nets[NET_REQ].links[wrap];
        assert!(l.lane_delivered(1) > 0, "wrap hop must ride VC 1");
        assert_eq!(l.lane_delivered(0), 0, "a wrap link's VC 0 lane stays clear");
    }

    /// A wide wormhole burst crossing the torus dateline completes —
    /// multi-flit packets over wrap links are exactly the traffic the
    /// dateline scheme exists for.
    #[test]
    fn torus_wide_burst_across_dateline() {
        let mut sys = NocSystem::new(NocConfig::torus(4, 4));
        sys.wide_init(NodeId(0))
            .push_ar(rd(2, 15, 6, 15 * TILE_SPAN), NodeId(15));
        let mut beats = 0;
        for _ in 0..400 {
            sys.step();
            while sys.wide_init(NodeId(0)).r_out.pop().is_some() {
                beats += 1;
            }
            if beats == 16 {
                break;
            }
        }
        assert_eq!(beats, 16);
        assert!(sys.run_until_idle(10));
    }

    /// The VC knob validates its range.
    #[test]
    #[should_panic(expected = "vcs must be in 1..=")]
    fn with_vcs_rejects_zero() {
        let _ = NocConfig::mesh(2, 2).with_vcs(0);
    }

    /// The gated, dense, and event step loops must agree on the
    /// calibrated zero-load number exactly: same round-trip latency,
    /// same total cycles to drain, same router activity. A one-cycle
    /// divergence here means a wake edge fires a cycle early or late —
    /// or, for event mode, a fast-forward jumped over a cycle that was
    /// not actually a no-op.
    #[test]
    fn gated_matches_dense_and_event_zero_load() {
        use crate::sim::SimMode;
        let run = |mode: SimMode| {
            let mut sys = NocSystem::new(NocConfig::mesh(2, 1).with_sim_mode(mode));
            sys.narrow_init(NodeId(0))
                .push_ar(rd(1, 0, 3, TILE_SPAN + 0x100), NodeId(1));
            let mut completed_at = None;
            for _ in 0..100 {
                sys.step();
                if sys.narrow_init(NodeId(0)).r_out.pop().is_some() {
                    completed_at = Some(sys.now);
                    break;
                }
            }
            assert!(sys.run_until_idle(10));
            if mode != SimMode::Event {
                assert_eq!(sys.skipped_cycles, 0, "only event mode may skip");
                assert_eq!(sys.stepped_cycles, sys.now);
            }
            (
                completed_at.expect("read completes"),
                sys.now,
                sys.router_flit_hops(NET_REQ),
                sys.router_flit_hops(NET_RSP),
            )
        };
        let gated = run(SimMode::Gated);
        assert_eq!(gated, run(SimMode::Dense));
        assert_eq!(gated, run(SimMode::Event));
    }

    /// Event-mode fast-forward actually skips: a single zero-load read
    /// spends the memory-latency window with empty networks and quiet
    /// NIs, so the calendar entry planted at accept time lets `step`
    /// jump straight to the retirement cycle. The clock, results, and
    /// the stepped/skipped split must reconcile exactly.
    #[test]
    fn event_mode_skips_memory_latency_window() {
        use crate::sim::SimMode;
        let mut sys = NocSystem::new(NocConfig::mesh(2, 1).with_sim_mode(SimMode::Event));
        assert_eq!(sys.cfg.sim_mode, SimMode::Event);
        sys.narrow_init(NodeId(0))
            .push_ar(rd(1, 0, 3, TILE_SPAN + 0x100), NodeId(1));
        let mut done = false;
        for _ in 0..100 {
            sys.step();
            if sys.narrow_init(NodeId(0)).r_out.pop().is_some() {
                done = true;
                break;
            }
        }
        assert!(done, "read completes under event mode");
        assert!(sys.run_until_idle(10));
        assert!(
            sys.skipped_cycles > 0,
            "memory latency window should fast-forward (skipped = {})",
            sys.skipped_cycles
        );
        assert_eq!(
            sys.stepped_cycles + sys.skipped_cycles,
            sys.now,
            "every cycle is either stepped or skipped"
        );
    }

    /// Activity tracking: after a gated system drains, its active sets
    /// prune back to (near-)empty — at most the one-sweep lag of links
    /// drained by the final pops — and a fresh injection re-populates
    /// them via the inject wake edge.
    #[test]
    fn gated_active_set_prunes_and_rewakes() {
        let mut sys = NocSystem::new(NocConfig::mesh(2, 2));
        sys.narrow_init(NodeId(0))
            .push_ar(rd(1, 0, 3, TILE_SPAN + 0x100), NodeId(1));
        let mut done = false;
        for _ in 0..100 {
            sys.step();
            if sys.narrow_init(NodeId(0)).r_out.pop().is_some() {
                done = true;
                break;
            }
        }
        assert!(done);
        assert!(sys.run_until_idle(20));
        // Two extra steps prune any stale (drained-by-pop) bits.
        sys.step();
        sys.step();
        for net in &sys.nets {
            assert_eq!(net.active_link_count(), 0, "drained fabric fully gated off");
        }
        // A new injection must wake the local link the same cycle.
        sys.narrow_init(NodeId(0))
            .push_ar(rd(2, 0, 3, TILE_SPAN + 0x140), NodeId(1));
        sys.step(); // injection happens in phase 3 of this step
        let inj = sys.nets[NET_REQ].inject[0];
        assert!(
            sys.nets[NET_REQ].link_is_active(inj),
            "inject wake edge marks the local link active"
        );
        let mut done = false;
        for _ in 0..100 {
            sys.step();
            if sys.narrow_init(NodeId(0)).r_out.pop().is_some() {
                done = true;
                break;
            }
        }
        assert!(done, "second read completes after re-wake");
        assert!(sys.run_until_idle(20));
    }

    /// Dense reference mode stays fully functional (it is the
    /// differential oracle, so it must keep passing the same workloads).
    #[test]
    fn dense_reference_mode_functional() {
        let mut sys = NocSystem::new(NocConfig::mesh(2, 1).dense());
        assert_eq!(sys.cfg.sim_mode, crate::sim::SimMode::Dense);
        sys.wide_init(NodeId(0))
            .push_ar(rd(2, 15, 6, TILE_SPAN), NodeId(1));
        let mut beats = 0;
        for _ in 0..200 {
            sys.step();
            while sys.wide_init(NodeId(0)).r_out.pop().is_some() {
                beats += 1;
            }
            if beats == 16 {
                break;
            }
        }
        assert_eq!(beats, 16);
        assert!(sys.run_until_idle(10));
    }

    /// Two concurrent wide writes from different tiles to the same target
    /// must not interleave their W bursts (wormhole atomicity end to end).
    #[test]
    fn concurrent_writes_no_interleave() {
        let mut sys = NocSystem::new(NocConfig::mesh(3, 1));
        sys.wide_init(NodeId(0))
            .push_aw(rd(1, 7, 6, 2 * TILE_SPAN), NodeId(2));
        sys.wide_init(NodeId(1))
            .push_aw(rd(1, 7, 6, 2 * TILE_SPAN + 0x1000), NodeId(2));
        let mut b0 = false;
        let mut b1 = false;
        for _ in 0..300 {
            sys.step();
            b0 |= sys.wide_init(NodeId(0)).b_out.pop().is_some();
            b1 |= sys.wide_init(NodeId(1)).b_out.pop().is_some();
        }
        // The target's write-assembly debug_asserts would have fired on any
        // interleaving (beats/AW mismatch); both writes completing is the
        // end-to-end check.
        assert!(b0 && b1);
        assert_eq!(sys.nodes[2].target.stats.writes_served, 2);
        assert!(sys.run_until_idle(10));
    }
}
