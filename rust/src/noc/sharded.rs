//! Deterministic sharded execution of a single simulation.
//!
//! One [`NocSystem`] is spatially partitioned into contiguous strips
//! ([`ShardPlan`]); each shard owns the links, routers, NIs, meters and
//! generators of its strip and steps them on its own thread. The engine
//! is **deterministic by construction**: the per-cycle phase structure
//! of the serial engine ([`NocSystem::step`]) is reproduced exactly,
//! with two barriers per simulated cycle, so the run's statistics are
//! byte-identical to the serial engine at any shard count.
//!
//! # Ownership
//!
//! Every link is owned by its **consumer** side: channel and inject
//! links by the shard of their sink router, eject links by the shard of
//! their host node. A link whose producer router lives in a different
//! shard than its owner is a **boundary link** — with row-strip
//! partitioning these are exactly the N/S channels crossing a strip
//! border (plus wraparound channels on tori/rings).
//!
//! # The two races, and their two mechanisms
//!
//! Within one serial cycle, only two interactions cross a strip border:
//!
//! * **Forward (flits)**: a producer router offers a flit into a
//!   boundary link during phase 2. The sharded producer instead pushes
//!   the flit into the owner's **mailbox**; the owner applies all
//!   mailbox offers — sorted by `(net, link, lane)` for determinism —
//!   at the start of its next turn, before link delivery. The serial
//!   engine would not have looked at that link again until the same
//!   point, so the late application is unobservable.
//! * **Backward (credits)**: the producer's switch allocation reads
//!   `can_offer` of the boundary link. The owner publishes a per-lane
//!   **credit mirror** (an [`AtomicU8`] bitmask) right after delivering
//!   the link in phase 1; barrier A orders every publish before any
//!   read. The mirror equals exactly what the serial producer would
//!   have read at the same point in the cycle, and cannot go stale
//!   mid-phase: a link has one producer, at most one offer per output
//!   per cycle, and the owner's own pops only *increase* credit.
//!
//! # Cycle protocol
//!
//! ```text
//! decision  — replicated on every shard from the published summaries:
//!             completion, budget, dense-mode skip, event fast-forward
//! drain     — apply mailbox offers into owned links (sorted)
//! phase 1   — deliver owned links, publish boundary credit mirrors
//! BARRIER A — mirrors visible before any router reads them
//! phase 2   — step woken owned routers; boundary offers → mailboxes
//! phase 3   — owned NIs terminate + inject, generators step,
//!             per-shard calendar pruned, summary published
//! BARRIER B — cycle sealed: summaries + mailboxes visible to all
//! ```
//!
//! Global decisions (are we done? may we fast-forward, and to where?)
//! are **replicated**, not centralized: each shard reads all published
//! summaries and computes the same answer from the same inputs, so no
//! coordinator thread exists and every shard takes the same branch
//! every cycle — the barrier counts always agree. Event-mode
//! fast-forward jumps only when *every* shard reports quiet, to the
//! minimum wake over all per-shard calendars and generator horizons —
//! exactly the serial jump target.
//!
//! The engine is driven through
//! [`TiledWorkload::run_to_completion`](crate::cluster::TiledWorkload::run_to_completion)
//! when [`NocConfig::shards`](super::NocConfig::shards) is greater
//! than 1; single-stepping entry points (`step`, `run_with_watchdog`)
//! always use the serial engine.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Barrier, Mutex};

use crate::cluster::ComputeTile;
use crate::flit::{BusKind, FlooFlit, MsgClass, Payload};
use crate::ni::Initiator;
use crate::router::{LinkPool, Router};
use crate::sim::{Link, LinkId, SimMode};
use crate::stats::BandwidthMeter;
use crate::topology::partition::ShardPlan;
use crate::topology::Topology;
use crate::util::activeset::ActiveSet;
use crate::util::calendar::Calendar;

use super::inject::{self, LocalPort};
use super::system::{InjectPlan, NetCounters, NocSystem, NodeNi};

/// A flit offered into a boundary link, in transit between shards.
struct BoundaryMsg {
    net: usize,
    lid: LinkId,
    vc: usize,
    flit: FlooFlit,
}

/// Immutable per-network lookup tables shared by every worker.
struct NetTables {
    /// Owning shard of each link (consumer side).
    owner: Vec<usize>,
    /// Links whose producer router lives in a different shard.
    boundary: Vec<bool>,
    /// Lane count of each link (for [`LinkPool::vcs`] on non-owned links).
    vcs: Vec<u8>,
    /// Consuming router of each link (`None` for eject links).
    link_sink: Vec<Option<usize>>,
    /// Per-node inject link.
    inject: Vec<LinkId>,
    /// Per-node eject link.
    eject: Vec<LinkId>,
    /// Links owned by each shard, ascending.
    owned_links: Vec<Vec<LinkId>>,
}

/// Immutable run-wide tables shared by every worker.
struct Tables {
    plan: ShardPlan,
    nets: Vec<NetTables>,
    /// Routers owned by each shard, ascending (identical across nets).
    owned_routers: Vec<Vec<usize>>,
    /// Nodes owned by each shard, ascending.
    owned_nodes: Vec<Vec<usize>>,
    /// System counters at decompose time; global in-flight counts are
    /// `base + Σ shard deltas`.
    base: Vec<NetCounters>,
    iplan: InjectPlan,
    dense: bool,
    event: bool,
    check_invariants: bool,
    num_nets: usize,
}

/// What one shard publishes at the end of every cycle; the replicated
/// decision logic reads all of them.
#[derive(Clone)]
struct Summary {
    /// Per-net flits injected by this shard since decompose.
    injected: Vec<u64>,
    /// Per-net flits ejected by this shard since decompose.
    ejected: Vec<u64>,
    /// Every owned NI is quiet (fast-forward precondition).
    nodes_quiet: bool,
    /// Every owned NI is idle (completion condition).
    nodes_idle: bool,
    /// Every owned generator has completed.
    gens_done: bool,
    /// Earliest scheduled memory retirement in this shard's calendar.
    mem_wake: u64,
    /// Generator wake horizon folded by this shard's last generator
    /// pass (gen-time clock).
    gen_wake: u64,
}

/// Cross-shard communication fabric for one run.
struct Shared {
    /// Per-net, per-link credit mirrors: bit `v` set ⇔ lane `v` of the
    /// (boundary) link can accept a flit. Only boundary links are ever
    /// published or read; barrier A orders publish before read, so
    /// `Relaxed` suffices.
    mirrors: Vec<Vec<AtomicU8>>,
    /// Per-destination-shard boundary flit queues.
    mailboxes: Vec<Mutex<Vec<BoundaryMsg>>>,
    /// Per-shard end-of-cycle summaries.
    summaries: Vec<Mutex<Summary>>,
    barrier: Barrier,
}

/// One network's state within a shard: full-length sparse vectors
/// (global indices preserved; `None` = owned by another shard).
struct ShardNet {
    links: Vec<Option<Link<FlooFlit>>>,
    routers: Vec<Option<Router>>,
    link_active: ActiveSet,
    router_wake: ActiveSet,
}

/// All state owned by one shard.
struct Shard {
    id: usize,
    now: u64,
    stepped: u64,
    skipped: u64,
    /// Generator wake horizon folded by the most recent generator pass.
    gen_fold: u64,
    nets: Vec<ShardNet>,
    nodes: Vec<Option<NodeNi>>,
    tiles: Vec<Option<ComputeTile>>,
    /// `[net][node]` ejection meters.
    meters: Vec<Vec<Option<BandwidthMeter>>>,
    /// Per-net injected/ejected deltas since decompose.
    counters: Vec<NetCounters>,
    calendar: Calendar,
    /// Boundary offers staged during phase 2, flushed to mailboxes.
    pending: Vec<BoundaryMsg>,
    /// Per-destination staging buckets for the flush.
    scratch: Vec<Vec<BoundaryMsg>>,
}

/// Per-lane credit bitmask of a link, as published in the mirror.
fn offer_mask(link: &Link<FlooFlit>) -> u8 {
    debug_assert!(link.vcs() <= 8, "credit mirror packs lanes into a u8");
    let mut mask = 0u8;
    for vc in 0..link.vcs() {
        if link.can_offer_vc(vc) {
            mask |= 1 << vc;
        }
    }
    mask
}

/// The [`LinkPool`] a shard's routers step against: owned links are
/// accessed directly; a non-owned (boundary) link answers credit checks
/// from its mirror and turns offers into mailbox messages. Peeks and
/// pops of non-owned links panic — a router's input links are always
/// owned by its own shard.
struct ShardLinks<'a> {
    links: &'a mut [Option<Link<FlooFlit>>],
    vcs: &'a [u8],
    mirror: &'a [AtomicU8],
    pending: &'a mut Vec<BoundaryMsg>,
    net: usize,
}

impl LinkPool for ShardLinks<'_> {
    fn vcs(&self, lid: LinkId) -> usize {
        self.vcs[lid] as usize
    }

    fn peek_vc(&self, lid: LinkId, vc: usize) -> Option<&FlooFlit> {
        self.links[lid]
            .as_ref()
            .expect("peek on non-owned link")
            .peek_vc(vc)
    }

    fn can_offer_vc(&self, lid: LinkId, vc: usize) -> bool {
        match self.links[lid].as_ref() {
            Some(l) => l.can_offer_vc(vc),
            None => self.mirror[lid].load(Ordering::Relaxed) & (1 << vc) != 0,
        }
    }

    fn pop_vc(&mut self, lid: LinkId, vc: usize) -> Option<FlooFlit> {
        self.links[lid]
            .as_mut()
            .expect("pop on non-owned link")
            .pop_vc(vc)
    }

    fn offer_vc(&mut self, lid: LinkId, vc: usize, flit: FlooFlit) {
        match self.links[lid].as_mut() {
            Some(l) => l.offer_vc(vc, flit),
            None => self.pending.push(BoundaryMsg {
                net: self.net,
                lid,
                vc,
                flit,
            }),
        }
    }

    fn buffered(&self, lid: LinkId) -> usize {
        self.links[lid]
            .as_ref()
            .expect("buffered on non-owned link")
            .buffered()
    }

    fn occupied_lanes(&self, lid: LinkId) -> u32 {
        // Only consulted for a router's input links, which are always
        // owned by the router's own shard (consumer-side ownership).
        self.links[lid]
            .as_ref()
            .expect("occupied_lanes on non-owned link")
            .occupied_lanes()
    }
}

/// The sharded engine's [`LocalPort`]: offers into the shard-local link
/// storage, waking the shard's active set and counting into the
/// shard's delta counters — mirroring `SerialPort::offer` exactly.
struct ShardPort<'a> {
    nets: &'a mut [ShardNet],
    counters: &'a mut [NetCounters],
    tables: &'a Tables,
    node_idx: usize,
}

impl LocalPort for ShardPort<'_> {
    fn can_offer(&self, net: usize) -> bool {
        let lid = self.tables.nets[net].inject[self.node_idx];
        self.nets[net].links[lid]
            .as_ref()
            .expect("inject link not owned by node's shard")
            .can_offer()
    }

    fn offer(&mut self, net: usize, flit: FlooFlit) {
        let lid = self.tables.nets[net].inject[self.node_idx];
        let snet = &mut self.nets[net];
        snet.links[lid]
            .as_mut()
            .expect("inject link not owned by node's shard")
            .offer(flit);
        snet.link_active.insert(lid);
        self.counters[net].injected += 1;
    }
}

/// Apply last cycle's boundary offers into owned links, in a canonical
/// `(net, link, lane)` order. At most one offer per lane per cycle can
/// exist, so the sort fixes only presentation order; semantically the
/// offers commute.
fn drain_mailbox(shard: &mut Shard, shared: &Shared) {
    let mut msgs = std::mem::take(&mut *shared.mailboxes[shard.id].lock().expect("mailbox lock"));
    if msgs.is_empty() {
        return;
    }
    msgs.sort_by_key(|m| (m.net, m.lid, m.vc));
    for m in msgs {
        let snet = &mut shard.nets[m.net];
        snet.links[m.lid]
            .as_mut()
            .expect("boundary flit routed to non-owned link")
            .offer_vc(m.vc, m.flit);
        snet.link_active.insert(m.lid);
    }
}

/// Phase 1, gated: sweep the shard's active set, delivering owned
/// links, waking their sink routers and publishing boundary credit
/// mirrors. The serial `Network::deliver_gated` sweep, restricted to
/// owned links.
fn deliver_gated(snet: &mut ShardNet, tn: &NetTables, mirror: &[AtomicU8], me: usize, check: bool) {
    if check {
        for &lid in &tn.owned_links[me] {
            let l = snet.links[lid].as_ref().expect("owned link missing");
            assert!(
                l.is_quiescent() || snet.link_active.contains(lid),
                "occupied link {lid} missing from the active set"
            );
        }
    }
    let ShardNet {
        links,
        link_active,
        router_wake,
        ..
    } = snet;
    router_wake.clear();
    for wi in 0..link_active.num_words() {
        let mut w = link_active.word(wi);
        while w != 0 {
            let lid = (wi << 6) + w.trailing_zeros() as usize;
            w &= w - 1;
            let link = links[lid].as_mut().expect("active bit on non-owned link");
            let s = link.deliver();
            if tn.boundary[lid] {
                mirror[lid].store(offer_mask(link), Ordering::Relaxed);
            }
            if s.consumer_ready {
                if let Some(r) = tn.link_sink[lid] {
                    router_wake.insert(r);
                }
            }
            if !s.still_active {
                link_active.remove(lid);
            }
        }
    }
}

/// Phase 1, dense: deliver every owned link in ascending order,
/// publishing boundary mirrors. The serial `Network::deliver_dense`
/// sweep, restricted to owned links.
fn deliver_dense(snet: &mut ShardNet, tn: &NetTables, mirror: &[AtomicU8], me: usize) {
    for &lid in &tn.owned_links[me] {
        let link = snet.links[lid].as_mut().expect("owned link missing");
        link.deliver();
        if tn.boundary[lid] {
            mirror[lid].store(offer_mask(link), Ordering::Relaxed);
        }
    }
}

/// Phase 2, gated: step exactly the owned routers woken by phase 1.
fn routers_gated(
    snet: &mut ShardNet,
    tn: &NetTables,
    owned_routers: &[usize],
    mirror: &[AtomicU8],
    pending: &mut Vec<BoundaryMsg>,
    net: usize,
    check: bool,
) {
    let ShardNet {
        links,
        routers,
        link_active,
        router_wake,
    } = snet;
    if check {
        for &r in owned_routers {
            let router = routers[r].as_ref().expect("owned router missing");
            // Router::is_quiescent, inlined over owned storage (a
            // router's input links are always owned by its own shard).
            let quiescent = router.in_links.iter().flatten().all(|&lid| {
                links[lid]
                    .as_ref()
                    .expect("router input link not owned")
                    .buffered()
                    == 0
            });
            assert!(
                quiescent || router_wake.contains(r),
                "router {r} has buffered input but was not woken"
            );
        }
    }
    for r in router_wake.iter() {
        let mut router = routers[r].take().expect("woken router not owned");
        let act = {
            let mut view = ShardLinks {
                links: links.as_mut_slice(),
                vcs: &tn.vcs,
                mirror,
                pending: &mut *pending,
                net,
            };
            router.step(&mut view)
        };
        debug_assert!(act.any_input, "woken router {r} saw no input");
        let mut m = act.woke_outputs;
        while m != 0 {
            let o = m.trailing_zeros() as usize;
            m &= m - 1;
            let lid = router.out_links[o].expect("commit woke an unconnected output port");
            // Non-owned outputs were staged for the owner's mailbox;
            // the owner wakes the link when it drains the flit.
            if links[lid].is_some() {
                link_active.insert(lid);
            }
        }
        routers[r] = Some(router);
    }
}

/// Phase 2, dense: step every owned router in ascending order.
fn routers_dense(
    snet: &mut ShardNet,
    tn: &NetTables,
    owned_routers: &[usize],
    mirror: &[AtomicU8],
    pending: &mut Vec<BoundaryMsg>,
    net: usize,
) {
    let ShardNet { links, routers, .. } = snet;
    for &r in owned_routers {
        let mut router = routers[r].take().expect("owned router missing");
        {
            let mut view = ShardLinks {
                links: links.as_mut_slice(),
                vcs: &tn.vcs,
                mirror,
                pending: &mut *pending,
                net,
            };
            router.step(&mut view);
        }
        routers[r] = Some(router);
    }
}

/// Route phase 2's staged boundary offers into their owners' mailboxes
/// (one lock per destination shard with traffic).
fn flush_pending(shard: &mut Shard, shared: &Shared, t: &Tables) {
    if shard.pending.is_empty() {
        return;
    }
    for m in shard.pending.drain(..) {
        let dst = t.nets[m.net].owner[m.lid];
        shard.scratch[dst].push(m);
    }
    for (dst, bucket) in shard.scratch.iter_mut().enumerate() {
        if !bucket.is_empty() {
            shared.mailboxes[dst].lock().expect("mailbox lock").append(bucket);
        }
    }
}

/// `NocSystem::eject_node`, over shard-local storage. The serial
/// engine skips a whole network when its conservation counter reads
/// zero; peeking the eject link directly is equivalent (an empty
/// network has nothing buffered anywhere), so no global counter is
/// needed here.
fn eject_node(shard: &mut Shard, t: &Tables, idx: usize, now: u64) {
    for n in 0..t.num_nets {
        let lid = t.nets[n].eject[idx];
        let consumed = {
            let Some(flit) = shard.nets[n].links[lid]
                .as_ref()
                .expect("eject link not owned by node's shard")
                .peek()
            else {
                continue;
            };
            let node = shard.nodes[idx].as_mut().expect("owned node missing");
            match flit.payload.class() {
                MsgClass::Request => node.target.handle_request(flit, now),
                MsgClass::Response => {
                    let init = match flit.payload.bus() {
                        BusKind::Narrow => node.narrow.as_mut(),
                        BusKind::Wide => node.wide.as_mut(),
                    }
                    .expect("response delivered to node without initiator");
                    init.handle_response(flit)
                }
            }
        };
        if consumed {
            let f = shard.nets[n].links[lid].as_mut().unwrap().pop().unwrap();
            shard.counters[n].ejected += 1;
            let wide_bits = match f.payload {
                Payload::WideR(_) | Payload::WideW { .. } => 512,
                _ => 0,
            };
            shard.meters[n][idx]
                .as_mut()
                .expect("eject meter missing")
                .observe(now, wide_bits);
        }
    }
}

/// Phase 3 over owned nodes, ascending: terminate, pump writes,
/// register memory retirements, inject, drain — byte-for-byte the
/// serial phase 3 body.
fn phase_local(shard: &mut Shard, t: &Tables, now: u64) {
    for &idx in &t.owned_nodes[shard.id] {
        eject_node(shard, t, idx, now);
        {
            let node = shard.nodes[idx].as_mut().expect("owned node missing");
            node.target.pump_writes(now);
            if t.event {
                if let Some(ts) = node.target.take_scheduled() {
                    shard.calendar.schedule(ts);
                }
            }
        }
        {
            let (nets, counters, nodes) =
                (&mut shard.nets, &mut shard.counters, &mut shard.nodes);
            let mut port = ShardPort {
                nets,
                counters,
                tables: t,
                node_idx: idx,
            };
            inject::inject_node(
                t.iplan,
                nodes[idx].as_mut().expect("owned node missing"),
                &mut port,
                now,
            );
        }
        let node = shard.nodes[idx].as_mut().expect("owned node missing");
        if let Some(n) = node.narrow.as_mut() {
            n.drain_cycle();
        }
        if let Some(w) = node.wide.as_mut() {
            w.drain_cycle();
        }
    }
}

/// The harness-driven generator pass (`ComputeTile::step` /
/// `NocSystem::step_generator`), over owned tiles at the
/// post-increment clock, folding the shard's generator wake horizon.
fn gen_pass(shard: &mut Shard, t: &Tables, topo: &Topology) {
    shard.gen_fold = u64::MAX;
    let now = shard.now;
    for &idx in &t.owned_nodes[shard.id] {
        let Some(tile) = shard.tiles[idx].as_mut() else {
            continue;
        };
        let node = shard.nodes[idx].as_mut().expect("owned node missing");
        let mut fold = u64::MAX;
        for g in [tile.core_gen.as_mut(), tile.dma_gen.as_mut()]
            .into_iter()
            .flatten()
        {
            let init = match g.cfg.bus {
                BusKind::Narrow => node.narrow.as_mut(),
                BusKind::Wide => node.wide.as_mut(),
            }
            .expect("generator attached to node without initiator");
            g.step(now, init, topo);
            if t.event {
                fold = fold.min(g.next_wake(now));
            }
        }
        shard.gen_fold = shard.gen_fold.min(fold);
    }
}

/// This shard's end-of-cycle summary: delta counters, the three
/// per-node conjunctions the global decisions need, and the two wake
/// horizons. Evaluated after the generator pass with `now` already
/// incremented — the clock the serial decision points read at.
fn summarize(shard: &Shard, t: &Tables) -> Summary {
    let now = shard.now;
    let mut quiet = true;
    let mut idle = true;
    let mut done = true;
    for &idx in &t.owned_nodes[shard.id] {
        let node = shard.nodes[idx].as_ref().expect("owned node missing");
        quiet = quiet
            && node.inj.quiet()
            && node.target.eject_quiet(now)
            && node
                .narrow
                .as_ref()
                .map(Initiator::inject_quiet)
                .unwrap_or(true)
            && node
                .wide
                .as_ref()
                .map(Initiator::inject_quiet)
                .unwrap_or(true);
        idle = idle
            && node.target.is_idle()
            && node.narrow.as_ref().map(Initiator::is_idle).unwrap_or(true)
            && node.wide.as_ref().map(Initiator::is_idle).unwrap_or(true);
        if let Some(tile) = shard.tiles[idx].as_ref() {
            done = done && tile.done();
        }
    }
    Summary {
        injected: shard.counters.iter().map(|c| c.injected).collect(),
        ejected: shard.counters.iter().map(|c| c.ejected).collect(),
        nodes_quiet: quiet,
        nodes_idle: idle,
        gens_done: done,
        mem_wake: shard.calendar.earliest().unwrap_or(u64::MAX),
        gen_wake: shard.gen_fold,
    }
}

/// One shard's run loop. Every shard computes every global decision
/// from the same published summaries, so all shards take the same
/// branch each iteration and the barrier counts always agree.
fn worker(shard: &mut Shard, shared: &Shared, t: &Tables, topo: &Topology, max_cycles: u64) -> bool {
    let mut cycles_left = max_cycles;
    loop {
        // ---- replicated decision ----
        let sums: Vec<Summary> = shared
            .summaries
            .iter()
            .map(|m| m.lock().expect("summary lock").clone())
            .collect();
        let mut in_flight = vec![0u64; t.num_nets];
        for (n, f) in in_flight.iter_mut().enumerate() {
            let injected: u64 = t.base[n].injected + sums.iter().map(|s| s.injected[n]).sum::<u64>();
            let ejected: u64 = t.base[n].ejected + sums.iter().map(|s| s.ejected[n]).sum::<u64>();
            *f = injected - ejected;
        }
        let links_idle = in_flight.iter().all(|&f| f == 0);
        let complete = links_idle
            && sums.iter().all(|s| s.gens_done)
            && sums.iter().all(|s| s.nodes_idle);
        if complete {
            return true;
        }
        if cycles_left == 0 {
            return false;
        }
        cycles_left -= 1;
        // ---- event-mode fast-forward (same jump on every shard) ----
        if t.event && links_idle && sums.iter().all(|s| s.nodes_quiet) {
            let mem_wake = sums.iter().map(|s| s.mem_wake).min().unwrap_or(u64::MAX);
            let gen_wake = match sums.iter().map(|s| s.gen_wake).min().unwrap_or(u64::MAX) {
                u64::MAX => u64::MAX,
                w => w.saturating_sub(1), // gen-time → phase-time
            };
            let target = mem_wake.min(gen_wake);
            if target != u64::MAX && target > shard.now {
                shard.skipped += target - shard.now;
                shard.now = target;
            }
        }
        shard.stepped += 1;
        let now = shard.now;
        // ---- boundary drain + phase 1 ----
        drain_mailbox(shard, shared);
        for n in 0..t.num_nets {
            if t.dense && in_flight[n] == 0 {
                continue;
            }
            let tn = &t.nets[n];
            let mirror = &shared.mirrors[n];
            if t.dense {
                deliver_dense(&mut shard.nets[n], tn, mirror, shard.id);
            } else {
                deliver_gated(&mut shard.nets[n], tn, mirror, shard.id, t.check_invariants);
            }
        }
        shared.barrier.wait(); // A: mirrors published before any router reads
        // ---- phase 2 ----
        for n in 0..t.num_nets {
            if t.dense && in_flight[n] == 0 {
                continue;
            }
            let tn = &t.nets[n];
            let mirror = &shared.mirrors[n];
            let owned_routers = &t.owned_routers[shard.id];
            if t.dense {
                routers_dense(&mut shard.nets[n], tn, owned_routers, mirror, &mut shard.pending, n);
            } else {
                routers_gated(
                    &mut shard.nets[n],
                    tn,
                    owned_routers,
                    mirror,
                    &mut shard.pending,
                    n,
                    t.check_invariants,
                );
            }
        }
        flush_pending(shard, shared, t);
        // ---- phase 3 + bookkeeping ----
        phase_local(shard, t, now);
        shard.now = now + 1;
        gen_pass(shard, t, topo);
        // Unconditional early prune: the serial engine prunes lazily at
        // quiet decision points, but every earliest() it ever consults
        // happens after a prune through the same (or later) clock, so
        // removing stale entries each cycle can never change a
        // consulted value.
        shard.calendar.prune_through(shard.now);
        *shared.summaries[shard.id].lock().expect("summary lock") = summarize(shard, t);
        shared.barrier.wait(); // B: cycle sealed
    }
}

/// Run `sys` + `tiles` to completion (or `max_cycles`) on
/// `sys.cfg.shards` threads, byte-identical to
/// [`TiledWorkload::run_to_completion`](crate::cluster::TiledWorkload::run_to_completion)
/// at `shards = 1`. Returns `true` when every generator completed and
/// the system drained within the budget.
///
/// The system is decomposed into per-shard state, stepped under
/// [`std::thread::scope`] (the first shard runs on the calling
/// thread), and recomposed on exit — callers see a plain `&mut`
/// borrow, no `Arc`, no lifetime leakage. If the partition degenerates
/// to a single strip (fabric too small to split), the serial loop runs
/// instead.
pub fn run_sharded(sys: &mut NocSystem, tiles: &mut Vec<ComputeTile>, max_cycles: u64) -> bool {
    let plan = ShardPlan::new(&sys.topo, sys.cfg.shards);
    if plan.shards <= 1 {
        for _ in 0..max_cycles {
            if tiles.iter().all(ComputeTile::done) && sys.is_idle() {
                return true;
            }
            sys.step();
            for tile in tiles.iter_mut() {
                tile.step(sys);
            }
        }
        return tiles.iter().all(ComputeTile::done) && sys.is_idle();
    }
    let shards = plan.shards;
    let num_nets = sys.nets.len();
    let num_nodes = sys.nodes.len();
    let num_routers = sys.nets[0].routers.len();

    // ---- immutable tables ----
    let mut nets_t = Vec::with_capacity(num_nets);
    for net in &sys.nets {
        let nl = net.links.len();
        let mut owner = vec![usize::MAX; nl];
        for (lid, sink) in net.link_sink.iter().enumerate() {
            if let Some(r) = sink {
                owner[lid] = plan.router_shard[*r];
            }
        }
        for (idx, &lid) in net.eject.iter().enumerate() {
            owner[lid] = plan.node_shard[idx];
        }
        let mut producer = vec![usize::MAX; nl];
        for (r, router) in net.routers.iter().enumerate() {
            for &lid in router.out_links.iter().flatten() {
                producer[lid] = plan.router_shard[r];
            }
        }
        for (idx, &lid) in net.inject.iter().enumerate() {
            producer[lid] = plan.node_shard[idx];
        }
        let boundary: Vec<bool> = (0..nl)
            .map(|l| {
                debug_assert!(
                    owner[l] != usize::MAX && producer[l] != usize::MAX,
                    "link {l} has no owner or producer"
                );
                producer[l] != owner[l]
            })
            .collect();
        let owned_links: Vec<Vec<LinkId>> = (0..shards)
            .map(|s| (0..nl).filter(|&l| owner[l] == s).collect())
            .collect();
        nets_t.push(NetTables {
            owner,
            boundary,
            vcs: net.links.iter().map(|l| l.vcs() as u8).collect(),
            link_sink: net.link_sink.clone(),
            inject: net.inject.clone(),
            eject: net.eject.clone(),
            owned_links,
        });
    }
    let tables = Tables {
        nets: nets_t,
        owned_routers: (0..shards).map(|s| plan.routers_of(s)).collect(),
        owned_nodes: (0..shards).map(|s| plan.nodes_of(s)).collect(),
        base: sys.counters.clone(),
        iplan: sys.plan,
        dense: sys.cfg.sim_mode == SimMode::Dense,
        event: sys.cfg.sim_mode == SimMode::Event,
        check_invariants: cfg!(debug_assertions) || sys.cfg.check_invariants,
        num_nets,
        plan,
    };
    let plan = &tables.plan;

    // ---- decompose ----
    sys.calendar.prune_through(sys.now);
    let mut shard_states: Vec<Shard> = (0..shards)
        .map(|s| Shard {
            id: s,
            now: sys.now,
            stepped: 0,
            skipped: 0,
            gen_fold: if s == 0 { sys.gen_wake_min } else { u64::MAX },
            nets: (0..num_nets)
                .map(|n| ShardNet {
                    links: (0..sys.nets[n].links.len()).map(|_| None).collect(),
                    routers: (0..num_routers).map(|_| None).collect(),
                    link_active: ActiveSet::new(sys.nets[n].links.len()),
                    router_wake: ActiveSet::new(num_routers),
                })
                .collect(),
            nodes: (0..num_nodes).map(|_| None).collect(),
            tiles: (0..num_nodes).map(|_| None).collect(),
            meters: (0..num_nets)
                .map(|_| (0..num_nodes).map(|_| None).collect())
                .collect(),
            counters: vec![NetCounters::default(); num_nets],
            calendar: Calendar::new(),
            pending: Vec::new(),
            scratch: (0..shards).map(|_| Vec::new()).collect(),
        })
        .collect();
    shard_states[0].calendar = std::mem::take(&mut sys.calendar);
    for n in 0..num_nets {
        for (lid, link) in std::mem::take(&mut sys.nets[n].links).into_iter().enumerate() {
            shard_states[tables.nets[n].owner[lid]].nets[n].links[lid] = Some(link);
        }
        for (r, router) in std::mem::take(&mut sys.nets[n].routers).into_iter().enumerate() {
            shard_states[plan.router_shard[r]].nets[n].routers[r] = Some(router);
        }
        let active = std::mem::replace(&mut sys.nets[n].link_active, ActiveSet::new(0));
        for lid in active.iter() {
            shard_states[tables.nets[n].owner[lid]].nets[n].link_active.insert(lid);
        }
    }
    for (idx, node) in std::mem::take(&mut sys.nodes).into_iter().enumerate() {
        shard_states[plan.node_shard[idx]].nodes[idx] = Some(node);
    }
    for (n, meters) in std::mem::take(&mut sys.eject_meters).into_iter().enumerate() {
        for (idx, meter) in meters.into_iter().enumerate() {
            shard_states[plan.node_shard[idx]].meters[n][idx] = Some(meter);
        }
    }
    for tile in std::mem::take(tiles) {
        let idx = tile.node.0 as usize;
        shard_states[plan.node_shard[idx]].tiles[idx] = Some(tile);
    }

    // ---- shared fabric (mirrors seeded from current link state) ----
    let mirrors: Vec<Vec<AtomicU8>> = (0..num_nets)
        .map(|n| {
            let tn = &tables.nets[n];
            (0..tn.owner.len())
                .map(|lid| {
                    let mask = if tn.boundary[lid] {
                        offer_mask(
                            shard_states[tn.owner[lid]].nets[n].links[lid]
                                .as_ref()
                                .expect("boundary link missing at decompose"),
                        )
                    } else {
                        0
                    };
                    AtomicU8::new(mask)
                })
                .collect()
        })
        .collect();
    let shared = Shared {
        mirrors,
        mailboxes: (0..shards).map(|_| Mutex::new(Vec::new())).collect(),
        summaries: shard_states
            .iter()
            .map(|sh| Mutex::new(summarize(sh, &tables)))
            .collect(),
        barrier: Barrier::new(shards),
    };

    // ---- run ----
    let topo = &sys.topo;
    let completed = std::thread::scope(|scope| {
        let shared = &shared;
        let tables = &tables;
        let mut rest = shard_states.iter_mut();
        let first = rest.next().expect("at least one shard");
        let handles: Vec<_> = rest
            .map(|sh| scope.spawn(move || worker(sh, shared, tables, topo, max_cycles)))
            .collect();
        let result = worker(first, shared, tables, topo, max_cycles);
        for h in handles {
            let r = h.join().expect("shard worker panicked");
            debug_assert_eq!(r, result, "shard workers disagree on the outcome");
        }
        result
    });

    // ---- recompose ----
    for n in 0..num_nets {
        let nl = tables.nets[n].owner.len();
        let mut links = Vec::with_capacity(nl);
        for lid in 0..nl {
            let s = tables.nets[n].owner[lid];
            links.push(
                shard_states[s].nets[n].links[lid]
                    .take()
                    .expect("link lost in recompose"),
            );
        }
        // Rebuild the active set from occupancy. This is a (possibly
        // proper) subset of what a serial run would hold — serial can
        // keep a bit set on a link drained by an eject pop until the
        // next sweep visits it — but an empty link's delivery is a
        // statistics-free no-op, so dropping such bits is unobservable.
        let mut act = ActiveSet::new(nl);
        for (lid, link) in links.iter().enumerate() {
            if !link.is_quiescent() {
                act.insert(lid);
            }
        }
        sys.nets[n].links = links;
        sys.nets[n].link_active = act;
        let mut routers = Vec::with_capacity(num_routers);
        for r in 0..num_routers {
            routers.push(
                shard_states[plan.router_shard[r]].nets[n].routers[r]
                    .take()
                    .expect("router lost in recompose"),
            );
        }
        sys.nets[n].routers = routers;
        for sh in &shard_states {
            sys.counters[n].injected += sh.counters[n].injected;
            sys.counters[n].ejected += sh.counters[n].ejected;
        }
    }
    sys.nodes = (0..num_nodes)
        .map(|idx| {
            shard_states[plan.node_shard[idx]].nodes[idx]
                .take()
                .expect("node lost in recompose")
        })
        .collect();
    sys.eject_meters = (0..num_nets)
        .map(|n| {
            (0..num_nodes)
                .map(|idx| {
                    shard_states[plan.node_shard[idx]].meters[n][idx]
                        .take()
                        .expect("meter lost in recompose")
                })
                .collect()
        })
        .collect();
    *tiles = (0..num_nodes)
        .filter_map(|idx| shard_states[plan.node_shard[idx]].tiles[idx].take())
        .collect();
    for sh in &mut shard_states {
        let cal = std::mem::take(&mut sh.calendar);
        sys.calendar.merge_from(cal);
    }
    sys.now = shard_states[0].now;
    sys.stepped_cycles += shard_states[0].stepped;
    sys.skipped_cycles += shard_states[0].skipped;
    if tables.event && shard_states[0].stepped > 0 {
        sys.gen_wake_min = shard_states
            .iter()
            .map(|sh| sh.gen_fold)
            .min()
            .unwrap_or(u64::MAX);
    }
    completed
}

#[cfg(test)]
mod tests {
    use crate::cluster::{TileTraffic, TiledWorkload};
    use crate::flit::NodeId;
    use crate::noc::{NocConfig, NocSystem};

    fn workload(shards: usize) -> TiledWorkload {
        let sys = NocSystem::new(NocConfig::mesh(4, 4).with_shards(shards));
        let profiles = (0..16)
            .map(|i| {
                if i % 3 == 0 {
                    TileTraffic::single_dma_1kib(NodeId(((i + 5) % 16) as u16))
                } else {
                    TileTraffic::idle()
                }
            })
            .collect();
        TiledWorkload::new(sys, profiles)
    }

    #[test]
    fn sharded_run_matches_serial_counters_and_clock() {
        let mut serial = workload(1);
        assert!(serial.run_to_completion(100_000));
        for shards in [2, 4] {
            let mut sharded = workload(shards);
            assert!(sharded.run_to_completion(100_000), "{shards} shards stuck");
            assert_eq!(sharded.sys.now, serial.sys.now, "{shards} shards: clock diverged");
            for n in 0..serial.sys.nets.len() {
                assert_eq!(
                    sharded.sys.counters[n].injected, serial.sys.counters[n].injected,
                    "{shards} shards: net {n} injected diverged"
                );
                assert_eq!(
                    sharded.sys.counters[n].ejected, serial.sys.counters[n].ejected,
                    "{shards} shards: net {n} ejected diverged"
                );
            }
        }
    }

    #[test]
    fn clamped_shard_request_still_completes() {
        // A 2×1 mesh holds at most two column strips; shards = 8 clamps
        // to 2 and the run must still complete and agree with serial.
        let mk = |shards| {
            let sys = NocSystem::new(NocConfig::mesh(2, 1).with_shards(shards));
            let profiles = vec![TileTraffic::single_dma_1kib(NodeId(1)), TileTraffic::idle()];
            TiledWorkload::new(sys, profiles)
        };
        let mut serial = mk(1);
        let mut sharded = mk(8);
        assert!(serial.run_to_completion(10_000));
        assert!(sharded.run_to_completion(10_000));
        assert_eq!(sharded.sys.now, serial.sys.now);
    }
}
