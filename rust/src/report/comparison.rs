//! Table II: comparison of FlooNoC with state-of-the-art NoCs.
//!
//! The paper's Table II is a spec/feature comparison; the entries below
//! encode the published rows (with the paper's own annotations) plus the
//! values our reproduction computes for "This work".

use crate::phys::BandwidthModel;

/// Feature flags as printed in Table II.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Support {
    /// Fully supported.
    Yes,
    /// Not supported.
    No,
    /// Partially supported (with the paper's annotation).
    Partial(&'static str),
    /// Not disclosed by the publication.
    Unknown,
}

impl Support {
    /// The cell text used in the rendered table.
    pub fn glyph(&self) -> String {
        match self {
            Support::Yes => "yes".to_string(),
            Support::No => "no".to_string(),
            Support::Partial(note) => format!("~({note})"),
            Support::Unknown => "n.a.".to_string(),
        }
    }
}

/// One comparison row.
#[derive(Debug, Clone)]
pub struct NocEntry {
    /// Design name with the paper's citation tag.
    pub name: &'static str,
    /// Link width in bits (as published; `0` = not disclosed).
    pub link_bits: &'static str,
    /// Frequency in GHz (0.0 = not disclosed).
    pub freq_ghz: f64,
    /// Peak link bandwidth in Gbps (0.0 = not disclosed).
    pub link_gbps: f64,
    /// Open-source availability.
    pub open_source: Support,
    /// Multiple-outstanding-transaction support.
    pub outstanding_txns: Support,
    /// Full AXI4 compliance (bursts, IDs, ordering).
    pub axi4_compliant: Support,
    /// Physically implemented (not just RTL/simulation).
    pub physical_impl: Support,
}

/// The published rows of Table II plus this reproduction's computed row.
pub fn table_two_entries() -> Vec<NocEntry> {
    let this_work_bw = BandwidthModel::default().wide_link_gbps();
    vec![
        NocEntry {
            name: "FlexNoC [9]",
            link_bits: "n.a.",
            freq_ghz: 0.0,
            link_gbps: 0.0,
            open_source: Support::No,
            outstanding_txns: Support::Yes,
            axi4_compliant: Support::Yes,
            physical_impl: Support::Partial("not benchmarked openly"),
        },
        NocEntry {
            name: "CoreLink [8]",
            link_bits: "<=512",
            freq_ghz: 1.0,
            link_gbps: 512.0,
            open_source: Support::No,
            outstanding_txns: Support::Yes,
            axi4_compliant: Support::Yes,
            physical_impl: Support::Unknown,
        },
        NocEntry {
            name: "ESP [4]",
            link_bits: "5x64",
            freq_ghz: 0.8,
            link_gbps: 281.0,
            open_source: Support::Yes,
            outstanding_txns: Support::No,
            axi4_compliant: Support::No,
            physical_impl: Support::Yes,
        },
        NocEntry {
            name: "Constellation [7]",
            link_bits: "64",
            freq_ghz: 0.5,
            link_gbps: 32.0,
            open_source: Support::Yes,
            outstanding_txns: Support::Partial("no AXI4 reordering"),
            axi4_compliant: Support::Partial("1 txn per ID"),
            physical_impl: Support::No,
        },
        NocEntry {
            name: "OpenPiton [6]",
            link_bits: "3x64",
            freq_ghz: 1.0,
            link_gbps: 192.0,
            open_source: Support::Yes,
            outstanding_txns: Support::Partial("AXI4-Lite only"),
            axi4_compliant: Support::No,
            physical_impl: Support::Yes,
        },
        NocEntry {
            name: "Celerity [5]",
            link_bits: "80",
            freq_ghz: 1.0,
            link_gbps: 80.0,
            open_source: Support::Yes,
            outstanding_txns: Support::No,
            axi4_compliant: Support::No,
            physical_impl: Support::Yes,
        },
        NocEntry {
            name: "AXI4-XP [1]",
            link_bits: "512/64",
            freq_ghz: 1.0,
            link_gbps: 512.0,
            open_source: Support::Yes,
            outstanding_txns: Support::Yes,
            axi4_compliant: Support::Yes,
            physical_impl: Support::Partial("not scalable"),
        },
        NocEntry {
            name: "This work",
            link_bits: "512/64",
            freq_ghz: 1.23,
            link_gbps: this_work_bw,
            open_source: Support::Yes,
            outstanding_txns: Support::Yes,
            axi4_compliant: Support::Yes,
            physical_impl: Support::Yes,
        },
    ]
}

/// Render Table II.
pub fn table_two() -> String {
    let mut out = String::new();
    out.push_str("Table II: comparison with state-of-the-art NoCs\n");
    out.push_str(&format!(
        "{:<18} {:>9} {:>6} {:>9} {:>7} {:>22} {:>10} {:>14}\n",
        "work", "link[b]", "GHz", "Gbps", "open", "outstanding", "AXI4", "phys impl"
    ));
    for e in table_two_entries() {
        out.push_str(&format!(
            "{:<18} {:>9} {:>6} {:>9} {:>7} {:>22} {:>10} {:>14}\n",
            e.name,
            e.link_bits,
            if e.freq_ghz > 0.0 {
                format!("{:.2}", e.freq_ghz)
            } else {
                "n.a.".into()
            },
            if e.link_gbps > 0.0 {
                format!("{:.0}", e.link_gbps)
            } else {
                "n.a.".into()
            },
            e.open_source.glyph(),
            e.outstanding_txns.glyph(),
            e.axi4_compliant.glyph(),
            e.physical_impl.glyph()
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn this_work_row_matches_paper() {
        let rows = table_two_entries();
        let tw = rows.last().unwrap();
        assert_eq!(tw.name, "This work");
        assert!((tw.freq_ghz - 1.23).abs() < 1e-9);
        assert!((tw.link_gbps - 629.76).abs() < 0.1);
        assert_eq!(tw.open_source, Support::Yes);
        assert_eq!(tw.axi4_compliant, Support::Yes);
    }

    #[test]
    fn eight_published_rows_plus_this_work() {
        assert_eq!(table_two_entries().len(), 8);
    }

    #[test]
    fn only_this_work_and_flexnoc_corelink_axi4xp_are_fully_axi4() {
        let full: Vec<_> = table_two_entries()
            .into_iter()
            .filter(|e| e.axi4_compliant == Support::Yes)
            .map(|e| e.name)
            .collect();
        assert_eq!(
            full,
            vec!["FlexNoC [9]", "CoreLink [8]", "AXI4-XP [1]", "This work"]
        );
    }

    #[test]
    fn renders() {
        let t = table_two();
        assert!(t.contains("This work"));
        assert!(t.contains("630") || t.contains("629"));
    }
}
