//! Table/figure renderers: turn experiment rows into the tables the
//! paper prints, plus the Table-II state-of-the-art comparison.

pub mod comparison;

pub use comparison::{table_two, NocEntry};

use crate::coordinator::{AblationRow, Fig5aRow, Fig5bRow};
use crate::flit::NocLayout;
use crate::noc::LinkMode;

fn mode_name(m: LinkMode) -> &'static str {
    match m {
        LinkMode::NarrowWide => "narrow-wide",
        LinkMode::WideOnly => "wide-only",
    }
}

/// Render Table I from the layout calculator.
pub fn table_one(layout: &NocLayout) -> String {
    let mut out = String::new();
    out.push_str("Table I: physical links (computed from AXI parameters)\n");
    out.push_str(&format!(
        "{:<12} {:>8} {:>8} {:>8}  {}\n",
        "link", "header", "payload", "total", "mapping"
    ));
    let rows = [
        (
            "narrow_req",
            layout.narrow_req(),
            "narrow AR/AW/W + wide AR/AW",
        ),
        ("narrow_rsp", layout.narrow_rsp(), "narrow R/B + wide B"),
        ("wide", layout.wide_link(), "wide W/R (512-bit data)"),
    ];
    for (name, l, map) in rows {
        out.push_str(&format!(
            "{:<12} {:>8} {:>8} {:>8}  {}\n",
            name,
            l.header.bits(),
            l.payload_bits,
            l.flit_bits(),
            map
        ));
    }
    out.push_str(&format!(
        "duplex channel wires (incl. valid/ready): {}\n",
        layout.duplex_wires()
    ));
    out
}

/// Render the Fig. 5a series.
pub fn fig5a_table(rows: &[Fig5aRow]) -> String {
    let mut out = String::new();
    out.push_str("Fig. 5a: narrow-transaction latency vs wide-burst interference\n");
    out.push_str(&format!(
        "{:<12} {:>6} {:>12} {:>10} {:>10} {:>10} {:>9}\n",
        "config", "bidir", "wide_outst", "mean", "p99", "max", "slowdown"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<12} {:>6} {:>12} {:>10.1} {:>10} {:>10} {:>8.2}x\n",
            mode_name(r.mode),
            r.bidir,
            r.wide_outstanding,
            r.narrow_mean,
            r.narrow_p99,
            r.narrow_max,
            r.slowdown
        ));
    }
    out
}

/// Render the Fig. 5b series.
pub fn fig5b_table(rows: &[Fig5bRow]) -> String {
    let mut out = String::new();
    out.push_str(
        "Fig. 5b: effective wide-link bandwidth vs narrow interference\n",
    );
    out.push_str(&format!(
        "{:<12} {:>6} {:>14} {:>12} {:>10}\n",
        "config", "bidir", "narrow_outst", "utilization", "makespan"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<12} {:>6} {:>14} {:>11.1}% {:>10}\n",
            mode_name(r.mode),
            r.bidir,
            r.narrow_outstanding,
            r.utilization * 100.0,
            r.makespan
        ));
    }
    out
}

/// Render an ablation series.
pub fn ablation_table(title: &str, rows: &[AblationRow]) -> String {
    let mut out = format!("{title}\n");
    out.push_str(&format!("{:<20} {:>10} {:>14}\n", "param", "value", "metric"));
    for r in rows {
        out.push_str(&format!(
            "{:<20} {:>10} {:>14.3}\n",
            r.param, r.value, r.metric
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_one_contains_paper_numbers() {
        let t = table_one(&NocLayout::default());
        assert!(t.contains("119"));
        assert!(t.contains("103"));
        assert!(t.contains("603"));
        assert!(t.contains("narrow_req"));
    }

    #[test]
    fn fig_tables_render() {
        let rows = vec![Fig5aRow {
            mode: LinkMode::NarrowWide,
            bidir: false,
            wide_outstanding: 4,
            narrow_mean: 18.5,
            narrow_p99: 20,
            narrow_max: 22,
            slowdown: 1.02,
        }];
        let t = fig5a_table(&rows);
        assert!(t.contains("narrow-wide"));
        assert!(t.contains("1.02x"));
    }
}
