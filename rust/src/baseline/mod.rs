//! Baselines the paper compares against.
//!
//! * **Wide-only links** (Fig. 5): built into the simulator as
//!   [`crate::noc::LinkMode::WideOnly`] — same routers/NIs, all payload
//!   classes multiplexed onto one wide request + one wide response
//!   network.
//! * **AXI4 matrix interconnect** ([`axi_matrix`]): the AXI4-XP-style
//!   alternative (Kurth et al. [1], Table II) where AXI4 itself is the
//!   link-level protocol — quantifying the ID-width growth and
//!   ID-tracking state that motivates FlooNoC's endpoint reordering.

pub mod axi_matrix;

pub use axi_matrix::{AxiMatrixModel, MatrixScaling};
