//! AXI4-matrix baseline: multi-hop interconnect built from AXI4 crossbars.
//!
//! Models the scalability cost structure of using AXI4 as the link-level
//! protocol (paper §II-A, §VII): every crossbar stage widens IDs by
//! log2(initiators) bits, and every stage must track outstanding
//! transactions *per ID value* to enforce same-ID ordering. The per-stage
//! tracker state therefore grows exponentially with hop count [1].
//!
//! The model also produces the latency/area consequences used in the
//! Table-II comparison row and the scalability ablation bench.

use crate::axi::idwidth;
use crate::util::json::Json;

/// One mesh deployment implemented as cascaded AXI4 crossbars.
#[derive(Debug, Clone)]
pub struct AxiMatrixModel {
    /// Endpoint ID bits (paper tile: 4).
    pub base_id_bits: u32,
    /// Initiator ports muxed per crossbar stage (5-port mesh node).
    pub initiators_per_stage: u32,
    /// Outstanding transactions supported per ID.
    pub outstanding_per_id: u32,
    /// Crossbar traversal latency in cycles (arbitration + mux).
    pub stage_latency: u64,
}

impl Default for AxiMatrixModel {
    fn default() -> Self {
        AxiMatrixModel {
            base_id_bits: 4,
            initiators_per_stage: 5,
            outstanding_per_id: 4,
            stage_latency: 2,
        }
    }
}

/// Scaling record for one hop count.
#[derive(Debug, Clone)]
pub struct MatrixScaling {
    /// Network diameter in interconnect stages.
    pub hops: u32,
    /// ID bits at the observation point (grows per stage).
    pub id_bits: u32,
    /// ID-tracker table entries required.
    pub tracker_entries: u128,
    /// Gate-count estimate for those trackers.
    pub tracker_gates: u128,
    /// End-to-end latency at this depth.
    pub latency_cycles: u64,
}

impl MatrixScaling {
    /// Serialize for reports.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("hops", Json::Num(self.hops as f64)),
            ("id_bits", Json::Num(self.id_bits as f64)),
            (
                "tracker_entries",
                Json::Num(self.tracker_entries.min(1 << 52) as f64),
            ),
            (
                "tracker_kge",
                Json::Num((self.tracker_gates.min(1 << 52) as f64) / 1e3),
            ),
            ("latency_cycles", Json::Num(self.latency_cycles as f64)),
        ])
    }
}

impl AxiMatrixModel {
    /// Cost of supporting transactions across `hops` crossbar stages.
    pub fn at_hops(&self, hops: u32) -> MatrixScaling {
        let id_bits =
            idwidth::id_width_after_hops(self.base_id_bits, self.initiators_per_stage, hops);
        MatrixScaling {
            hops,
            id_bits,
            tracker_entries: idwidth::tracker_entries(id_bits, self.outstanding_per_id),
            tracker_gates: idwidth::tracker_gates(id_bits, self.outstanding_per_id),
            latency_cycles: self.stage_latency * hops as u64,
        }
    }

    /// Sweep hop counts (the scalability ablation).
    pub fn sweep(&self, max_hops: u32) -> Vec<MatrixScaling> {
        (0..=max_hops).map(|h| self.at_hops(h)).collect()
    }

    /// The FlooNoC equivalent: NI reorder-table state is independent of
    /// hop count (only endpoint IDs matter).
    pub fn floonoc_ni_entries(&self) -> u128 {
        idwidth::floonoc_ni_table_entries(self.base_id_bits, self.outstanding_per_id)
    }

    /// Hop count at which the per-stage tracker alone exceeds the paper's
    /// *entire* NoC area budget (500 kGE) — the scalability wall.
    pub fn scalability_wall_hops(&self, budget_ge: u128) -> u32 {
        for h in 0..64 {
            if self.at_hops(h).tracker_gates > budget_ge {
                return h;
            }
        }
        64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracker_growth_is_exponential() {
        let m = AxiMatrixModel::default();
        let s = m.sweep(6);
        // Each hop adds ceil(log2 5) = 3 ID bits -> 8x tracker state.
        for w in s.windows(2) {
            assert_eq!(w[1].id_bits - w[0].id_bits, 3);
            assert_eq!(w[1].tracker_entries / w[0].tracker_entries, 8);
        }
    }

    #[test]
    fn floonoc_state_is_flat() {
        let m = AxiMatrixModel::default();
        let ni = m.floonoc_ni_entries();
        assert_eq!(ni, 64); // 16 IDs x 4 outstanding
        // At 7 hops the matrix tracker dwarfs the NI by >10^5.
        assert!(m.at_hops(7).tracker_entries > ni * 100_000);
    }

    #[test]
    fn scalability_wall_is_near() {
        let m = AxiMatrixModel::default();
        // 500 kGE NoC budget: the matrix blows through it within a few
        // hops — the paper's scalability argument, quantified.
        let wall = m.scalability_wall_hops(500_000);
        assert!(
            (2..=5).contains(&wall),
            "tracker exceeds the whole NoC budget within a few hops, got {wall}"
        );
    }

    #[test]
    fn latency_scales_linearly() {
        let m = AxiMatrixModel::default();
        assert_eq!(m.at_hops(4).latency_cycles, 8);
    }
}
