//! Command-line interface (hand-rolled: `clap` is not in the offline
//! crate snapshot).
//!
//! ```text
//! repro info                          system summary (layout, area, bw)
//! repro reproduce <exp> [--bidir]     regenerate a paper table/figure:
//!        tab1 | tab2 | fig5a | fig5b | fig6a | fig6b |
//!        latency | bandwidth | wires | scaling | all
//! repro simulate [--config f] [--topology k] [--routing r] [--vcs n] [--sim-mode m] [--txns n]  uniform traffic
//! repro verify [--config f] [--topology k] [--routing r] [--vcs n] [--json] [--deep]  static checks
//! repro sweep <rob|buffers|burst|mesh|topology|vcs|output-reg>  ablations
//! repro scale_topology [--mesh n]     mesh vs torus vs ring at equal tiles
//! repro dse [--mesh n] [--artifacts dir]              analytical model vs sim
//! repro bench [--out path] [--quick]  e2e perf scenarios -> BENCH_e2e.json
//! repro bench --profile [--quick]     per-phase wall-time profile of the
//!                                     saturated hot path -> BENCH_profile.json
//! ```
//!
//! Sweep-style commands (`reproduce fig5a|fig5b`, `sweep`, `dse`) accept
//! `--jobs <n>`: independent sweep points fan out over `n` worker threads
//! (0 or omitted = all cores, 1 = serial) with deterministic,
//! order-stable results.

use std::collections::HashMap;

use anyhow::{bail, Context};

/// Parsed command line: subcommand, positional args, `--key value` /
/// `--flag` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// The subcommand (first token).
    pub command: String,
    /// Positional arguments in order.
    pub positional: Vec<String>,
    /// `--key value` options.
    pub options: HashMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an argv-style iterator (excluding the program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> crate::Result<Args> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        let Some(cmd) = it.next() else {
            bail!("no command given (try 'repro help')");
        };
        args.command = cmd;
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                // `--key value` when the next token is not another option;
                // bare `--flag` otherwise.
                match it.peek() {
                    Some(next) if !next.starts_with("--") => {
                        let val = it.next().unwrap();
                        args.options.insert(key.to_string(), val);
                    }
                    _ => args.flags.push(key.to_string()),
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Was the bare flag `--name` given?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// The value of `--name value`, if given.
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    /// Integer option with a default; errors on non-integer input.
    pub fn opt_u64(&self, name: &str, default: u64) -> crate::Result<u64> {
        match self.opt(name) {
            Some(v) => v
                .parse()
                .with_context(|| format!("--{name} expects an integer, got '{v}'")),
            None => Ok(default),
        }
    }

    /// Positional argument by index.
    pub fn pos(&self, idx: usize) -> Option<&str> {
        self.positional.get(idx).map(String::as_str)
    }
}

/// The `repro help` text.
pub const HELP: &str = "\
FlooNoC reproduction CLI

USAGE: repro <command> [args]

COMMANDS:
  info                         layout, area, bandwidth and timing summary
  reproduce <experiment>       regenerate a paper table/figure:
                               tab1 tab2 fig5a fig5b fig6a fig6b latency
                               bandwidth wires scaling all
                               options: --bidir, --levels a,b,c, --jobs <n>
  simulate                     run uniform-random traffic on a fabric
                               (wide wormhole bursts included: wrap
                               fabrics are deadlock-free via dateline
                               virtual channels)
                               options: --config <file.json>, --txns <n>,
                               --mesh <n>, --topology <mesh|torus|ring>,
                               --routing <deterministic|adaptive>, --vcs <n>,
                               --sim-mode <gated|dense|event>,
                               --shards <n>, --wide-only, --no-verify,
                               --check-invariants
  verify                       statically verify a config before any cycle
                               runs: channel-dependency-graph deadlock
                               freedom, route sanity, config lints — the
                               same preflight simulate runs, as a command
                               (see docs/verification.md)
                               options: --config <file.json>, --mesh <n>,
                               --topology <mesh|torus|ring>, --routing
                               <deterministic|adaptive>, --vcs <n>,
                               --wide-only, --json (machine-readable
                               report), --deep (one gated warm-up epoch
                               with invariant scans forced on)
  sweep <ablation>             rob | buffers | burst | mesh | topology |
                               vcs | output-reg; options: --jobs <n>
  scale_topology               compare mesh vs torus vs ring at the same
                               tile count (uniform-random traffic): mean
                               hop counts and delivered throughput;
                               options: --mesh <n> (n*n tiles), --jobs <n>
  dse                          analytical link-load model (PJRT artifact)
                               cross-validated against the simulator, plus
                               a parallel cycle-accurate point sweep with
                               cross-topology rows; options: --mesh <n>,
                               --artifacts <dir>, --jobs <n>
  bench                        end-to-end performance scenarios (activity-
                               gated vs dense cycles/s on sparse + saturated
                               workloads, parallel-sweep speedup, cps gate)
                               written to BENCH_e2e.json at the repo root;
                               options: --out <path>, --quick, --profile
                               (--profile runs the per-phase wall-time
                               profiler over the saturated scenarios
                               instead — link deliver / router sweep / NI /
                               generators / gating overhead — and writes
                               BENCH_profile.json, schema floonoc-profile/1)

  --topology <kind>: fabric shape for simulate (mesh is the default;
              torus adds wraparound rows+columns, ring is a 1-D cycle).
  --routing <r>: routing discipline (simulate/verify): deterministic
              (default: XY / dateline dimension-order) or adaptive
              (minimal-adaptive over VC lanes above the fabric's escape
              lanes, which keep running the deterministic baseline —
              Duato-style; see docs/deadlock.md). Adaptive raises the
              default VC count by one adaptive lane; an explicit --vcs
              below escape+1 is rejected by the verifier (FV107).
  --vcs <n>:  virtual channels per link (default: 1 on meshes, 2 dateline
              VCs on torus/ring — see docs/deadlock.md; +1 adaptive lane
              under --routing adaptive).
  --sim-mode <m>: step-loop engine (simulate/verify): gated (default,
              active-set sweeps), dense (reference full sweep), event
              (gated + calendar fast-forward over idle cycles). All three
              are cycle-accurate and produce identical results — see
              docs/performance.md.
  --shards <n>: execution shards for the run loop (simulate; default 1 =
              serial). The fabric is cut into n contiguous strips stepped
              on n threads with deterministic cross-shard exchange —
              statistics are byte-identical at any shard count; clamped
              to the strip dimension (see docs/architecture.md).
  --no-verify: skip the static preflight verifier (simulate); configs the
              verifier rejects as deadlock-prone then build anyway.
  --check-invariants: enforce the gating "occupied => active" invariant
              scans in release builds too (debug builds always scan).
  --jobs <n>: worker threads for sweep points (0/omitted = all cores,
              1 = serial); results are identical for any worker count.
  help                         this text
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_subcommand_and_positionals() {
        let a = parse("reproduce fig5a");
        assert_eq!(a.command, "reproduce");
        assert_eq!(a.pos(0), Some("fig5a"));
    }

    #[test]
    fn parses_options_and_flags() {
        let a = parse("simulate --mesh 4 --wide-only --txns 100");
        assert_eq!(a.opt("mesh"), Some("4"));
        assert!(a.flag("wide-only"));
        assert_eq!(a.opt_u64("txns", 0).unwrap(), 100);
        assert_eq!(a.opt_u64("missing", 7).unwrap(), 7);
    }

    #[test]
    fn flag_followed_by_option() {
        let a = parse("reproduce fig5a --bidir --levels 0,4,8");
        assert!(a.flag("bidir"));
        assert_eq!(a.opt("levels"), Some("0,4,8"));
    }

    #[test]
    fn rejects_empty() {
        assert!(Args::parse(Vec::<String>::new()).is_err());
    }

    #[test]
    fn bad_int_is_error() {
        let a = parse("simulate --txns many");
        assert!(a.opt_u64("txns", 0).is_err());
    }
}
