//! AXI4 protocol ordering monitor.
//!
//! The executable statement of the AXI4 rules the paper's NI must uphold
//! (spec IHI0022E, summarized in §II-A of the paper):
//!
//! * responses to transactions with the **same ID** return in issue order;
//! * **R beats** of one read burst are contiguous per ID (no interleaving
//!   of different transactions with the same ID) and carry the right beat
//!   count with `last` on the final beat;
//! * a **B response** arrives only after the corresponding AW/W burst was
//!   fully issued, exactly once;
//! * transactions with *different* IDs may complete in any order (this is
//!   what the NI's ROB exploits).
//!
//! The monitor is attached at the AXI boundary (between generator and NI)
//! by every integration test, so any reordering bug in the NI or network
//! becomes a test failure here rather than a silent data hazard.

use std::collections::HashMap;

use super::types::{AxReq, AxiId, BResp, RBeat};

/// Result of a monitor check.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Violation {
    /// R/B carried an ID with no outstanding transaction.
    SpuriousResponse { id: AxiId },
    /// R beats of a burst interleaved with another txn of the same ID.
    ReadBeatOutOfOrder { id: AxiId, expected_beat: u32, got: u32 },
    /// `last` flag wrong for the beat position.
    BadLast { id: AxiId, beat: u32 },
    /// More B responses than writes issued for this ID.
    SpuriousWriteResponse { id: AxiId },
}

#[derive(Debug, Clone)]
struct OutstandingRead {
    req: AxReq,
    next_beat: u32,
}

/// Per-endpoint protocol monitor.
#[derive(Debug, Default)]
pub struct OrderingMonitor {
    /// Outstanding reads per ID, in issue order (front = oldest).
    reads: HashMap<AxiId, Vec<OutstandingRead>>,
    /// Outstanding writes per ID (count of fully-issued write bursts
    /// awaiting B), in issue order.
    writes: HashMap<AxiId, u32>,
    /// All violations observed (tests assert this stays empty).
    pub violations: Vec<Violation>,
    /// Completed read-transaction count.
    pub reads_completed: u64,
    /// Completed write-transaction count.
    pub writes_completed: u64,
}

impl OrderingMonitor {
    /// A fresh monitor with no outstanding state.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record an issued read request.
    pub fn on_ar(&mut self, req: AxReq) {
        self.reads.entry(req.id).or_default().push(OutstandingRead {
            req,
            next_beat: 0,
        });
    }

    /// Record a fully-issued write burst (AW + all W beats).
    pub fn on_aw(&mut self, req: AxReq) {
        *self.writes.entry(req.id).or_default() += 1;
    }

    /// Check an incoming read beat. AXI requires same-ID responses in issue
    /// order, so the beat must belong to the *oldest* outstanding read of
    /// its ID. Returns true when the beat completed a transaction.
    pub fn on_r(&mut self, beat: RBeat) -> bool {
        let Some(queue) = self.reads.get_mut(&beat.id) else {
            self.violations.push(Violation::SpuriousResponse { id: beat.id });
            return false;
        };
        let Some(head) = queue.first_mut() else {
            self.violations.push(Violation::SpuriousResponse { id: beat.id });
            return false;
        };
        if beat.beat != head.next_beat {
            self.violations.push(Violation::ReadBeatOutOfOrder {
                id: beat.id,
                expected_beat: head.next_beat,
                got: beat.beat,
            });
            return false;
        }
        let is_final = head.next_beat + 1 == head.req.beats();
        if beat.last != is_final {
            self.violations.push(Violation::BadLast {
                id: beat.id,
                beat: beat.beat,
            });
            return false;
        }
        head.next_beat += 1;
        if is_final {
            queue.remove(0);
            if queue.is_empty() {
                self.reads.remove(&beat.id);
            }
            self.reads_completed += 1;
            true
        } else {
            false
        }
    }

    /// Check an incoming write response.
    pub fn on_b(&mut self, resp: BResp) -> bool {
        match self.writes.get_mut(&resp.id) {
            Some(n) if *n > 0 => {
                *n -= 1;
                if *n == 0 {
                    self.writes.remove(&resp.id);
                }
                self.writes_completed += 1;
                true
            }
            _ => {
                self.violations
                    .push(Violation::SpuriousWriteResponse { id: resp.id });
                false
            }
        }
    }

    /// All issued transactions have completed.
    pub fn quiescent(&self) -> bool {
        self.reads.is_empty() && self.writes.is_empty()
    }

    /// Number of still-outstanding transactions.
    pub fn outstanding(&self) -> usize {
        self.reads.values().map(Vec::len).sum::<usize>()
            + self.writes.values().map(|&n| n as usize).sum::<usize>()
    }

    /// True when no violation has been observed.
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::axi::types::{Burst, Resp};

    fn rreq(id: AxiId, len: u8) -> AxReq {
        AxReq {
            id,
            addr: 0x1000,
            len,
            size: 3,
            burst: Burst::Incr,
            atop: false,
        }
    }

    fn rbeat(id: AxiId, beat: u32, last: bool) -> RBeat {
        RBeat {
            id,
            beat,
            last,
            resp: Resp::Okay,
        }
    }

    #[test]
    fn in_order_read_accepted() {
        let mut m = OrderingMonitor::new();
        m.on_ar(rreq(1, 1)); // 2 beats
        assert!(!m.on_r(rbeat(1, 0, false)));
        assert!(m.on_r(rbeat(1, 1, true)));
        assert!(m.ok());
        assert!(m.quiescent());
        assert_eq!(m.reads_completed, 1);
    }

    #[test]
    fn same_id_order_enforced() {
        let mut m = OrderingMonitor::new();
        m.on_ar(rreq(1, 0));
        m.on_ar(rreq(1, 1)); // second txn, 2 beats
        // Response for the *second* txn arriving first: its beat count is 2
        // so beat 0 matches the head's expectation... the head has 1 beat,
        // so a beat with last=false mismatches the head's `last` and trips
        // BadLast — the monitor catches the reorder.
        assert!(!m.on_r(rbeat(1, 0, false)));
        assert!(!m.ok());
    }

    #[test]
    fn different_ids_any_order() {
        let mut m = OrderingMonitor::new();
        m.on_ar(rreq(1, 0));
        m.on_ar(rreq(2, 0));
        assert!(m.on_r(rbeat(2, 0, true)));
        assert!(m.on_r(rbeat(1, 0, true)));
        assert!(m.ok());
        assert!(m.quiescent());
    }

    #[test]
    fn spurious_read_flagged() {
        let mut m = OrderingMonitor::new();
        m.on_r(rbeat(7, 0, true));
        assert_eq!(
            m.violations,
            vec![Violation::SpuriousResponse { id: 7 }]
        );
    }

    #[test]
    fn write_response_accounting() {
        let mut m = OrderingMonitor::new();
        m.on_aw(rreq(3, 0));
        m.on_aw(rreq(3, 0));
        assert!(m.on_b(BResp { id: 3, resp: Resp::Okay }));
        assert!(m.on_b(BResp { id: 3, resp: Resp::Okay }));
        assert!(!m.on_b(BResp { id: 3, resp: Resp::Okay }));
        assert_eq!(m.violations.len(), 1);
        assert_eq!(m.writes_completed, 2);
    }

    #[test]
    fn interleaved_beats_flagged() {
        let mut m = OrderingMonitor::new();
        m.on_ar(rreq(1, 3)); // 4 beats
        assert!(!m.on_r(rbeat(1, 0, false)));
        // Beat 2 arrives instead of beat 1 -> out of order.
        m.on_r(rbeat(1, 2, false));
        assert!(matches!(
            m.violations[0],
            Violation::ReadBeatOutOfOrder { id: 1, expected_beat: 1, got: 2 }
        ));
    }

    #[test]
    fn outstanding_counts() {
        let mut m = OrderingMonitor::new();
        m.on_ar(rreq(1, 0));
        m.on_aw(rreq(2, 0));
        assert_eq!(m.outstanding(), 2);
        m.on_r(rbeat(1, 0, true));
        assert_eq!(m.outstanding(), 1);
    }
}
