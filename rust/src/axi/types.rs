//! AXI4 channel payload types and burst arithmetic.

/// AXI4 transaction identifier. The paper's tile exposes 4-bit narrow and
/// wide IDs at the NI boundary; we keep `u16` for headroom in sweeps.
pub type AxiId = u16;

/// Byte address (paper: ADDRWIDTH = 48).
pub type Addr = u64;

/// Burst type (AxBURST).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Burst {
    /// Same address every beat (FIFO-style peripherals).
    Fixed,
    /// Incrementing addresses — the common case for memory.
    Incr,
    /// Wrapping bursts (cache-line fills).
    Wrap,
}

/// Response code (xRESP).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Resp {
    /// Normal success.
    Okay,
    /// Exclusive-access success.
    ExOkay,
    /// Slave error.
    SlvErr,
    /// Decode error (no target at the address).
    DecErr,
}

/// Read/write request descriptor (AR and AW carry the same fields).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AxReq {
    /// Transaction ID (AxID).
    pub id: AxiId,
    /// Start byte address (AxADDR).
    pub addr: Addr,
    /// AxLEN: beats = len + 1, 0..=255 (INCR).
    pub len: u8,
    /// AxSIZE: bytes per beat = 1 << size.
    pub size: u8,
    /// Burst type (AxBURST).
    pub burst: Burst,
    /// Atomic operation marker (AXI5-style ATOP as used by the PULP
    /// ecosystem; the paper's NI stores atomics in separate meta buffers).
    pub atop: bool,
}

impl AxReq {
    /// Number of data beats in the burst.
    #[inline]
    pub fn beats(&self) -> u32 {
        self.len as u32 + 1
    }

    /// Bytes per beat.
    #[inline]
    pub fn beat_bytes(&self) -> u32 {
        1 << self.size
    }

    /// Total payload bytes of the burst.
    #[inline]
    pub fn total_bytes(&self) -> u32 {
        self.beats() * self.beat_bytes()
    }

    /// Address of beat `i` per the AXI4 burst equations.
    pub fn beat_addr(&self, i: u32) -> Addr {
        let nb = self.beat_bytes() as u64;
        match self.burst {
            Burst::Fixed => self.addr,
            Burst::Incr => self.addr + nb * i as u64,
            Burst::Wrap => {
                let container = nb * self.beats() as u64;
                let base = self.addr & !(container - 1);
                base + ((self.addr - base) + nb * i as u64) % container
            }
        }
    }

    /// AXI4 forbids INCR bursts from crossing a 4 kB boundary.
    pub fn crosses_4k(&self) -> bool {
        match self.burst {
            Burst::Incr => {
                let last = self.addr + (self.total_bytes() as u64 - 1);
                (self.addr >> 12) != (last >> 12)
            }
            _ => false,
        }
    }

    /// Protocol-legality check used by generators and the ordering monitor.
    pub fn is_legal(&self, data_bytes: u32) -> bool {
        if self.beat_bytes() > data_bytes {
            return false; // AxSIZE must not exceed the bus width
        }
        if self.crosses_4k() {
            return false;
        }
        match self.burst {
            Burst::Wrap => {
                // WRAP: length must be 2, 4, 8 or 16 beats and the address
                // aligned to the beat size.
                matches!(self.beats(), 2 | 4 | 8 | 16)
                    && self.addr % self.beat_bytes() as u64 == 0
            }
            Burst::Fixed => self.beats() <= 16,
            Burst::Incr => true,
        }
    }
}

/// Write-data beat.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WBeat {
    /// Beat index within the burst (modelling WDATA; the simulator tracks
    /// payload identity, not bit patterns, except in the compute bridge).
    pub beat: u32,
    /// WLAST marker.
    pub last: bool,
}

/// Read-data beat.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RBeat {
    /// Transaction ID (RID).
    pub id: AxiId,
    /// Beat index within the burst.
    pub beat: u32,
    /// RLAST marker.
    pub last: bool,
    /// Per-beat response code.
    pub resp: Resp,
}

/// Write response.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BResp {
    /// Transaction ID (BID).
    pub id: AxiId,
    /// Response code.
    pub resp: Resp,
}

/// A complete transaction as observed by generators / scoreboards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    /// AR/R transaction.
    Read,
    /// AW/W/B transaction.
    Write,
}

/// Unique transaction tag used by scoreboards (not an AXI field).
pub type TxnTag = u64;

/// A transaction in flight, as tracked by test scoreboards and the
/// latency statistics.
#[derive(Debug, Clone)]
pub struct Txn {
    /// Scoreboard tag (unique per transaction).
    pub tag: TxnTag,
    /// Read or write.
    pub dir: Dir,
    /// The request descriptor.
    pub req: AxReq,
    /// Issue cycle.
    pub issued_at: u64,
    /// Completion cycle, once the last beat / B arrived.
    pub completed_at: Option<u64>,
}

impl Txn {
    /// Round-trip latency, if completed.
    pub fn latency(&self) -> Option<u64> {
        self.completed_at.map(|c| c - self.issued_at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(addr: Addr, len: u8, size: u8, burst: Burst) -> AxReq {
        AxReq {
            id: 0,
            addr,
            len,
            size,
            burst,
            atop: false,
        }
    }

    #[test]
    fn incr_beat_addresses() {
        let r = req(0x1000, 3, 3, Burst::Incr); // 4 beats x 8B
        assert_eq!(r.beats(), 4);
        assert_eq!(r.beat_addr(0), 0x1000);
        assert_eq!(r.beat_addr(3), 0x1018);
        assert_eq!(r.total_bytes(), 32);
    }

    #[test]
    fn wrap_beat_addresses() {
        // 4-beat x 4B wrap starting at offset 8 of a 16B container.
        let r = req(0x108, 3, 2, Burst::Wrap);
        assert_eq!(r.beat_addr(0), 0x108);
        assert_eq!(r.beat_addr(1), 0x10C);
        assert_eq!(r.beat_addr(2), 0x100); // wrapped
        assert_eq!(r.beat_addr(3), 0x104);
    }

    #[test]
    fn fixed_beat_addresses() {
        let r = req(0x200, 7, 2, Burst::Fixed);
        for i in 0..8 {
            assert_eq!(r.beat_addr(i), 0x200);
        }
    }

    #[test]
    fn four_k_boundary() {
        let ok = req(0xF80, 15, 3, Burst::Incr); // ends at 0xFFF
        assert!(!ok.crosses_4k());
        assert!(ok.is_legal(8));
        let bad = req(0xF88, 15, 3, Burst::Incr); // crosses into next page
        assert!(bad.crosses_4k());
        assert!(!bad.is_legal(8));
    }

    #[test]
    fn wrap_legality() {
        assert!(req(0x100, 3, 2, Burst::Wrap).is_legal(8)); // 4 beats ok
        assert!(!req(0x100, 2, 2, Burst::Wrap).is_legal(8)); // 3 beats bad
        assert!(!req(0x101, 3, 2, Burst::Wrap).is_legal(8)); // misaligned
    }

    #[test]
    fn size_exceeding_bus_illegal() {
        assert!(!req(0, 0, 4, Burst::Incr).is_legal(8)); // 16B beat on 8B bus
        assert!(req(0, 0, 3, Burst::Incr).is_legal(8));
    }

    #[test]
    fn fixed_len_cap() {
        assert!(req(0, 15, 2, Burst::Fixed).is_legal(8));
        assert!(!req(0, 16, 2, Burst::Fixed).is_legal(8));
    }

    #[test]
    fn txn_latency() {
        let t = Txn {
            tag: 1,
            dir: Dir::Read,
            req: req(0, 0, 3, Burst::Incr),
            issued_at: 10,
            completed_at: Some(28),
        };
        assert_eq!(t.latency(), Some(18));
    }
}
