//! ID-width growth arithmetic for AXI4 multi-hop interconnects.
//!
//! Background for the paper's scalability argument (§II-A, §VII): when AXI4
//! itself is used as the link-level protocol, every N:1 multiplexer stage
//! must widen the ID by log2(N) bits to keep transactions unique, and every
//! crossbar must track outstanding transactions *per ID*. This module
//! quantifies that growth and the resulting tracker state so the
//! AXI4-matrix baseline ([`crate::baseline::axi_matrix`]) can report the
//! exponential complexity the paper cites from Kurth et al. [1].

/// ID width after crossing `hops` crossbar stages, each muxing `initiators`
/// masters onto one slave port, starting from `base_bits` at the endpoint.
pub fn id_width_after_hops(base_bits: u32, initiators: u32, hops: u32) -> u32 {
    let grow = (initiators.max(2) as f64).log2().ceil() as u32;
    base_bits + grow * hops
}

/// Number of distinct IDs a tracker at the given stage must handle.
pub fn id_space(bits: u32) -> u128 {
    if bits >= 127 {
        u128::MAX
    } else {
        1u128 << bits
    }
}

/// Tracker state (in counter entries) for a crossbar that must support
/// `outstanding` transactions per ID over a `bits`-wide ID space. This is
/// the structure whose growth "increases exponentially in complexity" [1].
pub fn tracker_entries(bits: u32, outstanding: u32) -> u128 {
    id_space(bits).saturating_mul(outstanding as u128)
}

/// Approximate gate cost (GE) of an ID-tracking table: one small counter
/// (~12 GE including decode share) per entry, saturating to keep the model
/// defined in the absurd regimes the growth reaches.
pub fn tracker_gates(bits: u32, outstanding: u32) -> u128 {
    tracker_entries(bits, outstanding).saturating_mul(12)
}

/// The same cost for an endpoint-reordering NoC (FlooNoC): the routers keep
/// **no** per-ID state; only the NI's reorder table scales, and only with
/// the number of *endpoint* IDs, independent of hop count.
pub fn floonoc_ni_table_entries(endpoint_id_bits: u32, outstanding: u32) -> u128 {
    id_space(endpoint_id_bits).saturating_mul(outstanding as u128)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn width_grows_linearly_with_hops() {
        // 4-bit endpoint IDs, 4-initiator crossbars.
        assert_eq!(id_width_after_hops(4, 4, 0), 4);
        assert_eq!(id_width_after_hops(4, 4, 1), 6);
        assert_eq!(id_width_after_hops(4, 4, 7), 18);
    }

    #[test]
    fn tracker_state_explodes_exponentially() {
        let w0 = id_width_after_hops(4, 4, 0);
        let w7 = id_width_after_hops(4, 4, 7);
        let t0 = tracker_entries(w0, 4);
        let t7 = tracker_entries(w7, 4);
        // 14 extra bits -> 2^14 x more state.
        assert_eq!(t7 / t0, 1 << 14);
    }

    #[test]
    fn floonoc_state_independent_of_hops() {
        let ni = floonoc_ni_table_entries(4, 4);
        assert_eq!(ni, 64);
        // Even at 7 hops the NI table stays the same size, while the matrix
        // tracker grew by 2^14.
        assert!(tracker_entries(id_width_after_hops(4, 4, 7), 4) > 1000 * ni);
    }

    #[test]
    fn id_space_saturates() {
        assert_eq!(id_space(2), 4);
        assert_eq!(id_space(200), u128::MAX);
    }
}
