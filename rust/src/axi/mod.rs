//! AXI4 transaction model.
//!
//! Models the five AXI4 channels (AW, W, B, AR, R) at transaction/beat
//! granularity: IDs, burst types and lengths, the 4 kB boundary rule, and
//! the protocol's per-ID ordering requirements. This is the substrate the
//! paper's NI must remain compliant with; [`ordering::OrderingMonitor`] is
//! the executable statement of those rules and is attached to every
//! endpoint in the integration tests.

pub mod types;
pub mod ordering;
pub mod idwidth;

pub use types::*;
pub use ordering::OrderingMonitor;
