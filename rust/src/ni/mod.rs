//! AXI4 Network Interface — the paper's key contribution (§III-A).
//!
//! The NI decouples the AXI4 protocol from the NoC link-level protocol so
//! routers never track transaction state:
//!
//! * **end-to-end flow control** — a request enters the network only after
//!   reorder-buffer space for its *response* has been reserved;
//! * **[`rob::RobAllocator`]** — dynamic, arbitrary-burst-length allocation
//!   of response storage (SRAM for R data, SCM for tiny B responses);
//! * **[`reorder::ReorderTable`]** — one FIFO of ROB indices per AXI ID;
//!   a response whose index is at the head of its ID FIFO is *in order*
//!   and bypasses the ROB straight to the AXI interface (this single rule
//!   implements both paper optimizations: the first response of a stream,
//!   and same-destination streams under deterministic routing);
//! * **meta FIFO** (target side) — stores the request's source and ordering
//!   info so responses can be routed back; non-atomic requests are
//!   serialized onto one local ID, atomics get separate meta buffers;
//! * **[`initiator::Initiator`] / [`target::Target`]** — the two halves,
//!   instantiated once per AXI bus (narrow + wide) per tile.

pub mod rob;
pub mod reorder;
pub mod initiator;
pub mod target;

pub use initiator::{Initiator, InitiatorCfg};
pub use reorder::{ReorderTable, RspAction};
pub use rob::RobAllocator;
pub use target::{Target, TargetCfg};
