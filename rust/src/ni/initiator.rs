//! Initiator half of the AXI4 NI (paper Fig. 1).
//!
//! Accepts AXI requests from the attached bus (traffic generator / DMA /
//! compute bridge), enforces **end-to-end flow control** (a request is
//! only accepted once ROB space for its response and a reorder-table slot
//! for its ID are reserved), injects request flits, and terminates
//! response flits — bypassing in-order responses straight to the AXI
//! interface and buffering out-of-order ones in the ROB.

use crate::axi::{AxReq, AxiId, BResp, RBeat, Resp, WBeat};
use crate::flit::{BusKind, FlooFlit, Header, NodeId, Payload};
use crate::util::fifo::Fifo;

use super::reorder::{ReorderTable, RspAction};
use super::rob::RobAllocator;

/// Static configuration of one initiator (one per bus per tile).
#[derive(Debug, Clone)]
pub struct InitiatorCfg {
    /// Which bus this initiator serves.
    pub bus: BusKind,
    /// Distinct AXI IDs at this port (paper: 4-bit ⇒ 16).
    pub num_ids: usize,
    /// Max outstanding transactions per ID (reorder-table FIFO depth).
    pub per_id_depth: usize,
    /// Read-response ROB slots (beats). Paper: 2 kB/8 B = 256 narrow,
    /// 8 kB/64 B = 128 wide.
    pub rob_slots: u32,
    /// Outstanding write slots (B responses live in SCM; one slot each).
    pub wr_slots: u32,
    /// Depth of the AXI-side request/response FIFOs.
    pub port_depth: usize,
}

impl InitiatorCfg {
    /// The paper's narrow (64-bit) initiator sizing.
    pub fn narrow_default() -> Self {
        InitiatorCfg {
            bus: BusKind::Narrow,
            num_ids: 16,
            per_id_depth: 4,
            rob_slots: 256,
            wr_slots: 16,
            port_depth: 4,
        }
    }

    /// The paper's wide (512-bit) initiator sizing.
    pub fn wide_default() -> Self {
        InitiatorCfg {
            bus: BusKind::Wide,
            num_ids: 16,
            per_id_depth: 4,
            rob_slots: 128,
            wr_slots: 16,
            port_depth: 4,
        }
    }
}

/// An in-progress outgoing W-beat stream (one packet on the request link).
#[derive(Debug, Clone, Copy)]
struct WStream {
    req: AxReq,
    dst: NodeId,
    rob_idx: u32,
    next_beat: u32,
}

/// Counters for the experiment harness.
#[derive(Debug, Clone, Default)]
pub struct InitiatorStats {
    /// AR requests accepted.
    pub reads_issued: u64,
    /// AW requests accepted.
    pub writes_issued: u64,
    /// Reads fully returned to the bus.
    pub reads_completed: u64,
    /// Writes whose B reached the bus.
    pub writes_completed: u64,
    /// Cycles a read could not issue (ROB/credit stall).
    pub read_stall_cycles: u64,
    /// Cycles a write could not issue.
    pub write_stall_cycles: u64,
}

/// Initiator-side NI state for one AXI bus.
#[derive(Debug)]
pub struct Initiator {
    /// The sizing this initiator was built with.
    pub cfg: InitiatorCfg,
    /// The tile this initiator belongs to.
    pub node: NodeId,
    // ----- AXI side (generator <-> NI) -----------------------------------
    /// Read requests from the bus.
    pub ar_in: Fifo<AxReq>,
    /// Write requests from the bus (the NI streams the W beats itself;
    /// the tuple's second field is the destination resolved by the caller's
    /// address map — resolution happens at push time).
    pub aw_in: Fifo<(AxReq, NodeId)>,
    /// Same resolved-destination channel for reads.
    pub ar_dst: Fifo<NodeId>,
    /// Read data back to the bus.
    pub r_out: Fifo<RBeat>,
    /// Write responses back to the bus.
    pub b_out: Fifo<BResp>,
    // ----- reorder machinery ---------------------------------------------
    r_table: ReorderTable,
    r_rob: RobAllocator,
    b_table: ReorderTable,
    b_slots: RobAllocator,
    /// Outgoing W-beat stream, if a write burst is mid-flight. While set,
    /// this NI may not inject any other packet on the W link (wormhole).
    w_stream: Option<WStream>,
    /// Round-robin over IDs for ROB drains.
    drain_rr: usize,
    /// Issue/completion/stall counters.
    pub stats: InitiatorStats,
}

impl Initiator {
    /// Build an initiator NI for `node` with the given sizing.
    pub fn new(cfg: InitiatorCfg, node: NodeId) -> Self {
        Initiator {
            node,
            ar_in: Fifo::new(cfg.port_depth),
            aw_in: Fifo::new(cfg.port_depth),
            ar_dst: Fifo::new(cfg.port_depth),
            r_out: Fifo::new(cfg.port_depth),
            b_out: Fifo::new(cfg.port_depth),
            r_table: ReorderTable::new(cfg.num_ids, cfg.per_id_depth),
            r_rob: RobAllocator::new(cfg.rob_slots),
            b_table: ReorderTable::new(cfg.num_ids, cfg.per_id_depth),
            b_slots: RobAllocator::new(cfg.wr_slots),
            w_stream: None,
            drain_rr: 0,
            stats: InitiatorStats::default(),
            cfg,
        }
    }

    /// Convenience for generators: can another read with `id` be queued?
    pub fn ar_ready(&self) -> bool {
        !self.ar_in.is_full()
    }

    /// Convenience for generators: can another write be queued?
    pub fn aw_ready(&self) -> bool {
        !self.aw_in.is_full()
    }

    /// Queue a read request (generator side).
    pub fn push_ar(&mut self, req: AxReq, dst: NodeId) {
        self.ar_in.push(req);
        self.ar_dst.push(dst);
    }

    /// Queue a write request (generator side).
    pub fn push_aw(&mut self, req: AxReq, dst: NodeId) {
        self.aw_in.push((req, dst));
    }

    /// Outstanding transactions currently tracked.
    pub fn outstanding(&self) -> usize {
        self.r_table.outstanding() + self.b_table.outstanding()
    }

    /// Nothing tracked, streaming or queued.
    pub fn is_idle(&self) -> bool {
        self.outstanding() == 0
            && self.w_stream.is_none()
            && self.ar_in.is_empty()
            && self.aw_in.is_empty()
    }

    /// ROB occupancy (read side), for the sizing ablation.
    pub fn rob_occupancy(&self) -> f64 {
        self.r_rob.occupancy()
    }

    /// Peak read-ROB occupancy in slots (sizing ablations).
    pub fn rob_peak_slots(&self) -> u32 {
        self.r_rob.peak_used()
    }

    /// (bypassed, buffered) read-beat counts from the reorder table.
    pub fn reorder_stats(&self) -> (u64, u64) {
        (
            self.r_table.bypassed_beats + self.b_table.bypassed_beats,
            self.r_table.buffered_beats + self.b_table.buffered_beats,
        )
    }

    // ------------------------------------------------------------ injection

    /// True when a W-beat stream is mid-flight (the caller must not let any
    /// other packet onto the same physical link).
    pub fn streaming_w(&self) -> bool {
        self.w_stream.is_some()
    }

    /// True when stepping this initiator's inject/drain phase would be a
    /// provable no-op this cycle **and** no stall counter would tick:
    /// nothing queued to issue, no W stream mid-flight, and nothing
    /// drainable from the reorder tables. One conjunct of the
    /// event-driven fast-forward's skip condition
    /// ([`crate::sim::SimMode::Event`]): the cheaper [`Self::is_idle`]
    /// ignores queued-but-unissued requests, which this must not —
    /// `try_issue` ticks `read_stall_cycles`/`write_stall_cycles` while
    /// a head request waits, so skipping such a cycle would diverge the
    /// stats digest from the gated oracle.
    pub fn inject_quiet(&self) -> bool {
        self.ar_in.is_empty()
            && self.aw_in.is_empty()
            && self.w_stream.is_none()
            && !self.r_table.any_drainable()
            && !self.b_table.any_drainable()
    }

    /// Produce the next W-beat flit of the active stream, if any.
    pub fn next_w_flit(&mut self, now: u64) -> Option<FlooFlit> {
        let s = self.w_stream.as_mut()?;
        let beat = s.next_beat;
        let last = beat + 1 == s.req.beats();
        let flit = FlooFlit::new(
            Header {
                dst: s.dst,
                src: self.node,
                rob_idx: s.rob_idx,
                rob_req: true,
                atomic: s.req.atop,
                last,
            },
            match self.cfg.bus {
                BusKind::Narrow => Payload::NarrowW {
                    id: s.req.id,
                    beat: WBeat { beat, last },
                },
                BusKind::Wide => Payload::WideW {
                    id: s.req.id,
                    beat: WBeat { beat, last },
                },
            },
            now,
        );
        s.next_beat += 1;
        if last {
            self.w_stream = None;
        }
        Some(flit)
    }

    /// Try to issue the next request (AR preferred over AW via a simple
    /// alternation embedded in FIFO order — callers alternate by arrival).
    /// Returns the request flit to inject on the **request link**, or
    /// `None` when nothing can issue this cycle (empty queues or flow
    /// control refusing). Must not be called while `streaming_w()` on the
    /// same physical link the AW would start its W stream on — the caller
    /// (tile NI) enforces link-level wormhole atomicity.
    pub fn try_issue(&mut self, now: u64, w_link_free: bool) -> Option<FlooFlit> {
        // Reads first when both are pending and read flow control passes
        // (matching the RTL's rr between AR/AW; the asymmetry is invisible
        // at the throughput level because queues are short).
        if let Some(req) = self.ar_in.front().copied() {
            let beats = req.beats();
            if self.r_table.can_push(req.id) && self.r_rob.can_alloc(beats) {
                let grant = self.r_rob.alloc(beats).unwrap();
                self.r_table.push(req.id, grant, beats);
                self.ar_in.pop();
                let dst = self.ar_dst.pop().expect("ar/dst queues in lockstep");
                self.stats.reads_issued += 1;
                return Some(FlooFlit::new(
                    Header {
                        dst,
                        src: self.node,
                        rob_idx: grant.base,
                        rob_req: true,
                        atomic: false,
                        last: true,
                    },
                    match self.cfg.bus {
                        BusKind::Narrow => Payload::NarrowAr(req),
                        BusKind::Wide => Payload::WideAr(req),
                    },
                    now,
                ));
            } else {
                self.stats.read_stall_cycles += 1;
            }
        }
        if let Some(&(req, dst)) = self.aw_in.front() {
            // A write needs: a B slot, a B reorder entry, and the W link
            // free to start streaming beats right after the AW.
            if w_link_free
                && self.w_stream.is_none()
                && self.b_table.can_push(req.id)
                && self.b_slots.can_alloc(1)
            {
                let grant = self.b_slots.alloc(1).unwrap();
                self.b_table.push(req.id, grant, 1);
                self.aw_in.pop();
                self.w_stream = Some(WStream {
                    req,
                    dst,
                    rob_idx: grant.base,
                    next_beat: 0,
                });
                self.stats.writes_issued += 1;
                return Some(FlooFlit::new(
                    Header {
                        dst,
                        src: self.node,
                        rob_idx: grant.base,
                        rob_req: true,
                        atomic: req.atop,
                        last: true,
                    },
                    match self.cfg.bus {
                        BusKind::Narrow => Payload::NarrowAw(req),
                        BusKind::Wide => Payload::WideAw(req),
                    },
                    now,
                ));
            } else if !self.aw_in.is_empty() {
                self.stats.write_stall_cycles += 1;
            }
        }
        None
    }

    // ------------------------------------------------------------ responses

    /// Handle an arriving response flit addressed to this initiator.
    /// Returns `false` when the flit could not be consumed this cycle
    /// (AXI-side backpressure) — the caller leaves it in the link buffer.
    pub fn handle_response(&mut self, flit: &FlooFlit) -> bool {
        match flit.payload {
            Payload::NarrowR(beat) | Payload::WideR(beat) => {
                debug_assert_eq!(self.bus_matches_r(&flit.payload), true);
                let (action, _slot) =
                    match self.peek_r_action(beat.id, flit.header.rob_idx) {
                        Some(a) => a,
                        None => return false, // r_out full for a bypass
                    };
                let (action2, _slot2) = self.r_table.on_response_beat(
                    beat.id,
                    flit.header.rob_idx,
                    beat.last,
                );
                debug_assert_eq!(action, action2);
                match action2 {
                    RspAction::Forward => {
                        self.r_out.push(beat);
                        if beat.last {
                            let grant = self.r_table.complete_bypass(beat.id);
                            self.r_rob.release(grant);
                            self.stats.reads_completed += 1;
                        }
                    }
                    RspAction::Buffer => {
                        // Data would be written to ROB SRAM at `slot2`;
                        // the simulator tracks occupancy, not bit patterns.
                    }
                }
                true
            }
            Payload::NarrowB(resp) | Payload::WideB(resp) => {
                let head_ready = !self.b_out.is_full();
                if !head_ready {
                    return false;
                }
                let (action, _) = self.b_table.on_response_beat(
                    resp.id,
                    flit.header.rob_idx,
                    true,
                );
                match action {
                    RspAction::Forward => {
                        self.b_out.push(resp);
                        let grant = self.b_table.complete_bypass(resp.id);
                        self.b_slots.release(grant);
                        self.stats.writes_completed += 1;
                    }
                    RspAction::Buffer => {}
                }
                true
            }
            _ => panic!("request-class flit delivered to initiator"),
        }
    }

    fn bus_matches_r(&self, p: &Payload) -> bool {
        matches!(
            (self.cfg.bus, p),
            (BusKind::Narrow, Payload::NarrowR(_)) | (BusKind::Wide, Payload::WideR(_))
        )
    }

    /// Pre-check a read beat: would it bypass, and if so is there AXI-side
    /// space? (Avoids mutating the table when we must stall.)
    fn peek_r_action(&self, _id: AxiId, rob_idx: u32) -> Option<(RspAction, u32)> {
        // A bypass lands in r_out immediately; a buffered beat does not
        // touch r_out. We conservatively require r_out space only when the
        // beat would bypass. Recompute cheaply: bypass iff head-of-FIFO.
        let would_forward = self.r_table_would_forward(_id, rob_idx);
        if would_forward && self.r_out.is_full() {
            return None;
        }
        Some((
            if would_forward {
                RspAction::Forward
            } else {
                RspAction::Buffer
            },
            rob_idx,
        ))
    }

    fn r_table_would_forward(&self, id: AxiId, rob_idx: u32) -> bool {
        self.r_table.would_forward(id, rob_idx)
    }

    // --------------------------------------------------------------- drains

    /// Forward one buffered-and-now-in-order beat from the ROB to the AXI
    /// interface (one per cycle, round-robin over ready IDs). Called once
    /// per cycle by the tile NI *after* response handling; skipped when a
    /// bypass already used the AXI channel this cycle.
    pub fn drain_cycle(&mut self) {
        // Fast path: nothing buffered anywhere (the common case — most
        // responses take the in-order bypass and never touch the ROB).
        if !self.r_table.any_drainable() && !self.b_table.any_drainable() {
            return;
        }
        // R drains.
        if self.r_table.any_drainable() && !self.r_out.is_full() {
            if let Some(id) = self.r_table.next_drain_ready(self.drain_rr) {
                self.drain_rr = (id as usize + 1) % self.r_table.num_ids();
                if let Some((_slot, last)) = self.r_table.drain_step(id) {
                    // Reconstruct the beat for the AXI side.
                    let beat_no = self.r_table.draining_beats_done(id) - 1;
                    self.r_out.push(RBeat {
                        id,
                        beat: beat_no,
                        last,
                        resp: Resp::Okay,
                    });
                    if last {
                        let grant = self.r_table.complete_drain(id);
                        self.r_rob.release(grant);
                        self.stats.reads_completed += 1;
                    }
                }
            }
        }
        // B drains.
        if self.b_table.any_drainable() && !self.b_out.is_full() {
            if let Some(id) = self.b_table.next_drain_ready(0) {
                if let Some((_slot, last)) = self.b_table.drain_step(id) {
                    debug_assert!(last);
                    self.b_out.push(BResp {
                        id,
                        resp: Resp::Okay,
                    });
                    let grant = self.b_table.complete_drain(id);
                    self.b_slots.release(grant);
                    self.stats.writes_completed += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::axi::Burst;

    fn rd(id: AxiId, len: u8) -> AxReq {
        AxReq {
            id,
            addr: 0x2000,
            len,
            size: 3,
            burst: Burst::Incr,
            atop: false,
        }
    }

    fn init() -> Initiator {
        Initiator::new(InitiatorCfg::narrow_default(), NodeId(0))
    }

    fn rsp_flit(init_node: NodeId, id: AxiId, rob_idx: u32, beat: u32, last: bool) -> FlooFlit {
        FlooFlit::new(
            Header {
                dst: init_node,
                src: NodeId(5),
                rob_idx,
                rob_req: true,
                atomic: false,
                last,
            },
            Payload::NarrowR(RBeat {
                id,
                beat,
                last,
                resp: Resp::Okay,
            }),
            0,
        )
    }

    #[test]
    fn read_issue_allocates_and_injects() {
        let mut i = init();
        i.push_ar(rd(1, 3), NodeId(5));
        let flit = i.try_issue(0, true).unwrap();
        assert!(matches!(flit.payload, Payload::NarrowAr(_)));
        assert_eq!(flit.header.dst, NodeId(5));
        assert_eq!(flit.header.rob_idx, 0);
        assert_eq!(i.outstanding(), 1);
        assert_eq!(i.stats.reads_issued, 1);
    }

    #[test]
    fn in_order_response_bypasses_to_axi() {
        let mut i = init();
        i.push_ar(rd(1, 1), NodeId(5));
        let f = i.try_issue(0, true).unwrap();
        let idx = f.header.rob_idx;
        assert!(i.handle_response(&rsp_flit(NodeId(0), 1, idx, 0, false)));
        assert!(i.handle_response(&rsp_flit(NodeId(0), 1, idx, 1, true)));
        assert_eq!(i.r_out.len(), 2);
        assert_eq!(i.stats.reads_completed, 1);
        assert!(i.is_idle());
        let (bypassed, buffered) = i.reorder_stats();
        assert_eq!((bypassed, buffered), (2, 0));
    }

    #[test]
    fn out_of_order_buffered_then_drained() {
        let mut i = init();
        i.push_ar(rd(1, 0), NodeId(5)); // txn A -> rob 0
        i.push_ar(rd(1, 0), NodeId(6)); // txn B -> rob 1
        let fa = i.try_issue(0, true).unwrap();
        let fb = i.try_issue(0, true).unwrap();
        // B's response first: buffered, nothing on AXI yet.
        assert!(i.handle_response(&rsp_flit(NodeId(0), 1, fb.header.rob_idx, 0, true)));
        assert_eq!(i.r_out.len(), 0);
        // A's response: bypass.
        assert!(i.handle_response(&rsp_flit(NodeId(0), 1, fa.header.rob_idx, 0, true)));
        assert_eq!(i.r_out.len(), 1);
        // Drain brings B out next cycle.
        i.drain_cycle();
        assert_eq!(i.r_out.len(), 2);
        assert_eq!(i.stats.reads_completed, 2);
        assert!(i.is_idle());
    }

    #[test]
    fn flow_control_refuses_beyond_rob() {
        let mut cfg = InitiatorCfg::narrow_default();
        cfg.rob_slots = 4;
        let mut i = Initiator::new(cfg, NodeId(0));
        i.push_ar(rd(1, 3), NodeId(5)); // 4 beats: fills the ROB
        i.push_ar(rd(2, 0), NodeId(5));
        assert!(i.try_issue(0, true).is_some());
        // Second read cannot issue: no ROB space.
        assert!(i.try_issue(1, true).is_none());
        assert!(i.stats.read_stall_cycles > 0);
    }

    #[test]
    fn per_id_depth_limits_outstanding() {
        let mut cfg = InitiatorCfg::narrow_default();
        cfg.per_id_depth = 2;
        let mut i = Initiator::new(cfg, NodeId(0));
        for _ in 0..3 {
            i.push_ar(rd(7, 0), NodeId(5));
        }
        assert!(i.try_issue(0, true).is_some());
        assert!(i.try_issue(1, true).is_some());
        assert!(i.try_issue(2, true).is_none(), "depth=2 per ID");
    }

    #[test]
    fn write_streams_aw_then_w_beats() {
        let mut i = init();
        let mut w = rd(3, 1); // 2 beats
        w.addr = 0x3000;
        i.push_aw(w, NodeId(4));
        let aw = i.try_issue(0, true).unwrap();
        assert!(matches!(aw.payload, Payload::NarrowAw(_)));
        assert!(i.streaming_w());
        let w0 = i.next_w_flit(1).unwrap();
        assert!(matches!(
            w0.payload,
            Payload::NarrowW { beat: WBeat { beat: 0, last: false }, .. }
        ));
        assert!(!w0.header.last);
        let w1 = i.next_w_flit(2).unwrap();
        assert!(w1.header.last);
        assert!(!i.streaming_w());
        // B response completes the write.
        let b = FlooFlit::new(
            Header {
                dst: NodeId(0),
                src: NodeId(4),
                rob_idx: aw.header.rob_idx,
                rob_req: true,
                atomic: false,
                last: true,
            },
            Payload::NarrowB(BResp {
                id: 3,
                resp: Resp::Okay,
            }),
            3,
        );
        assert!(i.handle_response(&b));
        assert_eq!(i.b_out.len(), 1);
        assert_eq!(i.stats.writes_completed, 1);
        assert!(i.is_idle());
    }

    #[test]
    fn aw_blocked_while_w_link_busy() {
        let mut i = init();
        i.push_aw(rd(1, 0), NodeId(4));
        assert!(i.try_issue(0, false).is_none(), "W link busy: AW must wait");
        assert!(i.try_issue(0, true).is_some());
    }

    #[test]
    fn response_backpressure_stalls_flit() {
        let mut i = init();
        // Fill r_out completely.
        i.push_ar(rd(1, 3), NodeId(5));
        let f = i.try_issue(0, true).unwrap();
        for beat in 0..4u32 {
            let fl = rsp_flit(NodeId(0), 1, f.header.rob_idx, beat, beat == 3);
            if beat < 4 {
                // port_depth = 4: all four fit.
                assert!(i.handle_response(&fl));
            }
        }
        // Next transaction's response cannot bypass into a full r_out.
        i.push_ar(rd(1, 0), NodeId(5));
        let f2 = i.try_issue(1, true).unwrap();
        let fl = rsp_flit(NodeId(0), 1, f2.header.rob_idx, 0, true);
        assert!(!i.handle_response(&fl), "must stall, r_out full");
        // Generator consumes; retry succeeds.
        i.r_out.pop();
        assert!(i.handle_response(&fl));
    }
}
