//! Reorder table: per-AXI-ID FIFOs of ROB grants.
//!
//! Paper §III-A: "The reorder table, which is used for the ROB management,
//! consists of a FIFO for each AXI4 ID that can hold a configurable number
//! of indexes into the ROB (the depth corresponds to the number of
//! outstanding transactions for each ID)."
//!
//! The in-order test is the paper's "unique identifier" mechanism: each
//! response echoes the `rob_idx` of its request; if that index equals the
//! head of its ID's FIFO **and** the head is not already draining buffered
//! data, the response is in order and is forwarded directly to the AXI
//! interface (bypassing ROB storage). This one rule subsumes both paper
//! optimizations (first-of-stream, and same-destination streams under
//! deterministic routing).

use crate::axi::AxiId;
use crate::util::fifo::Fifo;

use super::rob::RobGrant;

/// State of one outstanding transaction in its ID FIFO.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EntryState {
    /// Waiting for its response; no beats arrived yet.
    Pending,
    /// Response is arriving in order and streaming straight to AXI.
    Bypassing { beats_done: u32 },
    /// Response arrived out of order; beats accumulate in the ROB.
    Buffering { beats_done: u32 },
    /// Fully buffered in the ROB, waiting to reach the FIFO head.
    Complete,
    /// At the head and draining buffered beats to AXI, one per cycle.
    Draining { beats_done: u32 },
}

/// One outstanding transaction tracked by the reorder table.
#[derive(Debug, Clone, Copy)]
pub struct Entry {
    /// ROB slots reserved for the response.
    pub grant: RobGrant,
    /// Response beats expected.
    pub beats: u32,
    /// Progress of the response.
    pub state: EntryState,
}

/// What the NI should do with an arriving response beat.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RspAction {
    /// Forward to the AXI interface this cycle (in-order bypass).
    Forward,
    /// Write into ROB storage at the grant's slots; drain later.
    Buffer,
}

/// Per-ID reorder bookkeeping for one response channel (R or B) of one bus.
#[derive(Debug)]
pub struct ReorderTable {
    /// FIFO per AXI ID; index = ID value.
    fifos: Vec<Fifo<Entry>>,
    /// Total outstanding entries across all IDs (O(1) idle check).
    count: usize,
    /// Entries currently in `Complete`/`Draining` state (O(1) guard for
    /// the drain scheduler — most responses bypass, so this is usually 0).
    drainable: usize,
    /// Beats forwarded straight to AXI (in-order fast path).
    pub bypassed_beats: u64,
    /// Beats written into ROB storage.
    pub buffered_beats: u64,
    /// Beats later drained from the ROB to AXI.
    pub drained_beats: u64,
}

impl ReorderTable {
    /// `num_ids` distinct AXI IDs, each with `depth` outstanding txns max.
    pub fn new(num_ids: usize, depth: usize) -> Self {
        ReorderTable {
            fifos: (0..num_ids).map(|_| Fifo::new(depth)).collect(),
            count: 0,
            drainable: 0,
            bypassed_beats: 0,
            buffered_beats: 0,
            drained_beats: 0,
        }
    }

    /// Number of AXI IDs the table tracks.
    pub fn num_ids(&self) -> usize {
        self.fifos.len()
    }

    /// Can a new transaction with `id` be tracked? (FIFO depth = max
    /// outstanding per ID; part of end-to-end flow control.)
    pub fn can_push(&self, id: AxiId) -> bool {
        !self.fifos[id as usize].is_full()
    }

    /// Register a new outstanding transaction (called at request injection,
    /// after the ROB grant succeeded).
    pub fn push(&mut self, id: AxiId, grant: RobGrant, beats: u32) {
        self.fifos[id as usize].push(Entry {
            grant,
            beats,
            state: EntryState::Pending,
        });
        self.count += 1;
    }

    /// Total outstanding transactions across all IDs (O(1)).
    pub fn outstanding(&self) -> usize {
        self.count
    }

    /// Pure query: would a response beat for (`id`, `rob_idx`) bypass to
    /// the AXI interface right now? Mirrors the decision logic of
    /// [`Self::on_response_beat`] without mutating (used for AXI-side
    /// backpressure checks).
    pub fn would_forward(&self, id: AxiId, rob_idx: u32) -> bool {
        let fifo = &self.fifos[id as usize];
        let Some(head) = fifo.front() else { return false };
        // A beat may only bypass if it is the head's AND the head has no
        // beats parked in the ROB (Pending/Bypassing): once any beat of a
        // burst was buffered, later beats must buffer too, or they would
        // overtake their own burst (same-ID beat-order violation).
        head.grant.base == rob_idx
            && matches!(
                head.state,
                EntryState::Pending | EntryState::Bypassing { .. }
            )
    }

    /// Beats already drained for `id`'s head entry (0 when not draining).
    pub fn draining_beats_done(&self, id: AxiId) -> u32 {
        match self.fifos[id as usize].front().map(|e| e.state) {
            Some(EntryState::Draining { beats_done }) => beats_done,
            _ => 0,
        }
    }

    /// A response beat arrived for `id` with echoed `rob_idx`. Decide
    /// bypass vs buffer and update entry state. Returns the action plus the
    /// absolute ROB slot for `Buffer` actions.
    ///
    /// `is_last` marks the final beat of the response burst.
    pub fn on_response_beat(&mut self, id: AxiId, rob_idx: u32, is_last: bool) -> (RspAction, u32) {
        let fifo = &mut self.fifos[id as usize];
        // Locate the entry by its grant base. Hardware addresses the table
        // by rob_idx directly; the FIFO scan here is over ≤depth entries.
        let do_bypass = fifo
            .front()
            .map(|e| {
                e.grant.base == rob_idx
                    && matches!(
                        e.state,
                        EntryState::Pending | EntryState::Bypassing { .. }
                    )
            })
            .unwrap_or(false);
        let e = fifo
            .iter_mut()
            .find(|e| e.grant.base == rob_idx)
            .expect("response for unknown rob_idx (protocol violation)");
        let beat_no = match e.state {
            EntryState::Pending => 0,
            EntryState::Bypassing { beats_done } | EntryState::Buffering { beats_done } => {
                beats_done
            }
            ref s => panic!("beat for entry in state {s:?}"),
        };
        debug_assert!(beat_no < e.beats);
        debug_assert_eq!(
            is_last,
            beat_no + 1 == e.beats,
            "last flag must match beat count"
        );
        if do_bypass {
            e.state = EntryState::Bypassing {
                beats_done: beat_no + 1,
            };
            self.bypassed_beats += 1;
            (RspAction::Forward, rob_idx)
        } else {
            let slot = e.grant.base + beat_no;
            if beat_no + 1 == e.beats {
                e.state = EntryState::Complete;
                self.drainable += 1;
            } else {
                e.state = EntryState::Buffering {
                    beats_done: beat_no + 1,
                };
            }
            self.buffered_beats += 1;
            (RspAction::Buffer, slot)
        }
    }

    /// A bypassing head entry finished (its last beat was forwarded).
    /// Pops it and returns its grant for ROB release.
    pub fn complete_bypass(&mut self, id: AxiId) -> RobGrant {
        let fifo = &mut self.fifos[id as usize];
        let head = fifo.front().expect("bypass completion without head");
        match head.state {
            EntryState::Bypassing { beats_done } if beats_done == head.beats => {}
            ref s => panic!("complete_bypass in state {s:?}"),
        }
        self.count -= 1;
        fifo.pop().unwrap().grant
    }

    /// If the head of `id`'s FIFO is `Complete` (fully buffered), start or
    /// continue draining: returns the ROB slot to read this cycle and
    /// whether this is the final beat. The caller forwards one beat per
    /// cycle to the AXI interface. Returns `None` when nothing to drain.
    pub fn drain_step(&mut self, id: AxiId) -> Option<(u32, bool)> {
        let fifo = &mut self.fifos[id as usize];
        let head = fifo.front_mut()?;
        let beats_done = match head.state {
            EntryState::Complete => 0,
            EntryState::Draining { beats_done } => beats_done,
            _ => return None,
        };
        let slot = head.grant.base + beats_done;
        let last = beats_done + 1 == head.beats;
        head.state = EntryState::Draining {
            beats_done: beats_done + 1,
        };
        self.drained_beats += 1;
        Some((slot, last))
    }

    /// Pop a fully drained head, returning its grant for ROB release.
    pub fn complete_drain(&mut self, id: AxiId) -> RobGrant {
        let fifo = &mut self.fifos[id as usize];
        let head = fifo.front().expect("drain completion without head");
        match head.state {
            EntryState::Draining { beats_done } if beats_done == head.beats => {}
            ref s => panic!("complete_drain in state {s:?}"),
        }
        self.count -= 1;
        self.drainable -= 1;
        fifo.pop().unwrap().grant
    }

    /// Allocation-free scheduler query: the first drain-ready ID at or
    /// after `start` (wrapping), for round-robin drain selection.
    pub fn next_drain_ready(&self, start: usize) -> Option<AxiId> {
        let n = self.fifos.len();
        for off in 0..n {
            let id = (start + off) % n;
            if matches!(
                self.fifos[id].front().map(|e| e.state),
                Some(EntryState::Complete) | Some(EntryState::Draining { .. })
            ) {
                return Some(id as AxiId);
            }
        }
        None
    }

    /// True when any entry exists at all (O(1)).
    pub fn any_outstanding(&self) -> bool {
        self.count > 0
    }

    /// True when some entry is fully buffered and awaiting drain (O(1)).
    pub fn any_drainable(&self) -> bool {
        self.drainable > 0
    }

    /// IDs whose head is complete and ready to drain (for the NI scheduler).
    pub fn drain_ready_ids(&self) -> Vec<AxiId> {
        self.fifos
            .iter()
            .enumerate()
            .filter(|(_, f)| {
                matches!(
                    f.front().map(|e| e.state),
                    Some(EntryState::Complete) | Some(EntryState::Draining { .. })
                )
            })
            .map(|(i, _)| i as AxiId)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> ReorderTable {
        ReorderTable::new(4, 4)
    }

    fn grant(base: u32, len: u32) -> RobGrant {
        RobGrant { base, len }
    }

    #[test]
    fn in_order_single_bypasses() {
        let mut t = table();
        t.push(1, grant(0, 1), 1);
        let (a, slot) = t.on_response_beat(1, 0, true);
        assert_eq!(a, RspAction::Forward);
        assert_eq!(slot, 0);
        let g = t.complete_bypass(1);
        assert_eq!(g, grant(0, 1));
        assert_eq!(t.bypassed_beats, 1);
        assert_eq!(t.outstanding(), 0);
    }

    #[test]
    fn out_of_order_buffers_then_drains() {
        let mut t = table();
        t.push(1, grant(0, 2), 2); // txn A, 2 beats
        t.push(1, grant(2, 1), 1); // txn B, 1 beat
        // B's response arrives first -> must buffer at its slot.
        let (a, slot) = t.on_response_beat(1, 2, true);
        assert_eq!(a, RspAction::Buffer);
        assert_eq!(slot, 2);
        // A arrives -> head -> bypasses.
        assert_eq!(t.on_response_beat(1, 0, false).0, RspAction::Forward);
        assert_eq!(t.on_response_beat(1, 0, true).0, RspAction::Forward);
        let ga = t.complete_bypass(1);
        assert_eq!(ga, grant(0, 2));
        // Now B (complete in ROB) drains.
        assert_eq!(t.drain_step(1), Some((2, true)));
        let gb = t.complete_drain(1);
        assert_eq!(gb, grant(2, 1));
        assert_eq!(t.outstanding(), 0);
        assert_eq!(t.buffered_beats, 1);
        assert_eq!(t.drained_beats, 1);
    }

    #[test]
    fn different_ids_independent() {
        let mut t = table();
        t.push(0, grant(0, 1), 1);
        t.push(1, grant(1, 1), 1);
        // ID 1 responds first; still head of its own FIFO -> bypass.
        assert_eq!(t.on_response_beat(1, 1, true).0, RspAction::Forward);
        assert_eq!(t.on_response_beat(0, 0, true).0, RspAction::Forward);
    }

    #[test]
    fn depth_limit_flow_control() {
        let mut t = ReorderTable::new(2, 2);
        assert!(t.can_push(0));
        t.push(0, grant(0, 1), 1);
        t.push(0, grant(1, 1), 1);
        assert!(!t.can_push(0));
        assert!(t.can_push(1), "other IDs unaffected");
    }

    #[test]
    fn head_draining_blocks_bypass() {
        let mut t = table();
        t.push(1, grant(0, 1), 1); // A
        t.push(1, grant(1, 2), 2); // B
        t.push(1, grant(3, 1), 1); // C
        // B arrives out of order (buffered, complete).
        t.on_response_beat(1, 1, false);
        t.on_response_beat(1, 1, true);
        // A arrives, bypasses, pops.
        t.on_response_beat(1, 0, true);
        t.complete_bypass(1);
        // B is head & complete -> drain begins.
        assert_eq!(t.drain_step(1), Some((1, false)));
        // C's response arrives while B drains: C is not head -> buffer.
        let (a, slot) = t.on_response_beat(1, 3, true);
        assert_eq!(a, RspAction::Buffer);
        assert_eq!(slot, 3);
        // Finish draining B.
        assert_eq!(t.drain_step(1), Some((2, true)));
        t.complete_drain(1);
        // C drains next.
        assert_eq!(t.drain_step(1), Some((3, true)));
        t.complete_drain(1);
    }

    #[test]
    fn drain_ready_ids_reports() {
        let mut t = table();
        t.push(2, grant(0, 1), 1);
        t.push(2, grant(1, 1), 1);
        t.on_response_beat(2, 1, true); // second txn buffered
        assert!(t.drain_ready_ids().is_empty(), "head still pending");
        t.on_response_beat(2, 0, true); // head bypasses
        t.complete_bypass(2);
        assert_eq!(t.drain_ready_ids(), vec![2]);
    }

    #[test]
    #[should_panic(expected = "unknown rob_idx")]
    fn unknown_response_panics() {
        let mut t = table();
        t.on_response_beat(0, 5, true);
    }
}
