//! Dynamic reorder-buffer allocator.
//!
//! The paper (§III-A): "The ROB allocation is dynamic and supports bursts
//! of arbitrary lengths. Once a new outgoing AXI4 request arrives, the next
//! available ROB space is checked, which can hold the size of the
//! corresponding response."
//!
//! Storage is managed at *slot* granularity (one slot = one response beat:
//! 8 B narrow, 64 B wide). Grants are contiguous runs of slots — the
//! response beat `i` of a burst lands at `base + i`, so the echoed
//! `rob_idx` plus the beat number addresses storage directly, exactly like
//! the SRAM in hardware. A first-fit free-extent allocator models the
//! dynamic allocation; extents merge on free.

/// A granted extent of ROB slots.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RobGrant {
    /// First slot (this is the `rob_idx` sent in the flit header).
    pub base: u32,
    /// Number of slots (response beats).
    pub len: u32,
}

/// First-fit extent allocator over `slots` ROB slots.
#[derive(Debug, Clone)]
pub struct RobAllocator {
    slots: u32,
    /// Sorted, disjoint, non-adjacent free extents (base, len).
    free: Vec<(u32, u32)>,
    /// Currently allocated slot count (for occupancy stats).
    used: u32,
    /// High-water mark of `used`.
    peak_used: u32,
    /// Successful allocations (flow-control visibility).
    pub grants: u64,
    /// Refused allocations (requests issued later instead).
    pub refusals: u64,
}

impl RobAllocator {
    /// An allocator over `slots` response-beat slots.
    pub fn new(slots: u32) -> Self {
        assert!(slots > 0, "a ROB needs at least one slot");
        RobAllocator {
            slots,
            free: vec![(0, slots)],
            used: 0,
            peak_used: 0,
            grants: 0,
            refusals: 0,
        }
    }

    /// Construct from a byte budget and per-beat granule (paper: 8 kB / 64 B
    /// for the wide bus, 2 kB / 8 B for the narrow bus). A budget that is
    /// not a granule multiple rounds **up** — the partial slot is bought,
    /// never silently dropped (a sub-granule budget used to truncate to
    /// zero slots and trip the bare capacity assert).
    pub fn from_bytes(bytes: u32, granule: u32) -> Self {
        assert!(
            granule > 0 && bytes > 0,
            "ROB byte budget and granule must be non-zero (bytes = {bytes}, granule = {granule})"
        );
        let slots = (bytes as u64 + granule as u64 - 1) / granule as u64;
        RobAllocator::new(slots as u32)
    }

    /// Capacity in slots.
    pub fn total_slots(&self) -> u32 {
        self.slots
    }

    /// Currently allocated slots.
    pub fn used_slots(&self) -> u32 {
        self.used
    }

    /// Currently free slots.
    pub fn free_slots(&self) -> u32 {
        self.slots - self.used
    }

    /// High-water mark of `used_slots`.
    pub fn peak_used(&self) -> u32 {
        self.peak_used
    }

    /// Would an allocation of `len` slots succeed right now?
    pub fn can_alloc(&self, len: u32) -> bool {
        self.free.iter().any(|&(_, l)| l >= len)
    }

    /// First-fit allocation of a contiguous run of `len` slots.
    pub fn alloc(&mut self, len: u32) -> Option<RobGrant> {
        assert!(len > 0, "zero-length ROB grant");
        for i in 0..self.free.len() {
            let (base, flen) = self.free[i];
            if flen >= len {
                if flen == len {
                    self.free.remove(i);
                } else {
                    self.free[i] = (base + len, flen - len);
                }
                self.used += len;
                self.peak_used = self.peak_used.max(self.used);
                self.grants += 1;
                return Some(RobGrant { base, len });
            }
        }
        self.refusals += 1;
        None
    }

    /// Release a previously granted extent, merging adjacent free extents.
    pub fn release(&mut self, grant: RobGrant) {
        assert!(grant.base + grant.len <= self.slots, "grant out of range");
        // Find insertion point keeping `free` sorted by base.
        let pos = self
            .free
            .partition_point(|&(b, _)| b < grant.base);
        // Sanity: no overlap with neighbours (double-free detection).
        if pos > 0 {
            let (pb, pl) = self.free[pos - 1];
            assert!(pb + pl <= grant.base, "double free / overlap below");
        }
        if pos < self.free.len() {
            let (nb, _) = self.free[pos];
            assert!(grant.base + grant.len <= nb, "double free / overlap above");
        }
        self.free.insert(pos, (grant.base, grant.len));
        self.used -= grant.len;
        // Merge with next.
        if pos + 1 < self.free.len() {
            let (b, l) = self.free[pos];
            let (nb, nl) = self.free[pos + 1];
            if b + l == nb {
                self.free[pos] = (b, l + nl);
                self.free.remove(pos + 1);
            }
        }
        // Merge with previous.
        if pos > 0 {
            let (pb, pl) = self.free[pos - 1];
            let (b, l) = self.free[pos];
            if pb + pl == b {
                self.free[pos - 1] = (pb, pl + l);
                self.free.remove(pos);
            }
        }
    }

    /// Occupancy in [0, 1].
    pub fn occupancy(&self) -> f64 {
        self.used as f64 / self.slots as f64
    }

    /// Internal invariant check (used by property tests): free extents are
    /// sorted, disjoint, non-adjacent, and account for `slots - used`.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut total = 0u32;
        let mut prev_end: Option<u32> = None;
        for &(b, l) in &self.free {
            if l == 0 {
                return Err("zero-length free extent".into());
            }
            if let Some(pe) = prev_end {
                if b < pe {
                    return Err(format!("overlapping extents at {b}"));
                }
                if b == pe {
                    return Err(format!("unmerged adjacent extents at {b}"));
                }
            }
            if b + l > self.slots {
                return Err("extent out of range".into());
            }
            prev_end = Some(b + l);
            total += l;
        }
        if total != self.slots - self.used {
            return Err(format!(
                "free accounting mismatch: extents {total}, expected {}",
                self.slots - self.used
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::{prop_assert, prop_assert_eq};

    #[test]
    fn alloc_and_release_roundtrip() {
        let mut rob = RobAllocator::new(16);
        let g1 = rob.alloc(4).unwrap();
        let g2 = rob.alloc(8).unwrap();
        assert_eq!(g1, RobGrant { base: 0, len: 4 });
        assert_eq!(g2, RobGrant { base: 4, len: 8 });
        assert_eq!(rob.free_slots(), 4);
        rob.release(g1);
        rob.release(g2);
        assert_eq!(rob.free_slots(), 16);
        rob.check_invariants().unwrap();
        // Full-range allocation possible again (merge happened).
        assert!(rob.alloc(16).is_some());
    }

    #[test]
    fn refuses_when_fragmented() {
        let mut rob = RobAllocator::new(8);
        let a = rob.alloc(2).unwrap();
        let b = rob.alloc(2).unwrap();
        let c = rob.alloc(2).unwrap();
        let _d = rob.alloc(2).unwrap();
        rob.release(a);
        rob.release(c);
        // 4 slots free but no contiguous run of 3.
        assert_eq!(rob.free_slots(), 4);
        assert!(!rob.can_alloc(3));
        assert!(rob.alloc(3).is_none());
        assert_eq!(rob.refusals, 1);
        rob.release(b);
        // a+b+c merged: 6 contiguous.
        assert!(rob.can_alloc(6));
        rob.check_invariants().unwrap();
    }

    #[test]
    fn arbitrary_burst_lengths() {
        // Paper: "supports bursts of arbitrary lengths" — e.g. a full 4 kB
        // burst (64 wide beats) out of the 128-slot wide ROB twice.
        let mut rob = RobAllocator::from_bytes(8 * 1024, 64);
        assert_eq!(rob.total_slots(), 128);
        let g1 = rob.alloc(64).unwrap();
        let g2 = rob.alloc(64).unwrap();
        assert!(rob.alloc(1).is_none(), "full");
        rob.release(g1);
        rob.release(g2);
        assert_eq!(rob.free_slots(), 128);
    }

    #[test]
    fn out_of_order_release() {
        let mut rob = RobAllocator::new(32);
        let grants: Vec<_> = (0..8).map(|_| rob.alloc(4).unwrap()).collect();
        // Release even-indexed grants first, then odd.
        for g in grants.iter().step_by(2) {
            rob.release(*g);
        }
        rob.check_invariants().unwrap();
        for g in grants.iter().skip(1).step_by(2) {
            rob.release(*g);
        }
        rob.check_invariants().unwrap();
        assert_eq!(rob.free_slots(), 32);
        assert_eq!(rob.free.len(), 1, "fully merged");
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_detected() {
        let mut rob = RobAllocator::new(8);
        let g = rob.alloc(4).unwrap();
        rob.release(g);
        rob.release(g);
    }

    #[test]
    fn peak_tracking() {
        let mut rob = RobAllocator::new(16);
        let g = rob.alloc(10).unwrap();
        rob.release(g);
        rob.alloc(2).unwrap();
        assert_eq!(rob.peak_used(), 10);
        assert_eq!(rob.used_slots(), 2);
    }

    #[test]
    fn from_bytes_rounds_up_partial_granules() {
        // 100 B at 64 B/beat is 1.5625 granules: the partial slot is
        // bought (2 slots), not truncated to 1.
        assert_eq!(RobAllocator::from_bytes(100, 64).total_slots(), 2);
        // A sub-granule budget still yields a usable 1-slot ROB instead
        // of truncating to zero and panicking on the capacity assert.
        assert_eq!(RobAllocator::from_bytes(8, 64).total_slots(), 1);
        // Exact multiples are unchanged.
        assert_eq!(RobAllocator::from_bytes(8 * 1024, 64).total_slots(), 128);
    }

    #[test]
    #[should_panic(expected = "bytes = 0, granule = 64")]
    fn from_bytes_zero_budget_names_both_values() {
        let _ = RobAllocator::from_bytes(0, 64);
    }

    #[test]
    #[should_panic(expected = "bytes = 512, granule = 0")]
    fn from_bytes_zero_granule_names_both_values() {
        let _ = RobAllocator::from_bytes(512, 0);
    }

    /// Seeded random alloc/release sweep: drive the allocator through
    /// long interleaved sequences of arbitrary-length allocations and
    /// out-of-order releases, checking [`RobAllocator::check_invariants`]
    /// (sorted/disjoint/non-adjacent free list, exact accounting) after
    /// every mutation, plus first-fit determinism of `can_alloc`.
    #[test]
    fn random_alloc_release_keeps_invariants() {
        crate::util::prop::check_default("rob-alloc-release", |rng| {
            let slots = 1 + rng.below(96) as u32;
            let mut rob = RobAllocator::new(slots);
            let mut live: Vec<RobGrant> = Vec::new();
            for _ in 0..128 {
                if rng.chance(0.55) {
                    let len = 1 + rng.below(16) as u32;
                    let could = rob.can_alloc(len);
                    match rob.alloc(len) {
                        Some(g) => {
                            prop_assert!(could, "alloc({len}) succeeded but can_alloc said no");
                            prop_assert!(
                                g.base + g.len <= slots,
                                "grant {g:?} beyond capacity {slots}"
                            );
                            live.push(g);
                        }
                        None => {
                            prop_assert!(!could, "can_alloc({len}) true but alloc refused");
                        }
                    }
                } else if !live.is_empty() {
                    let i = rng.below(live.len() as u64) as usize;
                    rob.release(live.swap_remove(i));
                }
                if let Err(msg) = rob.check_invariants() {
                    return Err(format!("slots {slots}: {msg}"));
                }
            }
            let held: u32 = live.iter().map(|g| g.len).sum();
            prop_assert_eq!(rob.used_slots(), held);
            for g in live.drain(..) {
                rob.release(g);
            }
            if let Err(msg) = rob.check_invariants() {
                return Err(format!("after full drain: {msg}"));
            }
            prop_assert_eq!(rob.free_slots(), slots);
            Ok(())
        });
    }
}
