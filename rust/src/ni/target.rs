//! Target half of the AXI4 NI.
//!
//! Terminates request flits at a node: pairs AW headers (narrow_req link)
//! with their W-beat streams (wide link for the wide bus, same link for
//! the narrow bus), forwards operations to the local memory, and turns
//! memory responses back into response flits addressed to the request's
//! source.
//!
//! The paper's **meta FIFO** is the per-operation `(src, rob_idx, rob_req)`
//! record that travels with each memory op: "the source ID of the request
//! is stored in the meta FIFO, together with the information required for
//! ordering the response. The order of all incoming non-atomic responses
//! is preserved by serializing them with an identical AXI4 ID" — our
//! in-order [`MemModel`] plays that serialized role, and atomics go
//! through a separate bounded meta buffer exactly as described.

use std::collections::HashMap;
use std::collections::VecDeque;

use crate::axi::AxReq;
use crate::flit::{BusKind, FlooFlit, Header, NodeId, Payload};
use crate::mem::{MemModel, MemRsp};

/// Target-side configuration (per node).
#[derive(Debug, Clone)]
pub struct TargetCfg {
    /// Latency of the local memory (SPM ≈ 5, memory controller ≈ 30+).
    pub mem_latency: u64,
    /// Max in-flight ops per memory port.
    pub mem_outstanding: usize,
    /// Pending (unmatched) AW / completed-W-burst queue bound per source.
    pub pending_writes: usize,
    /// Separate meta buffer depth for atomics.
    pub atomic_slots: usize,
}

impl TargetCfg {
    /// Tile SPM. `mem_latency = 7` is the zero-load calibration constant:
    /// the paper's §VI-A attributes 9 cycles of the 18-cycle round trip to
    /// "cluster-internal cuts and memory access latency"; we fold the two
    /// cluster-interconnect cut registers into the SPM access constant
    /// (5-cycle banked SPM + 2 cut cycles), giving exactly the published
    /// 18-cycle tile-to-adjacent-tile round trip.
    pub fn spm_default() -> Self {
        TargetCfg {
            mem_latency: 7,
            mem_outstanding: 16,
            pending_writes: 4,
            atomic_slots: 4,
        }
    }

    /// The boundary memory-controller timing (DRAM-ish latency).
    pub fn mem_ctrl_default() -> Self {
        TargetCfg {
            mem_latency: 30,
            mem_outstanding: 32,
            pending_writes: 8,
            atomic_slots: 4,
        }
    }
}

/// An AW waiting for its W burst (or vice versa).
#[derive(Debug, Clone, Copy)]
struct PendingAw {
    req: AxReq,
    src: NodeId,
    rob_idx: u32,
    rob_req: bool,
    atomic: bool,
}

/// Write-reassembly state per (source, bus).
#[derive(Debug, Default)]
struct WriteAssembly {
    /// AWs in arrival order, not yet matched to a complete W burst.
    aws: VecDeque<PendingAw>,
    /// Beat count of the W burst currently streaming in.
    cur_beats: u32,
    /// Completed W bursts (beat counts) not yet matched to an AW.
    done_bursts: VecDeque<u32>,
}

/// Counters.
#[derive(Debug, Clone, Default)]
pub struct TargetStats {
    /// Read bursts fully served.
    pub reads_served: u64,
    /// Write bursts fully served.
    pub writes_served: u64,
    /// Atomic transactions served.
    pub atomics_served: u64,
    /// Cycles a request flit stalled at the eject port.
    pub req_stall_cycles: u64,
}

/// Target-side NI state for one node (tile or memory controller).
#[derive(Debug)]
pub struct Target {
    /// The timing/sizing this target was built with.
    pub cfg: TargetCfg,
    /// The node this target serves.
    pub node: NodeId,
    /// 64-bit port memory.
    pub narrow_mem: MemModel,
    /// 512-bit port memory.
    pub wide_mem: MemModel,
    assembly: HashMap<(u16, BusKind), WriteAssembly>,
    /// Retirement cycle of the latest memory accept this cycle, not yet
    /// drained into the system's event calendar. A single slot suffices:
    /// both memory ports share `cfg.mem_latency`, so every accept in one
    /// cycle reports the same `now + latency`.
    newly_scheduled: Option<u64>,
    /// Atomics meta buffer (separate, as in the paper). Counts in-flight
    /// atomic ops; bounded.
    atomics_inflight: usize,
    /// Round-robin between narrow-mem and wide-mem for narrow_rsp
    /// injection (wide B competes with narrow R/B there).
    rsp_rr: bool,
    /// Service counters.
    pub stats: TargetStats,
}

impl Target {
    /// Build a target NI for `node`.
    pub fn new(cfg: TargetCfg, node: NodeId) -> Self {
        Target {
            narrow_mem: MemModel::new(cfg.mem_latency, cfg.mem_outstanding),
            wide_mem: MemModel::new(cfg.mem_latency, cfg.mem_outstanding),
            assembly: HashMap::new(),
            newly_scheduled: None,
            atomics_inflight: 0,
            rsp_rr: false,
            stats: TargetStats::default(),
            node,
            cfg,
        }
    }

    /// No memory op, assembly or atomic in flight.
    pub fn is_idle(&self) -> bool {
        self.narrow_mem.is_idle()
            && self.wide_mem.is_idle()
            && self.atomics_inflight == 0
            && self
                .assembly
                .values()
                .all(|a| a.aws.is_empty() && a.done_bursts.is_empty() && a.cur_beats == 0)
    }

    /// Handle a request-class flit. Returns `false` when it cannot be
    /// consumed this cycle (memory/assembly backpressure): the caller
    /// leaves it in the link buffer, modelling ready deassertion.
    pub fn handle_request(&mut self, flit: &FlooFlit, now: u64) -> bool {
        let h = flit.header;
        match flit.payload {
            Payload::NarrowAr(req) => self.accept_read(BusKind::Narrow, req, h, now),
            Payload::WideAr(req) => self.accept_read(BusKind::Wide, req, h, now),
            Payload::NarrowAw(req) => self.accept_aw(BusKind::Narrow, req, h, now),
            Payload::WideAw(req) => self.accept_aw(BusKind::Wide, req, h, now),
            Payload::NarrowW { beat, .. } => {
                self.accept_w(BusKind::Narrow, h.src, beat.last, now)
            }
            Payload::WideW { beat, .. } => {
                self.accept_w(BusKind::Wide, h.src, beat.last, now)
            }
            _ => panic!("response-class flit delivered to target"),
        }
    }

    fn mem(&mut self, bus: BusKind) -> &mut MemModel {
        match bus {
            BusKind::Narrow => &mut self.narrow_mem,
            BusKind::Wide => &mut self.wide_mem,
        }
    }

    fn accept_read(&mut self, bus: BusKind, req: AxReq, h: Header, now: u64) -> bool {
        if !self.mem(bus).can_accept() {
            self.stats.req_stall_cycles += 1;
            return false;
        }
        let ready_at = self
            .mem(bus)
            .accept(now, h.src, h.rob_idx, h.rob_req, h.atomic, req, true);
        self.newly_scheduled = Some(ready_at);
        self.stats.reads_served += 1;
        true
    }

    fn accept_aw(&mut self, bus: BusKind, req: AxReq, h: Header, now: u64) -> bool {
        if h.atomic && self.atomics_inflight >= self.cfg.atomic_slots {
            self.stats.req_stall_cycles += 1;
            return false;
        }
        let asm = self.assembly.entry((h.src.0, bus)).or_default();
        if asm.aws.len() >= self.cfg.pending_writes {
            self.stats.req_stall_cycles += 1;
            return false;
        }
        if h.atomic {
            self.atomics_inflight += 1;
        }
        asm.aws.push_back(PendingAw {
            req,
            src: h.src,
            rob_idx: h.rob_idx,
            rob_req: h.rob_req,
            atomic: h.atomic,
        });
        self.try_submit_write(h.src.0, bus, now);
        true
    }

    fn accept_w(&mut self, bus: BusKind, src: NodeId, last: bool, now: u64) -> bool {
        let asm = self.assembly.entry((src.0, bus)).or_default();
        if last && asm.done_bursts.len() >= self.cfg.pending_writes {
            self.stats.req_stall_cycles += 1;
            return false;
        }
        asm.cur_beats += 1;
        if last {
            let beats = asm.cur_beats;
            asm.cur_beats = 0;
            asm.done_bursts.push_back(beats);
            self.try_submit_write(src.0, bus, now);
        }
        true
    }

    /// Match the oldest AW with the oldest completed W burst and hand the
    /// write to memory when it has room.
    fn try_submit_write(&mut self, src: u16, bus: BusKind, now: u64) {
        // Split borrows: decide, then act.
        let ready = {
            let asm = self.assembly.get(&(src, bus)).unwrap();
            !asm.aws.is_empty() && !asm.done_bursts.is_empty()
        };
        if !ready || !self.mem(bus).can_accept() {
            return;
        }
        let (aw, beats) = {
            let asm = self.assembly.get_mut(&(src, bus)).unwrap();
            (asm.aws.pop_front().unwrap(), asm.done_bursts.pop_front().unwrap())
        };
        debug_assert_eq!(
            beats,
            aw.req.beats(),
            "W burst length must match its AW (src {src})"
        );
        let ready_at = self
            .mem(bus)
            .accept(now, aw.src, aw.rob_idx, aw.rob_req, aw.atomic, aw.req, false);
        self.newly_scheduled = Some(ready_at);
        if aw.atomic {
            self.stats.atomics_served += 1;
        } else {
            self.stats.writes_served += 1;
        }
    }

    /// Retry deferred write submissions (memory freed up this cycle).
    pub fn pump_writes(&mut self, now: u64) {
        if self.assembly.is_empty() {
            return; // fast path: no write reassembly in flight
        }
        let mut first: Option<(u16, BusKind)> = None;
        for (&k, a) in &self.assembly {
            if !a.aws.is_empty() && !a.done_bursts.is_empty() {
                first = Some(k);
                break;
            }
        }
        // At most one deferred submission per cycle matters (the memory
        // accepts one op per port per cycle anyway); avoids allocating a
        // key list in the per-node per-cycle path.
        if let Some((src, bus)) = first {
            self.try_submit_write(src, bus, now);
        }
    }

    /// Is the narrow memory ready to emit a response beat at `now`?
    pub fn narrow_head_ready(&self, now: u64) -> bool {
        self.narrow_mem.peek_head(now).is_some()
    }

    /// Wide memory head readiness: `Some(is_read)` when a beat is ready.
    pub fn wide_head(&self, now: u64) -> Option<bool> {
        self.wide_mem.peek_head(now).map(|op| op.is_read)
    }

    /// Pop the next narrow-memory response beat as a flit (narrow R or B).
    /// The caller (tile NI injection logic) owns wormhole contiguity: once
    /// a multi-beat R burst starts it must keep calling this source until
    /// the `last` flit.
    pub fn pop_narrow(&mut self, now: u64) -> Option<FlooFlit> {
        let rsp = self.narrow_mem.step(now)?;
        if rsp.atomic && !rsp.is_read {
            self.atomics_inflight -= 1;
        }
        Some(self.rsp_to_flit(BusKind::Narrow, rsp, now))
    }

    /// Pop the next wide-memory response beat as a flit (wide R or B).
    pub fn pop_wide(&mut self, now: u64) -> Option<FlooFlit> {
        let rsp = self.wide_mem.step(now)?;
        if rsp.atomic && !rsp.is_read {
            self.atomics_inflight -= 1;
        }
        Some(self.rsp_to_flit(BusKind::Wide, rsp, now))
    }

    /// Round-robin tiebreak bit for the caller's response arbitration.
    pub fn flip_rr(&mut self) -> bool {
        self.rsp_rr = !self.rsp_rr;
        self.rsp_rr
    }

    /// Drain the retirement cycle of any memory op accepted this cycle
    /// (at most one distinct value per cycle — both ports share the
    /// latency, so same-cycle accepts overwrite with the same value).
    /// The system's event-mode step loop feeds this into its calendar;
    /// cycle-stepped modes never drain it, and the stale value is inert.
    pub fn take_scheduled(&mut self) -> Option<u64> {
        self.newly_scheduled.take()
    }

    /// True when stepping this target's eject/inject phase at `now`
    /// would be a provable no-op: no memory head is ready to emit a
    /// beat, and no matched AW/W-burst pair is waiting for memory space
    /// (`pump_writes` would submit one — a state change). Deliberately
    /// conservative: a ready pair blocks the event-mode skip even when
    /// the memory is full, costing stepped cycles, never correctness.
    /// Future retirements of ops already inside the memories are covered
    /// by the calendar, not by this predicate.
    pub fn eject_quiet(&self, now: u64) -> bool {
        self.narrow_mem.peek_head(now).is_none()
            && self.wide_mem.peek_head(now).is_none()
            && self
                .assembly
                .values()
                .all(|a| a.aws.is_empty() || a.done_bursts.is_empty())
    }

    fn rsp_to_flit(&self, bus: BusKind, rsp: MemRsp, now: u64) -> FlooFlit {
        use crate::axi::{BResp, RBeat};
        let header = Header {
            dst: rsp.src,
            src: self.node,
            rob_idx: rsp.rob_idx,
            rob_req: rsp.rob_req,
            atomic: rsp.atomic,
            last: rsp.last,
        };
        let payload = match (bus, rsp.is_read) {
            (BusKind::Narrow, true) => Payload::NarrowR(RBeat {
                id: rsp.id,
                beat: rsp.beat,
                last: rsp.last,
                resp: rsp.resp,
            }),
            (BusKind::Wide, true) => Payload::WideR(RBeat {
                id: rsp.id,
                beat: rsp.beat,
                last: rsp.last,
                resp: rsp.resp,
            }),
            (BusKind::Narrow, false) => Payload::NarrowB(BResp {
                id: rsp.id,
                resp: rsp.resp,
            }),
            (BusKind::Wide, false) => Payload::WideB(BResp {
                id: rsp.id,
                resp: rsp.resp,
            }),
        };
        FlooFlit::new(header, payload, now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::axi::Burst;

    fn req(id: u16, len: u8, atop: bool) -> AxReq {
        AxReq {
            id,
            addr: 0x40,
            len,
            size: 6,
            burst: Burst::Incr,
            atop,
        }
    }

    fn hdr(src: u16, rob_idx: u32, atomic: bool, last: bool) -> Header {
        Header {
            dst: NodeId(9),
            src: NodeId(src),
            rob_idx,
            rob_req: true,
            atomic,
            last,
        }
    }

    fn fl(p: Payload, h: Header) -> FlooFlit {
        FlooFlit::new(h, p, 0)
    }

    #[test]
    fn read_request_to_response_flits() {
        let mut t = Target::new(TargetCfg::spm_default(), NodeId(9));
        let h = hdr(2, 5, false, true);
        assert!(t.handle_request(&fl(Payload::WideAr(req(1, 1, false)), h), 0));
        let lat = t.cfg.mem_latency;
        assert!(t.pop_wide(lat - 1).is_none(), "nothing before the latency");
        let r0 = t.pop_wide(lat).unwrap();
        assert_eq!(r0.header.dst, NodeId(2));
        assert_eq!(r0.header.rob_idx, 5);
        assert!(matches!(r0.payload, Payload::WideR(b) if b.beat == 0 && !b.last));
        let r1 = t.pop_wide(lat + 1).unwrap();
        assert!(r1.header.last);
        assert!(t.is_idle());
        assert_eq!(t.stats.reads_served, 1);
    }

    #[test]
    fn wide_write_pairs_aw_with_w_burst() {
        let mut t = Target::new(TargetCfg::spm_default(), NodeId(9));
        // W beats arrive before the AW (different physical links).
        assert!(t.handle_request(
            &fl(
                Payload::WideW {
                    id: 3,
                    beat: crate::axi::WBeat { beat: 0, last: false }
                },
                hdr(2, 7, false, false)
            ),
            0
        ));
        assert!(t.handle_request(
            &fl(
                Payload::WideW {
                    id: 3,
                    beat: crate::axi::WBeat { beat: 1, last: true }
                },
                hdr(2, 7, false, true)
            ),
            1
        ));
        assert!(!t.is_idle(), "unmatched W burst pending");
        assert!(t.handle_request(&fl(Payload::WideAw(req(3, 1, false)), hdr(2, 7, false, true)), 2));
        // B response comes back (Table I maps it onto narrow_rsp).
        let b = t.pop_wide(2 + t.cfg.mem_latency).unwrap();
        assert!(matches!(b.payload, Payload::WideB(_)));
        assert_eq!(b.header.dst, NodeId(2));
        assert!(t.is_idle());
        assert_eq!(t.stats.writes_served, 1);
    }

    #[test]
    fn narrow_write_aw_first() {
        let mut t = Target::new(TargetCfg::spm_default(), NodeId(9));
        let mut r = req(1, 0, false);
        r.size = 3;
        assert!(t.handle_request(&fl(Payload::NarrowAw(r), hdr(4, 0, false, true)), 0));
        assert!(t.handle_request(
            &fl(
                Payload::NarrowW {
                    id: 1,
                    beat: crate::axi::WBeat { beat: 0, last: true }
                },
                hdr(4, 0, false, true)
            ),
            1
        ));
        let b = t.pop_narrow(1 + t.cfg.mem_latency).unwrap();
        assert!(matches!(b.payload, Payload::NarrowB(_)));
    }

    #[test]
    fn memory_backpressure_stalls_reads() {
        let mut cfg = TargetCfg::spm_default();
        cfg.mem_outstanding = 1;
        let mut t = Target::new(cfg, NodeId(9));
        assert!(t.handle_request(&fl(Payload::NarrowAr(req(1, 0, false)), hdr(2, 0, false, true)), 0));
        assert!(
            !t.handle_request(&fl(Payload::NarrowAr(req(1, 0, false)), hdr(2, 1, false, true)), 0),
            "second read must stall"
        );
        assert!(t.stats.req_stall_cycles > 0);
    }

    #[test]
    fn atomics_use_separate_bounded_slots() {
        let mut cfg = TargetCfg::spm_default();
        cfg.atomic_slots = 1;
        let mut t = Target::new(cfg, NodeId(9));
        let mut w = req(1, 0, true);
        w.size = 3;
        assert!(t.handle_request(&fl(Payload::NarrowAw(w), hdr(2, 0, true, true)), 0));
        // Second atomic refused while the first is in flight.
        assert!(!t.handle_request(&fl(Payload::NarrowAw(w), hdr(2, 1, true, true)), 0));
        // Complete the first.
        assert!(t.handle_request(
            &fl(
                Payload::NarrowW {
                    id: 1,
                    beat: crate::axi::WBeat { beat: 0, last: true }
                },
                hdr(2, 0, true, true)
            ),
            0
        ));
        let b = t.pop_narrow(t.cfg.mem_latency).unwrap();
        assert!(b.header.atomic);
        assert_eq!(t.stats.atomics_served, 1);
        // Slot free again.
        assert!(t.handle_request(&fl(Payload::NarrowAw(w), hdr(2, 1, true, true)), 6));
    }

    #[test]
    fn rr_between_wide_b_and_narrow_rsp() {
        let mut t = Target::new(TargetCfg::spm_default(), NodeId(9));
        // One narrow read and one wide write complete at the same time.
        let mut nr = req(1, 0, false);
        nr.size = 3;
        assert!(t.handle_request(&fl(Payload::NarrowAr(nr), hdr(2, 0, false, true)), 0));
        assert!(t.handle_request(&fl(Payload::WideAw(req(2, 0, false)), hdr(3, 1, false, true)), 0));
        assert!(t.handle_request(
            &fl(
                Payload::WideW {
                    id: 2,
                    beat: crate::axi::WBeat { beat: 0, last: true }
                },
                hdr(3, 1, false, true)
            ),
            0
        ));
        let lat = t.cfg.mem_latency;
        assert!(t.narrow_head_ready(lat));
        assert_eq!(t.wide_head(lat), Some(false), "wide head is a B");
        let first = t.pop_narrow(lat).unwrap();
        let second = t.pop_wide(lat + 1).unwrap();
        assert!(matches!(first.payload, Payload::NarrowR(_)));
        assert!(matches!(second.payload, Payload::WideB(_)));
    }
}
