//! Parallel sweep execution.
//!
//! Every DSE/ablation sweep in this crate is a map over independent
//! simulation points: each point builds its own [`NocSystem`], drives its
//! own generators, and touches no shared state. [`ParallelRunner`] fans
//! such points out across OS threads with `std::thread::scope` (no extra
//! dependencies), while guaranteeing:
//!
//! * **stable result ordering** — results come back indexed by input
//!   position, so output is identical to a serial map;
//! * **deterministic seeding** — per-point RNG seeds are derived from
//!   `(base_seed, point index)` via [`mix_seed`], never from execution
//!   order or thread identity;
//! * **panic propagation** — a panicking point aborts the whole sweep
//!   with the worker's panic payload, instead of silently dropping work.
//!
//! Together these make a parallel sweep byte-identical to its serial
//! counterpart (covered by `tests/parallel_sweep.rs`), so callers can
//! default to all cores.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::cluster::{TileTraffic, TiledWorkload};
use crate::flit::{Coord, NodeId};
use crate::noc::{LinkMode, NocConfig, NocSystem, NET_WIDE};
use crate::router::PORT_E;
use crate::topology::{MemEdge, Topology, TopologyKind};
use crate::traffic::GenCfg;
use crate::util::json::Json;
use crate::util::rng::mix_seed;

/// Work-stealing-free parallel map over independent sweep points.
#[derive(Debug, Clone)]
pub struct ParallelRunner {
    threads: usize,
}

impl Default for ParallelRunner {
    /// One worker per available core.
    fn default() -> Self {
        ParallelRunner::new(0)
    }
}

impl ParallelRunner {
    /// `threads = 0` means "all available cores".
    pub fn new(threads: usize) -> Self {
        let threads = if threads == 0 {
            std::thread::available_parallelism()
                .map(std::num::NonZeroUsize::get)
                .unwrap_or(1)
        } else {
            threads
        };
        ParallelRunner { threads }
    }

    /// A runner that executes on the calling thread only (the serial
    /// reference used by the determinism tests).
    pub fn serial() -> Self {
        ParallelRunner::new(1)
    }

    /// Resolved worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Map `f` over `points`, returning results in input order. `f` gets
    /// the point's index so it can derive deterministic per-point seeds.
    pub fn run<P, R, F>(&self, points: &[P], f: F) -> Vec<R>
    where
        P: Sync,
        R: Send,
        F: Fn(usize, &P) -> R + Sync,
    {
        let n = points.len();
        let workers = self.threads.min(n).max(1);
        if workers == 1 {
            return points.iter().enumerate().map(|(i, p)| f(i, p)).collect();
        }
        // Dynamic index dispenser: long points don't serialize behind a
        // static chunking, and the (index, result) pairs restore input
        // order afterwards regardless of who computed what.
        let next = AtomicUsize::new(0);
        let mut indexed: Vec<(usize, R)> = Vec::with_capacity(n);
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    s.spawn(|| {
                        let mut out = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= n {
                                break;
                            }
                            out.push((i, f(i, &points[i])));
                        }
                        out
                    })
                })
                .collect();
            // Join every worker before unwinding: resuming the first
            // panic while another handle is still unjoined would make
            // `scope` panic during the unwind — a double panic aborts the
            // process and loses both diagnostics.
            let mut first_panic = None;
            for h in handles {
                match h.join() {
                    Ok(part) => indexed.extend(part),
                    Err(payload) => {
                        first_panic.get_or_insert(payload);
                    }
                }
            }
            if let Some(payload) = first_panic {
                std::panic::resume_unwind(payload);
            }
        });
        indexed.sort_by_key(|&(i, _)| i);
        indexed.into_iter().map(|(_, r)| r).collect()
    }
}

// The whole simulation stack must stay `Send` for scoped workers to own
// systems; this fails to compile if a non-Send handle (Rc, RefCell, raw
// client, ...) ever creeps into the per-point state.
const _: () = {
    const fn assert_send<T: Send>() {}
    assert_send::<NocSystem>();
    assert_send::<crate::sim::Engine<NocSystem>>();
    assert_send::<TiledWorkload>();
};

/// One point of a cycle-accurate sweep: the neighbour-ring DMA workload
/// (every tile streams bursts to its +x ring neighbour) parameterized
/// along the axes the paper's evaluation sweeps — link mode, burst
/// length, outstanding budget, mesh size.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// Display name of the point (report key).
    pub name: String,
    /// Fabric shape the point is simulated on. For [`TopologyKind::Mesh`]
    /// and [`TopologyKind::Torus`] the fabric is `mesh_n × mesh_n`; a
    /// [`TopologyKind::Ring`] keeps the tile count and lays the same
    /// `mesh_n²` tiles out as one closed chain.
    pub topology: TopologyKind,
    /// Grid side length (the fabric has `mesh_n²` tiles).
    pub mesh_n: u8,
    /// Link configuration (narrow-wide vs wide-only baseline).
    pub mode: LinkMode,
    /// AxLEN (beats = len + 1).
    pub burst_len: u8,
    /// DMA bursts per tile.
    pub bursts_per_tile: u64,
    /// Writes instead of reads.
    pub write: bool,
    /// Outstanding-transaction budget per tile.
    pub max_outstanding: u32,
    /// Base seed; the effective per-point seed also mixes in the point's
    /// index, and each tile's generator mixes in its node id.
    pub base_seed: u64,
}

impl SweepPoint {
    /// A small canonical point (used by examples/tests as a template).
    pub fn ring(name: &str, mesh_n: u8, mode: LinkMode) -> Self {
        SweepPoint {
            name: name.to_string(),
            topology: TopologyKind::Mesh,
            mesh_n,
            mode,
            burst_len: 15,
            bursts_per_tile: 8,
            write: false,
            max_outstanding: 4,
            base_seed: 0xF100_0C0D,
        }
    }

    /// Cartesian sweep grid over mesh sizes × link modes × burst lengths
    /// — the shape every sweep consumer (CLI `dse`, the `dse_sweep`
    /// example, `bench_e2e`, the determinism tests) wants. Point names
    /// are `ring-<n>x<n>-<nw|wo>-len<beats>`.
    pub fn grid(meshes: &[u8], modes: &[LinkMode], lens: &[u8]) -> Vec<SweepPoint> {
        let mut points = Vec::new();
        for &mesh_n in meshes {
            for &mode in modes {
                for &len in lens {
                    let tag = match mode {
                        LinkMode::NarrowWide => "nw",
                        LinkMode::WideOnly => "wo",
                    };
                    let name = format!("ring-{mesh_n}x{mesh_n}-{tag}-len{}", len as u32 + 1);
                    let mut p = SweepPoint::ring(&name, mesh_n, mode);
                    p.burst_len = len;
                    points.push(p);
                }
            }
        }
        points
    }

    /// The same point on a different fabric, with the kind appended to
    /// its name. Ring fabrics keep the tile count (`mesh_n²` tiles in
    /// one closed chain), so cross-topology rows compare like for like.
    pub fn on_topology(mut self, kind: TopologyKind) -> Self {
        self.topology = kind;
        self.name = format!("{}-{}", self.name, kind.name());
        self
    }
}

/// Measured outcome of one sweep point.
#[derive(Debug, Clone)]
pub struct SweepResult {
    /// The point's display name.
    pub name: String,
    /// Fabric the point ran on.
    pub topology: TopologyKind,
    /// Grid side length of the point.
    pub mesh_n: u8,
    /// Link configuration of the point.
    pub mode: LinkMode,
    /// Makespan until full drain.
    pub cycles: u64,
    /// Wide beats delivered across all tiles.
    pub wide_beats: u64,
    /// Delivered wide payload per cycle (bytes).
    pub bytes_per_cycle: f64,
    /// Mean E-link throughput over links that carried traffic
    /// (flits/cycle) on the wide-carrying network.
    pub e_link_tput: f64,
}

/// Neighbour DMA profiles on an arbitrary fabric: every tile streams to
/// its `+x` neighbour, wrapping at the row end (`(x+1) mod W`). On a
/// ring fabric this is the true next tile around the chain; on meshes
/// and tori it reproduces the per-row neighbour rings of the paper's
/// scaling workload. `mk(i, dst)` produces tile `i`'s DMA generator
/// config.
pub fn neighbor_profiles(
    topo: &Topology,
    mk: impl Fn(usize, NodeId) -> GenCfg,
) -> Vec<TileTraffic> {
    (0..topo.num_tiles)
        .map(|i| {
            let c = topo.node(NodeId(i as u16)).coord;
            let dst = topo.tile_at(Coord::new((c.x + 1) % topo.width, c.y));
            TileTraffic {
                core: None,
                dma: Some(mk(i, dst)),
            }
        })
        .collect()
}

/// Neighbour-ring DMA profiles on an `n × n` grid: tile `(x, y)` streams
/// to `((x+1) mod n, y)`. The mesh-grid specialization of
/// [`neighbor_profiles`] (one rule, one home) —
/// `coordinator::scale_mesh_with` and `dse::simulate_ring_throughput`
/// build their workloads through it.
pub fn ring_profiles(n: usize, mk: impl Fn(usize, NodeId) -> GenCfg) -> Vec<TileTraffic> {
    assert!(n <= u8::MAX as usize, "grid side exceeds u8 coordinates");
    neighbor_profiles(&Topology::mesh(n as u8, n as u8, MemEdge::None), mk)
}

/// Execute one sweep point to completion. Pure function of
/// `(idx, point)`: repeated calls give identical results, which is what
/// makes the parallel sweep reproducible.
pub fn run_point(idx: usize, p: &SweepPoint) -> SweepResult {
    let n = p.mesh_n as usize;
    let tiles = n * n;
    let mut cfg = match p.topology {
        TopologyKind::Mesh => NocConfig::mesh(p.mesh_n, p.mesh_n),
        TopologyKind::Torus => NocConfig::torus(p.mesh_n, p.mesh_n),
        TopologyKind::Ring => {
            assert!(tiles <= u8::MAX as usize, "ring point too large: {tiles} tiles");
            NocConfig::ring(tiles as u8)
        }
    };
    cfg.mode = p.mode;
    let sys = NocSystem::new(cfg);
    let seed = mix_seed(p.base_seed, idx as u64);
    let profiles = neighbor_profiles(&sys.topo, |i, dst| {
        let mut c = GenCfg::dma_burst(dst, p.bursts_per_tile, p.write);
        c.burst_len = p.burst_len;
        c.max_outstanding = p.max_outstanding;
        c.seed = mix_seed(seed, i as u64);
        c
    });
    let mut w = TiledWorkload::new(sys, profiles);
    assert!(
        w.run_to_completion(50_000_000),
        "sweep point '{}' did not drain",
        p.name
    );
    assert!(w.protocol_ok(), "sweep point '{}' violated AXI", p.name);
    let wide_net = match p.mode {
        LinkMode::NarrowWide => NET_WIDE,
        LinkMode::WideOnly => {
            // Wide data rides the response net for reads, the request net
            // for writes.
            if p.write {
                crate::noc::NET_REQ
            } else {
                crate::noc::NET_RSP
            }
        }
    };
    // Count wide *data* beats only: the eject meters observe 512 payload
    // bits per WideR/WideW flit and 0 for everything else sharing the
    // observed link, so `payload_bits / 512` excludes AW/AR/B header
    // flits even on the merged wide-only networks.
    let wide_beats: u64 = (0..tiles)
        .map(|i| w.sys.eject_meters[wide_net][i].payload_bits / 512)
        .sum();
    let cycles = w.sys.now.max(1);
    let (mut tput_sum, mut tput_links) = (0.0f64, 0u64);
    for r in &w.sys.nets[wide_net].routers {
        let f = r.forwarded_on(PORT_E);
        if f > 0 {
            tput_sum += f as f64 / cycles as f64;
            tput_links += 1;
        }
    }
    SweepResult {
        name: p.name.clone(),
        topology: p.topology,
        mesh_n: p.mesh_n,
        mode: p.mode,
        cycles,
        wide_beats,
        bytes_per_cycle: wide_beats as f64 * 64.0 / cycles as f64,
        e_link_tput: if tput_links > 0 {
            tput_sum / tput_links as f64
        } else {
            0.0
        },
    }
}

/// Run a whole sweep through the runner. Result order matches `points`.
pub fn run_sweep(points: &[SweepPoint], runner: &ParallelRunner) -> Vec<SweepResult> {
    runner.run(points, run_point)
}

/// Deterministic JSON report: object keys are sorted (`Json::Obj` is a
/// `BTreeMap`) and rows keep sweep order, so serial and parallel runs of
/// the same points serialize byte-identically.
pub fn sweep_report_json(results: &[SweepResult]) -> Json {
    Json::Arr(
        results
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("name", Json::Str(r.name.clone())),
                    ("topology", Json::Str(r.topology.name().to_string())),
                    ("mesh_n", Json::Num(r.mesh_n as f64)),
                    (
                        "mode",
                        Json::Str(
                            match r.mode {
                                LinkMode::NarrowWide => "narrow_wide",
                                LinkMode::WideOnly => "wide_only",
                            }
                            .to_string(),
                        ),
                    ),
                    ("cycles", Json::Num(r.cycles as f64)),
                    ("wide_beats", Json::Num(r.wide_beats as f64)),
                    ("bytes_per_cycle", Json::Num(r.bytes_per_cycle)),
                    ("e_link_tput", Json::Num(r.e_link_tput)),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runner_maps_in_order() {
        let points: Vec<u64> = (0..37).collect();
        let r = ParallelRunner::new(4);
        let got = r.run(&points, |i, &p| (i as u64, p * 2));
        let want: Vec<(u64, u64)> = (0..37).map(|i| (i, i * 2)).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn serial_runner_is_one_thread() {
        assert_eq!(ParallelRunner::serial().threads(), 1);
        assert!(ParallelRunner::default().threads() >= 1);
    }

    #[test]
    fn runner_handles_more_threads_than_points() {
        let r = ParallelRunner::new(16);
        let got = r.run(&[10u32, 20], |_, &p| p + 1);
        assert_eq!(got, vec![11, 21]);
    }

    #[test]
    fn empty_input_is_fine() {
        let r = ParallelRunner::default();
        let got: Vec<u32> = r.run(&[], |_, p: &u32| *p);
        assert!(got.is_empty());
    }

    #[test]
    #[should_panic(expected = "worker boom")]
    fn worker_panics_propagate() {
        let r = ParallelRunner::new(2);
        let _ = r.run(&[0u32, 1, 2, 3], |_, &p| {
            if p == 2 {
                panic!("worker boom");
            }
            p
        });
    }

    #[test]
    fn topology_points_complete_on_all_fabrics() {
        // The +x-neighbour workload is single-hop on every fabric (the
        // wrap link closes each row), so it is deadlock-safe even on
        // torus/ring and must drain everywhere.
        let base = SweepPoint::ring("xtopo", 2, LinkMode::NarrowWide);
        for kind in [TopologyKind::Mesh, TopologyKind::Torus, TopologyKind::Ring] {
            let p = base.clone().on_topology(kind);
            let r = run_point(0, &p);
            assert_eq!(r.topology, kind);
            assert!(r.wide_beats > 0, "{}: no data moved", p.name);
            // 4 tiles x 8 bursts x 16 beats on every fabric.
            assert_eq!(r.wide_beats, 4 * 8 * 16, "{}: beat count", p.name);
        }
    }

    #[test]
    fn point_results_are_reproducible() {
        let p = SweepPoint::ring("repro", 2, LinkMode::NarrowWide);
        let a = run_point(3, &p);
        let b = run_point(3, &p);
        assert_eq!((a.cycles, a.wide_beats), (b.cycles, b.wide_beats));
        assert!(a.wide_beats > 0);
        // A different index derives a different seed but the ring workload
        // is seed-insensitive in shape: it must still complete.
        let c = run_point(4, &p);
        assert!(c.wide_beats == a.wide_beats, "same workload size");
    }
}
