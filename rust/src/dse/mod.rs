//! Design-space exploration: the analytical XY link-load model.
//!
//! Three implementations of the same model are cross-validated:
//!
//! 1. a native Rust evaluation ([`link_loads`]) used for arbitrary mesh
//!    sizes and fast sweeps;
//! 2. the AOT-lowered JAX/Pallas artifact (`noc_perf.hlo.txt`, fixed at
//!    the `meta.json` mesh size) executed via PJRT — the L1/L2 model
//!    exercised from the L3 hot path;
//! 3. the cycle-accurate simulator, whose measured per-link throughput
//!    must agree with the analytical loads in the unsaturated regime.
//!
//! The mesh-only XY evaluation ([`link_loads`]) is complemented by a
//! fabric-generalized walker ([`fabric_link_loads`]) that covers torus
//! and ring deployments with their **per-VC lane split**: it walks every
//! flow's deterministic route with the exact same `RouteTable::lookup` +
//! `dateline_vc` pair the router hot loop asks, so wrap crossings land
//! on the dateline lane analytically just as they do in the simulator —
//! and the cross-check against measured per-lane link counters is an
//! *exact count* identity, not a proportionality fit.

pub mod parallel;

pub use parallel::{run_sweep, sweep_report_json, ParallelRunner, SweepPoint, SweepResult};

use anyhow::Context;

use crate::cluster::TiledWorkload;
use crate::flit::NodeId;
use crate::noc::{NocConfig, NocSystem, NET_WIDE};
use crate::router::routing::dateline_vc;
use crate::router::{PORT_E, PORT_LOCAL};
use crate::runtime::Runtime;
use crate::topology::Topology;
use crate::traffic::GenCfg;

/// Per-direction link loads for an `n×n` mesh: `loads[dir][y][x]` with
/// dir ∈ {E, W, N, S} — identical layout to the Python oracle.
pub type Loads = Vec<Vec<Vec<f64>>>;

/// Native Rust XY link-load model. `traffic[s][d]` in flits/cycle,
/// nodes row-major.
pub fn link_loads(traffic: &[Vec<f64>], n: usize) -> Loads {
    let mut loads = vec![vec![vec![0.0; n]; n]; 4];
    for s in 0..n * n {
        for d in 0..n * n {
            let t = traffic[s][d];
            if t == 0.0 || s == d {
                continue;
            }
            let (sx, sy) = (s % n, s / n);
            let (dx, dy) = (d % n, d / n);
            // X leg at row sy.
            if dx > sx {
                for x in sx..dx {
                    loads[0][sy][x] += t; // E link of (x, sy)
                }
            } else {
                for x in dx..sx {
                    loads[1][sy][x] += t; // W link stored at position x
                }
            }
            // Y leg at column dx.
            if dy > sy {
                for y in sy..dy {
                    loads[2][y][dx] += t;
                }
            } else {
                for y in dy..sy {
                    loads[3][y][dx] += t;
                }
            }
        }
    }
    loads
}

/// Max link load (the saturation bottleneck).
pub fn max_load(loads: &Loads) -> f64 {
    loads
        .iter()
        .flatten()
        .flatten()
        .copied()
        .fold(0.0, f64::max)
}

/// Mean load over all links.
pub fn mean_load(loads: &Loads) -> f64 {
    let total: f64 = loads.iter().flatten().flatten().sum();
    let count = loads.iter().flatten().flatten().count();
    total / count as f64
}

/// A canonical DSE workload: every tile streams to its +x ring neighbour
/// at `rate` flits/cycle.
pub fn ring_traffic(n: usize, rate: f64) -> Vec<Vec<f64>> {
    let mut t = vec![vec![0.0; n * n]; n * n];
    for y in 0..n {
        for x in 0..n {
            let s = y * n + x;
            let d = y * n + (x + 1) % n;
            t[s][d] = rate;
        }
    }
    t
}

/// Uniform-random traffic at aggregate injection `rate` per node.
pub fn uniform_traffic(n: usize, rate: f64) -> Vec<Vec<f64>> {
    let nodes = n * n;
    let mut t = vec![vec![rate / (nodes as f64 - 1.0); nodes]; nodes];
    for (s, row) in t.iter_mut().enumerate() {
        row[s] = 0.0;
    }
    t
}

/// Tornado traffic over a fabric's tiles at `rate` flits/cycle: every
/// tile targets the tile half-way around each wrapping dimension —
/// exactly [`crate::traffic::Pattern::Tornado`]'s destination function,
/// as an analytic matrix. On fabrics with even ring dimensions the
/// pattern is an involution (tornado of tornado is the identity), so
/// request and response flows traverse the same links mirrored.
pub fn tornado_traffic(topo: &Topology, rate: f64) -> Vec<Vec<f64>> {
    let tiles = topo.num_tiles;
    let w = topo.width as usize;
    let h = topo.height as usize;
    let mut t = vec![vec![0.0; tiles]; tiles];
    for (s, row) in t.iter_mut().enumerate() {
        let c = topo.node(NodeId(s as u16)).coord;
        let nx = (c.x as usize + w / 2) % w;
        let ny = if h > 1 { (c.y as usize + h / 2) % h } else { c.y as usize };
        let d = ny * w + nx;
        if d != s {
            row[d] = rate;
        }
    }
    t
}

/// Fabric-generalized analytic link loads with the per-VC lane split:
/// walk every flow of `traffic` (tile-indexed, flits/cycle) along its
/// deterministic route and accumulate the rate onto each traversed
/// `(router, output port, lane)`. Returns `loads[router][port][lane]`
/// with `radix` ports and `vcs` lanes per router.
///
/// The walk asks the same [`crate::router::RouteTable`] the live router
/// asks and applies the same [`dateline_vc`] lane switch (capped to the
/// link's top lane, as the router caps it), so on wrap fabrics the
/// wraparound links carry their load entirely on the dateline lane —
/// the quantity the simulator's per-lane `Link` counters measure.
/// Ejection (the final hop into the destination node) is not counted:
/// the loads cover router-to-router channels only.
pub fn fabric_link_loads(
    topo: &Topology,
    vcs: usize,
    traffic: &[Vec<f64>],
) -> Vec<Vec<Vec<f64>>> {
    assert!(vcs >= 1);
    let routers = topo.width as usize * topo.height as usize;
    let radix = topo.router_radix();
    // Neighbour map from the channel list: nbr[router][port] = (peer
    // router, peer input port).
    let mut nbr: Vec<Vec<Option<(usize, usize)>>> = vec![vec![None; radix]; routers];
    for (a, pa, b, pb) in topo.channels() {
        nbr[a][pa] = Some((b, pb));
        nbr[b][pb] = Some((a, pa));
    }
    let tables: Vec<_> = (0..routers)
        .map(|r| topo.route_table(topo.nodes[r].coord))
        .collect();
    let mut loads = vec![vec![vec![0.0f64; vcs]; radix]; routers];
    for (s, row) in traffic.iter().enumerate() {
        for (d, &t) in row.iter().enumerate() {
            if t == 0.0 || s == d {
                continue;
            }
            let dst = NodeId(d as u16);
            let mut r = topo.router_index(topo.node(NodeId(s as u16)).coord);
            let goal = topo.router_index(topo.node(dst).coord);
            let (mut in_port, mut vc) = (PORT_LOCAL, 0u8);
            let mut hops = 0usize;
            while r != goal {
                let o = tables[r].lookup(dst);
                let crosses = tables[r].crosses_dateline(o);
                let vo = dateline_vc(in_port, o, crosses, vc).min(vcs as u8 - 1);
                loads[r][o][vo as usize] += t;
                let (nr, np) = nbr[r][o].expect("deterministic route walked off the fabric");
                r = nr;
                in_port = np;
                vc = vo;
                hops += 1;
                assert!(hops <= routers, "route loop walking {s} -> {d}");
            }
        }
    }
    loads
}

/// Evaluate the PJRT `noc_perf` artifact on a traffic matrix (must match
/// the artifact's fixed mesh size). Returns (loads, max, mean, sat).
pub fn artifact_link_loads(
    rt: &Runtime,
    traffic: &[Vec<f64>],
) -> crate::Result<(Loads, f64, f64, f64)> {
    let n = rt.meta.dse_mesh_n;
    let nodes = n * n;
    anyhow::ensure!(
        traffic.len() == nodes,
        "artifact is specialized for a {n}x{n} mesh ({nodes} nodes), got {}",
        traffic.len()
    );
    let exe = rt.load("noc_perf")?;
    let flat: Vec<f32> = traffic
        .iter()
        .flat_map(|row| row.iter().map(|&v| v as f32))
        .collect();
    let out = exe
        .run_f32(&[(&flat, &[nodes, nodes])])
        .context("noc_perf execution")?;
    let loads_flat = &out[0];
    let mut loads = vec![vec![vec![0.0f64; n]; n]; 4];
    for dir in 0..4 {
        for y in 0..n {
            for x in 0..n {
                loads[dir][y][x] = loads_flat[dir * n * n + y * n + x] as f64;
            }
        }
    }
    Ok((loads, out[1][0] as f64, out[2][0] as f64, out[3][0] as f64))
}

/// Measure per-link wide-network throughput from a cycle-accurate run of
/// the ring workload, for comparison against the analytical E-link loads.
pub fn simulate_ring_throughput(n: u8, bursts: u64) -> (f64, u64) {
    let sys = NocSystem::new(NocConfig::mesh(n, n));
    let profiles = parallel::ring_profiles(n as usize, |_, dst| {
        let mut c = GenCfg::dma_burst(dst, bursts, true);
        c.max_outstanding = 4;
        c
    });
    let mut w = TiledWorkload::new(sys, profiles);
    assert!(w.run_to_completion(10_000_000), "ring workload didn't drain");
    assert!(w.protocol_ok());
    // Mean E-link throughput (flits/cycle) over the wide network routers
    // that actually carried ring traffic.
    let mut total = 0u64;
    let mut links = 0u64;
    for r in &w.sys.nets[NET_WIDE].routers {
        let f = r.forwarded_on(PORT_E);
        if f > 0 {
            total += f;
            links += 1;
        }
    }
    let cycles = w.sys.now.max(1);
    (total as f64 / links.max(1) as f64 / cycles as f64, cycles)
}

/// The `repro dse` command: evaluate the analytical model natively and
/// via the PJRT artifact, cross-check them, compare against the
/// cycle-accurate simulator on the ring workload, and fan a multi-point
/// cycle-accurate sweep out across `runner`'s cores.
pub fn run_dse(n: u8, artifacts_dir: &str, runner: &ParallelRunner) -> crate::Result<()> {
    let n_us = n as usize;
    println!("== analytical XY link-load model, {n}x{n} mesh ==");
    for (name, traffic) in [
        ("ring(+x, 0.25 flits/cycle)", ring_traffic(n_us, 0.25)),
        ("uniform(0.2 flits/cycle)", uniform_traffic(n_us, 0.2)),
    ] {
        let loads = link_loads(&traffic, n_us);
        println!(
            "{name:32} max link load {:.3}, mean {:.3}, saturation scale {:.2}x",
            max_load(&loads),
            mean_load(&loads),
            1.0 / max_load(&loads)
        );
    }
    // Fabric-generalized walker: the adversarial tornado on the wrap
    // fabric, with its per-VC lane split (wrap links ride the dateline
    // lane exclusively — see docs/deadlock.md).
    {
        let torus = Topology::torus(n, n, crate::topology::MemEdge::None);
        let loads = fabric_link_loads(&torus, 2, &tornado_traffic(&torus, 1.0));
        let (mut maxv, mut wrap, mut total) = (0.0f64, 0.0f64, 0.0f64);
        for (r, per_port) in loads.iter().enumerate() {
            let dl = torus.dateline_ports(torus.nodes[r].coord);
            for (p, lanes) in per_port.iter().enumerate() {
                let l: f64 = lanes.iter().sum();
                maxv = maxv.max(l);
                total += l;
                if (dl >> p) & 1 == 1 {
                    wrap += l;
                }
            }
        }
        println!(
            "torus tornado (1 flit/cycle/tile)       max link load {maxv:.3}, \
             wrap-link share {:.2} (all of it on the dateline lane)",
            wrap / total.max(1e-12)
        );
    }
    // PJRT artifact cross-check (fixed mesh size).
    match Runtime::new(artifacts_dir) {
        Ok(rt) => {
            let an = rt.meta.dse_mesh_n;
            let traffic = ring_traffic(an, 0.25);
            let native = link_loads(&traffic, an);
            let (art, art_max, _mean, art_sat) = artifact_link_loads(&rt, &traffic)?;
            let mut max_diff = 0.0f64;
            for dir in 0..4 {
                for y in 0..an {
                    for x in 0..an {
                        max_diff = max_diff.max((art[dir][y][x] - native[dir][y][x]).abs());
                    }
                }
            }
            println!(
                "PJRT artifact ({}x{an} mesh, platform {}): max load {:.3}, \
                 sat {:.2}x, |artifact - native|max = {:.2e}",
                an,
                rt.platform(),
                art_max,
                art_sat,
                max_diff
            );
            anyhow::ensure!(max_diff < 1e-5, "artifact disagrees with native model");
        }
        Err(e) => println!("(skipping PJRT cross-check: {e})"),
    }
    // Simulator cross-check on the ring workload.
    let (sim_tput, cycles) = simulate_ring_throughput(n, 8);
    let analytical = link_loads(&ring_traffic(n_us, 1.0), n_us);
    println!(
        "cycle-accurate ring run: mean E-link throughput {:.3} flits/cycle \
         over {cycles} cycles (analytical prediction: uniform E-link load; \
         measured value reflects DMA round-trip gaps)",
        sim_tput
    );
    let _ = analytical;
    // Multi-point cycle-accurate sweep, fanned out across cores. The
    // report is deterministic: identical for any worker count.
    let mut points = SweepPoint::grid(
        &[n],
        &[crate::noc::LinkMode::NarrowWide, crate::noc::LinkMode::WideOnly],
        &[3, 15],
    );
    // Cross-topology rows at the same tile count: the +x-neighbour
    // workload is a single wrap-closed hop on every fabric, so torus and
    // ring rows are directly comparable to the mesh baseline.
    {
        use crate::topology::TopologyKind;
        // "xneigh" (not the legacy "ring-" workload prefix): the fabric
        // kind suffix would otherwise collide with the workload name.
        let name = format!("xneigh-{n}x{n}-nw-len16");
        let base = SweepPoint::ring(&name, n, crate::noc::LinkMode::NarrowWide);
        points.push(base.clone().on_topology(TopologyKind::Torus));
        // Only the ring deployment is bounded by u8 node ids.
        if (n as usize) * (n as usize) <= u8::MAX as usize {
            points.push(base.on_topology(TopologyKind::Ring));
        } else {
            println!("(skipping ring row: {n}x{n} = {} tiles > 255)", n as u32 * n as u32);
        }
    }
    println!(
        "\n== cycle-accurate sweep: {} points on {} worker thread(s) ==",
        points.len(),
        runner.threads()
    );
    let results = run_sweep(&points, runner);
    println!("{}", crate::util::json::pretty(&sweep_report_json(&results)));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_loads_only_east_links() {
        // +x ring: wrap flows use W links; interior flows E links.
        let t = ring_traffic(4, 1.0);
        let loads = link_loads(&t, 4);
        // Non-wrap flows: x -> x+1 uses exactly one E link each.
        assert_eq!(loads[0][0][0], 1.0);
        assert_eq!(loads[0][0][2], 1.0);
        // Wrap flow (3 -> 0) uses W links at positions 0..3.
        assert_eq!(loads[1][0][0], 1.0);
        assert_eq!(loads[1][0][2], 1.0);
        // No vertical traffic in a +x ring.
        assert!(loads[2].iter().flatten().all(|&v| v == 0.0));
        assert!(loads[3].iter().flatten().all(|&v| v == 0.0));
    }

    #[test]
    fn uniform_traffic_is_symmetric() {
        let t = uniform_traffic(3, 0.9);
        let loads = link_loads(&t, 3);
        // Symmetry: E and W mirror each other.
        let e_sum: f64 = loads[0].iter().flatten().sum();
        let w_sum: f64 = loads[1].iter().flatten().sum();
        assert!((e_sum - w_sum).abs() < 1e-9);
        // Row sums of the traffic matrix equal the injection rate.
        for row in &t {
            let s: f64 = row.iter().sum();
            assert!((s - 0.9).abs() < 1e-9);
        }
    }

    #[test]
    fn max_load_bottleneck_center() {
        // Uniform traffic on a 4x4 mesh: center links carry the most.
        let t = uniform_traffic(4, 1.0);
        let loads = link_loads(&t, 4);
        let center = loads[0][1][1].max(loads[0][2][1]);
        let edge = loads[0][0][0];
        assert!(center > edge);
    }

    #[test]
    fn hop_conservation() {
        // Sum of all link loads equals sum of flow * manhattan distance.
        let n = 4;
        let t = uniform_traffic(n, 0.5);
        let loads = link_loads(&t, n);
        let total: f64 = loads.iter().flatten().flatten().sum();
        let mut want = 0.0;
        for s in 0..n * n {
            for d in 0..n * n {
                let (sx, sy) = (s % n, s / n);
                let (dx, dy) = (d % n, d / n);
                want += t[s][d]
                    * ((sx as i64 - dx as i64).abs() + (sy as i64 - dy as i64).abs()) as f64;
            }
        }
        assert!((total - want).abs() < 1e-9);
    }

    /// Per-VC split of the fabric walker: tornado on a 4×4 torus loads
    /// the wraparound links on the dateline lane *only* — lane 0 of
    /// every wrap link stays analytically clear, matching the dateline
    /// scheme the simulator enforces.
    #[test]
    fn tornado_wrap_loads_ride_the_dateline_lane() {
        use crate::topology::MemEdge;
        let topo = Topology::torus(4, 4, MemEdge::None);
        let loads = fabric_link_loads(&topo, 2, &tornado_traffic(&topo, 1.0));
        let mut wrap_lane1 = 0.0;
        for (r, per_port) in loads.iter().enumerate() {
            let dl = topo.dateline_ports(topo.nodes[r].coord);
            for (p, lanes) in per_port.iter().enumerate() {
                if (dl >> p) & 1 == 1 {
                    assert_eq!(lanes[0], 0.0, "wrap link lane 0 must stay clear");
                    wrap_lane1 += lanes[1];
                }
            }
        }
        assert!(wrap_lane1 > 0.0, "the tornado must exercise the wraps");
    }

    /// The analytic cross-check of the fabric walker against the live
    /// simulator: drive the tornado on a torus and a ring, then compare
    /// the *exact* per-link per-lane delivered-flit counters of the
    /// request network against `fabric_link_loads` scaled by the
    /// transaction count. Every request flit follows the deterministic
    /// route, so the identity is exact — not a proportionality fit.
    #[test]
    fn fabric_loads_match_measured_lane_counters() {
        use crate::cluster::TileTraffic;
        use crate::noc::NET_REQ;
        use crate::traffic::Pattern;
        let txns = 6u64;
        for cfg in [NocConfig::torus(4, 4), NocConfig::ring(8)] {
            let vcs = cfg.vcs;
            let sys = NocSystem::new(cfg);
            let tiles = sys.topo.num_tiles;
            let profiles: Vec<TileTraffic> = (0..tiles)
                .map(|i| {
                    let mut c = GenCfg::narrow_probe(NodeId(0), txns);
                    c.pattern = Pattern::Tornado;
                    c.seed = 0x7E57 + i as u64;
                    TileTraffic {
                        core: Some(c),
                        dma: None,
                    }
                })
                .collect();
            let mut w = TiledWorkload::new(sys, profiles);
            assert!(w.run_to_completion(1_000_000), "tornado did not drain");
            assert!(w.protocol_ok());
            let topo = &w.sys.topo;
            let loads = fabric_link_loads(topo, vcs, &tornado_traffic(topo, 1.0));
            let routers = topo.width as usize * topo.height as usize;
            let net = &w.sys.nets[NET_REQ];
            let mut checked = 0usize;
            for r in 0..routers {
                // Cardinal ports only: ejection links (local/attach) are
                // deliberately outside the analytic model.
                for p in 1..topo.router_radix() {
                    let Some(lid) = net.routers[r].out_links[p] else {
                        continue;
                    };
                    for (v, &load) in loads[r][p].iter().enumerate() {
                        let want = (load * txns as f64).round() as u64;
                        assert_eq!(
                            net.links[lid].lane_delivered(v),
                            want,
                            "router {r} port {p} lane {v}"
                        );
                        checked += 1;
                    }
                }
            }
            assert!(checked > 0, "cross-check must cover real links");
        }
    }

    #[test]
    fn simulated_ring_matches_analytical_shape() {
        // In the unsaturated regime the per-E-link throughput must be
        // uniform across used links (the analytical model's prediction
        // for the ring pattern).
        let (tput, _cycles) = simulate_ring_throughput(2, 4);
        assert!(tput > 0.05, "ring must move data, got {tput}");
    }
}
