//! The paper's experiments, parameterized and reproducible.

use crate::cluster::{TileTraffic, TiledWorkload};
use crate::dse::parallel::ParallelRunner;
use crate::flit::NodeId;
use crate::noc::{LinkMode, NocConfig, NocSystem, NET_REQ, NET_RSP, NET_WIDE};
use crate::phys::energy::{Activity, EnergyModel, PowerBreakdown};
use crate::router::RoutingKind;
use crate::topology::TopologyKind;
use crate::traffic::{GenCfg, Generator, Pattern};

/// Narrow transactions of the Fig. 5a probe (the paper's NUMNARROWTRANS).
pub const NUM_NARROW_TRANS: u64 = 100;
/// Wide bursts of the Fig. 5b transfer (the paper's NUMWIDETRANS).
pub const NUM_WIDE_TRANS: u64 = 16;
/// AxLEN for the paper's BURSTLEN = 16 beats.
pub const BURST_LEN: u8 = 15;

/// §VI-A: zero-load round-trip latency of a narrow read to the adjacent
/// tile. Returns total cycles (paper: 18).
pub fn zero_load_latency(mode: LinkMode) -> u64 {
    let mut cfg = NocConfig::mesh(2, 1);
    cfg.mode = mode;
    zero_load_latency_on(cfg, NodeId(0), NodeId(1))
}

/// Zero-load round-trip latency of a single narrow read from tile `src`
/// to tile `dst` on an arbitrary fabric — the §VI-A measurement opened
/// up to the topology axis (a one-wrap-hop ring read must match the
/// adjacent-tile mesh figure exactly).
pub fn zero_load_latency_on(cfg: NocConfig, src: NodeId, dst: NodeId) -> u64 {
    let mut sys = NocSystem::new(cfg);
    let mut g = Generator::new(GenCfg::narrow_probe(dst, 1), src);
    // Prime the request before the first cycle so issue aligns with t=0.
    sys.step_generator(&mut g);
    for _ in 0..400 {
        sys.step();
        sys.step_generator(&mut g);
        if g.done() {
            return g.latencies.max();
        }
    }
    panic!("zero-load read did not complete");
}

/// One point of the Fig. 5a curve.
#[derive(Debug, Clone)]
pub struct Fig5aRow {
    /// Link configuration of this point.
    pub mode: LinkMode,
    /// Whether a reverse wide stream ran too.
    pub bidir: bool,
    /// Interference level: concurrent outstanding wide bursts (0 = none).
    pub wide_outstanding: u32,
    /// Mean narrow round-trip latency (cycles).
    pub narrow_mean: f64,
    /// 99th-percentile narrow latency.
    pub narrow_p99: u64,
    /// Worst-case narrow latency.
    pub narrow_max: u64,
    /// Degradation vs the zero-interference point of the same config.
    pub slowdown: f64,
}

/// Fig. 5a: latency of `NUM_NARROW_TRANS` narrow transactions under
/// increasing wide-burst interference, for one link mode.
///
/// The paper measures *cluster-to-cluster* accesses: all traffic flows
/// between one pair of adjacent tiles. The narrow probe runs tile 0 →
/// tile 1 while wide DMA write bursts stream tile 0 → tile 1 over the
/// same links; `bidir` adds the reverse wide stream tile 1 → tile 0
/// (which additionally congests the probe's response path in the
/// wide-only configuration).
pub fn fig5a(mode: LinkMode, bidir: bool, levels: &[u32]) -> Vec<Fig5aRow> {
    fig5a_with(mode, bidir, levels, &ParallelRunner::default())
}

/// [`fig5a`] with an explicit runner: the interference levels are
/// independent simulations, so they fan out across cores. Rows come back
/// in `levels` order and are bit-identical to a serial run.
pub fn fig5a_with(
    mode: LinkMode,
    bidir: bool,
    levels: &[u32],
    runner: &ParallelRunner,
) -> Vec<Fig5aRow> {
    let points = runner.run(levels, |_, &level| fig5a_point(mode, bidir, level));
    // Slowdown normalization replays the serial scan: the baseline is the
    // level-0 point once it has been seen, in `levels` order.
    let mut rows = Vec::new();
    let mut baseline_mean = 0.0;
    for (&level, &(mean, p99, max)) in levels.iter().zip(&points) {
        if level == 0 {
            baseline_mean = mean;
        }
        rows.push(Fig5aRow {
            mode,
            bidir,
            wide_outstanding: level,
            narrow_mean: mean,
            narrow_p99: p99,
            narrow_max: max,
            slowdown: if baseline_mean > 0.0 {
                mean / baseline_mean
            } else {
                1.0
            },
        });
    }
    rows
}

fn fig5a_point(mode: LinkMode, bidir: bool, wide_outstanding: u32) -> (f64, u64, u64) {
    let mut cfg = NocConfig::mesh(2, 1);
    cfg.mode = mode;
    let sys = NocSystem::new(cfg);
    let probe_src = 0usize;
    let probe_dst = NodeId(1);
    let mut profiles: Vec<TileTraffic> = (0..2).map(|_| TileTraffic::idle()).collect();
    profiles[probe_src].core = Some(GenCfg::narrow_probe(probe_dst, NUM_NARROW_TRANS));
    if wide_outstanding > 0 {
        let mk = |dst: NodeId| {
            let mut c = GenCfg::dma_burst(dst, u64::MAX, true);
            c.burst_len = BURST_LEN;
            c.max_outstanding = wide_outstanding;
            c
        };
        profiles[0].dma = Some(mk(NodeId(1)));
        if bidir {
            profiles[1].dma = Some(mk(NodeId(0)));
        }
    }
    let mut w = TiledWorkload::new(sys, profiles);
    // Run until the probe finishes (wide generators are unbounded and keep
    // the interference sustained the whole time).
    for _ in 0..2_000_000u64 {
        w.step();
        if w.tiles[probe_src]
            .core_gen
            .as_ref()
            .map(Generator::done)
            .unwrap_or(false)
        {
            break;
        }
    }
    let g = w.tiles[probe_src].core_gen.as_mut().unwrap();
    assert!(g.done(), "narrow probe starved: did not finish");
    assert!(g.monitor.ok(), "protocol violation under interference");
    (g.latencies.mean(), g.latencies.p99(), g.latencies.max())
}

/// One point of the Fig. 5b curve.
#[derive(Debug, Clone)]
pub struct Fig5bRow {
    /// Link configuration of this point.
    pub mode: LinkMode,
    /// Whether a reverse wide stream ran too.
    pub bidir: bool,
    /// Narrow interference: outstanding-transaction budget of the
    /// competing narrow streams (0 = none). The paper's x-axis is the
    /// number of interfering narrow transactions; a budget of N keeps N
    /// narrow transactions in flight continuously.
    pub narrow_outstanding: u32,
    /// Effective wide-link bandwidth utilization in [0, 1] at the link
    /// delivering the wide data.
    pub utilization: f64,
    /// Wide transfer makespan in cycles (NUM_WIDE_TRANS bursts).
    pub makespan: u64,
}

/// Fig. 5b: effective bandwidth utilization of `NUM_WIDE_TRANS` wide
/// write bursts under increasing narrow-transaction interference.
///
/// Cluster-to-cluster, like the paper: the DMA at tile 0 writes 1 kB
/// bursts to tile 1 while the cores of both tiles issue single-beat
/// narrow reads to each other. In the wide-only configuration the AW
/// headers and the narrow requests share the physical link with the
/// W-beat stream (and B/narrow-R share the response link), so effective
/// utilization starts below peak and degrades further with narrow
/// interference; the narrow-wide configuration keeps the wide link free
/// of small messages (Table I) and stays flat. `bidir` adds a reverse
/// DMA stream tile 1 → tile 0.
pub fn fig5b(mode: LinkMode, bidir: bool, levels: &[u32]) -> Vec<Fig5bRow> {
    fig5b_with(mode, bidir, levels, &ParallelRunner::default())
}

/// [`fig5b`] with an explicit runner (independent points, stable order).
pub fn fig5b_with(
    mode: LinkMode,
    bidir: bool,
    levels: &[u32],
    runner: &ParallelRunner,
) -> Vec<Fig5bRow> {
    let points = runner.run(levels, |_, &level| fig5b_point(mode, bidir, level));
    levels
        .iter()
        .zip(points)
        .map(|(&level, (util, makespan))| Fig5bRow {
            mode,
            bidir,
            narrow_outstanding: level,
            utilization: util,
            makespan,
        })
        .collect()
}

fn fig5b_point(mode: LinkMode, bidir: bool, narrow_outstanding: u32) -> (f64, u64) {
    let mut cfg = NocConfig::mesh(2, 1);
    cfg.mode = mode;
    let sys = NocSystem::new(cfg);
    let dma_tile = 0usize;
    let mut profiles: Vec<TileTraffic> = (0..2).map(|_| TileTraffic::idle()).collect();
    {
        let mut c = GenCfg::dma_burst(NodeId(1), NUM_WIDE_TRANS, true);
        c.burst_len = BURST_LEN;
        c.max_outstanding = 8;
        profiles[dma_tile].dma = Some(c);
    }
    if bidir {
        let mut c = GenCfg::dma_burst(NodeId(0), NUM_WIDE_TRANS, true);
        c.burst_len = BURST_LEN;
        c.max_outstanding = 8;
        profiles[1].dma = Some(c);
    }
    if narrow_outstanding > 0 {
        // Narrow interference from the cores of both tiles (the paper's
        // 9-core clusters sustain many outstanding single-word accesses).
        for t in 0..2usize {
            let mut c = GenCfg::narrow_probe(NodeId(1 - t as u16), u64::MAX);
            c.max_outstanding = narrow_outstanding;
            c.ids = 16;
            profiles[t].core = Some(c);
        }
    }
    let mut w = TiledWorkload::new(sys, profiles);
    let mut makespan = 0;
    for _ in 0..2_000_000u64 {
        w.step();
        if w.tiles[dma_tile]
            .dma_gen
            .as_ref()
            .map(Generator::done)
            .unwrap_or(false)
        {
            makespan = w.sys.now;
            break;
        }
    }
    let g = w.tiles[dma_tile].dma_gen.as_ref().unwrap();
    assert!(g.done(), "wide transfer never finished");
    assert!(g.monitor.ok());
    // Observe the link delivering the wide W data into tile 1.
    let meter = w.sys.wide_write_meter(NodeId(1));
    (meter.utilization(), makespan)
}

/// §VI-B: measured peak wide-link bandwidth — a single saturating DMA
/// read stream; returns (utilization, Gbps at `freq_ghz`).
pub fn peak_bandwidth(freq_ghz: f64) -> (f64, f64) {
    let mut cfg = NocConfig::mesh(2, 1);
    cfg.mode = LinkMode::NarrowWide;
    let sys = NocSystem::new(cfg);
    let mut profiles: Vec<TileTraffic> = (0..2).map(|_| TileTraffic::idle()).collect();
    let mut c = GenCfg::dma_burst(NodeId(1), 64, false);
    c.burst_len = BURST_LEN;
    c.max_outstanding = 8;
    profiles[0].dma = Some(c);
    let mut w = TiledWorkload::new(sys, profiles);
    assert!(w.run_to_completion(100_000));
    let meter = w.sys.wide_read_meter(NodeId(0));
    let util = meter.utilization();
    (util, util * 512.0 * freq_ghz)
}

/// §VI-D / Fig. 6b: run the single-1 kB-DMA power scenario and feed the
/// measured activity into the energy model.
pub fn fig6b_power() -> (PowerBreakdown, f64) {
    let sys = NocSystem::new(NocConfig::mesh(2, 1));
    let profiles = vec![TileTraffic::single_dma_1kib(NodeId(1)), TileTraffic::idle()];
    let mut w = TiledWorkload::new(sys, profiles);
    assert!(w.run_to_completion(10_000));
    assert!(w.protocol_ok());
    let model = EnergyModel::default();
    // Activity: flit-hops per network over the active window. The §VI-D
    // energy quantity counts one router crossing per beat ("across the
    // tile"), so normalize wide hops by the 2 routers on the path.
    let wide_hops = w.sys.router_flit_hops(NET_WIDE);
    let narrow_hops = w.sys.router_flit_hops(0) + w.sys.router_flit_hops(NET_RSP);
    let window = w
        .sys
        .eject_meters
        .iter()
        .flat_map(|per_node| per_node.iter())
        .map(|m| m.last_cycle)
        .max()
        .unwrap_or(w.sys.now)
        .max(1);
    let act = Activity {
        wide_flit_hops: wide_hops / 2,
        narrow_flit_hops: narrow_hops / 2,
        cycles: window,
        active_cores: 0,
    };
    let breakdown = model.power(&act);
    let pj_per_byte_hop = model.transfer_pj(1024, 1) / 1024.0;
    (breakdown, pj_per_byte_hop)
}

/// Ablation row: one (parameter, value) → measured outcome.
#[derive(Debug, Clone)]
pub struct AblationRow {
    /// Name of the swept parameter.
    pub param: &'static str,
    /// The parameter's value at this point.
    pub value: u64,
    /// The measured outcome (meaning depends on the ablation).
    pub metric: f64,
}

/// ROB-size ablation: wide-transfer makespan (lower is better) as the wide
/// ROB shrinks — shows why the paper sized it for 2 outstanding max bursts.
pub fn ablate_rob_size(slots_options: &[u32]) -> Vec<AblationRow> {
    ablate_rob_size_with(slots_options, &ParallelRunner::default())
}

/// [`ablate_rob_size`] with an explicit sweep runner.
pub fn ablate_rob_size_with(
    slots_options: &[u32],
    runner: &ParallelRunner,
) -> Vec<AblationRow> {
    runner.run(slots_options, |_, &slots| {
        let mut cfg = NocConfig::mesh(2, 1);
        cfg.wide_init.rob_slots = slots;
        let sys = NocSystem::new(cfg);
        let mut profiles: Vec<TileTraffic> =
            (0..2).map(|_| TileTraffic::idle()).collect();
        let mut c = GenCfg::dma_burst(NodeId(1), 16, false);
        c.burst_len = BURST_LEN;
        c.max_outstanding = 8;
        profiles[0].dma = Some(c);
        let mut w = TiledWorkload::new(sys, profiles);
        assert!(w.run_to_completion(1_000_000));
        AblationRow {
            param: "wide_rob_slots",
            value: slots as u64,
            metric: w.sys.now as f64,
        }
    })
}

/// Router input-buffer depth ablation: narrow mean latency under fixed
/// wide interference.
pub fn ablate_buffer_depth(depths: &[usize]) -> Vec<AblationRow> {
    ablate_buffer_depth_with(depths, &ParallelRunner::default())
}

/// [`ablate_buffer_depth`] with an explicit sweep runner.
pub fn ablate_buffer_depth_with(depths: &[usize], runner: &ParallelRunner) -> Vec<AblationRow> {
    runner.run(depths, |_, &d| {
        let mut cfg = NocConfig::mesh(4, 1);
        cfg.in_buf_depth = d;
        let sys = NocSystem::new(cfg);
        let mut profiles: Vec<TileTraffic> =
            (0..4).map(|_| TileTraffic::idle()).collect();
        profiles[1].core = Some(GenCfg::narrow_probe(NodeId(2), 50));
        let mut dma = GenCfg::dma_burst(NodeId(3), u64::MAX, true);
        dma.max_outstanding = 4;
        profiles[0].dma = Some(dma);
        let mut w = TiledWorkload::new(sys, profiles);
        for _ in 0..1_000_000u64 {
            w.step();
            if w.tiles[1].core_gen.as_ref().unwrap().done() {
                break;
            }
        }
        let g = w.tiles[1].core_gen.as_mut().unwrap();
        AblationRow {
            param: "in_buf_depth",
            value: d as u64,
            metric: g.latencies.mean(),
        }
    })
}

/// Burst-length ablation: wide effective utilization vs AxLEN.
pub fn ablate_burst_len(lens: &[u8]) -> Vec<AblationRow> {
    ablate_burst_len_with(lens, &ParallelRunner::default())
}

/// [`ablate_burst_len`] with an explicit sweep runner.
pub fn ablate_burst_len_with(lens: &[u8], runner: &ParallelRunner) -> Vec<AblationRow> {
    runner.run(lens, |_, &len| {
        let sys = NocSystem::new(NocConfig::mesh(2, 1));
        let mut profiles: Vec<TileTraffic> =
            (0..2).map(|_| TileTraffic::idle()).collect();
        let mut c = GenCfg::dma_burst(NodeId(1), 32, false);
        c.burst_len = len;
        c.max_outstanding = 8;
        profiles[0].dma = Some(c);
        let mut w = TiledWorkload::new(sys, profiles);
        assert!(w.run_to_completion(1_000_000));
        let util = w.sys.wide_read_meter(NodeId(0)).utilization();
        AblationRow {
            param: "burst_len",
            value: len as u64 + 1,
            metric: util,
        }
    })
}

/// Mesh-size scaling: aggregate delivered wide bandwidth with all tiles
/// DMA-reading from their +x neighbour (ring in each row).
pub fn scale_mesh(sizes: &[u8]) -> Vec<AblationRow> {
    scale_mesh_with(sizes, &ParallelRunner::default())
}

/// [`scale_mesh`] with an explicit sweep runner.
pub fn scale_mesh_with(sizes: &[u8], runner: &ParallelRunner) -> Vec<AblationRow> {
    runner.run(sizes, |_, &n| {
        let sys = NocSystem::new(NocConfig::mesh(n, n));
        let tiles = (n as usize) * (n as usize);
        let profiles = crate::dse::parallel::ring_profiles(n as usize, |_, dst| {
            let mut c = GenCfg::dma_burst(dst, 8, false);
            c.max_outstanding = 4;
            c
        });
        let mut w = TiledWorkload::new(sys, profiles);
        assert!(w.run_to_completion(2_000_000), "mesh {n} didn't drain");
        assert!(w.protocol_ok());
        // Total wide beats delivered / makespan = beats/cycle.
        let beats: u64 = (0..tiles)
            .map(|i| w.sys.wide_read_meter(NodeId(i as u16)).flits)
            .sum();
        AblationRow {
            param: "mesh_n",
            value: n as u64,
            metric: beats as f64 * 64.0 / w.sys.now as f64, // bytes/cycle
        }
    })
}

/// One row of the cross-topology comparison: the same tile count
/// deployed as a mesh, a torus and a ring.
#[derive(Debug, Clone)]
pub struct TopologyRow {
    /// The fabric this row measured.
    pub kind: TopologyKind,
    /// Tile count (identical across the three rows of one comparison).
    pub tiles: usize,
    /// Analytic mean router-to-router hop count over all ordered tile
    /// pairs — the expected hop count of uniform-random traffic
    /// ([`crate::topology::Topology::mean_tile_hops`]).
    pub mean_hops: f64,
    /// *Measured* mean hops: router traversals per delivered flit on the
    /// request network (includes the inject and eject traversals, so it
    /// sits `+1` above the router-to-router figure).
    pub measured_hops: f64,
    /// Delivered transactions per kilocycle (bisection-limited: the ring
    /// funnels all cross-traffic through 2 links, the mesh through `n`,
    /// the torus through `2n`).
    pub txns_per_kcycle: f64,
    /// Makespan until full drain (cycles).
    pub cycles: u64,
}

/// `scale_mesh`-style cross-topology comparison: deploy the **same tile
/// count** (`n² `) as an `n×n` mesh, an `n×n` torus and an `n²`-node
/// ring, drive identical uniform-random narrow read traffic on each,
/// and report analytic + measured hop counts and delivered throughput.
///
/// The wrap fabrics run with their default dateline virtual channels
/// (see `docs/deadlock.md`), so the generators use their full default
/// outstanding budgets — the pre-VC era's bounded-budget workaround
/// (`max_outstanding = 2` to stay out of the cyclic-wait regime) is
/// gone, and the throughput rows reflect genuinely loaded fabrics.
pub fn scale_topology(n: u8) -> Vec<TopologyRow> {
    scale_topology_with(n, &ParallelRunner::default())
}

/// [`scale_topology`] with an explicit sweep runner (the three fabrics
/// are independent simulations and fan out in parallel).
pub fn scale_topology_with(n: u8, runner: &ParallelRunner) -> Vec<TopologyRow> {
    let tiles = n as usize * n as usize;
    let mut kinds = vec![TopologyKind::Mesh, TopologyKind::Torus];
    // Only the ring deployment is bounded by u8 node ids; larger sizes
    // still get the mesh-vs-torus comparison.
    if tiles <= u8::MAX as usize {
        kinds.push(TopologyKind::Ring);
    }
    runner.run(&kinds, |_, &kind| {
        let cfg = match kind {
            TopologyKind::Mesh => NocConfig::mesh(n, n),
            TopologyKind::Torus => NocConfig::torus(n, n),
            TopologyKind::Ring => NocConfig::ring(tiles as u8),
        };
        let sys = NocSystem::new(cfg);
        let mean_hops = sys.topo.mean_tile_hops();
        let profiles: Vec<TileTraffic> = (0..tiles)
            .map(|i| {
                let mut c = GenCfg::narrow_probe(NodeId(0), 8);
                c.pattern = Pattern::UniformTiles;
                c.seed = 0x5CA1E + i as u64;
                TileTraffic {
                    core: Some(c),
                    dma: None,
                }
            })
            .collect();
        let mut w = TiledWorkload::new(sys, profiles);
        assert!(w.run_to_completion(5_000_000), "{} fabric did not drain", kind.name());
        assert!(w.protocol_ok());
        let cycles = w.sys.now.max(1);
        let delivered = w.sys.counters[NET_REQ].ejected.max(1);
        let measured_hops = w.sys.router_flit_hops(NET_REQ) as f64 / delivered as f64;
        let txns: u64 = w
            .tiles
            .iter()
            .map(|t| t.core_gen.as_ref().map(|g| g.completed).unwrap_or(0))
            .sum();
        TopologyRow {
            kind,
            tiles,
            mean_hops,
            measured_hops,
            txns_per_kcycle: txns as f64 * 1000.0 / cycles as f64,
            cycles,
        }
    })
}

/// VC-count ablation on the adaptive-routing axis: tornado makespan on
/// a 4×4 torus as lanes are added above the fabric's 2 dateline escape
/// lanes. At the escape minimum (`vcs = 2`) the fabric runs the
/// deterministic dimension-ordered baseline; every additional lane is
/// an adaptive lane ([`RoutingKind::Adaptive`]), letting heads spread
/// the tornado's tied-distance flows over both ring directions instead
/// of piling onto the deterministic direction (`docs/experiments.md`).
pub fn ablate_vcs(vcs_options: &[usize]) -> Vec<AblationRow> {
    ablate_vcs_with(vcs_options, &ParallelRunner::default())
}

/// [`ablate_vcs`] with an explicit sweep runner.
pub fn ablate_vcs_with(vcs_options: &[usize], runner: &ParallelRunner) -> Vec<AblationRow> {
    runner.run(vcs_options, |_, &vcs| {
        let mut cfg = NocConfig::torus(4, 4).with_vcs(vcs);
        // Lanes above the dateline requirement unlock adaptivity; at the
        // bare requirement the sweep point is the deterministic baseline.
        if vcs > cfg.topology.default_vcs() {
            cfg.routing = RoutingKind::Adaptive;
        }
        let sys = NocSystem::new(cfg);
        let tiles = sys.topo.num_tiles;
        let profiles: Vec<TileTraffic> = (0..tiles)
            .map(|i| {
                let mut c = GenCfg::dma_burst(NodeId(0), 16, false);
                c.pattern = Pattern::Tornado;
                c.burst_len = BURST_LEN;
                c.max_outstanding = 4;
                c.seed = 0x70AD0 + i as u64;
                TileTraffic {
                    core: None,
                    dma: Some(c),
                }
            })
            .collect();
        let mut w = TiledWorkload::new(sys, profiles);
        assert!(w.run_to_completion(5_000_000), "vcs={vcs} tornado did not drain");
        assert!(w.protocol_ok());
        AblationRow {
            param: "vcs",
            value: vcs as u64,
            metric: w.sys.now as f64,
        }
    })
}

/// Output-register (1- vs 2-cycle router) ablation on zero-load latency.
pub fn ablate_output_reg() -> Vec<AblationRow> {
    [false, true]
        .iter()
        .map(|&reg| {
            let mut cfg = NocConfig::mesh(2, 1);
            cfg.output_reg = reg;
            let mut sys = NocSystem::new(cfg);
            let mut g = Generator::new(GenCfg::narrow_probe(NodeId(1), 1), NodeId(0));
            sys.step_generator(&mut g);
            for _ in 0..100 {
                sys.step();
                sys.step_generator(&mut g);
                if g.done() {
                    break;
                }
            }
            assert!(g.done());
            AblationRow {
                param: "output_reg",
                value: reg as u64,
                metric: g.latencies.max() as f64,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_load_is_eighteen() {
        assert_eq!(zero_load_latency(LinkMode::NarrowWide), 18);
    }

    /// The core Fig. 5a claim: narrow-wide stays flat, wide-only degrades
    /// severely (paper: up to 5×).
    #[test]
    fn fig5a_shape_holds() {
        let nw = fig5a(LinkMode::NarrowWide, true, &[0, 8]);
        let wo = fig5a(LinkMode::WideOnly, true, &[0, 8]);
        assert!(
            nw[1].slowdown < 1.3,
            "narrow-wide must be robust, got {:.2}x",
            nw[1].slowdown
        );
        assert!(
            wo[1].slowdown > 1.8,
            "wide-only must degrade clearly, got {:.2}x",
            wo[1].slowdown
        );
        assert!(wo[1].slowdown > nw[1].slowdown * 1.5);
    }

    /// The core Fig. 5b claim: narrow-wide sustains high utilization under
    /// narrow interference; wide-only starts below peak (AW self-overhead
    /// on the shared link) and loses further bandwidth.
    #[test]
    fn fig5b_shape_holds() {
        let nw = fig5b(LinkMode::NarrowWide, false, &[0, 32]);
        let wo = fig5b(LinkMode::WideOnly, false, &[0, 32]);
        assert!(
            nw[1].utilization > 0.9,
            "narrow-wide stays near peak (paper: 85 %, robust), got {:.2}",
            nw[1].utilization
        );
        assert!(
            wo[0].utilization < 0.97,
            "wide-only pays AW overhead even uncontended: {:.2}",
            wo[0].utilization
        );
        assert!(
            wo[1].utilization < nw[1].utilization - 0.08,
            "wide-only must lose utilization: {:.2} vs {:.2}",
            wo[1].utilization,
            nw[1].utilization
        );
        assert!(wo[1].utilization < wo[0].utilization - 0.03, "degrades with interference");
    }

    #[test]
    fn peak_bandwidth_near_line_rate() {
        let (util, gbps) = peak_bandwidth(1.23);
        assert!(util > 0.8, "sustained stream ≈ line rate, got {util:.2}");
        assert!(gbps > 500.0, "≈629 Gbps peak, got {gbps:.0}");
    }

    #[test]
    fn fig6b_reproduces_headlines() {
        let (p, pjb) = fig6b_power();
        assert!((130.0..=150.0).contains(&p.total_mw), "{:.1} mW", p.total_mw);
        assert!((0.04..=0.10).contains(&p.noc_fraction));
        assert!((pjb - 0.19).abs() < 0.01);
    }

    /// The acceptance check of the topology axis: at equal tile count,
    /// uniform-random traffic on a torus takes strictly fewer hops than
    /// on a mesh — analytically (expected hops over all pairs) *and* as
    /// measured from router activity of the live uniform-random run.
    #[test]
    fn scale_topology_torus_beats_mesh_on_hops() {
        let rows = scale_topology_with(4, &ParallelRunner::serial());
        assert_eq!(rows.len(), 3);
        assert!(rows.iter().all(|r| r.tiles == 16), "equal tile count");
        let get = |k: TopologyKind| rows.iter().find(|r| r.kind == k).unwrap();
        let mesh = get(TopologyKind::Mesh);
        let torus = get(TopologyKind::Torus);
        let ring = get(TopologyKind::Ring);
        assert!(
            torus.mean_hops < mesh.mean_hops,
            "torus {:.3} !< mesh {:.3}",
            torus.mean_hops,
            mesh.mean_hops
        );
        assert!(
            torus.measured_hops < mesh.measured_hops,
            "measured: torus {:.3} !< mesh {:.3}",
            torus.measured_hops,
            mesh.measured_hops
        );
        // The ring pays for its 2-link bisection with the longest paths.
        assert!(ring.mean_hops > mesh.mean_hops);
        assert!(rows.iter().all(|r| r.txns_per_kcycle > 0.0));
    }

    /// Ring zero-load: one wraparound hop costs exactly what one mesh
    /// hop costs — the paper's 18-cycle adjacent-tile figure — while the
    /// same endpoints on a chain without the wrap link pay 2 extra hops.
    #[test]
    fn ring_zero_load_wrap_matches_adjacent() {
        let ring_far = zero_load_latency_on(NocConfig::ring(4), NodeId(0), NodeId(3));
        let ring_adj = zero_load_latency_on(NocConfig::ring(4), NodeId(0), NodeId(1));
        let mesh_far = zero_load_latency_on(NocConfig::mesh(4, 1), NodeId(0), NodeId(3));
        assert_eq!(ring_adj, 18);
        assert_eq!(ring_far, 18, "0 -> 3 is one wrap hop on a 4-ring");
        assert!(mesh_far > ring_far, "the chain pays per extra hop");
    }

    /// The vcs sweep runs both regimes of its axis — the deterministic
    /// baseline at the dateline minimum and an adaptive point above it —
    /// on the same tornado workload, and both drain.
    #[test]
    fn vcs_ablation_covers_both_routing_regimes() {
        let rows = ablate_vcs_with(&[2, 3], &ParallelRunner::serial());
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|r| r.param == "vcs" && r.metric > 0.0));
        assert_eq!((rows[0].value, rows[1].value), (2, 3));
    }

    #[test]
    fn rob_ablation_monotone() {
        let rows = ablate_rob_size(&[16, 128]);
        // Smaller ROB => longer makespan (flow control throttles).
        assert!(rows[0].metric > rows[1].metric);
    }
}
