//! Experiment coordination: every table and figure of the paper's
//! evaluation as a runnable, parameterized experiment.
//!
//! See DESIGN.md §4 for the experiment index. Each function returns plain
//! row structs that [`crate::report`] renders as the paper's tables/series
//! and that EXPERIMENTS.md records as paper-vs-measured.

pub mod experiments;

pub use experiments::*;
