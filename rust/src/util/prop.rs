//! Minimal property-based testing driver (proptest substitute).
//!
//! `proptest` is not in the offline crate snapshot, so this module provides
//! the subset the test suite needs: seeded case generation, a configurable
//! number of cases, and reproducible failure reporting (the failing seed is
//! printed so a case can be replayed by pinning `PropConfig::seed`).
//!
//! No shrinking — generators are encouraged to produce small cases with
//! reasonable probability instead.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct PropConfig {
    /// Number of random cases to execute.
    pub cases: u32,
    /// Base seed; case `i` runs with seed `base_seed + i`.
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        // Respect PROP_CASES / PROP_SEED env so CI can dial effort up/down
        // and failures can be replayed.
        let cases = std::env::var("PROP_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(256);
        let seed = std::env::var("PROP_SEED")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0xF100_0C0D);
        PropConfig { cases, seed }
    }
}

/// Run `property` over `cases` seeded RNGs; panic with the failing seed on
/// the first failure. The property signals failure by returning `Err`.
pub fn check<F>(name: &str, cfg: &PropConfig, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let seed = cfg.seed.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (replay with \
                 PROP_SEED={seed} PROP_CASES=1): {msg}"
            );
        }
    }
}

/// Convenience: run with the default config.
pub fn check_default<F>(name: &str, property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    check(name, &PropConfig::default(), property);
}

/// Assertion helper for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Equality assertion helper for use inside properties.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_default("add-commutes", |rng| {
            let a = rng.below(1000) as i64;
            let b = rng.below(1000) as i64;
            prop_assert!(a + b == b + a, "commutativity {a} {b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check(
            "always-fails",
            &PropConfig { cases: 3, seed: 1 },
            |_| Err("nope".to_string()),
        );
    }

    #[test]
    fn cases_are_deterministic() {
        let mut seen = Vec::new();
        check(
            "collect",
            &PropConfig { cases: 5, seed: 99 },
            |rng| {
                seen.push(rng.next_u64());
                Ok(())
            },
        );
        let mut again = Vec::new();
        check(
            "collect2",
            &PropConfig { cases: 5, seed: 99 },
            |rng| {
                again.push(rng.next_u64());
                Ok(())
            },
        );
        assert_eq!(seen, again);
    }
}
