//! Tiny benchmark harness (criterion substitute for the offline build).
//!
//! `cargo bench` targets in this crate use `harness = false` and drive this
//! module directly: warmup, N timed repetitions, median/p10/p90 reporting,
//! and a machine-readable one-line summary that EXPERIMENTS.md references.

use std::time::{Duration, Instant};

/// One benchmark measurement series.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark name.
    pub name: String,
    /// Per-iteration wall time, sorted ascending.
    pub samples_ns: Vec<u64>,
    /// Optional throughput denominator (items per iteration).
    pub items_per_iter: Option<u64>,
}

impl BenchResult {
    /// Median per-iteration wall time.
    pub fn median_ns(&self) -> u64 {
        self.samples_ns[self.samples_ns.len() / 2]
    }

    /// 10th-percentile per-iteration wall time.
    pub fn p10_ns(&self) -> u64 {
        self.samples_ns[self.samples_ns.len() / 10]
    }

    /// 90th-percentile per-iteration wall time.
    pub fn p90_ns(&self) -> u64 {
        self.samples_ns[self.samples_ns.len() * 9 / 10]
    }

    /// items/s at the median, when a throughput denominator was given.
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter
            .map(|n| n as f64 / (self.median_ns() as f64 * 1e-9))
    }

    /// Human-readable single line.
    pub fn line(&self) -> String {
        let med = fmt_ns(self.median_ns());
        let p10 = fmt_ns(self.p10_ns());
        let p90 = fmt_ns(self.p90_ns());
        match self.throughput() {
            Some(tp) => format!(
                "{:<44} median {:>10}  [{} .. {}]  {:>12}/s",
                self.name,
                med,
                p10,
                p90,
                fmt_count(tp)
            ),
            None => format!(
                "{:<44} median {:>10}  [{} .. {}]",
                self.name, med, p10, p90
            ),
        }
    }
}

fn fmt_ns(ns: u64) -> String {
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.2} s", ns as f64 / 1e9)
    }
}

fn fmt_count(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2} G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2} M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2} k", x / 1e3)
    } else {
        format!("{x:.1}")
    }
}

/// Bench runner with fixed warmup/sample counts.
pub struct Bencher {
    /// Untimed warmup iterations before sampling.
    pub warmup: u32,
    /// Timed samples per benchmark.
    pub samples: u32,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        // BENCH_SAMPLES lets CI shrink bench time.
        let samples = std::env::var("BENCH_SAMPLES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(20);
        Bencher {
            warmup: 3,
            samples,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    /// A bencher with explicit warmup/sample counts.
    pub fn new(warmup: u32, samples: u32) -> Self {
        Bencher {
            warmup,
            samples,
            results: Vec::new(),
        }
    }

    /// Time `f` (which should perform one full iteration of work), with
    /// `items` the number of logical items processed per iteration (for
    /// throughput reporting).
    pub fn bench<F: FnMut()>(&mut self, name: &str, items: Option<u64>, mut f: F) {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.samples as usize);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_nanos() as u64);
        }
        samples.sort();
        let r = BenchResult {
            name: name.to_string(),
            samples_ns: samples,
            items_per_iter: items,
        };
        println!("{}", r.line());
        self.results.push(r);
    }

    /// Access collected results (e.g. to dump JSON).
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Measure a single closure once, returning its duration. Used by the
/// experiment harness for coarse end-to-end timings.
pub fn time_once<F: FnOnce()>(f: F) -> Duration {
    let t0 = Instant::now();
    f();
    t0.elapsed()
}

/// One simulator-throughput measurement: how many simulated cycles per
/// wall-clock second a step loop sustains.
#[derive(Debug, Clone, Copy)]
pub struct CpsResult {
    /// Simulated cycles executed.
    pub cycles: u64,
    /// Wall-clock time taken.
    pub wall_seconds: f64,
}

impl CpsResult {
    /// Simulated cycles per wall-clock second.
    pub fn cycles_per_second(&self) -> f64 {
        if self.wall_seconds > 0.0 {
            self.cycles as f64 / self.wall_seconds
        } else {
            0.0
        }
    }
}

/// Time `cycles` invocations of `step` (one simulated cycle each).
pub fn measure_cps<F: FnMut()>(cycles: u64, mut step: F) -> CpsResult {
    let t0 = Instant::now();
    for _ in 0..cycles {
        step();
    }
    CpsResult {
        cycles,
        wall_seconds: t0.elapsed().as_secs_f64(),
    }
}

/// The calibrated throughput floor for a named gate, if one is pinned.
///
/// Resolution order (first hit wins):
///
/// 1. `CPS_FLOOR_<NAME>` — per-gate floor; `<NAME>` is the gate name
///    uppercased with every non-alphanumeric character mapped to `_`
///    (so gate `4x4-saturated` reads `CPS_FLOOR_4X4_SATURATED`);
/// 2. `CPS_FLOOR` — one conservative floor for every gate.
///
/// CI pins the value measured on its own runner class (see the
/// `bench-smoke` job in `.github/workflows/ci.yml` and the calibration
/// notes in `docs/performance.md`); developer machines leave it unset
/// and the gate only reports. A floor that is set but unparsable
/// panics — silently disabling the gate would ship regressions while
/// CI believes it's enforced.
pub fn cps_floor(name: &str) -> Option<f64> {
    let sanitized: String = name
        .chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() {
                c.to_ascii_uppercase()
            } else {
                '_'
            }
        })
        .collect();
    for var in [format!("CPS_FLOOR_{sanitized}"), "CPS_FLOOR".to_string()] {
        if let Ok(raw) = std::env::var(&var) {
            let floor: f64 = raw
                .trim()
                .parse()
                .unwrap_or_else(|e| panic!("{var} {raw:?} is not a number: {e}"));
            return Some(floor);
        }
    }
    None
}

/// Cycles-per-second regression gate: measures, prints one
/// machine-readable line (`cps_gate name=<n> cycles_per_second=<v>`), and
/// panics if a floor is pinned (see [`cps_floor`]) and the measurement
/// falls below it. Benches run with `harness = false`, so the panic
/// makes `cargo bench` exit non-zero — CI can pin a throughput floor
/// without a criterion dependency.
pub fn cps_gate<F: FnMut()>(name: &str, cycles: u64, step: F) -> CpsResult {
    let r = measure_cps(cycles, step);
    let floor = cps_floor(name);
    println!(
        "cps_gate name={name} cycles={} wall_s={:.4} cycles_per_second={:.0} floor={}",
        r.cycles,
        r.wall_seconds,
        r.cycles_per_second(),
        floor.map(|f| format!("{f:.0}")).unwrap_or_else(|| "unset".into()),
    );
    if let Some(floor) = floor {
        assert!(
            r.cycles_per_second() >= floor,
            "cps regression: {name} ran at {:.0} cycles/s, floor is {floor:.0}",
            r.cycles_per_second()
        );
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_records() {
        let mut b = Bencher::new(1, 5);
        let mut count = 0u64;
        b.bench("noop", Some(1), || {
            count += 1;
        });
        assert_eq!(count, 6); // 1 warmup + 5 samples
        assert_eq!(b.results().len(), 1);
        assert!(b.results()[0].throughput().unwrap() > 0.0);
    }

    #[test]
    fn percentiles_ordered() {
        let r = BenchResult {
            name: "x".into(),
            samples_ns: (1..=100).collect(),
            items_per_iter: None,
        };
        assert!(r.p10_ns() <= r.median_ns());
        assert!(r.median_ns() <= r.p90_ns());
    }

    #[test]
    fn formatting_units() {
        assert_eq!(fmt_ns(500), "500 ns");
        assert!(fmt_ns(1_500).contains("µs"));
        assert!(fmt_ns(2_000_000).contains("ms"));
        assert!(fmt_ns(3_000_000_000).contains(" s"));
    }

    #[test]
    fn time_once_positive() {
        let d = time_once(|| {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(d.as_nanos() > 0);
    }

    #[test]
    fn cps_counts_every_cycle() {
        let mut n = 0u64;
        let r = measure_cps(1_000, || n += 1);
        assert_eq!(n, 1_000);
        assert_eq!(r.cycles, 1_000);
        assert!(r.cycles_per_second() > 0.0);
    }

    #[test]
    fn cps_gate_passes_without_floor() {
        // CPS_FLOOR is unset in unit tests; the gate must only report.
        let r = cps_gate("unit", 100, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.cycles, 100);
    }

    #[test]
    fn cps_floor_resolves_per_gate_then_global() {
        // Env mutation is process-global: keep all floor-env cases in this
        // one test to avoid racing parallel test threads on the same vars.
        std::env::set_var("CPS_FLOOR_4X4_SATURATED", "123.5");
        std::env::set_var("CPS_FLOOR", "7");
        assert_eq!(cps_floor("4x4-saturated"), Some(123.5), "per-gate wins");
        assert_eq!(cps_floor("other-gate"), Some(7.0), "global fallback");
        std::env::remove_var("CPS_FLOOR_4X4_SATURATED");
        std::env::remove_var("CPS_FLOOR");
        assert_eq!(cps_floor("4x4-saturated"), None, "unset means uncalibrated");
    }
}
