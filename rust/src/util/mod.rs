//! Offline-environment substrates: deterministic PRNG, JSON, a minimal
//! property-testing driver, and a bench timing harness.
//!
//! These exist because the build environment resolves crates only from a
//! vendored snapshot that lacks `rand`, `serde`, `proptest` and `criterion`
//! (see DESIGN.md §1 "Offline-toolchain substitutions").

pub mod rng;
pub mod json;
pub mod prop;
pub mod bench;
pub mod fifo;
pub mod activeset;
pub mod calendar;
