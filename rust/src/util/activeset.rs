//! Fixed-size bitmap of active component indices — the simulator's model
//! of clock gating.
//!
//! The activity-gated step loop (see `docs/performance.md`) keeps one
//! [`ActiveSet`] per component class per network: a bit per link and a
//! bit per router. A component is *stepped* only while its bit is set;
//! everything else is skipped exactly as a clock-gated hardware block
//! holds its state. Correctness rests on a single invariant maintained
//! by the wake edges: **every component whose step would not be a no-op
//! has its bit set.** The set may conservatively contain quiescent
//! components (they step as no-ops and are pruned), but never the
//! reverse.
//!
//! Iteration is in ascending index order over `u64` words with
//! `trailing_zeros`, so a sweep over the set is deterministic and costs
//! O(words + set bits) rather than O(components).

/// A bitmap over `0..len` component indices.
#[derive(Debug, Clone)]
pub struct ActiveSet {
    words: Vec<u64>,
    len: usize,
}

impl ActiveSet {
    /// An empty set over the index domain `0..len`.
    pub fn new(len: usize) -> Self {
        ActiveSet {
            // (len + 63) / 64 — `div_ceil` needs Rust 1.73, MSRV is 1.70.
            words: vec![0; (len + 63) / 64],
            len,
        }
    }

    /// Size of the index domain (not the number of set bits).
    #[inline]
    pub fn domain(&self) -> usize {
        self.len
    }

    /// Mark `idx` active. Idempotent; returns true when the bit was
    /// newly set (an actual wake-up edge, useful for instrumentation).
    #[inline]
    pub fn insert(&mut self, idx: usize) -> bool {
        debug_assert!(idx < self.len, "index {idx} outside domain {}", self.len);
        let w = &mut self.words[idx >> 6];
        let bit = 1u64 << (idx & 63);
        let newly = *w & bit == 0;
        *w |= bit;
        newly
    }

    /// Clear `idx` (component went quiescent).
    #[inline]
    pub fn remove(&mut self, idx: usize) {
        debug_assert!(idx < self.len, "index {idx} outside domain {}", self.len);
        self.words[idx >> 6] &= !(1u64 << (idx & 63));
    }

    /// Is `idx` active?
    #[inline]
    pub fn contains(&self, idx: usize) -> bool {
        debug_assert!(idx < self.len, "index {idx} outside domain {}", self.len);
        self.words[idx >> 6] & (1u64 << (idx & 63)) != 0
    }

    /// Deactivate everything.
    #[inline]
    pub fn clear(&mut self) {
        self.words.fill(0);
    }

    /// True when no component is active.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    /// Number of active components (popcount).
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Number of backing words (for word-wise sweeps).
    #[inline]
    pub fn num_words(&self) -> usize {
        self.words.len()
    }

    /// The `i`-th backing word. Sweeps copy a word, then walk its set
    /// bits with `trailing_zeros` while mutating the set itself — safe
    /// as long as the sweep only *clears* bits it has already visited
    /// (wake-ups during a sweep land in a different set or in bits the
    /// copied word no longer observes, by construction of the two-phase
    /// step loop).
    #[inline]
    pub fn word(&self, i: usize) -> u64 {
        self.words[i]
    }

    /// Iterate active indices in ascending order.
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &word)| {
            std::iter::successors(
                if word != 0 { Some(word) } else { None },
                |w| {
                    let next = w & (w - 1);
                    if next != 0 {
                        Some(next)
                    } else {
                        None
                    }
                },
            )
            .map(move |w| (wi << 6) + w.trailing_zeros() as usize)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_contains_remove() {
        let mut s = ActiveSet::new(200);
        assert!(s.is_empty());
        assert!(s.insert(0));
        assert!(s.insert(63));
        assert!(s.insert(64));
        assert!(s.insert(199));
        assert!(!s.insert(64), "re-insert is not a wake edge");
        assert!(s.contains(63) && s.contains(64) && s.contains(199));
        assert!(!s.contains(1));
        assert_eq!(s.count(), 4);
        s.remove(63);
        assert!(!s.contains(63));
        assert_eq!(s.count(), 3);
    }

    #[test]
    fn iterates_ascending_across_words() {
        let mut s = ActiveSet::new(300);
        for &i in &[5usize, 0, 255, 64, 63, 128, 299] {
            s.insert(i);
        }
        let got: Vec<usize> = s.iter().collect();
        assert_eq!(got, vec![0, 5, 63, 64, 128, 255, 299]);
    }

    #[test]
    fn clear_empties() {
        let mut s = ActiveSet::new(70);
        s.insert(3);
        s.insert(69);
        s.clear();
        assert!(s.is_empty());
        assert_eq!(s.count(), 0);
        assert_eq!(s.iter().count(), 0);
    }

    #[test]
    fn word_sweep_matches_iter() {
        let mut s = ActiveSet::new(130);
        for i in (0..130).step_by(7) {
            s.insert(i);
        }
        let mut via_words = Vec::new();
        for wi in 0..s.num_words() {
            let mut w = s.word(wi);
            while w != 0 {
                via_words.push((wi << 6) + w.trailing_zeros() as usize);
                w &= w - 1;
            }
        }
        let via_iter: Vec<usize> = s.iter().collect();
        assert_eq!(via_words, via_iter);
    }

    #[test]
    fn empty_domain_is_fine() {
        let s = ActiveSet::new(0);
        assert!(s.is_empty());
        assert_eq!(s.num_words(), 0);
        assert_eq!(s.iter().count(), 0);
    }
}
