//! Deterministic, seedable PRNG: xoshiro256** by Blackman & Vigna.
//!
//! A simulator must be reproducible run-to-run; a hand-rolled xoshiro
//! keeps every experiment deterministic given its seed and removes the
//! dependency on the (unavailable) `rand` crate.

/// xoshiro256** state. All experiment randomness flows through this.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

/// SplitMix64, used to expand a single `u64` seed into xoshiro state.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Mix a base seed with a salt into an independent stream seed (SplitMix64
/// finalizer). Used for deterministic per-point seeding in parallel sweeps:
/// the derived seed depends only on `(base, salt)`, never on execution
/// order, so serial and parallel runs see identical streams.
pub fn mix_seed(base: u64, salt: u64) -> u64 {
    let mut s = base ^ salt.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    splitmix64(&mut s)
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64 expansion).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `u32`.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` using Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0) is meaningless");
        // 128-bit multiply keeps the distribution unbiased.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform `usize` in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform float in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Geometric-ish packet inter-arrival: number of idle cycles before the
    /// next injection at offered rate `rate` (packets/cycle, 0 < rate <= 1).
    pub fn bernoulli_gap(&mut self, rate: f64) -> u64 {
        if rate >= 1.0 {
            return 0;
        }
        let mut gap = 0u64;
        while !self.chance(rate) {
            gap += 1;
            if gap > 1_000_000 {
                break; // degenerate rates: cap to keep sims finite
            }
        }
        gap
    }

    /// Derive an independent child generator (stream-splitting).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA076_1D64_78BD_642F)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean} should be ~0.5");
    }

    #[test]
    fn range_inclusive_bounds() {
        let mut r = Rng::new(9);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            let v = r.range(5, 8);
            assert!((5..=8).contains(&v));
            lo_seen |= v == 5;
            hi_seen |= v == 8;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffle changed order");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::new(5);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn bernoulli_gap_rate_one_is_zero() {
        let mut r = Rng::new(13);
        for _ in 0..100 {
            assert_eq!(r.bernoulli_gap(1.0), 0);
        }
    }

    #[test]
    fn bernoulli_gap_mean_matches_rate() {
        let mut r = Rng::new(17);
        let rate = 0.25;
        let n = 20_000;
        let mean: f64 =
            (0..n).map(|_| r.bernoulli_gap(rate) as f64).sum::<f64>() / n as f64;
        // E[gap] = (1-p)/p = 3 for p=0.25
        assert!((mean - 3.0).abs() < 0.15, "mean {mean}");
    }
}
