//! Event calendar for the event-driven simulation mode
//! ([`crate::sim::SimMode::Event`]).
//!
//! A [`Calendar`] is a min-heap of future wake times: components that can
//! become active *spontaneously* (a memory operation retiring after its
//! fixed latency, a generator's next issue window opening) schedule the
//! cycle at which they next need to be stepped. When every active set is
//! empty and every NI is provably quiet, the system fast-forwards `now`
//! to the earliest scheduled entry instead of ticking through dead
//! cycles (see `docs/performance.md`, "Event-driven fast-forward").
//!
//! Entries are *hints*, not obligations: the fast-forward path re-checks
//! real component state before and after every jump, so a stale entry
//! (e.g. a memory op that was popped before its scheduled cycle came up)
//! costs at most one wasted — provably no-op — stepped cycle. Entries at
//! or before the current cycle are discarded by [`Calendar::prune_through`]
//! once the caller has verified no component head is actually ready.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Min-heap of scheduled wake cycles. Duplicates are allowed (several
/// memory accepts in one cycle share a retirement time); they cost one
/// heap slot each and are drained together by pruning.
#[derive(Debug, Default, Clone)]
pub struct Calendar {
    heap: BinaryHeap<Reverse<u64>>,
}

impl Calendar {
    /// Empty calendar.
    pub fn new() -> Self {
        Calendar {
            heap: BinaryHeap::new(),
        }
    }

    /// Schedule a wake at cycle `at`.
    pub fn schedule(&mut self, at: u64) {
        self.heap.push(Reverse(at));
    }

    /// Earliest scheduled cycle, if any.
    pub fn earliest(&self) -> Option<u64> {
        self.heap.peek().map(|Reverse(t)| *t)
    }

    /// Drop every entry scheduled at or before `now`. Callers must have
    /// verified first that no component is actually ready at `now` —
    /// then entries ≤ `now` are provably stale (their ops already
    /// retired and were popped).
    pub fn prune_through(&mut self, now: u64) {
        while let Some(Reverse(t)) = self.heap.peek() {
            if *t > now {
                break;
            }
            self.heap.pop();
        }
    }

    /// Number of scheduled entries (duplicates counted).
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// No entries scheduled.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Drop all entries.
    pub fn clear(&mut self) {
        self.heap.clear();
    }

    /// Absorb every entry of `other` (duplicates kept, as always). The
    /// sharded engine gives each shard a private calendar during a run
    /// and folds them back into the system's single calendar here — a
    /// heap merge, so relative ordering of wake times is preserved
    /// regardless of which shard scheduled them.
    pub fn merge_from(&mut self, other: Calendar) {
        self.heap.extend(other.heap);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn earliest_is_min_regardless_of_insert_order() {
        let mut c = Calendar::new();
        assert_eq!(c.earliest(), None);
        c.schedule(50);
        c.schedule(10);
        c.schedule(30);
        assert_eq!(c.earliest(), Some(10));
        assert_eq!(c.len(), 3);
    }

    #[test]
    fn duplicates_are_kept_and_pruned_together() {
        let mut c = Calendar::new();
        c.schedule(7);
        c.schedule(7);
        c.schedule(9);
        assert_eq!(c.len(), 3);
        c.prune_through(7);
        assert_eq!(c.earliest(), Some(9));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn prune_through_is_inclusive_and_stops_at_future() {
        let mut c = Calendar::new();
        c.schedule(3);
        c.schedule(5);
        c.schedule(8);
        c.prune_through(5);
        assert_eq!(c.earliest(), Some(8));
        c.prune_through(100);
        assert!(c.is_empty());
        // Pruning an empty calendar is a no-op.
        c.prune_through(200);
        assert!(c.is_empty());
    }

    #[test]
    fn merge_from_keeps_every_entry_and_the_global_min() {
        let mut a = Calendar::new();
        a.schedule(40);
        a.schedule(12);
        let mut b = Calendar::new();
        b.schedule(7);
        b.schedule(40); // duplicate across calendars survives
        a.merge_from(b);
        assert_eq!(a.len(), 4);
        assert_eq!(a.earliest(), Some(7));
        a.prune_through(12);
        assert_eq!(a.earliest(), Some(40));
        assert_eq!(a.len(), 2);
        // Merging an empty calendar changes nothing.
        a.merge_from(Calendar::new());
        assert_eq!(a.len(), 2);
    }

    #[test]
    fn clear_empties() {
        let mut c = Calendar::new();
        c.schedule(1);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.earliest(), None);
    }
}
