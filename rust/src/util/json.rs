//! Minimal JSON value model, parser and serializer.
//!
//! Hand-rolled because `serde`/`serde_json` are not in the offline crate
//! snapshot. Supports the full JSON grammar minus `\u` surrogate pairs
//! beyond the BMP (sufficient for configs and metric dumps, which are
//! ASCII). Used by the config system ([`crate::config`]) and by metric
//! exports.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (f64, like JavaScript).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object with sorted keys.
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug)]
pub struct JsonError {
    /// Byte offset of the error.
    pub at: usize,
    /// What went wrong.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ------------------------------------------------------------ accessors

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Non-negative integer value, if exactly representable.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 && f <= u64::MAX as f64 {
                Some(f as u64)
            } else {
                None
            }
        })
    }

    /// [`Self::as_u64`] narrowed to `usize`.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    /// Boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// String slice, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Array slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Key map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Parse a JSON document from a string.
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: input.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

// ------------------------------------------------------------------ parser

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            at: self.i,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.i += 1;
        }
        c
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self
                                .bump()
                                .and_then(|c| (c as char).to_digit(16))
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16 + d;
                        }
                        out.push(
                            char::from_u32(code)
                                .ok_or_else(|| self.err("invalid codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8 starting at i-1.
                    let start = self.i - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("invalid utf8")),
                    };
                    if start + len > self.b.len() {
                        return Err(self.err("truncated utf8"));
                    }
                    let s = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|_| self.err("invalid utf8"))?;
                    out.push_str(s);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// -------------------------------------------------------------- serializer

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Infinity literals; emitting them
                    // produces output no conforming parser (including
                    // ours) accepts. `null` is the interchange-safe
                    // encoding. NaN also fails every guard below
                    // (NaN.fract() is NaN), so this arm must come first.
                    write!(f, "null")
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    // Small integral values print without a fraction. The
                    // 1e15 bound keeps the `as i64` cast exact (every
                    // integral f64 below it fits losslessly); larger
                    // magnitudes take the float path instead of casting —
                    // `f64`'s Display never uses scientific notation, so
                    // that path is valid JSON at any magnitude.
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Pretty-print with two-space indentation (for human-readable dumps).
pub fn pretty(v: &Json) -> String {
    let mut out = String::new();
    pp(v, 0, &mut out);
    out
}

fn pp(v: &Json, depth: usize, out: &mut String) {
    let pad = "  ".repeat(depth + 1);
    let close = "  ".repeat(depth);
    match v {
        Json::Arr(a) if !a.is_empty() => {
            out.push_str("[\n");
            for (i, x) in a.iter().enumerate() {
                out.push_str(&pad);
                pp(x, depth + 1, out);
                if i + 1 < a.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            out.push_str(&close);
            out.push(']');
        }
        Json::Obj(o) if !o.is_empty() => {
            out.push_str("{\n");
            for (i, (k, x)) in o.iter().enumerate() {
                out.push_str(&pad);
                out.push_str(&format!("{}", Json::Str(k.clone())));
                out.push_str(": ");
                pp(x, depth + 1, out);
                if i + 1 < o.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            out.push_str(&close);
            out.push('}');
        }
        other => out.push_str(&other.to_string()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"hi\\n\"").unwrap(),
            Json::Str("hi\n".to_string())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap(),
            &Json::Str("c".into())
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u0041\\u00e9\"").unwrap(),
            Json::Str("Aé".to_string())
        );
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo → wörld\"").unwrap();
        assert_eq!(v, Json::Str("héllo → wörld".to_string()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"b":false,"n":null,"o":{"k":"v"}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn pretty_roundtrip() {
        let v = Json::obj(vec![
            ("a", Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)])),
            ("b", Json::Str("x".into())),
        ]);
        let p = pretty(&v);
        assert_eq!(Json::parse(&p).unwrap(), v);
        assert!(p.contains('\n'));
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }

    #[test]
    fn non_finite_serializes_as_null() {
        // JSON has no NaN/Infinity literal; the serializer must not
        // emit one (a literal `NaN` broke BENCH_e2e.json consumers).
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Json::Num(f64::NEG_INFINITY).to_string(), "null");
        // Nested positions and the pretty-printer take the same path.
        let v = Json::obj(vec![("x", Json::Num(f64::NAN))]);
        assert_eq!(v.to_string(), r#"{"x":null}"#);
        assert_eq!(Json::parse(&pretty(&v)).unwrap().get("x"), Some(&Json::Null));
    }

    #[test]
    fn large_magnitudes_roundtrip_exactly() {
        // Above the exact-i64-cast bound the serializer must not cast
        // (2^63 `as i64` is garbage); every printed form must re-parse
        // to the identical f64.
        for &n in &[
            9.223372036854776e18, // 2^63: first value the old cast mangled
            1e15,                 // first value past the integer fast path
            -1e15,
            f64::MAX, // full-range extreme
            -f64::MAX,
            4.9e-324, // smallest subnormal
            123456789.123,
        ] {
            let s = Json::Num(n).to_string();
            assert!(
                !s.contains('e') && !s.contains("inf") && !s.contains("NaN"),
                "{n}: printed '{s}'"
            );
            assert_eq!(Json::parse(&s).unwrap(), Json::Num(n), "via '{s}'");
        }
    }

    #[test]
    fn serializer_output_always_reparses() {
        // Printer/parser closure over a grab-bag of values, including
        // the non-finite ones (which re-parse as null, not as numbers).
        let v = Json::obj(vec![
            ("nan", Json::Num(f64::NAN)),
            ("inf", Json::Num(f64::INFINITY)),
            ("big", Json::Num(1e300)),
            ("neg", Json::Num(-2.0f64.powi(63))),
            ("arr", Json::Arr(vec![Json::Num(f64::NEG_INFINITY), Json::Num(0.5)])),
        ]);
        let reparsed = Json::parse(&v.to_string()).unwrap();
        assert_eq!(reparsed.get("nan"), Some(&Json::Null));
        assert_eq!(reparsed.get("inf"), Some(&Json::Null));
        assert_eq!(reparsed.get("big"), Some(&Json::Num(1e300)));
        assert_eq!(reparsed.get("neg"), Some(&Json::Num(-9.223372036854776e18)));
    }
}
