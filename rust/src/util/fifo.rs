//! Bounded FIFO used throughout the simulator for input buffers, reorder
//! table entries and meta FIFOs.
//!
//! Capacity is a first-class, *enforced* property — RTL FIFOs cannot
//! silently grow, and neither can these. Pushing into a full FIFO is a
//! modelling bug and panics.
//!
//! ## Storage
//!
//! The queue is a fixed-capacity ring buffer over power-of-two storage:
//! wrap-around is a bitmask (`idx & mask`), not a modulo, and the storage
//! is allocated exactly once at construction — there is **no per-push heap
//! traffic**, unlike a growable `VecDeque`. FIFOs with capacity up to
//! [`INLINE_SLOTS`] (which covers every link input buffer and NI port
//! FIFO at default sizing) keep their slots *inline* in the struct, so the
//! hot-path buffers of a large mesh involve no pointer chase at all.
//!
//! ## High-water mark semantics
//!
//! [`Fifo::peak`] is the highest occupancy ever observed **over the
//! FIFO's lifetime**: it deliberately survives [`Fifo::clear`], because
//! sizing reports answer "how deep did this structure ever need to be",
//! and a cleared-and-reused ROB entry still occupied its peak depth while
//! it was live. Callers that want per-window reporting (peak since a
//! specific point, e.g. per reuse of a ROB slot) call
//! [`Fifo::reset_peak`] explicitly at the window boundary.

/// Capacities up to this many slots are stored inline (no heap
/// allocation at all). 8 covers the default link input buffers (2), NI
/// port FIFOs (4) and per-ID reorder FIFOs (4).
pub const INLINE_SLOTS: usize = 8;

/// Ring-buffer slot storage: inline arrays for small FIFOs, a single
/// one-time heap allocation for larger ones. Slot count is always a
/// power of two so wrap-around is a mask. Two inline tiers keep the
/// waste bounded: the hot per-link input buffers (default capacity 2)
/// carry at most two padding slots, not six — with hundreds of links
/// per fabric the padding would otherwise dominate the link arena's
/// cache footprint.
#[derive(Debug, Clone)]
enum Slots<T> {
    /// Up to 4 slots in the struct itself (capacities 1–4).
    Inline4([Option<T>; 4]),
    /// Up to [`INLINE_SLOTS`] slots in the struct itself (capacities 5–8).
    Inline8([Option<T>; INLINE_SLOTS]),
    /// `cap.next_power_of_two()` slots, allocated once at construction.
    Heap(Box<[Option<T>]>),
}

impl<T> Slots<T> {
    fn for_capacity(cap: usize) -> Self {
        if cap <= 4 {
            Slots::Inline4(std::array::from_fn(|_| None))
        } else if cap <= INLINE_SLOTS {
            Slots::Inline8(std::array::from_fn(|_| None))
        } else {
            Slots::Heap((0..cap.next_power_of_two()).map(|_| None).collect())
        }
    }

    #[inline]
    fn slice(&self) -> &[Option<T>] {
        match self {
            Slots::Inline4(a) => a,
            Slots::Inline8(a) => a,
            Slots::Heap(b) => b,
        }
    }

    #[inline]
    fn slice_mut(&mut self) -> &mut [Option<T>] {
        match self {
            Slots::Inline4(a) => a,
            Slots::Inline8(a) => a,
            Slots::Heap(b) => b,
        }
    }
}

/// Bounded FIFO with RTL-like semantics over masked ring-buffer storage.
#[derive(Debug, Clone)]
pub struct Fifo<T> {
    slots: Slots<T>,
    /// Index of the front element (always `< slots.len()`).
    head: usize,
    /// Occupancy.
    len: usize,
    /// `slots.len() - 1`; slot count is a power of two.
    mask: usize,
    /// Logical capacity in entries (enforced; `<=` slot count).
    cap: usize,
    /// High-water mark, for sizing reports. Survives [`Fifo::clear`]
    /// (lifetime semantics — see the module docs); reset explicitly with
    /// [`Fifo::reset_peak`].
    peak: usize,
}

impl<T> Fifo<T> {
    /// Create a FIFO with `cap` entries (`cap >= 1`).
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1, "zero-capacity fifo");
        let slots = Slots::for_capacity(cap);
        let mask = slots.slice().len() - 1;
        debug_assert!(slots.slice().len().is_power_of_two());
        Fifo {
            slots,
            head: 0,
            len: 0,
            mask,
            cap,
            peak: 0,
        }
    }

    /// Capacity in entries.
    #[inline]
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Current occupancy.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no entry is queued.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// True when at capacity (ready deasserted).
    #[inline]
    pub fn is_full(&self) -> bool {
        self.len >= self.cap
    }

    /// Free slots remaining.
    #[inline]
    pub fn space(&self) -> usize {
        self.cap - self.len
    }

    /// Highest occupancy ever observed since construction or the last
    /// [`Fifo::reset_peak`]. Intentionally survives [`Fifo::clear`]: a
    /// sizing report must see the depth a reused entry reached in *any*
    /// window of its lifetime.
    #[inline]
    pub fn peak(&self) -> usize {
        self.peak
    }

    /// Start a new high-water window: the peak restarts from the current
    /// occupancy. Use at reuse boundaries (e.g. when a ROB entry is
    /// recycled) for per-window sizing reports.
    #[inline]
    pub fn reset_peak(&mut self) {
        self.peak = self.len;
    }

    /// Push; panics when full (callers must check `is_full`/`space` first —
    /// that check models the ready signal).
    #[inline]
    pub fn push(&mut self, item: T) {
        assert!(!self.is_full(), "push into full fifo (missing ready check)");
        let idx = (self.head + self.len) & self.mask;
        debug_assert!(self.slots.slice()[idx].is_none(), "slot collision");
        self.slots.slice_mut()[idx] = Some(item);
        self.len += 1;
        self.peak = self.peak.max(self.len);
    }

    /// Try-push variant returning the item when full.
    #[inline]
    pub fn try_push(&mut self, item: T) -> Result<(), T> {
        if self.is_full() {
            Err(item)
        } else {
            self.push(item);
            Ok(())
        }
    }

    /// Pop the front entry, if any.
    #[inline]
    pub fn pop(&mut self) -> Option<T> {
        if self.len == 0 {
            return None;
        }
        let item = self.slots.slice_mut()[self.head].take();
        debug_assert!(item.is_some(), "occupied slot was empty");
        self.head = (self.head + 1) & self.mask;
        self.len -= 1;
        item
    }

    /// Borrow the front entry, if any.
    #[inline]
    pub fn front(&self) -> Option<&T> {
        if self.len == 0 {
            None
        } else {
            self.slots.slice()[self.head].as_ref()
        }
    }

    /// Mutably borrow the front entry, if any.
    #[inline]
    pub fn front_mut(&mut self) -> Option<&mut T> {
        if self.len == 0 {
            None
        } else {
            let head = self.head;
            self.slots.slice_mut()[head].as_mut()
        }
    }

    /// Iterate front→back without consuming.
    #[inline]
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        let slots = self.slots.slice();
        let (head, mask) = (self.head, self.mask);
        (0..self.len).map(move |i| {
            slots[(head + i) & mask]
                .as_ref()
                .expect("occupied ring slot is Some")
        })
    }

    /// Mutable iteration front→back (reorder-table style in-place updates).
    pub fn iter_mut(&mut self) -> impl Iterator<Item = &mut T> {
        let (front, back) = self.occupied_slices_mut();
        front
            .iter_mut()
            .chain(back.iter_mut())
            .map(|slot| slot.as_mut().expect("occupied ring slot is Some"))
    }

    /// Drop every queued entry. The high-water mark survives (see the
    /// module docs); use [`Fifo::reset_peak`] to start a new window.
    pub fn clear(&mut self) {
        // Straight slot wipe instead of a pop loop: no per-entry
        // index/branch work, and the ring restarts at slot zero.
        for slot in self.slots.slice_mut() {
            *slot = None;
        }
        self.head = 0;
        self.len = 0;
    }

    /// The occupied region as (first, second) mutable slices in
    /// front→back order; `second` is empty unless the region wraps.
    fn occupied_slices_mut(&mut self) -> (&mut [Option<T>], &mut [Option<T>]) {
        let slot_count = self.mask + 1;
        let (head, len) = (self.head, self.len);
        let slots = self.slots.slice_mut();
        if head + len <= slot_count {
            (&mut slots[head..head + len], &mut [])
        } else {
            let wrapped = head + len - slot_count;
            let (front_of_store, back_of_store) = slots.split_at_mut(head);
            (back_of_store, &mut front_of_store[..wrapped])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let mut f = Fifo::new(4);
        f.push(1);
        f.push(2);
        f.push(3);
        assert_eq!(f.pop(), Some(1));
        assert_eq!(f.pop(), Some(2));
        f.push(4);
        assert_eq!(f.pop(), Some(3));
        assert_eq!(f.pop(), Some(4));
        assert_eq!(f.pop(), None);
    }

    #[test]
    fn capacity_enforced() {
        let mut f = Fifo::new(2);
        assert!(f.try_push(1).is_ok());
        assert!(f.try_push(2).is_ok());
        assert!(f.is_full());
        assert_eq!(f.try_push(3), Err(3));
        assert_eq!(f.space(), 0);
    }

    #[test]
    #[should_panic(expected = "full fifo")]
    fn push_full_panics() {
        let mut f = Fifo::new(1);
        f.push(1);
        f.push(2);
    }

    #[test]
    fn peak_tracks_high_water() {
        let mut f = Fifo::new(8);
        for i in 0..5 {
            f.push(i);
        }
        for _ in 0..5 {
            f.pop();
        }
        f.push(9);
        assert_eq!(f.peak(), 5);
    }

    /// The ring wraps correctly at every head position: a long push/pop
    /// stream through a small FIFO (head circles the storage many times)
    /// preserves order and capacity accounting.
    #[test]
    fn masked_wrap_long_stream() {
        let mut f = Fifo::new(3); // non-power-of-two cap: storage is 4 (inline)
        let mut next_in = 0u32;
        let mut next_out = 0u32;
        for round in 0..100 {
            while !f.is_full() {
                f.push(next_in);
                next_in += 1;
            }
            assert_eq!(f.len(), 3, "round {round}");
            let drain = if round % 2 == 0 { 1 } else { 3 };
            for _ in 0..drain {
                assert_eq!(f.pop(), Some(next_out));
                next_out += 1;
            }
        }
        while let Some(v) = f.pop() {
            assert_eq!(v, next_out);
            next_out += 1;
        }
        assert_eq!(next_in, next_out);
        assert_eq!(f.peak(), 3);
    }

    /// Heap-backed capacities (> INLINE_SLOTS) behave identically,
    /// including the power-of-two rounding of the storage.
    #[test]
    fn heap_backed_large_capacity() {
        let mut f = Fifo::new(100); // storage 128, logical cap 100
        assert_eq!(f.capacity(), 100);
        for i in 0..100 {
            f.push(i);
        }
        assert!(f.is_full());
        assert_eq!(f.space(), 0);
        for i in 0..60 {
            assert_eq!(f.pop(), Some(i));
        }
        for i in 100..160 {
            f.push(i); // wraps through the 128-slot storage
        }
        for i in 60..160 {
            assert_eq!(f.pop(), Some(i));
        }
        assert!(f.is_empty());
        assert_eq!(f.peak(), 100);
    }

    /// Front/iter views agree with pop order across a wrapped region.
    #[test]
    fn iterators_front_to_back_across_wrap() {
        let mut f = Fifo::new(4);
        for i in 0..4 {
            f.push(i);
        }
        f.pop();
        f.pop();
        f.push(4);
        f.push(5); // occupied region now wraps the 4-slot inline storage
        assert_eq!(f.front(), Some(&2));
        let seen: Vec<i32> = f.iter().copied().collect();
        assert_eq!(seen, vec![2, 3, 4, 5]);
        for v in f.iter_mut() {
            *v += 10;
        }
        assert_eq!(f.pop(), Some(12));
        assert_eq!(f.front_mut().map(|v| *v), Some(13));
    }

    /// Documented lifetime semantics: `clear` drops the entries but the
    /// high-water mark survives — a reused ROB entry's sizing report must
    /// still show the depth it reached before the clear.
    #[test]
    fn clear_preserves_peak() {
        let mut f = Fifo::new(8);
        for i in 0..6 {
            f.push(i);
        }
        f.clear();
        assert!(f.is_empty());
        assert_eq!(f.len(), 0);
        assert_eq!(f.peak(), 6, "peak survives clear (lifetime high-water)");
        // The FIFO is fully reusable after a clear.
        f.push(42);
        assert_eq!(f.front(), Some(&42));
        assert_eq!(f.peak(), 6, "shallower reuse does not move the peak");
    }

    /// Per-window reporting: `reset_peak` starts a new high-water window
    /// at the current occupancy.
    #[test]
    fn reset_peak_starts_new_window() {
        let mut f = Fifo::new(8);
        for i in 0..7 {
            f.push(i);
        }
        for _ in 0..5 {
            f.pop();
        }
        assert_eq!(f.peak(), 7);
        f.reset_peak();
        assert_eq!(f.peak(), 2, "window restarts at current occupancy");
        f.push(9);
        assert_eq!(f.peak(), 3);
        f.clear();
        f.reset_peak();
        assert_eq!(f.peak(), 0, "clear + reset gives a fresh-window zero");
    }

    /// Clear followed by pushes must not resurrect stale slots (the ring
    /// indices restart cleanly).
    #[test]
    fn clear_then_refill_to_capacity() {
        let mut f = Fifo::new(5);
        for i in 0..5 {
            f.push(i);
        }
        f.clear();
        for i in 10..15 {
            f.push(i);
        }
        assert!(f.is_full());
        let seen: Vec<i32> = f.iter().copied().collect();
        assert_eq!(seen, vec![10, 11, 12, 13, 14]);
    }
}
