//! Bounded FIFO used throughout the simulator for input buffers, reorder
//! table entries and meta FIFOs.
//!
//! A thin wrapper over `VecDeque` that makes capacity a first-class,
//! *enforced* property — RTL FIFOs cannot silently grow, and neither can
//! these. Pushing into a full FIFO is a modelling bug and panics.

use std::collections::VecDeque;

/// Bounded FIFO with RTL-like semantics.
#[derive(Debug, Clone)]
pub struct Fifo<T> {
    q: VecDeque<T>,
    cap: usize,
    /// High-water mark, for sizing reports.
    peak: usize,
}

impl<T> Fifo<T> {
    /// Create a FIFO with `cap` entries (`cap >= 1`).
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1, "zero-capacity fifo");
        Fifo {
            q: VecDeque::with_capacity(cap),
            cap,
            peak: 0,
        }
    }

    /// Capacity in entries.
    #[inline]
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Current occupancy.
    #[inline]
    pub fn len(&self) -> usize {
        self.q.len()
    }

    /// True when no entry is queued.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// True when at capacity (ready deasserted).
    #[inline]
    pub fn is_full(&self) -> bool {
        self.q.len() >= self.cap
    }

    /// Free slots remaining.
    #[inline]
    pub fn space(&self) -> usize {
        self.cap - self.q.len()
    }

    /// Highest occupancy ever observed.
    #[inline]
    pub fn peak(&self) -> usize {
        self.peak
    }

    /// Push; panics when full (callers must check `is_full`/`space` first —
    /// that check models the ready signal).
    #[inline]
    pub fn push(&mut self, item: T) {
        assert!(!self.is_full(), "push into full fifo (missing ready check)");
        self.q.push_back(item);
        self.peak = self.peak.max(self.q.len());
    }

    /// Try-push variant returning the item when full.
    #[inline]
    pub fn try_push(&mut self, item: T) -> Result<(), T> {
        if self.is_full() {
            Err(item)
        } else {
            self.push(item);
            Ok(())
        }
    }

    /// Pop the front entry, if any.
    #[inline]
    pub fn pop(&mut self) -> Option<T> {
        self.q.pop_front()
    }

    /// Borrow the front entry, if any.
    #[inline]
    pub fn front(&self) -> Option<&T> {
        self.q.front()
    }

    /// Mutably borrow the front entry, if any.
    #[inline]
    pub fn front_mut(&mut self) -> Option<&mut T> {
        self.q.front_mut()
    }

    /// Iterate front→back without consuming.
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.q.iter()
    }

    /// Mutable iteration front→back (reorder-table style in-place updates).
    pub fn iter_mut(&mut self) -> impl Iterator<Item = &mut T> {
        self.q.iter_mut()
    }

    /// Drop every queued entry.
    pub fn clear(&mut self) {
        self.q.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let mut f = Fifo::new(4);
        f.push(1);
        f.push(2);
        f.push(3);
        assert_eq!(f.pop(), Some(1));
        assert_eq!(f.pop(), Some(2));
        f.push(4);
        assert_eq!(f.pop(), Some(3));
        assert_eq!(f.pop(), Some(4));
        assert_eq!(f.pop(), None);
    }

    #[test]
    fn capacity_enforced() {
        let mut f = Fifo::new(2);
        assert!(f.try_push(1).is_ok());
        assert!(f.try_push(2).is_ok());
        assert!(f.is_full());
        assert_eq!(f.try_push(3), Err(3));
        assert_eq!(f.space(), 0);
    }

    #[test]
    #[should_panic(expected = "full fifo")]
    fn push_full_panics() {
        let mut f = Fifo::new(1);
        f.push(1);
        f.push(2);
    }

    #[test]
    fn peak_tracks_high_water() {
        let mut f = Fifo::new(8);
        for i in 0..5 {
            f.push(i);
        }
        for _ in 0..5 {
            f.pop();
        }
        f.push(9);
        assert_eq!(f.peak(), 5);
    }
}
