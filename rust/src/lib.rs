//! # FlooNoC reproduction library
//!
//! A cycle-accurate reproduction of *FlooNoC: A Multi-Tbps Wide NoC for
//! Heterogeneous AXI4 Traffic* (Fischer et al., IEEE D&T 2023).
//!
//! The crate implements, from the bottom up:
//!
//! * [`sim`] — a deterministic, cycle-stepped simulation kernel with
//!   valid/ready links and single-cycle hop registers;
//! * [`axi`] — an AXI4 transaction model (AR/AW/W/R/B channels, IDs,
//!   bursts) plus a protocol ordering monitor;
//! * [`flit`] — the FlooNoC link-level protocol: parallel-header flits and
//!   the Table-I link-width calculator (119/103/603 bit);
//! * [`ni`] — the paper's key contribution: a fully AXI4-compliant network
//!   interface with a dynamically allocated reorder buffer (ROB), per-ID
//!   reorder table, meta FIFOs, and end-to-end flow control;
//! * [`router`] — configurable-radix single-cycle wormhole routers with
//!   pluggable, table-materialized routing rules (XY, wrap-minimizing
//!   torus dimension-ordered, ring shortest-direction), no virtual
//!   channels, multilink operation;
//! * [`topology`] — pluggable fabrics (2D mesh, torus, ring) of compute
//!   tiles with per-topology memory-controller placement, wraparound
//!   channel rules and a global address map;
//! * [`cluster`] — a behavioural Snitch-like compute tile (8 cores + DMA +
//!   SPM) calibrated to the paper's 18-cycle zero-load round trip;
//! * [`traffic`] — workload generators for the paper's Fig. 5 experiments
//!   and general synthetic patterns;
//! * [`phys`] — the physical model (area in kGE, energy in pJ/B/hop, wire
//!   counts and routing-channel geometry) calibrated to the published
//!   GF 12 nm post-layout results;
//! * [`baseline`] — the wide-only link configuration and an AXI4-matrix
//!   interconnect baseline;
//! * [`runtime`] / [`compute`] — the PJRT bridge that loads the AOT-lowered
//!   JAX/Pallas artifacts (`artifacts/*.hlo.txt`) and executes the tile
//!   compute and the analytical NoC model from the Rust side;
//! * [`coordinator`] — experiment orchestration reproducing every table and
//!   figure of the paper's evaluation;
//! * [`report`] — table/figure formatters, incl. the Table-II comparison;
//! * [`perf`] — end-to-end simulator-throughput scenarios (activity-gated
//!   vs dense reference) and the `BENCH_e2e.json` trajectory writer;
//! * [`verify`] — the static network analyzer: channel-dependency-graph
//!   acyclicity (deadlock freedom), route-table sanity and config lints
//!   as a mandatory build preflight, plus the live wait-for analysis
//!   the stall watchdog prints (see `docs/verification.md`).
//!
//! Python (JAX + Pallas) is used **only at build time** to author and
//! AOT-lower the compute kernels; the simulator and all experiments run
//! from this crate alone once `make artifacts` has been executed.

#![warn(missing_docs)]
#![forbid(unsafe_code)]

pub mod util;
pub mod sim;
pub mod axi;
pub mod flit;
pub mod ni;
pub mod router;
pub mod topology;
pub mod mem;
pub mod cluster;
pub mod traffic;
pub mod phys;
pub mod baseline;
pub mod noc;
pub mod stats;
pub mod config;
pub mod runtime;
pub mod compute;
pub mod dse;
pub mod coordinator;
pub mod report;
pub mod perf;
pub mod verify;
pub mod cli;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
