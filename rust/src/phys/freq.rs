//! Timing model: frequency ⇔ FO4 depth (§V: 1.23 GHz at 70 FO4 in
//! GF 12 nm, TT / 0.8 V / 25 °C).

/// Logic-depth/frequency conversion for a given technology's FO4 delay.
#[derive(Debug, Clone)]
pub struct TimingModel {
    /// FO4 inverter delay in picoseconds.
    pub fo4_ps: f64,
}

impl Default for TimingModel {
    fn default() -> Self {
        // Fitted: 1.23 GHz ⇔ 70 FO4 ⇒ FO4 ≈ 11.6 ps (GF 12 nm TT 0.8 V).
        TimingModel { fo4_ps: 11.614 }
    }
}

impl TimingModel {
    /// Clock frequency for a pipeline of `fo4_depth` FO4.
    pub fn freq_ghz(&self, fo4_depth: f64) -> f64 {
        1000.0 / (self.fo4_ps * fo4_depth)
    }

    /// FO4 depth implied by a target frequency.
    pub fn fo4_depth(&self, freq_ghz: f64) -> f64 {
        1000.0 / (self.fo4_ps * freq_ghz)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// §V: timing closes at 1.23 GHz ⇔ 70 FO4.
    #[test]
    fn paper_operating_point() {
        let t = TimingModel::default();
        assert!((t.freq_ghz(70.0) - 1.23).abs() < 0.01);
        assert!((t.fo4_depth(1.23) - 70.0).abs() < 0.5);
    }

    #[test]
    fn inverse_consistency() {
        let t = TimingModel::default();
        for depth in [40.0, 70.0, 100.0] {
            assert!((t.fo4_depth(t.freq_ghz(depth)) - depth).abs() < 1e-9);
        }
    }
}
