//! Area model in gate equivalents (GE), reproducing Fig. 6a.
//!
//! Structure: SRAM macros scale with bits, flop-based structures (link
//! buffers, SCM tables) scale with bits at a higher per-bit cost, crossbars
//! scale with `ports² × width`. Coefficients are fitted so the paper's tile
//! configuration lands on the published totals (≈5 MGE tile, ≈500 kGE NoC,
//! 10 %); the *scaling* then lets `repro sweep` explore other configs.

use crate::cluster::TileSpec;
use crate::flit::NocLayout;
use crate::util::json::Json;

/// Fitted technology/implementation coefficients (GF 12 nm flavoured).
#[derive(Debug, Clone)]
pub struct AreaModel {
    /// GE per SRAM bit (macro, incl. periphery amortized).
    pub ge_per_sram_bit: f64,
    /// GE per flop-based (SCM / buffer) bit.
    pub ge_per_scm_bit: f64,
    /// GE per crossbar bit-port² (mux-tree share).
    pub ge_per_xbar_bit: f64,
    /// GE per Snitch worker core incl. FPU share.
    pub ge_per_core: f64,
    /// GE for the DMA engine + control core.
    pub ge_dma: f64,
    /// GE for the cluster-internal AXI interconnect.
    pub ge_cluster_ic: f64,
    /// Fixed NI control logic (allocator, state machines) per bus.
    pub ge_ni_control: f64,
    /// Buffer-island flops per link bit per island set.
    pub ge_island_per_bit: f64,
    /// Number of island sets on the routing channel (§V: three).
    pub island_sets: u32,
}

impl Default for AreaModel {
    fn default() -> Self {
        AreaModel {
            ge_per_sram_bit: 1.6,
            ge_per_scm_bit: 8.0,
            ge_per_xbar_bit: 1.0,
            ge_per_core: 260_000.0,
            ge_dma: 95_000.0,
            ge_cluster_ic: 450_000.0,
            ge_ni_control: 95_000.0,
            ge_island_per_bit: 12.0,
            island_sets: 3,
        }
    }
}

/// One Fig. 6a slice, in GE.
#[derive(Debug, Clone)]
pub struct AreaBreakdown {
    /// Worker + DMA cores.
    pub cores: f64,
    /// Scratchpad memory.
    pub spm: f64,
    /// Shared instruction cache.
    pub icache: f64,
    /// DMA engine.
    pub dma: f64,
    /// Cluster-internal interconnect.
    pub cluster_ic: f64,
    /// The tile's NoC routers (all physical networks).
    pub routers: f64,
    /// NI control logic.
    pub ni: f64,
    /// ROB storage (SCM).
    pub rob: f64,
    /// Link buffer islands along the routing channel.
    pub buffer_islands: f64,
}

impl AreaBreakdown {
    /// Compute-cluster GE (everything but the NoC).
    pub fn cluster_total(&self) -> f64 {
        self.cores + self.spm + self.icache + self.dma + self.cluster_ic
    }

    /// NoC components: router + NI + ROB + buffer islands (the paper's
    /// "≈500 kGE, 10 % of the tile").
    pub fn noc_total(&self) -> f64 {
        self.routers + self.ni + self.rob + self.buffer_islands
    }

    /// Whole-tile GE.
    pub fn tile_total(&self) -> f64 {
        self.cluster_total() + self.noc_total()
    }

    /// NoC share of the tile (paper: ~10 %).
    pub fn noc_fraction(&self) -> f64 {
        self.noc_total() / self.tile_total()
    }

    /// Serialize for reports (kGE units).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("cores_kge", Json::Num(self.cores / 1e3)),
            ("spm_kge", Json::Num(self.spm / 1e3)),
            ("icache_kge", Json::Num(self.icache / 1e3)),
            ("dma_kge", Json::Num(self.dma / 1e3)),
            ("cluster_ic_kge", Json::Num(self.cluster_ic / 1e3)),
            ("routers_kge", Json::Num(self.routers / 1e3)),
            ("ni_kge", Json::Num(self.ni / 1e3)),
            ("rob_kge", Json::Num(self.rob / 1e3)),
            ("buffer_islands_kge", Json::Num(self.buffer_islands / 1e3)),
            ("noc_total_kge", Json::Num(self.noc_total() / 1e3)),
            ("tile_total_mge", Json::Num(self.tile_total() / 1e6)),
            ("noc_fraction", Json::Num(self.noc_fraction())),
        ])
    }
}

impl AreaModel {
    /// Router area for one physical link of `flit_bits`, radix `ports`,
    /// input-buffer depth `depth` (paper §III-C: input buffers + switch,
    /// loopback and impossible XY turns pruned from the crossbar).
    pub fn router_ge(&self, ports: u32, flit_bits: u32, depth: u32) -> f64 {
        let buf = (ports * depth * flit_bits) as f64 * self.ge_per_scm_bit;
        // XY pruning: of the ports² connections, loopback (ports) and the
        // two Y->X turn pairs (4) are disabled.
        let conns = (ports * ports - ports - 4).max(1) as f64;
        let xbar = conns * flit_bits as f64 * self.ge_per_xbar_bit;
        let arb = ports as f64 * 220.0;
        buf + xbar + arb
    }

    /// NI area (both buses): control + reorder tables (SCM) + meta FIFOs.
    pub fn ni_ge(&self, layout: &NocLayout, per_id_depth: u32, num_ids: u32) -> f64 {
        let table_bits = |rob_idx_bits: u32| {
            // Each reorder-table entry: rob index + beat count + state.
            (num_ids * per_id_depth * (rob_idx_bits + 10)) as f64
        };
        let tables = (table_bits(layout.narrow_rob.idx_bits())
            + table_bits(layout.wide_rob.idx_bits()))
            * self.ge_per_scm_bit;
        // Write-response slots (SCM) + meta FIFOs, both buses.
        let meta = 2.0 * (num_ids * per_id_depth) as f64 * 24.0 * self.ge_per_scm_bit / 8.0;
        2.0 * self.ge_ni_control + tables + meta
    }

    /// ROB storage: R-response ROBs in SRAM (8 kB + 2 kB), B-response and
    /// table state in SCM (counted in `ni_ge`).
    pub fn rob_ge(&self, layout: &NocLayout) -> f64 {
        ((layout.narrow_rob.bytes + layout.wide_rob.bytes) * 8) as f64 * self.ge_per_sram_bit
    }

    /// Buffer islands on the horizontal + vertical routing channels.
    pub fn islands_ge(&self, layout: &NocLayout) -> f64 {
        let channel_bits = layout.duplex_wires() as f64;
        channel_bits * self.ge_island_per_bit * self.island_sets as f64
    }

    /// Full Fig. 6a breakdown for a tile.
    pub fn tile(&self, spec: &TileSpec, layout: &NocLayout, in_buf_depth: u32) -> AreaBreakdown {
        let routers = self.router_ge(5, layout.narrow_req().flit_bits(), in_buf_depth)
            + self.router_ge(5, layout.narrow_rsp().flit_bits(), in_buf_depth)
            + self.router_ge(5, layout.wide_link().flit_bits(), in_buf_depth);
        AreaBreakdown {
            cores: spec.worker_cores as f64 * self.ge_per_core,
            spm: (spec.spm_kib * 1024 * 8) as f64 * self.ge_per_sram_bit,
            icache: (spec.icache_kib * 1024 * 8) as f64 * self.ge_per_sram_bit * 1.3,
            dma: self.ge_dma,
            cluster_ic: self.ge_cluster_ic,
            routers,
            ni: self.ni_ge(layout, 4, 16),
            rob: self.rob_ge(layout),
            buffer_islands: self.islands_ge(layout),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn breakdown() -> AreaBreakdown {
        AreaModel::default().tile(&TileSpec::default(), &NocLayout::default(), 2)
    }

    /// Fig. 6a headline: tile ≈ 5 MGE.
    #[test]
    fn tile_total_five_mge() {
        let b = breakdown();
        let mge = b.tile_total() / 1e6;
        assert!(
            (4.5..=5.5).contains(&mge),
            "tile ≈ 5 MGE (paper §VI-C), got {mge:.2}"
        );
    }

    /// Fig. 6a / abstract: NoC ≈ 450–500 kGE, ≈10 % of the tile.
    #[test]
    fn noc_area_and_fraction() {
        let b = breakdown();
        let kge = b.noc_total() / 1e3;
        assert!(
            (420.0..=560.0).contains(&kge),
            "NoC ≈ 450–500 kGE, got {kge:.0}"
        );
        let frac = b.noc_fraction();
        assert!(
            (0.08..=0.12).contains(&frac),
            "NoC ≈ 10 % of tile, got {:.1} %",
            frac * 100.0
        );
    }

    /// §VI-C: "The NoC's size is primarily governed by the NI and its
    /// ROBs" — NI+ROB must dominate the routers.
    #[test]
    fn ni_and_rob_dominate() {
        let b = breakdown();
        assert!(b.ni + b.rob > b.routers);
    }

    /// The wide router costs more than both narrow routers together
    /// (603 bit vs 119 + 103).
    #[test]
    fn router_scales_with_width() {
        let m = AreaModel::default();
        let wide = m.router_ge(5, 603, 2);
        let narrow = m.router_ge(5, 119, 2) + m.router_ge(5, 103, 2);
        assert!(wide > narrow);
    }

    /// Doubling the ROB doubles its SRAM area (sweepability).
    #[test]
    fn rob_area_scales() {
        let m = AreaModel::default();
        let mut l = NocLayout::default();
        let base = m.rob_ge(&l);
        l.wide_rob.bytes *= 2;
        l.narrow_rob.bytes *= 2;
        assert!((m.rob_ge(&l) / base - 2.0).abs() < 1e-9);
    }

    #[test]
    fn json_export_has_fraction() {
        let b = breakdown();
        let j = b.to_json();
        assert!(j.get("noc_fraction").unwrap().as_f64().unwrap() > 0.05);
    }
}
