//! Routing-channel geometry (§V): wire counts and channel width.
//!
//! The paper routes the horizontal and vertical duplex channels on four
//! reserved upper metal layers over the SRAM macros: "a duplex channel
//! requires approximately 1600 wires ... using two of the four metal
//! layers with preferred routing direction, the routing channel occupies
//! a slice of 120 µm", with buffer islands between SRAM macros refueling
//! the long wires (three sets suffice for a 1 mm tile).

use crate::flit::NocLayout;

/// Metal-stack parameters (GF 12 nm upper-layer flavoured).
#[derive(Debug, Clone)]
pub struct ChannelGeometry {
    /// Routing track pitch on the reserved layers, in µm.
    pub track_pitch_um: f64,
    /// Usable track utilization (margin for the power grid, §V).
    pub utilization: f64,
    /// Layers available per routing direction.
    pub layers_per_dir: u32,
    /// Tile edge length in mm (the paper's hard macro: 1 mm sides).
    pub tile_mm: f64,
    /// Max wire length between refueling buffers, in mm (transition-time
    /// limited; §V: three sets of buffers over 1 mm ⇒ ≈0.25 mm spacing).
    pub max_unbuffered_mm: f64,
}

impl Default for ChannelGeometry {
    fn default() -> Self {
        ChannelGeometry {
            track_pitch_um: 0.14,
            utilization: 0.97,
            layers_per_dir: 2,
            tile_mm: 1.0,
            max_unbuffered_mm: 0.26,
        }
    }
}

impl ChannelGeometry {
    /// Wires in one duplex channel (all three physical links, both
    /// directions, valid/ready included) — the "≈1600 wires".
    pub fn duplex_wires(&self, layout: &NocLayout) -> u32 {
        layout.duplex_wires()
    }

    /// Channel slice width in µm when routed on `layers_per_dir` layers.
    pub fn channel_width_um(&self, layout: &NocLayout) -> f64 {
        let per_layer =
            (self.duplex_wires(layout) as f64 / self.layers_per_dir as f64).ceil();
        per_layer * self.track_pitch_um / self.utilization
    }

    /// Number of buffer-island sets needed to cross the tile without
    /// violating transition-time limits: interior buffers between wire
    /// segments (§V: three sets for a 1 mm tile at ≈0.25 mm spacing).
    pub fn island_sets(&self) -> u32 {
        ((self.tile_mm / self.max_unbuffered_mm).ceil() as u32).saturating_sub(1)
    }

    /// Fraction of the tile edge consumed by one routing channel.
    pub fn edge_fraction(&self, layout: &NocLayout) -> f64 {
        self.channel_width_um(layout) / (self.tile_mm * 1000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// §V: "approximately 1600 wires".
    #[test]
    fn sixteen_hundred_wires() {
        let g = ChannelGeometry::default();
        let w = g.duplex_wires(&NocLayout::default());
        assert!((1500..=1700).contains(&w), "≈1600, got {w}");
    }

    /// §V: "the routing channel occupies a slice of 120 µm".
    #[test]
    fn one_twenty_micron_slice() {
        let g = ChannelGeometry::default();
        let um = g.channel_width_um(&NocLayout::default());
        assert!(
            (110.0..=130.0).contains(&um),
            "≈120 µm slice, got {um:.1}"
        );
    }

    /// §V: three buffer-island sets over the 1 mm macro.
    #[test]
    fn three_island_sets() {
        assert_eq!(ChannelGeometry::default().island_sets(), 3);
    }

    /// §VI-C: channels cover "roughly a quarter of the entire floorplan" —
    /// horizontal + vertical slices of ~120 µm each over a 1 mm tile ⇒
    /// 2 × 12 % ≈ 24 % of tile area overlapped (routed above SRAMs).
    #[test]
    fn quarter_of_floorplan_overlap() {
        let g = ChannelGeometry::default();
        let l = NocLayout::default();
        let frac = g.edge_fraction(&l);
        let covered = 2.0 * frac - frac * frac; // union of H + V strips
        assert!(
            (0.18..=0.30).contains(&covered),
            "≈ quarter of floorplan, got {:.1} %",
            covered * 100.0
        );
    }

    /// Wider meshes (more coord bits) widen the channel but only by header
    /// bits — sweepability check.
    #[test]
    fn channel_scales_with_headers() {
        let g = ChannelGeometry::default();
        let mut l = NocLayout::default();
        let base = g.channel_width_um(&l);
        l.coord_bits = 6;
        assert!(g.channel_width_um(&l) > base);
    }
}
