//! Physical model: area, energy/power, wires and bandwidth.
//!
//! The paper's physical results come from a GF 12 nm Fusion Compiler flow
//! we cannot run; this module substitutes an analytical model whose
//! *coefficients* are fitted to the published post-layout numbers and
//! whose *structure* (what scales with what) follows the architecture.
//! That lets every physical figure be regenerated and swept:
//!
//! * Fig. 6a — area breakdown (`area`): tile ≈ 5 MGE, NoC ≈ 500 kGE ≈ 10 %;
//! * Fig. 6b — power breakdown (`energy`): 139 mW tile, NoC ≈ 7 %,
//!   198 pJ / 1 kB / hop ⇒ 0.19 pJ/B/hop;
//! * §V — routing-channel geometry (`wires`): ≈1600 wires/duplex channel,
//!   ≈120 µm slice on two metal layers;
//! * §VI-B — bandwidth (`bandwidth`): 629 Gbps/link at 1.23 GHz,
//!   1.26 Tbps duplex, 4.4 TB/s at the boundary of a 7×7 mesh;
//! * timing (`freq`): 1.23 GHz ⇔ 70 FO4 in 12 nm.

pub mod area;
pub mod energy;
pub mod wires;
pub mod bandwidth;
pub mod freq;

pub use area::{AreaBreakdown, AreaModel};
pub use bandwidth::BandwidthModel;
pub use energy::{EnergyModel, PowerBreakdown};
pub use freq::TimingModel;
pub use wires::ChannelGeometry;
