//! Energy/power model, reproducing Fig. 6b and the §VI-D headline
//! (0.19 pJ/B/hop, 198 pJ per 1 kB tile crossing, 139 mW tile power with
//! NoC at 7 %).
//!
//! The model takes *simulated activity* (flit-hops per network from the
//! cycle-accurate run) and static calibration constants, and produces a
//! power breakdown over the measurement window — the same procedure as
//! the paper's post-layout PrimeTime flow, with fitted coefficients in
//! place of extracted parasitics.

use crate::util::json::Json;

/// Calibration constants (TT, 0.8 V, 25 °C, 1.23 GHz flavoured).
#[derive(Debug, Clone)]
pub struct EnergyModel {
    /// Dynamic energy to move one byte one hop (router + link + buffers) —
    /// the paper's headline 0.19 pJ/B/hop.
    pub pj_per_byte_hop: f64,
    /// Dynamic energy per narrow-link flit-hop (header-dominated small
    /// flits; ≈119 bit ≈ 15 B at the same per-byte cost).
    pub pj_per_narrow_flit_hop: f64,
    /// NoC idle/clock-tree power in mW (routers + NI clocked, no traffic).
    pub noc_idle_mw: f64,
    /// Cluster power with cores idle but clocked, DMA programmer active —
    /// the §VI-D scenario's compute baseline.
    pub cluster_idle_mw: f64,
    /// Additional cluster power per active core (not used in §VI-D where
    /// cores are idle; used by the examples' what-if sweeps).
    pub core_active_mw: f64,
    /// Clock frequency in GHz.
    pub freq_ghz: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            pj_per_byte_hop: 0.19,
            pj_per_narrow_flit_hop: 15.0 * 0.19,
            noc_idle_mw: 3.5,
            cluster_idle_mw: 129.3,
            core_active_mw: 9.5,
            freq_ghz: 1.23,
        }
    }
}

/// Activity observed during a measurement window.
#[derive(Debug, Clone, Default)]
pub struct Activity {
    /// Wide-network flit-hops (each flit carries 64 B).
    pub wide_flit_hops: u64,
    /// Narrow-network flit-hops (requests + responses).
    pub narrow_flit_hops: u64,
    /// Window length in cycles.
    pub cycles: u64,
    /// Cores actively computing (0 in the §VI-D scenario).
    pub active_cores: u32,
}

/// Fig. 6b output.
#[derive(Debug, Clone)]
pub struct PowerBreakdown {
    /// Compute-cluster power.
    pub cluster_mw: f64,
    /// NoC switching power over the window.
    pub noc_dynamic_mw: f64,
    /// NoC idle/leakage power.
    pub noc_idle_mw: f64,
    /// Total tile power.
    pub total_mw: f64,
    /// NoC share of the total (paper: 4-10 %).
    pub noc_fraction: f64,
    /// Total NoC dynamic energy in pJ over the window.
    pub noc_dynamic_pj: f64,
}

impl PowerBreakdown {
    /// Serialize for reports.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("cluster_mw", Json::Num(self.cluster_mw)),
            ("noc_dynamic_mw", Json::Num(self.noc_dynamic_mw)),
            ("noc_idle_mw", Json::Num(self.noc_idle_mw)),
            ("total_mw", Json::Num(self.total_mw)),
            ("noc_fraction", Json::Num(self.noc_fraction)),
            ("noc_dynamic_pj", Json::Num(self.noc_dynamic_pj)),
        ])
    }
}

impl EnergyModel {
    /// Dynamic NoC energy for the given activity, in pJ.
    pub fn noc_dynamic_pj(&self, act: &Activity) -> f64 {
        act.wide_flit_hops as f64 * 64.0 * self.pj_per_byte_hop
            + act.narrow_flit_hops as f64 * self.pj_per_narrow_flit_hop
    }

    /// Energy for moving `bytes` across `hops` hops on the wide network —
    /// the §VI-D "198 pJ for 1 kB across the tile" quantity.
    pub fn transfer_pj(&self, bytes: u64, hops: u32) -> f64 {
        bytes as f64 * hops as f64 * self.pj_per_byte_hop
    }

    /// Full power breakdown over a measurement window.
    pub fn power(&self, act: &Activity) -> PowerBreakdown {
        let window_ns = act.cycles as f64 / self.freq_ghz;
        let dyn_pj = self.noc_dynamic_pj(act);
        let noc_dynamic_mw = if window_ns > 0.0 {
            dyn_pj / window_ns // pJ/ns = mW
        } else {
            0.0
        };
        let cluster_mw =
            self.cluster_idle_mw + act.active_cores as f64 * self.core_active_mw;
        let total = cluster_mw + noc_dynamic_mw + self.noc_idle_mw;
        PowerBreakdown {
            cluster_mw,
            noc_dynamic_mw,
            noc_idle_mw: self.noc_idle_mw,
            total_mw: total,
            noc_fraction: (noc_dynamic_mw + self.noc_idle_mw) / total,
            noc_dynamic_pj: dyn_pj,
        }
    }

    /// Energy efficiency in pJ/B/hop implied by a measured activity window
    /// (sanity inverse of the calibration).
    pub fn measured_pj_per_byte_hop(&self, act: &Activity) -> f64 {
        let bytes_hops = act.wide_flit_hops as f64 * 64.0;
        if bytes_hops == 0.0 {
            return 0.0;
        }
        (act.wide_flit_hops as f64 * 64.0 * self.pj_per_byte_hop) / bytes_hops
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// §VI-D: 1 kB across one hop = 198 pJ (paper rounds 0.19 × 1024 ≈
    /// 194.6; the published 198 pJ ⇒ 0.193 pJ/B — within 2 %).
    #[test]
    fn one_kib_transfer_energy() {
        let m = EnergyModel::default();
        let pj = m.transfer_pj(1024, 1);
        assert!(
            (pj - 198.0).abs() / 198.0 < 0.02,
            "≈198 pJ per 1 kB/hop, got {pj:.1}"
        );
    }

    /// Fig. 6b: the §VI-D scenario (single 1 kB DMA, idle cores) lands on
    /// ≈139 mW total with the NoC at ≈7 %.
    #[test]
    fn fig6b_power_breakdown() {
        let m = EnergyModel::default();
        // 1 kB = 16 wide beats crossing the tile's router once (the
        // paper's "moving 1 kB across the tile"), over a ≈40-cycle active
        // window (burst + round-trip latency).
        let act = Activity {
            wide_flit_hops: 16,
            narrow_flit_hops: 4, // AW + B and change
            cycles: 40,
            active_cores: 0,
        };
        let p = m.power(&act);
        assert!(
            (130.0..=148.0).contains(&p.total_mw),
            "≈139 mW tile power, got {:.1}",
            p.total_mw
        );
        assert!(
            (0.05..=0.09).contains(&p.noc_fraction),
            "NoC ≈ 7 % of tile power, got {:.1} %",
            p.noc_fraction * 100.0
        );
    }

    #[test]
    fn dynamic_energy_scales_with_hops() {
        let m = EnergyModel::default();
        let a1 = Activity {
            wide_flit_hops: 100,
            ..Default::default()
        };
        let a2 = Activity {
            wide_flit_hops: 200,
            ..Default::default()
        };
        assert!((m.noc_dynamic_pj(&a2) / m.noc_dynamic_pj(&a1) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn idle_window_is_idle_power_only() {
        let m = EnergyModel::default();
        let p = m.power(&Activity {
            cycles: 1000,
            ..Default::default()
        });
        assert_eq!(p.noc_dynamic_mw, 0.0);
        assert!((p.total_mw - (m.cluster_idle_mw + m.noc_idle_mw)).abs() < 1e-9);
    }
}
