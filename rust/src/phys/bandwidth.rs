//! Bandwidth arithmetic (§VI-B): per-link peak, duplex, and mesh-boundary
//! aggregate.

use crate::flit::NocLayout;

/// Peak-bandwidth model at a given clock.
#[derive(Debug, Clone)]
pub struct BandwidthModel {
    /// Clock frequency the links run at.
    pub freq_ghz: f64,
    /// The link layout the widths come from.
    pub layout: NocLayout,
}

impl Default for BandwidthModel {
    fn default() -> Self {
        BandwidthModel {
            freq_ghz: 1.23,
            layout: NocLayout::default(),
        }
    }
}

impl BandwidthModel {
    /// Peak payload bandwidth of one wide link, Gbps (§VI-B: 629 Gbps).
    pub fn wide_link_gbps(&self) -> f64 {
        self.layout.wide_peak_gbps(self.freq_ghz)
    }

    /// Duplex wide-link bandwidth, Tbps (§VI-B: 1.26 Tbps).
    pub fn wide_duplex_tbps(&self) -> f64 {
        2.0 * self.wide_link_gbps() / 1000.0
    }

    /// Aggregate duplex bandwidth crossing the boundary of a `n×n` mesh in
    /// TB/s: every boundary router exposes one outward duplex channel
    /// (paper Fig. 4a — memory controllers at the boundary), 4n channels
    /// total (§VI-B: 4.4 TB/s for 7×7).
    pub fn mesh_boundary_tbs(&self, n: u32) -> f64 {
        let channels = 4 * n;
        let gbytes_per_chan = 2.0 * self.wide_link_gbps() / 8.0; // duplex GB/s
        channels as f64 * gbytes_per_chan / 1000.0
    }

    /// The frequency a serialized narrow NoC would need to match one wide
    /// link (§I's motivation: 512-bit @ 1 GHz over 32-bit needs 16 GHz).
    pub fn equivalent_narrow_freq_ghz(&self, narrow_bits: u32) -> f64 {
        self.wide_link_gbps() / narrow_bits as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// §VI-B: 629 Gbps per link at 1.23 GHz.
    #[test]
    fn wide_link_peak() {
        let m = BandwidthModel::default();
        assert!((m.wide_link_gbps() - 629.76).abs() < 0.1);
    }

    /// §VI-B: 1.26 Tbps duplex.
    #[test]
    fn duplex_peak() {
        let m = BandwidthModel::default();
        assert!((m.wide_duplex_tbps() - 1.26).abs() < 0.01);
    }

    /// §VI-B: "the aggregate bandwidth at the boundary of a 7×7 mesh
    /// amounts to 4.4 TB/s".
    #[test]
    fn seven_by_seven_boundary() {
        let m = BandwidthModel::default();
        let tbs = m.mesh_boundary_tbs(7);
        assert!(
            (4.3..=4.5).contains(&tbs),
            "≈4.4 TB/s, got {tbs:.2}"
        );
    }

    /// §I: serializing a 512-bit 1 GHz channel onto 32-bit needs 16 GHz.
    #[test]
    fn narrow_serialization_motivation() {
        let m = BandwidthModel {
            freq_ghz: 1.0,
            layout: NocLayout::default(),
        };
        assert!((m.equivalent_narrow_freq_ghz(32) - 16.0).abs() < 1e-9);
    }
}
