//! The simulation engine: owns the cycle counter and drives components.
//!
//! Components are plain structs wired together by the network builder
//! ([`crate::noc`]); the engine only provides the clocking discipline and
//! run-to-completion helpers. Keeping the engine this thin (no trait-object
//! component graph in the hot loop) is a deliberate performance choice —
//! the NoC stepping code is monomorphic and inlinable.

use super::Cycle;

/// Aggregate statistics maintained by the engine.
#[derive(Debug, Clone, Default)]
pub struct SimStats {
    /// Total cycles simulated.
    pub cycles: Cycle,
    /// Step-closure invocations actually executed. Equal to `cycles`
    /// under cycle-stepped modes; **≤ `cycles`** under event-driven
    /// fast-forward ([`Engine::run_until_clocked`]), where a single step
    /// may advance the system clock by many cycles — the gap
    /// `cycles - stepped_cycles` is exactly the idle time skipped.
    pub stepped_cycles: Cycle,
    /// Wall-clock seconds spent inside `run`.
    pub wall_seconds: f64,
}

impl SimStats {
    /// Simulated cycles per wall-clock second (engine throughput).
    pub fn cycles_per_second(&self) -> f64 {
        if self.wall_seconds > 0.0 {
            self.cycles as f64 / self.wall_seconds
        } else {
            0.0
        }
    }
}

/// The clocking engine. `S` is the complete simulated system; `step`
/// advances it one cycle.
pub struct Engine<S> {
    /// The simulated system.
    pub system: S,
    /// Current cycle.
    pub now: Cycle,
    /// Wall-clock throughput statistics.
    pub stats: SimStats,
}

impl<S> Engine<S> {
    /// Wrap a system at cycle 0.
    pub fn new(system: S) -> Self {
        Engine {
            system,
            now: 0,
            stats: SimStats::default(),
        }
    }

    /// Advance exactly `n` cycles.
    pub fn run_for<F>(&mut self, n: Cycle, mut step: F)
    where
        F: FnMut(&mut S, Cycle),
    {
        let t0 = std::time::Instant::now();
        for _ in 0..n {
            step(&mut self.system, self.now);
            self.now += 1;
        }
        self.stats.cycles += n;
        self.stats.stepped_cycles += n;
        self.stats.wall_seconds += t0.elapsed().as_secs_f64();
    }

    /// Run until `done` returns true or `max_cycles` elapse. Returns true
    /// when the predicate fired (i.e. the run completed, not timed out).
    ///
    /// A run that is already `done` at entry executes zero steps and
    /// charges **nothing** to [`SimStats`] — neither cycles nor wall
    /// time. Throughput numbers (`cycles_per_second`) would otherwise be
    /// silently diluted by no-op calls from completion-polling loops.
    pub fn run_until<F, D>(&mut self, max_cycles: Cycle, mut step: F, mut done: D) -> bool
    where
        F: FnMut(&mut S, Cycle),
        D: FnMut(&S, Cycle) -> bool,
    {
        if done(&self.system, self.now) {
            return true;
        }
        let t0 = std::time::Instant::now();
        let start = self.now;
        let mut completed = false;
        while self.now - start < max_cycles {
            if done(&self.system, self.now) {
                completed = true;
                break;
            }
            step(&mut self.system, self.now);
            self.now += 1;
        }
        self.stats.cycles += self.now - start;
        self.stats.stepped_cycles += self.now - start;
        self.stats.wall_seconds += t0.elapsed().as_secs_f64();
        completed
    }

    /// [`Self::run_until`] for systems that own their clock — the step
    /// closure returns the system's cycle counter *after* stepping, and
    /// the engine adopts it as `now`. This is the event-driven entry
    /// point: a fast-forwarding system ([`crate::sim::SimMode::Event`])
    /// may advance its clock by many cycles in one step, and every
    /// skipped cycle is charged to [`SimStats::cycles`] as if it had
    /// been stepped (they are provably no-ops), while
    /// [`SimStats::stepped_cycles`] counts only real step invocations.
    ///
    /// Same entry semantics as `run_until`: `done` at entry charges
    /// nothing. The `max_cycles` budget bounds *simulated* cycles, so a
    /// fast-forwarding run can overshoot the budget by one jump but
    /// never spins unboundedly.
    pub fn run_until_clocked<F, D>(&mut self, max_cycles: Cycle, mut step: F, mut done: D) -> bool
    where
        F: FnMut(&mut S) -> Cycle,
        D: FnMut(&S, Cycle) -> bool,
    {
        if done(&self.system, self.now) {
            return true;
        }
        let t0 = std::time::Instant::now();
        let start = self.now;
        let mut completed = false;
        while self.now - start < max_cycles {
            if done(&self.system, self.now) {
                completed = true;
                break;
            }
            self.now = step(&mut self.system);
            self.stats.stepped_cycles += 1;
        }
        self.stats.cycles += self.now - start;
        self.stats.wall_seconds += t0.elapsed().as_secs_f64();
        completed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Counter {
        v: u64,
    }

    #[test]
    fn run_for_advances_time() {
        let mut e = Engine::new(Counter { v: 0 });
        e.run_for(10, |s, _| s.v += 1);
        assert_eq!(e.now, 10);
        assert_eq!(e.system.v, 10);
        assert_eq!(e.stats.cycles, 10);
        assert_eq!(e.stats.stepped_cycles, 10, "cycle-stepped: stepped == cycles");
    }

    #[test]
    fn run_until_stops_on_predicate() {
        let mut e = Engine::new(Counter { v: 0 });
        let ok = e.run_until(1000, |s, _| s.v += 1, |s, _| s.v == 42);
        assert!(ok);
        assert_eq!(e.system.v, 42);
        assert_eq!(e.now, 42);
    }

    #[test]
    fn run_until_times_out() {
        let mut e = Engine::new(Counter { v: 0 });
        let ok = e.run_until(5, |s, _| s.v += 1, |_, _| false);
        assert!(!ok);
        assert_eq!(e.now, 5);
    }

    /// Timing edge: `done` already true at entry. Zero steps run and
    /// zero cycles AND zero wall time are charged to the stats — a
    /// completion-polling caller must not dilute the throughput figure.
    #[test]
    fn run_until_done_at_entry_charges_nothing() {
        let mut e = Engine::new(Counter { v: 7 });
        let mut steps = 0u64;
        let ok = e.run_until(
            1000,
            |s, _| {
                s.v += 1;
                steps += 1;
            },
            |s, _| s.v == 7,
        );
        assert!(ok, "predicate true at entry reports completion");
        assert_eq!(steps, 0, "no step may run");
        assert_eq!(e.now, 0, "time does not advance");
        assert_eq!(e.system.v, 7, "system untouched");
        assert_eq!(e.stats.cycles, 0, "zero cycles charged");
        assert_eq!(e.stats.wall_seconds, 0.0, "zero wall time charged");
        // A subsequent real run still accounts normally.
        let ok = e.run_until(1000, |s, _| s.v += 1, |s, _| s.v == 10);
        assert!(ok);
        assert_eq!(e.stats.cycles, 3);
    }

    /// `max_cycles == 0` with `done` false is a degenerate timeout: no
    /// steps, no charge, and the call reports not-completed.
    #[test]
    fn run_until_zero_budget_times_out_cleanly() {
        let mut e = Engine::new(Counter { v: 0 });
        let ok = e.run_until(0, |s, _| s.v += 1, |_, _| false);
        assert!(!ok);
        assert_eq!(e.system.v, 0);
        assert_eq!(e.stats.cycles, 0);
    }

    #[test]
    fn throughput_reported() {
        let mut e = Engine::new(Counter { v: 0 });
        e.run_for(100_000, |s, _| s.v = s.v.wrapping_add(1));
        assert!(e.stats.cycles_per_second() > 0.0);
    }

    /// A self-clocked system that jumps its clock 10 cycles per step:
    /// every skipped cycle is charged to `cycles` (throughput counts
    /// simulated time), while `stepped_cycles` counts invocations only.
    struct Jumper {
        clock: u64,
        steps: u64,
    }

    #[test]
    fn run_until_clocked_charges_skipped_cycles() {
        let mut e = Engine::new(Jumper { clock: 0, steps: 0 });
        let ok = e.run_until_clocked(
            1000,
            |s| {
                s.steps += 1;
                s.clock += 10;
                s.clock
            },
            |s, _| s.clock >= 50,
        );
        assert!(ok);
        assert_eq!(e.now, 50, "engine adopts the system clock");
        assert_eq!(e.system.steps, 5);
        assert_eq!(e.stats.cycles, 50, "skipped cycles count as simulated");
        assert_eq!(e.stats.stepped_cycles, 5, "only real invocations stepped");
    }

    #[test]
    fn run_until_clocked_done_at_entry_charges_nothing() {
        let mut e = Engine::new(Jumper { clock: 0, steps: 0 });
        let ok = e.run_until_clocked(
            1000,
            |s| {
                s.steps += 1;
                s.clock + 1
            },
            |_, _| true,
        );
        assert!(ok);
        assert_eq!(e.system.steps, 0);
        assert_eq!(e.stats.cycles, 0);
        assert_eq!(e.stats.stepped_cycles, 0);
    }

    #[test]
    fn run_until_clocked_times_out_on_simulated_budget() {
        let mut e = Engine::new(Jumper { clock: 0, steps: 0 });
        // 7-cycle jumps against a 20-cycle budget: the run stops at the
        // first step whose clock reaches the budget (21 ≥ 20), having
        // executed 3 steps, and reports not-completed.
        let ok = e.run_until_clocked(
            20,
            |s| {
                s.steps += 1;
                s.clock += 7;
                s.clock
            },
            |_, _| false,
        );
        assert!(!ok);
        assert_eq!(e.system.steps, 3);
        assert_eq!(e.now, 21);
        assert_eq!(e.stats.cycles, 21);
        assert_eq!(e.stats.stepped_cycles, 3);
    }
}
