//! Point-to-point link with per-virtual-channel lanes: each lane is a
//! one-entry register stage plus a bounded downstream input FIFO — the
//! unit of connectivity for every physical channel in the NoC.
//!
//! A link models one physical channel. With `vcs == 1` (every mesh link,
//! and all inject/eject links) it behaves exactly as the classic single
//! register + FIFO link. With `vcs > 1` the channel carries multiple
//! **virtual channels**: the producer names a lane per flit
//! ([`Link::offer_vc`]), each lane has its own register, pipeline stages
//! and input FIFO (splitting the configured buffer capacity across
//! lanes), and a flit stalled on one lane never blocks flits of another
//! lane — the isolation property dateline deadlock avoidance relies on
//! (see `docs/deadlock.md`). Channel *bandwidth* stays one flit per
//! cycle: the producer (router switch allocation) grants at most one
//! traversal per output per cycle; the lanes only isolate *stalls*.

use crate::util::fifo::Fifo;

/// Opaque link identifier (index into the engine's link table).
pub type LinkId = usize;

/// Upper bound on virtual-channel lanes per link. Lanes are stored
/// inline (a fixed array, not a heap `Vec`) so the deliver hot loop
/// walks one contiguous allocation; matches the router's `MAX_VCS`.
pub const MAX_LANES: usize = 4;

/// Upper bound on extra pipeline stages per lane. Stages are stored
/// inline for the same reason; the two-cycle router calibration uses at
/// most one, long-channel models a few.
pub const MAX_STAGES: usize = 4;

/// What a [`Link::deliver`] call did, for the activity-gated step loop
/// (see `docs/performance.md`): whether the link still holds flits (it
/// must stay in the active set — a flit parked in the last pipeline
/// stage or stalled in a lane register keeps the link "clocked" until it
/// is delivered *and* consumed), and whether any lane of the consumer's
/// input buffer now holds at least one flit (the wake-up edge towards
/// the downstream router / NI).
#[derive(Debug, Clone, Copy, Default)]
pub struct DeliverSummary {
    /// Flits remain anywhere in the link (registers, pipelines or
    /// buffers of any lane) after this deliver — keep the link in the
    /// active set.
    pub still_active: bool,
    /// At least one lane of the consumer's input buffer is non-empty
    /// after this deliver — wake the component that reads this link.
    pub consumer_ready: bool,
}

/// One virtual-channel lane: `reg` models the wire + output register of
/// the producer, `buf` models the consumer's per-VC input buffer, and
/// `pipe` the extra pipeline registers of long routing channels.
/// Transfer from `reg` to `buf` happens in the engine's deliver phase,
/// one cycle after the producer offered the flit.
#[derive(Debug, Clone)]
struct Lane<T> {
    reg: Option<T>,
    buf: Fifo<T>,
    /// Extra pipeline registers modelling long routing channels / elastic
    /// output buffers, stored inline (only `pipe[..stages]` is live).
    /// `pipe[0]` feeds `buf`; new offers enter `pipe[stages - 1]`.
    pipe: [Option<T>; MAX_STAGES],
    /// Live prefix length of `pipe` (the configured extra stages).
    stages: u8,
    /// Flits currently anywhere in this lane (register + live pipeline
    /// stages + buffer); drives the link's non-empty-lane bitmask.
    occ: u16,
    /// Flits that completed delivery into this lane's buffer.
    delivered: u64,
}

impl<T> Lane<T> {
    fn new(buf_depth: usize, extra_stages: usize) -> Self {
        Lane {
            reg: None,
            buf: Fifo::new(buf_depth),
            pipe: std::array::from_fn(|_| None),
            stages: extra_stages as u8,
            occ: 0,
            delivered: 0,
        }
    }
}

/// A unidirectional link: one lane per virtual channel sharing the
/// physical channel's bandwidth (the producer offers at most one flit
/// per cycle across all lanes), with per-lane stall isolation.
#[derive(Debug, Clone)]
pub struct Link<T> {
    /// Inline lane storage; only `lanes[..nlanes]` is live (spare lanes
    /// are empty single-slot stubs that no accessor ever reaches).
    lanes: [Lane<T>; MAX_LANES],
    /// Live lane count (the configured `vcs`).
    nlanes: u8,
    /// Bit `v` set ⇔ lane `v` holds at least one flit anywhere
    /// (register, pipeline or buffer). The deliver sweep walks only set
    /// bits — an empty lane's sub-phases are pure no-ops.
    lane_occ: u8,
    /// Bit `v` set ⇔ lane `v`'s consumer buffer is non-empty (i.e.
    /// `peek_vc(v)` would return `Some`). Consumers use this to skip
    /// empty lanes without probing each one.
    buf_occ: u8,
    /// Flits currently anywhere in the link (all lanes: registers +
    /// pipelines + buffers). Kept incrementally so `is_idle` is O(1) —
    /// the drain detector runs every cycle over every link and must not
    /// rescan storage.
    occupancy: u32,
    // --- instrumentation --------------------------------------------------
    /// Flits that completed delivery into any lane's buffer.
    pub delivered: u64,
    /// Lane-cycles in which a register held a flit but its lane's buffer
    /// was full.
    pub stall_cycles: u64,
    /// Lane-cycles in which a register held a flit (occupancy integral;
    /// with one lane this is exactly "cycles the register was busy").
    pub busy_cycles: u64,
}

impl<T> Link<T> {
    /// A single-lane link whose consumer-side input buffer holds
    /// `buf_depth` flits.
    pub fn new(buf_depth: usize) -> Self {
        Link::with_vcs(buf_depth, 1, 0)
    }

    /// A single-lane link with `extra_stages` additional pipeline
    /// registers, modelling the paper's two-cycle router with output
    /// buffers / buffer islands on long routing channels (§V).
    pub fn with_pipeline(buf_depth: usize, extra_stages: usize) -> Self {
        Link::with_vcs(buf_depth, 1, extra_stages)
    }

    /// A link carrying `vcs` virtual channels, each with `extra_stages`
    /// pipeline registers. The configured `buf_depth` is **split across
    /// lanes** (each lane buffers `max(1, buf_depth / vcs)` flits) so a
    /// multi-VC fabric costs the same total buffer storage as its 1-VC
    /// counterpart — matching how RTL VC routers partition one input
    /// SRAM into per-VC regions.
    pub fn with_vcs(buf_depth: usize, vcs: usize, extra_stages: usize) -> Self {
        assert!(vcs >= 1, "a link needs at least one lane");
        assert!(vcs <= MAX_LANES, "a link carries at most {MAX_LANES} lanes, got {vcs}");
        assert!(
            extra_stages <= MAX_STAGES,
            "a lane carries at most {MAX_STAGES} pipeline stages, got {extra_stages}"
        );
        let per_lane = (buf_depth / vcs).max(1);
        Link {
            lanes: std::array::from_fn(|v| {
                if v < vcs {
                    Lane::new(per_lane, extra_stages)
                } else {
                    Lane::new(1, 0)
                }
            }),
            nlanes: vcs as u8,
            lane_occ: 0,
            buf_occ: 0,
            occupancy: 0,
            delivered: 0,
            stall_cycles: 0,
            busy_cycles: 0,
        }
    }

    /// Number of virtual-channel lanes this link carries.
    #[inline]
    pub fn vcs(&self) -> usize {
        self.nlanes as usize
    }

    /// Can the producer offer a flit on lane 0 this cycle? Single-lane
    /// convenience for [`Self::can_offer_vc`].
    #[inline]
    pub fn can_offer(&self) -> bool {
        self.can_offer_vc(0)
    }

    /// Can the producer offer a flit on lane `vc` this cycle?
    /// (valid/ready at the producer end: true when that lane's entry
    /// register is empty.)
    #[inline]
    pub fn can_offer_vc(&self, vc: usize) -> bool {
        debug_assert!(vc < self.nlanes as usize, "lane {vc} out of range");
        let lane = &self.lanes[vc];
        match lane.stages {
            0 => lane.reg.is_none(),
            s => lane.pipe[s as usize - 1].is_none(),
        }
    }

    /// Producer offers a flit on lane 0 (single-lane convenience).
    #[inline]
    pub fn offer(&mut self, flit: T) {
        self.offer_vc(0, flit);
    }

    /// Producer offers a flit on lane `vc`. Panics if
    /// `!can_offer_vc(vc)` — the caller models the valid/ready handshake
    /// and must check first.
    #[inline]
    pub fn offer_vc(&mut self, vc: usize, flit: T) {
        debug_assert!(vc < self.nlanes as usize, "lane {vc} out of range");
        let lane = &mut self.lanes[vc];
        if lane.stages > 0 {
            let tail = &mut lane.pipe[lane.stages as usize - 1];
            assert!(tail.is_none(), "offer on busy link (missing can_offer)");
            *tail = Some(flit);
        } else {
            assert!(lane.reg.is_none(), "offer on busy link (missing can_offer)");
            lane.reg = Some(flit);
        }
        lane.occ += 1;
        self.lane_occ |= 1 << vc;
        self.occupancy += 1;
    }

    /// Deliver phase, per lane in two explicit sub-phases evaluated
    /// head-first so every register advances by at most one stage per
    /// cycle (all stages clock simultaneously in RTL; head-first
    /// in-cycle evaluation models exactly that):
    ///
    /// 1. **commit** — the head register moves into the lane's input
    ///    buffer when it has space (ready asserted); otherwise the
    ///    register stalls and backpressure propagates up that lane's
    ///    pipeline — *other lanes are unaffected*;
    /// 2. **advance** — each pipeline stage shifts one step towards the
    ///    head into whatever slot the commit (or an earlier shift)
    ///    freed.
    ///
    /// The commit must run before the advance: reversing them would let
    /// a flit traverse pipeline stage *and* register-to-buffer in one
    /// cycle, shortening the link's latency by one and breaking the
    /// two-cycle router calibration.
    ///
    /// Returns a [`DeliverSummary`] for the gated step loop; dense-mode
    /// and unit-test callers are free to ignore it.
    pub fn deliver(&mut self) -> DeliverSummary {
        // Fast path: an empty link has nothing to move. The common case on
        // large meshes — most links idle most cycles. (The gated step
        // loop hoists this check entirely by never visiting such links.)
        if self.occupancy == 0 {
            return DeliverSummary::default();
        }
        // Walk only lanes that hold a flit: an empty lane's sub-phases
        // are pure no-ops (empty register, empty pipeline, and no
        // counter or readiness contribution), so skipping clear bits
        // changes nothing observable.
        let mut occupied = self.lane_occ;
        while occupied != 0 {
            let v = occupied.trailing_zeros() as usize;
            occupied &= occupied - 1;
            let lane = &mut self.lanes[v];
            // Phase 1: commit the head register into the input buffer.
            if lane.reg.is_some() {
                self.busy_cycles += 1;
                if lane.buf.is_full() {
                    self.stall_cycles += 1;
                } else {
                    lane.buf.push(lane.reg.take().unwrap());
                    lane.delivered += 1;
                    self.delivered += 1;
                    self.buf_occ |= 1 << v;
                }
            }
            // Phase 2: advance pipeline stages head-first (index 0 feeds
            // the lane register).
            let stages = lane.stages as usize;
            if stages > 0 {
                if lane.reg.is_none() {
                    lane.reg = lane.pipe[0].take();
                }
                for i in 1..stages {
                    if lane.pipe[i - 1].is_none() {
                        lane.pipe[i - 1] = lane.pipe[i].take();
                    }
                }
            }
        }
        // Deliver moves flits *within* the link, so occupancy is exactly
        // what it was at entry (> 0): the link stays active until the
        // consumer pops every lane dry.
        DeliverSummary {
            still_active: true,
            consumer_ready: self.buf_occ != 0,
        }
    }

    /// Consumer-side: peek the head of lane 0's input buffer
    /// (single-lane convenience).
    #[inline]
    pub fn peek(&self) -> Option<&T> {
        self.peek_vc(0)
    }

    /// Consumer-side: peek the head of lane `vc`'s input buffer.
    #[inline]
    pub fn peek_vc(&self, vc: usize) -> Option<&T> {
        debug_assert!(vc < self.nlanes as usize, "lane {vc} out of range");
        self.lanes[vc].buf.front()
    }

    /// Consumer-side: pop the head of lane 0's input buffer
    /// (single-lane convenience).
    #[inline]
    pub fn pop(&mut self) -> Option<T> {
        self.pop_vc(0)
    }

    /// Consumer-side: pop the head of lane `vc`'s input buffer.
    #[inline]
    pub fn pop_vc(&mut self, vc: usize) -> Option<T> {
        debug_assert!(vc < self.nlanes as usize, "lane {vc} out of range");
        let lane = &mut self.lanes[vc];
        let flit = lane.buf.pop();
        if flit.is_some() {
            lane.occ -= 1;
            self.occupancy -= 1;
            if lane.buf.is_empty() {
                self.buf_occ &= !(1 << vc);
            }
            if lane.occ == 0 {
                self.lane_occ &= !(1 << vc);
            }
        }
        flit
    }

    /// Bitmask of lanes whose consumer buffer holds at least one
    /// delivered flit (bit `v` ⇔ [`Self::peek_vc`]`(v)` would return
    /// `Some`). Maintained incrementally, so consumers (the router's
    /// route-compute pass) skip empty lanes without probing each one.
    #[inline]
    pub fn occupied_lanes(&self) -> u32 {
        self.buf_occ as u32
    }

    /// Number of flits waiting in the input buffers of all lanes.
    #[inline]
    pub fn buffered(&self) -> usize {
        self.lanes[..self.nlanes as usize].iter().map(|l| l.buf.len()).sum()
    }

    /// Number of flits waiting in lane `vc`'s input buffer.
    #[inline]
    pub fn buffered_vc(&self, vc: usize) -> usize {
        debug_assert!(vc < self.nlanes as usize, "lane {vc} out of range");
        self.lanes[vc].buf.len()
    }

    /// Flits that completed delivery into lane `vc`'s buffer since
    /// construction (per-VC occupancy instrumentation: the dateline
    /// tests pin that wrap-crossing traffic really rides lane 1).
    #[inline]
    pub fn lane_delivered(&self, vc: usize) -> u64 {
        self.lanes[vc].delivered
    }

    /// True when no flit is anywhere in the link (any lane's register,
    /// pipeline or buffer) — used for drain detection. O(1) via the
    /// occupancy counter.
    #[inline]
    pub fn is_idle(&self) -> bool {
        debug_assert_eq!(
            self.occupancy == 0,
            self.lanes.iter().all(|l| {
                l.reg.is_none() && l.buf.is_empty() && l.pipe.iter().all(Option::is_none)
            }),
            "occupancy counter out of sync"
        );
        debug_assert_eq!(
            self.occupancy == 0,
            self.lane_occ == 0,
            "lane-occupancy bitmask out of sync"
        );
        self.occupancy == 0
    }

    /// Flits currently inside the link (all lanes: registers + pipelines
    /// + buffers).
    #[inline]
    pub fn occupancy(&self) -> u32 {
        self.occupancy
    }

    /// Clock-gating predicate: true when stepping this link would be a
    /// no-op (no flit anywhere inside it). The gated step loop drops
    /// quiescent links from the active set; unlike [`Self::is_idle`]
    /// this is the raw counter check with no debug cross-validation, so
    /// it stays branch-cheap inside per-cycle sweeps.
    #[inline]
    pub fn is_quiescent(&self) -> bool {
        self.occupancy == 0
    }

    /// Total pipeline latency of the link in cycles (1 + extra stages;
    /// identical for every lane).
    pub fn latency(&self) -> usize {
        1 + self.lanes[0].stages as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_cycle_hop() {
        let mut l: Link<u32> = Link::new(2);
        assert!(l.can_offer());
        l.offer(7);
        // Not yet visible to the consumer.
        assert_eq!(l.peek(), None);
        l.deliver();
        assert_eq!(l.peek(), Some(&7));
        assert_eq!(l.pop(), Some(7));
        assert!(l.is_idle());
    }

    #[test]
    fn backpressure_stalls_register() {
        let mut l: Link<u32> = Link::new(1);
        l.offer(1);
        l.deliver(); // 1 -> buf
        l.offer(2);
        l.deliver(); // buf full: 2 stays in reg
        assert!(!l.can_offer());
        assert_eq!(l.stall_cycles, 1);
        assert_eq!(l.pop(), Some(1));
        l.deliver(); // now 2 lands
        assert_eq!(l.pop(), Some(2));
    }

    #[test]
    fn pipeline_adds_latency() {
        let mut l: Link<u32> = Link::with_pipeline(2, 2);
        assert_eq!(l.latency(), 3);
        l.offer(9);
        l.deliver();
        assert_eq!(l.peek(), None);
        l.deliver();
        assert_eq!(l.peek(), None);
        l.deliver();
        assert_eq!(l.pop(), Some(9));
    }

    #[test]
    fn pipeline_streams_back_to_back() {
        let mut l: Link<u32> = Link::with_pipeline(4, 1);
        // Offer a flit every cycle; after the fill latency one must arrive
        // per cycle (full throughput despite extra stages).
        let mut received = Vec::new();
        for i in 0..6u32 {
            if l.can_offer() {
                l.offer(i);
            }
            l.deliver();
            if let Some(v) = l.pop() {
                received.push(v);
            }
        }
        // Fill latency of one extra stage, then one flit per cycle.
        assert_eq!(received, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn delivered_counts() {
        let mut l: Link<u32> = Link::new(4);
        for i in 0..3 {
            l.offer(i);
            l.deliver();
        }
        assert_eq!(l.delivered, 3);
        assert_eq!(l.buffered(), 3);
    }

    #[test]
    #[should_panic(expected = "busy link")]
    fn double_offer_panics() {
        let mut l: Link<u32> = Link::new(1);
        l.offer(1);
        l.offer(2);
    }

    /// Multi-stage timing, beat by beat: a 3-stage pipelined link has
    /// latency 4 (3 pipeline shifts + the register-to-buffer commit), one
    /// flit advances exactly one stage per deliver, and sustained offering
    /// still yields one delivery per cycle after the fill latency.
    #[test]
    fn multi_stage_pipeline_exact_timing() {
        let mut l: Link<u32> = Link::with_pipeline(4, 3);
        assert_eq!(l.latency(), 4);
        l.offer(1);
        for cycle in 1..=4u32 {
            assert_eq!(l.peek(), None, "too early at cycle {cycle}");
            l.deliver();
        }
        assert_eq!(l.pop(), Some(1), "arrives exactly at latency()");
        // Back-to-back streaming: offer every cycle; after the fill the
        // link must sustain one flit per cycle despite the extra stages.
        let mut got = Vec::new();
        for i in 10..20u32 {
            assert!(l.can_offer(), "full-throughput link never backpressures");
            l.offer(i);
            l.deliver();
            if let Some(v) = l.pop() {
                got.push(v);
            }
        }
        assert_eq!(got, vec![10, 11, 12, 13, 14, 15, 16], "fill latency then 1/cycle");
        assert_eq!(l.occupancy(), 3, "three flits still in flight");
        // Drain the tail.
        for _ in 0..4 {
            l.deliver();
            while let Some(v) = l.pop() {
                got.push(v);
            }
        }
        assert_eq!(got.last(), Some(&19));
        assert!(l.is_idle());
    }

    /// Gated-stepping contract on a multi-stage link: the deliver summary
    /// must report `still_active` every cycle a flit is anywhere in the
    /// pipeline — including the cycles where it has not yet reached the
    /// consumer buffer — and must only report `consumer_ready` once the
    /// flit lands. Dropping the link from the active set on any earlier
    /// cycle would strand the flit mid-pipeline forever.
    #[test]
    fn pipeline_flit_keeps_link_active_until_delivered() {
        let mut l: Link<u32> = Link::with_pipeline(2, 3);
        l.offer(77);
        // Cycles 1..=3: the flit walks the pipeline towards the register;
        // nothing is in the buffer yet but the link must stay active.
        for cycle in 1..=3u32 {
            let s = l.deliver();
            assert!(s.still_active, "mid-pipeline at cycle {cycle}");
            assert!(!s.consumer_ready, "not yet delivered at cycle {cycle}");
            assert!(!l.is_quiescent());
        }
        // Cycle 4: the register commits into the buffer — consumer wake.
        let s = l.deliver();
        assert!(s.still_active && s.consumer_ready, "delivery cycle wakes consumer");
        // The consumer pops; only now may the link leave the active set.
        assert_eq!(l.pop(), Some(77));
        assert!(l.is_quiescent());
        let s = l.deliver();
        assert!(!s.still_active && !s.consumer_ready, "empty link reports quiescent");
    }

    /// An unpopped delivered flit also keeps the link active: the summary
    /// must keep reporting both flags while the buffer holds it (a stalled
    /// consumer must keep being woken until it drains the buffer).
    #[test]
    fn buffered_flit_keeps_link_active_while_unconsumed() {
        let mut l: Link<u32> = Link::new(2);
        l.offer(5);
        let s = l.deliver();
        assert!(s.still_active && s.consumer_ready);
        for _ in 0..3 {
            // Consumer stalls: repeated delivers keep signalling.
            let s = l.deliver();
            assert!(s.still_active && s.consumer_ready);
        }
        assert_eq!(l.pop(), Some(5));
        assert!(l.is_quiescent());
    }

    /// Backpressure capacity: a stalled consumer lets the link absorb
    /// exactly buf_depth + 1 (register) + stages flits before ready drops.
    #[test]
    fn pipeline_capacity_under_stall() {
        let mut l: Link<u32> = Link::with_pipeline(2, 2);
        let mut accepted = 0u32;
        for i in 0..10u32 {
            if !l.can_offer() {
                break;
            }
            l.offer(i);
            accepted += 1;
            l.deliver();
        }
        assert_eq!(accepted, 5, "buf 2 + reg 1 + 2 stages");
        assert_eq!(l.occupancy(), 5);
        // Consumer drains: everything comes out in order, nothing lost.
        let mut got = Vec::new();
        for _ in 0..10 {
            if let Some(v) = l.pop() {
                got.push(v);
            }
            l.deliver();
        }
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
        assert!(l.is_idle());
    }

    // ------------------------------------------------- virtual channels

    /// The buffer split: a 2-VC link divides the configured depth across
    /// lanes, with a floor of one slot per lane.
    #[test]
    fn vc_lanes_split_buffer_capacity() {
        let l: Link<u32> = Link::with_vcs(4, 2, 0);
        assert_eq!(l.vcs(), 2);
        let mut l = l;
        for i in 0..2 {
            l.offer_vc(0, i);
            l.deliver();
        }
        assert_eq!(l.buffered_vc(0), 2, "half the depth per lane");
        l.offer_vc(0, 9);
        l.deliver(); // lane 0 buffer full: 9 stalls in lane 0's register
        assert!(!l.can_offer_vc(0));
        assert!(l.can_offer_vc(1), "lane 1 unaffected");
        // Depth 1 floor: vcs > depth still yields one slot per lane.
        let tiny: Link<u32> = Link::with_vcs(1, 2, 0);
        assert!(tiny.can_offer_vc(1), "every lane gets at least one slot");
    }

    /// The isolation property the dateline scheme relies on: a flit
    /// stalled on lane 0 (full buffer, unconsumed) must not delay a
    /// lane-1 flit by a single cycle.
    #[test]
    fn vc_stall_isolation() {
        let mut l: Link<u32> = Link::with_vcs(2, 2, 0);
        // Fill lane 0: buffer (1 slot) + register.
        l.offer_vc(0, 10);
        l.deliver();
        l.offer_vc(0, 11);
        l.deliver(); // lane 0 register stalls (buffer full)
        assert!(!l.can_offer_vc(0));
        let stalls_before = l.stall_cycles;
        // Lane 1 traffic flows at full single-cycle latency throughout.
        for i in 20..23u32 {
            assert!(l.can_offer_vc(1));
            l.offer_vc(1, i);
            l.deliver();
            assert_eq!(l.pop_vc(1), Some(i), "lane 1 unaffected by lane 0 stall");
        }
        assert!(l.stall_cycles > stalls_before, "lane 0 kept stalling meanwhile");
        // Drain lane 0: nothing was lost or reordered.
        assert_eq!(l.pop_vc(0), Some(10));
        l.deliver();
        assert_eq!(l.pop_vc(0), Some(11));
        assert!(l.is_idle());
        assert_eq!(l.lane_delivered(0), 2);
        assert_eq!(l.lane_delivered(1), 3);
    }

    /// Pipelined multi-VC links: each lane has its own stages, so a
    /// stalled lane parks flits mid-pipeline without touching the other
    /// lane, and the aggregate occupancy/gating contract still holds.
    #[test]
    fn vc_pipelined_lanes_and_gating() {
        let mut l: Link<u32> = Link::with_vcs(2, 2, 1);
        assert_eq!(l.latency(), 2);
        l.offer_vc(1, 5);
        let s = l.deliver(); // 5 advances to lane 1's register
        assert!(s.still_active && !s.consumer_ready);
        l.offer_vc(0, 6);
        let s = l.deliver(); // 5 lands; 6 advances
        assert!(s.consumer_ready);
        assert_eq!(l.peek_vc(1), Some(&5));
        assert_eq!(l.peek_vc(0), None, "lane 0 flit still one stage behind");
        l.deliver();
        assert_eq!(l.pop_vc(0), Some(6));
        assert_eq!(l.pop_vc(1), Some(5));
        assert!(l.is_quiescent());
        assert_eq!(l.occupancy(), 0);
    }

    /// Aggregate instrumentation sums over lanes: `buffered`/`delivered`
    /// see every lane, and `is_idle` only holds when all lanes drained.
    #[test]
    fn vc_aggregate_counters() {
        let mut l: Link<u32> = Link::with_vcs(4, 2, 0);
        l.offer_vc(0, 1);
        l.deliver();
        l.offer_vc(1, 2);
        l.deliver();
        assert_eq!(l.buffered(), 2);
        assert_eq!(l.delivered, 2);
        assert_eq!(l.occupancy(), 2);
        assert_eq!(l.pop_vc(0), Some(1));
        assert!(!l.is_idle(), "lane 1 still holds a flit");
        assert_eq!(l.pop_vc(1), Some(2));
        assert!(l.is_idle());
    }

    /// The non-empty-lane bitmask tracks delivered-and-unconsumed flits
    /// exactly: a bit is set when a flit lands in that lane's buffer and
    /// cleared when the consumer pops the lane dry — in-flight flits
    /// (register/pipeline) do not show.
    #[test]
    fn occupied_lanes_bitmask_tracks_buffers() {
        let mut l: Link<u32> = Link::with_vcs(4, 2, 0);
        assert_eq!(l.occupied_lanes(), 0);
        l.offer_vc(1, 7);
        assert_eq!(l.occupied_lanes(), 0, "in-flight, not yet delivered");
        l.deliver();
        assert_eq!(l.occupied_lanes(), 0b10);
        l.offer_vc(0, 8);
        l.deliver();
        assert_eq!(l.occupied_lanes(), 0b11);
        assert_eq!(l.pop_vc(1), Some(7));
        assert_eq!(l.occupied_lanes(), 0b01);
        assert_eq!(l.pop_vc(0), Some(8));
        assert_eq!(l.occupied_lanes(), 0);
        assert!(l.is_idle());
    }

    #[test]
    #[should_panic(expected = "at most")]
    fn too_many_lanes_panics() {
        let _: Link<u32> = Link::with_vcs(8, MAX_LANES + 1, 0);
    }
}
