//! Point-to-point link with a one-entry register stage and a bounded
//! downstream input FIFO — the unit of connectivity for every physical
//! channel in the NoC.

use crate::util::fifo::Fifo;

/// Opaque link identifier (index into the engine's link table).
pub type LinkId = usize;

/// A unidirectional link: `reg` models the wire + output register of the
/// producer, `buf` models the consumer's input buffer. Transfer from `reg`
/// to `buf` happens in the engine's deliver phase, one cycle after the
/// producer offered the flit.
#[derive(Debug, Clone)]
pub struct Link<T> {
    reg: Option<T>,
    buf: Fifo<T>,
    /// Extra pipeline registers modelling long routing channels / elastic
    /// output buffers. `pipeline[0]` feeds `buf`; new offers enter the tail.
    pipe: Vec<Option<T>>,
    // --- instrumentation --------------------------------------------------
    /// Flits that completed delivery into `buf`.
    pub delivered: u64,
    /// Cycles in which the register held a flit but the buffer was full.
    pub stall_cycles: u64,
    /// Cycles in which the register held a flit (occupancy integral).
    pub busy_cycles: u64,
}

impl<T> Link<T> {
    /// A link whose consumer-side input buffer holds `buf_depth` flits.
    pub fn new(buf_depth: usize) -> Self {
        Link {
            reg: None,
            buf: Fifo::new(buf_depth),
            pipe: Vec::new(),
            delivered: 0,
            stall_cycles: 0,
            busy_cycles: 0,
        }
    }

    /// A link with `extra_stages` additional pipeline registers, modelling
    /// the paper's two-cycle router with output buffers / buffer islands on
    /// long routing channels (§V).
    pub fn with_pipeline(buf_depth: usize, extra_stages: usize) -> Self {
        let mut l = Link::new(buf_depth);
        l.pipe = (0..extra_stages).map(|_| None).collect();
        l
    }

    /// Can the producer offer a flit this cycle? (valid/ready at the
    /// producer end: true when the entry register is empty.)
    #[inline]
    pub fn can_offer(&self) -> bool {
        if let Some(tail) = self.pipe.last() {
            tail.is_none()
        } else {
            self.reg.is_none()
        }
    }

    /// Producer offers a flit. Panics if `!can_offer()` — the caller models
    /// the valid/ready handshake and must check first.
    #[inline]
    pub fn offer(&mut self, flit: T) {
        if let Some(tail) = self.pipe.last_mut() {
            assert!(tail.is_none(), "offer on busy link (missing can_offer)");
            *tail = Some(flit);
        } else {
            assert!(self.reg.is_none(), "offer on busy link (missing can_offer)");
            self.reg = Some(flit);
        }
    }

    /// Deliver phase: advance pipeline stages and move the head register
    /// into the input buffer when space is available.
    pub fn deliver(&mut self) {
        if self.reg.is_some() {
            self.busy_cycles += 1;
        }
        // Head register -> input buffer.
        if self.reg.is_some() {
            if self.buf.is_full() {
                self.stall_cycles += 1;
            } else {
                self.buf.push(self.reg.take().unwrap());
                self.delivered += 1;
            }
        }
        // Shift the pipeline towards the head (index 0 is closest to `reg`).
        for i in 0..self.pipe.len() {
            if self.reg.is_none() && i == 0 {
                self.reg = self.pipe[0].take();
            } else if i > 0 && self.pipe[i - 1].is_none() {
                self.pipe[i - 1] = self.pipe[i].take();
            }
        }
    }

    /// Consumer-side: peek the head of the input buffer.
    #[inline]
    pub fn peek(&self) -> Option<&T> {
        self.buf.front()
    }

    /// Consumer-side: pop the head of the input buffer.
    #[inline]
    pub fn pop(&mut self) -> Option<T> {
        self.buf.pop()
    }

    /// Number of flits waiting in the input buffer.
    #[inline]
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// True when no flit is anywhere in the link (register, pipeline or
    /// buffer) — used for drain detection.
    pub fn is_idle(&self) -> bool {
        self.reg.is_none() && self.buf.is_empty() && self.pipe.iter().all(Option::is_none)
    }

    /// Total pipeline latency of the link in cycles (1 + extra stages).
    pub fn latency(&self) -> usize {
        1 + self.pipe.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_cycle_hop() {
        let mut l: Link<u32> = Link::new(2);
        assert!(l.can_offer());
        l.offer(7);
        // Not yet visible to the consumer.
        assert_eq!(l.peek(), None);
        l.deliver();
        assert_eq!(l.peek(), Some(&7));
        assert_eq!(l.pop(), Some(7));
        assert!(l.is_idle());
    }

    #[test]
    fn backpressure_stalls_register() {
        let mut l: Link<u32> = Link::new(1);
        l.offer(1);
        l.deliver(); // 1 -> buf
        l.offer(2);
        l.deliver(); // buf full: 2 stays in reg
        assert!(!l.can_offer());
        assert_eq!(l.stall_cycles, 1);
        assert_eq!(l.pop(), Some(1));
        l.deliver(); // now 2 lands
        assert_eq!(l.pop(), Some(2));
    }

    #[test]
    fn pipeline_adds_latency() {
        let mut l: Link<u32> = Link::with_pipeline(2, 2);
        assert_eq!(l.latency(), 3);
        l.offer(9);
        l.deliver();
        assert_eq!(l.peek(), None);
        l.deliver();
        assert_eq!(l.peek(), None);
        l.deliver();
        assert_eq!(l.pop(), Some(9));
    }

    #[test]
    fn pipeline_streams_back_to_back() {
        let mut l: Link<u32> = Link::with_pipeline(4, 1);
        // Offer a flit every cycle; after the fill latency one must arrive
        // per cycle (full throughput despite extra stages).
        let mut received = Vec::new();
        for i in 0..6u32 {
            if l.can_offer() {
                l.offer(i);
            }
            l.deliver();
            if let Some(v) = l.pop() {
                received.push(v);
            }
        }
        // Fill latency of one extra stage, then one flit per cycle.
        assert_eq!(received, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn delivered_counts() {
        let mut l: Link<u32> = Link::new(4);
        for i in 0..3 {
            l.offer(i);
            l.deliver();
        }
        assert_eq!(l.delivered, 3);
        assert_eq!(l.buffered(), 3);
    }

    #[test]
    #[should_panic(expected = "busy link")]
    fn double_offer_panics() {
        let mut l: Link<u32> = Link::new(1);
        l.offer(1);
        l.offer(2);
    }
}
