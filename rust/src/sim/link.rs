//! Point-to-point link with a one-entry register stage and a bounded
//! downstream input FIFO — the unit of connectivity for every physical
//! channel in the NoC.

use crate::util::fifo::Fifo;

/// Opaque link identifier (index into the engine's link table).
pub type LinkId = usize;

/// What a [`Link::deliver`] call did, for the activity-gated step loop
/// (see `docs/performance.md`): whether the link still holds flits (it
/// must stay in the active set — a flit parked in the last pipeline
/// stage or stalled in the register keeps the link "clocked" until it
/// is delivered *and* consumed), and whether the consumer's input
/// buffer now holds at least one flit (the wake-up edge towards the
/// downstream router / NI).
#[derive(Debug, Clone, Copy, Default)]
pub struct DeliverSummary {
    /// Flits remain anywhere in the link (register, pipeline or buffer)
    /// after this deliver — keep the link in the active set.
    pub still_active: bool,
    /// The consumer's input buffer is non-empty after this deliver —
    /// wake the component that reads this link.
    pub consumer_ready: bool,
}

/// A unidirectional link: `reg` models the wire + output register of the
/// producer, `buf` models the consumer's input buffer. Transfer from `reg`
/// to `buf` happens in the engine's deliver phase, one cycle after the
/// producer offered the flit.
#[derive(Debug, Clone)]
pub struct Link<T> {
    reg: Option<T>,
    buf: Fifo<T>,
    /// Extra pipeline registers modelling long routing channels / elastic
    /// output buffers. `pipeline[0]` feeds `buf`; new offers enter the tail.
    pipe: Vec<Option<T>>,
    /// Flits currently anywhere in the link (register + pipeline + buffer).
    /// Kept incrementally so `is_idle` is O(1) — the drain detector runs
    /// every cycle over every link and must not rescan storage.
    occupancy: u32,
    // --- instrumentation --------------------------------------------------
    /// Flits that completed delivery into `buf`.
    pub delivered: u64,
    /// Cycles in which the register held a flit but the buffer was full.
    pub stall_cycles: u64,
    /// Cycles in which the register held a flit (occupancy integral).
    pub busy_cycles: u64,
}

impl<T> Link<T> {
    /// A link whose consumer-side input buffer holds `buf_depth` flits.
    pub fn new(buf_depth: usize) -> Self {
        Link {
            reg: None,
            buf: Fifo::new(buf_depth),
            pipe: Vec::new(),
            occupancy: 0,
            delivered: 0,
            stall_cycles: 0,
            busy_cycles: 0,
        }
    }

    /// A link with `extra_stages` additional pipeline registers, modelling
    /// the paper's two-cycle router with output buffers / buffer islands on
    /// long routing channels (§V).
    pub fn with_pipeline(buf_depth: usize, extra_stages: usize) -> Self {
        let mut l = Link::new(buf_depth);
        l.pipe = (0..extra_stages).map(|_| None).collect();
        l
    }

    /// Can the producer offer a flit this cycle? (valid/ready at the
    /// producer end: true when the entry register is empty.)
    #[inline]
    pub fn can_offer(&self) -> bool {
        if let Some(tail) = self.pipe.last() {
            tail.is_none()
        } else {
            self.reg.is_none()
        }
    }

    /// Producer offers a flit. Panics if `!can_offer()` — the caller models
    /// the valid/ready handshake and must check first.
    #[inline]
    pub fn offer(&mut self, flit: T) {
        if let Some(tail) = self.pipe.last_mut() {
            assert!(tail.is_none(), "offer on busy link (missing can_offer)");
            *tail = Some(flit);
        } else {
            assert!(self.reg.is_none(), "offer on busy link (missing can_offer)");
            self.reg = Some(flit);
        }
        self.occupancy += 1;
    }

    /// Deliver phase, in two explicit sub-phases evaluated head-first so
    /// every register advances by at most one stage per cycle (all stages
    /// clock simultaneously in RTL; head-first in-cycle evaluation models
    /// exactly that):
    ///
    /// 1. **commit** — the head register moves into the consumer's input
    ///    buffer when it has space (ready asserted); otherwise the register
    ///    stalls and backpressure propagates up the pipeline;
    /// 2. **advance** — each pipeline stage shifts one step towards the
    ///    head into whatever slot the commit (or an earlier shift) freed.
    ///
    /// The commit must run before the advance: reversing them would let a
    /// flit traverse pipeline stage *and* register-to-buffer in one cycle,
    /// shortening the link's latency by one and breaking the two-cycle
    /// router calibration.
    ///
    /// Returns a [`DeliverSummary`] for the gated step loop; dense-mode
    /// and unit-test callers are free to ignore it.
    pub fn deliver(&mut self) -> DeliverSummary {
        // Fast path: an empty link has nothing to move. The common case on
        // large meshes — most links idle most cycles. (The gated step
        // loop hoists this check entirely by never visiting such links.)
        if self.occupancy == 0 {
            return DeliverSummary::default();
        }
        // Phase 1: commit the head register into the input buffer.
        if self.reg.is_some() {
            self.busy_cycles += 1;
            if self.buf.is_full() {
                self.stall_cycles += 1;
            } else {
                self.buf.push(self.reg.take().unwrap());
                self.delivered += 1;
            }
        }
        // Phase 2: advance pipeline stages head-first (index 0 feeds `reg`).
        if !self.pipe.is_empty() {
            if self.reg.is_none() {
                self.reg = self.pipe[0].take();
            }
            for i in 1..self.pipe.len() {
                if self.pipe[i - 1].is_none() {
                    self.pipe[i - 1] = self.pipe[i].take();
                }
            }
        }
        // Deliver moves flits *within* the link, so occupancy is exactly
        // what it was at entry (> 0): the link stays active until the
        // consumer pops the buffer dry.
        DeliverSummary {
            still_active: true,
            consumer_ready: !self.buf.is_empty(),
        }
    }

    /// Consumer-side: peek the head of the input buffer.
    #[inline]
    pub fn peek(&self) -> Option<&T> {
        self.buf.front()
    }

    /// Consumer-side: pop the head of the input buffer.
    #[inline]
    pub fn pop(&mut self) -> Option<T> {
        let flit = self.buf.pop();
        if flit.is_some() {
            self.occupancy -= 1;
        }
        flit
    }

    /// Number of flits waiting in the input buffer.
    #[inline]
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// True when no flit is anywhere in the link (register, pipeline or
    /// buffer) — used for drain detection. O(1) via the occupancy counter.
    #[inline]
    pub fn is_idle(&self) -> bool {
        debug_assert_eq!(
            self.occupancy == 0,
            self.reg.is_none() && self.buf.is_empty() && self.pipe.iter().all(Option::is_none),
            "occupancy counter out of sync"
        );
        self.occupancy == 0
    }

    /// Flits currently inside the link (register + pipeline + buffer).
    #[inline]
    pub fn occupancy(&self) -> u32 {
        self.occupancy
    }

    /// Clock-gating predicate: true when stepping this link would be a
    /// no-op (no flit anywhere inside it). The gated step loop drops
    /// quiescent links from the active set; unlike [`Self::is_idle`]
    /// this is the raw counter check with no debug cross-validation, so
    /// it stays branch-cheap inside per-cycle sweeps.
    #[inline]
    pub fn is_quiescent(&self) -> bool {
        self.occupancy == 0
    }

    /// Total pipeline latency of the link in cycles (1 + extra stages).
    pub fn latency(&self) -> usize {
        1 + self.pipe.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_cycle_hop() {
        let mut l: Link<u32> = Link::new(2);
        assert!(l.can_offer());
        l.offer(7);
        // Not yet visible to the consumer.
        assert_eq!(l.peek(), None);
        l.deliver();
        assert_eq!(l.peek(), Some(&7));
        assert_eq!(l.pop(), Some(7));
        assert!(l.is_idle());
    }

    #[test]
    fn backpressure_stalls_register() {
        let mut l: Link<u32> = Link::new(1);
        l.offer(1);
        l.deliver(); // 1 -> buf
        l.offer(2);
        l.deliver(); // buf full: 2 stays in reg
        assert!(!l.can_offer());
        assert_eq!(l.stall_cycles, 1);
        assert_eq!(l.pop(), Some(1));
        l.deliver(); // now 2 lands
        assert_eq!(l.pop(), Some(2));
    }

    #[test]
    fn pipeline_adds_latency() {
        let mut l: Link<u32> = Link::with_pipeline(2, 2);
        assert_eq!(l.latency(), 3);
        l.offer(9);
        l.deliver();
        assert_eq!(l.peek(), None);
        l.deliver();
        assert_eq!(l.peek(), None);
        l.deliver();
        assert_eq!(l.pop(), Some(9));
    }

    #[test]
    fn pipeline_streams_back_to_back() {
        let mut l: Link<u32> = Link::with_pipeline(4, 1);
        // Offer a flit every cycle; after the fill latency one must arrive
        // per cycle (full throughput despite extra stages).
        let mut received = Vec::new();
        for i in 0..6u32 {
            if l.can_offer() {
                l.offer(i);
            }
            l.deliver();
            if let Some(v) = l.pop() {
                received.push(v);
            }
        }
        // Fill latency of one extra stage, then one flit per cycle.
        assert_eq!(received, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn delivered_counts() {
        let mut l: Link<u32> = Link::new(4);
        for i in 0..3 {
            l.offer(i);
            l.deliver();
        }
        assert_eq!(l.delivered, 3);
        assert_eq!(l.buffered(), 3);
    }

    #[test]
    #[should_panic(expected = "busy link")]
    fn double_offer_panics() {
        let mut l: Link<u32> = Link::new(1);
        l.offer(1);
        l.offer(2);
    }

    /// Multi-stage timing, beat by beat: a 3-stage pipelined link has
    /// latency 4 (3 pipeline shifts + the register-to-buffer commit), one
    /// flit advances exactly one stage per deliver, and sustained offering
    /// still yields one delivery per cycle after the fill latency.
    #[test]
    fn multi_stage_pipeline_exact_timing() {
        let mut l: Link<u32> = Link::with_pipeline(4, 3);
        assert_eq!(l.latency(), 4);
        l.offer(1);
        for cycle in 1..=4u32 {
            assert_eq!(l.peek(), None, "too early at cycle {cycle}");
            l.deliver();
        }
        assert_eq!(l.pop(), Some(1), "arrives exactly at latency()");
        // Back-to-back streaming: offer every cycle; after the fill the
        // link must sustain one flit per cycle despite the extra stages.
        let mut got = Vec::new();
        for i in 10..20u32 {
            assert!(l.can_offer(), "full-throughput link never backpressures");
            l.offer(i);
            l.deliver();
            if let Some(v) = l.pop() {
                got.push(v);
            }
        }
        assert_eq!(got, vec![10, 11, 12, 13, 14, 15, 16], "fill latency then 1/cycle");
        assert_eq!(l.occupancy(), 3, "three flits still in flight");
        // Drain the tail.
        for _ in 0..4 {
            l.deliver();
            while let Some(v) = l.pop() {
                got.push(v);
            }
        }
        assert_eq!(got.last(), Some(&19));
        assert!(l.is_idle());
    }

    /// Gated-stepping contract on a multi-stage link: the deliver summary
    /// must report `still_active` every cycle a flit is anywhere in the
    /// pipeline — including the cycles where it has not yet reached the
    /// consumer buffer — and must only report `consumer_ready` once the
    /// flit lands. Dropping the link from the active set on any earlier
    /// cycle would strand the flit mid-pipeline forever.
    #[test]
    fn pipeline_flit_keeps_link_active_until_delivered() {
        let mut l: Link<u32> = Link::with_pipeline(2, 3);
        l.offer(77);
        // Cycles 1..=3: the flit walks the pipeline towards the register;
        // nothing is in the buffer yet but the link must stay active.
        for cycle in 1..=3u32 {
            let s = l.deliver();
            assert!(s.still_active, "mid-pipeline at cycle {cycle}");
            assert!(!s.consumer_ready, "not yet delivered at cycle {cycle}");
            assert!(!l.is_quiescent());
        }
        // Cycle 4: the register commits into the buffer — consumer wake.
        let s = l.deliver();
        assert!(s.still_active && s.consumer_ready, "delivery cycle wakes consumer");
        // The consumer pops; only now may the link leave the active set.
        assert_eq!(l.pop(), Some(77));
        assert!(l.is_quiescent());
        let s = l.deliver();
        assert!(!s.still_active && !s.consumer_ready, "empty link reports quiescent");
    }

    /// An unpopped delivered flit also keeps the link active: the summary
    /// must keep reporting both flags while the buffer holds it (a stalled
    /// consumer must keep being woken until it drains the buffer).
    #[test]
    fn buffered_flit_keeps_link_active_while_unconsumed() {
        let mut l: Link<u32> = Link::new(2);
        l.offer(5);
        let s = l.deliver();
        assert!(s.still_active && s.consumer_ready);
        for _ in 0..3 {
            // Consumer stalls: repeated delivers keep signalling.
            let s = l.deliver();
            assert!(s.still_active && s.consumer_ready);
        }
        assert_eq!(l.pop(), Some(5));
        assert!(l.is_quiescent());
    }

    /// Backpressure capacity: a stalled consumer lets the link absorb
    /// exactly buf_depth + 1 (register) + stages flits before ready drops.
    #[test]
    fn pipeline_capacity_under_stall() {
        let mut l: Link<u32> = Link::with_pipeline(2, 2);
        let mut accepted = 0u32;
        for i in 0..10u32 {
            if !l.can_offer() {
                break;
            }
            l.offer(i);
            accepted += 1;
            l.deliver();
        }
        assert_eq!(accepted, 5, "buf 2 + reg 1 + 2 stages");
        assert_eq!(l.occupancy(), 5);
        // Consumer drains: everything comes out in order, nothing lost.
        let mut got = Vec::new();
        for _ in 0..10 {
            if let Some(v) = l.pop() {
                got.push(v);
            }
            l.deliver();
        }
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
        assert!(l.is_idle());
    }
}
