//! Cycle-stepped simulation kernel.
//!
//! The simulator is a synchronous model of the RTL: a global cycle counter
//! advances, and every hardware structure steps once per cycle. Hop timing
//! and backpressure are modelled by [`Link`]: per virtual-channel lane, a
//! one-entry register stage in front of a bounded input FIFO (single-VC
//! links — every mesh link — have exactly one lane):
//!
//! ```text
//!   producer --(offer when reg empty)--> [reg] --(deliver when fifo space)--> [input fifo] --> consumer
//! ```
//!
//! Each cycle proceeds in two phases:
//!
//! 1. **deliver** — every link moves its registered flit into the consumer's
//!    input FIFO if there is space (this models the valid/ready handshake at
//!    the downstream input buffer);
//! 2. **step** — every component consumes from its input FIFOs and offers new
//!    flits into the links whose register is empty.
//!
//! Because a flit offered in cycle *t* is only visible to the consumer in
//! cycle *t+1*, every hop costs exactly one cycle — matching the paper's
//! "single-cycle latency due to input buffering" — and there are no
//! combinational loops regardless of component evaluation order.

pub mod link;
pub mod engine;

pub use engine::{Engine, SimStats};
pub use link::{DeliverSummary, Link, LinkId, MAX_LANES, MAX_STAGES};

/// Simulation time in clock cycles.
pub type Cycle = u64;

/// How the per-cycle step loop visits components.
///
/// Both modes are cycle-accurate and produce **byte-identical statistics**
/// (pinned by `tests/gated_equivalence.rs`); they differ only in which
/// components are *visited*, never in what a visited component does.
///
/// * [`SimMode::Gated`] — the default: per-network active-set bitmaps
///   (one bit per link, one per router) model clock gating. A component
///   is stepped only when it held flits last cycle or was written this
///   cycle; wake-up edges propagate at commit time (link → downstream
///   router, router → output link, NI inject → local link). Under sparse
///   traffic most of the fabric is quiescent most cycles, and the loop
///   cost tracks *activity* instead of *fabric size*.
/// * [`SimMode::Dense`] — the reference: every link delivers and every
///   router steps every cycle (a whole network is skipped only when its
///   flit-conservation counter proves it empty). Kept as the
///   differential-testing oracle and for debugging the gating itself.
/// * [`SimMode::Event`] — gated stepping plus **event-driven
///   fast-forward**: components that can become active spontaneously
///   (memory retirements, generator issue windows) register their next
///   interesting cycle in a calendar (`util::calendar`), and when every
///   active set is empty and every NI is provably quiet, `now` jumps
///   directly to the earliest scheduled event. Skipped cycles are
///   provably no-ops, so all statistics stay exactly as if they had
///   been stepped — sparse *time* becomes free, not just sparse space.
///
/// See `docs/performance.md` for the design and the equivalence argument.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SimMode {
    /// Activity-gated stepping (active-set bitmaps; the fast default).
    #[default]
    Gated,
    /// Dense reference stepping (every component, every cycle).
    Dense,
    /// Gated stepping + calendar-driven fast-forward over idle cycles.
    Event,
}

impl SimMode {
    /// Stable lowercase name (config files, CLI, bench reports).
    pub fn name(&self) -> &'static str {
        match self {
            SimMode::Gated => "gated",
            SimMode::Dense => "dense",
            SimMode::Event => "event",
        }
    }
}
