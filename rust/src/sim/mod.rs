//! Cycle-stepped simulation kernel.
//!
//! The simulator is a synchronous model of the RTL: a global cycle counter
//! advances, and every hardware structure steps once per cycle. Hop timing
//! and backpressure are modelled by [`Link`], a one-entry register stage in
//! front of a bounded input FIFO:
//!
//! ```text
//!   producer --(offer when reg empty)--> [reg] --(deliver when fifo space)--> [input fifo] --> consumer
//! ```
//!
//! Each cycle proceeds in two phases:
//!
//! 1. **deliver** — every link moves its registered flit into the consumer's
//!    input FIFO if there is space (this models the valid/ready handshake at
//!    the downstream input buffer);
//! 2. **step** — every component consumes from its input FIFOs and offers new
//!    flits into the links whose register is empty.
//!
//! Because a flit offered in cycle *t* is only visible to the consumer in
//! cycle *t+1*, every hop costs exactly one cycle — matching the paper's
//! "single-cycle latency due to input buffering" — and there are no
//! combinational loops regardless of component evaluation order.

pub mod link;
pub mod engine;

pub use engine::{Engine, SimStats};
pub use link::{Link, LinkId};

/// Simulation time in clock cycles.
pub type Cycle = u64;
