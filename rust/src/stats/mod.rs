//! Measurement infrastructure: latency distributions and bandwidth meters.
//!
//! Every paper experiment reduces to one of these two instruments:
//! Fig. 5a is a [`LatencyRecorder`] over narrow transactions, Fig. 5b a
//! [`BandwidthMeter`] over wide-link payload, §VI-A the mean of a
//! zero-load [`LatencyRecorder`], §VI-B the meter's peak.

use crate::util::json::Json;

/// Online latency statistics with full sample retention (sample counts in
/// these experiments are small: 10²–10⁵).
#[derive(Debug, Clone, Default)]
pub struct LatencyRecorder {
    samples: Vec<u64>,
    sorted: bool,
}

impl LatencyRecorder {
    /// An empty recorder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample.
    pub fn record(&mut self, latency: u64) {
        self.samples.push(latency);
        self.sorted = false;
    }

    /// Number of samples recorded.
    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Arithmetic mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<u64>() as f64 / self.samples.len() as f64
    }

    /// Smallest sample (0 when empty).
    pub fn min(&self) -> u64 {
        self.samples.iter().copied().min().unwrap_or(0)
    }

    /// Largest sample (0 when empty).
    pub fn max(&self) -> u64 {
        self.samples.iter().copied().max().unwrap_or(0)
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples.sort_unstable();
            self.sorted = true;
        }
    }

    /// Percentile in [0, 100]; nearest-rank.
    pub fn percentile(&mut self, p: f64) -> u64 {
        if self.samples.is_empty() {
            return 0;
        }
        self.ensure_sorted();
        let rank = ((p / 100.0) * (self.samples.len() as f64 - 1.0)).floor() as usize;
        self.samples[rank.min(self.samples.len() - 1)]
    }

    /// Median (nearest-rank).
    pub fn p50(&mut self) -> u64 {
        self.percentile(50.0)
    }

    /// 95th percentile (nearest-rank).
    pub fn p95(&mut self) -> u64 {
        self.percentile(95.0)
    }

    /// 99th percentile (nearest-rank).
    pub fn p99(&mut self) -> u64 {
        self.percentile(99.0)
    }

    /// Serialize summary statistics for reports.
    pub fn to_json(&mut self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count() as f64)),
            ("mean", Json::Num(self.mean())),
            ("min", Json::Num(self.min() as f64)),
            ("p50", Json::Num(self.p50() as f64)),
            ("p95", Json::Num(self.p95() as f64)),
            ("p99", Json::Num(self.p99() as f64)),
            ("max", Json::Num(self.max() as f64)),
        ])
    }
}

/// Payload-bandwidth meter for one observation point (e.g. the wide-link
/// ejection at a tile). Utilization is useful payload bits over the link's
/// theoretical peak (width × cycles) — the Fig. 5b metric.
#[derive(Debug, Clone)]
pub struct BandwidthMeter {
    /// Physical payload width of the observed link in bits.
    pub link_bits: u32,
    /// Useful payload bits observed.
    pub payload_bits: u64,
    /// Flits observed.
    pub flits: u64,
    /// First observation cycle (start of the measurement window).
    pub first_cycle: Option<u64>,
    /// Last observation cycle (end of the measurement window).
    pub last_cycle: u64,
}

impl BandwidthMeter {
    /// A meter for a link with `link_bits` of peak payload per cycle.
    pub fn new(link_bits: u32) -> Self {
        BandwidthMeter {
            link_bits,
            payload_bits: 0,
            flits: 0,
            first_cycle: None,
            last_cycle: 0,
        }
    }

    /// Record one delivered flit carrying `payload_bits` useful bits.
    pub fn observe(&mut self, now: u64, payload_bits: u32) {
        self.payload_bits += payload_bits as u64;
        self.flits += 1;
        if self.first_cycle.is_none() {
            self.first_cycle = Some(now);
        }
        self.last_cycle = now;
    }

    /// Active window in cycles (inclusive).
    pub fn window(&self) -> u64 {
        match self.first_cycle {
            Some(f) => self.last_cycle.saturating_sub(f) + 1,
            None => 0,
        }
    }

    /// Effective bandwidth utilization in [0, 1]: payload bits delivered
    /// over the link's peak capacity during the active window.
    pub fn utilization(&self) -> f64 {
        let w = self.window();
        // An empty window (never observed) or a zero-width link would
        // divide by zero; both are "no utilization", not NaN/inf — the
        // value flows into JSON reports, which reject non-finite numbers.
        if w == 0 || self.link_bits == 0 {
            return 0.0;
        }
        self.payload_bits as f64 / (self.link_bits as f64 * w as f64)
    }

    /// Delivered payload bandwidth in Gbps at `freq_ghz`.
    pub fn gbps(&self, freq_ghz: f64) -> f64 {
        let w = self.window();
        if w == 0 {
            return 0.0;
        }
        (self.payload_bits as f64 / w as f64) * freq_ghz
    }

    /// Serialize for reports.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("flits", Json::Num(self.flits as f64)),
            ("payload_bits", Json::Num(self.payload_bits as f64)),
            ("window_cycles", Json::Num(self.window() as f64)),
            ("utilization", Json::Num(self.utilization())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_summary() {
        let mut l = LatencyRecorder::new();
        for v in [10, 20, 30, 40, 50] {
            l.record(v);
        }
        assert_eq!(l.count(), 5);
        assert_eq!(l.mean(), 30.0);
        assert_eq!(l.min(), 10);
        assert_eq!(l.max(), 50);
        assert_eq!(l.p50(), 30);
    }

    #[test]
    fn percentiles_on_larger_set() {
        let mut l = LatencyRecorder::new();
        for v in 1..=100 {
            l.record(v);
        }
        assert_eq!(l.p50(), 50);
        assert_eq!(l.p95(), 95);
        assert_eq!(l.p99(), 99);
    }

    #[test]
    fn empty_recorder_is_zero() {
        let mut l = LatencyRecorder::new();
        assert_eq!(l.mean(), 0.0);
        assert_eq!(l.p99(), 0);
    }

    #[test]
    fn bandwidth_utilization() {
        let mut b = BandwidthMeter::new(512);
        // 8 cycles window, 4 full beats -> 50 % utilization.
        b.observe(0, 512);
        b.observe(2, 512);
        b.observe(4, 512);
        b.observe(7, 512);
        assert_eq!(b.window(), 8);
        assert!((b.utilization() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn bandwidth_gbps() {
        let mut b = BandwidthMeter::new(512);
        for t in 0..10 {
            b.observe(t, 512); // fully utilized
        }
        // 512 bit/cycle at 1.23 GHz = 629.76 Gbps.
        assert!((b.gbps(1.23) - 629.76).abs() < 1e-6);
    }

    #[test]
    fn json_export() {
        let mut l = LatencyRecorder::new();
        l.record(18);
        let j = l.to_json();
        assert_eq!(j.get("count").unwrap().as_u64(), Some(1));
        assert_eq!(j.get("mean").unwrap().as_f64(), Some(18.0));
    }

    /// Empty-window / degenerate-meter regressions: every derived rate
    /// must come back 0.0 — never NaN or inf — because these values feed
    /// JSON reports directly.
    #[test]
    fn empty_window_rates_are_zero_not_nan() {
        let b = BandwidthMeter::new(512);
        assert_eq!(b.window(), 0);
        assert_eq!(b.utilization(), 0.0);
        assert_eq!(b.gbps(1.23), 0.0);
        // A zero-width link (meter observing a header-only stream) must
        // not turn observations into an infinite utilization.
        let mut z = BandwidthMeter::new(0);
        z.observe(0, 0);
        z.observe(3, 0);
        assert!(z.utilization().is_finite());
        assert_eq!(z.utilization(), 0.0);
        assert_eq!(z.gbps(1.23), 0.0);
        // And the serialized form re-parses as numbers, not nulls.
        let j = z.to_json();
        assert_eq!(j.get("utilization").unwrap().as_f64(), Some(0.0));
    }
}
