//! Compute-tile model: the Snitch cluster of the paper's case study (§IV).
//!
//! The paper integrates the NoC into an L1-shared compute cluster with
//! 8 RISC-V worker cores (with FPUs), one DMA-control core, a 128 kB SPM
//! and an 8 kB shared instruction cache. For NoC evaluation only the
//! *traffic behaviour* of that cluster matters, so [`ComputeTile`] models:
//!
//! * the **DMA engine** — a wide-bus generator issuing long INCR bursts
//!   (512-bit beats), programmed by one core;
//! * the **cores** — a narrow-bus generator issuing single-word remote
//!   loads/stores (synchronization, configuration);
//! * the **SPM** — the target memory already attached to the tile's NI
//!   ([`crate::ni::Target`]), remotely accessible from both buses.
//!
//! The zero-load calibration (paper §VI-A: 18-cycle adjacent-tile round
//! trip = 8 router + 1 NI + 9 cluster/memory cycles) lives in the SPM
//! latency constant — see `TargetCfg::spm_default`.

use crate::flit::{BusKind, NodeId};
use crate::noc::NocSystem;
use crate::traffic::{GenCfg, Generator};

/// Static description of the paper's tile (used by the physical model and
/// the reports; the traffic behaviour lives in the generators).
#[derive(Debug, Clone)]
pub struct TileSpec {
    /// RISC-V worker cores (paper: 8).
    pub worker_cores: u32,
    /// DMA-control cores (paper: 1).
    pub dma_cores: u32,
    /// Scratchpad memory (paper: 128 kB).
    pub spm_kib: u32,
    /// Shared instruction cache (paper: 8 kB).
    pub icache_kib: u32,
    /// Core-bus width in bits.
    pub narrow_data_width: u32,
    /// DMA-bus width in bits.
    pub wide_data_width: u32,
}

impl Default for TileSpec {
    fn default() -> Self {
        TileSpec {
            worker_cores: 8,
            dma_cores: 1,
            spm_kib: 128,
            icache_kib: 8,
            narrow_data_width: 64,
            wide_data_width: 512,
        }
    }
}

/// Traffic profile of one tile: what its cores and DMA are doing.
#[derive(Debug, Clone)]
pub struct TileTraffic {
    /// Narrow (core) workload; `None` = cores idle.
    pub core: Option<GenCfg>,
    /// Wide (DMA) workload; `None` = DMA idle.
    pub dma: Option<GenCfg>,
}

impl TileTraffic {
    /// A tile generating no traffic.
    pub fn idle() -> Self {
        TileTraffic {
            core: None,
            dma: None,
        }
    }

    /// The paper's energy experiment (§VI-D): a single 1 kB DMA transfer,
    /// all cores idle except the DMA programmer.
    pub fn single_dma_1kib(dst: NodeId) -> Self {
        TileTraffic {
            core: None,
            dma: Some(GenCfg::dma_burst(dst, 1, true)),
        }
    }
}

/// A live compute tile: generators bound to a tile's initiators.
#[derive(Debug)]
pub struct ComputeTile {
    /// The tile's node id.
    pub node: NodeId,
    /// Static description (cores, SPM, bus widths).
    pub spec: TileSpec,
    /// Live narrow (core) generator, if any.
    pub core_gen: Option<Generator>,
    /// Live wide (DMA) generator, if any.
    pub dma_gen: Option<Generator>,
}

impl ComputeTile {
    /// Bind a traffic profile to a tile (seeds are decorrelated per
    /// node).
    pub fn new(node: NodeId, traffic: TileTraffic) -> Self {
        let mk = |cfg: Option<GenCfg>, bus: BusKind| {
            cfg.map(|mut c| {
                debug_assert_eq!(c.bus, bus);
                // Distinct seed per tile for decorrelated streams.
                c.seed ^= 0x9E37 + node.0 as u64 * 0x1_0001;
                Generator::new(c, node)
            })
        };
        ComputeTile {
            node,
            spec: TileSpec::default(),
            core_gen: mk(traffic.core, BusKind::Narrow),
            dma_gen: mk(traffic.dma, BusKind::Wide),
        }
    }

    /// Step both generators against the system.
    pub fn step(&mut self, sys: &mut NocSystem) {
        if let Some(g) = self.core_gen.as_mut() {
            sys.step_generator(g);
        }
        if let Some(g) = self.dma_gen.as_mut() {
            sys.step_generator(g);
        }
    }

    /// Both generators (where present) have completed.
    pub fn done(&self) -> bool {
        self.core_gen.as_ref().map(Generator::done).unwrap_or(true)
            && self.dma_gen.as_ref().map(Generator::done).unwrap_or(true)
    }

    /// Protocol compliance across both buses.
    pub fn protocol_ok(&self) -> bool {
        self.core_gen
            .as_ref()
            .map(|g| g.monitor.ok())
            .unwrap_or(true)
            && self
                .dma_gen
                .as_ref()
                .map(|g| g.monitor.ok())
                .unwrap_or(true)
    }
}

/// A whole mesh of tiles plus its traffic, stepped as one workload.
/// This is the harness the Fig. 5 experiments and examples drive.
pub struct TiledWorkload {
    /// The simulated NoC.
    pub sys: NocSystem,
    /// One compute tile per topology tile, by node id.
    pub tiles: Vec<ComputeTile>,
}

impl TiledWorkload {
    /// Build from a system and per-tile traffic profiles (index = tile id).
    pub fn new(sys: NocSystem, profiles: Vec<TileTraffic>) -> Self {
        assert_eq!(profiles.len(), sys.topo.num_tiles);
        let tiles = profiles
            .into_iter()
            .enumerate()
            .map(|(i, p)| ComputeTile::new(NodeId(i as u16), p))
            .collect();
        TiledWorkload { sys, tiles }
    }

    /// One global cycle: NoC step, then all tile generators.
    pub fn step(&mut self) {
        self.sys.step();
        for t in &mut self.tiles {
            t.step(&mut self.sys);
        }
    }

    /// All tiles' generators have completed.
    pub fn done(&self) -> bool {
        self.tiles.iter().all(ComputeTile::done)
    }

    /// Run until all generators complete and the network drains, or
    /// `max_cycles` pass. Returns true on completion.
    ///
    /// With [`NocConfig::shards`](crate::noc::NocConfig::shards)
    /// greater than 1, the run executes on the deterministic sharded
    /// engine ([`crate::noc::sharded`]) — same statistics, byte for
    /// byte, at any shard count. Single-stepping entry points
    /// ([`Self::step`], [`Self::run_with_watchdog`]) always run serial.
    pub fn run_to_completion(&mut self, max_cycles: u64) -> bool {
        if self.sys.cfg.shards > 1 {
            return crate::noc::sharded::run_sharded(&mut self.sys, &mut self.tiles, max_cycles);
        }
        for _ in 0..max_cycles {
            if self.done() && self.sys.is_idle() {
                return true;
            }
            self.step();
        }
        self.done() && self.sys.is_idle()
    }

    /// [`Self::run_to_completion`] with a **stalled-cycle watchdog**: if
    /// no flit is ejected anywhere in the system for `stall_window`
    /// consecutive cycles while work remains, the run is declared stuck
    /// and `Err(cycle_of_last_progress)` is returned. `Ok(true)` means
    /// completed-and-drained, `Ok(false)` means the cycle budget ran out
    /// while the system was still (slowly) progressing.
    ///
    /// This is the forward-progress instrument of the wrap-fabric
    /// saturation suite (`tests/vc_deadlock.rs`): a wormhole deadlock on
    /// a torus/ring shows up as an ejection flat-line long before any
    /// multi-million-cycle timeout, and the returned cycle pinpoints
    /// when traffic seized. Pick `stall_window` well above the longest
    /// legitimate quiet gap (memory latency + drain of one burst —
    /// hundreds of cycles, not thousands). Under
    /// [`SimMode::Event`](crate::sim::SimMode) the window is measured
    /// in *simulated* cycles, and a single fast-forwarding step can
    /// legitimately advance `now` past it (e.g. over a
    /// [`DutyCycle`](crate::traffic::DutyCycle) silence) — size the
    /// window above the longest duty period, or run watchdog suites in
    /// gated mode, where a skipped-over idle gap cannot exist.
    ///
    /// A trip is not a bare error: before returning, the verifier's
    /// live wait-for analysis ([`Self::stall_analysis`]) is printed to
    /// stderr — every blocked `(router, input, vc) → (output, vc)`
    /// dependency plus any cycle among them, in the same chain format
    /// static `FV001` findings use.
    ///
    /// ```
    /// use floonoc::cluster::{TileTraffic, TiledWorkload};
    /// use floonoc::flit::NodeId;
    /// use floonoc::noc::{NocConfig, NocSystem};
    /// let sys = NocSystem::new(NocConfig::mesh(2, 1));
    /// let profiles = vec![TileTraffic::single_dma_1kib(NodeId(1)), TileTraffic::idle()];
    /// let mut w = TiledWorkload::new(sys, profiles);
    /// assert_eq!(w.run_with_watchdog(10_000, 1_000), Ok(true));
    /// ```
    pub fn run_with_watchdog(&mut self, max_cycles: u64, stall_window: u64) -> Result<bool, u64> {
        let progress = |w: &TiledWorkload| -> u64 {
            let ejected: u64 = w.sys.counters.iter().map(|c| c.ejected).sum();
            let completed: u64 = w
                .tiles
                .iter()
                .flat_map(|t| [&t.core_gen, &t.dma_gen])
                .flatten()
                .map(|g| g.completed)
                .sum();
            ejected + completed
        };
        let mut last_progress = progress(self);
        let mut last_progress_at = self.sys.now;
        for _ in 0..max_cycles {
            if self.done() && self.sys.is_idle() {
                return Ok(true);
            }
            self.step();
            let p = progress(self);
            if p != last_progress {
                last_progress = p;
                last_progress_at = self.sys.now;
            } else if self.sys.now - last_progress_at >= stall_window {
                eprintln!(
                    "watchdog tripped (no progress since cycle {last_progress_at}):\n{}",
                    self.stall_analysis()
                );
                return Err(last_progress_at);
            }
        }
        Ok(self.done() && self.sys.is_idle())
    }

    /// The verifier's live wait-for analysis of the network's current
    /// state ([`crate::verify::live`]): every blocked
    /// `(router, input, vc) → (output, vc)` dependency, plus any cycle
    /// among them — the same chain format static findings use. Printed
    /// automatically when [`Self::run_with_watchdog`] trips; callers
    /// that match the `Err` themselves can include it in their panic
    /// message.
    pub fn stall_analysis(&self) -> String {
        crate::verify::live::analyze(&self.sys)
    }

    /// All tiles' protocol monitors are clean.
    pub fn protocol_ok(&self) -> bool {
        self.tiles.iter().all(ComputeTile::protocol_ok)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::NocConfig;
    use crate::traffic::Pattern;

    #[test]
    fn single_dma_tile_runs() {
        let sys = NocSystem::new(NocConfig::mesh(2, 1));
        let profiles = vec![
            TileTraffic::single_dma_1kib(NodeId(1)),
            TileTraffic::idle(),
        ];
        let mut w = TiledWorkload::new(sys, profiles);
        assert!(w.run_to_completion(2_000));
        assert!(w.protocol_ok());
        assert_eq!(w.sys.nodes[1].target.stats.writes_served, 1);
    }

    #[test]
    fn all_tiles_active_mesh() {
        // 2×2 mesh, every tile DMA-reads from its +x neighbour while its
        // cores probe the same neighbour — heterogeneous traffic on every
        // link, protocol-checked.
        let sys = NocSystem::new(NocConfig::mesh(2, 2));
        let profiles = (0..4)
            .map(|i| {
                let dst = NodeId(((i as u16) / 2) * 2 + ((i as u16) + 1) % 2);
                TileTraffic {
                    core: Some(GenCfg::narrow_probe(dst, 10)),
                    dma: Some(GenCfg::dma_burst(dst, 4, false)),
                }
            })
            .collect();
        let mut w = TiledWorkload::new(sys, profiles);
        assert!(w.run_to_completion(20_000));
        assert!(w.protocol_ok());
        for t in &w.tiles {
            assert_eq!(t.core_gen.as_ref().unwrap().completed, 10);
            assert_eq!(t.dma_gen.as_ref().unwrap().completed, 4);
        }
    }

    #[test]
    fn uniform_random_all_to_all() {
        let sys = NocSystem::new(NocConfig::mesh(3, 3));
        let profiles = (0..9)
            .map(|_| TileTraffic {
                core: Some(GenCfg {
                    pattern: Pattern::UniformTiles,
                    ..GenCfg::narrow_probe(NodeId(0), 20)
                }),
                dma: None,
            })
            .collect();
        let mut w = TiledWorkload::new(sys, profiles);
        assert!(w.run_to_completion(50_000));
        assert!(w.protocol_ok());
    }

    /// The watchdog's two verdicts: a healthy run completes under a sane
    /// window, and a window smaller than the scenario's legitimate quiet
    /// gaps trips (documenting why callers must size the window above
    /// memory latency + burst drain, not at a handful of cycles).
    #[test]
    fn watchdog_completes_and_trips_by_window() {
        let mk = || {
            let sys = NocSystem::new(NocConfig::mesh(2, 1));
            let profiles = vec![
                TileTraffic {
                    core: Some(GenCfg::narrow_probe(NodeId(1), 3)),
                    dma: None,
                },
                TileTraffic::idle(),
            ];
            TiledWorkload::new(sys, profiles)
        };
        assert_eq!(mk().run_with_watchdog(10_000, 1_000), Ok(true));
        // An 18-cycle zero-load round trip has ejection-free stretches
        // longer than 2 cycles: the undersized window must trip.
        assert!(mk().run_with_watchdog(10_000, 2).is_err());
    }

    #[test]
    fn tile_spec_defaults_match_paper() {
        let s = TileSpec::default();
        assert_eq!(s.worker_cores, 8);
        assert_eq!(s.dma_cores, 1);
        assert_eq!(s.spm_kib, 128);
        assert_eq!(s.icache_kib, 8);
        assert_eq!((s.narrow_data_width, s.wide_data_width), (64, 512));
    }
}
