//! Flit header and payload types.
//!
//! All physical networks carry the same Rust flit type; *which* network a
//! payload class rides on is the Table-I mapping implemented by
//! [`ChannelClass::phys_link`]. This mirrors the hardware, where the three
//! links differ in wire count but the routers are payload-agnostic — and it
//! lets the wide-only baseline (§VI, Fig. 5 comparison) reuse the exact
//! same router/NI machinery with a different mapping.

use crate::axi::{AxReq, AxiId, BResp, RBeat, WBeat};

/// Node identifier in the network (tile or memory controller).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u16);

/// (x, y) mesh coordinate, used by XY routing.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Coord {
    /// Column (0 = west edge).
    pub x: u8,
    /// Row (0 = south edge).
    pub y: u8,
}

impl Coord {
    /// Build a coordinate.
    pub fn new(x: u8, y: u8) -> Self {
        Coord { x, y }
    }
}

/// Parallel header lines present on every flit (paper Fig. 2): routing
/// (dst/src), ordering (rob index + whether the response must consult the
/// ROB), atomic marker, and `last` for wormhole packets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Header {
    /// Destination node (route-table index).
    pub dst: NodeId,
    /// Source node (response return address).
    pub src: NodeId,
    /// Slot index into the initiator's ROB, allocated at injection and
    /// echoed by the response (the paper's "unique identifier").
    pub rob_idx: u32,
    /// True when ROB space was reserved for the response.
    pub rob_req: bool,
    /// Atomic transaction marker (separate meta buffers at the target NI).
    pub atomic: bool,
    /// Wormhole: final flit of the packet (single-flit packets set it).
    pub last: bool,
}

/// One flit: parallel header + payload, plus an injection timestamp used
/// only for latency accounting (not a hardware field).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlooFlit {
    /// Parallel header lines.
    pub header: Header,
    /// The message carried by this flit.
    pub payload: Payload,
    /// Injection cycle (latency accounting only).
    pub injected_at: u64,
    /// Virtual channel the flit currently rides (a link-level sideband,
    /// not an AXI header line). Flits inject on VC 0; on wrap fabrics
    /// the router rewrites this when the flit crosses a dateline
    /// (`router::routing::dateline_vc`) and it selects the lane of the
    /// next [`crate::sim::Link`]. Always 0 on meshes and on every
    /// single-VC configuration. See `docs/deadlock.md`.
    pub vc: u8,
}

/// Every message class that can cross the NoC. `Narrow*` originate from the
/// 64-bit AXI bus, `Wide*` from the 512-bit bus.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Payload {
    /// Narrow read request.
    NarrowAr(AxReq),
    /// Narrow write request.
    NarrowAw(AxReq),
    /// Narrow write-data beat.
    NarrowW {
        /// Transaction ID the beat belongs to.
        id: AxiId,
        /// The data beat.
        beat: WBeat,
    },
    /// Narrow read-data beat.
    NarrowR(RBeat),
    /// Narrow write response.
    NarrowB(BResp),
    /// Wide read request.
    WideAr(AxReq),
    /// Wide write request.
    WideAw(AxReq),
    /// Wide write-data beat (512-bit payload).
    WideW {
        /// Transaction ID the beat belongs to.
        id: AxiId,
        /// The data beat.
        beat: WBeat,
    },
    /// Wide read-data beat (512-bit payload).
    WideR(RBeat),
    /// Wide write response.
    WideB(BResp),
}

/// Which AXI bus a payload belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BusKind {
    /// The 64-bit core bus.
    Narrow,
    /// The 512-bit DMA bus.
    Wide,
}

/// Request- vs response-class messages. The paper keeps these on separate
/// physical links *always* ("AXI4 requests and responses are always sent
/// over different physical links to prevent message-level deadlocks").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MsgClass {
    /// AR/AW/W-class messages.
    Request,
    /// R/B-class messages.
    Response,
}

/// The three FlooNoC physical links of Table I.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ChannelClass {
    /// The 119-bit narrow request link.
    NarrowReq,
    /// The 103-bit narrow response link.
    NarrowRsp,
    /// The 603-bit wide link.
    Wide,
}

impl Payload {
    /// Which AXI bus this payload originates from.
    pub fn bus(&self) -> BusKind {
        match self {
            Payload::NarrowAr(_)
            | Payload::NarrowAw(_)
            | Payload::NarrowW { .. }
            | Payload::NarrowR(_)
            | Payload::NarrowB(_) => BusKind::Narrow,
            _ => BusKind::Wide,
        }
    }

    /// Request- or response-class message.
    pub fn class(&self) -> MsgClass {
        match self {
            Payload::NarrowAr(_)
            | Payload::NarrowAw(_)
            | Payload::NarrowW { .. }
            | Payload::WideAr(_)
            | Payload::WideAw(_)
            | Payload::WideW { .. } => MsgClass::Request,
            _ => MsgClass::Response,
        }
    }

    /// Table-I mapping: which of the three physical links this payload
    /// rides in the narrow-wide configuration. Wide AR/AW and wide B are
    /// deliberately mapped to the *narrow* links to keep the wide link free
    /// for bulk data (§III-B).
    pub fn phys_link(&self) -> ChannelClass {
        match self {
            Payload::NarrowAr(_)
            | Payload::NarrowAw(_)
            | Payload::NarrowW { .. }
            | Payload::WideAr(_)
            | Payload::WideAw(_) => ChannelClass::NarrowReq,
            Payload::NarrowR(_) | Payload::NarrowB(_) | Payload::WideB(_) => {
                ChannelClass::NarrowRsp
            }
            Payload::WideW { .. } | Payload::WideR(_) => ChannelClass::Wide,
        }
    }

    /// Useful payload bits this flit carries (for effective-bandwidth
    /// accounting, Fig. 5b): the *data* content, not headers/strobe.
    pub fn payload_bits(&self) -> u32 {
        match self {
            Payload::NarrowAr(_) | Payload::NarrowAw(_) => 48, // an address
            Payload::WideAr(_) | Payload::WideAw(_) => 48,
            Payload::NarrowW { .. } | Payload::NarrowR(_) => 64,
            Payload::WideW { .. } | Payload::WideR(_) => 512,
            Payload::NarrowB(_) | Payload::WideB(_) => 2,
        }
    }
}

impl FlooFlit {
    /// Assemble a flit stamped with its injection cycle. Flits start on
    /// virtual channel 0 (the dateline scheme's injection invariant —
    /// see `docs/deadlock.md`); routers rewrite [`FlooFlit::vc`] at
    /// dateline crossings.
    pub fn new(header: Header, payload: Payload, now: u64) -> Self {
        FlooFlit {
            header,
            payload,
            injected_at: now,
            vc: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::axi::{Burst, Resp};

    fn req(id: AxiId) -> AxReq {
        AxReq {
            id,
            addr: 0x1000,
            len: 15,
            size: 6,
            burst: Burst::Incr,
            atop: false,
        }
    }

    /// Table I "Mapping & Primary Payload" column, as code.
    #[test]
    fn table_one_mapping() {
        use ChannelClass::*;
        assert_eq!(Payload::NarrowAr(req(0)).phys_link(), NarrowReq);
        assert_eq!(Payload::NarrowAw(req(0)).phys_link(), NarrowReq);
        assert_eq!(
            Payload::NarrowW {
                id: 0,
                beat: WBeat { beat: 0, last: true }
            }
            .phys_link(),
            NarrowReq
        );
        // Wide AR/AW ride the narrow request link.
        assert_eq!(Payload::WideAr(req(0)).phys_link(), NarrowReq);
        assert_eq!(Payload::WideAw(req(0)).phys_link(), NarrowReq);
        // Responses.
        assert_eq!(
            Payload::NarrowR(RBeat {
                id: 0,
                beat: 0,
                last: true,
                resp: Resp::Okay
            })
            .phys_link(),
            NarrowRsp
        );
        assert_eq!(
            Payload::NarrowB(BResp { id: 0, resp: Resp::Okay }).phys_link(),
            NarrowRsp
        );
        // Wide B rides the narrow response link.
        assert_eq!(
            Payload::WideB(BResp { id: 0, resp: Resp::Okay }).phys_link(),
            NarrowRsp
        );
        // Only bulk data uses the wide link.
        assert_eq!(
            Payload::WideW {
                id: 0,
                beat: WBeat { beat: 0, last: false }
            }
            .phys_link(),
            Wide
        );
        assert_eq!(
            Payload::WideR(RBeat {
                id: 0,
                beat: 0,
                last: false,
                resp: Resp::Okay
            })
            .phys_link(),
            Wide
        );
    }

    #[test]
    fn request_response_separation() {
        // Deadlock-freedom invariant: no payload class maps requests and
        // responses onto the same physical link.
        let reqs = [
            Payload::NarrowAr(req(0)),
            Payload::NarrowAw(req(0)),
            Payload::WideAr(req(0)),
        ];
        let rsps = [
            Payload::NarrowR(RBeat {
                id: 0,
                beat: 0,
                last: true,
                resp: Resp::Okay,
            }),
            Payload::NarrowB(BResp { id: 0, resp: Resp::Okay }),
            Payload::WideB(BResp { id: 0, resp: Resp::Okay }),
        ];
        for r in &reqs {
            assert_eq!(r.class(), MsgClass::Request);
            for s in &rsps {
                assert_eq!(s.class(), MsgClass::Response);
                assert_ne!(r.phys_link(), s.phys_link());
            }
        }
    }

    #[test]
    fn payload_bits_for_bandwidth_accounting() {
        assert_eq!(
            Payload::WideR(RBeat {
                id: 0,
                beat: 0,
                last: false,
                resp: Resp::Okay
            })
            .payload_bits(),
            512
        );
        assert_eq!(Payload::WideB(BResp { id: 0, resp: Resp::Okay }).payload_bits(), 2);
    }
}
