//! Table-I link-width calculator.
//!
//! Computes the physical width of the three FlooNoC links from first
//! principles: exact AXI4(+ATOP) channel payload widths plus the parallel
//! header lines of Fig. 2. With the paper's parameters (ADDR = 48,
//! DATA = 64/512, 4-bit IDs, 2 kB narrow / 8 kB wide ROB, ≤16×16 mesh)
//! the calculator reproduces Table I bit-for-bit:
//!
//! | link       | header | widest payload      | total |
//! |------------|--------|---------------------|-------|
//! | narrow_req |  32    | AW+ATOP = 87        | 119   |
//! | narrow_rsp |  32    | R(64)   = 71        | 103   |
//! | wide       |  26    | W(512)  = 577       | 603   |
//!
//! Field inventory (documented in DESIGN.md):
//! * narrow header: dst(4+4) + src(4+4) + rob_req(1) + rob_idx(8) +
//!   last(1) + axi_ch(3) + atop(3) = 32. The 8-bit rob_idx addresses the
//!   2 kB narrow ROB at 8 B granularity (256 slots).
//! * wide header: dst(8) + src(8) + rob_req(1) + rob_idx(7) + last(1) +
//!   axi_ch(1) = 26. The 7-bit rob_idx addresses the 8 kB wide ROB at
//!   64 B granularity (128 slots); 1 bit distinguishes W from R.

/// AXI4 bus parameterization at the NI boundary.
#[derive(Debug, Clone, Copy)]
pub struct AxiParams {
    /// Address width (paper: 48).
    pub addr_width: u32,
    /// Data width of this bus (64 narrow / 512 wide).
    pub data_width: u32,
    /// ID width at the endpoint (paper tile: 4).
    pub id_width: u32,
    /// ATOP sideband width on AW (PULP AXI: 6).
    pub atop_width: u32,
}

impl AxiParams {
    /// The paper's 64-bit narrow bus parameters.
    pub fn narrow() -> Self {
        AxiParams {
            addr_width: 48,
            data_width: 64,
            id_width: 4,
            atop_width: 6,
        }
    }

    /// The paper's 512-bit wide bus parameters.
    pub fn wide() -> Self {
        AxiParams {
            addr_width: 48,
            data_width: 512,
            id_width: 4,
            atop_width: 6,
        }
    }

    /// AR payload bits: addr + id + len(8) + size(3) + burst(2) + lock(1)
    /// + cache(4) + prot(3) + qos(4) + region(4).
    pub fn ar_bits(&self) -> u32 {
        self.addr_width + self.id_width + 8 + 3 + 2 + 1 + 4 + 3 + 4 + 4
    }

    /// AW payload bits: AR fields + ATOP sideband.
    pub fn aw_bits(&self) -> u32 {
        self.ar_bits() + self.atop_width
    }

    /// W payload bits: data + strb + last.
    pub fn w_bits(&self) -> u32 {
        self.data_width + self.data_width / 8 + 1
    }

    /// R payload bits: data + id + resp(2) + last.
    pub fn r_bits(&self) -> u32 {
        self.data_width + self.id_width + 2 + 1
    }

    /// B payload bits: id + resp(2).
    pub fn b_bits(&self) -> u32 {
        self.id_width + 2
    }
}

/// Header geometry for one physical link.
#[derive(Debug, Clone, Copy)]
pub struct HeaderLayout {
    /// Destination coordinate bits (x+y).
    pub dst_bits: u32,
    /// Source coordinate bits (x+y).
    pub src_bits: u32,
    /// ROB index bits (log2 of ROB slots).
    pub rob_idx_bits: u32,
    /// Payload-type discriminator bits.
    pub axi_ch_bits: u32,
    /// ATOP class echo bits (narrow links only).
    pub atop_bits: u32,
}

impl HeaderLayout {
    /// rob_req(1) + last(1) + all configurable fields.
    pub fn bits(&self) -> u32 {
        self.dst_bits + self.src_bits + 1 + self.rob_idx_bits + 1 + self.axi_ch_bits + self.atop_bits
    }
}

/// Complete layout of one physical link.
#[derive(Debug, Clone, Copy)]
pub struct LinkLayout {
    /// Parallel header line widths.
    pub header: HeaderLayout,
    /// Payload bits (the widest AXI channel mapped to this link).
    pub payload_bits: u32,
}

impl LinkLayout {
    /// Total parallel wires carrying flit content (excl. valid/ready).
    pub fn flit_bits(&self) -> u32 {
        self.header.bits() + self.payload_bits
    }

    /// Physical wires per direction including the valid/ready handshake.
    pub fn wires_simplex(&self) -> u32 {
        self.flit_bits() + 2
    }
}

/// ROB sizing, used both here (rob_idx width) and by the NI.
#[derive(Debug, Clone, Copy)]
pub struct RobParams {
    /// Total ROB bytes (paper: 2 kB narrow, 8 kB wide).
    pub bytes: u32,
    /// Allocation granule = one data beat (8 B narrow, 64 B wide).
    pub granule: u32,
}

impl RobParams {
    /// The paper's 2 kB narrow ROB.
    pub fn narrow() -> Self {
        RobParams {
            bytes: 2 * 1024,
            granule: 8,
        }
    }

    /// The paper's 8 kB wide ROB.
    pub fn wide() -> Self {
        RobParams {
            bytes: 8 * 1024,
            granule: 64,
        }
    }

    /// Number of allocation granules.
    pub fn slots(&self) -> u32 {
        self.bytes / self.granule
    }

    /// Header bits needed to index a slot.
    pub fn idx_bits(&self) -> u32 {
        u32::BITS - (self.slots() - 1).leading_zeros()
    }
}

/// The full narrow-wide NoC layout (all three physical links).
#[derive(Debug, Clone)]
pub struct NocLayout {
    /// Narrow-bus AXI parameters.
    pub narrow: AxiParams,
    /// Wide-bus AXI parameters.
    pub wide: AxiParams,
    /// Narrow ROB sizing.
    pub narrow_rob: RobParams,
    /// Wide ROB sizing.
    pub wide_rob: RobParams,
    /// Coordinate bits per axis (4 ⇒ up to 16×16 meshes).
    pub coord_bits: u32,
}

impl Default for NocLayout {
    fn default() -> Self {
        NocLayout {
            narrow: AxiParams::narrow(),
            wide: AxiParams::wide(),
            narrow_rob: RobParams::narrow(),
            wide_rob: RobParams::wide(),
            coord_bits: 4,
        }
    }
}

impl NocLayout {
    fn narrow_header(&self) -> HeaderLayout {
        HeaderLayout {
            dst_bits: 2 * self.coord_bits,
            src_bits: 2 * self.coord_bits,
            rob_idx_bits: self.narrow_rob.idx_bits(),
            // narrow_req carries 5 payload kinds, narrow_rsp 3; a shared
            // 3-bit discriminator covers both.
            axi_ch_bits: 3,
            atop_bits: 3,
        }
    }

    fn wide_header(&self) -> HeaderLayout {
        HeaderLayout {
            dst_bits: 2 * self.coord_bits,
            src_bits: 2 * self.coord_bits,
            rob_idx_bits: self.wide_rob.idx_bits(),
            // wide carries only W and R: 1 bit.
            axi_ch_bits: 1,
            atop_bits: 0,
        }
    }

    /// `narrow_req`: narrow AR/AW/W plus wide AR/AW (Table I mapping) —
    /// sized by the widest member of that union.
    pub fn narrow_req(&self) -> LinkLayout {
        let payload = self
            .narrow
            .aw_bits()
            .max(self.narrow.ar_bits())
            .max(self.narrow.w_bits())
            .max(self.wide.aw_bits())
            .max(self.wide.ar_bits());
        LinkLayout {
            header: self.narrow_header(),
            payload_bits: payload,
        }
    }

    /// `narrow_rsp`: narrow R/B plus wide B.
    pub fn narrow_rsp(&self) -> LinkLayout {
        let payload = self
            .narrow
            .r_bits()
            .max(self.narrow.b_bits())
            .max(self.wide.b_bits());
        LinkLayout {
            header: self.narrow_header(),
            payload_bits: payload,
        }
    }

    /// `wide`: wide W and R only.
    pub fn wide_link(&self) -> LinkLayout {
        let payload = self.wide.w_bits().max(self.wide.r_bits());
        LinkLayout {
            header: self.wide_header(),
            payload_bits: payload,
        }
    }

    /// Wires of a full duplex channel (all three links, both directions,
    /// incl. valid/ready) — the §V "approximately 1600 wires".
    pub fn duplex_wires(&self) -> u32 {
        2 * (self.narrow_req().wires_simplex()
            + self.narrow_rsp().wires_simplex()
            + self.wide_link().wires_simplex())
    }

    /// Peak payload bandwidth of the wide link in Gbps at `freq_ghz`:
    /// 512 data bits per cycle (the paper's 629 Gbps at 1.23 GHz).
    pub fn wide_peak_gbps(&self, freq_ghz: f64) -> f64 {
        self.wide.data_width as f64 * freq_ghz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axi_channel_payload_widths() {
        let n = AxiParams::narrow();
        let w = AxiParams::wide();
        assert_eq!(n.ar_bits(), 81);
        assert_eq!(n.aw_bits(), 87);
        assert_eq!(n.w_bits(), 73);
        assert_eq!(n.r_bits(), 71);
        assert_eq!(n.b_bits(), 6);
        assert_eq!(w.w_bits(), 577);
        assert_eq!(w.r_bits(), 519);
    }

    #[test]
    fn rob_index_widths() {
        assert_eq!(RobParams::narrow().slots(), 256);
        assert_eq!(RobParams::narrow().idx_bits(), 8);
        assert_eq!(RobParams::wide().slots(), 128);
        assert_eq!(RobParams::wide().idx_bits(), 7);
    }

    /// Table I, bit for bit.
    #[test]
    fn table_one_link_widths() {
        let l = NocLayout::default();
        assert_eq!(l.narrow_req().flit_bits(), 119, "narrow_req (Table I)");
        assert_eq!(l.narrow_rsp().flit_bits(), 103, "narrow_rsp (Table I)");
        assert_eq!(l.wide_link().flit_bits(), 603, "wide (Table I)");
    }

    #[test]
    fn header_widths() {
        let l = NocLayout::default();
        assert_eq!(l.narrow_req().header.bits(), 32);
        assert_eq!(l.narrow_rsp().header.bits(), 32);
        assert_eq!(l.wide_link().header.bits(), 26);
    }

    /// §V: "a duplex channel requires approximately 1600 wires".
    #[test]
    fn duplex_channel_wire_count() {
        let l = NocLayout::default();
        let wires = l.duplex_wires();
        assert_eq!(wires, 2 * (121 + 105 + 605));
        assert!((1500..=1700).contains(&wires), "≈1600 wires, got {wires}");
    }

    /// §VI-B: 629 Gbps per wide link at 1.23 GHz.
    #[test]
    fn wide_peak_bandwidth() {
        let l = NocLayout::default();
        let gbps = l.wide_peak_gbps(1.23);
        assert!((gbps - 629.76).abs() < 0.01, "512 bit × 1.23 GHz = {gbps}");
    }

    #[test]
    fn bigger_mesh_grows_headers_not_payload() {
        let mut l = NocLayout::default();
        let base = l.wide_link().flit_bits();
        l.coord_bits = 6; // up to 64×64 tiles
        assert_eq!(l.wide_link().flit_bits(), base + 8);
        assert_eq!(l.wide_link().payload_bits, 577);
    }
}
