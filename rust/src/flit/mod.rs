//! FlooNoC link-level protocol: flits with parallel header lines.
//!
//! The paper's key link-level decision (§III-B): instead of serializing a
//! packet into header/payload/tail flits over a narrow link, every flit
//! carries its full header on dedicated parallel wires and the whole
//! payload in one cycle. Three physical links exist per direction:
//!
//! * `narrow_req` (119 bit) — narrow AR/AW/W plus *wide* AR/AW (small
//!   messages that would waste the wide link);
//! * `narrow_rsp` (103 bit) — narrow R/B plus wide B;
//! * `wide` (603 bit) — wide W and R bursts only.
//!
//! [`layout`] computes these widths from first principles and is checked
//! against Table I bit-for-bit in its tests.

pub mod layout;
pub mod types;

pub use layout::{AxiParams, LinkLayout, NocLayout, RobParams};
pub use types::*;
