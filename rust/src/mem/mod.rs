//! Memory endpoint models: tile SPM and boundary memory controllers.
//!
//! Both are fully pipelined fixed-latency request/response engines: a
//! request accepted at cycle *t* produces its first response beat at
//! *t + latency*, then one beat per cycle (the SPM's banked array and the
//! controller's DRAM channel both sustain one beat/cycle at their port
//! width). This is the behaviour the paper's latency budget attributes to
//! "cluster-internal cuts and memory access latency" (§VI-A).

use std::collections::VecDeque;

use crate::axi::{AxReq, Resp};
use crate::flit::NodeId;

/// A memory access in flight inside the model.
#[derive(Debug, Clone)]
pub struct MemOp {
    /// Originating node (for response routing by the target NI).
    pub src: NodeId,
    /// Echoed ROB index.
    pub rob_idx: u32,
    /// Whether the response must consult the originator's ROB.
    pub rob_req: bool,
    /// Atomic-transaction marker.
    pub atomic: bool,
    /// The request being served.
    pub req: AxReq,
    /// Read (true) or write (false).
    pub is_read: bool,
    /// Cycle at which the first response beat is ready.
    ready_at: u64,
    /// Beats already emitted.
    beats_done: u32,
}

/// One response beat leaving the memory.
#[derive(Debug, Clone, Copy)]
pub struct MemRsp {
    /// Node the response returns to.
    pub src: NodeId,
    /// Echoed ROB index.
    pub rob_idx: u32,
    /// Whether the response must consult the originator's ROB.
    pub rob_req: bool,
    /// Atomic-transaction marker.
    pub atomic: bool,
    /// Echoed AXI ID.
    pub id: u16,
    /// Read-data beat (true) or write response (false).
    pub is_read: bool,
    /// Beat index within the burst.
    pub beat: u32,
    /// Last beat of the burst.
    pub last: bool,
    /// Response code.
    pub resp: Resp,
}

/// Fixed-latency pipelined memory port.
#[derive(Debug)]
pub struct MemModel {
    /// Cycles from accept to first beat.
    pub latency: u64,
    /// In-flight + waiting ops, in acceptance order. Responses are emitted
    /// in acceptance order (the target NI serializes onto one local ID, so
    /// the memory must preserve order — §III-A).
    ops: VecDeque<MemOp>,
    /// Max ops in flight (accept backpressure beyond this).
    pub max_outstanding: usize,
    /// Total beats served (bandwidth accounting).
    pub beats_served: u64,
}

impl MemModel {
    /// A memory port with the given first-beat latency and depth.
    pub fn new(latency: u64, max_outstanding: usize) -> Self {
        MemModel {
            latency,
            ops: VecDeque::new(),
            max_outstanding,
            beats_served: 0,
        }
    }

    /// Accept backpressure: false once `max_outstanding` ops are in.
    pub fn can_accept(&self) -> bool {
        self.ops.len() < self.max_outstanding
    }

    /// Operations currently in flight.
    pub fn outstanding(&self) -> usize {
        self.ops.len()
    }

    /// No operation in flight.
    pub fn is_idle(&self) -> bool {
        self.ops.is_empty()
    }

    /// Accept an operation at cycle `now`. Returns the cycle at which the
    /// first response beat will be ready (`now + latency`) so the caller
    /// can register the retirement in an event calendar
    /// ([`crate::util::calendar::Calendar`]) for the event-driven
    /// fast-forward path.
    pub fn accept(
        &mut self,
        now: u64,
        src: NodeId,
        rob_idx: u32,
        rob_req: bool,
        atomic: bool,
        req: AxReq,
        is_read: bool,
    ) -> u64 {
        assert!(self.can_accept(), "memory accept without can_accept");
        let ready_at = now + self.latency;
        self.ops.push_back(MemOp {
            src,
            rob_idx,
            rob_req,
            atomic,
            req,
            is_read,
            ready_at,
            beats_done: 0,
        });
        ready_at
    }

    /// Cycle at which the head operation's next beat becomes ready, if
    /// any op is in flight. Ops queue in acceptance order with monotonic
    /// `ready_at`, so the head is always the earliest.
    pub fn next_ready_at(&self) -> Option<u64> {
        self.ops.front().map(|op| op.ready_at)
    }

    /// Peek the head operation if it is ready to emit a beat at `now`
    /// (without consuming). Used by the target NI to decide which physical
    /// link the next response needs before committing to pop it.
    pub fn peek_head(&self, now: u64) -> Option<&MemOp> {
        let op = self.ops.front()?;
        (now >= op.ready_at).then_some(op)
    }

    /// Emit at most one response beat this cycle (the head op, in order).
    /// Writes produce a single B beat; reads produce `beats` R beats.
    pub fn step(&mut self, now: u64) -> Option<MemRsp> {
        let op = self.ops.front_mut()?;
        if now < op.ready_at {
            return None;
        }
        let total = if op.is_read { op.req.beats() } else { 1 };
        let beat = op.beats_done;
        let last = beat + 1 == total;
        let rsp = MemRsp {
            src: op.src,
            rob_idx: op.rob_idx,
            rob_req: op.rob_req,
            atomic: op.atomic,
            id: op.req.id,
            is_read: op.is_read,
            beat,
            last,
            resp: Resp::Okay,
        };
        op.beats_done += 1;
        if last {
            self.ops.pop_front();
        }
        self.beats_served += 1;
        Some(rsp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::axi::Burst;

    fn req(len: u8) -> AxReq {
        AxReq {
            id: 1,
            addr: 0x100,
            len,
            size: 6,
            burst: Burst::Incr,
            atop: false,
        }
    }

    #[test]
    fn read_latency_then_streaming() {
        let mut m = MemModel::new(5, 4);
        m.accept(10, NodeId(2), 7, true, false, req(3), true); // 4 beats
        for t in 10..15 {
            assert!(m.step(t).is_none(), "latency not yet elapsed at {t}");
        }
        let beats: Vec<_> = (15..19).map(|t| m.step(t).unwrap()).collect();
        assert_eq!(beats.len(), 4);
        assert_eq!(beats[0].beat, 0);
        assert!(!beats[0].last);
        assert!(beats[3].last);
        assert!(m.is_idle());
        assert_eq!(m.beats_served, 4);
    }

    #[test]
    fn write_single_b_response() {
        let mut m = MemModel::new(3, 4);
        m.accept(0, NodeId(1), 0, false, false, req(15), false);
        assert!(m.step(2).is_none());
        let b = m.step(3).unwrap();
        assert!(!b.is_read);
        assert!(b.last);
        assert!(m.is_idle());
    }

    #[test]
    fn responses_in_acceptance_order() {
        let mut m = MemModel::new(1, 4);
        m.accept(0, NodeId(1), 10, true, false, req(0), true);
        m.accept(0, NodeId(2), 20, true, false, req(0), true);
        let a = m.step(1).unwrap();
        let b = m.step(2).unwrap();
        assert_eq!(a.rob_idx, 10);
        assert_eq!(b.rob_idx, 20);
    }

    #[test]
    fn pipelining_overlaps_latency() {
        // Two back-to-back single-beat reads at latency 5: second completes
        // one cycle after the first (pipelined), not 5 cycles after.
        let mut m = MemModel::new(5, 4);
        m.accept(0, NodeId(1), 0, true, false, req(0), true);
        m.accept(1, NodeId(1), 1, true, false, req(0), true);
        let mut done = Vec::new();
        for t in 0..12 {
            if let Some(r) = m.step(t) {
                done.push((t, r.rob_idx));
            }
        }
        assert_eq!(done, vec![(5, 0), (6, 1)]);
    }

    #[test]
    fn outstanding_limit() {
        let mut m = MemModel::new(1, 2);
        m.accept(0, NodeId(1), 0, true, false, req(0), true);
        m.accept(0, NodeId(1), 1, true, false, req(0), true);
        assert!(!m.can_accept());
    }

    /// The accept return value and `next_ready_at` expose the retirement
    /// schedule the event-driven mode's calendar runs on: accept at `t`
    /// reports `t + latency`, and the head op is always the earliest
    /// (acceptance order ⇒ monotonic ready times).
    #[test]
    fn accept_reports_retirement_cycle() {
        let mut m = MemModel::new(5, 4);
        assert_eq!(m.next_ready_at(), None);
        let t0 = m.accept(10, NodeId(1), 0, true, false, req(0), true);
        assert_eq!(t0, 15);
        let t1 = m.accept(12, NodeId(1), 1, true, false, req(0), true);
        assert_eq!(t1, 17);
        assert_eq!(m.next_ready_at(), Some(15));
        m.step(15).unwrap(); // single-beat read retires the head
        assert_eq!(m.next_ready_at(), Some(17));
    }
}
