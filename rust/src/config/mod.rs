//! JSON-backed configuration for the simulator and experiments.
//!
//! A config file selects the mesh geometry, link mode, NI/ROB sizing and
//! memory latencies; every field is optional and defaults to the paper's
//! tile configuration. Example:
//!
//! ```json
//! {
//!   "mesh": {"width": 4, "height": 4, "mem_edge": "west"},
//!   "mode": "narrow_wide",
//!   "vcs": 1,
//!   "router": {"in_buf_depth": 2, "output_reg": true},
//!   "ni": {"wide_rob_slots": 128, "narrow_rob_slots": 256,
//!          "per_id_depth": 4, "num_ids": 16},
//!   "mem": {"spm_latency": 7, "mem_ctrl_latency": 30}
//! }
//! ```

use anyhow::{bail, Context};

use crate::noc::{LinkMode, NocConfig};
use crate::router::RoutingKind;
use crate::sim::SimMode;
use crate::topology::{MemEdge, TopologyKind};
use crate::util::json::Json;

/// Parse a topology name as used by the CLI (`--topology`) and the
/// config file (`"topology"` key).
pub fn topology_from_str(s: &str) -> crate::Result<TopologyKind> {
    Ok(match s {
        "mesh" => TopologyKind::Mesh,
        "torus" => TopologyKind::Torus,
        "ring" => TopologyKind::Ring,
        other => bail!("unknown topology '{other}' (mesh|torus|ring)"),
    })
}

/// Parse a full [`NocConfig`] from JSON text.
pub fn noc_config_from_json(text: &str) -> crate::Result<NocConfig> {
    let j = Json::parse(text).context("config is not valid JSON")?;
    noc_config_from_value(&j)
}

/// Parse from an already-parsed JSON value.
pub fn noc_config_from_value(j: &Json) -> crate::Result<NocConfig> {
    let mut cfg = NocConfig::default();
    if let Some(t) = j.get("topology").and_then(Json::as_str) {
        cfg.topology = topology_from_str(t)?;
    }
    if let Some(mesh) = j.get("mesh") {
        if let Some(w) = mesh.get("width").and_then(Json::as_u64) {
            cfg.width = w as u8;
        }
        if let Some(h) = mesh.get("height").and_then(Json::as_u64) {
            cfg.height = h as u8;
        }
        if let Some(edge) = mesh.get("mem_edge").and_then(Json::as_str) {
            cfg.mem_edge = match edge {
                "none" => MemEdge::None,
                "west" => MemEdge::West,
                "east_west" => MemEdge::EastWest,
                "all" => MemEdge::All,
                other => bail!("unknown mem_edge '{other}'"),
            };
        }
    }
    if let Some(mode) = j.get("mode").and_then(Json::as_str) {
        cfg.mode = match mode {
            "narrow_wide" => LinkMode::NarrowWide,
            "wide_only" => LinkMode::WideOnly,
            other => bail!("unknown mode '{other}'"),
        };
    }
    if let Some(sim) = j.get("sim_mode").and_then(Json::as_str) {
        cfg.sim_mode = match sim {
            "gated" => SimMode::Gated,
            "dense" => SimMode::Dense,
            "event" => SimMode::Event,
            other => bail!("unknown sim_mode '{other}' (gated|dense|event)"),
        };
    }
    // Routing discipline: parsed before `"vcs"` so an adaptive config
    // with the VC count omitted gets the adaptive default
    // (`default_vcs + 1`: the escape lanes plus one adaptive lane)
    // instead of the deterministic fabric default.
    if let Some(r) = j.get("routing").and_then(Json::as_str) {
        cfg.routing = match r {
            "deterministic" => RoutingKind::Deterministic,
            "adaptive" => RoutingKind::Adaptive,
            other => bail!("unknown routing '{other}' (deterministic|adaptive)"),
        };
    }
    // Virtual channels: explicit `"vcs"` wins; omitted defaults to the
    // fabric's requirement (1 on meshes, 2 dateline VCs on torus/ring —
    // matching the `NocConfig::torus`/`ring` builders), plus one
    // adaptive lane under adaptive routing (matching
    // `NocConfig::adaptive`). An explicit value below the adaptive
    // minimum is kept as written — the FV107 preflight lint rejects it
    // with a readable message instead of a silent correction.
    match j.get("vcs").map(|v| v.as_usize()) {
        Some(Some(v)) if (1..=crate::router::MAX_VCS).contains(&v) => cfg.vcs = v,
        Some(_) => bail!("vcs must be an integer in 1..={}", crate::router::MAX_VCS),
        None => {
            cfg.vcs = cfg.topology.default_vcs()
                + usize::from(cfg.routing == RoutingKind::Adaptive);
        }
    }
    if let Some(r) = j.get("router") {
        if let Some(d) = r.get("in_buf_depth").and_then(Json::as_usize) {
            if d == 0 {
                bail!("in_buf_depth must be >= 1");
            }
            cfg.in_buf_depth = d;
        }
        if let Some(o) = r.get("output_reg").and_then(Json::as_bool) {
            cfg.output_reg = o;
        }
    }
    if let Some(ni) = j.get("ni") {
        if let Some(s) = ni.get("wide_rob_slots").and_then(Json::as_u64) {
            cfg.wide_init.rob_slots = s as u32;
        }
        if let Some(s) = ni.get("narrow_rob_slots").and_then(Json::as_u64) {
            cfg.narrow_init.rob_slots = s as u32;
        }
        if let Some(d) = ni.get("per_id_depth").and_then(Json::as_usize) {
            cfg.wide_init.per_id_depth = d;
            cfg.narrow_init.per_id_depth = d;
        }
        if let Some(n) = ni.get("num_ids").and_then(Json::as_usize) {
            cfg.wide_init.num_ids = n;
            cfg.narrow_init.num_ids = n;
        }
    }
    if let Some(mem) = j.get("mem") {
        if let Some(l) = mem.get("spm_latency").and_then(Json::as_u64) {
            cfg.spm.mem_latency = l;
        }
        if let Some(l) = mem.get("mem_ctrl_latency").and_then(Json::as_u64) {
            cfg.mem_ctrl.mem_latency = l;
        }
    }
    // Verifier knobs: preflight on by default, release-build invariant
    // scans off by default (see `docs/verification.md`).
    if let Some(v) = j.get("verify").and_then(Json::as_bool) {
        cfg.verify = v;
    }
    if let Some(c) = j.get("check_invariants").and_then(Json::as_bool) {
        cfg.check_invariants = c;
    }
    // Execution shards (deterministic sharded engine). 1 = serial; the
    // engine clamps to the fabric's strip dimension at run time.
    match j.get("shards").map(|v| v.as_usize()) {
        Some(Some(s)) if s >= 1 => cfg.shards = s,
        Some(_) => bail!("shards must be an integer >= 1"),
        None => {}
    }
    if cfg.width == 0 || cfg.height == 0 {
        bail!("mesh dimensions must be >= 1");
    }
    if cfg.topology == TopologyKind::Ring && cfg.height != 1 {
        bail!("a ring is one-dimensional: height must be 1, got {}", cfg.height);
    }
    Ok(cfg)
}

/// Serialize a config back to JSON (round-trip support, dumped into
/// experiment records so every result is reproducible from its file).
pub fn noc_config_to_json(cfg: &NocConfig) -> Json {
    Json::obj(vec![
        ("topology", Json::Str(cfg.topology.name().to_string())),
        (
            "mesh",
            Json::obj(vec![
                ("width", Json::Num(cfg.width as f64)),
                ("height", Json::Num(cfg.height as f64)),
                (
                    "mem_edge",
                    Json::Str(
                        match cfg.mem_edge {
                            MemEdge::None => "none",
                            MemEdge::West => "west",
                            MemEdge::EastWest => "east_west",
                            MemEdge::All => "all",
                        }
                        .to_string(),
                    ),
                ),
            ]),
        ),
        (
            "mode",
            Json::Str(
                match cfg.mode {
                    LinkMode::NarrowWide => "narrow_wide",
                    LinkMode::WideOnly => "wide_only",
                }
                .to_string(),
            ),
        ),
        ("sim_mode", Json::Str(cfg.sim_mode.name().to_string())),
        (
            "routing",
            Json::Str(
                match cfg.routing {
                    RoutingKind::Deterministic => "deterministic",
                    RoutingKind::Adaptive => "adaptive",
                }
                .to_string(),
            ),
        ),
        ("vcs", Json::Num(cfg.vcs as f64)),
        ("verify", Json::Bool(cfg.verify)),
        ("check_invariants", Json::Bool(cfg.check_invariants)),
        ("shards", Json::Num(cfg.shards as f64)),
        (
            "router",
            Json::obj(vec![
                ("in_buf_depth", Json::Num(cfg.in_buf_depth as f64)),
                ("output_reg", Json::Bool(cfg.output_reg)),
            ]),
        ),
        (
            "ni",
            Json::obj(vec![
                ("wide_rob_slots", Json::Num(cfg.wide_init.rob_slots as f64)),
                (
                    "narrow_rob_slots",
                    Json::Num(cfg.narrow_init.rob_slots as f64),
                ),
                ("per_id_depth", Json::Num(cfg.wide_init.per_id_depth as f64)),
                ("num_ids", Json::Num(cfg.wide_init.num_ids as f64)),
            ]),
        ),
        (
            "mem",
            Json::obj(vec![
                ("spm_latency", Json::Num(cfg.spm.mem_latency as f64)),
                (
                    "mem_ctrl_latency",
                    Json::Num(cfg.mem_ctrl.mem_latency as f64),
                ),
            ]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_from_empty_object() {
        let cfg = noc_config_from_json("{}").unwrap();
        assert_eq!(cfg.width, 2);
        assert_eq!(cfg.mode, LinkMode::NarrowWide);
        assert_eq!(cfg.wide_init.rob_slots, 128);
    }

    #[test]
    fn full_config_parses() {
        let cfg = noc_config_from_json(
            r#"{
                "mesh": {"width": 4, "height": 3, "mem_edge": "west"},
                "mode": "wide_only",
                "router": {"in_buf_depth": 4, "output_reg": false},
                "ni": {"wide_rob_slots": 64, "per_id_depth": 2},
                "mem": {"spm_latency": 9}
            }"#,
        )
        .unwrap();
        assert_eq!((cfg.width, cfg.height), (4, 3));
        assert_eq!(cfg.mem_edge, MemEdge::West);
        assert_eq!(cfg.mode, LinkMode::WideOnly);
        assert_eq!(cfg.in_buf_depth, 4);
        assert!(!cfg.output_reg);
        assert_eq!(cfg.wide_init.rob_slots, 64);
        assert_eq!(cfg.wide_init.per_id_depth, 2);
        assert_eq!(cfg.spm.mem_latency, 9);
    }

    #[test]
    fn rejects_bad_values() {
        assert!(noc_config_from_json(r#"{"mode": "quantum"}"#).is_err());
        assert!(noc_config_from_json(r#"{"mesh": {"mem_edge": "north"}}"#).is_err());
        assert!(noc_config_from_json(r#"{"router": {"in_buf_depth": 0}}"#).is_err());
        assert!(noc_config_from_json("not json").is_err());
    }

    #[test]
    fn topology_axis_parses() {
        let torus = r#"{"topology": "torus", "mesh": {"width": 4, "height": 4}}"#;
        let cfg = noc_config_from_json(torus).unwrap();
        assert_eq!(cfg.topology, TopologyKind::Torus);
        let ring = r#"{"topology": "ring", "mesh": {"width": 8, "height": 1}}"#;
        let cfg = noc_config_from_json(ring).unwrap();
        assert_eq!((cfg.topology, cfg.width), (TopologyKind::Ring, 8));
        // Omitted => mesh (backwards compatible).
        assert_eq!(noc_config_from_json("{}").unwrap().topology, TopologyKind::Mesh);
        // Invalid name / 2-D ring are rejected.
        assert!(noc_config_from_json(r#"{"topology": "hypercube"}"#).is_err());
        let two_d_ring = r#"{"topology": "ring", "mesh": {"width": 4, "height": 2}}"#;
        assert!(noc_config_from_json(two_d_ring).is_err());
    }

    #[test]
    fn sim_mode_axis_parses() {
        assert_eq!(
            noc_config_from_json(r#"{"sim_mode": "dense"}"#).unwrap().sim_mode,
            SimMode::Dense
        );
        assert_eq!(
            noc_config_from_json(r#"{"sim_mode": "gated"}"#).unwrap().sim_mode,
            SimMode::Gated
        );
        assert_eq!(
            noc_config_from_json(r#"{"sim_mode": "event"}"#).unwrap().sim_mode,
            SimMode::Event
        );
        // Omitted => gated (the fast default, backwards compatible).
        assert_eq!(noc_config_from_json("{}").unwrap().sim_mode, SimMode::Gated);
        assert!(noc_config_from_json(r#"{"sim_mode": "warp"}"#).is_err());
        // Round-trips through serialization (all three modes).
        for cfg in [
            NocConfig::mesh(3, 3).dense(),
            NocConfig::mesh(3, 3).event(),
            NocConfig::mesh(3, 3),
        ] {
            let back = noc_config_from_value(&noc_config_to_json(&cfg)).unwrap();
            assert_eq!(back.sim_mode, cfg.sim_mode);
        }
    }

    #[test]
    fn topology_roundtrips() {
        for cfg in [NocConfig::torus(3, 3), NocConfig::ring(6), NocConfig::mesh(2, 2)] {
            let back = noc_config_from_value(&noc_config_to_json(&cfg)).unwrap();
            assert_eq!(back.topology, cfg.topology);
            assert_eq!((back.width, back.height), (cfg.width, cfg.height));
            assert_eq!(back.vcs, cfg.vcs);
        }
    }

    #[test]
    fn vcs_axis_parses() {
        // Explicit value wins on any fabric.
        let j = r#"{"topology": "torus", "vcs": 1}"#;
        assert_eq!(noc_config_from_json(j).unwrap().vcs, 1);
        let j = r#"{"vcs": 2}"#;
        assert_eq!(noc_config_from_json(j).unwrap().vcs, 2);
        // Omitted: the fabric's requirement (mesh 1, wrap fabrics 2).
        assert_eq!(noc_config_from_json("{}").unwrap().vcs, 1);
        let torus = r#"{"topology": "torus", "mesh": {"width": 4, "height": 4}}"#;
        assert_eq!(noc_config_from_json(torus).unwrap().vcs, 2);
        let ring = r#"{"topology": "ring", "mesh": {"width": 8, "height": 1}}"#;
        assert_eq!(noc_config_from_json(ring).unwrap().vcs, 2);
        // Out-of-range and non-integer values are rejected.
        assert!(noc_config_from_json(r#"{"vcs": 0}"#).is_err());
        assert!(noc_config_from_json(r#"{"vcs": 99}"#).is_err());
        assert!(noc_config_from_json(r#"{"vcs": "two"}"#).is_err());
        // Round-trips through serialization, including non-defaults.
        let cfg = NocConfig::torus(3, 3).with_vcs(1);
        let back = noc_config_from_value(&noc_config_to_json(&cfg)).unwrap();
        assert_eq!(back.vcs, 1);
    }

    #[test]
    fn routing_axis_parses_and_roundtrips() {
        // Omitted => deterministic (backwards compatible).
        assert_eq!(
            noc_config_from_json("{}").unwrap().routing,
            RoutingKind::Deterministic
        );
        // Adaptive with vcs omitted defaults to escape lanes + 1.
        let mesh = r#"{"routing": "adaptive"}"#;
        let cfg = noc_config_from_json(mesh).unwrap();
        assert_eq!((cfg.routing, cfg.vcs), (RoutingKind::Adaptive, 2));
        let torus = r#"{"topology": "torus", "mesh": {"width": 4, "height": 4},
                        "routing": "adaptive"}"#;
        let cfg = noc_config_from_json(torus).unwrap();
        assert_eq!((cfg.routing, cfg.vcs), (RoutingKind::Adaptive, 3));
        // An explicit vcs wins (even below the adaptive minimum — the
        // FV107 preflight lint rejects it at build, not at parse).
        let j = r#"{"routing": "adaptive", "vcs": 4}"#;
        assert_eq!(noc_config_from_json(j).unwrap().vcs, 4);
        let j = r#"{"routing": "adaptive", "vcs": 1}"#;
        assert_eq!(noc_config_from_json(j).unwrap().vcs, 1);
        // Key order does not matter: `routing` after `vcs` in the file
        // still leaves the explicit vcs untouched.
        let j = r#"{"vcs": 3, "routing": "adaptive"}"#;
        assert_eq!(noc_config_from_json(j).unwrap().vcs, 3);
        // Bad names are rejected.
        assert!(noc_config_from_json(r#"{"routing": "oblivious"}"#).is_err());
        // Round-trips through serialization.
        let cfg = NocConfig::torus(4, 4).adaptive();
        let back = noc_config_from_value(&noc_config_to_json(&cfg)).unwrap();
        assert_eq!((back.routing, back.vcs), (RoutingKind::Adaptive, 3));
    }

    #[test]
    fn shards_knob_parses_and_roundtrips() {
        // Omitted => serial.
        assert_eq!(noc_config_from_json("{}").unwrap().shards, 1);
        assert_eq!(noc_config_from_json(r#"{"shards": 4}"#).unwrap().shards, 4);
        // Zero and non-integer values are rejected.
        assert!(noc_config_from_json(r#"{"shards": 0}"#).is_err());
        assert!(noc_config_from_json(r#"{"shards": "four"}"#).is_err());
        // Round-trips through serialization.
        let cfg = NocConfig::mesh(4, 4).with_shards(4);
        let back = noc_config_from_value(&noc_config_to_json(&cfg)).unwrap();
        assert_eq!(back.shards, 4);
    }

    #[test]
    fn roundtrip() {
        let mut cfg = NocConfig::mesh(5, 5).wide_only();
        cfg.in_buf_depth = 3;
        let j = noc_config_to_json(&cfg);
        let back = noc_config_from_value(&j).unwrap();
        assert_eq!(back.width, 5);
        assert_eq!(back.mode, LinkMode::WideOnly);
        assert_eq!(back.in_buf_depth, 3);
    }

    #[test]
    fn verify_knobs_parse_and_roundtrip() {
        // Defaults: preflight on, invariant scans off.
        let cfg = noc_config_from_json("{}").unwrap();
        assert!(cfg.verify && !cfg.check_invariants);
        let cfg =
            noc_config_from_json(r#"{"verify": false, "check_invariants": true}"#).unwrap();
        assert!(!cfg.verify && cfg.check_invariants);
        // Round-trips through serialization.
        let back = noc_config_from_value(&noc_config_to_json(&cfg)).unwrap();
        assert!(!back.verify && back.check_invariants);
    }
}
