//! Property battery for minimal-adaptive routing on Duato escape VCs.
//!
//! Four pins (see "Adaptive routing on escape VCs" in
//! `docs/deadlock.md`):
//!
//! 1. **Candidate-table properties on live fabrics** — every
//!    per-router adaptive route table publishes non-empty candidate
//!    sets, every candidate hop strictly decreases the fabric distance
//!    (minimal adaptivity: adaptive paths are exactly as long as the
//!    deterministic ones), the deterministic escape step is always a
//!    member (fallback never mis-routes), and the escape-lane count is
//!    the fabric's dateline VC default — the subgraph the CDG proof
//!    covers.
//! 2. **Escape-only degeneration** — with zero adaptive lanes
//!    (`vcs == escape lanes`, buildable only under `no_verify` because
//!    FV107 rejects it) the adaptive router has no admissible adaptive
//!    candidate, ever, and must reproduce the deterministic run's
//!    digest byte for byte: adaptivity is *additive* on top of the
//!    baseline, not a different router.
//! 3. **Tornado drain under adaptivity** — the adversarial pattern on
//!    the wrap fabric drains with a stall watchdog armed: congestion
//!    scoring plus escape fallback must never livelock or deadlock.
//! 4. **Adaptivity pays** — at a fixed horizon on the 8×8 torus
//!    tornado (the pattern whose even-ring ties the deterministic rule
//!    breaks uniformly east, piling every flow onto one direction),
//!    the adaptive fabric ejects at least as many flits as the
//!    deterministic one.

use floonoc::cluster::{TileTraffic, TiledWorkload};
use floonoc::flit::{Coord, NodeId};
use floonoc::noc::{NocConfig, NocSystem};
use floonoc::perf;
use floonoc::sim::SimMode;
use floonoc::topology::TopologyKind;
use floonoc::traffic::{GenCfg, Pattern};

mod common;
use common::digest;

use floonoc::router::{PORT_E, PORT_LOCAL, PORT_N, PORT_S, PORT_W};

/// Pin 1: candidate sets materialized into live per-router tables are
/// non-empty, strictly distance-decreasing, contain the escape step,
/// and reserve exactly the fabric's dateline lanes for escape.
#[test]
fn live_adaptive_tables_are_minimal_and_contain_escape() {
    let cfgs = [
        NocConfig::mesh(3, 3),
        NocConfig::mesh(4, 2),
        NocConfig::torus(4, 4),
        NocConfig::torus(5, 3),
        NocConfig::ring(8),
        NocConfig::ring(7),
    ];
    for cfg in cfgs {
        let sys = NocSystem::new(cfg.adaptive());
        let topo = &sys.topo;
        let alg = topo.adaptive_algorithm();
        let wraps = topo.kind != TopologyKind::Mesh;
        let (w, h) = (topo.width, topo.height);
        for y in 0..h {
            for x in 0..w {
                let me = Coord::new(x, y);
                let table = topo.route_table_adaptive(me);
                assert!(table.is_adaptive());
                assert_eq!(
                    table.escape_lanes() as usize,
                    topo.kind.default_vcs(),
                    "{:?}: escape lanes are the dateline VC default",
                    topo.kind
                );
                for (i, node) in topo.nodes.iter().enumerate() {
                    let dst = NodeId(i as u16);
                    let cand = table.candidates(dst);
                    let escape = table.lookup(dst);
                    assert_ne!(cand, 0, "{:?} {me:?}->{dst:?}: empty candidates", topo.kind);
                    assert_ne!(
                        cand & (1 << escape),
                        0,
                        "{:?} {me:?}->{dst:?}: escape port {escape} not a candidate",
                        topo.kind
                    );
                    if node.coord == me {
                        // Arrived (tile) or attached (mem ctrl): the
                        // single exit port, nothing adaptive about it.
                        assert_eq!(cand, 1 << escape);
                        continue;
                    }
                    // Minimality: each candidate hop is one closer.
                    for port in [PORT_N, PORT_E, PORT_S, PORT_W] {
                        if cand & (1 << port) == 0 {
                            continue;
                        }
                        let next = match (port, wraps) {
                            (PORT_E, true) => Coord::new((x + 1) % w, y),
                            (PORT_E, false) => Coord::new(x + 1, y),
                            (PORT_W, true) => Coord::new((x + w - 1) % w, y),
                            (PORT_W, false) => Coord::new(x - 1, y),
                            (PORT_N, true) => Coord::new(x, (y + 1) % h),
                            (PORT_N, false) => Coord::new(x, y + 1),
                            (PORT_S, true) => Coord::new(x, (y + h - 1) % h),
                            (PORT_S, false) => Coord::new(x, y - 1),
                            _ => unreachable!(),
                        };
                        assert_eq!(
                            alg.distance(next, node.coord) + 1,
                            alg.distance(me, node.coord),
                            "{:?} {me:?}->{:?} via port {port}: not minimal",
                            topo.kind,
                            node.coord
                        );
                    }
                    // Tiles never see PORT_LOCAL as an adaptive detour.
                    assert_eq!(cand & (1 << PORT_LOCAL), 0);
                }
            }
        }
    }
}

/// A small mixed workload (tornado narrow + uniform DMA bursts) on the
/// given pre-built system.
fn mixed_workload(sys: NocSystem) -> TiledWorkload {
    let tiles = sys.topo.num_tiles;
    let profiles: Vec<TileTraffic> = (0..tiles)
        .map(|i| TileTraffic {
            core: Some(GenCfg {
                pattern: Pattern::Tornado,
                num_txns: 10,
                seed: 0xE5CA + i as u64,
                ..GenCfg::narrow_probe(NodeId(0), 10)
            }),
            dma: Some(GenCfg {
                pattern: Pattern::UniformTiles,
                num_txns: 2,
                burst_len: 7,
                seed: 0xD0A + i as u64,
                ..GenCfg::dma_burst(NodeId(0), 2, false)
            }),
        })
        .collect();
    TiledWorkload::new(sys, profiles)
}

/// Pin 2: with `vcs == escape_lanes` the adaptive lane range is empty,
/// so every head falls back to the escape baseline every cycle — the
/// run must be byte-identical to the deterministic configuration. FV107
/// rejects this degenerate config in normal operation, hence
/// `no_verify`; the point of building it anyway is exactly this
/// equality.
#[test]
fn escape_only_adaptive_reproduces_deterministic_digest() {
    for kind in [TopologyKind::Mesh, TopologyKind::Torus, TopologyKind::Ring] {
        let base = NocConfig::fabric(kind, 3, 3);
        let esc = base.topology.default_vcs();
        let run = |cfg: NocConfig| {
            let mut w = mixed_workload(NocSystem::new(cfg));
            assert!(w.run_to_completion(2_000_000), "{kind:?} must drain");
            assert!(w.protocol_ok());
            digest(&mut w)
        };
        let det = run(base.clone());
        let adp = run(base.adaptive().with_vcs(esc).no_verify());
        assert!(
            det == adp,
            "{kind:?}: escape-only adaptive must equal deterministic\n\
             --- deterministic ---\n{det}\n--- adaptive(vcs={esc}) ---\n{adp}"
        );
    }
}

/// Pin 3: adversarial tornado on the adaptive 8×8 torus drains with a
/// stall watchdog armed — total ejections must advance every 25 000
/// cycles until completion (the same window `verify_static.rs` uses for
/// the deterministic fabrics).
#[test]
fn tornado_adaptive_torus_drains_without_stall() {
    const STALL_WINDOW: u64 = 25_000;
    let sys = NocSystem::new(NocConfig::torus(8, 8).adaptive());
    let tiles = sys.topo.num_tiles;
    let profiles: Vec<TileTraffic> = (0..tiles)
        .map(|i| TileTraffic {
            core: Some(GenCfg {
                pattern: Pattern::Tornado,
                num_txns: 40,
                seed: 0x70AD + i as u64,
                ..GenCfg::narrow_probe(NodeId(0), 40)
            }),
            dma: Some(GenCfg {
                pattern: Pattern::Tornado,
                num_txns: 4,
                burst_len: 15,
                seed: 0x500 + i as u64,
                ..GenCfg::dma_burst(NodeId(0), 4, false)
            }),
        })
        .collect();
    let mut w = TiledWorkload::new(sys, profiles);
    let outcome = w.run_with_watchdog(5_000_000, STALL_WINDOW);
    assert_eq!(
        outcome,
        Ok(true),
        "adaptive tornado must drain without a stall:\n{}",
        w.stall_analysis()
    );
    assert!(w.protocol_ok());
}

/// Pin 4: the tornado study headline. At a fixed horizon on the 8×8
/// torus, minimal-adaptive routing must eject at least as many flits as
/// the deterministic baseline — the deterministic rule breaks every
/// half-way tie east, so all tornado flows share one direction per ring
/// while the adaptive candidates spread them over both.
#[test]
fn adaptive_beats_deterministic_on_torus_tornado() {
    let horizon = 4_000u64;
    let run = |adaptive: bool| {
        let mut w = if adaptive {
            perf::tornado_adaptive_workload(8, SimMode::Gated)
        } else {
            perf::tornado_deterministic_workload(8, SimMode::Gated)
        };
        for _ in 0..horizon {
            w.step();
        }
        assert!(w.protocol_ok());
        w.sys.counters.iter().map(|c| c.ejected).sum::<u64>()
    };
    let det = run(false);
    let adp = run(true);
    assert!(det > 0, "deterministic baseline must make progress");
    assert!(
        adp >= det,
        "adaptive tornado throughput regressed: {adp} ejected vs {det} deterministic"
    );
}
