//! Seeded randomized three-way differential sweep: ~50 random
//! (topology, shape, pattern, link-mode, routing, vcs, buffer-depth,
//! duty, seed) points, each run to completion under [`SimMode::Dense`],
//! [`SimMode::Gated`] and [`SimMode::Event`] and compared by
//! byte-identical stats digest (`common::assert_modes_equivalent` — the
//! same runner the curated grid in `gated_equivalence.rs` uses).
//!
//! The sweep is deterministic: one fixed master seed drives every
//! random choice, so a failing point reproduces exactly (its full
//! parameter set is in the assertion label). Alongside the sweep live
//! the duty-cycle regressions: fast-forward must *actually skip* on
//! bursty workloads (`stepped_cycles` ≪ `now`) and must never fire
//! while any generator remains issue-eligible every cycle.

use floonoc::cluster::{TileTraffic, TiledWorkload};
use floonoc::flit::NodeId;
use floonoc::noc::{NocConfig, NocSystem};
use floonoc::sim::SimMode;
use floonoc::topology::TopologyKind;
use floonoc::traffic::{DutyCycle, GenCfg, Pattern};
use floonoc::util::rng::Rng;

mod common;
use common::{assert_modes_equivalent, digest};

/// One randomly drawn sweep point (everything needed to rebuild the
/// workload deterministically in any sim mode).
#[derive(Debug, Clone)]
struct Point {
    kind: TopologyKind,
    width: u8,
    height: u8,
    wide_only: bool,
    adaptive: bool,
    vcs: usize,
    in_buf_depth: usize,
    pattern: Pattern,
    core_txns: u64,
    dma_txns: u64,
    dma_burst_len: u8,
    duty: Option<DutyCycle>,
    seed: u64,
}

/// Draw one point. Constraints keep every draw valid: wrap fabrics
/// (torus/ring) keep at least their 2 dateline VCs, adaptive points
/// keep at least one lane above the escape lanes (mesh ≥ 2, wrap ≥ 3 —
/// the FV107 bound), tornado needs a non-degenerate shape (width ≥ 2,
/// which all draws satisfy).
fn draw(rng: &mut Rng) -> Point {
    let kind = *rng.choose(&[TopologyKind::Mesh, TopologyKind::Torus, TopologyKind::Ring]);
    let (width, height) = match kind {
        TopologyKind::Ring => ((4 + rng.below(7)) as u8, 1),
        _ => ((2 + rng.below(3)) as u8, (2 + rng.below(3)) as u8),
    };
    let adaptive = rng.chance(0.35);
    let vcs = match (kind, adaptive) {
        (TopologyKind::Mesh, false) => 1 + rng.below(2) as usize,
        (TopologyKind::Mesh, true) => 2 + rng.below(3) as usize,
        (_, false) => 2 + rng.below(2) as usize,
        (_, true) => 3 + rng.below(2) as usize,
    };
    let pattern = *rng.choose(&[
        Pattern::UniformTiles,
        Pattern::Tornado,
        Pattern::NearestNeighbor,
        Pattern::Neighbor,
    ]);
    let duty = rng.chance(0.4).then(|| DutyCycle {
        period: 64 + rng.below(192),
        active: 4 + rng.below(12),
        offset: rng.below(64),
    });
    Point {
        kind,
        width,
        height,
        wide_only: rng.chance(0.3),
        adaptive,
        vcs,
        in_buf_depth: *rng.choose(&[1usize, 2, 4]),
        pattern,
        core_txns: 4 + rng.below(8),
        dma_txns: 1 + rng.below(3),
        dma_burst_len: *rng.choose(&[3u8, 7, 15]),
        duty,
        seed: rng.below(1 << 32),
    }
}

/// Build the point's workload in the requested mode.
fn build(p: &Point, mode: SimMode) -> TiledWorkload {
    let mut cfg = match p.kind {
        TopologyKind::Ring => NocConfig::ring(p.width),
        k => NocConfig::fabric(k, p.width, p.height),
    }
    .with_sim_mode(mode)
    .with_vcs(p.vcs);
    if p.wide_only {
        cfg = cfg.wide_only();
    }
    if p.adaptive {
        // The drawn vcs already satisfies the adaptive minimum, so the
        // builder only flips the routing discipline here.
        cfg = cfg.adaptive().with_vcs(p.vcs);
    }
    cfg.in_buf_depth = p.in_buf_depth;
    let sys = NocSystem::new(cfg);
    let tiles = sys.topo.num_tiles;
    let profiles: Vec<TileTraffic> = (0..tiles)
        .map(|i| TileTraffic {
            core: Some(GenCfg {
                pattern: p.pattern,
                num_txns: p.core_txns,
                seed: p.seed ^ (0xC0 + i as u64),
                duty: p.duty.map(|d| DutyCycle {
                    // Stagger the window grid per tile so the bursts
                    // decorrelate without killing the shared idle gaps.
                    offset: d.offset + 3 * i as u64,
                    ..d
                }),
                ..GenCfg::narrow_probe(NodeId(0), p.core_txns)
            }),
            dma: Some(GenCfg {
                pattern: Pattern::UniformTiles,
                num_txns: p.dma_txns,
                burst_len: p.dma_burst_len,
                seed: p.seed ^ (0xDA00 + i as u64),
                ..GenCfg::dma_burst(NodeId(0), p.dma_txns, false)
            }),
        })
        .collect();
    TiledWorkload::new(sys, profiles)
}

/// The headline sweep: 50 seeded random points, three-way digest
/// equality on every one.
#[test]
fn randomized_three_way_differential_sweep() {
    let mut rng = Rng::new(0x5EED_2026);
    for i in 0..50 {
        let p = draw(&mut rng);
        assert_modes_equivalent(&format!("point {i}: {p:?}"), 2_000_000, |mode| {
            build(&p, mode)
        });
    }
}

/// Duty-cycle regression: on a bursty workload (short full-rate windows
/// separated by long silence) the event engine must fast-forward —
/// executing a small fraction of the simulated cycles — while staying
/// byte-identical to gated and dense.
#[test]
fn duty_cycled_workload_skips_and_stays_equivalent() {
    let mk = |mode: SimMode| {
        let sys = NocSystem::new(NocConfig::mesh(4, 4).with_sim_mode(mode));
        let tiles = sys.topo.num_tiles;
        let profiles: Vec<TileTraffic> = (0..tiles)
            .map(|i| TileTraffic {
                core: Some(GenCfg {
                    pattern: Pattern::UniformTiles,
                    num_txns: 24,
                    seed: 0xD077 + i as u64,
                    duty: Some(DutyCycle {
                        period: 256,
                        active: 8,
                        offset: 4 * (i as u64 % 4),
                    }),
                    ..GenCfg::narrow_probe(NodeId(0), 24)
                }),
                dma: None,
            })
            .collect();
        TiledWorkload::new(sys, profiles)
    };
    assert_modes_equivalent("duty-cycled/4x4", 2_000_000, mk);
    // The equivalence above proves correctness; now prove the speed
    // mechanism engaged at all: most cycles must be skipped, not stepped.
    let mut w = mk(SimMode::Event);
    assert!(w.run_to_completion(2_000_000));
    let (stepped, now) = (w.sys.stepped_cycles, w.sys.now);
    assert!(
        stepped * 4 < now,
        "duty workload must skip >75% of cycles: stepped {stepped} of {now}"
    );
}

/// Anti-regression on the skip condition itself: while any generator is
/// issue-eligible every cycle (full rate, no duty window, outstanding
/// budget never saturated), its wake is always "next cycle" and the
/// fast-forward must never fire. Both engines step the same 5 000
/// cycles and agree on every counter mid-flight.
#[test]
fn full_rate_workload_never_skips() {
    let mk = |mode: SimMode| {
        let sys = NocSystem::new(NocConfig::mesh(3, 3).with_sim_mode(mode));
        let tiles = sys.topo.num_tiles;
        let profiles: Vec<TileTraffic> = (0..tiles)
            .map(|i| TileTraffic {
                core: Some(GenCfg {
                    pattern: Pattern::UniformTiles,
                    num_txns: u64::MAX,
                    max_outstanding: 64,
                    seed: 0xF00 + i as u64,
                    ..GenCfg::narrow_probe(NodeId(0), 1)
                }),
                dma: None,
            })
            .collect();
        TiledWorkload::new(sys, profiles)
    };
    let run = |mode: SimMode| {
        let mut w = mk(mode);
        for _ in 0..5_000 {
            w.step();
        }
        (digest(&mut w), w.sys.skipped_cycles)
    };
    let (gated, gated_skipped) = run(SimMode::Gated);
    let (event, event_skipped) = run(SimMode::Event);
    assert_eq!(gated_skipped, 0);
    assert_eq!(
        event_skipped, 0,
        "an always-eligible generator pins the wake to now + 1 — no jump is possible"
    );
    assert!(gated == event, "mid-flight digests must agree\n{gated}\n---\n{event}");
}
