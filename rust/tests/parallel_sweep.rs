//! Determinism and ordering guarantees of the parallel sweep runner:
//! the same seeds must produce byte-identical report JSON whether points
//! run serially, on a few threads, or on all cores — and repeated runs
//! must agree with themselves.

use floonoc::coordinator as exp;
use floonoc::dse::parallel::{run_sweep, sweep_report_json, ParallelRunner, SweepPoint};
use floonoc::noc::LinkMode;
use floonoc::util::json::pretty;

fn demo_points() -> Vec<SweepPoint> {
    let mut points = SweepPoint::grid(
        &[2, 3],
        &[LinkMode::NarrowWide, LinkMode::WideOnly],
        &[3, 15],
    );
    for p in &mut points {
        p.bursts_per_tile = 4;
    }
    points
}

/// The headline guarantee: same seeds => byte-identical report JSON for
/// serial and parallel execution.
#[test]
fn parallel_report_byte_identical_to_serial() {
    let points = demo_points();
    let serial = run_sweep(&points, &ParallelRunner::serial());
    let parallel = run_sweep(&points, &ParallelRunner::new(4));
    let all_cores = run_sweep(&points, &ParallelRunner::default());
    let s = pretty(&sweep_report_json(&serial));
    assert_eq!(s, pretty(&sweep_report_json(&parallel)), "4 threads diverged");
    assert_eq!(s, pretty(&sweep_report_json(&all_cores)), "all cores diverged");
    // And the sweep did real work.
    assert_eq!(serial.len(), points.len());
    for (p, r) in points.iter().zip(&serial) {
        assert_eq!(p.name, r.name, "result order matches input order");
        assert!(r.cycles > 0 && r.wide_beats > 0, "{} moved data", r.name);
    }
}

/// Repeating the identical parallel sweep reproduces itself exactly
/// (per-point seeding depends only on (base_seed, index)).
#[test]
fn parallel_sweep_self_reproducible() {
    let points = demo_points();
    let a = run_sweep(&points, &ParallelRunner::new(3));
    let b = run_sweep(&points, &ParallelRunner::new(3));
    assert_eq!(
        pretty(&sweep_report_json(&a)),
        pretty(&sweep_report_json(&b))
    );
}

/// Changing the base seed is observable in the derived generator streams
/// for seed-sensitive workloads, while the deterministic ring workload's
/// aggregate beat count is seed-invariant (fixed destinations, fixed
/// burst counts).
#[test]
fn seeding_is_per_point_and_deterministic() {
    let mut a = demo_points();
    let base = run_sweep(&a, &ParallelRunner::serial());
    for p in &mut a {
        p.base_seed ^= 0xDEAD_BEEF;
    }
    let reseeded = run_sweep(&a, &ParallelRunner::serial());
    for (x, y) in base.iter().zip(&reseeded) {
        assert_eq!(x.wide_beats, y.wide_beats, "workload size is seed-free");
    }
}

/// The paper experiments fan out through the same runner: Fig. 5a rows
/// computed serially and in parallel must agree exactly, including the
/// slowdown normalization against the level-0 baseline.
#[test]
fn fig5a_parallel_matches_serial() {
    let levels = [0u32, 2];
    let serial = exp::fig5a_with(LinkMode::NarrowWide, false, &levels, &ParallelRunner::serial());
    let parallel = exp::fig5a_with(LinkMode::NarrowWide, false, &levels, &ParallelRunner::new(2));
    assert_eq!(serial.len(), parallel.len());
    for (s, p) in serial.iter().zip(&parallel) {
        assert_eq!(s.wide_outstanding, p.wide_outstanding);
        assert_eq!(s.narrow_mean.to_bits(), p.narrow_mean.to_bits());
        assert_eq!(s.narrow_p99, p.narrow_p99);
        assert_eq!(s.narrow_max, p.narrow_max);
        assert_eq!(s.slowdown.to_bits(), p.slowdown.to_bits());
    }
}

/// Ablations through the runner keep their serial ordering and values.
#[test]
fn ablation_parallel_matches_serial() {
    let sizes = [16u32, 128];
    let serial = exp::ablate_rob_size_with(&sizes, &ParallelRunner::serial());
    let parallel = exp::ablate_rob_size_with(&sizes, &ParallelRunner::new(2));
    for (s, p) in serial.iter().zip(&parallel) {
        assert_eq!(s.value, p.value);
        assert_eq!(s.metric.to_bits(), p.metric.to_bits());
    }
    // Flow-control physics still hold through the parallel path.
    assert!(serial[0].metric > serial[1].metric, "small ROB throttles");
}
