//! Bounded-horizon mode-equivalence differential over the **saturated**
//! bench scenarios (`floonoc::perf`): every tile injecting at full rate,
//! `num_txns: u64::MAX`, so the workloads never drain and the drain-based
//! sweep in `mode_equivalence_sweep.rs` cannot cover them. These are
//! exactly the scenarios the hot-path optimisations (bitmask switch
//! allocation, memoized route lookups, flattened link lanes) are measured
//! on — this suite pins that the fast path changes *nothing observable*:
//! each scenario runs to a fixed cycle horizon under dense / gated /
//! event stepping at 1, 2 and 4 shards, and every digest must be
//! byte-identical to the serial dense reference.

mod common;

use floonoc::perf;

#[test]
fn saturated_4x4_modes_and_shards_identical() {
    common::assert_modes_equivalent_bounded("saturated_4x4", 1_500, |m| {
        perf::saturated_workload(4, m)
    });
}

#[test]
fn wrap_saturated_torus_4x4_modes_and_shards_identical() {
    common::assert_modes_equivalent_bounded("wrap_saturated_torus_4x4", 1_500, |m| {
        perf::wrap_saturated_workload(4, m)
    });
}

#[test]
fn tornado_adaptive_torus_4x4_modes_and_shards_identical() {
    common::assert_modes_equivalent_bounded("tornado_adaptive_4x4", 1_200, |m| {
        perf::tornado_adaptive_workload(4, m)
    });
}

#[test]
fn saturated_8x8_modes_and_shards_identical() {
    common::assert_modes_equivalent_bounded("saturated_8x8", 800, |m| {
        perf::saturated_workload(8, m)
    });
}
