//! The static verifier against live fabrics: acceptance of every
//! shipped default, rejection of the known-deadlockable configurations,
//! property sweeps over random fabric shapes, and the
//! **verifier-vs-watchdog agreement matrix** — the end-to-end claim
//! that the channel-dependency-graph verdict predicts what a saturating
//! wormhole workload actually does on the simulated network.
//!
//! The rejection side exploits the verifier's sharpness boundary
//! (docs/verification.md): a wrapping dimension needs length >= 4
//! before minimal routing exercises enough consecutive ring channels to
//! close a CDG cycle, so 3x3 torus/ring fabrics at a single VC are
//! *correctly* accepted while 4x4 torus and rings of length >= 4 at a
//! single VC are rejected with a printed cycle.

use floonoc::cluster::{TileTraffic, TiledWorkload};
use floonoc::flit::NodeId;
use floonoc::noc::{NocConfig, NocSystem};
use floonoc::prop_assert;
use floonoc::topology::{MemEdge, Topology};
use floonoc::traffic::{GenCfg, Pattern};
use floonoc::util::prop::check_default;
use floonoc::verify::{preflight, verify_topology};

// ---------------------------------------------------------------------
// Acceptance: every configuration the repo ships as a default.
// ---------------------------------------------------------------------

/// All shipped default configurations verify with zero error-severity
/// findings — mesh/torus/ring across the sizes the test suite and
/// sweeps use, in both link modes.
#[test]
fn shipped_defaults_verify_clean() {
    let configs: Vec<(NocConfig, &str)> = vec![
        (NocConfig::mesh(2, 2), "mesh 2x2"),
        (NocConfig::mesh(3, 3), "mesh 3x3"),
        (NocConfig::mesh(4, 4), "mesh 4x4"),
        (NocConfig::mesh(7, 7), "mesh 7x7"),
        (NocConfig::torus(3, 3), "torus 3x3"),
        (NocConfig::torus(4, 4), "torus 4x4"),
        (NocConfig::torus(8, 8), "torus 8x8"),
        (NocConfig::ring(4), "ring 4"),
        (NocConfig::ring(8), "ring 8"),
        (NocConfig::ring(16), "ring 16"),
        (NocConfig::torus(4, 4).wide_only(), "torus 4x4 wide-only"),
        (NocConfig::mesh(4, 4).wide_only(), "mesh 4x4 wide-only"),
        (NocConfig::mesh(4, 4).adaptive(), "mesh 4x4 adaptive"),
        (NocConfig::torus(4, 4).adaptive(), "torus 4x4 adaptive"),
        (NocConfig::torus(8, 8).adaptive(), "torus 8x8 adaptive"),
        (NocConfig::ring(8).adaptive(), "ring 8 adaptive"),
    ];
    for (cfg, label) in configs {
        let report = preflight(&cfg);
        assert!(
            !report.has_errors(),
            "{label}: shipped default must verify clean, got:\n{report}"
        );
    }
}

/// The example configs under `examples/configs/` — the ones CI feeds to
/// `repro verify --json` — parse and verify clean, so the CI gate and
/// this suite agree on the same artifacts.
#[test]
fn example_configs_verify_clean() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("examples/configs");
    let mut seen = 0;
    for entry in std::fs::read_dir(&dir).expect("examples/configs exists") {
        let path = entry.expect("readable dir entry").path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        seen += 1;
        let text = std::fs::read_to_string(&path).expect("readable config");
        let cfg = floonoc::config::noc_config_from_json(&text)
            .unwrap_or_else(|e| panic!("{}: parse failed: {e:#}", path.display()));
        let report = preflight(&cfg);
        assert!(
            !report.has_errors(),
            "{}: example config must verify clean, got:\n{report}",
            path.display()
        );
    }
    assert!(seen >= 4, "expected the shipped example configs, found {seen}");
}

// ---------------------------------------------------------------------
// Rejection: known-deadlockable configurations.
// ---------------------------------------------------------------------

/// A 4x4 torus forced to a single VC is rejected with an FV001 deadlock
/// finding whose context prints the offending CDG cycle as a readable
/// `(router, port, vc) -> ...` chain, plus the FV101 wrap-fabric lint.
#[test]
fn torus_4x4_at_one_vc_is_rejected_with_printed_cycle() {
    let report = preflight(&NocConfig::torus(4, 4).with_vcs(1));
    assert!(report.has_errors(), "must reject, got:\n{report}");
    let deadlocks = report.with_code("FV001");
    assert!(!deadlocks.is_empty(), "expected FV001, got:\n{report}");
    let chain = &deadlocks[0].context;
    assert!(
        chain.iter().any(|l| l.contains('→') && l.contains("vc")),
        "FV001 context must print the cycle chain, got: {chain:?}"
    );
    assert!(
        chain.iter().any(|l| l.starts_with("back to ")),
        "the chain must visibly close, got: {chain:?}"
    );
    assert!(
        !report.with_code("FV101").is_empty(),
        "downgraded wrap fabric must also carry the FV101 lint:\n{report}"
    );
}

/// Rings of length >= 4 at a single VC are rejected; both directions of
/// the 8-ring close a cycle.
#[test]
fn rings_at_one_vc_are_rejected() {
    for n in [4u8, 8] {
        let report = preflight(&NocConfig::ring(n).with_vcs(1));
        assert!(
            !report.with_code("FV001").is_empty(),
            "ring {n} @ 1 VC must be rejected, got:\n{report}"
        );
    }
}

/// FV107: an adaptive config whose VC count leaves no lane above the
/// escape lanes has nothing to adapt on — rejected at error tier, on
/// every fabric, with the escape-lane arithmetic in the message's
/// context. The builder cannot produce this state (`adaptive()` raises
/// the VC count); it takes a manual override, exactly what the lint is
/// for.
#[test]
fn adaptive_without_a_lane_above_escape_is_rejected_fv107() {
    let mut mesh = NocConfig::mesh(4, 4).adaptive();
    mesh.vcs = 1; // escape lanes alone
    let mut torus = NocConfig::torus(4, 4).adaptive();
    torus.vcs = 2; // both dateline lanes, zero adaptive lanes
    let mut ring = NocConfig::ring(8).adaptive();
    ring.vcs = 1; // below even the escape minimum
    for cfg in [mesh, torus, ring] {
        let report = preflight(&cfg);
        assert!(report.has_errors(), "{:?}: must reject, got:\n{report}", cfg.topology);
        assert!(
            !report.with_code("FV107").is_empty(),
            "{:?}: expected FV107, got:\n{report}",
            cfg.topology
        );
    }
}

/// The escape restriction is **sharp**, not conservative: running the
/// very same candidate sets with no escape subgraph beneath them
/// (`verify_adaptive_unrestricted`) closes an FV001 cycle on every
/// fabric the adaptive defaults ship on — while `preflight` accepts
/// those same fabrics because the deployed router confines the proof
/// obligation to the deterministic escape lanes.
#[test]
fn adaptive_escape_restriction_is_sharp() {
    for (topo, cfg, label) in [
        (
            Topology::torus(4, 4, MemEdge::None),
            NocConfig::torus(4, 4).adaptive(),
            "torus 4x4",
        ),
        (
            Topology::ring(8, MemEdge::None),
            NocConfig::ring(8).adaptive(),
            "ring 8",
        ),
        (
            Topology::mesh(4, 4, MemEdge::None),
            NocConfig::mesh(4, 4).adaptive(),
            "mesh 4x4",
        ),
    ] {
        let unrestricted = floonoc::verify::verify_adaptive_unrestricted(&topo);
        assert!(
            unrestricted.has_errors() && !unrestricted.with_code("FV001").is_empty(),
            "{label}: unrestricted adaptivity must close a cycle, got:\n{unrestricted}"
        );
        assert!(
            !preflight(&cfg).has_errors(),
            "{label}: the escape-restricted deployment must stay accepted"
        );
    }
}

/// Clearing the dateline mask (no VC switch on the wrap links) defeats
/// the escape lane even with 2 VCs: the verifier finds the cycle.
#[test]
fn cleared_dateline_mask_is_rejected() {
    let topo = Topology::torus(4, 4, MemEdge::None);
    let zeros = vec![0u8; topo.nodes.len()];
    let report = verify_topology(&topo, 2, &zeros);
    assert!(
        !report.with_code("FV001").is_empty(),
        "cleared dateline masks must close a CDG cycle, got:\n{report}"
    );
}

/// The sharpness boundary: 3-long wrapping dimensions never route more
/// than one in-dimension hop, so the directional rings never close —
/// the verifier accepts these at a single VC (with warnings, no
/// errors). This is what keeps `NocConfig::torus(3, 3).with_vcs(1)`
/// building without an escape hatch.
#[test]
fn three_rings_at_one_vc_are_accepted_with_warnings() {
    for (cfg, label) in [
        (NocConfig::torus(3, 3).with_vcs(1), "torus 3x3 @ 1 VC"),
        (NocConfig::ring(3).with_vcs(1), "ring 3 @ 1 VC"),
        (NocConfig::torus(2, 2).with_vcs(1), "torus 2x2 @ 1 VC"),
    ] {
        let report = preflight(&cfg);
        assert!(!report.has_errors(), "{label}: must accept, got:\n{report}");
        assert!(
            report.warning_count() > 0,
            "{label}: the capped dateline lanes must still warn"
        );
    }
}

/// FV106 fires exactly when the input-buffer depth is below the VC
/// count (every lane collapses to the one-slot minimum), names the
/// effective per-lane depth, and stays quiet at depth >= vcs and on the
/// FV103-owned zero-depth case.
#[test]
fn undersized_buffer_depth_lints_fv106() {
    let mut cfg = NocConfig::torus(4, 4); // default: 2 dateline VCs
    cfg.in_buf_depth = 1;
    let report = preflight(&cfg);
    assert!(!report.has_errors(), "a degraded depth is a warning, not an error:\n{report}");
    let findings = report.with_code("FV106");
    assert_eq!(findings.len(), 1, "expected exactly one FV106, got:\n{report}");
    assert!(
        findings[0].message.contains("1 buffer slot"),
        "message must name the effective per-lane depth, got: {}",
        findings[0].message
    );
    cfg.in_buf_depth = 2;
    assert!(
        preflight(&cfg).with_code("FV106").is_empty(),
        "depth == vcs must not lint"
    );
    cfg.in_buf_depth = 0;
    let zero = preflight(&cfg);
    assert!(
        zero.with_code("FV106").is_empty() && !zero.with_code("FV103").is_empty(),
        "zero depth belongs to FV103 alone, got:\n{zero}"
    );
}

/// The machine-readable report carries the stable schema tag and agrees
/// with the programmatic verdict on both sides.
#[test]
fn json_report_schema_is_stable() {
    for (cfg, ok) in [
        (NocConfig::torus(4, 4), true),
        (NocConfig::torus(4, 4).with_vcs(1), false),
    ] {
        let report = preflight(&cfg);
        let j = report.to_json();
        assert_eq!(
            j.get("schema").and_then(floonoc::util::json::Json::as_str),
            Some("floonoc-verify/1")
        );
        assert_eq!(j.get("ok").and_then(floonoc::util::json::Json::as_bool), Some(ok));
    }
    // FV107 travels through the same machine-readable report: code,
    // error severity, and the flipped gate verdict.
    let mut cfg = NocConfig::torus(4, 4).adaptive();
    cfg.vcs = 2;
    let j = preflight(&cfg).to_json();
    assert_eq!(j.get("ok").and_then(floonoc::util::json::Json::as_bool), Some(false));
    let rendered = j.to_string();
    assert!(rendered.contains("FV107"), "FV107 must appear in the JSON report: {rendered}");
}

// ---------------------------------------------------------------------
// Property sweeps over random fabric shapes.
// ---------------------------------------------------------------------

/// Every default-VC fabric of any shape verifies clean: the shipped
/// dateline configuration is deadlock-free by construction, and the
/// verifier never false-positives on it.
#[test]
fn prop_default_vc_fabrics_verify_clean() {
    check_default("default-vc fabrics verify clean", |rng| {
        let cfg = match rng.index(3) {
            0 => NocConfig::mesh(rng.range(2, 8) as u8, rng.range(2, 8) as u8),
            1 => NocConfig::torus(rng.range(2, 8) as u8, rng.range(2, 8) as u8),
            _ => NocConfig::ring(rng.range(2, 32) as u8),
        };
        let report = preflight(&cfg);
        prop_assert!(!report.has_errors(), "default config rejected:\n{report}");
        Ok(())
    });
}

/// Every wrap fabric with a dimension of length >= 4 forced to a single
/// VC is rejected with FV001: minimal routing on a 4-long directional
/// ring exercises every consecutive channel pair, closing the cycle.
#[test]
fn prop_long_wrap_dimension_at_one_vc_is_rejected() {
    check_default("long wrap dimension @ 1 VC rejected", |rng| {
        let base = if rng.chance(0.5) {
            // At least one torus dimension long enough to wrap-cycle.
            let long = rng.range(4, 8) as u8;
            let other = rng.range(2, 8) as u8;
            if rng.chance(0.5) {
                NocConfig::torus(long, other)
            } else {
                NocConfig::torus(other, long)
            }
        } else {
            NocConfig::ring(rng.range(4, 32) as u8)
        };
        let report = preflight(&base.with_vcs(1));
        prop_assert!(
            !report.with_code("FV001").is_empty(),
            "expected an FV001 deadlock finding, got:\n{report}"
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Agreement matrix: static verdict vs watchdog outcome.
// ---------------------------------------------------------------------

/// A saturating wide-wormhole workload: tornado pattern (every flow
/// travels the wrap diameter — the adversarial case for datelines) with
/// full-length bursts on every tile.
fn tornado_workload(sys: NocSystem, wide_txns: u64) -> TiledWorkload {
    let tiles = sys.topo.num_tiles;
    let profiles: Vec<TileTraffic> = (0..tiles)
        .map(|i| TileTraffic {
            core: None,
            dma: Some(GenCfg {
                pattern: Pattern::Tornado,
                num_txns: wide_txns,
                burst_len: 15,
                seed: 0xA62E + i as u64,
                ..GenCfg::dma_burst(NodeId(0), wide_txns, false)
            }),
        })
        .collect();
    TiledWorkload::new(sys, profiles)
}

/// Uniform-random wide + narrow saturation, as in `tests/vc_deadlock.rs`.
fn uniform_workload(sys: NocSystem, wide_txns: u64) -> TiledWorkload {
    let tiles = sys.topo.num_tiles;
    let profiles: Vec<TileTraffic> = (0..tiles)
        .map(|i| TileTraffic {
            core: Some(GenCfg {
                pattern: Pattern::UniformTiles,
                num_txns: 2 * wide_txns,
                seed: 0xA62E + i as u64,
                ..GenCfg::narrow_probe(NodeId(0), 2 * wide_txns)
            }),
            dma: Some(GenCfg {
                pattern: Pattern::UniformTiles,
                num_txns: wide_txns,
                burst_len: 15,
                seed: 0xA62F + i as u64,
                ..GenCfg::dma_burst(NodeId(0), wide_txns, false)
            }),
        })
        .collect();
    TiledWorkload::new(sys, profiles)
}

/// Cycles of zero ejection progress treated as a seizure (rationale in
/// `tests/vc_deadlock.rs`).
const STALL_WINDOW: u64 = 25_000;

/// The agreement matrix. For each configuration the static verdict is
/// computed first; a **clean** verdict must see the saturating workload
/// drain under the watchdog, and a **rejected** verdict (built with the
/// `no_verify` escape hatch) must see the watchdog trip on a genuine
/// wormhole deadlock. The verifier is neither optimistic (rejected
/// configs really do seize) nor just pattern-matching on "wrap + 1 VC"
/// (the accepted 3x3 torus at 1 VC survives the same saturation).
#[test]
fn verifier_verdict_matches_watchdog_outcome() {
    struct Case {
        cfg: NocConfig,
        label: &'static str,
        tornado: bool,
    }
    let cases = vec![
        Case {
            cfg: NocConfig::mesh(3, 3),
            label: "mesh 3x3 default",
            tornado: false,
        },
        Case {
            cfg: NocConfig::torus(3, 3),
            label: "torus 3x3 default",
            tornado: true,
        },
        Case {
            cfg: NocConfig::ring(6),
            label: "ring 6 default",
            tornado: false,
        },
        Case {
            cfg: NocConfig::torus(3, 3).with_vcs(1),
            label: "torus 3x3 @ 1 VC (sharp accept)",
            tornado: true,
        },
        Case {
            cfg: NocConfig::torus(4, 4).with_vcs(1),
            label: "torus 4x4 @ 1 VC",
            tornado: true,
        },
        Case {
            cfg: NocConfig::ring(8).with_vcs(1),
            label: "ring 8 @ 1 VC",
            tornado: true,
        },
    ];
    for case in cases {
        let verdict_clean = !preflight(&case.cfg).has_errors();
        let sys = NocSystem::new(case.cfg.no_verify());
        let mut w = if case.tornado {
            tornado_workload(sys, 3)
        } else {
            uniform_workload(sys, 3)
        };
        let outcome = w.run_with_watchdog(5_000_000, STALL_WINDOW);
        match (verdict_clean, outcome) {
            (true, Ok(true)) => {}
            (false, Err(_)) => {}
            (true, bad) => panic!(
                "{}: verifier accepted but the workload did not drain ({bad:?})\n{}",
                case.label,
                w.stall_analysis()
            ),
            (false, bad) => panic!(
                "{}: verifier rejected but the watchdog saw no deadlock ({bad:?})",
                case.label
            ),
        }
        if verdict_clean {
            assert!(w.protocol_ok(), "{}: AXI protocol violations", case.label);
        }
    }
}
