//! Differential proof that activity-gated stepping ([`SimMode::Gated`])
//! and event-driven fast-forward stepping ([`SimMode::Event`]) are both
//! cycle-accurately **byte-identical** to the dense reference sweep
//! ([`SimMode::Dense`]).
//!
//! Methodology (see `docs/performance.md`): the same seeded workload is
//! run to completion three times — once per [`SimMode`] — and every
//! observable counter in the system is serialized into one digest
//! string: total cycles, per-network flit-conservation counters,
//! per-link delivered/stall/busy counters, per-router-per-port
//! forwarding counters, per-node target statistics and per-tile
//! generator completions and latency aggregates. All digests must be
//! equal to the byte. Any divergence — a component skipped while it had
//! work, a wake edge firing a cycle early or late, a fast-forward
//! jumping over a cycle that was not actually a no-op — shows up as a
//! counter mismatch somewhere in this digest.
//!
//! The grid covers all three fabrics × three traffic patterns (uniform
//! random, tornado, nearest-neighbor) × both link modes, which together
//! exercise XY mesh routing, both directions of every wraparound link,
//! wormhole bursts across pipelined links, and long quiescent stretches
//! between bursts. A second grid reruns every fabric under
//! minimal-adaptive routing (tornado traffic, escape + adaptive VC
//! lanes) — congestion-scored port selection must also be a pure
//! function of simulator state. The three-way runner itself is shared
//! (`common::assert_modes_equivalent`) with the seeded randomized sweep
//! in `mode_equivalence_sweep.rs`.

use floonoc::cluster::{TileTraffic, TiledWorkload};
use floonoc::flit::NodeId;
use floonoc::noc::{NocConfig, NocSystem};
use floonoc::sim::SimMode;
use floonoc::topology::TopologyKind;
use floonoc::traffic::{GenCfg, Pattern};

mod common;
use common::assert_modes_equivalent;

/// 9-tile fabric of `kind` (3×3 for mesh/torus, 9-ring), mode selected.
fn fabric(kind: TopologyKind, mode: SimMode) -> NocSystem {
    NocSystem::new(NocConfig::fabric(kind, 3, 3).with_sim_mode(mode))
}

/// The differential workload: every tile runs seeded narrow traffic with
/// the pattern under test plus a few uniform-random wide DMA bursts —
/// multi-hop wide wormholes are deadlock-safe on the wrap fabrics now
/// that torus/ring default to 2 dateline VCs (docs/deadlock.md), so the
/// differential grid exercises the VC-aware switch (per-lane wake edges,
/// per-VC locks, dateline switches) on every wrap fabric cell.
/// Bursty-with-gaps by construction: the narrow generators finish at
/// different times, leaving long quiescent stretches that exercise the
/// gating/pruning paths — and give the event engine real idle windows
/// to fast-forward over — not just saturation.
fn workload(kind: TopologyKind, pattern: Pattern, mode: SimMode) -> TiledWorkload {
    let sys = fabric(kind, mode);
    let tiles = sys.topo.num_tiles;
    let profiles: Vec<TileTraffic> = (0..tiles)
        .map(|i| TileTraffic {
            core: Some(GenCfg {
                pattern,
                num_txns: 12,
                seed: 0xBEEF + i as u64,
                ..GenCfg::narrow_probe(NodeId(0), 12)
            }),
            dma: Some(GenCfg {
                pattern: Pattern::UniformTiles,
                num_txns: 3,
                burst_len: 7,
                seed: 0xD0A + i as u64,
                ..GenCfg::dma_burst(NodeId(0), 3, false)
            }),
        })
        .collect();
    TiledWorkload::new(sys, profiles)
}

fn assert_equivalent(kind: TopologyKind, pattern: Pattern) {
    assert_modes_equivalent(&format!("{kind:?}/{pattern:?}"), 2_000_000, |mode| {
        workload(kind, pattern, mode)
    });
}

const PATTERNS: [Pattern; 3] = [
    Pattern::UniformTiles,
    Pattern::Tornado,
    Pattern::NearestNeighbor,
];

#[test]
fn mesh_gated_equals_dense_across_patterns() {
    for p in PATTERNS {
        assert_equivalent(TopologyKind::Mesh, p);
    }
}

#[test]
fn torus_gated_equals_dense_across_patterns() {
    for p in PATTERNS {
        assert_equivalent(TopologyKind::Torus, p);
    }
}

#[test]
fn ring_gated_equals_dense_across_patterns() {
    for p in PATTERNS {
        assert_equivalent(TopologyKind::Ring, p);
    }
}

/// The adaptive-routing differential workload: same shape as
/// [`workload`] but the narrow generators drive tornado (the pattern
/// adaptivity actually spreads) and the fabric runs minimal-adaptive
/// routing over `vcs` lanes. Congestion scoring reads only
/// producer-side credit registers, so the chosen output port must be a
/// pure function of simulator state — any engine- or shard-dependent
/// read would flip a grant and split the digests.
fn adaptive_workload(kind: TopologyKind, vcs: usize, mode: SimMode) -> TiledWorkload {
    let sys = NocSystem::new(
        NocConfig::fabric(kind, 3, 3)
            .adaptive()
            .with_vcs(vcs)
            .with_sim_mode(mode),
    );
    let tiles = sys.topo.num_tiles;
    let profiles: Vec<TileTraffic> = (0..tiles)
        .map(|i| TileTraffic {
            core: Some(GenCfg {
                pattern: Pattern::Tornado,
                num_txns: 12,
                seed: 0xBEEF + i as u64,
                ..GenCfg::narrow_probe(NodeId(0), 12)
            }),
            dma: Some(GenCfg {
                pattern: Pattern::UniformTiles,
                num_txns: 3,
                burst_len: 7,
                seed: 0xD0A + i as u64,
                ..GenCfg::dma_burst(NodeId(0), 3, false)
            }),
        })
        .collect();
    TiledWorkload::new(sys, profiles)
}

/// Adaptive routing through the full differential grid: every fabric at
/// its minimum legal adaptive VC count (escape lanes + 1) and at the
/// maximum (4), under dense / gated / event stepping.
#[test]
fn adaptive_routing_gated_equals_dense_across_fabrics() {
    for (kind, vcs) in [
        (TopologyKind::Mesh, 2),
        (TopologyKind::Mesh, 4),
        (TopologyKind::Torus, 3),
        (TopologyKind::Torus, 4),
        (TopologyKind::Ring, 3),
        (TopologyKind::Ring, 4),
    ] {
        assert_modes_equivalent(&format!("adaptive/{kind:?}/vcs{vcs}"), 2_000_000, |mode| {
            adaptive_workload(kind, vcs, mode)
        });
    }
}

/// Wide-only baseline link configuration through the same differential
/// harness: the gating and fast-forward must be mode-agnostic (two
/// networks, merged response classes, W beats on the request net).
#[test]
fn wide_only_mode_gated_equals_dense() {
    assert_modes_equivalent("wide-only/3x3", 2_000_000, |mode| {
        let sys = NocSystem::new(NocConfig::mesh(3, 3).wide_only().with_sim_mode(mode));
        let tiles = sys.topo.num_tiles;
        let profiles: Vec<TileTraffic> = (0..tiles)
            .map(|i| TileTraffic {
                core: Some(GenCfg {
                    pattern: Pattern::UniformTiles,
                    num_txns: 8,
                    seed: 0xFACE + i as u64,
                    ..GenCfg::narrow_probe(NodeId(0), 8)
                }),
                dma: Some(GenCfg {
                    pattern: Pattern::Neighbor,
                    num_txns: 2,
                    seed: 0xCAFE + i as u64,
                    write_fraction: 1.0,
                    ..GenCfg::dma_burst(NodeId(0), 2, true)
                }),
            })
            .collect();
        TiledWorkload::new(sys, profiles)
    });
}

/// Pipelined multi-stage links under gating: with deeper output
/// pipelines (buffer islands on long routing channels) a flit spends
/// several cycles in stages where *only* the link occupancy — not any
/// router input — proves the network busy. If the active set dropped
/// those links, the flit would strand and the run would time out; if the
/// event engine skipped while a stage was occupied, the in-flight
/// counter guard would have to be wrong. The digest equality
/// additionally pins exact timing.
#[test]
fn pipelined_links_gated_equals_dense() {
    assert_modes_equivalent("pipelined/3x1", 200_000, |mode| {
        let mut cfg = NocConfig::mesh(3, 1).with_sim_mode(mode);
        cfg.in_buf_depth = 1; // tight buffers: maximum backpressure
        let sys = NocSystem::new(cfg);
        let profiles = vec![
            TileTraffic {
                core: Some(GenCfg {
                    pattern: Pattern::FixedDst(NodeId(2)),
                    ..GenCfg::narrow_probe(NodeId(2), 6)
                }),
                dma: Some(GenCfg::dma_burst(NodeId(2), 2, false)),
            },
            TileTraffic::idle(),
            TileTraffic::idle(),
        ];
        TiledWorkload::new(sys, profiles)
    });
}
